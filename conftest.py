"""Make the build-time `compile` package importable when pytest runs from
the repository root (tests also run from python/ via `make test`)."""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
