#!/usr/bin/env sh
# PR-2 speedup measurement, per the protocol in rust/DESIGN.md:
#
#   DFLOP_THREADS=1 single-thread wall-clock of optimizer_bench and
#   pipeline_bench, current tree vs the pre-PR binary, same machine.
#
# Usage:  rust/scripts/bench_pr2.sh [<baseline-ref>]
#
# <baseline-ref> defaults to HEAD~1 (the commit before the PR-2 squash).
# The baseline is built in a temporary git worktree so the working tree is
# never touched. Results land in:
#
#   BENCH_PR2.json           — current tree (machine-readable, merged rows)
#   BENCH_PR2.baseline.json  — baseline ref (same schema)
#
# The current tree's pipeline_bench additionally carries the in-binary
# pair "1F1B engine …" (event-driven core) vs "1F1B polling oracle
# (pre-PR2 baseline)" — a cross-check of the same speedup that needs no
# second build.
set -eu

ref="${1:-HEAD~1}"
root="$(git rev-parse --show-toplevel)"
cd "$root"

echo "== current tree =="
rm -f BENCH_PR2.json
DFLOP_THREADS=1 DFLOP_BENCH_JSON="$root/BENCH_PR2.json" \
    cargo bench --bench optimizer_bench --bench pipeline_bench

echo "== baseline ($ref) =="
tmp="$(mktemp -d)"
trap 'git worktree remove --force "$tmp/baseline" 2>/dev/null || true; rm -rf "$tmp"' EXIT
git worktree add --detach "$tmp/baseline" "$ref"
rm -f BENCH_PR2.baseline.json
# Older refs may predate DFLOP_BENCH_JSON support; fall back to the
# printed table in that case (the env var is simply ignored there).
(cd "$tmp/baseline" && DFLOP_THREADS=1 DFLOP_BENCH_JSON="$root/BENCH_PR2.baseline.json" \
    cargo bench --bench optimizer_bench --bench pipeline_bench)

echo
echo "Wrote BENCH_PR2.json (current) and BENCH_PR2.baseline.json ($ref)."
echo "Speedup = baseline mean_s / current mean_s per matching bench row."
