#!/usr/bin/env sh
# Bench-regression gate: regenerate the bench document and check the
# named in-binary speedup claims with dflop-bench-compare — including the
# PR-7 fault-fleet acceptance pair (fault-aware strictly faster mean step
# and strictly smaller worst straggler gap than static θ* under the same
# skewed-churn FaultTrace), the PR-8 observability pair (recorder-on
# mean step within 1.02× of recorder-off on the same fleet — bit-identical
# by contract), and the PR-9 audit pair (counterfactual pricing via delta
# replay at ≤ ½× a fresh re-sim over the same 64 batches — bit-identical
# by the pricer's own in-bench assertion), and the PR-10 interleaving
# pairs (bubble-filling execution strictly faster mean step AND strictly
# smaller bubble fraction than plain DFLOP on the video mixture —
# simulated seconds from paired runs under a provably-optimal ILP
# regime).
#
# Usage:  rust/scripts/bench_gate.sh [<out.json>]
#
# <out.json> defaults to BENCH_PR10.json at the repository root. The run is
# single-threaded (override with DFLOP_THREADS) and quick-mode by default
# so CI finishes in seconds; set FULL=1 for stable full-rep statistics.
# Alongside the merged document, per-target BENCH_<target>.json files are
# written next to it (DFLOP_BENCH_JSON_DIR), keeping rows comparable with
# the single-target artifacts older PRs uploaded.
#
# Exit status is dflop-bench-compare's: 0 all expectations hold, 1 a
# claimed speedup regressed, 2 the document is missing rows or malformed.
set -eu

root="$(git rev-parse --show-toplevel)"
cd "$root"
out="${1:-$root/BENCH_PR10.json}"
case "$out" in
    /*) ;;
    *) out="$root/$out" ;;
esac

quick="1"
[ "${FULL:-0}" = "1" ] && quick=""

rm -f "$out"
DFLOP_THREADS="${DFLOP_THREADS:-1}" \
    DFLOP_BENCH_QUICK="$quick" \
    DFLOP_BENCH_JSON="$out" \
    DFLOP_BENCH_JSON_DIR="$(dirname "$out")" \
    cargo bench

cargo run --release --bin dflop-bench-compare -- "$out"
