//! The Profiling Engine (§3.2): measurement backends, interpolation,
//! the Model Profiler, the Data Profiler, and per-item duration estimation.
pub mod backend;
pub mod engine;
pub mod estimator;
pub mod interp;

pub use backend::{MeasureBackend, SimBackend};
pub use engine::{profile_data, DataProfile, ModelProfile, ModelProfiler, ProfilerGrids};
pub use estimator::Estimator;
