//! The Profiling Engine (§3.2): Model Profiler + Data Profiler.
//!
//! The Model Profiler sweeps a synthetic shape × TP grid through a
//! [`MeasureBackend`] and fits the interpolation models the optimizer and
//! scheduler consume: `E_thr`, `L_lin_thr`, `L_attn_thr` (throughput) and
//! `model_state` / `act_state` (memory). The Data Profiler samples the
//! training dataset and builds the empirical input-shape distribution.
//!
//! Both are *offline* components; their wall-clock is tracked and reported
//! as the one-time overhead of Table 4. The Model Profiler's shape × TP
//! grid is swept per-TP-column on the `util::parallel` pool when the
//! backend can fork independent measurement streams (fits stay
//! bit-identical at any thread count); the *online* continuation of this
//! engine — windowed live statistics, drift detection, replanning — lives
//! in the `stream` subsystem.

use crate::data::dataset::Dataset;
use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::profiling::backend::MeasureBackend;
use crate::profiling::interp::{Interp1D, Linear2, PerTp};
use crate::util::parallel::par_map;
use crate::util::stats::{Histogram, Summary};
use std::sync::Mutex;

/// Fitted throughput models (per-GPU achieved FLOP/s).
#[derive(Clone, Debug)]
pub struct ThroughputModel {
    /// `E_thr(effective_batch, tp)`.
    pub e_thr: PerTp,
    /// `L_lin_thr(packed_total_tokens, tp)`.
    pub l_lin_thr: PerTp,
    /// `L_attn_thr(seq_len, tp)`.
    pub l_attn_thr: PerTp,
    /// Fixed fwd+bwd overhead per (microbatch × stage) execution for each
    /// module, per TP degree — the intercept of the affine time-in-layers
    /// fit at two small layer counts (§3.2.1's two-layer-count probes).
    pub enc_stage_overhead: Vec<(usize, f64)>,
    pub llm_stage_overhead: Vec<(usize, f64)>,
}

impl ThroughputModel {
    fn lookup_ovh(v: &[(usize, f64)], tp: usize) -> f64 {
        if let Some(&(_, o)) = v.iter().find(|(t, _)| *t == tp) {
            return o;
        }
        // An unprofiled TP degree used to silently price as zero overhead,
        // systematically underestimating unprofiled plans. Fall back to
        // the nearest profiled degree instead (ties toward the smaller
        // one — overheads grow with TP, so the conservative neighbour).
        debug_assert!(!v.is_empty(), "empty per-stage overhead table");
        v.iter()
            .min_by_key(|(t, _)| (t.abs_diff(tp), *t))
            .map(|&(_, o)| o)
            .unwrap_or(0.0)
    }

    /// Per-stage fixed overhead (seconds, fwd+bwd) for the encoder / LLM.
    pub fn enc_overhead(&self, tp: usize) -> f64 {
        Self::lookup_ovh(&self.enc_stage_overhead, tp)
    }

    pub fn llm_overhead(&self, tp: usize) -> f64 {
        Self::lookup_ovh(&self.llm_stage_overhead, tp)
    }
}

/// Fitted memory models. The paper fits linear models from measurements at
/// two distinct small layer counts per TP degree (§3.2.1 Memory Profiling).
#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// `model_state_E(layers)` per TP degree.
    e_state: Vec<(usize, Linear2)>,
    /// `model_state_L(layers)` per TP degree.
    l_state: Vec<(usize, Linear2)>,
    /// Activation bytes per (layer · unit) for the encoder, per TP degree.
    e_act_coeff: Vec<(usize, f64)>,
    /// Activation bytes per (layer · token) for the LLM, per TP degree.
    l_act_coeff: Vec<(usize, f64)>,
}

fn lookup<T: Copy>(v: &[(usize, T)], tp: usize) -> T {
    v.iter()
        .find(|(t, _)| *t == tp)
        .unwrap_or_else(|| panic!("TP degree {tp} not in memory model"))
        .1
}

impl MemoryModel {
    /// `model_state_E(l, E_tp)` (Eq 4).
    pub fn e_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        lookup(&self.e_state, tp).eval(layers).max(0.0)
    }

    /// `model_state_L(l, L_tp)` (Eq 5).
    pub fn l_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        lookup(&self.l_state, tp).eval(layers).max(0.0)
    }

    /// `act_state_E(l, E_tp, batch, seq)` — seq is fixed per architecture,
    /// so the shape argument is the effective batch in units.
    pub fn e_act_bytes(&self, layers: f64, tp: usize, units: f64) -> f64 {
        lookup(&self.e_act_coeff, tp) * layers * units
    }

    /// `act_state_L(l, L_tp, 1, seq)`.
    pub fn l_act_bytes(&self, layers: f64, tp: usize, seq: f64) -> f64 {
        lookup(&self.l_act_coeff, tp) * layers * seq
    }
}

/// Everything the Model Profiler produces.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub model_name: String,
    pub throughput: ThroughputModel,
    pub memory: MemoryModel,
    /// Simulated/measured wall-clock of the profiling run (Table 4).
    pub profiling_seconds: f64,
}

/// Default measurement grids. Shape axes are geometric (the behaviours
/// being captured are saturation curves); TP covers powers of two up to the
/// node size (Eq 2).
pub struct ProfilerGrids {
    pub units: Vec<f64>,
    pub llm_tokens: Vec<f64>,
    pub tps: Vec<usize>,
}

impl ProfilerGrids {
    pub fn standard(gpus_per_node: usize) -> ProfilerGrids {
        let mut tps = Vec::new();
        let mut t = 1;
        while t <= gpus_per_node {
            tps.push(t);
            t *= 2;
        }
        ProfilerGrids {
            units: vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0],
            llm_tokens: vec![
                128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 16384.0, 32768.0,
            ],
            tps,
        }
    }

    /// A coarser grid for quick tests.
    pub fn coarse(gpus_per_node: usize) -> ProfilerGrids {
        let mut g = Self::standard(gpus_per_node);
        g.units = vec![1.0, 8.0, 64.0];
        g.llm_tokens = vec![256.0, 4096.0, 32768.0];
        g
    }
}

/// The Model Profiler (§3.2.1).
pub struct ModelProfiler<'a, B: MeasureBackend> {
    pub backend: &'a mut B,
    pub grids: ProfilerGrids,
}

/// Everything the profiler measures and fits for one TP degree. TP
/// columns are mutually independent (each probes only its own degree),
/// which is what makes the grid embarrassingly parallel.
struct TpColumn {
    e_curve: Interp1D,
    lin_curve: Interp1D,
    attn_curve: Interp1D,
    enc_ovh: f64,
    llm_ovh: f64,
    e_state: Linear2,
    l_state: Linear2,
    e_act_coeff: f64,
    l_act_coeff: f64,
}

/// Measure one TP degree's full column: throughput grids, the affine
/// overhead probes, and the memory probes — the exact probe set and
/// arithmetic of the original serial sweep, so fits are bit-identical
/// regardless of how columns are distributed over workers.
fn measure_tp<B: MeasureBackend>(
    backend: &mut B,
    m: &Mllm,
    grids: &ProfilerGrids,
    tp: usize,
) -> TpColumn {
    // ---- throughput grids ----
    let e_ys: Vec<f64> = grids
        .units
        .iter()
        .map(|&u| backend.encoder_throughput(m, u, tp))
        .collect();
    let lin_ys: Vec<f64> = grids
        .llm_tokens
        .iter()
        .map(|&s| backend.llm_linear_throughput(m, s, tp))
        .collect();
    let attn_ys: Vec<f64> = grids
        .llm_tokens
        .iter()
        .map(|&s| backend.llm_attn_throughput(m, s, tp))
        .collect();

    // ---- per-stage fixed overhead: affine fit over layer count ----
    // time(l) = c·l + b  ⇒  b = 2·t(l0) − t(2·l0).
    let (l0, units_ref, seq_ref) = (4.0, 8.0, 2048.0);
    let te1 = backend.encoder_time_at(m, units_ref, l0, tp);
    let te2 = backend.encoder_time_at(m, units_ref, 2.0 * l0, tp);
    let tl1 = backend.llm_time_at(m, seq_ref, l0, tp);
    let tl2 = backend.llm_time_at(m, seq_ref, 2.0 * l0, tp);

    // ---- memory: two small layer counts, linear in layers ----
    let (m0, m1) = (2.0, 4.0);
    let es0 = backend.encoder_state_bytes(m, m0, tp);
    let es1 = backend.encoder_state_bytes(m, m1, tp);
    let ls0 = backend.llm_state_bytes(m, m0, tp);
    let ls1 = backend.llm_state_bytes(m, m1, tp);
    // Activations are linear in (layers × shape): fit the coefficient
    // from one probe, sanity-checked by a second.
    let probe_units = 8.0;
    let ea = backend.encoder_act_bytes(m, m1, tp, probe_units);
    let probe_seq = 4096.0;
    let la = backend.llm_act_bytes(m, m1, tp, probe_seq);

    TpColumn {
        e_curve: Interp1D::new(grids.units.clone(), e_ys),
        lin_curve: Interp1D::new(grids.llm_tokens.clone(), lin_ys),
        attn_curve: Interp1D::new(grids.llm_tokens.clone(), attn_ys),
        enc_ovh: (2.0 * te1 - te2).max(0.0),
        llm_ovh: (2.0 * tl1 - tl2).max(0.0),
        e_state: Linear2::fit(m0, es0, m1, es1),
        l_state: Linear2::fit(m0, ls0, m1, ls1),
        e_act_coeff: ea / (m1 * probe_units),
        l_act_coeff: la / (m1 * probe_seq),
    }
}

impl<'a, B: MeasureBackend> ModelProfiler<'a, B> {
    pub fn new(backend: &'a mut B, grids: ProfilerGrids) -> Self {
        ModelProfiler { backend, grids }
    }

    /// Run the full shape × TP grid and fit all models.
    ///
    /// When the backend can fork independent measurement streams
    /// ([`MeasureBackend::fork`]), the per-TP columns are measured
    /// concurrently on the `util::parallel` pool — the grid is the
    /// dominant cost of every `run_system` cell's offline phase. Fit
    /// assembly happens in grid (TP) order and fork wall-clocks are
    /// joined in the same order, so the profile is bit-identical at any
    /// `--threads` setting; non-forkable backends get the serial sweep.
    pub fn profile(&mut self, m: &Mllm) -> ModelProfile
    where
        B: Send,
    {
        let start = self.backend.measured_seconds();
        let tps = self.grids.tps.clone();

        // One fork per TP column, created serially up front; any refusal
        // falls back to the serial sweep (partial forks carry no
        // wall-clock, so dropping them loses nothing).
        let mut forks: Vec<B> = Vec::with_capacity(tps.len());
        let mut splittable = true;
        for _ in &tps {
            match self.backend.fork() {
                Some(b) => forks.push(b),
                None => {
                    splittable = false;
                    break;
                }
            }
        }

        let columns: Vec<TpColumn> = if splittable {
            let slots: Vec<Mutex<Option<B>>> =
                forks.into_iter().map(|b| Mutex::new(Some(b))).collect();
            let grids = &self.grids;
            let measured: Vec<(TpColumn, B)> = par_map(tps.len(), |i| {
                let mut b = slots[i]
                    .lock()
                    .expect("fork slot lock")
                    .take()
                    .expect("each slot is taken exactly once");
                let col = measure_tp(&mut b, m, grids, tps[i]);
                (col, b)
            });
            let mut cols = Vec::with_capacity(tps.len());
            for (col, b) in measured {
                self.backend.join(b);
                cols.push(col);
            }
            cols
        } else {
            tps.iter()
                .map(|&tp| measure_tp(&mut *self.backend, m, &self.grids, tp))
                .collect()
        };

        // ---- assemble fits in TP-grid order ----
        let mut e_curves = Vec::with_capacity(tps.len());
        let mut lin_curves = Vec::with_capacity(tps.len());
        let mut attn_curves = Vec::with_capacity(tps.len());
        let mut enc_ovh = Vec::with_capacity(tps.len());
        let mut llm_ovh = Vec::with_capacity(tps.len());
        let mut e_state = Vec::with_capacity(tps.len());
        let mut l_state = Vec::with_capacity(tps.len());
        let mut e_act_coeff = Vec::with_capacity(tps.len());
        let mut l_act_coeff = Vec::with_capacity(tps.len());
        for (&tp, col) in tps.iter().zip(columns) {
            e_curves.push((tp, col.e_curve));
            lin_curves.push((tp, col.lin_curve));
            attn_curves.push((tp, col.attn_curve));
            enc_ovh.push((tp, col.enc_ovh));
            llm_ovh.push((tp, col.llm_ovh));
            e_state.push((tp, col.e_state));
            l_state.push((tp, col.l_state));
            e_act_coeff.push((tp, col.e_act_coeff));
            l_act_coeff.push((tp, col.l_act_coeff));
        }

        ModelProfile {
            model_name: m.name.to_string() + "/" + m.llm.name,
            throughput: ThroughputModel {
                e_thr: PerTp::new(e_curves),
                l_lin_thr: PerTp::new(lin_curves),
                l_attn_thr: PerTp::new(attn_curves),
                enc_stage_overhead: enc_ovh,
                llm_stage_overhead: llm_ovh,
            },
            memory: MemoryModel { e_state, l_state, e_act_coeff, l_act_coeff },
            profiling_seconds: self.backend.measured_seconds() - start,
        }
    }
}

/// Empirical workload statistics from the Data Profiler (§3.2.2).
#[derive(Clone, Debug)]
pub struct DataProfile {
    pub dataset_name: String,
    pub model_name: String,
    /// The sampled shapes themselves — the optimizer evaluates the expected
    /// makespan over this set (Eq 1's D).
    pub samples: Vec<ItemShape>,
    pub units_summary: Summary,
    pub seq_summary: Summary,
    pub units_hist: Histogram,
    pub seq_hist: Histogram,
    /// Wall-clock of the sampling pass (Table 4).
    pub profiling_seconds: f64,
}

impl DataProfile {
    /// Assemble a profile from already-collected shape samples — shared
    /// by the offline Data Profiler ([`profile_data`]) and the stream
    /// subsystem's live refit (`stream::replan::live_profile`), so the
    /// offline reference and the online recharacterization can never
    /// diverge structurally.
    pub fn from_samples(
        dataset_name: &str,
        m: &Mllm,
        samples: Vec<ItemShape>,
        profiling_seconds: f64,
    ) -> DataProfile {
        assert!(!samples.is_empty(), "DataProfile::from_samples on empty sample set");
        let units: Vec<f64> = samples.iter().map(|s| s.units as f64).collect();
        let seqs: Vec<f64> = samples.iter().map(|s| s.llm_seq as f64).collect();
        DataProfile {
            dataset_name: dataset_name.to_string(),
            model_name: m.name.to_string() + "/" + m.llm.name,
            units_hist: Histogram::of(&units, 32),
            seq_hist: Histogram::of(&seqs, 32),
            units_summary: Summary::of(&units),
            seq_summary: Summary::of(&seqs),
            samples,
            profiling_seconds,
        }
    }

    pub fn mean_units(&self) -> f64 {
        self.units_summary.mean
    }

    pub fn mean_seq(&self) -> f64 {
        self.seq_summary.mean
    }
}

/// The Data Profiler: random-samples the dataset and computes the precise
/// per-item input shapes under the target architecture.
pub fn profile_data(m: &Mllm, dataset: &mut Dataset, n_samples: usize) -> DataProfile {
    let t0 = std::time::Instant::now();
    let samples = dataset.shaped_batch(m, n_samples);
    // Charge a simulated per-item preprocessing cost (tokenization + image
    // shape math) so the reported Data Profiler overhead is in the paper's
    // band (~1.5 min for a full corpus sample) rather than the synthetic
    // generator's microseconds.
    let simulated = n_samples as f64 * 0.018;
    let name = dataset.name.clone();
    DataProfile::from_samples(&name, m, samples, simulated + t0.elapsed().as_secs_f64())
}

/// Re-profiling conditions (§3.2.3): the Model Profiler is keyed by the
/// model architecture; the Data Profiler by (model, dataset).
#[derive(Default, Debug)]
pub struct ReprofilePolicy {
    last_model: Option<String>,
    last_data: Option<(String, String)>,
}

impl ReprofilePolicy {
    /// Does the model profile need to be rebuilt for `model_key`?
    pub fn model_needs(&mut self, model_key: &str) -> bool {
        let stale = self.last_model.as_deref() != Some(model_key);
        self.last_model = Some(model_key.to_string());
        stale
    }

    /// Does the data profile need to be rebuilt for (model, dataset)?
    pub fn data_needs(&mut self, model_key: &str, dataset_key: &str) -> bool {
        let key = (model_key.to_string(), dataset_key.to_string());
        let stale = self.last_data.as_ref() != Some(&key);
        self.last_data = Some(key);
        stale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;

    fn profile_smooth() -> (ModelProfile, Mllm, Truth) {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(truth.clone());
        let mut profiler =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8));
        (profiler.profile(&m), m, truth)
    }

    #[test]
    fn overhead_lookup_falls_back_to_nearest_profiled_tp() {
        let (p, _, _) = profile_smooth();
        // The standard grid profiles TP ∈ {1, 2, 4, 8}. Unprofiled
        // degrees must price as the nearest profiled one (ties toward
        // the smaller), never as zero.
        assert_eq!(
            p.throughput.enc_overhead(3).to_bits(),
            p.throughput.enc_overhead(2).to_bits()
        );
        assert_eq!(
            p.throughput.llm_overhead(6).to_bits(),
            p.throughput.llm_overhead(4).to_bits(),
            "tie |6-4| = |6-8| must resolve to the smaller degree"
        );
        assert_eq!(
            p.throughput.enc_overhead(16).to_bits(),
            p.throughput.enc_overhead(8).to_bits()
        );
        assert!(p.throughput.llm_overhead(6) > 0.0, "fallback must not be zero");
    }

    /// Wrapper that refuses to fork: forces the profiler's serial sweep.
    struct NoFork(SimBackend);

    impl MeasureBackend for NoFork {
        fn encoder_throughput(&mut self, m: &Mllm, units: f64, tp: usize) -> f64 {
            self.0.encoder_throughput(m, units, tp)
        }
        fn llm_linear_throughput(&mut self, m: &Mllm, total: f64, tp: usize) -> f64 {
            self.0.llm_linear_throughput(m, total, tp)
        }
        fn llm_attn_throughput(&mut self, m: &Mllm, seq: f64, tp: usize) -> f64 {
            self.0.llm_attn_throughput(m, seq, tp)
        }
        fn encoder_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64 {
            self.0.encoder_state_bytes(m, layers, tp)
        }
        fn llm_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64 {
            self.0.llm_state_bytes(m, layers, tp)
        }
        fn encoder_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, units: f64) -> f64 {
            self.0.encoder_act_bytes(m, layers, tp, units)
        }
        fn llm_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, seq: f64) -> f64 {
            self.0.llm_act_bytes(m, layers, tp, seq)
        }
        fn encoder_time_at(&mut self, m: &Mllm, units: f64, layers: f64, tp: usize) -> f64 {
            self.0.encoder_time_at(m, units, layers, tp)
        }
        fn llm_time_at(&mut self, m: &Mllm, total: f64, layers: f64, tp: usize) -> f64 {
            self.0.llm_time_at(m, total, layers, tp)
        }
        fn measured_seconds(&self) -> f64 {
            self.0.measured_seconds()
        }
    }

    #[test]
    fn parallel_grid_fits_bit_match_serial_sweep() {
        // The forked (pool) sweep and the forced-serial sweep must
        // produce identical fits everywhere the models are evaluated.
        let truth = Truth::new(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut forked_backend = SimBackend::new(truth.clone());
        let forked =
            ModelProfiler::new(&mut forked_backend, ProfilerGrids::standard(8)).profile(&m);
        let mut serial_backend = NoFork(SimBackend::new(truth));
        let serial =
            ModelProfiler::new(&mut serial_backend, ProfilerGrids::standard(8)).profile(&m);
        for &tp in &[1usize, 2, 4, 8] {
            for &u in &[1.0, 3.0, 8.0, 77.0, 128.0] {
                assert_eq!(
                    forked.throughput.e_thr.eval(u, tp).to_bits(),
                    serial.throughput.e_thr.eval(u, tp).to_bits(),
                    "e_thr({u}, {tp})"
                );
            }
            for &s in &[128.0, 700.0, 4096.0, 20_000.0] {
                assert_eq!(
                    forked.throughput.l_lin_thr.eval(s, tp).to_bits(),
                    serial.throughput.l_lin_thr.eval(s, tp).to_bits()
                );
                assert_eq!(
                    forked.throughput.l_attn_thr.eval(s, tp).to_bits(),
                    serial.throughput.l_attn_thr.eval(s, tp).to_bits()
                );
            }
            assert_eq!(
                forked.throughput.enc_overhead(tp).to_bits(),
                serial.throughput.enc_overhead(tp).to_bits()
            );
            assert_eq!(
                forked.throughput.llm_overhead(tp).to_bits(),
                serial.throughput.llm_overhead(tp).to_bits()
            );
            assert_eq!(
                forked.memory.l_state_bytes(16.0, tp).to_bits(),
                serial.memory.l_state_bytes(16.0, tp).to_bits()
            );
            assert_eq!(
                forked.memory.e_act_bytes(4.0, tp, 8.0).to_bits(),
                serial.memory.e_act_bytes(4.0, tp, 8.0).to_bits()
            );
        }
        // Same probe set ⇒ same total measurement wall-clock (joined in
        // grid order, so parallelism cannot change the sum's terms).
        assert!(
            (forked.profiling_seconds / serial.profiling_seconds - 1.0).abs() < 1e-9,
            "wall-clock accounting diverged: {} vs {}",
            forked.profiling_seconds,
            serial.profiling_seconds
        );
    }

    #[test]
    fn interpolation_matches_truth_on_grid_points() {
        let (p, m, truth) = profile_smooth();
        for &tp in &[1usize, 2, 4, 8] {
            for &u in &[1.0, 8.0, 64.0] {
                let pred = p.throughput.e_thr.eval(u, tp);
                let actual = truth.encoder_throughput(&m, u, tp);
                assert!(
                    (pred / actual - 1.0).abs() < 1e-9,
                    "tp {tp} units {u}: {pred} vs {actual}"
                );
            }
        }
    }

    #[test]
    fn interpolation_close_off_grid_for_smooth_truth() {
        let (p, m, truth) = profile_smooth();
        // Off-grid points: linear interpolation of a smooth saturating
        // curve should be within a few percent.
        for &seq in &[700.0, 3000.0, 12000.0] {
            let pred = p.throughput.l_lin_thr.eval(seq, 2);
            let layers = m.llm.layers as f64;
            let t = truth.llm_linear_time(&m, seq, layers, 2);
            let lin = m.llm.linear_flop_fwd(seq, layers, m.llm_mlp_matrices) * 3.0;
            let actual = lin / t / 2.0;
            let err = (pred / actual - 1.0).abs();
            assert!(err < 0.05, "seq {seq}: err {err}");
        }
    }

    #[test]
    fn memory_model_recovers_closed_forms() {
        let (p, m, _) = profile_smooth();
        for &tp in &[1usize, 4] {
            let pred = p.memory.l_state_bytes(16.0, tp);
            let actual = m.llm_model_state_bytes(16.0, tp);
            assert!((pred / actual - 1.0).abs() < 0.05, "tp {tp}: {pred} vs {actual}");
            let pa = p.memory.l_act_bytes(16.0, tp, 2048.0);
            let aa = m.llm_act_bytes(16.0, tp, 2048.0);
            assert!((pa / aa - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn profiling_overhead_in_paper_band() {
        // Paper Table 4: throughput profiling 6–10 min, memory 3–9 min.
        let (p, _, _) = profile_smooth();
        let minutes = p.profiling_seconds / 60.0;
        assert!(
            (1.0..20.0).contains(&minutes),
            "profiling overhead {minutes:.1} min out of plausible band"
        );
    }

    #[test]
    fn data_profiler_summarizes_mixture() {
        let m = llava_ov(llama3("8b"));
        let mut d = crate::data::dataset::Dataset::mixed(77);
        let dp = profile_data(&m, &mut d, 2000);
        assert_eq!(dp.samples.len(), 2000);
        assert!(dp.mean_units() > 1.0);
        assert!(dp.mean_seq() > 500.0);
        assert_eq!(dp.units_hist.total, 2000);
    }

    #[test]
    fn reprofile_policy_tracks_changes() {
        let mut p = ReprofilePolicy::default();
        assert!(p.model_needs("a"));
        assert!(!p.model_needs("a"));
        assert!(p.model_needs("b"), "model change → reprofile");
        assert!(p.data_needs("b", "mixed"));
        assert!(!p.data_needs("b", "mixed"));
        assert!(p.data_needs("b", "video"), "dataset change → reprofile");
        assert!(p.data_needs("a", "video"), "model change → data reprofile");
    }
}
