//! The Profiling Engine (§3.2): Model Profiler + Data Profiler.
//!
//! The Model Profiler sweeps a synthetic shape × TP grid through a
//! [`MeasureBackend`] and fits the interpolation models the optimizer and
//! scheduler consume: `E_thr`, `L_lin_thr`, `L_attn_thr` (throughput) and
//! `model_state` / `act_state` (memory). The Data Profiler samples the
//! training dataset and builds the empirical input-shape distribution.
//!
//! Both are *offline* components; their wall-clock is tracked and reported
//! as the one-time overhead of Table 4.

use crate::data::dataset::Dataset;
use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::profiling::backend::MeasureBackend;
use crate::profiling::interp::{Interp1D, Linear2, PerTp};
use crate::util::stats::{Histogram, Summary};

/// Fitted throughput models (per-GPU achieved FLOP/s).
#[derive(Clone, Debug)]
pub struct ThroughputModel {
    /// `E_thr(effective_batch, tp)`.
    pub e_thr: PerTp,
    /// `L_lin_thr(packed_total_tokens, tp)`.
    pub l_lin_thr: PerTp,
    /// `L_attn_thr(seq_len, tp)`.
    pub l_attn_thr: PerTp,
    /// Fixed fwd+bwd overhead per (microbatch × stage) execution for each
    /// module, per TP degree — the intercept of the affine time-in-layers
    /// fit at two small layer counts (§3.2.1's two-layer-count probes).
    pub enc_stage_overhead: Vec<(usize, f64)>,
    pub llm_stage_overhead: Vec<(usize, f64)>,
}

impl ThroughputModel {
    fn lookup_ovh(v: &[(usize, f64)], tp: usize) -> f64 {
        v.iter().find(|(t, _)| *t == tp).map(|(_, o)| *o).unwrap_or(0.0)
    }

    /// Per-stage fixed overhead (seconds, fwd+bwd) for the encoder / LLM.
    pub fn enc_overhead(&self, tp: usize) -> f64 {
        Self::lookup_ovh(&self.enc_stage_overhead, tp)
    }

    pub fn llm_overhead(&self, tp: usize) -> f64 {
        Self::lookup_ovh(&self.llm_stage_overhead, tp)
    }
}

/// Fitted memory models. The paper fits linear models from measurements at
/// two distinct small layer counts per TP degree (§3.2.1 Memory Profiling).
#[derive(Clone, Debug)]
pub struct MemoryModel {
    /// `model_state_E(layers)` per TP degree.
    e_state: Vec<(usize, Linear2)>,
    /// `model_state_L(layers)` per TP degree.
    l_state: Vec<(usize, Linear2)>,
    /// Activation bytes per (layer · unit) for the encoder, per TP degree.
    e_act_coeff: Vec<(usize, f64)>,
    /// Activation bytes per (layer · token) for the LLM, per TP degree.
    l_act_coeff: Vec<(usize, f64)>,
}

fn lookup<T: Copy>(v: &[(usize, T)], tp: usize) -> T {
    v.iter()
        .find(|(t, _)| *t == tp)
        .unwrap_or_else(|| panic!("TP degree {tp} not in memory model"))
        .1
}

impl MemoryModel {
    /// `model_state_E(l, E_tp)` (Eq 4).
    pub fn e_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        lookup(&self.e_state, tp).eval(layers).max(0.0)
    }

    /// `model_state_L(l, L_tp)` (Eq 5).
    pub fn l_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        lookup(&self.l_state, tp).eval(layers).max(0.0)
    }

    /// `act_state_E(l, E_tp, batch, seq)` — seq is fixed per architecture,
    /// so the shape argument is the effective batch in units.
    pub fn e_act_bytes(&self, layers: f64, tp: usize, units: f64) -> f64 {
        lookup(&self.e_act_coeff, tp) * layers * units
    }

    /// `act_state_L(l, L_tp, 1, seq)`.
    pub fn l_act_bytes(&self, layers: f64, tp: usize, seq: f64) -> f64 {
        lookup(&self.l_act_coeff, tp) * layers * seq
    }
}

/// Everything the Model Profiler produces.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub model_name: String,
    pub throughput: ThroughputModel,
    pub memory: MemoryModel,
    /// Simulated/measured wall-clock of the profiling run (Table 4).
    pub profiling_seconds: f64,
}

/// Default measurement grids. Shape axes are geometric (the behaviours
/// being captured are saturation curves); TP covers powers of two up to the
/// node size (Eq 2).
pub struct ProfilerGrids {
    pub units: Vec<f64>,
    pub llm_tokens: Vec<f64>,
    pub tps: Vec<usize>,
}

impl ProfilerGrids {
    pub fn standard(gpus_per_node: usize) -> ProfilerGrids {
        let mut tps = Vec::new();
        let mut t = 1;
        while t <= gpus_per_node {
            tps.push(t);
            t *= 2;
        }
        ProfilerGrids {
            units: vec![1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0],
            llm_tokens: vec![
                128.0, 256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 16384.0, 32768.0,
            ],
            tps,
        }
    }

    /// A coarser grid for quick tests.
    pub fn coarse(gpus_per_node: usize) -> ProfilerGrids {
        let mut g = Self::standard(gpus_per_node);
        g.units = vec![1.0, 8.0, 64.0];
        g.llm_tokens = vec![256.0, 4096.0, 32768.0];
        g
    }
}

/// The Model Profiler (§3.2.1).
pub struct ModelProfiler<'a, B: MeasureBackend> {
    pub backend: &'a mut B,
    pub grids: ProfilerGrids,
}

impl<'a, B: MeasureBackend> ModelProfiler<'a, B> {
    pub fn new(backend: &'a mut B, grids: ProfilerGrids) -> Self {
        ModelProfiler { backend, grids }
    }

    /// Run the full grid and fit all models.
    pub fn profile(&mut self, m: &Mllm) -> ModelProfile {
        let start = self.backend.measured_seconds();

        // ---- throughput grids ----
        let mut e_curves = Vec::new();
        let mut lin_curves = Vec::new();
        let mut attn_curves = Vec::new();
        for &tp in &self.grids.tps {
            let e_ys: Vec<f64> = self
                .grids
                .units
                .iter()
                .map(|&u| self.backend.encoder_throughput(m, u, tp))
                .collect();
            e_curves.push((tp, Interp1D::new(self.grids.units.clone(), e_ys)));

            let lin_ys: Vec<f64> = self
                .grids
                .llm_tokens
                .iter()
                .map(|&s| self.backend.llm_linear_throughput(m, s, tp))
                .collect();
            lin_curves.push((tp, Interp1D::new(self.grids.llm_tokens.clone(), lin_ys)));

            let attn_ys: Vec<f64> = self
                .grids
                .llm_tokens
                .iter()
                .map(|&s| self.backend.llm_attn_throughput(m, s, tp))
                .collect();
            attn_curves.push((tp, Interp1D::new(self.grids.llm_tokens.clone(), attn_ys)));
        }

        // ---- per-stage fixed overhead: affine fit over layer count ----
        let mut enc_ovh = Vec::new();
        let mut llm_ovh = Vec::new();
        for &tp in &self.grids.tps {
            // time(l) = c·l + b  ⇒  b = 2·t(l0) − t(2·l0).
            let (l0, units_ref, seq_ref) = (4.0, 8.0, 2048.0);
            let te1 = self.backend.encoder_time_at(m, units_ref, l0, tp);
            let te2 = self.backend.encoder_time_at(m, units_ref, 2.0 * l0, tp);
            enc_ovh.push((tp, (2.0 * te1 - te2).max(0.0)));
            let tl1 = self.backend.llm_time_at(m, seq_ref, l0, tp);
            let tl2 = self.backend.llm_time_at(m, seq_ref, 2.0 * l0, tp);
            llm_ovh.push((tp, (2.0 * tl1 - tl2).max(0.0)));
        }

        // ---- memory: two small layer counts per TP, linear in layers ----
        let (l0, l1) = (2.0, 4.0);
        let mut e_state = Vec::new();
        let mut l_state = Vec::new();
        let mut e_act_coeff = Vec::new();
        let mut l_act_coeff = Vec::new();
        for &tp in &self.grids.tps {
            let es0 = self.backend.encoder_state_bytes(m, l0, tp);
            let es1 = self.backend.encoder_state_bytes(m, l1, tp);
            e_state.push((tp, Linear2::fit(l0, es0, l1, es1)));

            let ls0 = self.backend.llm_state_bytes(m, l0, tp);
            let ls1 = self.backend.llm_state_bytes(m, l1, tp);
            l_state.push((tp, Linear2::fit(l0, ls0, l1, ls1)));

            // Activations are linear in (layers × shape): fit the
            // coefficient from one probe, sanity-checked by a second.
            let probe_units = 8.0;
            let ea = self.backend.encoder_act_bytes(m, l1, tp, probe_units);
            e_act_coeff.push((tp, ea / (l1 * probe_units)));

            let probe_seq = 4096.0;
            let la = self.backend.llm_act_bytes(m, l1, tp, probe_seq);
            l_act_coeff.push((tp, la / (l1 * probe_seq)));
        }

        ModelProfile {
            model_name: m.name.to_string() + "/" + m.llm.name,
            throughput: ThroughputModel {
                e_thr: PerTp::new(e_curves),
                l_lin_thr: PerTp::new(lin_curves),
                l_attn_thr: PerTp::new(attn_curves),
                enc_stage_overhead: enc_ovh,
                llm_stage_overhead: llm_ovh,
            },
            memory: MemoryModel { e_state, l_state, e_act_coeff, l_act_coeff },
            profiling_seconds: self.backend.measured_seconds() - start,
        }
    }
}

/// Empirical workload statistics from the Data Profiler (§3.2.2).
#[derive(Clone, Debug)]
pub struct DataProfile {
    pub dataset_name: String,
    pub model_name: String,
    /// The sampled shapes themselves — the optimizer evaluates the expected
    /// makespan over this set (Eq 1's D).
    pub samples: Vec<ItemShape>,
    pub units_summary: Summary,
    pub seq_summary: Summary,
    pub units_hist: Histogram,
    pub seq_hist: Histogram,
    /// Wall-clock of the sampling pass (Table 4).
    pub profiling_seconds: f64,
}

impl DataProfile {
    pub fn mean_units(&self) -> f64 {
        self.units_summary.mean
    }

    pub fn mean_seq(&self) -> f64 {
        self.seq_summary.mean
    }
}

/// The Data Profiler: random-samples the dataset and computes the precise
/// per-item input shapes under the target architecture.
pub fn profile_data(m: &Mllm, dataset: &mut Dataset, n_samples: usize) -> DataProfile {
    let t0 = std::time::Instant::now();
    let samples = dataset.shaped_batch(m, n_samples);
    let units: Vec<f64> = samples.iter().map(|s| s.units as f64).collect();
    let seqs: Vec<f64> = samples.iter().map(|s| s.llm_seq as f64).collect();
    // Charge a simulated per-item preprocessing cost (tokenization + image
    // shape math) so the reported Data Profiler overhead is in the paper's
    // band (~1.5 min for a full corpus sample) rather than the synthetic
    // generator's microseconds.
    let simulated = n_samples as f64 * 0.018;
    DataProfile {
        dataset_name: dataset.name.clone(),
        model_name: m.name.to_string() + "/" + m.llm.name,
        units_hist: Histogram::of(&units, 32),
        seq_hist: Histogram::of(&seqs, 32),
        units_summary: Summary::of(&units),
        seq_summary: Summary::of(&seqs),
        samples,
        profiling_seconds: simulated + t0.elapsed().as_secs_f64(),
    }
}

/// Re-profiling conditions (§3.2.3): the Model Profiler is keyed by the
/// model architecture; the Data Profiler by (model, dataset).
#[derive(Default, Debug)]
pub struct ReprofilePolicy {
    last_model: Option<String>,
    last_data: Option<(String, String)>,
}

impl ReprofilePolicy {
    /// Does the model profile need to be rebuilt for `model_key`?
    pub fn model_needs(&mut self, model_key: &str) -> bool {
        let stale = self.last_model.as_deref() != Some(model_key);
        self.last_model = Some(model_key.to_string());
        stale
    }

    /// Does the data profile need to be rebuilt for (model, dataset)?
    pub fn data_needs(&mut self, model_key: &str, dataset_key: &str) -> bool {
        let key = (model_key.to_string(), dataset_key.to_string());
        let stale = self.last_data.as_ref() != Some(&key);
        self.last_data = Some(key);
        stale
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;

    fn profile_smooth() -> (ModelProfile, Mllm, Truth) {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(truth.clone());
        let mut profiler =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8));
        (profiler.profile(&m), m, truth)
    }

    #[test]
    fn interpolation_matches_truth_on_grid_points() {
        let (p, m, truth) = profile_smooth();
        for &tp in &[1usize, 2, 4, 8] {
            for &u in &[1.0, 8.0, 64.0] {
                let pred = p.throughput.e_thr.eval(u, tp);
                let actual = truth.encoder_throughput(&m, u, tp);
                assert!(
                    (pred / actual - 1.0).abs() < 1e-9,
                    "tp {tp} units {u}: {pred} vs {actual}"
                );
            }
        }
    }

    #[test]
    fn interpolation_close_off_grid_for_smooth_truth() {
        let (p, m, truth) = profile_smooth();
        // Off-grid points: linear interpolation of a smooth saturating
        // curve should be within a few percent.
        for &seq in &[700.0, 3000.0, 12000.0] {
            let pred = p.throughput.l_lin_thr.eval(seq, 2);
            let layers = m.llm.layers as f64;
            let t = truth.llm_linear_time(&m, seq, layers, 2);
            let lin = m.llm.linear_flop_fwd(seq, layers, m.llm_mlp_matrices) * 3.0;
            let actual = lin / t / 2.0;
            let err = (pred / actual - 1.0).abs();
            assert!(err < 0.05, "seq {seq}: err {err}");
        }
    }

    #[test]
    fn memory_model_recovers_closed_forms() {
        let (p, m, _) = profile_smooth();
        for &tp in &[1usize, 4] {
            let pred = p.memory.l_state_bytes(16.0, tp);
            let actual = m.llm_model_state_bytes(16.0, tp);
            assert!((pred / actual - 1.0).abs() < 0.05, "tp {tp}: {pred} vs {actual}");
            let pa = p.memory.l_act_bytes(16.0, tp, 2048.0);
            let aa = m.llm_act_bytes(16.0, tp, 2048.0);
            assert!((pa / aa - 1.0).abs() < 1e-6);
        }
    }

    #[test]
    fn profiling_overhead_in_paper_band() {
        // Paper Table 4: throughput profiling 6–10 min, memory 3–9 min.
        let (p, _, _) = profile_smooth();
        let minutes = p.profiling_seconds / 60.0;
        assert!(
            (1.0..20.0).contains(&minutes),
            "profiling overhead {minutes:.1} min out of plausible band"
        );
    }

    #[test]
    fn data_profiler_summarizes_mixture() {
        let m = llava_ov(llama3("8b"));
        let mut d = crate::data::dataset::Dataset::mixed(77);
        let dp = profile_data(&m, &mut d, 2000);
        assert_eq!(dp.samples.len(), 2000);
        assert!(dp.mean_units() > 1.0);
        assert!(dp.mean_seq() > 500.0);
        assert_eq!(dp.units_hist.total, 2000);
    }

    #[test]
    fn reprofile_policy_tracks_changes() {
        let mut p = ReprofilePolicy::default();
        assert!(p.model_needs("a"));
        assert!(!p.model_needs("a"));
        assert!(p.model_needs("b"), "model change → reprofile");
        assert!(p.data_needs("b", "mixed"));
        assert!(!p.data_needs("b", "mixed"));
        assert!(p.data_needs("b", "video"), "dataset change → reprofile");
        assert!(p.data_needs("a", "video"), "model change → data reprofile");
    }
}
