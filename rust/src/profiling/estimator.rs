//! Per-item duration estimation from fitted profiles.
//!
//! Implements the paper's duration model (§3.3.1):
//!
//! ```text
//! E_dur(d;θ) = E_FLOP(d;θ) / E_thr(b(d), E_tp)
//! L_dur(d;θ) = L_FLOP(d;θ) / L_thr(s(d), L_tp)
//! ```
//!
//! with the LLM side split into linear and attention components measured
//! independently (§3.2.1). Durations are for the *whole module*; pipeline
//! stage durations divide by the module's PP degree at the call site
//! (Algorithm 1 lines 25–26).

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::profiling::engine::ThroughputModel;

/// Estimates per-item durations under a fitted throughput model.
pub struct Estimator<'a> {
    pub m: &'a Mllm,
    pub thr: &'a ThroughputModel,
}

impl<'a> Estimator<'a> {
    pub fn new(m: &'a Mllm, thr: &'a ThroughputModel) -> Self {
        Estimator { m, thr }
    }

    /// Predicted full-encoder fwd+bwd time for one item at TP `tp`.
    pub fn enc_item_dur(&self, shape: &ItemShape, tp: usize) -> f64 {
        if shape.units == 0 {
            return 0.0;
        }
        let units = shape.units as f64;
        let flop = shape.encoder_flop(self.m);
        flop / (self.thr.e_thr.eval(units, tp) * tp as f64)
    }

    /// Predicted full-LLM fwd+bwd time for one item at TP `tp`.
    pub fn llm_item_dur(&self, shape: &ItemShape, tp: usize) -> f64 {
        let seq = shape.llm_seq as f64;
        if seq <= 0.0 {
            return 0.0;
        }
        let layers = self.m.llm.layers as f64;
        let lin_flop = self
            .m
            .llm
            .linear_flop_fwd(seq, layers, self.m.llm_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        let attn_flop =
            self.m.llm.attn_flop_fwd(seq, layers) * (1.0 + Mllm::BWD_FACTOR);
        lin_flop / (self.thr.l_lin_thr.eval(seq, tp) * tp as f64)
            + attn_flop / (self.thr.l_attn_thr.eval(seq, tp) * tp as f64)
    }

    /// Predicted fwd+bwd time of a whole *packed* encoder microbatch with
    /// `units_total` units at TP `tp` — effective-batch efficiency applies
    /// to the packed total (`E_thr(b, tp)`), not per item.
    pub fn enc_bucket_dur(&self, units_total: f64, tp: usize) -> f64 {
        if units_total <= 0.0 {
            return 0.0;
        }
        let flop = self.m.encoder_flop_total_f64(units_total);
        flop / (self.thr.e_thr.eval(units_total, tp) * tp as f64)
    }

    /// Predicted fwd+bwd time of a whole *packed* LLM microbatch: linear
    /// work is priced at the packed total's throughput (`L_lin_thr(ΣS)`),
    /// attention per instance (§3.2.1) — this is what makes packing small
    /// items into one microbatch cheaper than pricing them separately.
    pub fn llm_bucket_dur(&self, seqs: &[f64], tp: usize) -> f64 {
        let total: f64 = seqs.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        let layers = self.m.llm.layers as f64;
        let lin_flop = self
            .m
            .llm
            .linear_flop_fwd(total, layers, self.m.llm_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        let mut t = lin_flop / (self.thr.l_lin_thr.eval(total, tp) * tp as f64);
        for &s in seqs {
            if s <= 0.0 {
                continue;
            }
            let attn_flop =
                self.m.llm.attn_flop_fwd(s, layers) * (1.0 + Mllm::BWD_FACTOR);
            t += attn_flop / (self.thr.l_attn_thr.eval(s, tp) * tp as f64);
        }
        t
    }

    /// [`Self::llm_bucket_dur`] for a pack of `count` identical sequences
    /// of length `seq` (fractional counts allowed) — allocation-free form
    /// for the optimizer's mean-phase inner loop.
    pub fn llm_bucket_dur_uniform(&self, seq: f64, count: f64, tp: usize) -> f64 {
        let total = seq * count;
        if total <= 0.0 {
            return 0.0;
        }
        let layers = self.m.llm.layers as f64;
        let lin_flop = self
            .m
            .llm
            .linear_flop_fwd(total, layers, self.m.llm_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        let attn_flop =
            self.m.llm.attn_flop_fwd(seq, layers) * (1.0 + Mllm::BWD_FACTOR) * count;
        lin_flop / (self.thr.l_lin_thr.eval(total, tp) * tp as f64)
            + attn_flop / (self.thr.l_attn_thr.eval(seq, tp) * tp as f64)
    }

    /// Predicted per-GPU LLM throughput for a packed sequence (used by
    /// Adaptive Correction to compare against observed throughput, Eq 7).
    pub fn llm_pred_throughput(&self, seq: f64, tp: usize) -> f64 {
        // Weighted combination of the two paths by their FLOP shares.
        let layers = self.m.llm.layers as f64;
        let lin = self.m.llm.linear_flop_fwd(seq, layers, self.m.llm_mlp_matrices);
        let attn = self.m.llm.attn_flop_fwd(seq, layers);
        let t = lin / self.thr.l_lin_thr.eval(seq, tp)
            + attn / self.thr.l_attn_thr.eval(seq, tp);
        (lin + attn) / t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfiler, ProfilerGrids};

    #[test]
    fn estimates_track_ground_truth_for_smooth_model() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(truth.clone());
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);

        let shape = ItemShape { units: 6, llm_seq: 3200, source: 0 };
        for &tp in &[1usize, 2, 4] {
            let pred_e = est.enc_item_dur(&shape, tp);
            let true_e =
                truth.encoder_stage_time(&m, 6.0, m.encoder.layers as f64, tp);
            let err_e = (pred_e / true_e - 1.0).abs();
            assert!(err_e < 0.08, "enc tp {tp}: err {err_e}");

            let pred_l = est.llm_item_dur(&shape, tp);
            let true_l =
                truth.llm_stage_time(&m, &[3200.0], m.llm.layers as f64, tp);
            let err_l = (pred_l / true_l - 1.0).abs();
            assert!(err_l < 0.08, "llm tp {tp}: err {err_l}");
        }
    }

    #[test]
    fn zero_shapes_cost_nothing() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(truth);
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let shape = ItemShape { units: 0, llm_seq: 0, source: 0 };
        assert_eq!(est.enc_item_dur(&shape, 1), 0.0);
        assert_eq!(est.llm_item_dur(&shape, 1), 0.0);
    }

    #[test]
    fn durations_decrease_with_tp() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(truth);
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        // Large enough work that TP actually helps despite comm overhead.
        let shape = ItemShape { units: 64, llm_seq: 16000, source: 0 };
        assert!(est.enc_item_dur(&shape, 4) < est.enc_item_dur(&shape, 1));
        assert!(est.llm_item_dur(&shape, 4) < est.llm_item_dur(&shape, 1));
    }
}
