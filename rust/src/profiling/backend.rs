//! Measurement backends for the Profiling Engine.
//!
//! The Model Profiler (§3.2.1) is backend-agnostic: it issues *measurement
//! requests* (run this module slice at this shape and TP degree; report
//! achieved throughput / bytes) and fits interpolation models over the
//! results. Two backends exist:
//!
//! - [`SimBackend`] measures the analytic A100 ground-truth model
//!   ([`Truth`]) — used for all paper-figure reproductions.
//! - `PjrtBackend` (in `runtime/`) times real compiled HLO artifacts on the
//!   CPU PJRT client — used by the end-to-end example to show the engine
//!   works against real execution.
//!
//! Backends accumulate simulated/real measurement wall-clock so Table 4's
//! one-time profiling overhead can be reported.

use crate::model::catalog::Mllm;
use crate::perfmodel::Truth;

/// A source of throughput / memory measurements.
pub trait MeasureBackend {
    /// Per-GPU achieved FLOP/s of the full encoder at effective batch
    /// `units`, TP `tp`.
    fn encoder_throughput(&mut self, m: &Mllm, units: f64, tp: usize) -> f64;

    /// Per-GPU achieved FLOP/s of the LLM's linear (GEMM) path for a packed
    /// total of `total` tokens at TP `tp`.
    fn llm_linear_throughput(&mut self, m: &Mllm, total: f64, tp: usize) -> f64;

    /// Per-GPU achieved FLOP/s of the LLM's attention path for an instance
    /// of sequence length `seq` at TP `tp`.
    fn llm_attn_throughput(&mut self, m: &Mllm, seq: f64, tp: usize) -> f64;

    /// Model-state bytes per GPU for `layers` encoder / LLM layers at `tp`.
    fn encoder_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64;
    fn llm_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64;

    /// Activation bytes per GPU for one microbatch.
    fn encoder_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, units: f64) -> f64;
    fn llm_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, seq: f64) -> f64;

    /// Raw module time at an explicit layer count (used to fit the fixed
    /// per-stage overhead: time(l) is affine in l; the intercept is the
    /// per-stage cost a pipeline pays per microbatch regardless of depth).
    fn encoder_time_at(&mut self, m: &Mllm, units: f64, layers: f64, tp: usize) -> f64;
    fn llm_time_at(&mut self, m: &Mllm, total: f64, layers: f64, tp: usize) -> f64;

    /// Cumulative wall-clock consumed by measurements so far (seconds).
    fn measured_seconds(&self) -> f64;

    /// Fork an independent measurement stream for one slice of the
    /// profiling grid (same measured system, zero accumulated
    /// wall-clock). The Model Profiler uses one fork per TP degree to
    /// sweep the shape × TP grid on the worker pool; backends that cannot
    /// split (e.g. a stateful hardware session holding real devices)
    /// keep the default `None` and get the serial sweep.
    fn fork(&mut self) -> Option<Self>
    where
        Self: Sized,
    {
        None
    }

    /// Fold a fork's accumulated measurement wall-clock back into this
    /// backend. Called in grid order after a parallel sweep, so the
    /// total stays deterministic at any thread count.
    fn join(&mut self, _child: Self)
    where
        Self: Sized,
    {
    }
}

/// Measures the analytic cluster ground truth, charging simulated
/// wall-clock per measurement (each throughput point is measured with
/// `REPS` repetitions plus a warm-up, as a real profiler would).
pub struct SimBackend {
    pub truth: Truth,
    elapsed: f64,
}

impl SimBackend {
    const REPS: f64 = 3.0;
    /// Fixed per-measurement setup cost (process-group setup, allocator
    /// warm-up) — makes profiling overhead realistically minutes, not ms.
    const SETUP: f64 = 0.35;

    pub fn new(truth: Truth) -> SimBackend {
        SimBackend { truth, elapsed: 0.0 }
    }

    fn charge(&mut self, run_time: f64) {
        self.elapsed += Self::SETUP + (1.0 + Self::REPS) * run_time;
    }
}

impl MeasureBackend for SimBackend {
    fn encoder_throughput(&mut self, m: &Mllm, units: f64, tp: usize) -> f64 {
        let layers = m.encoder.layers as f64;
        let t = self.truth.encoder_stage_time(m, units, layers, tp);
        self.charge(t);
        m.encoder_flop_total(units.max(1.0) as usize) / t / tp as f64
    }

    fn llm_linear_throughput(&mut self, m: &Mllm, total: f64, tp: usize) -> f64 {
        let layers = m.llm.layers as f64;
        let t = self.truth.llm_linear_time(m, total, layers, tp);
        self.charge(t);
        let lin = m
            .llm
            .linear_flop_fwd(total, layers, m.llm_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        lin / t / tp as f64
    }

    fn llm_attn_throughput(&mut self, m: &Mllm, seq: f64, tp: usize) -> f64 {
        let layers = m.llm.layers as f64;
        let t = self.truth.llm_attn_time(m, seq, layers, tp);
        self.charge(t);
        let attn = m.llm.attn_flop_fwd(seq, layers) * (1.0 + Mllm::BWD_FACTOR);
        attn / t / tp as f64
    }

    fn encoder_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64 {
        self.charge(0.05);
        m.encoder_model_state_bytes(layers, tp)
    }

    fn llm_state_bytes(&mut self, m: &Mllm, layers: f64, tp: usize) -> f64 {
        self.charge(0.05);
        m.llm_model_state_bytes(layers, tp)
    }

    fn encoder_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, units: f64) -> f64 {
        self.charge(0.05);
        m.encoder_act_bytes(layers, tp, units)
    }

    fn llm_act_bytes(&mut self, m: &Mllm, layers: f64, tp: usize, seq: f64) -> f64 {
        self.charge(0.05);
        m.llm_act_bytes(layers, tp, seq)
    }

    fn encoder_time_at(&mut self, m: &Mllm, units: f64, layers: f64, tp: usize) -> f64 {
        let t = self.truth.encoder_stage_time(m, units, layers, tp);
        self.charge(t);
        t
    }

    fn llm_time_at(&mut self, m: &Mllm, total: f64, layers: f64, tp: usize) -> f64 {
        let t = self.truth.llm_stage_time(m, &[total], layers, tp);
        self.charge(t);
        t
    }

    fn measured_seconds(&self) -> f64 {
        self.elapsed
    }

    fn fork(&mut self) -> Option<Self> {
        Some(SimBackend::new(self.truth.clone()))
    }

    fn join(&mut self, child: Self) {
        self.elapsed += child.elapsed;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::perfmodel::ClusterSpec;

    #[test]
    fn fork_measures_independently_then_joins() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut b = SimBackend::new(truth);
        let mut child = b.fork().expect("sim backend splits");
        assert_eq!(child.measured_seconds(), 0.0);
        let thr_child = child.encoder_throughput(&m, 8.0, 2);
        let spent = child.measured_seconds();
        assert!(spent > 0.0);
        assert_eq!(b.measured_seconds(), 0.0, "parent unaffected by fork");
        // Fork measures the same system …
        let thr_parent = b.encoder_throughput(&m, 8.0, 2);
        assert_eq!(thr_child.to_bits(), thr_parent.to_bits());
        // … and joining folds its wall-clock back in.
        let before = b.measured_seconds();
        b.join(child);
        assert_eq!(b.measured_seconds(), before + spent);
    }

    #[test]
    fn sim_backend_round_trips_truth() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut b = SimBackend::new(truth.clone());
        // thr · tp · time == flop by construction.
        let thr = b.encoder_throughput(&m, 8.0, 2);
        let t = truth.encoder_stage_time(&m, 8.0, m.encoder.layers as f64, 2);
        let flop = m.encoder_flop_total(8);
        assert!((thr * 2.0 * t / flop - 1.0).abs() < 1e-9);
    }

    #[test]
    fn measurements_accumulate_wallclock() {
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let mut b = SimBackend::new(truth);
        assert_eq!(b.measured_seconds(), 0.0);
        b.encoder_throughput(&m, 4.0, 1);
        let after_one = b.measured_seconds();
        assert!(after_one > 0.0);
        b.llm_linear_throughput(&m, 2048.0, 1);
        assert!(b.measured_seconds() > after_one);
    }
}
