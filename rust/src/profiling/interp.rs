//! Piecewise-linear interpolation over measurement grids.
//!
//! The paper's Model Profiler characterizes throughput and memory "via
//! linear interpolation" over a grid of measured input shapes (§3.2.1).
//! This module provides the 1-D interpolant and the per-TP family used by
//! the throughput models (`E_thr`, `L_lin_thr`, `L_attn_thr`): TP degrees
//! are powers of two and measured exactly, so only the shape axis is
//! interpolated.

/// 1-D piecewise-linear interpolant with linear extrapolation at the ends.
#[derive(Clone, Debug)]
pub struct Interp1D {
    /// Strictly increasing sample coordinates.
    xs: Vec<f64>,
    ys: Vec<f64>,
}

impl Interp1D {
    pub fn new(xs: Vec<f64>, ys: Vec<f64>) -> Interp1D {
        assert_eq!(xs.len(), ys.len(), "interp grid size mismatch");
        assert!(xs.len() >= 2, "need at least two grid points");
        assert!(
            xs.windows(2).all(|w| w[0] < w[1]),
            "grid coordinates must be strictly increasing"
        );
        Interp1D { xs, ys }
    }

    /// Evaluate at `x`. Outside the grid, extrapolates linearly from the
    /// closest segment (clamped at zero — throughputs and byte counts are
    /// never negative).
    pub fn eval(&self, x: f64) -> f64 {
        let n = self.xs.len();
        // Find segment via binary search.
        let seg = match self
            .xs
            .binary_search_by(|probe| probe.partial_cmp(&x).expect("NaN"))
        {
            Ok(i) => return self.ys[i],
            Err(0) => 0,
            Err(i) if i >= n => n - 2,
            Err(i) => i - 1,
        };
        let (x0, x1) = (self.xs[seg], self.xs[seg + 1]);
        let (y0, y1) = (self.ys[seg], self.ys[seg + 1]);
        let t = (x - x0) / (x1 - x0);
        (y0 + t * (y1 - y0)).max(0.0)
    }

    /// Grid coordinates (used by tests and reporting).
    pub fn grid(&self) -> &[f64] {
        &self.xs
    }
}

/// A family of 1-D interpolants keyed by TP degree.
///
/// `E_thr(batch, tp)`-style models: the shape axis is interpolated, the TP
/// axis is looked up exactly (TP is profiled at every power of two up to
/// `N_gpu_node`, Eq 2).
#[derive(Clone, Debug)]
pub struct PerTp {
    curves: Vec<(usize, Interp1D)>,
}

impl PerTp {
    pub fn new(curves: Vec<(usize, Interp1D)>) -> PerTp {
        assert!(!curves.is_empty());
        PerTp { curves }
    }

    /// Evaluate at (x, tp). Panics if `tp` was not profiled — the optimizer
    /// only explores profiled TP degrees (Eq 2).
    pub fn eval(&self, x: f64, tp: usize) -> f64 {
        self.curves
            .iter()
            .find(|(t, _)| *t == tp)
            .unwrap_or_else(|| panic!("TP degree {tp} was not profiled"))
            .1
            .eval(x)
    }

    pub fn tps(&self) -> Vec<usize> {
        self.curves.iter().map(|(t, _)| *t).collect()
    }
}

/// Linear model `y = a·x + b` fitted from exactly two measurements — the
/// paper's memory model is built by "varying the number of layers between
/// two distinct small values" and interpolating linearly (§3.2.1).
#[derive(Clone, Copy, Debug)]
pub struct Linear2 {
    pub a: f64,
    pub b: f64,
}

impl Linear2 {
    pub fn fit(x0: f64, y0: f64, x1: f64, y1: f64) -> Linear2 {
        assert!(x0 != x1, "degenerate linear fit");
        let a = (y1 - y0) / (x1 - x0);
        Linear2 { a, b: y0 - a * x0 }
    }

    pub fn eval(&self, x: f64) -> f64 {
        self.a * x + self.b
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interp_hits_grid_points() {
        let it = Interp1D::new(vec![1.0, 2.0, 4.0], vec![10.0, 20.0, 40.0]);
        assert_eq!(it.eval(1.0), 10.0);
        assert_eq!(it.eval(2.0), 20.0);
        assert_eq!(it.eval(4.0), 40.0);
    }

    #[test]
    fn interp_linear_between() {
        let it = Interp1D::new(vec![0.0, 10.0], vec![0.0, 100.0]);
        assert!((it.eval(2.5) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn extrapolates_clamped_at_zero() {
        let it = Interp1D::new(vec![1.0, 2.0], vec![10.0, 20.0]);
        assert!((it.eval(3.0) - 30.0).abs() < 1e-12);
        assert_eq!(it.eval(-100.0), 0.0);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn rejects_unsorted_grid() {
        Interp1D::new(vec![2.0, 1.0], vec![0.0, 0.0]);
    }

    #[test]
    fn per_tp_family_lookup() {
        let f = PerTp::new(vec![
            (1, Interp1D::new(vec![0.0, 1.0], vec![0.0, 10.0])),
            (2, Interp1D::new(vec![0.0, 1.0], vec![0.0, 5.0])),
        ]);
        assert!((f.eval(0.5, 1) - 5.0).abs() < 1e-12);
        assert!((f.eval(0.5, 2) - 2.5).abs() < 1e-12);
        assert_eq!(f.tps(), vec![1, 2]);
    }

    #[test]
    #[should_panic(expected = "was not profiled")]
    fn per_tp_rejects_unknown_tp() {
        let f = PerTp::new(vec![(1, Interp1D::new(vec![0.0, 1.0], vec![0.0, 1.0]))]);
        f.eval(0.5, 4);
    }

    #[test]
    fn linear2_fit_recovers_line() {
        let l = Linear2::fit(2.0, 7.0, 4.0, 11.0);
        assert!((l.eval(0.0) - 3.0).abs() < 1e-12);
        assert!((l.eval(10.0) - 23.0).abs() < 1e-12);
    }
}
