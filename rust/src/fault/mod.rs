//! Fault-injected elastic fleet: deterministic churn, stragglers, and
//! link degradation threaded through the engine at iteration boundaries.
//!
//! [`FleetState`] replays a [`FaultTrace`] and keeps two views of
//! cluster health. The **raw** view is physics: it decides which shards
//! draw data this iteration and which slowdown/link factors
//! `shard::sync` charges into the step barrier, and it applies to every
//! system identically — a crashed replica is gone whether or not the
//! planner is fault-aware. The **confirmed** view is the raw view
//! debounced over `confirm` consecutive iterations (mirroring the drift
//! detector's confirmation hysteresis), and is the only thing
//! *responses* — slowdown-weighted batch splits, warm topology replans —
//! may react to, so transient blips don't thrash the plan.

pub mod events;

pub use events::{FaultEvent, FaultKind, FaultTrace, FleetHealth};

use crate::shard::ShardedDataset;

/// What `FleetState::advance` did at one iteration boundary, for the
/// engine's telemetry counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultDelta {
    /// Shards taken down this boundary (crashes and elastic leaves).
    pub failures: usize,
    /// Shards brought back this boundary (recoveries and elastic joins).
    pub recoveries: usize,
    /// Whether active membership changed, forcing a deterministic
    /// reshard of the batch split.
    pub resharded: bool,
    /// Whether the fleet runs this iteration off nominal health.
    pub degraded: bool,
}

/// Aggregate fault counters carried on `RunResult`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FaultStats {
    pub failures: usize,
    pub recoveries: usize,
    pub reshard_events: usize,
    pub degraded_iters: usize,
}

/// The raw health the executor charges this iteration, in active-member
/// order (parallel to the drawn per-shard batches).
#[derive(Clone, Debug, PartialEq)]
pub struct FleetView {
    /// Execution-time multiplier per active member (1.0 = healthy).
    pub slowdown: Vec<f64>,
    /// Cross-shard allreduce multiplier (1.0 = healthy).
    pub link_factor: f64,
}

impl FleetView {
    /// Whether charging would change anything. When false the executor
    /// skips the degradation path entirely, keeping healthy iterations
    /// bit-identical to a run without fault injection.
    pub fn is_degrading(&self) -> bool {
        self.link_factor != 1.0 || self.slowdown.iter().any(|s| *s != 1.0)
    }
}

/// Replays a [`FaultTrace`] across a run, maintaining the raw and
/// confirmed health views.
#[derive(Clone, Debug)]
pub struct FleetState {
    trace: FaultTrace,
    raw: FleetHealth,
    confirmed: FleetHealth,
    streak: usize,
    confirm: usize,
    respond: bool,
    next_event: usize,
}

impl FleetState {
    /// `confirm` is the number of consecutive diverged iterations before
    /// the raw view is promoted to confirmed — pass the drift detector's
    /// confirmation count so faults debounce like drift does.
    pub fn new(trace: FaultTrace, respond: bool, confirm: usize) -> FleetState {
        let shards = trace.shards;
        FleetState {
            trace,
            raw: FleetHealth::healthy(shards),
            confirmed: FleetHealth::healthy(shards),
            streak: 0,
            confirm: confirm.max(1),
            respond,
            next_event: 0,
        }
    }

    /// Deliver every event due at `iteration`, then advance the
    /// confirmation debounce one step. Call once per iteration, before
    /// the batch is drawn.
    pub fn advance(&mut self, iteration: usize) -> FaultDelta {
        let mut d = FaultDelta::default();
        while self.next_event < self.trace.events.len()
            && self.trace.events[self.next_event].iteration <= iteration
        {
            let e = self.trace.events[self.next_event];
            self.next_event += 1;
            let active_before = self.raw.n_active();
            if self.raw.apply(e.kind) {
                match e.kind {
                    FaultKind::Fail { .. } | FaultKind::Leave { .. } => d.failures += 1,
                    FaultKind::Recover { .. } | FaultKind::Join { .. } => d.recoveries += 1,
                    _ => {}
                }
                if self.raw.n_active() != active_before {
                    d.resharded = true;
                }
            }
        }
        if self.raw == self.confirmed {
            self.streak = 0;
        } else {
            self.streak += 1;
            if self.streak >= self.confirm {
                self.confirmed = self.raw.clone();
                self.streak = 0;
            }
        }
        d.degraded = self.raw.is_degraded();
        d
    }

    /// Active shard slots this iteration (raw view — physics).
    pub fn members(&self) -> Vec<usize> {
        self.raw.active()
    }

    /// Per-member batch counts for this iteration. Responding fleets
    /// weight the split by the *confirmed* inverse slowdown so confirmed
    /// stragglers draw less work; non-responding fleets (and healthy
    /// ones) split evenly, bit-identical to the un-injected path.
    pub fn counts(&self, gbs: usize) -> Vec<usize> {
        let members = self.members();
        if self.respond {
            let weights: Vec<f64> = members
                .iter()
                .map(|&s| 1.0 / self.confirmed.slowdown[s])
                .collect();
            ShardedDataset::weighted_counts(gbs, &weights)
        } else {
            ShardedDataset::split_counts(gbs, members.len())
        }
    }

    /// The raw factors the executor must charge this iteration.
    pub fn view(&self) -> FleetView {
        FleetView {
            slowdown: self.members().iter().map(|&s| self.raw.slowdown[s]).collect(),
            link_factor: self.raw.link_factor,
        }
    }

    /// The *confirmed* (debounced) factors in active-member order — what
    /// a responding executor may steer by without thrashing on transient
    /// blips. Same member mapping as [`FleetState::view`], but sourced
    /// from the confirmed health the replanner already trusts.
    pub fn confirmed_view(&self) -> FleetView {
        FleetView {
            slowdown: self
                .members()
                .iter()
                .map(|&s| self.confirmed.slowdown[s])
                .collect(),
            link_factor: self.confirmed.link_factor,
        }
    }

    /// Confirmed active-member count — what a fault-aware policy plans
    /// for (debounced, so transient blips don't trigger replans).
    pub fn confirmed_active(&self) -> usize {
        self.confirmed.n_active()
    }

    pub fn raw_health(&self) -> &FleetHealth {
        &self.raw
    }

    pub fn confirmed_health(&self) -> &FleetHealth {
        &self.confirmed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(key: &str, respond: bool) -> FleetState {
        let trace = FaultTrace::by_key(key, 4, 42).expect("named trace");
        FleetState::new(trace, respond, 2)
    }

    #[test]
    fn advance_counts_faults_and_debounces_confirmation() {
        let mut fs = fleet("skewed-churn", true);
        for it in 0..3 {
            let d = fs.advance(it);
            assert_eq!(d, FaultDelta::default(), "healthy prefix at iteration {it}");
        }
        let d = fs.advance(3);
        assert_eq!(d.failures, 1);
        assert!(d.resharded);
        assert!(d.degraded);
        assert_eq!(fs.members(), vec![0, 1, 2], "raw membership shrinks immediately");
        assert_eq!(fs.confirmed_active(), 4, "confirmation lags the raw view");
        fs.advance(4);
        assert_eq!(fs.confirmed_active(), 3, "promoted after `confirm` iterations");
        let mut recoveries = 0;
        for it in 5..18 {
            recoveries += fs.advance(it).recoveries;
        }
        assert_eq!(recoveries, 1);
        assert_eq!(fs.members(), vec![0, 1, 2, 3]);
        assert!(!fs.raw_health().is_degraded(), "skewed-churn heals by the end");
    }

    #[test]
    fn responding_fleets_shift_work_off_confirmed_stragglers() {
        let mut fs = fleet("skewed-churn", true);
        for it in 0..9 {
            fs.advance(it);
        }
        // By iteration 8 the 1.7x straggler on slot 1 is confirmed and
        // slot 3 is still down (it recovers at iteration 13).
        let counts = fs.counts(48);
        assert_eq!(counts.iter().sum::<usize>(), 48);
        assert_eq!(counts.len(), 3, "slot 3 is down");
        assert!(
            counts[1] < counts[0] && counts[1] < counts[2],
            "confirmed straggler draws the least work: {counts:?}"
        );

        let mut st = fleet("skewed-churn", false);
        for it in 0..9 {
            st.advance(it);
        }
        assert_eq!(st.counts(48), vec![16, 16, 16], "static fleets split evenly");
    }

    #[test]
    fn confirmed_view_maps_slots_to_active_member_order() {
        let mut fs = fleet("skewed-churn", true);
        for it in 0..9 {
            fs.advance(it);
        }
        // Slot 3 is down, so the confirmed view must be 3-wide and index
        // by *active* position — confirmed_view()[1] is slot 1's factor.
        let cv = fs.confirmed_view();
        assert_eq!(cv.slowdown.len(), fs.members().len());
        for (pos, &slot) in fs.members().iter().enumerate() {
            assert_eq!(
                cv.slowdown[pos].to_bits(),
                fs.confirmed_health().slowdown[slot].to_bits(),
                "active position {pos} must carry slot {slot}'s confirmed factor"
            );
        }
        assert!(cv.is_degrading(), "the 1.7x straggler is confirmed by now");
    }

    #[test]
    fn healthy_fleet_views_do_not_degrade() {
        let mut fs = fleet("none", true);
        for it in 0..20 {
            let d = fs.advance(it);
            assert_eq!(d, FaultDelta::default());
        }
        assert!(!fs.view().is_degrading());
        assert_eq!(fs.counts(48), ShardedDataset::split_counts(48, 4));
    }
}
