//! Seeded, replayable fault event streams.
//!
//! A [`FaultTrace`] is a deterministic schedule of cluster-health events —
//! replica fail/recover, elastic shard join/leave, persistent stragglers
//! with a slowdown factor, and degraded allreduce links — delivered at
//! iteration boundaries only, so the bit-determinism contract (identical
//! results at any `DFLOP_THREADS`) holds under injection. Traces come
//! from named scenario keys or from the seeded long-horizon generator
//! emulating hours of production churn; the same `(key, shards, seed)`
//! triple always replays the same stream.

use crate::util::rng::Rng;

/// One kind of cluster-health transition. `Fail`/`Recover` model
/// crashes, `Leave`/`Join` model deliberate elastic membership changes;
/// both pairs move the same up/down bit and differ only in intent, so a
/// trace can mix them freely.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// Replica crash: the shard drops out of the DP group.
    Fail { shard: usize },
    /// A crashed replica comes back and rejoins the group.
    Recover { shard: usize },
    /// Elastic scale-down: the shard leaves the group deliberately.
    Leave { shard: usize },
    /// Elastic scale-up: the shard (re)joins the group.
    Join { shard: usize },
    /// Persistent straggler: every iteration on this shard runs
    /// `slowdown`× slower (factor ≥ 1) until cleared.
    Straggle { shard: usize, slowdown: f64 },
    /// The straggling shard returns to full speed.
    StraggleClear { shard: usize },
    /// The cross-shard allreduce link degrades by `factor` (≥ 1).
    LinkDegrade { factor: f64 },
    /// The allreduce link returns to full bandwidth.
    LinkRestore,
}

impl FaultKind {
    /// The shard a per-shard event targets (`None` for link events).
    pub fn shard(&self) -> Option<usize> {
        match *self {
            FaultKind::Fail { shard }
            | FaultKind::Recover { shard }
            | FaultKind::Leave { shard }
            | FaultKind::Join { shard }
            | FaultKind::Straggle { shard, .. }
            | FaultKind::StraggleClear { shard } => Some(shard),
            FaultKind::LinkDegrade { .. } | FaultKind::LinkRestore => None,
        }
    }
}

/// A fault delivered at the start of iteration `iteration`, before the
/// batch is drawn.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub iteration: usize,
    pub kind: FaultKind,
}

/// A replayable fault schedule over a DP group of `shards` slots.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultTrace {
    pub key: String,
    pub shards: usize,
    /// Sorted by iteration; order within an iteration is delivery order.
    pub events: Vec<FaultEvent>,
}

/// The slot the named scenarios straggle: slot 1 when the fleet is big
/// enough, else slot 0 (the scenarios fail the *last* slot, so the two
/// roles never collide on fleets of ≥ 2 shards).
fn straggle_slot(shards: usize) -> usize {
    usize::from(shards >= 3)
}

/// The acceptance scenario: a replica failure, an *escalating* straggler
/// (1.25× then 1.7×, so a confirmation-debounced responder is already
/// re-weighting when the worse factor lands), and a degraded allreduce
/// link, all healing before the run ends. Pairs with the `skewed-shard`
/// dataset so data skew and cluster faults overlap.
fn skewed_churn(shards: usize) -> Vec<FaultEvent> {
    let failed = shards - 1;
    let slow = straggle_slot(shards);
    vec![
        ev(3, FaultKind::Fail { shard: failed }),
        ev(5, FaultKind::Straggle { shard: slow, slowdown: 1.25 }),
        ev(7, FaultKind::Straggle { shard: slow, slowdown: 1.7 }),
        ev(9, FaultKind::LinkDegrade { factor: 1.8 }),
        ev(13, FaultKind::Recover { shard: failed }),
        ev(14, FaultKind::StraggleClear { shard: slow }),
        ev(15, FaultKind::LinkRestore),
    ]
}

/// Crash/recover plus a deliberate leave/join on another slot.
fn churn(shards: usize) -> Vec<FaultEvent> {
    vec![
        ev(2, FaultKind::Fail { shard: shards - 1 }),
        ev(6, FaultKind::Recover { shard: shards - 1 }),
        ev(9, FaultKind::Leave { shard: 0 }),
        ev(13, FaultKind::Join { shard: 0 }),
    ]
}

/// One persistent straggler that never heals.
fn straggler(shards: usize) -> Vec<FaultEvent> {
    vec![ev(4, FaultKind::Straggle { shard: straggle_slot(shards), slowdown: 1.5 })]
}

/// A degraded allreduce link for a window of iterations.
fn degraded_link() -> Vec<FaultEvent> {
    vec![
        ev(4, FaultKind::LinkDegrade { factor: 2.0 }),
        ev(12, FaultKind::LinkRestore),
    ]
}

/// Seeded long-horizon traffic trace: a per-iteration random walk over
/// ~512 iterations of simulated production churn. Events are generated
/// in iteration order with explicit bookkeeping, so every fault is
/// properly paired, the fleet never empties, and the same seed always
/// replays the same stream.
fn long_horizon(shards: usize, seed: u64) -> Vec<FaultEvent> {
    const HORIZON: usize = 512;
    let mut rng = Rng::new(seed ^ 0xF1EE7);
    let mut events = Vec::new();
    let mut up = vec![true; shards];
    let mut straggling = vec![false; shards];
    let mut degraded = false;
    for t in 8..HORIZON {
        for shard in 0..shards {
            if up[shard] {
                let survivors = up.iter().filter(|u| **u).count();
                if survivors > 1 && rng.chance(0.01) {
                    up[shard] = false;
                    events.push(ev(t, FaultKind::Fail { shard }));
                }
            } else if rng.chance(0.08) {
                up[shard] = true;
                events.push(ev(t, FaultKind::Recover { shard }));
            }
            if !straggling[shard] {
                if rng.chance(0.008) {
                    straggling[shard] = true;
                    let slowdown = 1.0 + rng.uniform(0.2, 0.9);
                    events.push(ev(t, FaultKind::Straggle { shard, slowdown }));
                }
            } else if rng.chance(0.06) {
                straggling[shard] = false;
                events.push(ev(t, FaultKind::StraggleClear { shard }));
            }
        }
        if !degraded {
            if rng.chance(0.004) {
                degraded = true;
                let factor = 1.0 + rng.uniform(0.3, 1.2);
                events.push(ev(t, FaultKind::LinkDegrade { factor }));
            }
        } else if rng.chance(0.05) {
            degraded = false;
            events.push(ev(t, FaultKind::LinkRestore));
        }
    }
    events
}

fn ev(iteration: usize, kind: FaultKind) -> FaultEvent {
    FaultEvent { iteration, kind }
}

impl FaultTrace {
    /// Build the named trace for a DP group of `shards` slots. `seed`
    /// only feeds the `long-horizon` generator; the short named
    /// scenarios are fixed schedules. Returns `None` for unknown keys
    /// or fleets too small to inject into (< 2 shards).
    pub fn by_key(key: &str, shards: usize, seed: u64) -> Option<FaultTrace> {
        if shards < 2 {
            return None;
        }
        let events = match key {
            "none" => Vec::new(),
            "churn" => churn(shards),
            "straggler" => straggler(shards),
            "degraded-link" => degraded_link(),
            "skewed-churn" => skewed_churn(shards),
            "long-horizon" => long_horizon(shards, seed),
            _ => return None,
        };
        let events: Vec<FaultEvent> = events
            .into_iter()
            .filter(|e| e.kind.shard().is_none_or(|s| s < shards))
            .collect();
        Some(FaultTrace { key: key.to_string(), shards, events })
    }

    /// The scenario keys `by_key` accepts, for error messages.
    pub fn keys() -> &'static [&'static str] {
        &["none", "churn", "straggler", "degraded-link", "skewed-churn", "long-horizon"]
    }
}

/// Instantaneous cluster health over the DP group's shard slots.
#[derive(Clone, Debug, PartialEq)]
pub struct FleetHealth {
    /// Whether each slot participates in the group this iteration.
    pub up: Vec<bool>,
    /// Execution-time multiplier per slot (1.0 = healthy, ≥ 1).
    pub slowdown: Vec<f64>,
    /// Cross-shard allreduce multiplier (1.0 = healthy, ≥ 1).
    pub link_factor: f64,
}

impl FleetHealth {
    pub fn healthy(shards: usize) -> FleetHealth {
        assert!(shards >= 1, "a fleet needs at least one shard slot");
        FleetHealth {
            up: vec![true; shards],
            slowdown: vec![1.0; shards],
            link_factor: 1.0,
        }
    }

    /// Active slot indices, ascending.
    pub fn active(&self) -> Vec<usize> {
        (0..self.up.len()).filter(|&s| self.up[s]).collect()
    }

    pub fn n_active(&self) -> usize {
        self.up.iter().filter(|u| **u).count()
    }

    /// Anything off nominal: a down slot, a straggler, or a slow link.
    pub fn is_degraded(&self) -> bool {
        self.up.iter().any(|u| !u)
            || self.slowdown.iter().any(|s| *s != 1.0)
            || self.link_factor != 1.0
    }

    /// Apply one event; returns whether the state changed. Idempotent
    /// (re-applying the same event is a no-op) and refuses to take down
    /// the last active slot, so the group always has a survivor.
    pub fn apply(&mut self, kind: FaultKind) -> bool {
        match kind {
            FaultKind::Fail { shard } | FaultKind::Leave { shard } => {
                if self.up[shard] && self.n_active() > 1 {
                    self.up[shard] = false;
                    true
                } else {
                    false
                }
            }
            FaultKind::Recover { shard } | FaultKind::Join { shard } => {
                if !self.up[shard] {
                    self.up[shard] = true;
                    true
                } else {
                    false
                }
            }
            FaultKind::Straggle { shard, slowdown } => {
                assert!(slowdown >= 1.0, "slowdown factors are multipliers >= 1");
                if self.slowdown[shard] != slowdown {
                    self.slowdown[shard] = slowdown;
                    true
                } else {
                    false
                }
            }
            FaultKind::StraggleClear { shard } => {
                if self.slowdown[shard] != 1.0 {
                    self.slowdown[shard] = 1.0;
                    true
                } else {
                    false
                }
            }
            FaultKind::LinkDegrade { factor } => {
                assert!(factor >= 1.0, "link factors are multipliers >= 1");
                if self.link_factor != factor {
                    self.link_factor = factor;
                    true
                } else {
                    false
                }
            }
            FaultKind::LinkRestore => {
                if self.link_factor != 1.0 {
                    self.link_factor = 1.0;
                    true
                } else {
                    false
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_key_covers_every_scenario_and_rejects_unknowns() {
        for key in FaultTrace::keys() {
            let t = FaultTrace::by_key(key, 4, 42).expect("named trace");
            assert_eq!(t.key, *key);
            assert_eq!(t.shards, 4);
        }
        assert!(FaultTrace::by_key("bogus", 4, 42).is_none());
        assert!(FaultTrace::by_key("churn", 1, 42).is_none(), "no fleet to inject into");
    }

    #[test]
    fn traces_are_sorted_in_bounds_and_survivable() {
        for key in FaultTrace::keys() {
            for shards in [2, 3, 4, 8] {
                let t = FaultTrace::by_key(key, shards, 7).expect("named trace");
                let mut health = FleetHealth::healthy(shards);
                let mut last = 0usize;
                for e in &t.events {
                    assert!(e.iteration >= last, "{key}: events out of order");
                    last = e.iteration;
                    if let Some(s) = e.kind.shard() {
                        assert!(s < shards, "{key}: shard {s} out of bounds");
                    }
                    health.apply(e.kind);
                    assert!(health.n_active() >= 1, "{key}: fleet emptied");
                }
            }
        }
    }

    #[test]
    fn long_horizon_is_replayable_and_seed_sensitive() {
        let a = FaultTrace::by_key("long-horizon", 4, 11).expect("trace");
        let b = FaultTrace::by_key("long-horizon", 4, 11).expect("trace");
        assert_eq!(a, b, "same (key, shards, seed) must replay bit-identically");
        assert!(!a.events.is_empty(), "512 iterations of churn produce events");
        let c = FaultTrace::by_key("long-horizon", 4, 12).expect("trace");
        assert_ne!(a.events, c.events, "different seeds explore different churn");
    }

    #[test]
    fn health_apply_is_idempotent_and_guards_the_last_survivor() {
        let mut h = FleetHealth::healthy(2);
        assert!(h.apply(FaultKind::Fail { shard: 0 }));
        assert!(!h.apply(FaultKind::Fail { shard: 0 }), "re-applying is a no-op");
        assert!(!h.apply(FaultKind::Fail { shard: 1 }), "last survivor stays up");
        assert_eq!(h.active(), vec![1]);
        assert!(h.apply(FaultKind::Recover { shard: 0 }));
        assert_eq!(h, FleetHealth::healthy(2), "fail-then-recover round-trips");

        assert!(h.apply(FaultKind::Straggle { shard: 1, slowdown: 1.5 }));
        assert!(h.is_degraded());
        assert!(h.apply(FaultKind::StraggleClear { shard: 1 }));
        assert!(h.apply(FaultKind::LinkDegrade { factor: 2.0 }));
        assert!(h.apply(FaultKind::LinkRestore));
        assert!(!h.is_degraded());
    }
}
