//! # DFLOP — data-driven framework for multimodal LLM training pipeline
//! # optimization (reproduction)
//!
//! Three-layer reproduction of An et al., "DFLOP" (CS.DC 2026):
//!
//! - **L3 (this crate)** — the paper's system contribution in rust: the
//!   Profiling Engine (§3.2), Data-aware 3D Parallelism Optimizer (§3.3),
//!   Online Microbatch Scheduler with ILP + LPT + Adaptive Correction
//!   (§3.4), plus every substrate they need: an A100 cluster ground-truth
//!   model, a 1F1B pipeline executor, Megatron/PyTorch-style baselines, a
//!   workload synthesizer, and a PJRT runtime for real execution.
//! - **L2 (python/compile/model.py)** — a real small MLLM (encoder →
//!   connector → LLM) in JAX, AOT-lowered to HLO text artifacts.
//! - **L1 (python/compile/kernels/)** — Pallas kernels (packed varlen
//!   attention, fused MLP) called from L2.
//!
//! See DESIGN.md for the system inventory and per-experiment index.

// The `xla` feature gates the real PJRT path, which needs the vendored
// `xla` crate. Fail with instructions instead of E0432 until it is wired
// in (delete this guard as part of adding the path dependency).
#[cfg(feature = "xla")]
compile_error!(
    "the `xla` feature needs the vendored `xla` crate: add it as a path dependency in \
     rust/Cargo.toml and remove this guard (see DESIGN.md, \"Reproduction posture\")"
);

pub mod baselines;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod fault;
pub mod figures;
pub mod obs;
pub mod perfmodel;
pub mod pipeline;
pub mod optimizer;
pub mod profiling;
pub mod runtime;
pub mod scheduler;
pub mod shard;
pub mod sim;
pub mod stream;
pub mod model;
pub mod util;
