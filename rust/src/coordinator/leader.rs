//! The leader loop: real multimodal training driven by DFLOP scheduling.
//!
//! Each iteration draws a global batch of variable-shape items (images +
//! token sequences), partitions it into microbatches — balanced by the
//! hybrid ILP/LPT mechanism or randomly (the baseline policy) — packs each
//! microbatch into the smallest compiled shape bucket, and executes it
//! through the PJRT [`TrainSession`]. Balanced buckets pad less and hit
//! smaller buckets, which is the real-hardware analogue of the paper's
//! pipeline-bubble reduction.
//!
//! Scheduling runs on a separate thread, one iteration ahead of execution
//! (§3.4.2's asynchronous prefetch): while iteration `t` executes, the
//! partition for `t+1` is computed on the CPU.

use crate::runtime::artifacts::Manifest;
use crate::runtime::session::TrainSession;
use crate::runtime::taskgen::{prototype, TrainBatch};
use crate::scheduler::ilp;
use crate::scheduler::lpt::ItemCost;
use crate::util::error::Result;
use crate::util::rng::Rng;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// How microbatches are formed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SchedMode {
    /// DFLOP: hybrid ILP/LPT balancing on predicted per-item cost.
    Balanced,
    /// Baseline: random assignment with equal counts.
    Random,
}

/// One logical training item before packing.
#[derive(Clone, Debug)]
pub struct Item {
    pub key: u32,
    pub tokens: usize,
}

/// Leader configuration.
#[derive(Clone, Debug)]
pub struct LeaderConfig {
    /// Items per global batch.
    pub gbs: usize,
    /// Microbatches per iteration.
    pub n_mb: usize,
    pub iterations: usize,
    pub lr: f32,
    pub seed: u64,
    pub mode: SchedMode,
    /// ILP budget per scheduling call.
    pub ilp_budget: Duration,
}

/// Outcome of a leader run.
#[derive(Clone, Debug)]
pub struct LeaderReport {
    pub losses: Vec<f32>,
    /// Wall-clock per iteration (execution only; scheduling overlaps).
    pub iter_seconds: Vec<f64>,
    /// Scheduling wall-clock per iteration (hidden by the async design).
    pub sched_seconds: Vec<f64>,
    /// Padding overhead: padded tokens / useful tokens, averaged.
    pub padding_overhead: f64,
    pub steps: u64,
}

impl LeaderReport {
    pub fn mean_iter_seconds(&self) -> f64 {
        self.iter_seconds.iter().sum::<f64>() / self.iter_seconds.len().max(1) as f64
    }

    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap_or(&f32::NAN)
    }
}

/// The training leader.
pub struct Leader {
    pub session: TrainSession,
    pub cfg: LeaderConfig,
}

/// Draw one global batch of logical items.
fn draw_items(rng: &mut Rng, manifest: &Manifest, gbs: usize) -> Vec<Item> {
    (0..gbs)
        .map(|_| Item {
            key: rng.below(manifest.task.n_keys as u64) as u32,
            // Heavy-tailed token lengths (the heterogeneity DFLOP targets).
            tokens: (rng.lognormal(4.2, 0.5).round() as usize).clamp(24, 360),
        })
        .collect()
}

/// Estimated per-item cost: encoder work ∝ images (1 per item here), LLM
/// linear work ∝ tokens plus quadratic attention share. The coefficients
/// only need to be *proportional* for balancing to work.
fn item_costs(items: &[Item]) -> Vec<ItemCost> {
    items
        .iter()
        .map(|it| ItemCost {
            enc: 1.0,
            llm: it.tokens as f64 + (it.tokens as f64) * (it.tokens as f64) / 512.0,
        })
        .collect()
}

/// Partition items into `n_mb` index groups.
fn partition(
    items: &[Item],
    n_mb: usize,
    mode: SchedMode,
    budget: Duration,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    match mode {
        SchedMode::Balanced => {
            let costs = item_costs(items);
            ilp::solve(&costs, n_mb, budget).assignment.buckets
        }
        SchedMode::Random => {
            let mut order: Vec<usize> = (0..items.len()).collect();
            rng.shuffle(&mut order);
            let mut out = vec![Vec::new(); n_mb];
            for (pos, &i) in order.iter().enumerate() {
                out[pos % n_mb].push(i);
            }
            out
        }
    }
}

/// Pack one microbatch of items into a concrete [`TrainBatch`] for the
/// smallest fitting compiled bucket. Token sequences are generated from
/// each item's key (same recurrence as `taskgen`); overflow beyond the
/// largest bucket is truncated (and counted as padding overhead 0).
fn pack(
    rng: &mut Rng,
    manifest: &Manifest,
    items: &[Item],
) -> (TrainBatch, f64) {
    let m = &manifest.model;
    let n_img = items.len().max(1);
    let useful: usize = items.iter().map(|i| i.tokens).sum();
    let bucket = manifest
        .bucket_for(n_img, useful)
        .or_else(|| manifest.train_steps.iter().max_by_key(|b| (b.n_img, b.seq)))
        .expect("at least one bucket");
    let (bn, bs) = (bucket.n_img, bucket.seq);

    let t = m.tokens_per_image;
    let p = m.patch_dim;
    let mut batch = TrainBatch {
        n_img: bn,
        seq: bs,
        patches: vec![0.0; bn * t * p],
        token_ids: vec![0; bs],
        segment_ids: vec![0; bs],
        img_index: vec![bn as i32; bs],
        keys: Vec::new(),
    };
    let mut pos = 0usize;
    for (i, item) in items.iter().enumerate().take(bn) {
        let proto = prototype(item.key, p);
        for tok in 0..t {
            for j in 0..p {
                batch.patches[(i * t + tok) * p + j] =
                    proto[j] + (manifest.task.noise * rng.normal()) as f32;
            }
        }
        let remaining = bs - pos;
        let len = item.tokens.min(remaining);
        if len == 0 {
            break;
        }
        let mut cur = rng.below(m.vocab as u64) as i64;
        for s in 0..len {
            batch.token_ids[pos + s] = cur as i32;
            batch.segment_ids[pos + s] = (i + 1) as i32;
            batch.img_index[pos + s] = i as i32;
            cur = (cur + 1 + item.key as i64) % m.vocab as i64;
        }
        batch.keys.push(item.key);
        pos += len;
    }
    let overhead = (bs - pos) as f64 / pos.max(1) as f64;
    (batch, overhead)
}

impl Leader {
    pub fn new(session: TrainSession, cfg: LeaderConfig) -> Leader {
        Leader { session, cfg }
    }

    /// Run the training loop with asynchronous scheduling: a scheduler
    /// thread partitions batch `t+1` while batch `t` executes.
    pub fn run(&mut self) -> Result<LeaderReport> {
        let cfg = self.cfg.clone();
        let manifest = self.session.manifest.clone();
        let (tx, rx) = mpsc::sync_channel::<(Vec<Item>, Vec<Vec<usize>>, f64)>(1);

        // Scheduler thread: draws + partitions all iterations ahead,
        // bounded by the channel to one-iteration lookahead.
        let sched = std::thread::spawn(move || {
            let mut rng = Rng::new(cfg.seed);
            for _ in 0..cfg.iterations {
                let items = draw_items(&mut rng, &manifest, cfg.gbs);
                let t0 = Instant::now();
                let groups =
                    partition(&items, cfg.n_mb, cfg.mode, cfg.ilp_budget, &mut rng);
                let sched_s = t0.elapsed().as_secs_f64();
                if tx.send((items, groups, sched_s)).is_err() {
                    return; // executor dropped
                }
            }
        });

        let mut pack_rng = Rng::new(self.cfg.seed ^ 0x9ACC);
        let mut losses = Vec::new();
        let mut iter_seconds = Vec::new();
        let mut sched_seconds = Vec::new();
        let mut pad_acc = 0.0;
        let mut pad_n = 0usize;
        for _ in 0..self.cfg.iterations {
            let (items, groups, sched_s) = rx.recv().expect("scheduler thread alive");
            sched_seconds.push(sched_s);
            let t0 = Instant::now();
            let mut loss_acc = 0.0f64;
            let mut mb_count = 0usize;
            for group in &groups {
                if group.is_empty() {
                    continue;
                }
                let mb_items: Vec<Item> =
                    group.iter().map(|&i| items[i].clone()).collect();
                let (batch, overhead) = pack(&mut pack_rng, &self.session.manifest, &mb_items);
                pad_acc += overhead;
                pad_n += 1;
                let loss = self.session.step(&batch, self.cfg.lr)?;
                loss_acc += loss as f64;
                mb_count += 1;
            }
            iter_seconds.push(t0.elapsed().as_secs_f64());
            losses.push((loss_acc / mb_count.max(1) as f64) as f32);
        }
        sched.join().expect("scheduler thread");
        Ok(LeaderReport {
            losses,
            iter_seconds,
            sched_seconds,
            padding_overhead: pad_acc / pad_n.max(1) as f64,
            steps: self.session.steps_taken,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partition_modes_cover_all_items() {
        let mut rng = Rng::new(1);
        let items: Vec<Item> = (0..17)
            .map(|i| Item { key: i % 8, tokens: 24 + (i as usize * 13) % 200 })
            .collect();
        for mode in [SchedMode::Balanced, SchedMode::Random] {
            let groups =
                partition(&items, 4, mode, Duration::from_millis(20), &mut rng);
            let mut seen = vec![false; 17];
            for g in &groups {
                for &i in g {
                    assert!(!seen[i]);
                    seen[i] = true;
                }
            }
            assert!(seen.into_iter().all(|s| s), "{mode:?}");
        }
    }

    #[test]
    fn balanced_partition_has_lower_spread() {
        let mut rng = Rng::new(2);
        let items: Vec<Item> = (0..32)
            .map(|_| Item {
                key: rng.below(8) as u32,
                tokens: (rng.lognormal(4.2, 0.5).round() as usize).clamp(24, 360),
            })
            .collect();
        let load = |groups: &[Vec<usize>]| -> (f64, f64) {
            let loads: Vec<f64> = groups
                .iter()
                .map(|g| g.iter().map(|&i| items[i].tokens as f64).sum())
                .collect();
            let max = loads.iter().cloned().fold(0.0, f64::max);
            let min = loads.iter().cloned().fold(f64::INFINITY, f64::min);
            (max, min)
        };
        let bal = partition(&items, 4, SchedMode::Balanced, Duration::from_millis(50), &mut rng);
        let ran = partition(&items, 4, SchedMode::Random, Duration::from_millis(50), &mut rng);
        let (bmax, bmin) = load(&bal);
        let (rmax, rmin) = load(&ran);
        assert!(bmax - bmin <= rmax - rmin, "balanced spread {} vs random {}", bmax - bmin, rmax - rmin);
    }
}
