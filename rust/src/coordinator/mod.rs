//! L3 coordinator: ties the Online Microbatch Scheduler to the real PJRT
//! runtime for end-to-end training, with the paper's asynchronous
//! scheduling (§3.4.2: "while the model executes the computation for the
//! current iteration, the scheduler processes the subsequent global batch
//! in parallel on the CPU").
#[cfg(feature = "xla")]
pub mod leader;

#[cfg(feature = "xla")]
pub use leader::{Leader, LeaderConfig, LeaderReport, SchedMode};
