//! Deterministic drift detection against the profile-time reference.
//!
//! The Data Profiler's sampled distribution is the contract θ* was
//! optimized against; this module watches the live [`ShapeStats`] window
//! and decides when that contract is broken. Three complementary
//! statistics are computed, all pure functions of integer aggregates:
//!
//! - **Quantile distance** — mean relative displacement of the LLM
//!   sequence-length deciles between the live window and the reference
//!   (the per-item *LLM work shape* moving);
//! - **Units distance** — the same over encoder unit deciles, against an
//!   absolute floor so small-integer decile flips read as noise (the
//!   *encoder work shape* moving, which sizes θ*'s GPU split);
//! - **Mixture total variation** — `½ · Σ_s |p_live(s) − p_ref(s)|` over
//!   source item shares (the *modality mix* moving, e.g. a curriculum
//!   text→video ramp), which reacts even when per-source shapes are
//!   stable.
//!
//! The decision uses the max of the two with **hysteresis** so sampling
//! noise cannot thrash the replanner: the score must sit at or above
//! `enter` for `confirm` consecutive windows to fire; between `exit` and
//! `enter` the confirmation count holds; at or below `exit` it resets.
//! After a replan the caller rebases the reference onto the live window
//! ([`DriftDetector::rebase`]), so subsequent drift is measured against
//! the distribution the *new* plan was fitted to.

use crate::data::item::ItemShape;
use crate::stream::window::ShapeStats;

/// Detector thresholds. Defaults are sized for windows of ≥150 items:
/// stationary Table-2 mixtures score ≲0.1 on both statistics, while the
/// scenario shifts in `data::sources` score 0.4–0.8.
#[derive(Clone, Copy, Debug)]
pub struct DriftConfig {
    /// Fire threshold (score ≥ enter for `confirm` windows ⇒ drift).
    pub enter: f64,
    /// Re-arm threshold (score ≤ exit resets the confirmation count).
    pub exit: f64,
    /// Consecutive over-threshold windows required before firing.
    pub confirm: usize,
}

impl Default for DriftConfig {
    fn default() -> Self {
        DriftConfig { enter: 0.25, exit: 0.10, confirm: 2 }
    }
}

/// The drift statistics for one window evaluation.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftStat {
    /// Mean relative decile displacement of LLM sequence lengths.
    pub quantile_dist: f64,
    /// Mean relative decile displacement of encoder unit counts. Unit
    /// deciles are small integers, so the relative error is taken against
    /// a floor of [`UNITS_FLOOR`] — otherwise a one-unit flip of a
    /// low decile (2 → 3) would read as a 50% shift and sampling noise
    /// could thrash the detector.
    pub units_dist: f64,
    /// Total-variation distance between source mixture shares.
    pub mix_tv: f64,
}

/// Denominator floor for the encoder-units decile distance.
pub const UNITS_FLOOR: f64 = 8.0;

impl DriftStat {
    /// The scalar the hysteresis thresholds apply to.
    pub fn score(&self) -> f64 {
        self.quantile_dist.max(self.units_dist).max(self.mix_tv)
    }
}

/// One observation's outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Decision {
    /// Score below the hysteresis band (or inside it with no history).
    Stable,
    /// Score at/above `enter` but not yet confirmed.
    Watch,
    /// Drift confirmed — the caller should replan and
    /// [`DriftDetector::rebase`].
    Drift,
}

/// Stateful detector comparing live windows against a reference
/// distribution.
#[derive(Clone, Debug)]
pub struct DriftDetector {
    pub cfg: DriftConfig,
    reference: ShapeStats,
    watch: usize,
    /// Statistics of the most recent observation (diagnostics).
    pub last: Option<DriftStat>,
}

impl DriftDetector {
    pub fn new(cfg: DriftConfig, reference: ShapeStats) -> DriftDetector {
        DriftDetector { cfg, reference, watch: 0, last: None }
    }

    /// Build the reference from profile-time samples.
    pub fn from_shapes(cfg: DriftConfig, shapes: &[ItemShape]) -> DriftDetector {
        DriftDetector::new(cfg, ShapeStats::of_batch(shapes))
    }

    pub fn reference(&self) -> &ShapeStats {
        &self.reference
    }

    /// Compute the statistics for a live aggregate (stateless).
    pub fn statistic(&self, live: &ShapeStats) -> DriftStat {
        stat_between(&self.reference, live)
    }

    /// Evaluate one full window and advance the hysteresis state machine.
    pub fn observe(&mut self, live: &ShapeStats) -> Decision {
        let stat = self.statistic(live);
        self.last = Some(stat);
        let score = stat.score();
        if score >= self.cfg.enter {
            self.watch += 1;
            if self.watch >= self.cfg.confirm {
                self.watch = 0;
                return Decision::Drift;
            }
            return Decision::Watch;
        }
        if score <= self.cfg.exit {
            self.watch = 0;
        }
        // Inside the hysteresis band the confirmation count holds.
        Decision::Stable
    }

    /// Adopt a new reference (after a replan) and reset confirmation.
    pub fn rebase(&mut self, reference: ShapeStats) {
        self.reference = reference;
        self.watch = 0;
    }
}

/// The drift statistics between two arbitrary aggregates — the stateless
/// core [`DriftDetector::statistic`] is built on. The shard layer reuses
/// it as a *skew* statistic, scoring each shard's window against the
/// pooled cross-shard window to decide whether the replicas are
/// distributionally heterogeneous (`shard::agg::ShardWindows::max_skew`).
pub fn stat_between(reference: &ShapeStats, live: &ShapeStats) -> DriftStat {
    let mut seq_acc = 0.0;
    let mut units_acc = 0.0;
    for k in 1..=9 {
        let q = k as f64 / 10.0;
        let r = reference.seq_quantile(q);
        let l = live.seq_quantile(q);
        seq_acc += (l - r).abs() / r.max(1.0);
        let ru = reference.units_quantile(q);
        let lu = live.units_quantile(q);
        units_acc += (lu - ru).abs() / ru.max(UNITS_FLOOR);
    }
    let ref_shares = reference.source_shares();
    let live_shares = live.source_shares();
    let tv: f64 = live_shares
        .iter()
        .zip(&ref_shares)
        .map(|(l, r)| (l - r).abs())
        .sum();
    DriftStat {
        quantile_dist: seq_acc / 9.0,
        units_dist: units_acc / 9.0,
        mix_tv: 0.5 * tv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::stream::window::ShapeWindow;

    fn uniform_shapes(seq: u32, n: usize, source: u8) -> Vec<ItemShape> {
        vec![ItemShape { units: 2, llm_seq: seq, source }; n]
    }

    #[test]
    fn identical_distribution_scores_zero() {
        let shapes = uniform_shapes(1000, 200, 0);
        let det = DriftDetector::from_shapes(DriftConfig::default(), &shapes);
        let s = det.statistic(&ShapeStats::of_batch(&shapes));
        assert_eq!(s.quantile_dist, 0.0);
        assert_eq!(s.units_dist, 0.0);
        assert_eq!(s.mix_tv, 0.0);
    }

    #[test]
    fn encoder_units_drift_is_detected() {
        // LLM sequence lengths and source mix stay stable while per-item
        // encoder units grow (e.g. higher-resolution tiling): only the
        // units axis can see it.
        let shapes_with_units = |units: u32| -> Vec<ItemShape> {
            (0..300u32)
                .map(|i| ItemShape { units, llm_seq: 3000 + (i % 7), source: 0 })
                .collect()
        };
        let mut det =
            DriftDetector::from_shapes(DriftConfig::default(), &shapes_with_units(4));
        let live = ShapeStats::of_batch(&shapes_with_units(24));
        let s = det.statistic(&live);
        assert_eq!(s.quantile_dist, 0.0);
        assert_eq!(s.mix_tv, 0.0);
        assert!(s.units_dist > 1.0, "units drift invisible: {s:?}");
        assert_eq!(det.observe(&live), Decision::Watch);
        assert_eq!(det.observe(&live), Decision::Drift);
    }

    #[test]
    fn stationary_mixture_never_fires() {
        // The no-thrash guarantee at the detector level: a stationary
        // Table-2 mixture must not fire over a long run.
        let m = llava_ov(llama3("8b"));
        let mut profile_ds = Dataset::mixed(0xDA7A);
        let det_ref = profile_ds.shaped_batch(&m, 512);
        let mut det = DriftDetector::from_shapes(DriftConfig::default(), &det_ref);
        let mut ds = Dataset::mixed(7);
        let mut w = ShapeWindow::new(8);
        for _ in 0..30 {
            w.push(&ds.shaped_batch(&m, 64));
            if w.is_full() {
                assert_ne!(det.observe(w.stats()), Decision::Drift);
            }
        }
    }

    #[test]
    fn sustained_shift_fires_after_confirmation() {
        let reference = uniform_shapes(1000, 300, 0);
        let cfg = DriftConfig { enter: 0.2, exit: 0.08, confirm: 2 };
        let mut det = DriftDetector::from_shapes(cfg, &reference);
        // ~60% longer sequences: quantile distance well past `enter`.
        let live = ShapeStats::of_batch(&uniform_shapes(1600, 300, 0));
        assert_eq!(det.observe(&live), Decision::Watch);
        assert_eq!(det.observe(&live), Decision::Drift);
        // After firing the count reset; it takes `confirm` windows again.
        assert_eq!(det.observe(&live), Decision::Watch);
    }

    #[test]
    fn mixture_shift_fires_even_with_stable_shapes() {
        // Same per-item shapes, different source labels: only mix_tv sees
        // it.
        let reference = uniform_shapes(1000, 300, 0);
        let mut det = DriftDetector::from_shapes(DriftConfig::default(), &reference);
        let live = ShapeStats::of_batch(&uniform_shapes(1000, 300, 3));
        let s = det.statistic(&live);
        assert_eq!(s.quantile_dist, 0.0);
        assert!((s.mix_tv - 1.0).abs() < 1e-12);
        assert_eq!(det.observe(&live), Decision::Watch);
    }

    #[test]
    fn hysteresis_band_holds_then_exit_resets() {
        let reference = uniform_shapes(1000, 400, 0);
        let cfg = DriftConfig { enter: 0.30, exit: 0.05, confirm: 3 };
        let mut det = DriftDetector::from_shapes(cfg, &reference);
        let high = ShapeStats::of_batch(&uniform_shapes(1700, 400, 0));
        // 20% of the mass displaced one octave up: only the top decile
        // moves, so the mean decile displacement lands inside the
        // (exit, enter) hysteresis band.
        let mut mid_shapes = uniform_shapes(1000, 320, 0);
        mid_shapes.extend(uniform_shapes(1600, 80, 0));
        let mid = ShapeStats::of_batch(&mid_shapes);
        let calm = ShapeStats::of_batch(&uniform_shapes(1000, 400, 0));
        assert_eq!(det.observe(&high), Decision::Watch);
        // Inside the band: Stable, but the confirmation count holds …
        assert_eq!(det.observe(&mid), Decision::Stable);
        assert_eq!(det.observe(&high), Decision::Watch);
        // … so one more over-threshold window completes confirm = 3.
        assert_eq!(det.observe(&high), Decision::Drift);
        // At/below exit the count resets.
        assert_eq!(det.observe(&high), Decision::Watch);
        assert_eq!(det.observe(&calm), Decision::Stable);
        assert_eq!(det.observe(&high), Decision::Watch);
        assert_eq!(det.observe(&high), Decision::Watch);
        assert_eq!(det.observe(&high), Decision::Drift);
    }

    #[test]
    fn rebase_adopts_new_reference() {
        let reference = uniform_shapes(1000, 300, 0);
        let mut det = DriftDetector::from_shapes(DriftConfig::default(), &reference);
        let live = ShapeStats::of_batch(&uniform_shapes(1700, 300, 2));
        assert!(det.statistic(&live).score() > det.cfg.enter);
        det.rebase(live.clone());
        assert_eq!(det.statistic(&live).score(), 0.0);
    }
}
