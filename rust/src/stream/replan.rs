//! Adaptive replanning: confirmed drift → refit the shape distribution →
//! warm-started optimizer run → plan swap between iterations.
//!
//! The [`Replanner`] glues the stream layer together: it feeds every
//! global batch into the sliding [`ShapeWindow`] and the
//! [`ShapeReservoir`], asks the [`DriftDetector`] whether the live
//! distribution still matches the one θ* was optimized for, and on
//! confirmed drift rebuilds Eq 1's `D` from the reservoir and re-invokes
//! `optimizer::search` **warm-started from the incumbent θ***
//! ([`optimize_warm`]) — the incumbent seeds the candidate top-K and its
//! mean-approximation score (with a slack margin) prunes GPU splits that
//! cannot come near it, so a replan is much cheaper than a cold search.
//! The optimizer
//! itself fans its scan and Eq-1 refinement over the `util::parallel`
//! pool, and the new plan is swapped in at the next iteration boundary.
//!
//! Thrash control is layered: the detector's hysteresis (enter/exit
//! thresholds + confirmation count), a post-replan cooldown, and a
//! reference rebase onto the window that triggered the replan — so the
//! next drift is measured against the distribution the *new* plan was
//! fitted to. On stationary data no replan ever fires (enforced by the
//! trainer's no-thrash test).

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::batch::{candidate_tables, eval_candidates};
use crate::optimizer::plan::Theta;
use crate::optimizer::search::{optimize_warm, OptimizerInputs};
use crate::profiling::engine::{DataProfile, ModelProfile};
use crate::stream::drift::{Decision, DriftConfig, DriftDetector, DriftStat};
use crate::stream::reservoir::ShapeReservoir;
use crate::stream::window::{ShapeStats, ShapeWindow};
use std::time::{Duration, Instant};

/// Controller tuning. Defaults detect the `data::sources` scenario shifts
/// within a few iterations at GBS ≥ 32 while never firing on stationary
/// Table-2 mixtures.
#[derive(Clone, Debug)]
pub struct ReplanConfig {
    /// Sliding-window width in global batches.
    pub window_batches: usize,
    /// Shapes retained for refitting the live distribution.
    pub reservoir: usize,
    /// Iterations after a replan before drift is evaluated again.
    pub cooldown: usize,
    /// Base cooldown after a *failed* refit (optimizer found no feasible
    /// plan): the retry fires after `retry_backoff << (attempt − 1)`
    /// iterations, capped at `cooldown`, instead of silently keeping the
    /// stale θ* for a full cooldown.
    pub retry_backoff: usize,
    /// Failed-refit retries before giving up: the stale plan is then
    /// accepted as the new reference and the normal cadence resumes.
    pub max_refit_retries: usize,
    /// Detector thresholds (hysteresis + confirmation).
    pub drift: DriftConfig,
}

impl Default for ReplanConfig {
    fn default() -> Self {
        ReplanConfig {
            window_batches: 8,
            reservoir: 384,
            cooldown: 8,
            retry_backoff: 2,
            max_refit_retries: 3,
            drift: DriftConfig::default(),
        }
    }
}

/// One confirmed-drift replan (swapped or not).
#[derive(Clone, Debug)]
pub struct ReplanEvent {
    /// Iteration whose batch confirmed the drift.
    pub iteration: usize,
    /// Detector statistics at the trigger.
    pub stat: DriftStat,
    pub old: Theta,
    pub new: Theta,
    /// Whether the optimizer actually changed the plan.
    pub swapped: bool,
    /// Eq-1 expected makespan of `new` under the refitted distribution.
    pub expected_makespan: f64,
    /// Eq-1 expected makespan of the *incumbent* `old` under the same
    /// refitted distribution — scored via the batched evaluator before
    /// the warm restart, so `expected_incumbent − expected_makespan` is
    /// the optimizer's predicted benefit of the swap (`obs::audit`
    /// compares it to the measured counterfactual benefit). NaN on
    /// failed refits.
    pub expected_incumbent: f64,
    /// Wall-clock of the warm-started optimizer run.
    pub elapsed: Duration,
}

/// The optimizer-facing context a replan needs (everything in
/// `OptimizerInputs` except the data profile, which the replanner refits
/// itself). The engine's plan policies carry one per run — per-replica
/// GBS for sharded runs — and `engine::hetero` reuses it for every
/// per-shard fit.
#[derive(Clone, Copy)]
pub struct ReplanContext<'a> {
    pub m: &'a Mllm,
    pub profile: &'a ModelProfile,
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    pub mem_capacity: f64,
    pub gbs: usize,
}

impl<'a> ReplanContext<'a> {
    /// Assemble the optimizer inputs for a (re)plan against `data` — the
    /// single place the context-to-inputs mapping lives (used by the
    /// replan path and by every test that seeds an initial θ*).
    pub fn inputs<'b>(&'b self, data: &'b DataProfile) -> OptimizerInputs<'b> {
        OptimizerInputs {
            m: self.m,
            profile: self.profile,
            data,
            n_gpus: self.n_gpus,
            gpus_per_node: self.gpus_per_node,
            mem_capacity: self.mem_capacity,
            gbs: self.gbs,
            // Replans only run for the full system (scheduler active).
            assume_balanced: true,
        }
    }
}

/// The drift-aware plan controller.
#[derive(Clone, Debug)]
pub struct Replanner {
    pub cfg: ReplanConfig,
    window: ShapeWindow,
    reservoir: ShapeReservoir,
    detector: DriftDetector,
    /// The live plan (starts at the offline θ*).
    pub theta: Theta,
    /// Every confirmed drift, in iteration order.
    pub events: Vec<ReplanEvent>,
    cooldown: usize,
    iteration: usize,
    failed_refits: usize,
    /// The detector's decision at the latest evaluated window (`None`
    /// until one fills), surfaced for observability.
    last_decision: Option<Decision>,
}

impl Replanner {
    /// `reference` is the offline Data Profiler output θ* was fitted to.
    pub fn new(reference: &DataProfile, theta: Theta, cfg: ReplanConfig) -> Replanner {
        let detector = DriftDetector::from_shapes(cfg.drift, &reference.samples);
        Replanner {
            window: ShapeWindow::new(cfg.window_batches),
            reservoir: ShapeReservoir::new(cfg.reservoir),
            detector,
            theta,
            events: Vec::new(),
            cooldown: 0,
            iteration: 0,
            failed_refits: 0,
            last_decision: None,
            cfg,
        }
    }

    /// Feed one iteration's global batch — call *before* scheduling it.
    /// Returns the new plan when a confirmed drift swapped it; the caller
    /// applies it to this batch and everything after (the batch has not
    /// been scheduled yet, so the swap lands on the iteration boundary
    /// just crossed — exactly what `sim::trainer` does).
    pub fn observe_batch(
        &mut self,
        ctx: &ReplanContext,
        shapes: &[ItemShape],
    ) -> Option<Theta> {
        self.observe_stats(ctx, ShapeStats::of_batch(shapes), shapes)
    }

    /// [`Replanner::observe_batch`] for callers that aggregate the batch
    /// summary themselves: the shard layer merges per-shard
    /// [`ShapeStats`] into one global summary (`shard::agg`) and feeds it
    /// here with the pooled shapes, so drift is detected — and a replan
    /// fired — exactly *once* for the whole DP group instead of once per
    /// shard. `stats` must summarize exactly `shapes` (the integer merge
    /// guarantees the two views are bit-identical).
    pub fn observe_stats(
        &mut self,
        ctx: &ReplanContext,
        stats: ShapeStats,
        shapes: &[ItemShape],
    ) -> Option<Theta> {
        let iteration = self.iteration;
        self.iteration += 1;
        self.window.push_stats(stats);
        self.reservoir.extend(shapes);
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return None;
        }
        if !self.window.is_full() {
            return None;
        }
        let decision = self.detector.observe(self.window.stats());
        self.last_decision = Some(decision);
        match decision {
            Decision::Drift => self.replan(ctx, iteration),
            Decision::Watch | Decision::Stable => None,
        }
    }

    /// Confirmed drift: refit `D` from the reservoir and warm-restart the
    /// optimizer from the incumbent.
    fn replan(&mut self, ctx: &ReplanContext, iteration: usize) -> Option<Theta> {
        let stat = self.detector.last.expect("observe ran before replan");
        self.refit(ctx, iteration, stat)
    }

    /// Refit for a *confirmed external event* — a debounced topology
    /// change reported by the fault layer — rather than for data drift:
    /// same reservoir refit, warm restart, event record, and cooldown as
    /// a drift replan, but triggered by the caller. Returns `None` before
    /// any batch has been observed (nothing to refit from) or when the
    /// optimizer keeps the incumbent plan.
    pub fn force_replan(&mut self, ctx: &ReplanContext, iteration: usize) -> Option<Theta> {
        if self.reservoir.shapes().is_empty() {
            return None;
        }
        // Not a drift trigger: record whatever the detector last measured
        // (zero statistics if it never evaluated a window).
        let stat = self.detector.last.unwrap_or(DriftStat {
            quantile_dist: 0.0,
            units_dist: 0.0,
            mix_tv: 0.0,
        });
        self.refit(ctx, iteration, stat)
    }

    fn refit(&mut self, ctx: &ReplanContext, iteration: usize, stat: DriftStat) -> Option<Theta> {
        let t0 = Instant::now();
        let live = live_profile(ctx.m, self.reservoir.shapes());
        let inp = ctx.inputs(&live);
        // Score the incumbent under the refitted distribution first: one
        // batched-evaluator simulation whose Eq-1 value anchors the
        // replan's *predicted* benefit (audited against the measured
        // counterfactual by `obs::audit`).
        let incumbent = std::slice::from_ref(&self.theta);
        let (keys, tables) = candidate_tables(&inp, incumbent);
        let expected_incumbent = eval_candidates(&inp, &keys, &tables, incumbent)[0];
        match optimize_warm(&inp, Some(self.theta)) {
            Some(r) => {
                let swapped = r.theta != self.theta;
                self.events.push(ReplanEvent {
                    iteration,
                    stat,
                    old: self.theta,
                    new: r.theta,
                    swapped,
                    expected_makespan: r.expected_makespan,
                    expected_incumbent,
                    elapsed: t0.elapsed(),
                });
                self.theta = r.theta;
                self.failed_refits = 0;
                // Rebase: the new plan was fitted to (approximately) the
                // current window; measure future drift against it, and
                // hold off while the window refills with post-swap
                // batches.
                self.detector.rebase(self.window.stats().clone());
                self.cooldown = self.cfg.cooldown;
                swapped.then_some(r.theta)
            }
            // No feasible plan under the live distribution (should not
            // happen when the incumbent itself is feasible): keep θ.
            None => {
                self.failed_refits += 1;
                self.events.push(ReplanEvent {
                    iteration,
                    stat,
                    old: self.theta,
                    new: self.theta,
                    swapped: false,
                    expected_makespan: f64::NAN,
                    expected_incumbent: f64::NAN,
                    elapsed: t0.elapsed(),
                });
                if self.failed_refits <= self.cfg.max_refit_retries {
                    // Bounded deterministic retry: no rebase (the detector
                    // keeps firing on the unchanged reference) and an
                    // exponentially backed-off cooldown, so the refit gets
                    // another chance soon instead of silently keeping the
                    // stale θ* for a full cooldown.
                    self.cooldown = (self.cfg.retry_backoff << (self.failed_refits - 1))
                        .clamp(1, self.cfg.cooldown.max(1));
                } else {
                    // Retries exhausted: accept the stale plan as the new
                    // reference and return to the normal cadence.
                    self.failed_refits = 0;
                    self.detector.rebase(self.window.stats().clone());
                    self.cooldown = self.cfg.cooldown;
                }
                None
            }
        }
    }

    /// Confirmed drifts that actually changed the plan.
    pub fn swaps(&self) -> usize {
        self.events.iter().filter(|e| e.swapped).count()
    }

    /// Batches observed so far (the next batch's iteration index).
    pub fn iterations_observed(&self) -> usize {
        self.iteration
    }

    /// Iterations left before drift is evaluated again.
    pub fn cooldown_remaining(&self) -> usize {
        self.cooldown
    }

    /// Consecutive refits the optimizer has failed (retry attempt count).
    pub fn failed_refits(&self) -> usize {
        self.failed_refits
    }

    /// Detector statistics of the latest evaluated window.
    pub fn last_stat(&self) -> Option<DriftStat> {
        self.detector.last
    }

    /// The detector's decision at the latest evaluated window (`None`
    /// until the first window fills) — the observability recorder's view
    /// of the drift phase.
    pub fn drift_decision(&self) -> Option<Decision> {
        self.last_decision
    }

    pub fn window(&self) -> &ShapeWindow {
        &self.window
    }
}

/// Refit a [`DataProfile`] from live samples (the online analogue of
/// `profiling::engine::profile_data`, sharing its assembly via
/// [`DataProfile::from_samples`]; the sampling pass is the training
/// stream itself, so no profiling wall-clock is charged).
pub fn live_profile(m: &Mllm, shapes: &[ItemShape]) -> DataProfile {
    assert!(!shapes.is_empty(), "live_profile on empty reservoir");
    DataProfile::from_samples("live-window", m, shapes.to_vec(), 0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};

    fn fixture() -> (Mllm, ModelProfile, ClusterSpec) {
        let m = llava_ov(llama3("8b"));
        let cluster = ClusterSpec::hgx_a100(1);
        let mut backend = SimBackend::new(Truth::new(cluster));
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
        (m, profile, cluster)
    }

    fn ctx<'a>(
        m: &'a Mllm,
        profile: &'a ModelProfile,
        cluster: &ClusterSpec,
        gbs: usize,
    ) -> ReplanContext<'a> {
        ReplanContext {
            m,
            profile,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs,
        }
    }

    #[test]
    fn stationary_stream_never_replans() {
        let (m, profile, cluster) = fixture();
        let mut profile_ds = Dataset::mixed(0xDA7A);
        let data = profile_data(&m, &mut profile_ds, 256);
        let rctx = ctx(&m, &profile, &cluster, 32);
        let theta = crate::optimizer::search::optimize(&rctx.inputs(&data))
            .expect("feasible")
            .theta;
        let mut rp = Replanner::new(&data, theta, ReplanConfig::default());
        let mut ds = Dataset::mixed(9);
        for _ in 0..20 {
            let batch = ds.shaped_batch(&m, 32);
            assert!(rp.observe_batch(&rctx, &batch).is_none());
        }
        assert!(rp.events.is_empty(), "stationary data fired {:?}", rp.events);
        assert_eq!(rp.theta, theta);
    }

    #[test]
    fn distribution_switch_triggers_replan_and_rebase() {
        // Profile on the narrow multi-image scenario, then switch the
        // stream to video: the detector must confirm drift, the replanner
        // must produce a (feasible) plan for the new distribution, and
        // after the rebase + cooldown the now-stationary video stream must
        // not fire again.
        let (m, profile, cluster) = fixture();
        let data = profile_data(&m, &mut Dataset::multi_image(0xDA7A), 256);
        let rctx = ctx(&m, &profile, &cluster, 64);
        let theta = crate::optimizer::search::optimize(&rctx.inputs(&data))
            .expect("feasible")
            .theta;
        let cfg = ReplanConfig {
            window_batches: 4,
            cooldown: 4,
            ..ReplanConfig::default()
        };
        let mut rp = Replanner::new(&data, theta, cfg);
        let mut ds = Dataset::video(11);
        for _ in 0..16 {
            let batch = ds.shaped_batch(&m, 64);
            rp.observe_batch(&rctx, &batch);
        }
        assert_eq!(rp.events.len(), 1, "expected exactly one drift: {:?}", rp.events);
        let e = &rp.events[0];
        assert!(e.stat.score() >= rp.cfg.drift.enter);
        assert!(e.expected_makespan > 0.0);
        assert!(
            e.expected_incumbent > 0.0
                && e.expected_incumbent >= e.expected_makespan * (1.0 - 1e-9),
            "incumbent re-score must be finite and no better than the refit winner: \
             incumbent {} vs adopted {}",
            e.expected_incumbent,
            e.expected_makespan
        );
        assert_eq!(rp.theta.gpus(), cluster.total_gpus());
    }

    #[test]
    fn failed_refits_retry_with_bounded_backoff() {
        let (m, profile, cluster) = fixture();
        let data = profile_data(&m, &mut Dataset::mixed(0xDA7A), 256);
        let rctx = ctx(&m, &profile, &cluster, 32);
        let theta = crate::optimizer::search::optimize(&rctx.inputs(&data))
            .expect("feasible")
            .theta;
        let mut rp = Replanner::new(&data, theta, ReplanConfig::default());
        let mut ds = Dataset::mixed(9);
        for _ in 0..3 {
            rp.observe_batch(&rctx, &ds.shaped_batch(&m, 32));
        }
        // A context no plan can satisfy: every refit fails.
        let infeasible = ReplanContext { mem_capacity: 1.0, ..rctx };
        // Attempts 1..=max retry with exponential backoff, capped at the
        // normal cooldown; the stale plan is kept throughout.
        for (attempt, want_cooldown) in [(1usize, 2usize), (2, 4), (3, 8)] {
            assert!(rp.force_replan(&infeasible, attempt).is_none());
            assert_eq!(rp.failed_refits(), attempt);
            assert_eq!(rp.cooldown_remaining(), want_cooldown, "attempt {attempt}");
            assert_eq!(rp.theta, theta, "failed refits keep the incumbent");
        }
        // One more failure exhausts the retries: the counter resets and
        // the normal cadence resumes.
        assert!(rp.force_replan(&infeasible, 4).is_none());
        assert_eq!(rp.failed_refits(), 0);
        assert_eq!(rp.cooldown_remaining(), rp.cfg.cooldown);
        assert_eq!(rp.events.len(), 4);
        assert!(rp.events.iter().all(|e| !e.swapped));
        assert!(rp.events.iter().all(|e| e.expected_makespan.is_nan()));
        // A feasible refit clears the failure streak.
        assert_eq!(rp.force_replan(&rctx, 5).is_some(), rp.theta != theta);
        assert_eq!(rp.failed_refits(), 0);
    }

    #[test]
    fn force_replan_needs_observed_batches_and_records_an_event() {
        let (m, profile, cluster) = fixture();
        let data = profile_data(&m, &mut Dataset::mixed(0xDA7A), 256);
        let rctx = ctx(&m, &profile, &cluster, 32);
        let theta = crate::optimizer::search::optimize(&rctx.inputs(&data))
            .expect("feasible")
            .theta;
        let mut rp = Replanner::new(&data, theta, ReplanConfig::default());
        // Nothing observed yet: nothing to refit from.
        assert!(rp.force_replan(&rctx, 0).is_none());
        assert!(rp.events.is_empty());
        let mut ds = Dataset::mixed(9);
        for _ in 0..2 {
            rp.observe_batch(&rctx, &ds.shaped_batch(&m, 32));
        }
        // A confirmed topology change shrinks the group: the per-replica
        // batch grows and the refit runs against the live reservoir.
        let shrunk = ReplanContext { gbs: 48, ..rctx };
        rp.force_replan(&shrunk, 2);
        assert_eq!(rp.events.len(), 1, "forced refits are recorded like drift replans");
        assert_eq!(rp.events[0].iteration, 2);
        assert_eq!(rp.events[0].stat.score(), 0.0, "no drift statistic backs the event");
        assert_eq!(rp.cooldown_remaining(), rp.cfg.cooldown);
    }

    #[test]
    fn live_profile_summarizes_reservoir() {
        let m = llava_ov(llama3("8b"));
        let shapes = Dataset::video(3).shaped_batch(&m, 200);
        let p = live_profile(&m, &shapes);
        assert_eq!(p.samples.len(), 200);
        assert_eq!(p.dataset_name, "live-window");
        assert!(p.mean_seq() > 500.0);
        assert_eq!(p.profiling_seconds, 0.0);
    }
}
