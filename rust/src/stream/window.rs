//! Sliding-window shape statistics over the incoming batch stream.
//!
//! The profiling engine characterizes the dataset *once*, offline; this
//! module keeps the same characterization **live**: every global batch is
//! summarized into exact integer aggregates (item/token sums per source
//! plus mergeable log-binned quantile sketches for the encoder-unit and
//! LLM-sequence axes), and a [`ShapeWindow`] maintains the aggregate over
//! the last `W` batches by merging the new batch and un-merging the
//! evicted one.
//!
//! Everything stored is an integer, so merge followed by unmerge is
//! *exact* — the running window aggregate is bit-identical to a
//! from-scratch recompute over the retained batches after any
//! push/evict sequence (a property test below enforces this). Derived
//! f64 statistics (means, quantiles, mixture shares) are pure functions
//! of those integers, which is what makes the whole drift path
//! deterministic across thread counts.

use crate::data::item::ItemShape;
use std::collections::VecDeque;

/// Sketch resolution: two sub-bins per power of two of the value range
/// (`u32` values ⇒ 32 octaves ⇒ 64 bins). Each bin spans a 1.5×/1.33×
/// geometric slice — quantile estimates are within a few percent, ample
/// for drift detection.
pub const SKETCH_BINS: usize = 64;

/// Fixed per-source slot count (Table 2 has five sources; headroom for
/// synthetic scenario mixes).
pub const MAX_SOURCES: usize = 16;

/// Log-spaced bin index of a positive value: `2·⌊log2 v⌋` plus one if `v`
/// is past the octave's geometric midpoint (`1.5·2^l`). Pure integer math
/// — no floating point on the ingest path.
#[inline]
pub fn bin_of(v: u32) -> usize {
    debug_assert!(v >= 1, "bin_of(0)");
    let l = 31 - v.leading_zeros() as usize;
    let sub = if l == 0 {
        0
    } else {
        usize::from((v as u64) >= (3u64 << (l - 1)))
    };
    2 * l + sub
}

/// `[lo, hi)` value range covered by bin `idx` (for quantile readout).
#[inline]
fn bin_edges(idx: usize) -> (f64, f64) {
    let base = (1u64 << (idx / 2)) as f64;
    if idx % 2 == 0 {
        (base, base * 1.5)
    } else {
        (base * 1.5, base * 2.0)
    }
}

/// Linear-interpolated quantile estimate from sketch counts.
fn sketch_quantile(counts: &[u64], total: u64, q: f64) -> f64 {
    debug_assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if total == 0 {
        return 0.0;
    }
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut acc = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        if c == 0 {
            continue;
        }
        if acc + c >= target {
            let (lo, hi) = bin_edges(i);
            let frac = (target - acc) as f64 / c as f64;
            return lo + (hi - lo) * frac;
        }
        acc += c;
    }
    // Unreachable when `total` matches the counts; safe fallback.
    bin_edges(counts.len() - 1).1
}

/// Exact integer shape aggregates of one batch (or a merged window of
/// batches): per-modality/source item and token summaries plus the two
/// mergeable quantile sketches.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShapeStats {
    /// Items summarized.
    pub items: u64,
    /// Items with at least one encoder unit (the units sketch's total).
    pub unit_items: u64,
    /// Items with a non-empty LLM sequence (the seq sketch's total).
    pub seq_items: u64,
    /// Total encoder units (tiles / frames / audio-seconds).
    pub units_sum: u64,
    /// Total packed LLM tokens.
    pub seq_sum: u64,
    /// Item counts per Table-2 source slot.
    pub source_items: Vec<u64>,
    /// LLM token sums per source slot (token-weighted mixture view).
    pub source_tokens: Vec<u64>,
    /// Log-binned sketch of per-item LLM sequence lengths.
    pub seq_sketch: Vec<u64>,
    /// Log-binned sketch of per-item encoder unit counts.
    pub units_sketch: Vec<u64>,
}

impl Default for ShapeStats {
    fn default() -> Self {
        ShapeStats {
            items: 0,
            unit_items: 0,
            seq_items: 0,
            units_sum: 0,
            seq_sum: 0,
            source_items: vec![0; MAX_SOURCES],
            source_tokens: vec![0; MAX_SOURCES],
            seq_sketch: vec![0; SKETCH_BINS],
            units_sketch: vec![0; SKETCH_BINS],
        }
    }
}

impl ShapeStats {
    /// Summarize one batch from scratch.
    pub fn of_batch(shapes: &[ItemShape]) -> ShapeStats {
        let mut s = ShapeStats::default();
        for shape in shapes {
            s.add_item(shape);
        }
        s
    }

    /// Fold one item into the aggregate.
    pub fn add_item(&mut self, s: &ItemShape) {
        self.items += 1;
        self.units_sum += s.units as u64;
        self.seq_sum += s.llm_seq as u64;
        let src = (s.source as usize).min(MAX_SOURCES - 1);
        self.source_items[src] += 1;
        self.source_tokens[src] += s.llm_seq as u64;
        if s.llm_seq >= 1 {
            self.seq_items += 1;
            self.seq_sketch[bin_of(s.llm_seq)] += 1;
        }
        if s.units >= 1 {
            self.unit_items += 1;
            self.units_sketch[bin_of(s.units)] += 1;
        }
    }

    /// Add another aggregate (sketches are mergeable by construction).
    pub fn merge(&mut self, other: &ShapeStats) {
        self.items += other.items;
        self.unit_items += other.unit_items;
        self.seq_items += other.seq_items;
        self.units_sum += other.units_sum;
        self.seq_sum += other.seq_sum;
        for (a, b) in self.source_items.iter_mut().zip(&other.source_items) {
            *a += b;
        }
        for (a, b) in self.source_tokens.iter_mut().zip(&other.source_tokens) {
            *a += b;
        }
        for (a, b) in self.seq_sketch.iter_mut().zip(&other.seq_sketch) {
            *a += b;
        }
        for (a, b) in self.units_sketch.iter_mut().zip(&other.units_sketch) {
            *a += b;
        }
    }

    /// Exact inverse of [`ShapeStats::merge`] — integer subtraction, so an
    /// evicted batch leaves no residue.
    pub fn unmerge(&mut self, other: &ShapeStats) {
        self.items -= other.items;
        self.unit_items -= other.unit_items;
        self.seq_items -= other.seq_items;
        self.units_sum -= other.units_sum;
        self.seq_sum -= other.seq_sum;
        for (a, b) in self.source_items.iter_mut().zip(&other.source_items) {
            *a -= b;
        }
        for (a, b) in self.source_tokens.iter_mut().zip(&other.source_tokens) {
            *a -= b;
        }
        for (a, b) in self.seq_sketch.iter_mut().zip(&other.seq_sketch) {
            *a -= b;
        }
        for (a, b) in self.units_sketch.iter_mut().zip(&other.units_sketch) {
            *a -= b;
        }
    }

    pub fn mean_units(&self) -> f64 {
        if self.items == 0 {
            0.0
        } else {
            self.units_sum as f64 / self.items as f64
        }
    }

    pub fn mean_seq(&self) -> f64 {
        if self.items == 0 {
            0.0
        } else {
            self.seq_sum as f64 / self.items as f64
        }
    }

    /// Estimated `q`-quantile of per-item LLM sequence lengths.
    pub fn seq_quantile(&self, q: f64) -> f64 {
        sketch_quantile(&self.seq_sketch, self.seq_items, q)
    }

    /// Estimated `q`-quantile of per-item encoder unit counts.
    pub fn units_quantile(&self, q: f64) -> f64 {
        sketch_quantile(&self.units_sketch, self.unit_items, q)
    }

    /// Item-count share per source slot (zeros when empty).
    pub fn source_shares(&self) -> Vec<f64> {
        if self.items == 0 {
            return vec![0.0; MAX_SOURCES];
        }
        self.source_items
            .iter()
            .map(|&c| c as f64 / self.items as f64)
            .collect()
    }
}

/// Sliding window of per-batch [`ShapeStats`] with an exactly-maintained
/// running aggregate: push is O(batch + bins), eviction is O(bins) — O(1)
/// amortized per item, no per-item allocation beyond the batch summary.
#[derive(Clone, Debug)]
pub struct ShapeWindow {
    capacity: usize,
    batches: VecDeque<ShapeStats>,
    agg: ShapeStats,
}

impl ShapeWindow {
    /// Window over the last `capacity` global batches.
    pub fn new(capacity: usize) -> ShapeWindow {
        assert!(capacity >= 1, "window capacity must be >= 1");
        ShapeWindow {
            capacity,
            batches: VecDeque::with_capacity(capacity + 1),
            agg: ShapeStats::default(),
        }
    }

    /// Ingest one global batch, evicting the oldest batch once full.
    pub fn push(&mut self, shapes: &[ItemShape]) {
        self.push_stats(ShapeStats::of_batch(shapes));
    }

    /// Ingest an already-summarized batch — the entry the shard layer
    /// uses after merging per-shard [`ShapeStats`] into one global batch
    /// summary (`shard::agg`). Because everything is integer, a merged
    /// summary pushed here is bit-identical to pushing the pooled shapes
    /// through [`ShapeWindow::push`].
    pub fn push_stats(&mut self, s: ShapeStats) {
        self.agg.merge(&s);
        self.batches.push_back(s);
        if self.batches.len() > self.capacity {
            let old = self.batches.pop_front().expect("window non-empty");
            self.agg.unmerge(&old);
        }
    }

    /// True once the window holds `capacity` batches.
    pub fn is_full(&self) -> bool {
        self.batches.len() == self.capacity
    }

    /// Batches currently held.
    pub fn batches(&self) -> usize {
        self.batches.len()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The running window aggregate.
    pub fn stats(&self) -> &ShapeStats {
        &self.agg
    }

    /// From-scratch merge of the retained batches — the oracle the
    /// incremental aggregate is property-tested against.
    pub fn recompute(&self) -> ShapeStats {
        let mut s = ShapeStats::default();
        for b in &self.batches {
            s.merge(b);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn item(g: &mut crate::util::prop::Gen) -> ItemShape {
        ItemShape {
            units: g.rng.below(65) as u32,
            llm_seq: 1 + g.rng.below(40_000) as u32,
            source: g.rng.below(6) as u8,
        }
    }

    #[test]
    fn bin_of_is_monotone_and_in_range() {
        let mut prev = 0usize;
        for v in 1u32..5000 {
            let b = bin_of(v);
            assert!(b >= prev, "bin went backwards at {v}");
            assert!(b < SKETCH_BINS);
            prev = b;
        }
        assert_eq!(bin_of(1), 0);
        assert_eq!(bin_of(u32::MAX), SKETCH_BINS - 1);
        // Values land inside their bin's edges.
        for v in [1u32, 2, 3, 7, 729, 4096, 50_000] {
            let (lo, hi) = bin_edges(bin_of(v));
            assert!(lo <= v as f64 && (v as f64) < hi, "{v} outside [{lo},{hi})");
        }
    }

    #[test]
    fn sketch_quantiles_track_exact_quantiles() {
        forall("sketch quantile accuracy", 50, |g| {
            let n = 200 + g.rng.index(800);
            let vals: Vec<u32> =
                (0..n).map(|_| 1 + g.rng.lognormal(7.0, 0.8).round() as u32).collect();
            let shapes: Vec<ItemShape> = vals
                .iter()
                .map(|&v| ItemShape { units: 1, llm_seq: v, source: 0 })
                .collect();
            let s = ShapeStats::of_batch(&shapes);
            let mut sorted: Vec<f64> = vals.iter().map(|&v| v as f64).collect();
            sorted.sort_by(|a, b| a.partial_cmp(b).expect("no NaN"));
            let mut ok = true;
            for q in [0.25, 0.5, 0.9] {
                let exact = crate::util::stats::quantile_sorted(&sorted, q);
                let est = s.seq_quantile(q);
                // One geometric bin (≤1.5×) of resolution either way.
                if est > exact * 1.6 || est < exact / 1.6 {
                    ok = false;
                }
            }
            (format!("n={n}"), ok)
        });
    }

    #[test]
    fn window_aggregate_bit_matches_recompute() {
        // The satellite property: after arbitrary push/evict sequences the
        // running aggregate equals both the from-scratch merge of retained
        // batch summaries and a direct re-summarization of the retained
        // raw shapes — exactly, field for field (all-integer state).
        forall("window merge/evict exact", 80, |g| {
            let cap = g.size(6);
            let mut w = ShapeWindow::new(cap);
            let mut kept: std::collections::VecDeque<Vec<ItemShape>> =
                std::collections::VecDeque::new();
            let pushes = g.size(14);
            for _ in 0..pushes {
                let n = g.size(48);
                let batch: Vec<ItemShape> = (0..n).map(|_| item(g)).collect();
                w.push(&batch);
                kept.push_back(batch);
                if kept.len() > cap {
                    kept.pop_front();
                }
            }
            let mut fresh = ShapeStats::default();
            for b in &kept {
                for s in b {
                    fresh.add_item(s);
                }
            }
            let ok = *w.stats() == fresh && w.recompute() == fresh;
            (format!("cap={cap} pushes={pushes}"), ok)
        });
    }

    #[test]
    fn window_evicts_oldest_batches() {
        let mut w = ShapeWindow::new(2);
        let old = vec![ItemShape { units: 1, llm_seq: 100, source: 0 }; 10];
        let new = vec![ItemShape { units: 1, llm_seq: 100, source: 1 }; 10];
        w.push(&old);
        assert!(!w.is_full());
        w.push(&new);
        assert!(w.is_full());
        w.push(&new);
        // The source-0 batch fell out of the window.
        assert_eq!(w.stats().source_items[0], 0);
        assert_eq!(w.stats().source_items[1], 20);
        assert_eq!(w.stats().items, 20);
    }

    #[test]
    fn derived_statistics_are_sane() {
        let shapes: Vec<ItemShape> = (1..=100)
            .map(|i| ItemShape { units: i % 7, llm_seq: 100 * i, source: (i % 3) as u8 })
            .collect();
        let s = ShapeStats::of_batch(&shapes);
        assert_eq!(s.items, 100);
        assert!((s.mean_seq() - 5050.0).abs() < 1e-9);
        let shares = s.source_shares();
        assert!((shares.iter().sum::<f64>() - 1.0).abs() < 1e-9);
        // Median of 100·{1..100} ≈ 5050 within sketch resolution.
        let med = s.seq_quantile(0.5);
        assert!((3_500.0..7_500.0).contains(&med), "median {med}");
        assert!(s.seq_quantile(0.9) > med);
    }
}
