//! Ring reservoir of the most recent item shapes.
//!
//! The drift detector works on sketches, but refitting the `Estimator`'s
//! shape distribution (Eq 1's `D`) needs concrete samples. This reservoir
//! keeps the last `capacity` shapes of the batch stream in a fixed ring —
//! deterministic, allocation-free after warm-up, and exactly the
//! "recent distribution" a replan should optimize for (a classical
//! random-replacement reservoir would keep a uniform sample of *all*
//! history, which is precisely wrong under drift).

use crate::data::item::ItemShape;

#[derive(Clone, Debug)]
pub struct ShapeReservoir {
    capacity: usize,
    buf: Vec<ItemShape>,
    /// Next slot to overwrite once full.
    next: usize,
}

impl ShapeReservoir {
    pub fn new(capacity: usize) -> ShapeReservoir {
        assert!(capacity >= 1, "reservoir capacity must be >= 1");
        ShapeReservoir { capacity, buf: Vec::with_capacity(capacity), next: 0 }
    }

    pub fn push(&mut self, s: &ItemShape) {
        if self.buf.len() < self.capacity {
            self.buf.push(*s);
        } else {
            self.buf[self.next] = *s;
            self.next = (self.next + 1) % self.capacity;
        }
    }

    pub fn extend(&mut self, shapes: &[ItemShape]) {
        for s in shapes {
            self.push(s);
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// The retained shapes in ring-storage order (deterministic for a
    /// given stream; the Eq-1 refinement is order-sensitive only in its
    /// floating-point summation, so a stable order keeps replans
    /// bit-reproducible).
    pub fn shapes(&self) -> &[ItemShape] {
        &self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(seq: u32) -> ItemShape {
        ItemShape { units: 1, llm_seq: seq, source: 0 }
    }

    #[test]
    fn fills_then_overwrites_oldest() {
        let mut r = ShapeReservoir::new(3);
        for i in 1..=3 {
            r.push(&shape(i));
        }
        assert_eq!(r.len(), 3);
        r.push(&shape(4)); // overwrites slot 0 (the oldest)
        let seqs: Vec<u32> = r.shapes().iter().map(|s| s.llm_seq).collect();
        assert_eq!(seqs, vec![4, 2, 3]);
        r.push(&shape(5));
        let seqs: Vec<u32> = r.shapes().iter().map(|s| s.llm_seq).collect();
        assert_eq!(seqs, vec![4, 5, 3]);
    }

    #[test]
    fn retains_exactly_the_last_capacity_items() {
        let mut r = ShapeReservoir::new(8);
        let batch: Vec<ItemShape> = (1..=20).map(shape).collect();
        r.extend(&batch);
        assert_eq!(r.len(), 8);
        let mut seqs: Vec<u32> = r.shapes().iter().map(|s| s.llm_seq).collect();
        seqs.sort_unstable();
        assert_eq!(seqs, (13..=20).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_for_a_given_stream() {
        let batch: Vec<ItemShape> = (1..=50).map(shape).collect();
        let mut a = ShapeReservoir::new(16);
        let mut b = ShapeReservoir::new(16);
        a.extend(&batch);
        b.extend(&batch);
        assert_eq!(a.shapes(), b.shapes());
    }
}
