//! Online drift detection and adaptive replanning — continuous profiling
//! made real.
//!
//! The offline layers (`profiling` → `optimizer`) fit θ* to a *snapshot*
//! of the data distribution; the per-iteration layer (`scheduler`)
//! balances within the plan but cannot change it. This subsystem is the
//! layer between them, operating over *time*:
//!
//! - [`window`] — sliding-window shape statistics over the incoming
//!   global batches: exact integer aggregates plus mergeable log-binned
//!   quantile sketches, O(1) amortized per item.
//! - [`drift`] — a deterministic detector comparing the live window
//!   against the profile-time reference (LLM-sequence and encoder-unit
//!   decile distances + mixture total variation) with hysteresis so
//!   noise cannot thrash the plan.
//! - [`reservoir`] — the last-N item shapes, the concrete samples a
//!   refit needs.
//! - [`replan`] — the controller: on confirmed drift, refit Eq 1's `D`
//!   from the reservoir, warm-restart `optimizer::search` from the
//!   incumbent θ* on the worker pool, and swap the plan between
//!   iterations.
//!
//! `sim::trainer` wires this into full runs as
//! `SystemKind::DflopAdaptive`; the non-stationary scenarios it reacts
//! to live in `data::sources` (curriculum ramp, video bursts, modality
//! dropout). Everything here is bit-deterministic across `--threads`
//! settings — see `rust/DESIGN.md` ("Stream subsystem").

pub mod drift;
pub mod replan;
pub mod reservoir;
pub mod window;

pub use drift::{stat_between, Decision, DriftConfig, DriftDetector, DriftStat};
pub use replan::{live_profile, ReplanConfig, ReplanContext, ReplanEvent, Replanner};
pub use reservoir::ShapeReservoir;
pub use window::{ShapeStats, ShapeWindow};
