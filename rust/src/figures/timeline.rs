//! ASCII rendering of 1F1B pipeline timelines (Fig 1).

use crate::pipeline::sim::OpRecord;

/// Render a per-stage timeline: digits = forward ops (bucket index mod 10),
/// '#' = backward ops, '.' = idle. `width` columns span the makespan.
pub fn render(timeline: &[OpRecord], n_stages: usize, width: usize) -> String {
    let makespan = timeline
        .iter()
        .map(|o| o.finish)
        .fold(0.0f64, f64::max)
        .max(1e-12);
    let mut rows = vec![vec!['.'; width]; n_stages];
    for op in timeline {
        let c0 = ((op.start / makespan) * width as f64) as usize;
        let c1 = (((op.finish / makespan) * width as f64).ceil() as usize).min(width);
        let ch = if op.is_forward {
            char::from_digit((op.bucket % 10) as u32, 10).expect("digit")
        } else {
            '#'
        };
        for c in c0..c1.max(c0 + 1).min(width) {
            rows[op.stage][c] = ch;
        }
    }
    let mut out = String::new();
    for (s, row) in rows.iter().enumerate() {
        out.push_str(&format!("stage {s:>2} |"));
        out.extend(row.iter());
        out.push_str("|\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::sim::{simulate, Route};

    #[test]
    fn renders_all_stages() {
        let routes: Vec<Route> = (0..4)
            .map(|_| Route {
                stages: vec![0, 1],
                fwd: vec![1.0; 2],
                bwd: vec![2.0; 2],
                comm: vec![0.0; 2],
            })
            .collect();
        let r = simulate(2, &routes);
        let text = render(&r.timeline, 2, 60);
        assert_eq!(text.lines().count(), 2);
        assert!(text.contains('#'), "backward ops rendered");
        assert!(text.contains('0'), "forward ops rendered");
    }
}
