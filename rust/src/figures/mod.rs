//! Regeneration harness for every table and figure in the paper's
//! evaluation (§5). Each `figNN` function runs the relevant experiment on
//! the simulated cluster and prints the same rows/series the paper reports.
//! Absolute numbers come from the analytic A100 model; the comparisons
//! (who wins, by what factor, where crossovers fall) are the reproduction
//! target — see EXPERIMENTS.md for paper-vs-measured.
//!
//! System-level figures build their full (system × model × dataset ×
//! cluster) evaluation grid up front and sweep it with [`run_cells`] on
//! the `util::parallel` pool, so a figure's wall-clock is its slowest
//! cell, not the sum of all of them. Rows are always assembled from the
//! results in grid order, so thread count never reorders output; the one
//! remaining wall-clock sensitivity is DFLOP cells whose per-iteration
//! ILP budget expires mid-search (the incumbent then depends on timing,
//! as it always did — see `scheduler::ilp`).

pub mod timeline;

use crate::data::dataset::Dataset;
use crate::model::catalog::{
    internvl_25, llava_ov, llama3, paper_configs, qwen2_audio, qwen25, Mllm,
};
use crate::obs::bubble::{iteration_bubble_fraction, stage_bubbles};
use crate::obs::critical::{critical_path, op_slack, OpSlack};
use crate::obs::ObsConfig;
use crate::optimizer::plan::{ModPar, Theta};
use crate::optimizer::search::{optimize, OptimizerInputs};
use crate::perfmodel::{ClusterSpec, Truth};
use crate::pipeline::build::{iterate_ws, SystemPlan};
use crate::pipeline::sim::{ideal_bubble_fraction, SimWorkspace};
use crate::profiling::backend::SimBackend;
use crate::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use crate::scheduler::ilp;
use crate::scheduler::lpt::{self, ItemCost};
use crate::shard::ShardConfig;
use crate::sim::{run_cells, Cell, FaultConfig, RunConfig, RunResult, SystemKind};
use crate::util::stats::{BoxPlot, Histogram, Summary};
use crate::util::table::{bytes, f, secs, speedup, Table};

/// Shared experiment options (paper scale by default where affordable).
#[derive(Clone, Copy, Debug)]
pub struct FigOpts {
    pub nodes: usize,
    pub gbs: usize,
    pub iters: usize,
    pub seed: u64,
}

impl Default for FigOpts {
    fn default() -> Self {
        FigOpts { nodes: 4, gbs: 128, iters: 4, seed: 42 }
    }
}

/// The three headline systems, in every figure's column order.
const SYSTEMS: [SystemKind; 3] = [SystemKind::Dflop, SystemKind::Megatron, SystemKind::Pytorch];

/// Cross a model list with a system list on one dataset: models outer,
/// systems inner — the order every figure's row assembly indexes by.
fn cross_specs<'d>(
    models: &[&Mllm],
    kinds: &[SystemKind],
    dataset: &'d str,
) -> Vec<(SystemKind, Mllm, &'d str)> {
    let mut specs = Vec::with_capacity(models.len() * kinds.len());
    for m in models {
        for &kind in kinds {
            specs.push((kind, (*m).clone(), dataset));
        }
    }
    specs
}

/// Evaluate (system, model, dataset) cells at this figure's options on the
/// worker pool; results come back in spec order. Figures only use built-in
/// dataset keys, so the up-front key validation in [`run_cells`] cannot
/// fail here.
fn run_grid(specs: Vec<(SystemKind, Mllm, &str)>, o: &FigOpts) -> Vec<RunResult> {
    let cells: Vec<Cell> = specs
        .into_iter()
        .map(|(kind, m, dataset)| Cell {
            kind,
            m,
            dataset: dataset.to_string(),
            cfg: RunConfig::new(o.nodes, o.gbs, o.iters, o.seed),
        })
        .collect();
    run_cells(&cells).expect("built-in dataset keys")
}

// ------------------------------------------------------------------
// Fig 1 — ideal vs real 1F1B schedules
// ------------------------------------------------------------------

pub fn fig01(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let truth = Truth::new(ClusterSpec::hgx_a100(1));
    // 6 microbatches through encoder stage 0 + 3 LLM stages (the paper's
    // Fig 1 layout).
    let theta = Theta {
        enc: ModPar { tp: 2, pp: 1, dp: 1 },
        llm: ModPar { tp: 2, pp: 3, dp: 1 },
        n_mb: 6,
    };
    let plan = SystemPlan { m: &m, truth: &truth, theta };
    let mut out = String::new();

    // Twelve concrete mixed-dataset items; the ideal case replaces each
    // with the batch mean so both schedules carry identical total work.
    let mut ds = Dataset::mixed(o.seed);
    let items = ds.shaped_batch(&m, 12);
    let mean_shape = crate::data::item::ItemShape {
        units: (items.iter().map(|s| s.units as f64).sum::<f64>() / 12.0).round() as u32,
        llm_seq: (items.iter().map(|s| s.llm_seq as f64).sum::<f64>() / 12.0).round()
            as u32,
        source: 0,
    };
    let ideal_buckets: Vec<Vec<_>> = (0..6).map(|_| vec![mean_shape; 2]).collect();
    let mut ws = SimWorkspace::new();
    let ideal = iterate_ws(&plan, &ideal_buckets, &mut ws);
    out.push_str("Fig 1 (top) — ideal 1F1B: identical microbatches\n");
    out.push_str(&timeline::render(&ideal.timeline, ideal.n_stages, 96));
    out.push_str(&format!(
        "makespan {}  total idle {}\n\n",
        secs(ideal.pipeline_makespan),
        secs(ideal.total_idle())
    ));

    // Real: the same items in heterogeneous random-composition buckets.
    let real_buckets: Vec<Vec<_>> = items.chunks(2).map(|c| c.to_vec()).collect();
    let real = iterate_ws(&plan, &real_buckets, &mut ws);
    out.push_str("Fig 1 (bottom) — real 1F1B: mixed single-image/multi-image/video microbatches\n");
    out.push_str(&timeline::render(&real.timeline, real.n_stages, 96));
    out.push_str(&format!(
        "makespan {}  total idle {}  (idle inflation {})\n",
        secs(real.pipeline_makespan),
        secs(real.total_idle()),
        speedup(real.total_idle() / ideal.total_idle().max(1e-12))
    ));
    out
}

// ------------------------------------------------------------------
// Fig 2 — throughput vs input shape and TP degree
// ------------------------------------------------------------------

pub fn fig02(_o: &FigOpts) -> String {
    let truth = Truth::new(ClusterSpec::hgx_a100(1));
    let m = llava_ov(qwen25("7b"));
    let mut out = String::new();

    let mut t = Table::new(
        "Fig 2a — SigLIP encoder throughput (TFLOP/s per GPU) vs effective batch",
        &["eff. batch", "tp=1", "tp=2", "tp=4", "tp=8", "tp8/tp1"],
    );
    for &units in &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0] {
        let thr: Vec<f64> = [1usize, 2, 4, 8]
            .iter()
            .map(|&tp| truth.encoder_throughput(&m, units, tp) / 1e12)
            .collect();
        t.row(vec![
            format!("{units}"),
            f(thr[0], 1),
            f(thr[1], 1),
            f(thr[2], 1),
            f(thr[3], 1),
            f(thr[3] / thr[0], 2),
        ]);
    }
    out.push_str(&t.render());

    let mut t = Table::new(
        "Fig 2b — Qwen-2.5 LLM throughput (TFLOP/s per GPU) vs sequence length",
        &["seq len", "tp=1", "tp=2", "tp=4", "tp=8", "tp8/tp1"],
    );
    for &seq in &[256.0, 512.0, 1024.0, 2048.0, 4096.0, 8192.0, 16384.0] {
        let thr: Vec<f64> = [1usize, 2, 4, 8]
            .iter()
            .map(|&tp| truth.llm_throughput(&m, seq, tp) / 1e12)
            .collect();
        t.row(vec![
            format!("{seq}"),
            f(thr[0], 1),
            f(thr[1], 1),
            f(thr[2], 1),
            f(thr[3], 1),
            f(thr[3] / thr[0], 2),
        ]);
    }
    out.push_str(&t.render());
    out
}

// ------------------------------------------------------------------
// Fig 4 — stage-wise duration distributions across data items
// ------------------------------------------------------------------

pub fn fig04(o: &FigOpts) -> String {
    let m = llava_ov(qwen25("7b"));
    let truth = Truth::new(ClusterSpec::hgx_a100(o.nodes));
    let mut ds = Dataset::mixed(o.seed);
    let items = ds.shaped_batch(&m, 2000);
    let enc: Vec<f64> = items
        .iter()
        .filter(|s| s.units > 0)
        .map(|s| truth.encoder_stage_time(&m, s.units as f64, m.encoder.layers as f64, 1) * 1e3)
        .collect();
    let llm: Vec<f64> = items
        .iter()
        .map(|s| truth.llm_stage_time(&m, &[s.llm_seq as f64], m.llm.layers as f64, 1) * 1e3)
        .collect();
    let mut out = String::new();
    for (name, xs) in [("modality encoder (SigLIP)", &enc), ("LLM (Qwen-2.5)", &llm)] {
        let s = Summary::of(xs);
        let h = Histogram::of(xs, 40);
        out.push_str(&format!(
            "Fig 4 — {name} per-item duration (ms): mean {:.1}  p50 {:.1}  p95 {:.1}  cv {:.2}\n  {}\n",
            s.mean, s.p50, s.p95, s.cv(), h.sparkline()
        ));
    }
    out
}

// ------------------------------------------------------------------
// Fig 7 — end-to-end performance across MLLM configurations
// ------------------------------------------------------------------

pub fn fig07(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 7a — per-GPU throughput (TFLOP/s) and DFLOP speedups (mixed dataset)",
        &["configuration", "DFLOP", "Megatron", "PyTorch", "vs Mega", "vs PyTorch"],
    );
    let mut t2 = Table::new(
        "Fig 7b — total training time (hours, one pass over the 185k-sample mixed corpus)",
        &["configuration", "DFLOP", "Megatron", "PyTorch", "saved vs best baseline"],
    );
    let configs = paper_configs();
    let models: Vec<&Mllm> = configs.iter().map(|c| &c.mllm).collect();
    let results = run_grid(cross_specs(&models, &SYSTEMS, "mixed"), o);
    for (i, cfg) in configs.iter().enumerate() {
        let (d, mg, pt) = (&results[3 * i], &results[3 * i + 1], &results[3 * i + 2]);
        t.row(vec![
            cfg.label.to_string(),
            f(d.per_gpu_throughput / 1e12, 1),
            f(mg.per_gpu_throughput / 1e12, 1),
            f(pt.per_gpu_throughput / 1e12, 1),
            speedup(d.speedup_over(mg)),
            speedup(d.speedup_over(pt)),
        ]);
        let steps = 185_000.0 / o.gbs as f64;
        let hours = |r: &RunResult| steps * r.mean_iteration_time / 3600.0;
        let best_base = hours(mg).min(hours(pt));
        t2.row(vec![
            cfg.label.to_string(),
            f(hours(d), 1),
            f(hours(mg), 1),
            f(hours(pt), 1),
            format!("{} h", f(best_base - hours(d), 1)),
        ]);
    }
    t.render() + &t2.render()
}

// ------------------------------------------------------------------
// Fig 8 — gain vs computational-load ratio
// ------------------------------------------------------------------

pub fn fig08(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 8 — encoder/LLM FLOP ratio vs max DFLOP gain",
        &["configuration", "enc/LLM FLOP ratio", "max gain"],
    );
    let mut points: Vec<(f64, f64, String)> = Vec::new();
    let configs = paper_configs();
    let models: Vec<&Mllm> = configs.iter().map(|c| &c.mllm).collect();
    let results = run_grid(cross_specs(&models, &SYSTEMS, "mixed"), o);
    for (i, cfg) in configs.iter().enumerate() {
        let mut ds = Dataset::mixed(o.seed);
        let probe = ds.shaped_batch(&cfg.mllm, 256);
        let mean_units = probe.iter().map(|s| s.units as f64).sum::<f64>() / 256.0;
        let mean_seq = probe.iter().map(|s| s.llm_seq as f64).sum::<f64>() / 256.0;
        let ratio = cfg.mllm.compute_ratio(mean_units, mean_seq);
        let (d, mg, pt) = (&results[3 * i], &results[3 * i + 1], &results[3 * i + 2]);
        let gain = d.speedup_over(mg).max(d.speedup_over(pt));
        points.push((ratio, gain, cfg.label.to_string()));
    }
    points.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN"));
    for (ratio, gain, label) in &points {
        t.row(vec![label.clone(), f(*ratio, 3), speedup(*gain)]);
    }
    // Rank correlation between ratio (toward balance) and gain.
    let n = points.len() as f64;
    let mean_r = points.iter().map(|p| p.0).sum::<f64>() / n;
    let mean_g = points.iter().map(|p| p.1).sum::<f64>() / n;
    let cov: f64 = points.iter().map(|p| (p.0 - mean_r) * (p.1 - mean_g)).sum();
    let var_r: f64 = points.iter().map(|p| (p.0 - mean_r).powi(2)).sum();
    let var_g: f64 = points.iter().map(|p| (p.1 - mean_g).powi(2)).sum();
    let corr = cov / (var_r.sqrt() * var_g.sqrt()).max(1e-12);
    t.render() + &format!("Pearson correlation(ratio, gain) = {corr:.2}\n")
}

// ------------------------------------------------------------------
// Fig 9 — audio-modality generalization (Qwen2-Audio)
// ------------------------------------------------------------------

pub fn fig09(o: &FigOpts) -> String {
    let m = qwen2_audio();
    // Audio items are small (pooled ~7 tokens/s of audio); the paper's
    // audio recipe uses a correspondingly larger global batch.
    let mut oo = *o;
    oo.gbs = o.gbs * 4;
    let results = run_grid(cross_specs(&[&m], &SYSTEMS, "audio"), &oo);
    let (d, mg, pt) = (&results[0], &results[1], &results[2]);
    let mut t = Table::new(
        "Fig 9 — Qwen2-Audio on the audio workload",
        &["system", "TFLOP/s per GPU", "DFLOP speedup"],
    );
    t.row(vec!["DFLOP".into(), f(d.per_gpu_throughput / 1e12, 1), "1.00x".into()]);
    t.row(vec![
        "Megatron-LM".into(),
        f(mg.per_gpu_throughput / 1e12, 1),
        speedup(d.speedup_over(mg)),
    ]);
    t.row(vec![
        "PyTorch".into(),
        f(pt.per_gpu_throughput / 1e12, 1),
        speedup(d.speedup_over(pt)),
    ]);
    t.render()
}

// ------------------------------------------------------------------
// Fig 10 — ablation: incremental components
// ------------------------------------------------------------------

pub fn fig10(o: &FigOpts) -> String {
    let configs = [
        ("LLaVA-OV (Llama-3 8B)", llava_ov(llama3("8b"))),
        ("LLaVA-OV (Qwen-2.5 32B)", llava_ov(qwen25("32b"))),
        ("InternVL 2.5 (Qwen-2.5 72B)", crate::model::catalog::internvl_25(qwen25("72b"))),
    ];
    let mut t = Table::new(
        "Fig 10 — component ablation (gain over the PyTorch baseline)",
        &["configuration", "+optimizer", "+scheduler", "full DFLOP"],
    );
    let kinds = [
        SystemKind::Pytorch,
        SystemKind::DflopOptimizerOnly,
        SystemKind::DflopSchedulerOnly,
        SystemKind::Dflop,
    ];
    let models: Vec<&Mllm> = configs.iter().map(|(_, m)| m).collect();
    let results = run_grid(cross_specs(&models, &kinds, "mixed"), o);
    for (i, (label, _)) in configs.iter().enumerate() {
        let pt = &results[4 * i];
        let opt = &results[4 * i + 1];
        let sched = &results[4 * i + 2];
        let full = &results[4 * i + 3];
        t.row(vec![
            label.to_string(),
            speedup(opt.speedup_over(pt)),
            speedup(sched.speedup_over(pt)),
            speedup(full.speedup_over(pt)),
        ]);
    }
    t.render()
}

// ------------------------------------------------------------------
// Fig 11 — robustness across dataset scenarios
// ------------------------------------------------------------------

pub fn fig11(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut t = Table::new(
        "Fig 11a — per-GPU throughput (TFLOP/s) across workload scenarios",
        &["dataset", "DFLOP", "Megatron", "PyTorch", "DFLOP max gain"],
    );
    let mut out2 = String::from("Fig 11b — LLM input shape distributions (packed seq len):\n");
    let keys = ["multi-image", "video", "mixed"];
    let specs = keys.iter().flat_map(|key| cross_specs(&[&m], &SYSTEMS, key)).collect();
    let results = run_grid(specs, o);
    for (i, key) in keys.into_iter().enumerate() {
        let (d, mg, pt) = (&results[3 * i], &results[3 * i + 1], &results[3 * i + 2]);
        let gain = d.speedup_over(mg).max(d.speedup_over(pt));
        t.row(vec![
            key.to_string(),
            f(d.per_gpu_throughput / 1e12, 1),
            f(mg.per_gpu_throughput / 1e12, 1),
            f(pt.per_gpu_throughput / 1e12, 1),
            speedup(gain),
        ]);
        let mut ds = Dataset::by_key(key, o.seed).expect("dataset");
        let seqs: Vec<f64> = ds
            .shaped_batch(&m, 2000)
            .iter()
            .map(|s| s.llm_seq as f64)
            .collect();
        let s = Summary::of(&seqs);
        out2.push_str(&format!(
            "  {key:12} mean {:6.0}  p95 {:6.0}  cv {:.2}  {}\n",
            s.mean,
            s.p95,
            s.cv(),
            Histogram::of(&seqs, 40).sparkline()
        ));
    }
    t.render() + &out2
}

// ------------------------------------------------------------------
// Fig 12 — GPU cluster scalability
// ------------------------------------------------------------------

pub fn fig12(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut t = Table::new(
        "Fig 12 — total cluster throughput (PFLOP/s) vs node count (16/32 projected)",
        &["nodes", "DFLOP", "Megatron", "PyTorch", "DFLOP max gain"],
    );
    let mut dflop_series = Vec::new();
    let node_counts = [1usize, 2, 4, 8];
    let mut cells = Vec::new();
    for &nodes in &node_counts {
        let gbs = (o.gbs * nodes / 4).max(32);
        for kind in SYSTEMS {
            cells.push(Cell {
                kind,
                m: m.clone(),
                dataset: "mixed".to_string(),
                cfg: RunConfig::new(nodes, gbs, o.iters, o.seed),
            });
        }
    }
    let results = run_cells(&cells).expect("built-in dataset keys");
    for (i, &nodes) in node_counts.iter().enumerate() {
        let (d, mg, pt) = (&results[3 * i], &results[3 * i + 1], &results[3 * i + 2]);
        let total = |r: &RunResult| r.per_gpu_throughput * r.n_gpus as f64 / 1e15;
        dflop_series.push((nodes as f64, total(d), total(mg), total(pt)));
        t.row(vec![
            format!("{nodes}"),
            f(total(d), 2),
            f(total(mg), 2),
            f(total(pt), 2),
            speedup(d.speedup_over(mg).max(d.speedup_over(pt))),
        ]);
    }
    // Projection: extend the measured per-node efficiency trend (paper
    // projects 16/32 nodes from 1–8 node measurements).
    let last = dflop_series.last().expect("series");
    let prev = dflop_series[dflop_series.len() - 2];
    for &nodes in &[16.0f64, 32.0] {
        let scale = nodes / last.0;
        let eff = |l: f64, p: f64| (l / p / 2.0).min(1.0); // efficiency of last doubling
        let proj = |li: f64, pi: f64| li * scale * eff(li, pi).powf((nodes / last.0).log2());
        t.row(vec![
            format!("{nodes} (proj)"),
            f(proj(last.1, prev.1), 2),
            f(proj(last.2, prev.2), 2),
            f(proj(last.3, prev.3), 2),
            "-".into(),
        ]);
    }
    t.render()
}

// ------------------------------------------------------------------
// Fig 13 — pipeline-bubble idle time
// ------------------------------------------------------------------

pub fn fig13(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut t = Table::new(
        "Fig 13 — GPU idle time from pipeline bubbles (GPU·s per iteration)",
        &["system", "ideal (1F1B formula)", "real (measured)", "real/ideal"],
    );
    let mut reals = Vec::new();
    let results = run_grid(cross_specs(&[&m], &SYSTEMS, "mixed"), o);
    for (kind, r) in SYSTEMS.into_iter().zip(&results) {
        let p = r.theta.pipeline_depth();
        let frac = ideal_bubble_fraction(p, r.theta.n_mb);
        // Ideal idle GPU·s: bubble fraction × stages × iteration time.
        let n_stages = r.theta.enc.pp * r.theta.enc.dp + r.theta.llm.pp * r.theta.llm.dp;
        let ideal = frac * n_stages as f64 * r.mean_iteration_time;
        reals.push((kind, r.mean_idle));
        t.row(vec![
            kind.label().to_string(),
            f(ideal, 2),
            f(r.mean_idle, 2),
            f(r.mean_idle / ideal.max(1e-9), 2),
        ]);
    }
    let dflop = reals[0].1;
    let mut out = t.render();
    for (kind, idle) in &reals[1..] {
        out.push_str(&format!(
            "idle reduction vs {}: {:.0}%\n",
            kind.label(),
            (1.0 - dflop / idle) * 100.0
        ));
    }
    out
}

// ------------------------------------------------------------------
// Fig 14 — stage-wise throughput distribution
// ------------------------------------------------------------------

pub fn fig14(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut t = Table::new(
        "Fig 14 — stage throughput distribution (TFLOP/s per stage-GPU group)",
        &["system", "median", "q1", "q3", "whisker lo", "whisker hi"],
    );
    let results = run_grid(cross_specs(&[&m], &SYSTEMS, "mixed"), o);
    for (kind, r) in SYSTEMS.into_iter().zip(&results) {
        // Normalize stage-group throughput to per-GPU: encoder stages hold
        // E_tp GPUs, LLM stages L_tp (stage layout: enc first).
        let enc_stages = r.theta.enc.pp * r.theta.enc.dp;
        let mut samples = Vec::new();
        for it in &r.iterations {
            for (sidx, (flop, busy)) in
                it.stage_flop.iter().zip(&it.stage_busy).enumerate()
            {
                if *flop > 0.0 && *busy > 0.0 {
                    let tp = if sidx < enc_stages { r.theta.enc.tp } else { r.theta.llm.tp };
                    samples.push(flop / busy / tp as f64 / 1e12);
                }
            }
        }
        let b = BoxPlot::of(&samples);
        t.row(vec![
            kind.label().to_string(),
            f(b.median, 1),
            f(b.q1, 1),
            f(b.q3, 1),
            f(b.whisker_lo, 1),
            f(b.whisker_hi, 1),
        ]);
    }
    t.render()
}

// ------------------------------------------------------------------
// Fig 15 — Adaptive Correction cost-benefit
// ------------------------------------------------------------------

pub fn fig15(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    // Monitoring cost (the paper measures ≈4% by toggling the tracker).
    const COST: f64 = 0.04;
    let mut t = Table::new(
        "Fig 15 — Adaptive Correction net speedup (gain − 4% monitoring cost)",
        &["anomaly rate", "latency +25%", "+50%", "+75%", "+100%"],
    );
    // Shape buckets that actually occur in the workload.
    let mut ds = Dataset::mixed(o.seed);
    let probe = ds.shaped_batch(&m, 512);
    let mut buckets: Vec<u64> = probe
        .iter()
        .map(|s| Truth::llm_bucket(s.llm_seq as f64))
        .collect();
    buckets.sort_unstable();
    buckets.dedup();
    // Warm-up iterations let the tracker accumulate observations before
    // the steady-state window is measured (the paper's initial training
    // phase, §3.4.3).
    let warmup = 4usize;
    let rates = [("low (1%)", 0.01f64), ("medium (3%)", 0.03), ("high (5%)", 0.05)];
    let latencies = [0.25f64, 0.50, 0.75, 1.00];
    // The whole 3×4 grid of corrected/uncorrected pairs is one batch of
    // independent cells — 24 simulated systems swept across the pool.
    let mut cells = Vec::new();
    for &(_, rate) in &rates {
        for &latency in &latencies {
            let n_anomalous = ((buckets.len() as f64 * rate).ceil() as usize).max(1);
            let injected: Vec<(u64, f64)> = buckets
                .iter()
                .step_by((buckets.len() / n_anomalous).max(1))
                .take(n_anomalous)
                .map(|&b| (b, 1.0 / (1.0 + latency)))
                .collect();
            let mut cfg_on = RunConfig::new(o.nodes, o.gbs, o.iters + 2 * warmup, o.seed);
            cfg_on.injected = injected;
            let mut cfg_off = cfg_on.clone();
            cfg_off.disable_correction = true;
            for cfg in [cfg_on, cfg_off] {
                cells.push(Cell {
                    kind: SystemKind::Dflop,
                    m: m.clone(),
                    dataset: "mixed".to_string(),
                    cfg,
                });
            }
        }
    }
    let results = run_cells(&cells).expect("built-in dataset keys");
    for (ri, &(label, _)) in rates.iter().enumerate() {
        let mut row = vec![label.to_string()];
        for li in 0..latencies.len() {
            let pair = (ri * latencies.len() + li) * 2;
            let (on, off) = (&results[pair], &results[pair + 1]);
            let steady = |r: &RunResult| {
                let iters = &r.iterations[warmup..];
                iters.iter().map(|s| s.iteration_time).sum::<f64>() / iters.len() as f64
            };
            let gain = steady(off) / steady(on) - 1.0;
            let net = gain - COST;
            row.push(if net <= 0.0 {
                format!("{:+.1}% (off)", net * 100.0)
            } else {
                format!("{:+.1}%", net * 100.0)
            });
        }
        t.row(row);
    }
    t.render()
}

// ------------------------------------------------------------------
// Fig 16 — component overheads at scale
// ------------------------------------------------------------------

pub fn fig16(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut out = String::new();

    // 16a: optimizer wall-clock vs GPUs × GBS. The grid itself stays
    // serial on purpose: each `optimize()` call parallelizes internally,
    // and the reported number is its wall-clock — running cells
    // concurrently would contend for the same cores and inflate it.
    let mut t = Table::new(
        "Fig 16a — Data-aware 3D Parallelism Optimizer wall-clock",
        &["GPUs", "GBS=512", "GBS=1024", "GBS=2048"],
    );
    let truth = Truth::new(ClusterSpec::hgx_a100(1));
    let mut backend = SimBackend::new(truth);
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(o.seed);
    let data = profile_data(&m, &mut ds, 256);
    for &gpus in &[64usize, 256, 1024] {
        let mut row = vec![format!("{gpus}")];
        for &gbs in &[512usize, 1024, 2048] {
            let inp = OptimizerInputs {
                m: &m,
                profile: &profile,
                data: &data,
                n_gpus: gpus,
                gpus_per_node: 8,
                mem_capacity: ClusterSpec::hgx_a100(1).gpu.mem_bytes,
                gbs,
                assume_balanced: true,
            };
            let r = optimize(&inp).expect("feasible");
            row.push(secs(r.elapsed.as_secs_f64()));
        }
        t.row(row);
    }
    out.push_str(&t.render());

    // 16b: scheduler wall-clock vs GBS with the paper's fallback behaviour.
    let mut t = Table::new(
        "Fig 16b — Online Microbatch Scheduler wall-clock (50 ms ILP limit)",
        &["GBS", "time", "solver", "imbalance vs LB"],
    );
    let mut ds = Dataset::mixed(o.seed ^ 1);
    for &gbs in &[64usize, 128, 256, 512, 1024, 2048] {
        let shapes = ds.shaped_batch(&m, gbs);
        let items: Vec<ItemCost> = shapes
            .iter()
            .map(|s| ItemCost {
                enc: s.units as f64,
                llm: s.llm_seq as f64,
            })
            .collect();
        let mbuckets = (gbs / 8).max(2);
        let t0 = std::time::Instant::now();
        let r = ilp::solve(&items, mbuckets, std::time::Duration::from_millis(50));
        let elapsed = t0.elapsed().as_secs_f64();
        let lb = lpt::lower_bound(&items, mbuckets);
        t.row(vec![
            format!("{gbs}"),
            secs(elapsed),
            if r.optimal { "ILP (optimal)".into() } else { "LPT fallback".to_string() },
            format!("{:.3}%", (r.assignment.c_max() / lb - 1.0).max(0.0) * 100.0),
        ]);
    }
    out.push_str(&t.render());
    out
}

// ------------------------------------------------------------------
// Fig 17 (extension) — drift adaptation: static θ* vs adaptive replanning
// ------------------------------------------------------------------

/// Minimum iterations for a drift-grid run: the scenario schedules play
/// out over ~16 iterations, so shorter runs would end before the detector
/// can confirm anything. Shared with the `drift_adapt` example so its
/// JSON metadata reports the iteration count actually run.
pub const DRIFT_MIN_ITERS: usize = 20;

/// The (scenario × {frozen, adaptive}) evaluation grid behind Fig 17 and
/// the `drift_adapt` example: every non-stationary scenario plus the
/// stationary mixed control, evaluated as one parallel cell batch.
/// Returns `(scenario, frozen, adaptive)` rows in scenario order.
pub fn drift_grid(o: &FigOpts) -> Vec<(&'static str, RunResult, RunResult)> {
    // InternViT-6B makes the encoder/LLM GPU split strongly
    // distribution-dependent — the regime where a frozen plan hurts most.
    let m = internvl_25(qwen25("7b"));
    let iters = o.iters.max(DRIFT_MIN_ITERS);
    let scenarios: [&'static str; 3] = ["curriculum", "bursty-video", "mixed"];
    let mut cells = Vec::new();
    for key in scenarios {
        for kind in [SystemKind::Dflop, SystemKind::DflopAdaptive] {
            cells.push(Cell {
                kind,
                m: m.clone(),
                dataset: key.to_string(),
                cfg: RunConfig::new(o.nodes, o.gbs, iters, o.seed),
            });
        }
    }
    let mut results = run_cells(&cells).expect("built-in dataset keys").into_iter();
    scenarios
        .into_iter()
        .map(|key| {
            let frozen = results.next().expect("grid row");
            let adaptive = results.next().expect("grid row");
            (key, frozen, adaptive)
        })
        .collect()
}

pub fn fig_drift(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 17 — frozen θ* vs drift-adaptive replanning (streaming extension, InternVL 2.5 / Qwen-2.5 7B)",
        &[
            "scenario",
            "frozen (TFLOP/s)",
            "adaptive (TFLOP/s)",
            "gain",
            "replans",
            "first swap @ iter",
        ],
    );
    let rows = drift_grid(o);
    let mut notes = String::new();
    for (key, frozen, adaptive) in &rows {
        let first_swap = adaptive
            .replan_events
            .iter()
            .find(|e| e.swapped)
            .map(|e| e.iteration.to_string())
            .unwrap_or_else(|| "-".into());
        t.row(vec![
            key.to_string(),
            f(frozen.per_gpu_throughput / 1e12, 1),
            f(adaptive.per_gpu_throughput / 1e12, 1),
            speedup(adaptive.speedup_over(frozen)),
            format!("{}", adaptive.replans),
            first_swap,
        ]);
        if *key == "mixed" {
            let evidence = match adaptive.replan_events.last() {
                Some(e) => format!(
                    "last confirmed drift at iter {} (score {:.3})",
                    e.iteration,
                    e.stat.score()
                ),
                None => "no drift was ever confirmed".to_string(),
            };
            notes.push_str(&format!(
                "no-thrash check (stationary mixed): {} replans, {evidence}\n",
                adaptive.replans,
            ));
        }
    }
    t.render() + &notes
}

/// Minimum iterations for a shard-grid run: the skew gate needs every
/// per-shard window (`ShardConfig::default().window_batches` batches) full
/// before rebalancing can activate, and the hot-shard burst lands at batch
/// 8 — shorter runs would end before the shard layer does anything.
/// Shared with the `shard_balance` example.
pub const SHARD_MIN_ITERS: usize = 14;

/// The (scenario × {static, rebalanced}) evaluation grid behind the shard
/// figure and the `shard_balance` example: the stationary skew scenarios,
/// the mid-run hot shard, the all-shards curriculum ramp (one *global*
/// replan, not one per shard), and the stationary homogeneous control.
/// Returns `(scenario, static, rebalanced)` rows in scenario order.
pub fn shard_grid_with(o: &FigOpts, dp_shards: usize) -> Vec<(&'static str, RunResult, RunResult)> {
    let m = llava_ov(llama3("8b"));
    let iters = o.iters.max(SHARD_MIN_ITERS);
    let scenarios: [&'static str; 5] =
        ["skewed-shard", "laggard-shard", "hot-shard", "curriculum", "mixed"];
    let mut cells = Vec::new();
    for key in scenarios {
        for rebalance in [false, true] {
            let mut cfg = RunConfig::new(o.nodes, o.gbs, iters, o.seed);
            cfg.shard = Some(ShardConfig {
                dp_shards,
                rebalance,
                ..ShardConfig::default()
            });
            cells.push(Cell {
                kind: SystemKind::DflopSharded,
                m: m.clone(),
                dataset: key.to_string(),
                cfg,
            });
        }
    }
    let mut results = run_cells(&cells).expect("built-in dataset keys").into_iter();
    scenarios
        .into_iter()
        .map(|key| {
            let stat = results.next().expect("grid row");
            let rebal = results.next().expect("grid row");
            (key, stat, rebal)
        })
        .collect()
}

/// [`shard_grid_with`] at the default shard count.
pub fn shard_grid(o: &FigOpts) -> Vec<(&'static str, RunResult, RunResult)> {
    shard_grid_with(o, ShardConfig::default().dp_shards)
}

pub fn fig_shard(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 18 — static sharding vs cross-shard rebalancing (shard subsystem, LLaVA-OV / Llama-3 8B, 4 DP shards)",
        &[
            "scenario",
            "static step (s)",
            "DFLOP step (s)",
            "gain",
            "gap static (s)",
            "gap DFLOP (s)",
            "migrations",
            "replans",
        ],
    );
    let rows = shard_grid(o);
    let mut notes = String::new();
    for (key, stat, rebal) in &rows {
        t.row(vec![
            key.to_string(),
            f(stat.mean_iteration_time, 3),
            f(rebal.mean_iteration_time, 3),
            speedup(stat.mean_iteration_time / rebal.mean_iteration_time),
            f(stat.mean_straggler_gap(), 3),
            f(rebal.mean_straggler_gap(), 3),
            format!("{}", rebal.migrations),
            format!("{}", rebal.replans),
        ]);
        if *key == "mixed" {
            notes.push_str(&format!(
                "quiet check (homogeneous shards): {} migrations, {} replans\n",
                rebal.migrations, rebal.replans,
            ));
        }
        if *key == "curriculum" {
            notes.push_str(&format!(
                "global-replan check (all shards ramp): {} replan(s) for the whole DP group\n",
                rebal.replans,
            ));
        }
    }
    t.render() + &notes
}

// ------------------------------------------------------------------
// Fig 19 (extension) — heterogeneous per-replica plans vs one global θ*
// ------------------------------------------------------------------

/// Minimum iterations for a hetero-grid run: the per-shard skew windows
/// (`window_batches` = 4 here) must fill before a fit can trigger, and
/// the comparison needs a stretch of post-fit iterations. Shared with the
/// `hetero_plan` example.
pub const HETERO_MIN_ITERS: usize = 12;

/// The (scenario × {global θ*, per-replica θ}) evaluation grid behind the
/// hetero figure and the `hetero_plan` example: the stationary skew
/// scenarios plus the homogeneous control, all under *static* sharding so
/// the two arms execute identical item placements and only the plans
/// differ. InternVL's 6B encoder makes the encoder/LLM split strongly
/// distribution-dependent — the regime where one pooled plan hurts most.
/// Returns `(scenario, global, hetero)` rows in scenario order.
pub fn hetero_grid_with(
    o: &FigOpts,
    dp_shards: usize,
) -> Vec<(&'static str, RunResult, RunResult)> {
    let m = internvl_25(qwen25("7b"));
    let iters = o.iters.max(HETERO_MIN_ITERS);
    let scenarios: [&'static str; 3] = ["skewed-shard", "laggard-shard", "mixed"];
    let mut cells = Vec::new();
    for key in scenarios {
        for hetero in [false, true] {
            let mut cfg = RunConfig::new(o.nodes, o.gbs, iters, o.seed);
            cfg.shard = Some(ShardConfig {
                dp_shards,
                rebalance: false,
                hetero,
                window_batches: 4,
                ..ShardConfig::default()
            });
            cells.push(Cell {
                kind: SystemKind::DflopSharded,
                m: m.clone(),
                dataset: key.to_string(),
                cfg,
            });
        }
    }
    let mut results = run_cells(&cells).expect("built-in dataset keys").into_iter();
    scenarios
        .into_iter()
        .map(|key| {
            let global = results.next().expect("grid row");
            let hetero = results.next().expect("grid row");
            (key, global, hetero)
        })
        .collect()
}

/// [`hetero_grid_with`] at the default shard count.
pub fn hetero_grid(o: &FigOpts) -> Vec<(&'static str, RunResult, RunResult)> {
    hetero_grid_with(o, ShardConfig::default().dp_shards)
}

pub fn fig_hetero(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 19 — one global θ* vs heterogeneous per-replica plans (static shards, InternVL 2.5 / Qwen-2.5 7B)",
        &[
            "scenario",
            "global step (s)",
            "hetero step (s)",
            "gain",
            "gap global (s)",
            "gap hetero (s)",
            "distinct plans",
            "replans",
        ],
    );
    let rows = hetero_grid(o);
    let mut notes = String::new();
    for (key, global, hetero) in &rows {
        let mut distinct: Vec<Theta> = Vec::new();
        for th in &hetero.hetero_thetas {
            if !distinct.contains(th) {
                distinct.push(*th);
            }
        }
        t.row(vec![
            key.to_string(),
            f(global.mean_iteration_time, 3),
            f(hetero.mean_iteration_time, 3),
            speedup(global.mean_iteration_time / hetero.mean_iteration_time),
            f(global.mean_straggler_gap(), 3),
            f(hetero.mean_straggler_gap(), 3),
            format!("{}", distinct.len().max(1)),
            format!("{}", hetero.replans),
        ]);
        if *key == "mixed" {
            notes.push_str(&format!(
                "quiet check (homogeneous shards): {} fitted plans, {} replans\n",
                hetero.hetero_thetas.len(),
                hetero.replans,
            ));
        }
    }
    t.render() + &notes
}

// ------------------------------------------------------------------
// Fig 20 (extension) — fault-injected elastic fleet: static θ* vs
// degradation-aware replanning under the same deterministic FaultTrace
// ------------------------------------------------------------------

/// Minimum iterations for a fleet-grid run: the scripted fault scenarios
/// play out over ~16 iterations (last recovery at 15), and the comparison
/// needs post-heal iterations on both sides. Shared with the
/// `fleet_churn` example.
pub const FLEET_MIN_ITERS: usize = 18;

/// The (fault scenario × {static θ*, fault-aware}) evaluation grid behind
/// Fig 20 and the `fleet_churn` example. Both arms replay the *same*
/// seeded [`crate::fault::FaultTrace`] — identical failures, stragglers,
/// and link degradation — and differ only in whether the system responds
/// (slowdown-weighted resharding + warm topology replans). Rebalancing is
/// on (the default): since PR 10 the cost balancer prices items by the
/// *confirmed* per-shard slowdown (`engine::exec::ShardedExec`), so it
/// composes with — instead of fighting — the fault-aware batch weighting.
/// The "none" control pins the zero-replans guarantee. Returns
/// `(trace, dataset, static, aware)` rows in scenario order.
pub fn fleet_grid_with(
    o: &FigOpts,
    dp_shards: usize,
) -> Vec<(&'static str, &'static str, RunResult, RunResult)> {
    let m = llava_ov(llama3("8b"));
    let iters = o.iters.max(FLEET_MIN_ITERS);
    let scenarios: [(&'static str, &'static str); 5] = [
        ("skewed-churn", "skewed-shard"),
        ("churn", "mixed"),
        ("straggler", "mixed"),
        ("degraded-link", "mixed"),
        ("none", "skewed-shard"),
    ];
    let mut cells = Vec::new();
    for (trace, dataset) in scenarios {
        for respond in [false, true] {
            let mut cfg = RunConfig::new(o.nodes, o.gbs, iters, o.seed);
            cfg.shard = Some(ShardConfig {
                dp_shards,
                window_batches: 4,
                ..ShardConfig::default()
            });
            cfg.faults = Some(FaultConfig { trace: trace.to_string(), respond });
            cells.push(Cell {
                kind: SystemKind::DflopSharded,
                m: m.clone(),
                dataset: dataset.to_string(),
                cfg,
            });
        }
    }
    let mut results = run_cells(&cells).expect("built-in scenario keys").into_iter();
    scenarios
        .into_iter()
        .map(|(trace, dataset)| {
            let stat = results.next().expect("grid row");
            let aware = results.next().expect("grid row");
            (trace, dataset, stat, aware)
        })
        .collect()
}

/// [`fleet_grid_with`] at the default shard count.
pub fn fleet_grid(o: &FigOpts) -> Vec<(&'static str, &'static str, RunResult, RunResult)> {
    fleet_grid_with(o, ShardConfig::default().dp_shards)
}

pub fn fig_fleet(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Fig 20 — fault-injected fleet: static θ* vs degradation-aware replanning (same FaultTrace both arms, LLaVA-OV / Llama-3 8B, 4 DP shards)",
        &[
            "fault trace",
            "static step (s)",
            "aware step (s)",
            "gain",
            "worst gap static (s)",
            "worst gap aware (s)",
            "fail/rec",
            "degr iters",
            "replans",
        ],
    );
    let rows = fleet_grid(o);
    // Survival threshold: 1.25× the healthy fleet's mean step (the
    // "none"-trace aware arm is the healthy control by construction).
    let control = rows
        .iter()
        .find(|(trace, ..)| *trace == "none")
        .map(|(_, _, _, aware)| aware.mean_iteration_time)
        .expect("none control in the grid");
    let worst = |r: &RunResult| r.straggler_gaps.iter().cloned().fold(0.0f64, f64::max);
    let mut survival = String::from(
        "survival (fraction of iterations with step <= 1.25x healthy mean):\n",
    );
    let mut notes = String::new();
    for (trace, dataset, stat, aware) in &rows {
        t.row(vec![
            format!("{trace} ({dataset})"),
            f(stat.mean_iteration_time, 3),
            f(aware.mean_iteration_time, 3),
            speedup(stat.mean_iteration_time / aware.mean_iteration_time),
            f(worst(stat), 3),
            f(worst(aware), 3),
            format!("{}/{}", aware.fault.failures, aware.fault.recoveries),
            format!("{}", aware.fault.degraded_iters),
            format!("{}", aware.replans),
        ]);
        let survive = |r: &RunResult| {
            let ok = r
                .iterations
                .iter()
                .filter(|s| s.iteration_time <= 1.25 * control)
                .count();
            ok as f64 / r.iterations.len().max(1) as f64
        };
        survival.push_str(&format!(
            "  {trace:14} static {:.2}  aware {:.2}\n",
            survive(stat),
            survive(aware)
        ));
        if *trace == "none" {
            notes.push_str(&format!(
                "fault-free control: {} replans (must be 0), {} fault events\n",
                aware.replans,
                aware.fault.failures + aware.fault.recoveries,
            ));
        }
        if *trace == "skewed-churn" {
            if let Some((q, p99)) = aware.straggler_gap_percentiles.last() {
                notes.push_str(&format!(
                    "straggler gap p{:.0} under skewed-churn: static {:.3}s, aware {:.3}s\n",
                    q * 100.0,
                    stat.straggler_gap_percentiles.last().map_or(0.0, |&(_, v)| v),
                    p99,
                ));
            }
        }
    }
    t.render() + &survival + &notes
}

// ------------------------------------------------------------------
// Bubbles (extension) — per-stage bubble/utilization accounting from
// the obs subsystem's gap-interval extraction
// ------------------------------------------------------------------

pub fn fig_bubbles(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let mut t = Table::new(
        "Bubbles — per-iteration pipeline bubble fraction (obs::bubble, mixed dataset)",
        &["system", "ideal (1F1B)", "mean", "min", "max"],
    );
    let results = run_grid(cross_specs(&[&m], &SYSTEMS, "mixed"), o);
    for (kind, r) in SYSTEMS.into_iter().zip(&results) {
        let fracs: Vec<f64> = r.iterations.iter().map(iteration_bubble_fraction).collect();
        let mean = fracs.iter().sum::<f64>() / fracs.len().max(1) as f64;
        let lo = fracs.iter().cloned().fold(f64::INFINITY, f64::min);
        let hi = fracs.iter().cloned().fold(0.0f64, f64::max);
        let ideal = ideal_bubble_fraction(r.theta.pipeline_depth(), r.theta.n_mb);
        t.row(vec![
            kind.label().to_string(),
            f(ideal, 3),
            f(mean, 3),
            f(lo, 3),
            f(hi, 3),
        ]);
    }
    // Per-stage drill-down on DFLOP's last iteration: where the bubbles
    // actually sit once the scheduler has balanced the buckets.
    let d = &results[0];
    let last = d.iterations.last().expect("at least one iteration");
    let sb = stage_bubbles(&last.timeline, last.n_stages, last.pipeline_makespan, &last.stage_busy);
    let mut t2 = Table::new(
        "Bubbles — DFLOP per-stage busy/idle, last iteration (gap intervals)",
        &["stage", "busy (s)", "idle (s)", "gaps", "longest gap (s)"],
    );
    for s in 0..sb.busy.len() {
        let gaps: Vec<_> = sb.gaps.iter().filter(|g| g.stage == s).collect();
        let longest = gaps.iter().map(|g| g.len()).fold(0.0f64, f64::max);
        t2.row(vec![
            format!("{s}"),
            f(sb.busy[s], 3),
            f(sb.idle[s], 3),
            format!("{}", gaps.len()),
            f(longest, 3),
        ]);
    }
    // Before/after for the bubble-filling execution model (PR 10): plain
    // DFLOP vs DFLOP (interleaved) on the video mixture, where encoder
    // skew creates the bubbles the fill pass targets.
    let vm = internvl_25(qwen25("7b"));
    let pair = run_grid(
        cross_specs(&[&vm], &[SystemKind::Dflop, SystemKind::DflopInterleaved], "video"),
        o,
    );
    let mut t3 = Table::new(
        "Bubbles — bubble-filling before/after (InternVL-2.5 / Qwen2.5 7B, video dataset)",
        &["system", "mean step (s)", "bubble fraction", "sub-ops", "filled GPU.s"],
    );
    for (kind, r) in [SystemKind::Dflop, SystemKind::DflopInterleaved].into_iter().zip(&pair) {
        let fracs: Vec<f64> = r.iterations.iter().map(iteration_bubble_fraction).collect();
        let mean = fracs.iter().sum::<f64>() / fracs.len().max(1) as f64;
        let subops: usize = r.iterations.iter().map(|s| s.fills.len()).sum();
        let filled: f64 = r.iterations.iter().map(|s| s.filled_time()).sum();
        t3.row(vec![
            kind.label().to_string(),
            f(r.mean_iteration_time, 4),
            f(mean, 3),
            format!("{subops}"),
            f(filled, 3),
        ]);
    }
    t.render()
        + &t2.render()
        + &format!(
            "stage-area bubble fraction (last DFLOP iteration): {:.3}\n",
            sb.bubble_fraction()
        )
        + &t3.render()
}

// ------------------------------------------------------------------
// Critical path (extension) — chain extraction, slack, and blame from
// the obs subsystem's critical-path analysis
// ------------------------------------------------------------------

pub fn fig_critpath(o: &FigOpts) -> String {
    let m = llava_ov(llama3("8b"));
    let results = run_grid(cross_specs(&[&m], &SYSTEMS, "mixed"), o);
    let mut t = Table::new(
        "Critical path — last-iteration chain accounting (obs::critical, mixed dataset)",
        &["system", "makespan", "chain ops", "enc (s)", "llm (s)", "comm wait (s)", "bit-exact"],
    );
    for (kind, r) in SYSTEMS.into_iter().zip(&results) {
        let last = r.iterations.last().expect("at least one iteration");
        let cp = critical_path(&last.timeline, last.n_stages, last.pipeline_makespan)
            .expect("recorded timeline always yields a chain");
        let enc_stages = r.theta.enc.dp * r.theta.enc.pp;
        let (enc, llm, comm) = cp.modality_blame(enc_stages);
        t.row(vec![
            kind.label().to_string(),
            secs(last.pipeline_makespan),
            format!("{}", cp.spans.iter().filter(|s| !s.is_comm).count()),
            f(enc, 3),
            f(llm, 3),
            f(comm, 3),
            // The defining property: chain span durations telescope to
            // the makespan bit pattern, not merely within a tolerance.
            if cp.total().to_bits() == last.pipeline_makespan.to_bits() {
                "yes".to_string()
            } else {
                "NO".to_string()
            },
        ]);
    }

    // DFLOP drill-down: the per-stage blame split plus the largest
    // off-chain slack slots — the machine-readable list the
    // bubble-exploiting execution model (ROADMAP item 1) consumes.
    let d = &results[0];
    let last = d.iterations.last().expect("at least one iteration");
    let cp = critical_path(&last.timeline, last.n_stages, last.pipeline_makespan)
        .expect("recorded timeline always yields a chain");
    let blame = cp.stage_blame(last.n_stages);
    let worst = blame
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(s, b)| format!("stage {s} ({:.3} s)", b))
        .unwrap_or_else(|| "-".into());

    let slacks = op_slack(&last.timeline, last.n_stages, last.pipeline_makespan);
    let mut off_chain: Vec<&OpSlack> = slacks.iter().filter(|s| !s.critical).collect();
    off_chain.sort_by(|a, b| {
        b.slack
            .total_cmp(&a.slack)
            .then(a.stage.cmp(&b.stage))
            .then(a.bucket.cmp(&b.bucket))
            .then(a.is_forward.cmp(&b.is_forward))
    });
    let mut t2 = Table::new(
        "Critical path — DFLOP top slack slots, last iteration (obs::critical::op_slack)",
        &["stage", "bucket", "op", "start (s)", "finish (s)", "slack (s)"],
    );
    for s in off_chain.iter().take(8) {
        t2.row(vec![
            format!("{}", s.stage),
            format!("{}", s.bucket),
            if s.is_forward { "fwd".into() } else { "bwd".to_string() },
            f(s.start, 3),
            f(s.finish, 3),
            f(s.slack, 3),
        ]);
    }
    // Before/after for the bubble-filling execution model (PR 10): the
    // interleaved system consumes exactly these slack slots, so its chain
    // accounting shows how much encoder blame the fill pass removed.
    let vm = internvl_25(qwen25("7b"));
    let pair = run_grid(
        cross_specs(&[&vm], &[SystemKind::Dflop, SystemKind::DflopInterleaved], "video"),
        o,
    );
    let mut t3 = Table::new(
        "Critical path — bubble-filling before/after (InternVL-2.5 / Qwen2.5 7B, video dataset)",
        &["system", "makespan", "enc (s)", "llm (s)", "comm wait (s)", "sub-ops"],
    );
    for (kind, r) in [SystemKind::Dflop, SystemKind::DflopInterleaved].into_iter().zip(&pair) {
        let last = r.iterations.last().expect("at least one iteration");
        let cp = critical_path(&last.timeline, last.n_stages, last.pipeline_makespan)
            .expect("recorded timeline always yields a chain");
        let enc_stages = r.theta.enc.dp * r.theta.enc.pp;
        let (enc, llm, comm) = cp.modality_blame(enc_stages);
        let subops: usize = r.iterations.iter().map(|s| s.fills.len()).sum();
        t3.row(vec![
            kind.label().to_string(),
            secs(last.pipeline_makespan),
            f(enc, 3),
            f(llm, 3),
            f(comm, 3),
            format!("{subops}"),
        ]);
    }
    t.render()
        + &t2.render()
        + &format!(
            "DFLOP chain: {} of {} ops critical, heaviest blame {worst}, comm wait {:.3} s\n",
            slacks.iter().filter(|s| s.critical).count(),
            slacks.len(),
            cp.comm_wait(),
        )
        + &t3.render()
}

// ------------------------------------------------------------------
// Audit (extension) — predicted-vs-measured residuals and replan
// attribution from the obs subsystem's post-run audit
// ------------------------------------------------------------------

pub fn fig_audit(o: &FigOpts) -> String {
    // Same grid shape as Fig 17: the drift scenarios are where plan
    // epochs actually change, so the replan attribution has material.
    let m = internvl_25(qwen25("7b"));
    let iters = o.iters.max(DRIFT_MIN_ITERS);
    let scenarios: [&'static str; 3] = ["curriculum", "bursty-video", "mixed"];
    let mut cells = Vec::new();
    for key in scenarios {
        for kind in [SystemKind::Dflop, SystemKind::DflopAdaptive] {
            let mut cfg = RunConfig::new(o.nodes, o.gbs, iters, o.seed);
            cfg.obs = Some(ObsConfig { timelines: false, metrics: false, audit: true });
            cells.push(Cell { kind, m: m.clone(), dataset: key.to_string(), cfg });
        }
    }
    let results = run_cells(&cells).expect("built-in dataset keys");

    let mut t = Table::new(
        "Audit — estimator predicted vs simulated measured step time (obs::audit)",
        &["scenario", "system", "audited iters", "mean |rel err|", "bias (s)"],
    );
    let mut audits = Vec::new();
    for (i, key) in scenarios.into_iter().enumerate() {
        for (j, kind) in [SystemKind::Dflop, SystemKind::DflopAdaptive].into_iter().enumerate()
        {
            let r = &results[i * 2 + j];
            let a = r
                .obs
                .as_deref()
                .and_then(|log| log.audit.as_ref())
                .expect("audit-enabled run records a report");
            t.row(vec![
                key.to_string(),
                kind.label().to_string(),
                format!("{}", a.rows.len()),
                format!("{:.2}%", a.mean_abs_rel_err * 100.0),
                format!("{:+.3}", a.bias),
            ]);
            audits.push((key, kind, a.clone()));
        }
    }

    // Counterfactual replan attribution: incumbent θ re-priced over the
    // realized post-swap batches (delta replay) vs the plan it adopted.
    let mut t2 = Table::new(
        "Audit — counterfactual replan attribution (delta replay of the incumbent θ)",
        &["scenario", "swap @ iter", "window", "incumbent (s)", "adopted (s)", "measured gain", "predicted gain"],
    );
    let mut any_swap = false;
    for (key, kind, a) in &audits {
        if *kind != SystemKind::DflopAdaptive {
            continue;
        }
        for ra in &a.replans {
            any_swap = true;
            t2.row(vec![
                key.to_string(),
                format!("{}", ra.iteration),
                format!("{}", ra.window),
                f(ra.incumbent_mean, 3),
                f(ra.adopted_mean, 3),
                format!("{:+.3} s", ra.measured_benefit),
                if ra.predicted_benefit.is_finite() {
                    format!("{:+.3} s", ra.predicted_benefit)
                } else {
                    "-".to_string()
                },
            ]);
        }
    }
    let note = if any_swap {
        String::new()
    } else {
        "no plan swaps in any scenario — attribution table empty\n".to_string()
    };
    t.render() + &t2.render() + &note
}

// ------------------------------------------------------------------
// Tables 2 and 4
// ------------------------------------------------------------------

pub fn table2(_o: &FigOpts) -> String {
    let mut t = Table::new(
        "Table 2 — composition of the mixed dataset",
        &["dataset", "data type", "# of samples"],
    );
    let kinds = ["Single Image", "Single Image", "Single Image", "Multiple Images", "Video"];
    for (src, kind) in Dataset::mixed(0).sources.iter().zip(kinds) {
        t.row(vec![src.name.to_string(), kind.to_string(), format!("{}k", src.samples / 1000)]);
    }
    t.render()
}

pub fn table4(o: &FigOpts) -> String {
    let mut t = Table::new(
        "Table 4 — total training time and DFLOP overhead (mixed dataset)",
        &["model", "training time", "DFLOP overhead", "relative"],
    );
    let configs = paper_configs();
    let cells: Vec<Cell> = configs
        .iter()
        .map(|cfg| Cell {
            kind: SystemKind::Dflop,
            m: cfg.mllm.clone(),
            dataset: "mixed".to_string(),
            cfg: RunConfig::new(8, o.gbs, o.iters, o.seed),
        })
        .collect();
    let results = run_cells(&cells).expect("built-in dataset keys");
    for (cfg, d) in configs.iter().zip(&results) {
        let steps = 185_000.0 / o.gbs as f64;
        let train_h = steps * d.mean_iteration_time / 3600.0;
        let overhead_min =
            (d.profiling_seconds + d.optimizer_elapsed.as_secs_f64()) / 60.0;
        t.row(vec![
            cfg.label.to_string(),
            format!("{:.2} h", train_h),
            format!("{:.2} min", overhead_min),
            format!("{:.1}%", overhead_min / 60.0 / train_h * 100.0),
        ]);
    }
    t.render()
}

/// Memory footprint report (supporting the Eq 4–5 feasibility checks).
pub fn memory_report(_o: &FigOpts) -> String {
    let mut t = Table::new(
        "memory model — per-GPU model states at TP=8, PP=1",
        &["model", "LLM state", "encoder state"],
    );
    for cfg in paper_configs() {
        let m = &cfg.mllm;
        t.row(vec![
            cfg.label.to_string(),
            bytes(m.llm_model_state_bytes(m.llm.layers as f64, 8)),
            bytes(m.encoder_model_state_bytes(m.encoder.layers as f64, 8)),
        ]);
    }
    t.render()
}

/// Run every figure and table in order.
pub fn all(o: &FigOpts) -> String {
    let mut out = String::new();
    out.push_str(&fig01(o));
    out.push_str(&fig02(o));
    out.push_str(&fig04(o));
    out.push_str(&fig07(o));
    out.push_str(&fig08(o));
    out.push_str(&fig09(o));
    out.push_str(&fig10(o));
    out.push_str(&fig11(o));
    out.push_str(&fig12(o));
    out.push_str(&fig13(o));
    out.push_str(&fig14(o));
    out.push_str(&fig15(o));
    out.push_str(&fig16(o));
    out.push_str(&fig_drift(o));
    out.push_str(&fig_shard(o));
    out.push_str(&fig_hetero(o));
    out.push_str(&fig_fleet(o));
    out.push_str(&fig_bubbles(o));
    out.push_str(&fig_critpath(o));
    out.push_str(&fig_audit(o));
    out.push_str(&table2(o));
    out.push_str(&table4(o));
    out
}

/// Dispatch by figure id.
pub fn by_id(id: &str, o: &FigOpts) -> Option<String> {
    Some(match id {
        "1" => fig01(o),
        "2" => fig02(o),
        "4" => fig04(o),
        "7" => fig07(o),
        "8" => fig08(o),
        "9" => fig09(o),
        "10" => fig10(o),
        "11" => fig11(o),
        "12" => fig12(o),
        "13" => fig13(o),
        "14" => fig14(o),
        "15" => fig15(o),
        "16" => fig16(o),
        "17" | "drift" => fig_drift(o),
        "18" | "shard" => fig_shard(o),
        "19" | "hetero" => fig_hetero(o),
        "20" | "fleet" => fig_fleet(o),
        "bubbles" => fig_bubbles(o),
        "critpath" => fig_critpath(o),
        "audit" => fig_audit(o),
        "all" => all(o),
        _ => return None,
    })
}
