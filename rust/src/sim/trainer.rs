//! Iteration-level training simulation of complete systems.
//!
//! A [`run_system`] call plays one (system × model × dataset × cluster)
//! cell of the paper's evaluation: it performs the system's offline phase
//! (profiling + strategy selection), then simulates `iters` training
//! iterations — scheduling each global batch, executing it on the 1F1B
//! engine against the ground-truth cluster, and feeding measurements back
//! into Adaptive Correction — and aggregates the statistics every figure
//! consumes.
//!
//! Since PR 5 the actual machinery lives in `crate::engine`: one shared
//! iteration loop behind the `PlanPolicy` / `ExecModel` seams, with the
//! unified `Telemetry` collector assembling [`RunResult`]. This module
//! keeps the run *vocabulary* ([`SystemKind`], [`RunConfig`],
//! [`RunResult`], [`Cell`]) and the two historical entry points, both thin
//! delegates to [`crate::engine::run`].

use crate::fault::FaultStats;
use crate::model::catalog::Mllm;
use crate::obs::{ObsConfig, RunLog};
use crate::optimizer::plan::Theta;
use crate::pipeline::build::IterationStats;
use crate::shard::ShardConfig;
use crate::stream::replan::{ReplanConfig, ReplanEvent};
use crate::util::error::Result;
use std::time::Duration;

/// The systems compared in the evaluation (§5.1 baselines + §5.3.2
/// ablation variants + the streaming extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Full DFLOP: data-aware optimizer + online scheduler + correction.
    Dflop,
    /// Full DFLOP plus bubble-filling interleaved execution: per-bucket
    /// encoder forward work is decomposed into sub-ops (sized by the
    /// batch's shape stats) and packed into the LLM pipeline's 1F1B
    /// bubbles (`pipeline::build::iterate_interleaved`).
    /// `RunConfig::bubble_fill = false` degrades it to plain [`Dflop`]
    /// bit-for-bit.
    DflopInterleaved,
    /// Full DFLOP plus the `stream` subsystem: drift detection over the
    /// live batch stream and warm-started replanning on confirmed drift.
    DflopAdaptive,
    /// Full DFLOP plus the `shard` subsystem: per-shard data streams,
    /// cross-shard rebalancing behind a distributional skew gate, the
    /// step barrier with straggler-gap telemetry, and *global* (merged)
    /// drift replanning. `RunConfig::shard` configures the shard layer;
    /// `rebalance: false` is the static-sharding baseline and
    /// `hetero: true` fits heterogeneous per-replica plans
    /// (`engine::hetero`).
    DflopSharded,
    /// Ablation: data-aware optimizer, random microbatching.
    DflopOptimizerOnly,
    /// Ablation: baseline (Megatron) strategy, online scheduler.
    DflopSchedulerOnly,
    /// Megatron-LM-style baseline.
    Megatron,
    /// Plain-PyTorch-style baseline.
    Pytorch,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::Dflop => "DFLOP",
            SystemKind::DflopInterleaved => "DFLOP (interleaved)",
            SystemKind::DflopAdaptive => "DFLOP (adaptive)",
            SystemKind::DflopSharded => "DFLOP (sharded)",
            SystemKind::DflopOptimizerOnly => "DFLOP (optimizer only)",
            SystemKind::DflopSchedulerOnly => "DFLOP (scheduler only)",
            SystemKind::Megatron => "Megatron-LM",
            SystemKind::Pytorch => "PyTorch",
        }
    }
}

/// Parameters of one simulated run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub nodes: usize,
    pub gbs: usize,
    pub iters: usize,
    pub seed: u64,
    /// Data Profiler sample count.
    pub profile_samples: usize,
    /// ILP time budget per scheduling call.
    pub ilp_budget: Duration,
    /// Disable Adaptive Correction (Fig 15 off-arm).
    pub disable_correction: bool,
    /// Anomaly injection for Fig 15: (shape-bucket, throughput factor).
    pub injected: Vec<(u64, f64)>,
    /// Stream-subsystem tuning for [`SystemKind::DflopAdaptive`] and
    /// [`SystemKind::DflopSharded`] runs (`None` =
    /// [`ReplanConfig::default`]); ignored by other systems.
    pub replan: Option<ReplanConfig>,
    /// Shard-layer tuning for [`SystemKind::DflopSharded`] runs (`None` =
    /// [`ShardConfig::default`]); ignored by other systems.
    pub shard: Option<ShardConfig>,
    /// Fault injection for [`SystemKind::DflopSharded`] fleet runs:
    /// `None` runs the healthy pipeline untouched. Requires `shard` with
    /// `dp_shards >= 2` and no `hetero` (validated up front).
    pub faults: Option<FaultConfig>,
    /// Observability recorder configuration (`crate::obs`). `None` — the
    /// default — keeps the recorder off, which is guaranteed zero-cost
    /// and bit-identical to a build without the seam.
    pub obs: Option<ObsConfig>,
    /// Bubble-filling switch for [`SystemKind::DflopInterleaved`] runs
    /// (ignored by every other system). `false` disables the fill pass,
    /// making an interleaved run bit-identical to plain
    /// [`SystemKind::Dflop`] on every statistic — the parity anchor.
    pub bubble_fill: bool,
}

/// Fault-injection arm of a fleet run.
#[derive(Clone, Debug)]
pub struct FaultConfig {
    /// Scenario key for [`crate::fault::FaultTrace::by_key`] — one of
    /// `none|churn|straggler|degraded-link|skewed-churn|long-horizon`.
    pub trace: String,
    /// `true` = degradation-aware arm (slowdown-weighted resharding +
    /// warm topology replans); `false` = static-θ* arm that absorbs the
    /// same injected physics without responding.
    pub respond: bool,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig { trace: "none".to_string(), respond: true }
    }
}

impl RunConfig {
    pub fn new(nodes: usize, gbs: usize, iters: usize, seed: u64) -> RunConfig {
        RunConfig {
            nodes,
            gbs,
            iters,
            seed,
            profile_samples: 512,
            ilp_budget: Duration::from_millis(50),
            disable_correction: false,
            injected: Vec::new(),
            replan: None,
            shard: None,
            faults: None,
            obs: None,
            bubble_fill: true,
        }
    }
}

/// Aggregated outcome of a run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub system: SystemKind,
    pub theta: Theta,
    pub n_gpus: usize,
    /// Mean per-GPU achieved throughput (FLOP/s).
    pub per_gpu_throughput: f64,
    /// Mean iteration wall-clock (simulated seconds).
    pub mean_iteration_time: f64,
    /// Mean per-iteration total idle GPU-seconds (Fig 13).
    pub mean_idle: f64,
    /// Per-stage throughput samples pooled over iterations (Fig 14).
    pub stage_throughput_samples: Vec<f64>,
    /// Per-bucket module times pooled over iterations (Fig 4).
    pub bucket_enc_times: Vec<f64>,
    pub bucket_llm_times: Vec<f64>,
    /// Scheduling wall-clock per iteration (real, Fig 16b).
    pub sched_elapsed: Vec<Duration>,
    /// How often the ILP hit its limit and fell back to the incumbent.
    pub lpt_fallbacks: usize,
    /// Offline overheads (Table 4): model+data profiling, optimizer.
    pub profiling_seconds: f64,
    pub optimizer_elapsed: Duration,
    /// Confirmed drifts that swapped the plan (adaptive runs; 0 elsewhere
    /// — and 0 on stationary data is the no-thrash guarantee).
    pub replans: usize,
    /// Every confirmed drift, in iteration order (adaptive runs).
    pub replan_events: Vec<ReplanEvent>,
    /// Per-iteration cross-shard straggler gap — the slowest replica's
    /// lead over the fastest (sharded runs; empty elsewhere).
    pub straggler_gaps: Vec<f64>,
    /// `(quantile, gap)` percentiles of `straggler_gaps` at p50/p90/p99
    /// (sharded runs; empty elsewhere).
    pub straggler_gap_percentiles: Vec<(f64, f64)>,
    /// Total items migrated across shards over the run (sharded runs;
    /// 0 elsewhere — and 0 on homogeneous shards is the quiet guarantee).
    pub migrations: usize,
    /// Injected-fault counters of a fleet run (all zero without
    /// `RunConfig::faults`).
    pub fault: FaultStats,
    /// The assigned per-replica plans of a heterogeneous sharded run, in
    /// shard order (empty everywhere else — including hetero runs whose
    /// shards never diverged from the global θ).
    pub hetero_thetas: Vec<Theta>,
    /// Full per-iteration stats for figure-specific postprocessing.
    pub iterations: Vec<IterationStats>,
    /// The observability recorder's log (`Some` iff `RunConfig::obs` was
    /// set): structured events, per-iteration traces, and the metrics
    /// registry, ready for `obs::chrome::trace_json` /
    /// `Registry::dump`.
    pub obs: Option<Box<RunLog>>,
}

impl RunResult {
    /// Speedup of `self` over `other` in per-GPU throughput.
    pub fn speedup_over(&self, other: &RunResult) -> f64 {
        self.per_gpu_throughput / other.per_gpu_throughput
    }

    /// Mean per-iteration straggler gap (0 for non-sharded runs).
    pub fn mean_straggler_gap(&self) -> f64 {
        if self.straggler_gaps.is_empty() {
            0.0
        } else {
            self.straggler_gaps.iter().sum::<f64>() / self.straggler_gaps.len() as f64
        }
    }
}

/// One independent (system × model × dataset × cluster) evaluation cell of
/// the paper's grid. Cells are self-contained — the model, dataset key,
/// and full [`RunConfig`] (cluster size included) travel with the cell —
/// so a batch of them can run on any worker in any order.
#[derive(Clone, Debug)]
pub struct Cell {
    pub kind: SystemKind,
    pub m: Mllm,
    pub dataset: String,
    pub cfg: RunConfig,
}

/// Evaluate a batch of cells on the `util::parallel` pool.
///
/// Every cell is validated (`engine::validate`) *before* any worker
/// starts, so a bad dataset key is an error here rather than a panic on a
/// pool thread. Results come back in cell order, and every cell is seeded
/// from its own `cfg.seed`, so the output is identical to calling
/// [`run_system`] in a serial loop — this is what lets the figure harness
/// sweep a whole (system × model × dataset) grid across all cores.
pub fn run_cells(cells: &[Cell]) -> Result<Vec<RunResult>> {
    for c in cells {
        crate::engine::validate(c.kind, &c.dataset, &c.cfg)?;
    }
    Ok(crate::util::parallel::par_map(cells.len(), |i| {
        let c = &cells[i];
        run_system(c.kind, &c.m, &c.dataset, &c.cfg)
    }))
}

/// Run one system on one workload through [`crate::engine::run`].
///
/// Infallible wrapper kept for tests, benches, and examples that pass
/// literal keys; fallible callers (the CLI, [`run_cells`]) use the engine
/// entry directly.
pub fn run_system(
    kind: SystemKind,
    m: &Mllm,
    dataset_key: &str,
    cfg: &RunConfig,
) -> RunResult {
    crate::engine::run(kind, m, dataset_key, cfg).unwrap_or_else(|e| panic!("{e}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::perfmodel::Truth;

    fn quick_cfg() -> RunConfig {
        let mut c = RunConfig::new(1, 32, 3, 42);
        c.profile_samples = 256;
        c
    }

    #[test]
    fn dflop_beats_baselines_on_mixed_workload() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let dflop = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let mega = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let torch = run_system(SystemKind::Pytorch, &m, "mixed", &cfg);
        assert!(
            dflop.speedup_over(&mega) > 1.0,
            "DFLOP {:.3e} vs Megatron {:.3e}",
            dflop.per_gpu_throughput,
            mega.per_gpu_throughput
        );
        assert!(
            dflop.speedup_over(&torch) > 1.0,
            "DFLOP {:.3e} vs PyTorch {:.3e}",
            dflop.per_gpu_throughput,
            torch.per_gpu_throughput
        );
    }

    #[test]
    fn ablations_land_between_baseline_and_full() {
        // Fig 10's structure: PyTorch ≤ Megatron ≤ {optimizer-only,
        // scheduler-only} ≤ full DFLOP (small tolerance for sim noise).
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(2, 64, 3, 42);
        cfg.profile_samples = 256;
        let full = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let opt_only = run_system(SystemKind::DflopOptimizerOnly, &m, "mixed", &cfg);
        let sched_only = run_system(SystemKind::DflopSchedulerOnly, &m, "mixed", &cfg);
        let mega = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let torch = run_system(SystemKind::Pytorch, &m, "mixed", &cfg);
        assert!(mega.per_gpu_throughput >= torch.per_gpu_throughput * 0.98);
        assert!(opt_only.per_gpu_throughput >= mega.per_gpu_throughput * 0.95);
        assert!(sched_only.per_gpu_throughput >= mega.per_gpu_throughput * 0.95);
        assert!(full.per_gpu_throughput >= opt_only.per_gpu_throughput * 0.95);
        assert!(full.per_gpu_throughput >= sched_only.per_gpu_throughput * 0.95);
    }

    #[test]
    fn run_produces_complete_statistics() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let r = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        assert_eq!(r.iterations.len(), 3);
        assert_eq!(r.sched_elapsed.len(), 3);
        assert!(!r.stage_throughput_samples.is_empty());
        assert!(!r.bucket_llm_times.is_empty());
        assert!(r.profiling_seconds > 0.0);
        assert!(r.per_gpu_throughput > 0.0);
        assert!(r.per_gpu_throughput < 312e12, "exceeds peak");
        assert!(r.hetero_thetas.is_empty());
    }

    #[test]
    fn deterministic_given_seed() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let a = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let b = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        assert_eq!(a.per_gpu_throughput, b.per_gpu_throughput);
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn unknown_dataset_key_is_an_error_not_a_pool_panic() {
        // Satellite: keys are validated before any profiling or pool
        // work, at both the engine entry and the cell batch.
        let m = llava_ov(llama3("8b"));
        let cfg = RunConfig::new(1, 8, 1, 1);
        assert!(crate::engine::run(SystemKind::Dflop, &m, "bogus", &cfg).is_err());
        assert!(crate::engine::run(SystemKind::DflopSharded, &m, "bogus", &cfg).is_err());
        let cells = vec![Cell {
            kind: SystemKind::Dflop,
            m: m.clone(),
            dataset: "bogus".into(),
            cfg: cfg.clone(),
        }];
        assert!(run_cells(&cells).is_err());
        // Shard-count arithmetic is validated up front too.
        let mut tiny = RunConfig::new(1, 2, 1, 1);
        tiny.shard = Some(ShardConfig { dp_shards: 4, ..ShardConfig::default() });
        assert!(crate::engine::run(SystemKind::DflopSharded, &m, "mixed", &tiny).is_err());
    }

    #[test]
    fn adaptive_never_replans_on_stationary_data() {
        // The no-thrash guarantee: on the stationary mixed workload the
        // drift detector must not fire a single replan over a run several
        // windows long, and the adaptive system ends on the offline θ*.
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(1, 32, 14, 42);
        cfg.profile_samples = 256;
        let frozen = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let adaptive = run_system(SystemKind::DflopAdaptive, &m, "mixed", &cfg);
        assert_eq!(adaptive.replans, 0, "replanned on stationary data");
        assert!(
            adaptive.replan_events.is_empty(),
            "drift fired on stationary data: {:?}",
            adaptive.replan_events
        );
        assert_eq!(adaptive.theta, frozen.theta);
    }

    #[test]
    fn interleaved_beats_plain_dflop_on_video_heavy_mixture() {
        // The PR-10 acceptance scenario: InternVL's 6B encoder on the
        // video mixture, where per-bucket unit variance puts encoder
        // heads on the critical path. Bubble-filling must strictly cut
        // both the mean step time and the bubble fraction; with the fill
        // switched off the interleaved system must be bit-identical to
        // plain DFLOP on every statistic.
        let m = crate::model::catalog::internvl_25(
            crate::model::catalog::qwen25("7b"),
        );
        let mut cfg = RunConfig::new(2, 16, 4, 42);
        cfg.profile_samples = 256;
        // Provably-optimal schedules: the comparison is plan-for-plan,
        // not incumbent-vs-incumbent.
        cfg.ilp_budget = Duration::from_secs(10);
        let plain = run_system(SystemKind::Dflop, &m, "video", &cfg);
        let inter = run_system(SystemKind::DflopInterleaved, &m, "video", &cfg);
        assert_eq!(plain.lpt_fallbacks, 0);
        assert_eq!(inter.lpt_fallbacks, 0);
        assert_eq!(inter.theta, plain.theta, "fill must not change the plan");
        assert!(
            inter.iterations.iter().any(|s| !s.fills.is_empty()),
            "no iteration placed a single sub-op"
        );
        assert!(
            inter.mean_iteration_time < plain.mean_iteration_time,
            "interleaved step {:.4}s not below plain {:.4}s",
            inter.mean_iteration_time,
            plain.mean_iteration_time
        );
        let frac = |r: &RunResult| {
            r.iterations
                .iter()
                .map(crate::obs::bubble::iteration_bubble_fraction)
                .sum::<f64>()
                / r.iterations.len() as f64
        };
        assert!(
            frac(&inter) < frac(&plain),
            "bubble fraction not reduced: {:.4} vs {:.4}",
            frac(&inter),
            frac(&plain)
        );

        // The parity anchor: bubble_fill = false degrades the new kind to
        // plain DFLOP bit-for-bit.
        let mut off_cfg = cfg.clone();
        off_cfg.bubble_fill = false;
        let off = run_system(SystemKind::DflopInterleaved, &m, "video", &off_cfg);
        assert_eq!(off.theta, plain.theta);
        assert_eq!(
            off.mean_iteration_time.to_bits(),
            plain.mean_iteration_time.to_bits()
        );
        assert_eq!(
            off.per_gpu_throughput.to_bits(),
            plain.per_gpu_throughput.to_bits()
        );
        assert!(off.iterations.iter().all(|s| s.fills.is_empty()));
    }

    fn sharded_cfg(rebalance: bool) -> RunConfig {
        let mut cfg = RunConfig::new(1, 64, 14, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig { rebalance, ..ShardConfig::default() });
        cfg
    }

    #[test]
    fn sharded_rebalance_beats_static_on_skewed_shards() {
        // The acceptance scenario: a graded video→image tilt across four
        // DP shards. Static sharding pays the video-heavy replica's
        // makespan at every barrier; the rebalancer must migrate work,
        // cut the simulated step time, and shrink the straggler gap.
        let m = llava_ov(llama3("8b"));
        let stat = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &sharded_cfg(false));
        let rebal = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &sharded_cfg(true));
        assert_eq!(stat.migrations, 0, "static baseline must not migrate");
        assert!(rebal.migrations > 0, "skew never activated the balancer");
        assert!(
            rebal.mean_iteration_time < stat.mean_iteration_time,
            "rebalanced step {:.3}s not below static {:.3}s",
            rebal.mean_iteration_time,
            stat.mean_iteration_time
        );
        assert!(
            rebal.mean_straggler_gap() < stat.mean_straggler_gap(),
            "straggler gap not reduced: {:.3}s vs {:.3}s",
            rebal.mean_straggler_gap(),
            stat.mean_straggler_gap()
        );
        assert!(rebal.speedup_over(&stat) > 1.0);
        // Telemetry shape: one gap per iteration, all finite.
        assert_eq!(rebal.straggler_gaps.len(), 14);
        assert!(rebal.straggler_gaps.iter().all(|g| g.is_finite() && *g >= 0.0));
    }

    #[test]
    fn sharded_homogeneous_shards_are_quiet() {
        // The quiet guarantee: statistically identical shards must see
        // zero migrations and zero global replans, making the full system
        // bit-identical to the static baseline.
        let m = llava_ov(llama3("8b"));
        let stat = run_system(SystemKind::DflopSharded, &m, "mixed", &sharded_cfg(false));
        let rebal = run_system(SystemKind::DflopSharded, &m, "mixed", &sharded_cfg(true));
        assert_eq!(rebal.migrations, 0, "homogeneous shards migrated");
        assert_eq!(rebal.replans, 0, "homogeneous shards replanned");
        assert!(rebal.replan_events.is_empty());
        assert_eq!(
            rebal.per_gpu_throughput.to_bits(),
            stat.per_gpu_throughput.to_bits(),
            "quiet sharded run must equal static sharding exactly"
        );
        assert_eq!(rebal.theta, stat.theta);
    }

    #[test]
    fn sharded_accounting_is_complete() {
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(1, 32, 3, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig { dp_shards: 4, ..ShardConfig::default() });
        let r = run_system(SystemKind::DflopSharded, &m, "laggard-shard", &cfg);
        assert_eq!(r.n_gpus, 8 * 4, "4 replicas of one 8-GPU node");
        assert_eq!(r.iterations.len(), 3);
        assert_eq!(r.straggler_gaps.len(), 3);
        // The laggard makes the gap strictly positive from the start.
        assert!(r.straggler_gaps.iter().all(|&g| g > 0.0));
        assert!(r.per_gpu_throughput > 0.0);
        assert!(r.per_gpu_throughput < 312e12, "exceeds peak");
        // Stage accounting concatenates all replicas.
        let stages_per_replica = r.theta.enc.gpus() / r.theta.enc.tp
            + r.theta.llm.gpus() / r.theta.llm.tp;
        assert_eq!(r.iterations[0].n_stages, 4 * stages_per_replica);
        // FLOP conservation across the merged view.
        let s = &r.iterations[0];
        let sum: f64 = s.stage_flop.iter().sum();
        assert!((sum / s.total_flop - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_replans_and_beats_frozen_on_curriculum() {
        // The acceptance scenario: a curriculum text→video ramp. The
        // frozen θ* was fitted to the image-heavy warm-up phase; the
        // adaptive system must detect the ramp, swap plans at least once,
        // and end the run with measurably higher mean throughput.
        // InternVL's 6B encoder makes the encoder/LLM GPU split strongly
        // distribution-dependent, so a stale split is expensive.
        let m = crate::model::catalog::internvl_25(
            crate::model::catalog::qwen25("7b"),
        );
        let mut cfg = RunConfig::new(2, 32, 22, 42);
        cfg.profile_samples = 256;
        // A slightly quicker cadence than the defaults so the run reaches
        // a fully video-fitted plan (second replan) with iterations to
        // spare before the steady-state comparison window.
        cfg.replan = Some(crate::stream::replan::ReplanConfig {
            window_batches: 6,
            cooldown: 4,
            ..crate::stream::replan::ReplanConfig::default()
        });
        let frozen = run_system(SystemKind::Dflop, &m, "curriculum", &cfg);
        let adaptive = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &cfg);
        assert!(
            adaptive.replans >= 1,
            "curriculum ramp never triggered a plan swap: {:?}",
            adaptive.replan_events
        );
        // Post-ramp steady state (the last 4 iterations are firmly in the
        // video-dominated phase and past the swaps): the adapted plan must
        // be measurably faster than the frozen one.
        let steady = |r: &RunResult| {
            let tail = &r.iterations[r.iterations.len() - 4..];
            tail.iter().map(|s| s.iteration_time).sum::<f64>() / tail.len() as f64
        };
        let gain = steady(&frozen) / steady(&adaptive);
        assert!(
            gain > 1.02,
            "adaptive steady-state {:.3}s not measurably below frozen {:.3}s (gain {gain:.3})",
            steady(&adaptive),
            steady(&frozen)
        );
        // Whole-run throughput must not regress either (pre-drift
        // iterations are identical plans).
        assert!(
            adaptive.speedup_over(&frozen) > 0.99,
            "adaptive lost overall: {:.3e} vs {:.3e}",
            adaptive.per_gpu_throughput,
            frozen.per_gpu_throughput
        );
        // The swap happened after the ramp began and changed the plan.
        let first = adaptive.replan_events.iter().find(|e| e.swapped).expect("swap");
        assert!(first.iteration >= 7, "swapped before the ramp: {first:?}");
        assert_ne!(first.old, first.new);
    }

    #[test]
    fn plan_swap_resets_stale_correction_penalties_on_curriculum() {
        // Satellite regression: anomaly injection makes Adaptive
        // Correction learn strong per-bucket penalties against the
        // warm-up θ; the curriculum ramp then swaps the plan. The engine
        // resets the Eq-7 EMAs at the swap (see
        // `engine::exec::SingleReplicaExec::apply_plan`), so the adaptive
        // run must still replan and must not lose to the frozen plan in
        // the post-ramp steady state — with stale penalties carried
        // across the swap, the first post-replan schedules would be
        // biased by ratios measured under the old θ.
        let m = crate::model::catalog::internvl_25(
            crate::model::catalog::qwen25("7b"),
        );
        let mut cfg = RunConfig::new(2, 32, 22, 42);
        cfg.profile_samples = 256;
        cfg.replan = Some(crate::stream::replan::ReplanConfig {
            window_batches: 6,
            cooldown: 4,
            ..crate::stream::replan::ReplanConfig::default()
        });
        // Slow down a spread of LLM shape buckets so the tracker learns
        // real penalties during the warm-up phase.
        let mut ds = crate::data::dataset::Dataset::curriculum(42);
        let probe = ds.shaped_batch(&m, 256);
        let mut buckets: Vec<u64> = probe
            .iter()
            .map(|s| Truth::llm_bucket(s.llm_seq as f64))
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        cfg.injected = buckets.iter().step_by(4).map(|&b| (b, 0.6)).collect();
        let frozen = run_system(SystemKind::Dflop, &m, "curriculum", &cfg);
        let adaptive = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &cfg);
        assert!(
            adaptive.replans >= 1,
            "anomalous curriculum never swapped: {:?}",
            adaptive.replan_events
        );
        let steady = |r: &RunResult| {
            let tail = &r.iterations[r.iterations.len() - 4..];
            tail.iter().map(|s| s.iteration_time).sum::<f64>() / tail.len() as f64
        };
        assert!(
            steady(&adaptive) < steady(&frozen),
            "post-swap steady state regressed: adaptive {:.3}s vs frozen {:.3}s",
            steady(&adaptive),
            steady(&frozen)
        );
    }

    fn hetero_cfg(hetero: bool, rebalance: bool) -> RunConfig {
        let mut cfg = RunConfig::new(2, 64, 12, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig {
            rebalance,
            hetero,
            window_batches: 4,
            ..ShardConfig::default()
        });
        cfg
    }

    #[test]
    fn hetero_plans_beat_global_on_skewed_shards() {
        // The PR-5 acceptance scenario: graded video→image tilt across
        // four static shards (no migrations — the comparison isolates the
        // plans). InternVL's 6B encoder makes the encoder/LLM split
        // strongly distribution-dependent, so the video-heavy replica's
        // per-shard θ must strictly cut both the step time (it is the
        // barrier bottleneck) and the straggler gap.
        let m = crate::model::catalog::internvl_25(
            crate::model::catalog::qwen25("7b"),
        );
        let global = run_system(
            SystemKind::DflopSharded,
            &m,
            "skewed-shard",
            &hetero_cfg(false, false),
        );
        let hetero = run_system(
            SystemKind::DflopSharded,
            &m,
            "skewed-shard",
            &hetero_cfg(true, false),
        );
        assert!(
            !hetero.hetero_thetas.is_empty(),
            "skewed shards never triggered a per-shard fit"
        );
        assert_eq!(hetero.hetero_thetas.len(), 4);
        assert!(
            hetero.hetero_thetas.iter().any(|t| *t != global.theta),
            "per-shard fit only reproduced the global plan: {:?}",
            hetero.hetero_thetas
        );
        assert!(
            hetero.mean_iteration_time < global.mean_iteration_time,
            "per-replica plans did not beat the global θ*: {:.3}s vs {:.3}s",
            hetero.mean_iteration_time,
            global.mean_iteration_time
        );
        assert!(
            hetero.mean_straggler_gap() < global.mean_straggler_gap(),
            "straggler gap not reduced: {:.3}s vs {:.3}s",
            hetero.mean_straggler_gap(),
            global.mean_straggler_gap()
        );
        // Static sharding in both arms, and the global controller sees
        // the same merged stream — no migrations, same replan count.
        assert_eq!(hetero.migrations, 0);
        assert_eq!(global.migrations, 0);
        assert_eq!(hetero.replans, global.replans, "per-shard fits are not replans");
    }

    #[test]
    fn hetero_composes_with_rebalancing() {
        // The CLI default for `--hetero-plans` leaves rebalancing ON:
        // migrations are priced at the global θ in both arms (and the
        // global θ never changes here — skewed shards pool to a
        // stationary mixture), so the migration stream must be
        // bit-identical with hetero on or off, and per-replica plans must
        // not wreck the composed system. The strict plan-win comparison
        // lives in the static-sharding test above; this guards the
        // composition against interaction bugs.
        let m = llava_ov(llama3("8b"));
        let global = run_system(
            SystemKind::DflopSharded,
            &m,
            "skewed-shard",
            &{
                let mut c = hetero_cfg(false, true);
                c.nodes = 1;
                c
            },
        );
        let hetero = run_system(
            SystemKind::DflopSharded,
            &m,
            "skewed-shard",
            &{
                let mut c = hetero_cfg(true, true);
                c.nodes = 1;
                c
            },
        );
        assert_eq!(hetero.migrations, global.migrations, "migration stream diverged");
        assert_eq!(hetero.replans, global.replans);
        assert_eq!(hetero.straggler_gaps.len(), 12);
        assert!(hetero.straggler_gaps.iter().all(|g| g.is_finite() && *g >= 0.0));
        assert!(hetero.per_gpu_throughput > 0.0);
        // Per-shard plans only swap in on a strict predicted win for the
        // shard's (home-dominated) items, so the composed system must not
        // regress materially against the global plan.
        assert!(
            hetero.mean_iteration_time <= global.mean_iteration_time * 1.05,
            "hetero + rebalance regressed: {:.3}s vs {:.3}s",
            hetero.mean_iteration_time,
            global.mean_iteration_time
        );
    }

    #[test]
    fn hetero_homogeneous_is_bit_identical_to_global() {
        // Zero extra replans and bit-identical telemetry on homogeneous
        // shards: the skew gate never opens, so the per-shard policy must
        // leave the exact global code path untouched.
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(1, 64, 12, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig::default());
        let mut hcfg = cfg.clone();
        hcfg.shard = Some(ShardConfig { hetero: true, ..ShardConfig::default() });
        let global = run_system(SystemKind::DflopSharded, &m, "mixed", &cfg);
        let hetero = run_system(SystemKind::DflopSharded, &m, "mixed", &hcfg);
        assert!(hetero.hetero_thetas.is_empty(), "homogeneous shards fitted plans");
        assert_eq!(hetero.replans, 0);
        assert_eq!(
            hetero.per_gpu_throughput.to_bits(),
            global.per_gpu_throughput.to_bits(),
            "hetero mode changed a homogeneous run"
        );
        assert_eq!(
            hetero.mean_iteration_time.to_bits(),
            global.mean_iteration_time.to_bits()
        );
        assert_eq!(hetero.theta, global.theta);
        assert_eq!(hetero.migrations, global.migrations);
    }
}
