//! Iteration-level training simulation of complete systems.
//!
//! A [`run_system`] call plays one (system × model × dataset × cluster)
//! cell of the paper's evaluation: it performs the system's offline phase
//! (profiling + strategy selection), then simulates `iters` training
//! iterations — scheduling each global batch, executing it on the 1F1B
//! engine against the ground-truth cluster, and feeding measurements back
//! into Adaptive Correction — and aggregates the statistics every figure
//! consumes.

use crate::baselines::homogeneous::{
    megatron_tune, pytorch_tune, random_buckets, PYTORCH_SOFTWARE_FACTOR,
};
use crate::data::dataset::Dataset;
use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::plan::Theta;
use crate::optimizer::search::{optimize, OptimizerInputs};
use crate::perfmodel::{ClusterSpec, Truth};
use crate::pipeline::build::{iterate_ws, IterationStats, SystemPlan};
use crate::pipeline::sim::SimWorkspace;
use crate::profiling::backend::{MeasureBackend, SimBackend};
use crate::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use crate::profiling::estimator::Estimator;
use crate::scheduler::correction::{Correction, CorrectionConfig};
use crate::scheduler::lpt::ItemCost;
use crate::scheduler::online::{OnlineScheduler, SchedulerConfig, Solver};
use crate::shard::agg::{merge_shard_stats, ShardWindows};
use crate::shard::balance::rebalance;
use crate::shard::partition::ShardedDataset;
use crate::shard::sync::{
    cross_shard_allreduce, lpt_shard_buckets, simulate_shards, step_barrier, BarrierStats,
};
use crate::shard::ShardConfig;
use crate::stream::replan::{ReplanConfig, ReplanContext, ReplanEvent, Replanner};
use crate::stream::window::ShapeStats;
use crate::util::rng::Rng;
use std::time::Duration;

/// The systems compared in the evaluation (§5.1 baselines + §5.3.2
/// ablation variants + the streaming extension).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// Full DFLOP: data-aware optimizer + online scheduler + correction.
    Dflop,
    /// Full DFLOP plus the `stream` subsystem: drift detection over the
    /// live batch stream and warm-started replanning on confirmed drift.
    DflopAdaptive,
    /// Full DFLOP plus the `shard` subsystem: per-shard data streams,
    /// cross-shard rebalancing behind a distributional skew gate, the
    /// step barrier with straggler-gap telemetry, and *global* (merged)
    /// drift replanning. `RunConfig::shard` configures the shard layer;
    /// `rebalance: false` is the static-sharding baseline.
    DflopSharded,
    /// Ablation: data-aware optimizer, random microbatching.
    DflopOptimizerOnly,
    /// Ablation: baseline (Megatron) strategy, online scheduler.
    DflopSchedulerOnly,
    /// Megatron-LM-style baseline.
    Megatron,
    /// Plain-PyTorch-style baseline.
    Pytorch,
}

impl SystemKind {
    pub fn label(&self) -> &'static str {
        match self {
            SystemKind::Dflop => "DFLOP",
            SystemKind::DflopAdaptive => "DFLOP (adaptive)",
            SystemKind::DflopSharded => "DFLOP (sharded)",
            SystemKind::DflopOptimizerOnly => "DFLOP (optimizer only)",
            SystemKind::DflopSchedulerOnly => "DFLOP (scheduler only)",
            SystemKind::Megatron => "Megatron-LM",
            SystemKind::Pytorch => "PyTorch",
        }
    }
}

/// Parameters of one simulated run.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub nodes: usize,
    pub gbs: usize,
    pub iters: usize,
    pub seed: u64,
    /// Data Profiler sample count.
    pub profile_samples: usize,
    /// ILP time budget per scheduling call.
    pub ilp_budget: Duration,
    /// Disable Adaptive Correction (Fig 15 off-arm).
    pub disable_correction: bool,
    /// Anomaly injection for Fig 15: (shape-bucket, throughput factor).
    pub injected: Vec<(u64, f64)>,
    /// Stream-subsystem tuning for [`SystemKind::DflopAdaptive`] and
    /// [`SystemKind::DflopSharded`] runs (`None` =
    /// [`ReplanConfig::default`]); ignored by other systems.
    pub replan: Option<ReplanConfig>,
    /// Shard-layer tuning for [`SystemKind::DflopSharded`] runs (`None` =
    /// [`ShardConfig::default`]); ignored by other systems.
    pub shard: Option<ShardConfig>,
}

impl RunConfig {
    pub fn new(nodes: usize, gbs: usize, iters: usize, seed: u64) -> RunConfig {
        RunConfig {
            nodes,
            gbs,
            iters,
            seed,
            profile_samples: 512,
            ilp_budget: Duration::from_millis(50),
            disable_correction: false,
            injected: Vec::new(),
            replan: None,
            shard: None,
        }
    }
}

/// Aggregated outcome of a run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub system: SystemKind,
    pub theta: Theta,
    pub n_gpus: usize,
    /// Mean per-GPU achieved throughput (FLOP/s).
    pub per_gpu_throughput: f64,
    /// Mean iteration wall-clock (simulated seconds).
    pub mean_iteration_time: f64,
    /// Mean per-iteration total idle GPU-seconds (Fig 13).
    pub mean_idle: f64,
    /// Per-stage throughput samples pooled over iterations (Fig 14).
    pub stage_throughput_samples: Vec<f64>,
    /// Per-bucket module times pooled over iterations (Fig 4).
    pub bucket_enc_times: Vec<f64>,
    pub bucket_llm_times: Vec<f64>,
    /// Scheduling wall-clock per iteration (real, Fig 16b).
    pub sched_elapsed: Vec<Duration>,
    /// How often the ILP hit its limit and fell back to the incumbent.
    pub lpt_fallbacks: usize,
    /// Offline overheads (Table 4): model+data profiling, optimizer.
    pub profiling_seconds: f64,
    pub optimizer_elapsed: Duration,
    /// Confirmed drifts that swapped the plan (adaptive runs; 0 elsewhere
    /// — and 0 on stationary data is the no-thrash guarantee).
    pub replans: usize,
    /// Every confirmed drift, in iteration order (adaptive runs).
    pub replan_events: Vec<ReplanEvent>,
    /// Per-iteration cross-shard straggler gap — the slowest replica's
    /// lead over the fastest (sharded runs; empty elsewhere).
    pub straggler_gaps: Vec<f64>,
    /// Total items migrated across shards over the run (sharded runs;
    /// 0 elsewhere — and 0 on homogeneous shards is the quiet guarantee).
    pub migrations: usize,
    /// Full per-iteration stats for figure-specific postprocessing.
    pub iterations: Vec<IterationStats>,
}

impl RunResult {
    /// Speedup of `self` over `other` in per-GPU throughput.
    pub fn speedup_over(&self, other: &RunResult) -> f64 {
        self.per_gpu_throughput / other.per_gpu_throughput
    }

    /// Mean per-iteration straggler gap (0 for non-sharded runs).
    pub fn mean_straggler_gap(&self) -> f64 {
        if self.straggler_gaps.is_empty() {
            0.0
        } else {
            self.straggler_gaps.iter().sum::<f64>() / self.straggler_gaps.len() as f64
        }
    }
}

/// Materialize bucket index groups into item-shape buckets.
fn materialize(shapes: &[ItemShape], groups: &[Vec<usize>]) -> Vec<Vec<ItemShape>> {
    groups
        .iter()
        .map(|g| g.iter().map(|&i| shapes[i]).collect())
        .collect()
}

/// One independent (system × model × dataset × cluster) evaluation cell of
/// the paper's grid. Cells are self-contained — the model, dataset key,
/// and full [`RunConfig`] (cluster size included) travel with the cell —
/// so a batch of them can run on any worker in any order.
#[derive(Clone, Debug)]
pub struct Cell {
    pub kind: SystemKind,
    pub m: Mllm,
    pub dataset: String,
    pub cfg: RunConfig,
}

/// Evaluate a batch of cells on the `util::parallel` pool.
///
/// Results come back in cell order, and every cell is seeded from its own
/// `cfg.seed`, so the output is identical to calling [`run_system`] in a
/// serial loop — this is what lets the figure harness sweep a whole
/// (system × model × dataset) grid across all cores.
pub fn run_cells(cells: &[Cell]) -> Vec<RunResult> {
    crate::util::parallel::par_map(cells.len(), |i| {
        let c = &cells[i];
        run_system(c.kind, &c.m, &c.dataset, &c.cfg)
    })
}

/// Run one system on one workload.
pub fn run_system(
    kind: SystemKind,
    m: &Mllm,
    dataset_key: &str,
    cfg: &RunConfig,
) -> RunResult {
    if kind == SystemKind::DflopSharded {
        return run_sharded(m, dataset_key, cfg);
    }
    let cluster = ClusterSpec::hgx_a100(cfg.nodes);
    let mut truth = Truth::new(cluster);
    truth.injected = cfg.injected.clone();
    if kind == SystemKind::Pytorch {
        truth.software_factor = PYTORCH_SOFTWARE_FACTOR;
    }

    // ---- offline phase ----
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(cluster.gpus_per_node))
        .profile(m);
    let mut profile_ds = Dataset::by_key(dataset_key, cfg.seed ^ 0xDA7A)
        .unwrap_or_else(|| panic!("unknown dataset '{dataset_key}'"));
    let data = profile_data(m, &mut profile_ds, cfg.profile_samples);
    let profiling_seconds = backend.measured_seconds().max(data.profiling_seconds);

    let (mut theta, optimizer_elapsed) = match kind {
        SystemKind::Dflop | SystemKind::DflopAdaptive | SystemKind::DflopOptimizerOnly => {
            let inp = OptimizerInputs {
                m,
                profile: &profile,
                data: &data,
                n_gpus: cluster.total_gpus(),
                gpus_per_node: cluster.gpus_per_node,
                mem_capacity: cluster.gpu.mem_bytes,
                gbs: cfg.gbs,
                assume_balanced: kind != SystemKind::DflopOptimizerOnly,
            };
            let r = optimize(&inp).expect("no feasible DFLOP configuration");
            (r.theta, r.elapsed)
        }
        SystemKind::DflopSchedulerOnly | SystemKind::Megatron => {
            let c = megatron_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible Megatron configuration");
            (c.theta, Duration::ZERO)
        }
        SystemKind::Pytorch => {
            let c = pytorch_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible PyTorch configuration");
            (c.theta, Duration::ZERO)
        }
    };

    // ---- online phase ----
    let est = Estimator::new(m, &profile.throughput);
    let uses_scheduler = matches!(
        kind,
        SystemKind::Dflop | SystemKind::DflopAdaptive | SystemKind::DflopSchedulerOnly
    );
    let mut correction_cfg = CorrectionConfig::default();
    if cfg.disable_correction {
        // A zero-benefit window of one iteration deactivates immediately.
        correction_cfg.window = 1;
        correction_cfg.cost_fraction = f64::INFINITY;
    }
    let mut scheduler = OnlineScheduler::new(
        theta,
        SchedulerConfig { ilp_budget: cfg.ilp_budget },
        Correction::new(correction_cfg),
    );

    let mut ds = Dataset::by_key(dataset_key, cfg.seed).expect("dataset");
    let mut rng = Rng::new(cfg.seed ^ 0xB0CC);

    // Stream subsystem: window + drift detector + warm-replan controller,
    // seeded with the offline Data Profiler output as the reference
    // distribution (the contract θ* was optimized against).
    let mut replanner = if kind == SystemKind::DflopAdaptive {
        Some(Replanner::new(
            &data,
            theta,
            cfg.replan.clone().unwrap_or_default(),
        ))
    } else {
        None
    };
    let rctx = ReplanContext {
        m,
        profile: &profile,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: cfg.gbs,
    };

    // One simulation workspace per run (= per pool worker task): every
    // iteration's route build + 1F1B execution reuses the same arena.
    let mut sim_ws = SimWorkspace::new();
    let mut iterations = Vec::with_capacity(cfg.iters);
    let mut sched_elapsed = Vec::with_capacity(cfg.iters);
    let mut lpt_fallbacks = 0usize;
    let mut stage_thr_samples = Vec::new();
    let mut bucket_enc_times = Vec::new();
    let mut bucket_llm_times = Vec::new();

    for _ in 0..cfg.iters {
        let shapes = ds.shaped_batch(m, cfg.gbs);

        // Drift check before scheduling: the batch's shapes are known to
        // the CPU-side scheduler ahead of execution, and a confirmed
        // drift swaps the plan at this iteration boundary.
        if let Some(rp) = replanner.as_mut() {
            if let Some(new_theta) = rp.observe_batch(&rctx, &shapes) {
                theta = new_theta;
                scheduler.theta = new_theta;
            }
        }
        let plan = SystemPlan { m, truth: &truth, theta };

        let buckets: Vec<Vec<ItemShape>> = if uses_scheduler {
            let sched = scheduler.schedule(&est, &shapes);
            sched_elapsed.push(sched.elapsed);
            if sched.solver == Solver::LptFallback {
                lpt_fallbacks += 1;
            }
            materialize(&shapes, &sched.assignment.buckets)
        } else {
            let t0 = std::time::Instant::now();
            let b = random_buckets(&shapes, theta.buckets(), &mut rng);
            sched_elapsed.push(t0.elapsed());
            b
        };

        let stats = iterate_ws(&plan, &buckets, &mut sim_ws);

        // ---- Adaptive Correction feedback (Eq 7) ----
        if uses_scheduler && scheduler.correction.is_active() {
            let mut observations = Vec::new();
            let mut mispredicted = 0.0;
            let l_layers = m.llm.layers as f64;
            for bucket in &buckets {
                let total: f64 = bucket.iter().map(|i| i.llm_seq as f64).sum();
                if total <= 0.0 {
                    continue;
                }
                for item in bucket {
                    let seq = item.llm_seq as f64;
                    if seq <= 0.0 {
                        continue;
                    }
                    // Observed per-item time: the coordinator times the
                    // per-instance attention kernels and apportions the
                    // packed linear time by token share.
                    let lin_share = truth
                        .llm_linear_time(m, total, l_layers, theta.llm.tp)
                        * seq
                        / total;
                    let attn = truth.llm_attn_time(m, seq, l_layers, theta.llm.tp);
                    let actual = lin_share + attn;
                    let pred = est.llm_item_dur(item, theta.llm.tp);
                    let flop = item.llm_flop(m);
                    observations.push((
                        Truth::llm_bucket(seq),
                        flop / actual,
                        flop / pred,
                    ));
                    mispredicted += (actual - pred).abs() / theta.llm.pp as f64;
                }
            }
            let benefit = mispredicted
                / (stats.buckets.len().max(1) as f64)
                / stats.pipeline_makespan.max(1e-12);
            scheduler.feedback(&observations, benefit);
        }

        stage_thr_samples.extend(stats.stage_throughputs());
        for b in &stats.buckets {
            if b.enc_time > 0.0 {
                bucket_enc_times.push(b.enc_time);
            }
            if b.llm_time > 0.0 {
                bucket_llm_times.push(b.llm_time);
            }
        }
        iterations.push(stats);
    }

    let n = iterations.len().max(1) as f64;
    let mean_iter = iterations.iter().map(|s| s.iteration_time).sum::<f64>() / n;
    let mean_idle = iterations.iter().map(|s| s.total_idle()).sum::<f64>() / n;
    let mean_thr = iterations
        .iter()
        .map(|s| s.cluster_throughput())
        .sum::<f64>()
        / n;

    let (replans, replan_events) = match replanner {
        Some(rp) => (rp.swaps(), rp.events),
        None => (0, Vec::new()),
    };

    RunResult {
        system: kind,
        theta,
        n_gpus: cluster.total_gpus(),
        per_gpu_throughput: mean_thr / cluster.total_gpus() as f64,
        mean_iteration_time: mean_iter,
        mean_idle,
        stage_throughput_samples: stage_thr_samples,
        bucket_enc_times,
        bucket_llm_times,
        sched_elapsed,
        lpt_fallbacks,
        profiling_seconds,
        optimizer_elapsed,
        replans,
        replan_events,
        straggler_gaps: Vec::new(),
        migrations: 0,
        iterations,
    }
}

/// Combine one step's per-replica iteration stats into a cluster-level
/// view: stage arrays concatenate in shard order, idle is charged against
/// the slowest replica's pipeline (straggler wait shows up as idle on the
/// fast replicas), and the iteration time is the barrier's step time.
/// Per-op timelines are dropped — an S-replica timeline has no single
/// 1F1B rendering.
fn merge_shard_iterations(per: Vec<IterationStats>, barrier: &BarrierStats) -> IterationStats {
    let pipeline_max = per.iter().map(|s| s.pipeline_makespan).fold(0.0, f64::max);
    let n_stages = per.iter().map(|s| s.n_stages).sum();
    let mut stage_busy = Vec::with_capacity(n_stages);
    let mut stage_flop = Vec::with_capacity(n_stages);
    let mut buckets = Vec::new();
    let mut total_flop = 0.0;
    for s in per {
        stage_busy.extend(s.stage_busy);
        stage_flop.extend(s.stage_flop);
        buckets.extend(s.buckets);
        total_flop += s.total_flop;
    }
    let stage_idle = stage_busy.iter().map(|&b| pipeline_max - b).collect();
    IterationStats {
        iteration_time: barrier.step_time,
        pipeline_makespan: pipeline_max,
        dp_sync_time: barrier.step_time - pipeline_max,
        stage_busy,
        stage_idle,
        stage_flop,
        n_stages,
        total_flop,
        buckets,
        timeline: Vec::new(),
    }
}

/// [`run_system`] for [`SystemKind::DflopSharded`]: S data-parallel
/// replicas of the per-replica plan θ*, each drawing from its own shard
/// dataset (`shard::partition`), synchronized by the step barrier
/// (`shard::sync`). Per iteration:
///
/// 1. per-shard batches are summarized and merged (`shard::agg`) — one
///    *global* drift detector watches the pooled window and, on confirmed
///    drift, one warm-started replan swaps θ for every replica at the
///    iteration boundary;
/// 2. the skew gate scores each shard's window against the pooled window;
///    at or above `skew_enter` (and with `rebalance` on) the bounded
///    migration walk (`shard::balance`) redistributes the global batch on
///    predicted per-item cost;
/// 3. every replica LPT-partitions its items and runs its own 1F1B sim,
///    fanned over the worker pool in shard order; the step time is the
///    slowest replica plus the cross-shard allreduce.
///
/// The whole path is budget-free (no ILP deadline), so every statistic is
/// bit-identical across `--threads` settings.
fn run_sharded(m: &Mllm, scenario: &str, cfg: &RunConfig) -> RunResult {
    let sc = cfg.shard.clone().unwrap_or_default();
    let shards = sc.dp_shards;
    assert!(shards >= 1, "sharded run needs at least one shard");
    assert!(
        cfg.gbs >= shards,
        "per-shard batch must be non-empty: gbs {} < {} shards",
        cfg.gbs,
        shards
    );
    // `cfg.nodes` sizes one replica; the deployment is `shards` replicas.
    let cluster = ClusterSpec::hgx_a100(cfg.nodes);
    let mut truth = Truth::new(cluster);
    // Fig-15-style anomaly injection applies to every replica (they share
    // the ground-truth cluster model).
    truth.injected = cfg.injected.clone();

    // ---- offline phase: model profile + pooled data profile + θ* ----
    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(cluster.gpus_per_node))
        .profile(m);
    let mut profile_sd = ShardedDataset::by_key(scenario, shards, cfg.seed ^ 0xDA7A)
        .unwrap_or_else(|| panic!("unknown shard scenario '{scenario}'"));
    let data = profile_sd.profile_pooled(m, cfg.profile_samples);
    let profiling_seconds = backend.measured_seconds().max(data.profiling_seconds);

    // θ* sizes one replica: per-replica GBS (ceil so memory is checked
    // against the largest shard after remainder distribution). As
    // everywhere else, Eq 4–5 prices activations at the *mean* shape — a
    // skewed shard's heavy batches exceed that mean under static sharding
    // already, and the rebalance walk only tightens this envelope: it
    // never raises any replica's predicted load above the static
    // bottleneck (accepted moves keep every touched shard strictly below
    // the current maximum), and per-bucket memory scales with
    // load / bucket count, not raw item count.
    let rctx = ReplanContext {
        m,
        profile: &profile,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: cfg.gbs.div_ceil(shards),
    };
    let r0 = optimize(&rctx.inputs(&data)).expect("no feasible sharded configuration");
    let (mut theta, optimizer_elapsed) = (r0.theta, r0.elapsed);

    // ---- online phase ----
    let est = Estimator::new(m, &profile.throughput);
    let mut sd = ShardedDataset::by_key(scenario, shards, cfg.seed).expect("scenario");
    let counts = ShardedDataset::split_counts(cfg.gbs, shards);
    let mut replanner =
        Replanner::new(&data, theta, cfg.replan.clone().unwrap_or_default());
    let mut gate = ShardWindows::new(shards, sc.window_batches);

    let mut iterations = Vec::with_capacity(cfg.iters);
    let mut sched_elapsed = Vec::with_capacity(cfg.iters);
    let mut straggler_gaps = Vec::with_capacity(cfg.iters);
    let mut migrations = 0usize;
    let mut stage_thr_samples = Vec::new();
    let mut bucket_enc_times = Vec::new();
    let mut bucket_llm_times = Vec::new();

    for _ in 0..cfg.iters {
        let shard_batches = sd.shard_batches(m, &counts);

        // Global drift: merge the per-shard summaries (bit-identical to a
        // pooled recompute) and let ONE detector/replanner see the step.
        let per_stats: Vec<ShapeStats> =
            shard_batches.iter().map(|b| ShapeStats::of_batch(b)).collect();
        let merged = merge_shard_stats(&per_stats);
        let pooled: Vec<ItemShape> =
            shard_batches.iter().flat_map(|b| b.iter().copied()).collect();
        if let Some(new_theta) = replanner.observe_stats(&rctx, merged, &pooled) {
            theta = new_theta;
        }
        gate.push(per_stats);

        let t0 = std::time::Instant::now();
        // Skew gate + bounded migration on predicted per-item cost at θ.
        let home: Vec<usize> = shard_batches
            .iter()
            .enumerate()
            .flat_map(|(r, b)| std::iter::repeat(r).take(b.len()))
            .collect();
        let groups: Vec<Vec<usize>> = if sc.rebalance && gate.skewed(sc.skew_enter) {
            let items: Vec<ItemCost> = pooled
                .iter()
                .map(|s| ItemCost {
                    enc: est.enc_item_dur(s, theta.enc.tp) / theta.enc.pp as f64,
                    llm: est.llm_item_dur(s, theta.llm.tp) / theta.llm.pp as f64,
                })
                .collect();
            let rb = rebalance(&items, &home, shards, &sc.balance);
            migrations += rb.migrations;
            rb.groups(shards)
        } else {
            // Static sharding: every item executes where it was drawn.
            let mut g: Vec<Vec<usize>> = vec![Vec::new(); shards];
            for (i, &r) in home.iter().enumerate() {
                g[r].push(i);
            }
            g
        };

        // Per-replica LPT microbatching, then the replica fan-out.
        let shard_buckets: Vec<Vec<Vec<ItemShape>>> = groups
            .iter()
            .map(|g| {
                let shapes: Vec<ItemShape> = g.iter().map(|&i| pooled[i]).collect();
                lpt_shard_buckets(&est, theta, &shapes)
            })
            .collect();
        sched_elapsed.push(t0.elapsed());

        let per_replica = simulate_shards(m, &truth, theta, &shard_buckets);
        let barrier = step_barrier(
            per_replica.iter().map(|s| s.iteration_time).collect(),
            cross_shard_allreduce(m, &truth, theta, shards),
        );
        straggler_gaps.push(barrier.straggler_gap);
        let stats = merge_shard_iterations(per_replica, &barrier);

        stage_thr_samples.extend(stats.stage_throughputs());
        for b in &stats.buckets {
            if b.enc_time > 0.0 {
                bucket_enc_times.push(b.enc_time);
            }
            if b.llm_time > 0.0 {
                bucket_llm_times.push(b.llm_time);
            }
        }
        iterations.push(stats);
    }

    let n = iterations.len().max(1) as f64;
    let mean_iter = iterations.iter().map(|s| s.iteration_time).sum::<f64>() / n;
    let mean_idle = iterations.iter().map(|s| s.total_idle()).sum::<f64>() / n;
    let mean_thr = iterations
        .iter()
        .map(|s| s.cluster_throughput())
        .sum::<f64>()
        / n;
    let n_gpus = cluster.total_gpus() * shards;

    RunResult {
        system: SystemKind::DflopSharded,
        theta,
        n_gpus,
        per_gpu_throughput: mean_thr / n_gpus as f64,
        mean_iteration_time: mean_iter,
        mean_idle,
        stage_throughput_samples: stage_thr_samples,
        bucket_enc_times,
        bucket_llm_times,
        sched_elapsed,
        lpt_fallbacks: 0,
        profiling_seconds,
        optimizer_elapsed,
        replans: replanner.swaps(),
        replan_events: replanner.events,
        straggler_gaps,
        migrations,
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};

    fn quick_cfg() -> RunConfig {
        let mut c = RunConfig::new(1, 32, 3, 42);
        c.profile_samples = 256;
        c
    }

    #[test]
    fn dflop_beats_baselines_on_mixed_workload() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let dflop = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let mega = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let torch = run_system(SystemKind::Pytorch, &m, "mixed", &cfg);
        assert!(
            dflop.speedup_over(&mega) > 1.0,
            "DFLOP {:.3e} vs Megatron {:.3e}",
            dflop.per_gpu_throughput,
            mega.per_gpu_throughput
        );
        assert!(
            dflop.speedup_over(&torch) > 1.0,
            "DFLOP {:.3e} vs PyTorch {:.3e}",
            dflop.per_gpu_throughput,
            torch.per_gpu_throughput
        );
    }

    #[test]
    fn ablations_land_between_baseline_and_full() {
        // Fig 10's structure: PyTorch ≤ Megatron ≤ {optimizer-only,
        // scheduler-only} ≤ full DFLOP (small tolerance for sim noise).
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(2, 64, 3, 42);
        cfg.profile_samples = 256;
        let full = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let opt_only = run_system(SystemKind::DflopOptimizerOnly, &m, "mixed", &cfg);
        let sched_only = run_system(SystemKind::DflopSchedulerOnly, &m, "mixed", &cfg);
        let mega = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let torch = run_system(SystemKind::Pytorch, &m, "mixed", &cfg);
        assert!(mega.per_gpu_throughput >= torch.per_gpu_throughput * 0.98);
        assert!(opt_only.per_gpu_throughput >= mega.per_gpu_throughput * 0.95);
        assert!(sched_only.per_gpu_throughput >= mega.per_gpu_throughput * 0.95);
        assert!(full.per_gpu_throughput >= opt_only.per_gpu_throughput * 0.95);
        assert!(full.per_gpu_throughput >= sched_only.per_gpu_throughput * 0.95);
    }

    #[test]
    fn run_produces_complete_statistics() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let r = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        assert_eq!(r.iterations.len(), 3);
        assert_eq!(r.sched_elapsed.len(), 3);
        assert!(!r.stage_throughput_samples.is_empty());
        assert!(!r.bucket_llm_times.is_empty());
        assert!(r.profiling_seconds > 0.0);
        assert!(r.per_gpu_throughput > 0.0);
        assert!(r.per_gpu_throughput < 312e12, "exceeds peak");
    }

    #[test]
    fn deterministic_given_seed() {
        let m = llava_ov(llama3("8b"));
        let cfg = quick_cfg();
        let a = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        let b = run_system(SystemKind::Megatron, &m, "mixed", &cfg);
        assert_eq!(a.per_gpu_throughput, b.per_gpu_throughput);
        assert_eq!(a.theta, b.theta);
    }

    #[test]
    fn adaptive_never_replans_on_stationary_data() {
        // The no-thrash guarantee: on the stationary mixed workload the
        // drift detector must not fire a single replan over a run several
        // windows long, and the adaptive system ends on the offline θ*.
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(1, 32, 14, 42);
        cfg.profile_samples = 256;
        let frozen = run_system(SystemKind::Dflop, &m, "mixed", &cfg);
        let adaptive = run_system(SystemKind::DflopAdaptive, &m, "mixed", &cfg);
        assert_eq!(adaptive.replans, 0, "replanned on stationary data");
        assert!(
            adaptive.replan_events.is_empty(),
            "drift fired on stationary data: {:?}",
            adaptive.replan_events
        );
        assert_eq!(adaptive.theta, frozen.theta);
    }

    fn sharded_cfg(rebalance: bool) -> RunConfig {
        let mut cfg = RunConfig::new(1, 64, 14, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig { rebalance, ..ShardConfig::default() });
        cfg
    }

    #[test]
    fn sharded_rebalance_beats_static_on_skewed_shards() {
        // The acceptance scenario: a graded video→image tilt across four
        // DP shards. Static sharding pays the video-heavy replica's
        // makespan at every barrier; the rebalancer must migrate work,
        // cut the simulated step time, and shrink the straggler gap.
        let m = llava_ov(llama3("8b"));
        let stat = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &sharded_cfg(false));
        let rebal = run_system(SystemKind::DflopSharded, &m, "skewed-shard", &sharded_cfg(true));
        assert_eq!(stat.migrations, 0, "static baseline must not migrate");
        assert!(rebal.migrations > 0, "skew never activated the balancer");
        assert!(
            rebal.mean_iteration_time < stat.mean_iteration_time,
            "rebalanced step {:.3}s not below static {:.3}s",
            rebal.mean_iteration_time,
            stat.mean_iteration_time
        );
        assert!(
            rebal.mean_straggler_gap() < stat.mean_straggler_gap(),
            "straggler gap not reduced: {:.3}s vs {:.3}s",
            rebal.mean_straggler_gap(),
            stat.mean_straggler_gap()
        );
        assert!(rebal.speedup_over(&stat) > 1.0);
        // Telemetry shape: one gap per iteration, all finite.
        assert_eq!(rebal.straggler_gaps.len(), 14);
        assert!(rebal.straggler_gaps.iter().all(|g| g.is_finite() && *g >= 0.0));
    }

    #[test]
    fn sharded_homogeneous_shards_are_quiet() {
        // The quiet guarantee: statistically identical shards must see
        // zero migrations and zero global replans, making the full system
        // bit-identical to the static baseline.
        let m = llava_ov(llama3("8b"));
        let stat = run_system(SystemKind::DflopSharded, &m, "mixed", &sharded_cfg(false));
        let rebal = run_system(SystemKind::DflopSharded, &m, "mixed", &sharded_cfg(true));
        assert_eq!(rebal.migrations, 0, "homogeneous shards migrated");
        assert_eq!(rebal.replans, 0, "homogeneous shards replanned");
        assert!(rebal.replan_events.is_empty());
        assert_eq!(
            rebal.per_gpu_throughput.to_bits(),
            stat.per_gpu_throughput.to_bits(),
            "quiet sharded run must equal static sharding exactly"
        );
        assert_eq!(rebal.theta, stat.theta);
    }

    #[test]
    fn sharded_accounting_is_complete() {
        let m = llava_ov(llama3("8b"));
        let mut cfg = RunConfig::new(1, 32, 3, 42);
        cfg.profile_samples = 256;
        cfg.shard = Some(ShardConfig { dp_shards: 4, ..ShardConfig::default() });
        let r = run_system(SystemKind::DflopSharded, &m, "laggard-shard", &cfg);
        assert_eq!(r.n_gpus, 8 * 4, "4 replicas of one 8-GPU node");
        assert_eq!(r.iterations.len(), 3);
        assert_eq!(r.straggler_gaps.len(), 3);
        // The laggard makes the gap strictly positive from the start.
        assert!(r.straggler_gaps.iter().all(|&g| g > 0.0));
        assert!(r.per_gpu_throughput > 0.0);
        assert!(r.per_gpu_throughput < 312e12, "exceeds peak");
        // Stage accounting concatenates all replicas.
        let stages_per_replica = r.theta.enc.gpus() / r.theta.enc.tp
            + r.theta.llm.gpus() / r.theta.llm.tp;
        assert_eq!(r.iterations[0].n_stages, 4 * stages_per_replica);
        // FLOP conservation across the merged view.
        let s = &r.iterations[0];
        let sum: f64 = s.stage_flop.iter().sum();
        assert!((sum / s.total_flop - 1.0).abs() < 1e-9);
    }

    #[test]
    fn adaptive_replans_and_beats_frozen_on_curriculum() {
        // The acceptance scenario: a curriculum text→video ramp. The
        // frozen θ* was fitted to the image-heavy warm-up phase; the
        // adaptive system must detect the ramp, swap plans at least once,
        // and end the run with measurably higher mean throughput.
        // InternVL's 6B encoder makes the encoder/LLM GPU split strongly
        // distribution-dependent, so a stale split is expensive.
        let m = crate::model::catalog::internvl_25(
            crate::model::catalog::qwen25("7b"),
        );
        let mut cfg = RunConfig::new(2, 32, 22, 42);
        cfg.profile_samples = 256;
        // A slightly quicker cadence than the defaults so the run reaches
        // a fully video-fitted plan (second replan) with iterations to
        // spare before the steady-state comparison window.
        cfg.replan = Some(crate::stream::replan::ReplanConfig {
            window_batches: 6,
            cooldown: 4,
            ..crate::stream::replan::ReplanConfig::default()
        });
        let frozen = run_system(SystemKind::Dflop, &m, "curriculum", &cfg);
        let adaptive = run_system(SystemKind::DflopAdaptive, &m, "curriculum", &cfg);
        assert!(
            adaptive.replans >= 1,
            "curriculum ramp never triggered a plan swap: {:?}",
            adaptive.replan_events
        );
        // Post-ramp steady state (the last 4 iterations are firmly in the
        // video-dominated phase and past the swaps): the adapted plan must
        // be measurably faster than the frozen one.
        let steady = |r: &RunResult| {
            let tail = &r.iterations[r.iterations.len() - 4..];
            tail.iter().map(|s| s.iteration_time).sum::<f64>() / tail.len() as f64
        };
        let gain = steady(&frozen) / steady(&adaptive);
        assert!(
            gain > 1.02,
            "adaptive steady-state {:.3}s not measurably below frozen {:.3}s (gain {gain:.3})",
            steady(&adaptive),
            steady(&frozen)
        );
        // Whole-run throughput must not regress either (pre-drift
        // iterations are identical plans).
        assert!(
            adaptive.speedup_over(&frozen) > 0.99,
            "adaptive lost overall: {:.3e} vs {:.3e}",
            adaptive.per_gpu_throughput,
            frozen.per_gpu_throughput
        );
        // The swap happened after the ramp began and changed the plan.
        let first = adaptive.replan_events.iter().find(|e| e.swapped).expect("swap");
        assert!(first.iteration >= 7, "swapped before the ramp: {first:?}");
        assert_ne!(first.old, first.new);
    }
}
