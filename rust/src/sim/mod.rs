//! Iteration-level training simulation of complete systems (DFLOP,
//! ablations, baselines) over the ground-truth cluster, plus the parallel
//! evaluation-grid substrate the figure harness sweeps with. The run
//! machinery itself lives behind `crate::engine`'s policy/executor seams;
//! this module keeps the run vocabulary and entry points.
pub mod trainer;

pub use trainer::{run_cells, run_system, Cell, FaultConfig, RunConfig, RunResult, SystemKind};
