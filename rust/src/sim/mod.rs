//! Iteration-level training simulation of complete systems (DFLOP,
//! ablations, baselines) over the ground-truth cluster.
pub mod trainer;

pub use trainer::{run_system, RunConfig, RunResult, SystemKind};
