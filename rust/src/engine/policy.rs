//! Plan selection behind one seam: who decides which θ executes next?
//!
//! Every system the trainer simulates answers that question differently —
//! a frozen offline θ* (baselines, ablations, plain DFLOP), a global
//! drift-adaptive θ (`stream::replan`, fed either a single batch or the
//! merged per-shard summaries), or the heterogeneous per-replica plans of
//! the sharded hetero mode — but the engine loop only ever asks one
//! question per iteration: *given this draw, did the plan change at this
//! boundary?* [`PlanPolicy`] is that question; the executors
//! (`engine::exec`) consume whatever [`PlanSet`] comes back.
//!
//! Policies observe the draw **before** it is scheduled, so a swap lands
//! on the iteration boundary just crossed — the contract `stream::replan`
//! documents and `sim::trainer` always implemented inline.

use crate::engine::hetero::{assign_plans, fit_per_shard};
use crate::engine::Draw;
use crate::optimizer::plan::Theta;
use crate::profiling::engine::DataProfile;
use crate::profiling::estimator::Estimator;
use crate::shard::agg::{merge_shard_stats, ShardWindows};
use crate::shard::ShardConfig;
use crate::stream::drift::Decision;
use crate::stream::replan::{ReplanConfig, ReplanContext, ReplanEvent, Replanner};
use crate::stream::reservoir::ShapeReservoir;

/// Map a drift detector's decision to the recorder's phase vocabulary.
fn phase_of(d: Option<Decision>) -> Option<&'static str> {
    d.map(|d| match d {
        Decision::Stable => "stable",
        Decision::Watch => "watch",
        Decision::Drift => "drift",
    })
}

/// The plan a policy hands the executor for one iteration.
#[derive(Clone, Debug)]
pub struct PlanSet {
    /// The global θ — scheduling reference frame, allreduce sizing, and
    /// what `RunResult::theta` reports.
    pub global: Theta,
    /// Per-replica overrides (heterogeneous sharded runs); `None` means
    /// every replica runs `global`.
    pub per_replica: Option<Vec<Theta>>,
}

impl PlanSet {
    pub fn global(theta: Theta) -> PlanSet {
        PlanSet { global: theta, per_replica: None }
    }

    /// Shard r's effective θ.
    pub fn replica_theta(&self, r: usize) -> Theta {
        match &self.per_replica {
            Some(ts) => ts[r],
            None => self.global,
        }
    }
}

/// One iteration's plan decision, observed ahead of scheduling.
pub trait PlanPolicy {
    /// Feed the iteration's draw; `Some` when the plan changed at this
    /// boundary (the executor applies it to this draw and everything
    /// after).
    fn observe(&mut self, draw: &Draw) -> Option<PlanSet>;

    /// Drain the confirmed-drift event log (call once, at run end).
    fn take_events(&mut self) -> Vec<ReplanEvent> {
        Vec::new()
    }

    /// The fault layer's *confirmed* (debounced) active-member count for
    /// this iteration, reported ahead of `observe`. Default no-op:
    /// health-blind policies plan for the configured topology forever.
    fn observe_health(&mut self, _confirmed_active: usize) {}

    /// The drift detector's phase after this iteration's `observe`
    /// (`stable`/`watch`/`drift`), for the observability recorder.
    /// `None` — the default — for policies without a detector.
    fn drift_phase(&self) -> Option<&'static str> {
        None
    }
}

/// The offline θ* frozen for the whole run (baselines, ablations, plain
/// DFLOP, and the static-plan arm of every comparison).
pub struct StaticPolicy;

impl PlanPolicy for StaticPolicy {
    fn observe(&mut self, _draw: &Draw) -> Option<PlanSet> {
        None
    }
}

/// One global drift-adaptive plan (`stream::replan`): single-replica runs
/// feed it whole batches, sharded runs feed it the merged per-shard
/// summaries — so a DP group fires exactly one global replan, never S.
pub struct AdaptivePolicy<'a> {
    rp: Replanner,
    rctx: ReplanContext<'a>,
}

impl<'a> AdaptivePolicy<'a> {
    /// `reference` is the offline Data Profiler output θ* was fitted to.
    pub fn new(
        reference: &DataProfile,
        theta: Theta,
        cfg: ReplanConfig,
        rctx: ReplanContext<'a>,
    ) -> AdaptivePolicy<'a> {
        AdaptivePolicy { rp: Replanner::new(reference, theta, cfg), rctx }
    }
}

impl PlanPolicy for AdaptivePolicy<'_> {
    fn observe(&mut self, draw: &Draw) -> Option<PlanSet> {
        let new = match draw {
            Draw::Single(shapes) => self.rp.observe_batch(&self.rctx, shapes),
            Draw::Sharded { stats, pooled, .. } => {
                self.rp.observe_stats(&self.rctx, merge_shard_stats(stats), pooled)
            }
        };
        new.map(PlanSet::global)
    }

    fn take_events(&mut self) -> Vec<ReplanEvent> {
        std::mem::take(&mut self.rp.events)
    }

    fn drift_phase(&self) -> Option<&'static str> {
        phase_of(self.rp.drift_decision())
    }
}

/// The fault-aware sharded controller: the drift-adaptive global plan
/// plus topology replans. Data drift runs through the exact
/// `AdaptivePolicy` path (merged per-shard summaries into one
/// `stream::replan` controller), so a fault-free run is bit-identical to
/// the plain adaptive sharded policy. When the fault layer *confirms* a
/// changed active-member count (debounced like drift confirmation, so
/// transient blips never reach here), the per-replica batch the
/// surviving replicas actually execute has changed — the policy
/// warm-replans θ* for the new topology via the replanner's
/// `force_replan`, which shares the drift path's event log, cooldown,
/// and failed-refit retry contract.
pub struct FaultAwarePolicy<'a> {
    rp: Replanner,
    /// Context template for the *full* configured membership; only the
    /// per-replica GBS changes with the active-member count.
    rctx: ReplanContext<'a>,
    /// The run's global batch size (split over the active members).
    gbs: usize,
    /// The membership the live θ was fitted for.
    fitted_active: usize,
    /// The fault layer's confirmed membership this iteration.
    confirmed_active: usize,
}

impl<'a> FaultAwarePolicy<'a> {
    /// `rctx` is the engine's sharded replan context (per-replica GBS at
    /// full membership); `gbs` the global batch; `shards` the configured
    /// DP group size.
    pub fn new(
        reference: &DataProfile,
        theta: Theta,
        cfg: ReplanConfig,
        rctx: ReplanContext<'a>,
        gbs: usize,
        shards: usize,
    ) -> FaultAwarePolicy<'a> {
        FaultAwarePolicy {
            rp: Replanner::new(reference, theta, cfg),
            rctx,
            gbs,
            fitted_active: shards,
            confirmed_active: shards,
        }
    }

    /// The replan context for an `active`-member group: same cluster and
    /// profile, per-replica GBS re-split over the survivors (ceil, so
    /// memory is checked against the largest shard — mirroring the
    /// offline sharded fit).
    fn ctx_at(&self, active: usize) -> ReplanContext<'a> {
        ReplanContext { gbs: self.gbs.div_ceil(active.max(1)), ..self.rctx }
    }
}

impl PlanPolicy for FaultAwarePolicy<'_> {
    fn observe(&mut self, draw: &Draw) -> Option<PlanSet> {
        let Draw::Sharded { stats, pooled, .. } = draw else {
            unreachable!("fault-aware policy fed a single-replica draw")
        };
        // Drift first, against the topology the live plan was fitted
        // for — byte-for-byte the AdaptivePolicy path while the fleet
        // stays at full strength.
        let ctx = self.ctx_at(self.fitted_active);
        if let Some(new) = self.rp.observe_stats(&ctx, merge_shard_stats(stats), pooled) {
            return Some(PlanSet::global(new));
        }
        // A confirmed topology change re-sizes the per-replica batch:
        // warm-replan θ* for the surviving group. One forced refit per
        // confirmed change — the optimizer keeping the incumbent (or
        // failing, which enters the bounded-retry contract) still counts
        // as planned-for, so the fleet doesn't refit every iteration.
        if self.confirmed_active != self.fitted_active {
            let iteration = self.rp.iterations_observed().saturating_sub(1);
            let ctx = self.ctx_at(self.confirmed_active);
            let swap = self.rp.force_replan(&ctx, iteration);
            self.fitted_active = self.confirmed_active;
            return swap.map(PlanSet::global);
        }
        None
    }

    fn take_events(&mut self) -> Vec<ReplanEvent> {
        std::mem::take(&mut self.rp.events)
    }

    fn observe_health(&mut self, confirmed_active: usize) {
        self.confirmed_active = confirmed_active;
    }

    fn drift_phase(&self) -> Option<&'static str> {
        phase_of(self.rp.drift_decision())
    }
}

/// Heterogeneous per-replica plans on top of the global controller
/// (`engine::hetero`): the global `stream::replan` drift loop is retained
/// unchanged, and per-shard θ_s are fitted from each shard's own recent
/// shapes once the `shard::agg` skew gate confirms the shards really
/// differ — so homogeneous shards never fit (zero extra replans, and the
/// run stays bit-identical to the global plan).
pub struct PerShardPolicy<'a> {
    global: Replanner,
    rctx: ReplanContext<'a>,
    est: &'a Estimator<'a>,
    /// The policy's own skew view — deliberately a second copy of the
    /// executor's rebalance gate rather than a reference across the
    /// seam: both are built from the same `ShardConfig` and fed the same
    /// draws, so they agree by construction, and the duplicate merge
    /// cost is a few hundred integer adds per iteration.
    windows: ShardWindows,
    /// Per-shard recent shapes, the refit corpus for θ_s.
    reservoirs: Vec<ShapeReservoir>,
    skew_enter: f64,
    /// Assigned per-replica plans; `None` while (or whenever) every shard
    /// is best served by the global θ.
    fitted: Option<Vec<Theta>>,
    /// Iterations before the next fit attempt after one that normalized
    /// back to the global plan: the reservoirs need a window's worth of
    /// fresh shapes before a retry can conclude differently, and skew
    /// stays confirmed continuously, so an unthrottled retry would run
    /// S warm optimizer searches every iteration.
    fit_cooldown: usize,
    /// The retry distance (= the skew window width).
    fit_retry: usize,
}

impl<'a> PerShardPolicy<'a> {
    pub fn new(
        reference: &DataProfile,
        theta: Theta,
        replan_cfg: ReplanConfig,
        rctx: ReplanContext<'a>,
        est: &'a Estimator<'a>,
        sc: &ShardConfig,
    ) -> PerShardPolicy<'a> {
        let reservoirs = (0..sc.dp_shards)
            .map(|_| ShapeReservoir::new(replan_cfg.reservoir))
            .collect();
        PerShardPolicy {
            global: Replanner::new(reference, theta, replan_cfg),
            rctx,
            est,
            windows: ShardWindows::new(sc.dp_shards, sc.window_batches),
            reservoirs,
            skew_enter: sc.skew_enter,
            fitted: None,
            fit_cooldown: 0,
            fit_retry: sc.window_batches.max(1),
        }
    }

    /// Fit one θ_s per shard warm-started from `global`, run the
    /// assignment step, and normalize an all-global outcome back to
    /// `None` (so the executor keeps the exact global code path).
    fn refit(&mut self, global: Theta) {
        let fitted = fit_per_shard(&self.rctx, global, &self.reservoirs);
        let assigned = assign_plans(self.est, &fitted, &self.reservoirs);
        self.fitted = if assigned.iter().all(|t| *t == global) {
            None
        } else {
            Some(assigned)
        };
    }
}

impl PlanPolicy for PerShardPolicy<'_> {
    fn observe(&mut self, draw: &Draw) -> Option<PlanSet> {
        let Draw::Sharded { batches, stats, pooled } = draw else {
            unreachable!("per-shard policy fed a single-replica draw")
        };
        let swap = self.global.observe_stats(&self.rctx, merge_shard_stats(stats), pooled);
        self.windows.push(stats.clone());
        for (res, b) in self.reservoirs.iter_mut().zip(batches) {
            res.extend(b);
        }
        if let Some(g) = swap {
            // The pooled distribution moved: the global plan swapped, and
            // any per-shard plans were fitted against stale shards —
            // refit them against the new incumbent. With no fits yet,
            // re-arm the skew trigger immediately.
            if self.fitted.is_some() {
                self.refit(g);
            } else {
                self.fit_cooldown = 0;
            }
            return Some(PlanSet { global: g, per_replica: self.fitted.clone() });
        }
        match &self.fitted {
            Some(_) => {
                // Transient skew can converge back without moving the
                // *pooled* distribution (per-shard divergence cancels in
                // the merge, and the global detector was rebased), so
                // fitted plans need their own exit: once the worst
                // shard's score falls below half the entry threshold the
                // plans are tuned to data the shards no longer draw —
                // revert to the global plan. The half-threshold
                // hysteresis plus the retry cooldown keeps a score
                // hovering at the gate from flapping plans every window.
                if self.windows.is_full() && !self.windows.skewed(self.skew_enter * 0.5) {
                    self.fitted = None;
                    self.fit_cooldown = self.fit_retry;
                    return Some(PlanSet::global(self.global.theta));
                }
            }
            None => {
                if self.fit_cooldown > 0 {
                    self.fit_cooldown -= 1;
                } else if self.windows.is_full() && self.windows.skewed(self.skew_enter) {
                    let g = self.global.theta;
                    self.refit(g);
                    match &self.fitted {
                        Some(f) => {
                            return Some(PlanSet { global: g, per_replica: Some(f.clone()) })
                        }
                        // Every shard still reads best-served by the
                        // global plan (e.g. the reservoirs are dominated
                        // by early, near-pooled shapes): retry after the
                        // window turns over rather than latching off —
                        // shards that keep diverging under a stationary
                        // pooled mixture would otherwise never get their
                        // plans.
                        None => self.fit_cooldown = self.fit_retry,
                    }
                }
            }
        }
        None
    }

    fn take_events(&mut self) -> Vec<ReplanEvent> {
        std::mem::take(&mut self.global.events)
    }

    fn drift_phase(&self) -> Option<&'static str> {
        phase_of(self.global.drift_decision())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
    use crate::stream::window::ShapeStats;

    fn theta() -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 3, dp: 1 },
            n_mb: 4,
        }
    }

    #[test]
    fn static_policy_never_swaps() {
        let m = llava_ov(llama3("8b"));
        let mut ds = Dataset::mixed(3);
        let mut p = StaticPolicy;
        for _ in 0..4 {
            let draw = Draw::Single(ds.shaped_batch(&m, 8));
            assert!(p.observe(&draw).is_none());
        }
        assert!(p.take_events().is_empty());
    }

    #[test]
    fn converged_shards_revert_fitted_plans_to_global() {
        // The hetero exit path: plans fitted during a transient skew must
        // not latch on after the shards converge back to the pooled mix.
        // The fitted state is seeded directly so the test is independent
        // of optimizer behaviour and runs no search at all.
        let m = llava_ov(llama3("8b"));
        let cluster = ClusterSpec::hgx_a100(1);
        let mut backend = SimBackend::new(Truth::new(cluster));
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let data = profile_data(&m, &mut Dataset::mixed(0xDA7A), 256);
        let rctx = ReplanContext {
            m: &m,
            profile: &profile,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs: 16,
        };
        let g = theta();
        let sc = ShardConfig { dp_shards: 2, window_batches: 3, ..ShardConfig::default() };
        let mut p =
            PerShardPolicy::new(&data, g, ReplanConfig::default(), rctx, &est, &sc);
        let mut alt = g;
        alt.n_mb = 8;
        p.fitted = Some(vec![alt, alt]);

        // Statistically identical shards at 192-item windows: the skew
        // score sits far below half the entry threshold once the windows
        // fill, so the policy must hand back the global plan
        // (per_replica = None) exactly once and then stay quiet.
        let mut a = Dataset::mixed(3);
        let mut b = Dataset::mixed(4);
        let mut reverts = 0;
        for _ in 0..6 {
            let batches = vec![a.shaped_batch(&m, 64), b.shaped_batch(&m, 64)];
            let stats = batches.iter().map(|x| ShapeStats::of_batch(x)).collect();
            let pooled = batches.iter().flat_map(|x| x.iter().copied()).collect();
            let draw = Draw::Sharded { batches, stats, pooled };
            if let Some(plan) = p.observe(&draw) {
                assert!(plan.per_replica.is_none(), "revert must drop to the global θ");
                assert_eq!(plan.global, g);
                reverts += 1;
            }
        }
        assert_eq!(reverts, 1, "converged shards kept (or re-dropped) stale plans");
        assert!(p.fitted.is_none());
        assert!(p.take_events().is_empty(), "revert is not a replan");
    }

    #[test]
    fn plan_set_replica_theta_falls_back_to_global() {
        let g = theta();
        let set = PlanSet::global(g);
        assert_eq!(set.replica_theta(0), g);
        assert_eq!(set.replica_theta(3), g);
        let mut other = g;
        other.n_mb = 8;
        let het = PlanSet { global: g, per_replica: Some(vec![g, other]) };
        assert_eq!(het.replica_theta(0), g);
        assert_eq!(het.replica_theta(1), other);
    }
}
