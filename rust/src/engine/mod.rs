//! The unified policy-driven execution engine.
//!
//! Every `SystemKind` used to run through one of two parallel monoliths in
//! `sim::trainer` (`run_system` / `run_sharded`), each hand-rolling the
//! offline profile→optimize phase, the online iteration loop, replanner
//! wiring, and telemetry. This module is the seam that replaces them: one
//! [`run`] entry whose loop owns dataset draws, drift checks, scheduling,
//! correction, and telemetry, with two trait objects supplying the
//! system-specific behaviour —
//!
//! - [`policy::PlanPolicy`] decides *which plan executes next*: the frozen
//!   offline θ* ([`policy::StaticPolicy`]), the drift-adaptive global θ
//!   ([`policy::AdaptivePolicy`], single-batch or merged-shard-summary
//!   fed), or heterogeneous per-replica plans
//!   ([`policy::PerShardPolicy`] + [`hetero`]).
//! - [`exec::ExecModel`] turns a draw into an executed iteration: one
//!   1F1B replica with the Online Scheduler and Adaptive Correction
//!   ([`exec::SingleReplicaExec`]), or S replicas behind the step barrier
//!   with the skew-gated migration walk ([`exec::ShardedExec`]).
//!
//! [`telemetry::Telemetry`] collects what both loops used to bundle ad
//! hoc, and assembles the one canonical `RunResult`.
//!
//! **Determinism contract.** The engine adds no arithmetic of its own:
//! drawing, observing, scheduling, executing, and recording happen in
//! exactly the order the old loops used, so every statistic is
//! bit-identical to the pre-engine code — modulo the one deliberate
//! behaviour change this PR ships, the Eq-7 correction-penalty reset at
//! a plan swap, which can move adaptive-run numbers after a confirmed
//! drift. `tests/engine_parity.rs` pins the refactor per `SystemKind` at
//! `--threads 1` and `8` with that reset mirrored into its reference
//! transcriptions, and the PR-1..4 thread-count invariants carry over
//! unchanged.
//!
//! Dataset keys are validated *before* any profiling or pool work, so an
//! unknown key is a `util::error::Result` error at the API boundary — not
//! a panic inside a worker thread.

pub mod exec;
pub mod hetero;
pub mod policy;
pub mod telemetry;

use crate::baselines::homogeneous::{megatron_tune, pytorch_tune, PYTORCH_SOFTWARE_FACTOR};
use crate::data::dataset::Dataset;
use crate::data::item::ItemShape;
use crate::fault::{FaultTrace, FleetState};
use crate::model::catalog::Mllm;
use crate::obs::Recorder;
use crate::optimizer::plan::Theta;
use crate::optimizer::search::{optimize, OptimizerInputs};
use crate::perfmodel::{ClusterSpec, Truth};
use crate::profiling::backend::{MeasureBackend, SimBackend};
use crate::profiling::engine::{
    profile_data, DataProfile, ModelProfile, ModelProfiler, ProfilerGrids,
};
use crate::profiling::estimator::Estimator;
use crate::shard::partition::ShardedDataset;
use crate::shard::ShardConfig;
use crate::sim::trainer::{RunConfig, RunResult, SystemKind};
use crate::stream::replan::ReplanContext;
use crate::stream::window::ShapeStats;
use crate::util::error::Result;
use exec::{ExecModel, InterleavedExec, ShardedExec, SingleReplicaExec};
use policy::{AdaptivePolicy, FaultAwarePolicy, PerShardPolicy, PlanPolicy, StaticPolicy};
use std::time::Duration;
use telemetry::Telemetry;

/// One iteration's input, drawn ahead of plan observation and scheduling.
#[derive(Clone, Debug)]
pub enum Draw {
    /// One global batch (single-replica systems).
    Single(Vec<ItemShape>),
    /// Per-shard batches plus their exact integer summaries and the
    /// pooled concatenation (shard order) — computed once so the policy
    /// (global drift merge) and executor (skew gate, rebalance pricing)
    /// see the same values.
    Sharded {
        batches: Vec<Vec<ItemShape>>,
        stats: Vec<ShapeStats>,
        pooled: Vec<ItemShape>,
    },
}

/// The engine's dataset seam: one stream per run, drawn in iteration
/// order.
pub enum DataFeed {
    Single {
        ds: Dataset,
        gbs: usize,
    },
    Sharded {
        sd: ShardedDataset,
        /// Active shard slots, ascending; `counts[i]` items come from
        /// shard `members[i]`'s stream. Full membership unless a fault
        /// trace shrinks the group.
        members: Vec<usize>,
        counts: Vec<usize>,
    },
}

impl DataFeed {
    pub fn single(ds: Dataset, gbs: usize) -> DataFeed {
        DataFeed::Single { ds, gbs }
    }

    pub fn sharded(sd: ShardedDataset, counts: Vec<usize>) -> DataFeed {
        let members = (0..sd.n_shards()).collect();
        DataFeed::Sharded { sd, members, counts }
    }

    /// Repoint a sharded feed at an elastic fleet's current membership
    /// and per-member batch split (fault-injected runs; the healthy path
    /// never calls this).
    pub fn set_fleet(&mut self, new_members: Vec<usize>, new_counts: Vec<usize>) {
        let DataFeed::Sharded { members, counts, .. } = self else {
            unreachable!("fleet membership on a single-replica feed")
        };
        assert_eq!(new_members.len(), new_counts.len(), "one count per member");
        *members = new_members;
        *counts = new_counts;
    }

    /// Draw the next iteration's input.
    pub fn draw(&mut self, m: &Mllm) -> Draw {
        match self {
            DataFeed::Single { ds, gbs } => Draw::Single(ds.shaped_batch(m, *gbs)),
            DataFeed::Sharded { sd, members, counts } => {
                let batches = sd.shard_batches_members(m, members, counts);
                let stats = batches.iter().map(|b| ShapeStats::of_batch(b)).collect();
                let pooled = batches.iter().flat_map(|b| b.iter().copied()).collect();
                Draw::Sharded { batches, stats, pooled }
            }
        }
    }
}

/// Validate a run's inputs before any profiling or pool work: dataset /
/// shard-scenario keys and the shard-count arithmetic. `run_cells` calls
/// this up front for every cell so an unknown key can never poison a
/// worker thread.
pub fn validate(kind: SystemKind, dataset_key: &str, cfg: &RunConfig) -> Result<()> {
    if kind == SystemKind::DflopSharded {
        let sc = cfg.shard.clone().unwrap_or_default();
        if sc.dp_shards < 1 {
            crate::bail!("sharded run needs at least one shard");
        }
        if cfg.gbs < sc.dp_shards {
            crate::bail!(
                "per-shard batch must be non-empty: gbs {} < {} shards",
                cfg.gbs,
                sc.dp_shards
            );
        }
        if ShardedDataset::by_key(dataset_key, sc.dp_shards, 0).is_none() {
            crate::bail!(
                "unknown shard scenario '{dataset_key}' (try skewed-shard|laggard-shard|\
                 hot-shard|homogeneous-shard or any plain dataset key)"
            );
        }
    } else if Dataset::by_key(dataset_key, 0).is_none() {
        crate::bail!(
            "unknown dataset '{dataset_key}' (try mixed|multi-image|video|audio|\
             curriculum|bursty-video|modality-dropout)"
        );
    }
    if let Some(fc) = &cfg.faults {
        if kind != SystemKind::DflopSharded {
            crate::bail!(
                "fault injection needs the sharded fleet ({} has no DP group to degrade)",
                kind.label()
            );
        }
        let sc = cfg.shard.clone().unwrap_or_default();
        if sc.hetero {
            crate::bail!("fault injection does not compose with per-shard plans (hetero)");
        }
        if sc.dp_shards < 2 {
            crate::bail!(
                "fault injection needs at least 2 DP shards to degrade, got {}",
                sc.dp_shards
            );
        }
        if FaultTrace::by_key(&fc.trace, sc.dp_shards, cfg.seed).is_none() {
            crate::bail!(
                "unknown fault trace '{}' (try none|churn|straggler|degraded-link|\
                 skewed-churn|long-horizon)",
                fc.trace
            );
        }
    }
    Ok(())
}

/// Everything a run's offline phase produces: the ground-truth cluster,
/// the Model/Data Profiler outputs, and the offline plan θ*.
pub struct Offline {
    pub cluster: ClusterSpec,
    pub truth: Truth,
    pub profile: ModelProfile,
    pub data: DataProfile,
    /// Offline overheads (Table 4): model+data profiling wall-clock.
    pub profiling_seconds: f64,
    pub theta: Theta,
    pub optimizer_elapsed: Duration,
}

/// The shared offline phase: profile the model against the ground truth,
/// profile the data (pooled across shards for sharded runs), and select
/// the system's offline plan. Assumes `validate` has passed.
fn offline(kind: SystemKind, m: &Mllm, dataset_key: &str, cfg: &RunConfig) -> Offline {
    let cluster = ClusterSpec::hgx_a100(cfg.nodes);
    let mut truth = Truth::new(cluster);
    truth.injected = cfg.injected.clone();
    if kind == SystemKind::Pytorch {
        truth.software_factor = PYTORCH_SOFTWARE_FACTOR;
    }

    let mut backend = SimBackend::new(truth.clone());
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(cluster.gpus_per_node))
        .profile(m);
    let data = if kind == SystemKind::DflopSharded {
        let shards = cfg.shard.clone().unwrap_or_default().dp_shards;
        let mut profile_sd = ShardedDataset::by_key(dataset_key, shards, cfg.seed ^ 0xDA7A)
            .expect("validated shard scenario");
        profile_sd.profile_pooled(m, cfg.profile_samples)
    } else {
        let mut profile_ds =
            Dataset::by_key(dataset_key, cfg.seed ^ 0xDA7A).expect("validated dataset");
        profile_data(m, &mut profile_ds, cfg.profile_samples)
    };
    let profiling_seconds = backend.measured_seconds().max(data.profiling_seconds);

    let (theta, optimizer_elapsed) = match kind {
        SystemKind::Dflop
        | SystemKind::DflopInterleaved
        | SystemKind::DflopAdaptive
        | SystemKind::DflopOptimizerOnly => {
            let inp = OptimizerInputs {
                m,
                profile: &profile,
                data: &data,
                n_gpus: cluster.total_gpus(),
                gpus_per_node: cluster.gpus_per_node,
                mem_capacity: cluster.gpu.mem_bytes,
                gbs: cfg.gbs,
                assume_balanced: kind != SystemKind::DflopOptimizerOnly,
            };
            let r = optimize(&inp).expect("no feasible DFLOP configuration");
            (r.theta, r.elapsed)
        }
        SystemKind::DflopSharded => {
            // θ* sizes one replica: per-replica GBS (ceil so memory is
            // checked against the largest shard after remainder
            // distribution), fitted to the *pooled* distribution the
            // rebalancer steers every replica towards.
            let shards = cfg.shard.clone().unwrap_or_default().dp_shards;
            let rctx = ReplanContext {
                m,
                profile: &profile,
                n_gpus: cluster.total_gpus(),
                gpus_per_node: cluster.gpus_per_node,
                mem_capacity: cluster.gpu.mem_bytes,
                gbs: cfg.gbs.div_ceil(shards),
            };
            let r = optimize(&rctx.inputs(&data)).expect("no feasible sharded configuration");
            (r.theta, r.elapsed)
        }
        SystemKind::DflopSchedulerOnly | SystemKind::Megatron => {
            let c = megatron_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible Megatron configuration");
            (c.theta, Duration::ZERO)
        }
        SystemKind::Pytorch => {
            let c = pytorch_tune(m, &truth, cfg.gbs, data.mean_units(), data.mean_seq())
                .expect("no feasible PyTorch configuration");
            (c.theta, Duration::ZERO)
        }
    };

    Offline {
        cluster,
        truth,
        profile,
        data,
        profiling_seconds,
        theta,
        optimizer_elapsed,
    }
}

/// Run one system on one workload through the engine: validate → offline
/// phase → the shared iteration loop → `RunResult` assembly.
///
/// This is the single entry every `SystemKind` executes through —
/// `sim::run_system` / `sim::run_cells`, the figure grids, the CLI `run`
/// command, and the examples are all thin callers.
pub fn run(kind: SystemKind, m: &Mllm, dataset_key: &str, cfg: &RunConfig) -> Result<RunResult> {
    validate(kind, dataset_key, cfg)?;
    let off = offline(kind, m, dataset_key, cfg);
    let est = Estimator::new(m, &off.profile.throughput);

    let sharded = kind == SystemKind::DflopSharded;
    let sc: ShardConfig = cfg.shard.clone().unwrap_or_default();
    let shards = sc.dp_shards;
    // The optimizer-facing context of every (re)plan: per-replica GBS for
    // sharded runs, the full global batch otherwise.
    let rctx = ReplanContext {
        m,
        profile: &off.profile,
        n_gpus: off.cluster.total_gpus(),
        gpus_per_node: off.cluster.gpus_per_node,
        mem_capacity: off.cluster.gpu.mem_bytes,
        gbs: if sharded { cfg.gbs.div_ceil(shards) } else { cfg.gbs },
    };

    let mut feed = if sharded {
        DataFeed::sharded(
            ShardedDataset::by_key(dataset_key, shards, cfg.seed).expect("validated scenario"),
            ShardedDataset::split_counts(cfg.gbs, shards),
        )
    } else {
        DataFeed::single(
            Dataset::by_key(dataset_key, cfg.seed).expect("validated dataset"),
            cfg.gbs,
        )
    };

    // Plan policy: who decides which θ executes next.
    let replan_cfg = cfg.replan.clone().unwrap_or_default();
    // Fault-injected fleet: the seeded trace replayed at iteration
    // boundaries, with confirmation debounce matched to the drift
    // detector's so topology responses share the no-thrash cadence.
    let mut fleet = cfg.faults.as_ref().map(|fc| {
        FleetState::new(
            FaultTrace::by_key(&fc.trace, shards, cfg.seed).expect("validated fault trace"),
            fc.respond,
            replan_cfg.drift.confirm,
        )
    });
    let mut policy: Box<dyn PlanPolicy + '_> = match kind {
        SystemKind::DflopAdaptive => {
            Box::new(AdaptivePolicy::new(&off.data, off.theta, replan_cfg, rctx))
        }
        SystemKind::DflopSharded if cfg.faults.is_some() => {
            if cfg.faults.as_ref().is_some_and(|fc| fc.respond) {
                Box::new(FaultAwarePolicy::new(
                    &off.data,
                    off.theta,
                    replan_cfg,
                    rctx,
                    cfg.gbs,
                    shards,
                ))
            } else {
                // The static-θ* arm absorbs the injected physics without
                // replanning — the comparison baseline.
                Box::new(StaticPolicy)
            }
        }
        SystemKind::DflopSharded if sc.hetero => Box::new(PerShardPolicy::new(
            &off.data,
            off.theta,
            replan_cfg,
            rctx,
            &est,
            &sc,
        )),
        SystemKind::DflopSharded => {
            Box::new(AdaptivePolicy::new(&off.data, off.theta, replan_cfg, rctx))
        }
        _ => Box::new(StaticPolicy),
    };

    // Execution model: how a scheduled iteration actually runs.
    let mut exec: Box<dyn ExecModel + '_> = if sharded {
        Box::new(ShardedExec::new(m, &off.truth, &est, off.theta, &sc))
    } else if kind == SystemKind::DflopInterleaved {
        Box::new(InterleavedExec::new(m, &off.truth, &est, off.theta, cfg))
    } else {
        Box::new(SingleReplicaExec::new(kind, m, &off.truth, &est, off.theta, cfg))
    };

    // ---- the one shared iteration loop ----
    let mut tel = Telemetry::new(cfg.iters);
    // The observability recorder rides on the telemetry collector so the
    // policy/exec seams reach it without signature changes. `None` keeps
    // the zero-cost `Recorder::Off`.
    tel.rec = Recorder::new(cfg.obs.as_ref());
    for it in 0..cfg.iters {
        // Fault events land strictly at iteration boundaries, before the
        // draw, so membership, batch split, and injected health are fixed
        // for the whole iteration — this is what keeps fleet runs
        // bit-identical at any `DFLOP_THREADS`.
        if let Some(fs) = fleet.as_mut() {
            let delta = fs.advance(it);
            tel.record_fault(&delta);
            feed.set_fleet(fs.members(), fs.counts(cfg.gbs));
            exec.set_health(&fs.view());
            // Responding fleets also steer the rebalance pricing by the
            // *confirmed* (debounced) factors — the same view the batch
            // split uses — so non-responding and healthy runs stay
            // bit-identical to the un-injected path.
            if cfg.faults.as_ref().is_some_and(|fc| fc.respond) {
                exec.set_confirmed_health(&fs.confirmed_view());
            }
            policy.observe_health(fs.confirmed_active());
        }
        let draw = feed.draw(m);
        // Stage the realized batch for the post-run audit (pooled view
        // on sharded systems — the same shapes the drift merge sees).
        if tel.rec.wants_audit() {
            match &draw {
                Draw::Single(b) => tel.rec.audit_batch(b),
                Draw::Sharded { pooled, .. } => tel.rec.audit_batch(pooled),
            }
        }
        // Drift check before scheduling: the batch's shapes are known to
        // the CPU-side scheduler ahead of execution, and a confirmed
        // drift swaps the plan at this iteration boundary.
        if let Some(plan) = policy.observe(&draw) {
            tel.rec.plan_swap(exec.plan().global, &plan);
            exec.apply_plan(&plan);
        }
        if tel.rec.is_on() {
            tel.rec.drift_phase(policy.drift_phase());
        }
        let sched = exec.schedule(&draw, &mut tel);
        let stats = exec.execute(&sched, &mut tel);
        exec.correct(&sched, &stats);
        tel.record_iteration(stats);
    }

    let n_gpus = off.cluster.total_gpus() * if sharded { shards } else { 1 };
    let final_plan = exec.plan().clone();
    let mut result = tel.finish(
        kind,
        final_plan.global,
        n_gpus,
        off.profiling_seconds,
        off.optimizer_elapsed,
        policy.take_events(),
        final_plan.per_replica.unwrap_or_default(),
    );
    // Post-run analysis tier: price the recorded batches against the
    // plans that executed them. Runs after the loop on the same thread
    // over sim-time data only, so the determinism contract holds.
    if let Some(log) = result.obs.as_deref_mut() {
        if log.cfg.audit {
            crate::obs::audit::run_audit(
                log,
                off.theta,
                &result.iterations,
                &result.replan_events,
                m,
                &off.profile.throughput,
            );
        }
    }
    Ok(result)
}
