//! Execution models behind the engine seam: how one scheduled iteration
//! actually runs.
//!
//! Two models cover every `SystemKind`:
//!
//! - [`SingleReplicaExec`] — one pipeline replica per run: the Online
//!   Microbatch Scheduler (or the baselines' random partitioner) buckets
//!   the batch, one reusable [`SimWorkspace`] executes the 1F1B
//!   iteration, and Adaptive Correction feedback (Eq 7) closes the loop.
//! - [`ShardedExec`] — S data-parallel replicas behind the step barrier:
//!   the `shard::agg` skew gate and `shard::balance` migration walk
//!   redistribute the global batch, every replica LPT-buckets and
//!   simulates its share on the worker pool, and the barrier charges the
//!   cross-shard allreduce. With per-replica plans present
//!   (`engine::hetero`) each replica runs its own θ_r; the allreduce is
//!   the slowest replica group's ring.
//!
//! Both bodies are verbatim transplants of the loops that used to live in
//! `sim::trainer::{run_system, run_sharded}` — the parity suite
//! (`tests/engine_parity.rs`) holds them bit-identical to the originals.

use crate::baselines::homogeneous::random_buckets;
use crate::data::item::ItemShape;
use crate::engine::policy::PlanSet;
use crate::engine::telemetry::Telemetry;
use crate::engine::Draw;
use crate::fault::FleetView;
use crate::model::catalog::Mllm;
use crate::perfmodel::Truth;
use crate::pipeline::build::{iterate_interleaved, iterate_ws, IterationStats, SystemPlan};
use crate::pipeline::sim::SimWorkspace;
use crate::profiling::estimator::Estimator;
use crate::scheduler::correction::{Correction, CorrectionConfig};
use crate::scheduler::lpt::ItemCost;
use crate::scheduler::online::{OnlineScheduler, SchedulerConfig, Solver};
use crate::shard::agg::ShardWindows;
use crate::shard::balance::rebalance;
use crate::shard::sync::{
    charge_straggler, cross_shard_allreduce, degraded_allreduce, lpt_shard_buckets,
    simulate_shards, simulate_shards_hetero, step_barrier, BarrierStats,
};
use crate::shard::ShardConfig;
use crate::sim::trainer::{RunConfig, SystemKind};
use crate::util::rng::Rng;

/// One iteration's scheduled work: per-replica microbatch buckets
/// (single-replica models carry exactly one replica entry).
#[derive(Clone, Debug)]
pub struct Scheduled {
    pub replicas: Vec<Vec<Vec<ItemShape>>>,
}

/// How a system turns a draw into an executed iteration.
pub trait ExecModel {
    /// Swap in a policy decision at the iteration boundary.
    fn apply_plan(&mut self, plan: &PlanSet);

    /// The live plan (its global θ is what `RunResult::theta` reports).
    fn plan(&self) -> &PlanSet;

    /// Partition the draw into microbatch buckets (scheduling wall-clock
    /// and solver fallbacks / migrations land in `tel`).
    fn schedule(&mut self, draw: &Draw, tel: &mut Telemetry) -> Scheduled;

    /// Execute the scheduled iteration (straggler gaps land in `tel`).
    fn execute(&mut self, sched: &Scheduled, tel: &mut Telemetry) -> IterationStats;

    /// Feed execution measurements back into the plan's estimators
    /// (Adaptive Correction); default no-op for models without it.
    fn correct(&mut self, _sched: &Scheduled, _stats: &IterationStats) {}

    /// Expose the fault layer's injected health for this iteration (raw
    /// view, active-member order). Default no-op: models without a
    /// degradation path ignore it.
    fn set_health(&mut self, _view: &FleetView) {}

    /// The health the model would charge this iteration, if any.
    fn health(&self) -> Option<&FleetView> {
        None
    }

    /// Expose the fault layer's *confirmed* (debounced) health — what
    /// responses may react to, as opposed to [`ExecModel::set_health`]'s
    /// raw injected view, which only charges execution. Default no-op.
    fn set_confirmed_health(&mut self, _view: &FleetView) {}
}

/// Materialize bucket index groups into item-shape buckets.
fn materialize(shapes: &[ItemShape], groups: &[Vec<usize>]) -> Vec<Vec<ItemShape>> {
    groups
        .iter()
        .map(|g| g.iter().map(|&i| shapes[i]).collect())
        .collect()
}

/// One pipeline replica: scheduler (ILP/LPT or random) + 1F1B workspace +
/// Adaptive Correction.
pub struct SingleReplicaExec<'a> {
    m: &'a Mllm,
    truth: &'a Truth,
    est: &'a Estimator<'a>,
    plan: PlanSet,
    scheduler: OnlineScheduler,
    rng: Rng,
    uses_scheduler: bool,
    /// One simulation workspace per run (= per pool worker task): every
    /// iteration's route build + 1F1B execution reuses the same arena.
    ws: SimWorkspace,
}

impl<'a> SingleReplicaExec<'a> {
    pub fn new(
        kind: SystemKind,
        m: &'a Mllm,
        truth: &'a Truth,
        est: &'a Estimator<'a>,
        theta: crate::optimizer::plan::Theta,
        cfg: &RunConfig,
    ) -> SingleReplicaExec<'a> {
        let uses_scheduler = matches!(
            kind,
            SystemKind::Dflop
                | SystemKind::DflopAdaptive
                | SystemKind::DflopInterleaved
                | SystemKind::DflopSchedulerOnly
        );
        let mut correction_cfg = CorrectionConfig::default();
        if cfg.disable_correction {
            // A zero-benefit window of one iteration deactivates immediately.
            correction_cfg.window = 1;
            correction_cfg.cost_fraction = f64::INFINITY;
        }
        SingleReplicaExec {
            m,
            truth,
            est,
            plan: PlanSet::global(theta),
            scheduler: OnlineScheduler::new(
                theta,
                SchedulerConfig { ilp_budget: cfg.ilp_budget },
                Correction::new(correction_cfg),
            ),
            rng: Rng::new(cfg.seed ^ 0xB0CC),
            uses_scheduler,
            ws: SimWorkspace::new(),
        }
    }
}

impl ExecModel for SingleReplicaExec<'_> {
    fn apply_plan(&mut self, plan: &PlanSet) {
        self.plan = PlanSet::global(plan.global);
        self.scheduler.theta = plan.global;
        // Drift-aware Adaptive Correction: Eq-7 penalties were measured
        // against the old θ's predictions — stale ratios would bias the
        // first post-swap schedules, so they reset with the plan.
        self.scheduler.correction.reset_penalties();
    }

    fn plan(&self) -> &PlanSet {
        &self.plan
    }

    fn schedule(&mut self, draw: &Draw, tel: &mut Telemetry) -> Scheduled {
        let Draw::Single(shapes) = draw else {
            unreachable!("single-replica exec fed a sharded draw")
        };
        let buckets = if self.uses_scheduler {
            let sched = self.scheduler.schedule(self.est, shapes);
            tel.sched_elapsed.push(sched.elapsed);
            if sched.solver == Solver::LptFallback {
                tel.lpt_fallbacks += 1;
                tel.rec.lpt_fallback();
            }
            materialize(shapes, &sched.assignment.buckets)
        } else {
            let t0 = std::time::Instant::now();
            let b = random_buckets(shapes, self.plan.global.buckets(), &mut self.rng);
            tel.sched_elapsed.push(t0.elapsed());
            b
        };
        Scheduled { replicas: vec![buckets] }
    }

    fn execute(&mut self, sched: &Scheduled, _tel: &mut Telemetry) -> IterationStats {
        let plan = SystemPlan { m: self.m, truth: self.truth, theta: self.plan.global };
        iterate_ws(&plan, &sched.replicas[0], &mut self.ws)
    }

    /// Adaptive Correction feedback (Eq 7).
    fn correct(&mut self, sched: &Scheduled, stats: &IterationStats) {
        if !(self.uses_scheduler && self.scheduler.correction.is_active()) {
            return;
        }
        let theta = self.plan.global;
        let buckets = &sched.replicas[0];
        let mut observations = Vec::new();
        let mut mispredicted = 0.0;
        let l_layers = self.m.llm.layers as f64;
        for bucket in buckets {
            let total: f64 = bucket.iter().map(|i| i.llm_seq as f64).sum();
            if total <= 0.0 {
                continue;
            }
            for item in bucket {
                let seq = item.llm_seq as f64;
                if seq <= 0.0 {
                    continue;
                }
                // Observed per-item time: the coordinator times the
                // per-instance attention kernels and apportions the
                // packed linear time by token share.
                let lin = self.truth.llm_linear_time(self.m, total, l_layers, theta.llm.tp);
                let lin_share = lin * seq / total;
                let attn = self.truth.llm_attn_time(self.m, seq, l_layers, theta.llm.tp);
                let actual = lin_share + attn;
                let pred = self.est.llm_item_dur(item, theta.llm.tp);
                let flop = item.llm_flop(self.m);
                observations.push((
                    Truth::llm_bucket(seq),
                    flop / actual,
                    flop / pred,
                ));
                mispredicted += (actual - pred).abs() / theta.llm.pp as f64;
            }
        }
        let benefit = mispredicted
            / (stats.buckets.len().max(1) as f64)
            / stats.pipeline_makespan.max(1e-12);
        self.scheduler.feedback(&observations, benefit);
    }
}

/// Bubble-filling interleaved execution (`SystemKind::DflopInterleaved`):
/// schedules exactly like [`SingleReplicaExec`] (same ILP/LPT bucketing,
/// same Adaptive Correction), but executes through
/// `pipeline::build::iterate_interleaved`, which decomposes each
/// microbatch's first encoder leg into unit-granularity sub-ops and packs
/// them into the LLM stages' bubble slots. With the fill pass disabled
/// (`RunConfig::bubble_fill = false`) every call delegates verbatim to the
/// inner model, so the run is bit-identical to plain DFLOP — the parity
/// baseline `tests/engine_parity.rs` pins.
pub struct InterleavedExec<'a> {
    inner: SingleReplicaExec<'a>,
    fill: bool,
}

impl<'a> InterleavedExec<'a> {
    pub fn new(
        m: &'a Mllm,
        truth: &'a Truth,
        est: &'a Estimator<'a>,
        theta: crate::optimizer::plan::Theta,
        cfg: &RunConfig,
    ) -> InterleavedExec<'a> {
        InterleavedExec {
            inner: SingleReplicaExec::new(
                SystemKind::DflopInterleaved,
                m,
                truth,
                est,
                theta,
                cfg,
            ),
            fill: cfg.bubble_fill,
        }
    }
}

impl ExecModel for InterleavedExec<'_> {
    fn apply_plan(&mut self, plan: &PlanSet) {
        self.inner.apply_plan(plan);
    }

    fn plan(&self) -> &PlanSet {
        self.inner.plan()
    }

    fn schedule(&mut self, draw: &Draw, tel: &mut Telemetry) -> Scheduled {
        self.inner.schedule(draw, tel)
    }

    fn execute(&mut self, sched: &Scheduled, tel: &mut Telemetry) -> IterationStats {
        if !self.fill {
            return self.inner.execute(sched, tel);
        }
        let plan = SystemPlan {
            m: self.inner.m,
            truth: self.inner.truth,
            theta: self.inner.plan.global,
        };
        iterate_interleaved(&plan, &sched.replicas[0], &mut self.inner.ws)
    }

    fn correct(&mut self, sched: &Scheduled, stats: &IterationStats) {
        self.inner.correct(sched, stats);
    }
}

/// Combine one step's per-replica iteration stats into a cluster-level
/// view: stage arrays concatenate in shard order, idle is charged against
/// the slowest replica's pipeline (straggler wait shows up as idle on the
/// fast replicas), and the iteration time is the barrier's step time.
/// The merged stats carry no `timeline` — an S-replica timeline has no
/// single 1F1B rendering — but the observability recorder captures the
/// per-replica timelines replica-tagged before the merge (see
/// `ShardedExec::execute`), so `--trace` renders every replica's ops.
fn merge_shard_iterations(per: Vec<IterationStats>, barrier: &BarrierStats) -> IterationStats {
    let pipeline_max = per.iter().map(|s| s.pipeline_makespan).fold(0.0, f64::max);
    let n_stages = per.iter().map(|s| s.n_stages).sum();
    let mut stage_busy = Vec::with_capacity(n_stages);
    let mut stage_flop = Vec::with_capacity(n_stages);
    let mut buckets = Vec::new();
    let mut total_flop = 0.0;
    for s in per {
        stage_busy.extend(s.stage_busy);
        stage_flop.extend(s.stage_flop);
        buckets.extend(s.buckets);
        total_flop += s.total_flop;
    }
    let stage_idle = stage_busy.iter().map(|&b| pipeline_max - b).collect();
    IterationStats {
        iteration_time: barrier.step_time,
        pipeline_makespan: pipeline_max,
        dp_sync_time: barrier.step_time - pipeline_max,
        stage_busy,
        stage_idle,
        stage_flop,
        n_stages,
        total_flop,
        buckets,
        timeline: Vec::new(),
        fills: Vec::new(),
    }
}

/// S data-parallel replicas behind the step barrier, with the skew-gated
/// bounded-migration rebalance and (optionally) per-replica plans.
pub struct ShardedExec<'a> {
    m: &'a Mllm,
    truth: &'a Truth,
    est: &'a Estimator<'a>,
    plan: PlanSet,
    /// The rebalance skew gate's per-shard windows.
    gate: ShardWindows,
    sc: ShardConfig,
    /// Injected cluster health for the current iteration (fault runs
    /// only); `None` or an all-healthy view leaves the execution path
    /// bit-identical to a run without fault injection.
    health: Option<FleetView>,
    /// Confirmed (debounced) health, active-member order — the response
    /// side of the split: the rebalance pricing weights item costs by it.
    /// `None` or all-ones leaves the pricing bit-identical to a healthy
    /// run. Only set on degradation-aware arms (`FaultConfig::respond`).
    confirmed: Option<FleetView>,
}

impl<'a> ShardedExec<'a> {
    pub fn new(
        m: &'a Mllm,
        truth: &'a Truth,
        est: &'a Estimator<'a>,
        theta: crate::optimizer::plan::Theta,
        sc: &ShardConfig,
    ) -> ShardedExec<'a> {
        ShardedExec {
            m,
            truth,
            est,
            plan: PlanSet::global(theta),
            gate: ShardWindows::new(sc.dp_shards, sc.window_batches),
            sc: sc.clone(),
            health: None,
            confirmed: None,
        }
    }
}

impl ExecModel for ShardedExec<'_> {
    fn apply_plan(&mut self, plan: &PlanSet) {
        self.plan = plan.clone();
    }

    fn plan(&self) -> &PlanSet {
        &self.plan
    }

    fn schedule(&mut self, draw: &Draw, tel: &mut Telemetry) -> Scheduled {
        let Draw::Sharded { batches, stats, pooled } = draw else {
            unreachable!("sharded exec fed a single-replica draw")
        };
        // Elastic membership: the group is however many batches were
        // drawn this iteration. A membership change resets the skew
        // gate's windows — the old per-shard histories describe a group
        // that no longer exists — deterministically on every replica.
        if stats.len() != self.gate.n_shards() {
            self.gate = ShardWindows::new(stats.len(), self.sc.window_batches);
        }
        self.gate.push(stats.clone());
        let t0 = std::time::Instant::now();
        let theta = self.plan.global;
        let shards = batches.len();
        // Skew gate + bounded migration on predicted per-item cost at the
        // global θ — the reference frame every replica shares, so the
        // migration decision is identical whether per-replica plans are
        // active or not.
        let home: Vec<usize> = batches
            .iter()
            .enumerate()
            .flat_map(|(r, b)| std::iter::repeat(r).take(b.len()))
            .collect();
        let skewed = self.sc.rebalance && self.gate.skewed(self.sc.skew_enter);
        let groups: Vec<Vec<usize>> = if skewed {
            // Degradation-aware pricing: a confirmed straggler executes
            // its items slower, so each item's cost is weighted by its
            // home shard's confirmed slowdown factor — the migration walk
            // then moves work *off* degraded replicas instead of
            // balancing blindly. A healthy / absent confirmed view leaves
            // every cost bit-identical to the unweighted computation.
            let conf = self.confirmed.as_ref().filter(|v| {
                v.slowdown.len() == shards && v.slowdown.iter().any(|&f| f != 1.0)
            });
            let items: Vec<ItemCost> = pooled
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let mut c = ItemCost {
                        enc: self.est.enc_item_dur(s, theta.enc.tp) / theta.enc.pp as f64,
                        llm: self.est.llm_item_dur(s, theta.llm.tp) / theta.llm.pp as f64,
                    };
                    if let Some(v) = conf {
                        let f = v.slowdown[home[i]];
                        if f != 1.0 {
                            c.enc *= f;
                            c.llm *= f;
                        }
                    }
                    c
                })
                .collect();
            let rb = rebalance(&items, &home, shards, &self.sc.balance);
            tel.migrations += rb.migrations;
            tel.rec.migrations(rb.migrations);
            rb.groups(shards)
        } else {
            // Static sharding: every item executes where it was drawn.
            let mut g: Vec<Vec<usize>> = vec![Vec::new(); shards];
            for (i, &r) in home.iter().enumerate() {
                g[r].push(i);
            }
            g
        };

        // Per-replica LPT microbatching at each replica's own plan.
        let replicas: Vec<Vec<Vec<ItemShape>>> = groups
            .iter()
            .enumerate()
            .map(|(r, g)| {
                let shapes: Vec<ItemShape> = g.iter().map(|&i| pooled[i]).collect();
                lpt_shard_buckets(self.est, self.plan.replica_theta(r), &shapes)
            })
            .collect();
        tel.sched_elapsed.push(t0.elapsed());
        Scheduled { replicas }
    }

    fn set_health(&mut self, view: &FleetView) {
        self.health = Some(view.clone());
    }

    fn health(&self) -> Option<&FleetView> {
        self.health.as_ref()
    }

    fn set_confirmed_health(&mut self, view: &FleetView) {
        self.confirmed = Some(view.clone());
    }

    fn execute(&mut self, sched: &Scheduled, tel: &mut Telemetry) -> IterationStats {
        let shards = sched.replicas.len();
        let (mut per_replica, mut allreduce) = match &self.plan.per_replica {
            Some(thetas) => (
                simulate_shards_hetero(self.m, self.truth, thetas, &sched.replicas),
                // The ring runs at the pace of the slowest replica
                // group's gradient slices.
                thetas
                    .iter()
                    .map(|&t| cross_shard_allreduce(self.m, self.truth, t, shards))
                    .fold(0.0, f64::max),
            ),
            None => (
                simulate_shards(self.m, self.truth, self.plan.global, &sched.replicas),
                cross_shard_allreduce(self.m, self.truth, self.plan.global, shards),
            ),
        };
        // Charge injected degradation before the barrier so straggler
        // slowdowns and slow links surface in the step time and the
        // straggler gap exactly like organic skew. Skipped entirely when
        // the fleet is healthy, keeping those iterations bit-identical
        // to a run without fault injection.
        if let Some(h) = &self.health {
            if h.is_degrading() {
                debug_assert_eq!(
                    h.slowdown.len(),
                    per_replica.len(),
                    "health view must match the active membership"
                );
                for (stats, &factor) in per_replica.iter_mut().zip(&h.slowdown) {
                    if factor != 1.0 {
                        charge_straggler(stats, factor);
                    }
                }
                allreduce = degraded_allreduce(allreduce, h.link_factor);
            }
        }
        let barrier = step_barrier(
            per_replica.iter().map(|s| s.iteration_time).collect(),
            allreduce,
        );
        tel.straggler_gaps.push(barrier.straggler_gap);
        // Capture the per-replica execution *after* the health charge so
        // recorded traces match the stretched times the barrier saw, and
        // *before* the merge drops the timelines.
        if tel.rec.is_on() {
            tel.rec.replica_timelines(&per_replica);
            tel.rec.barrier(&barrier);
        }
        merge_shard_iterations(per_replica, &barrier)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::optimizer::plan::{ModPar, Theta};
    use crate::perfmodel::ClusterSpec;
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfiler, ProfilerGrids};

    fn theta() -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 3, dp: 1 },
            n_mb: 4,
        }
    }

    #[test]
    fn apply_plan_resets_correction_penalties() {
        // The drift-aware Adaptive Correction satellite at the unit
        // level: learned per-bucket penalties must not survive a plan
        // swap, while the cost-benefit state (activation) does.
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth.clone());
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let cfg = RunConfig::new(1, 8, 1, 7);
        let mut exec =
            SingleReplicaExec::new(SystemKind::Dflop, &m, &truth, &est, theta(), &cfg);
        exec.scheduler.correction.observe(5, 0.5, 1.0);
        exec.scheduler.correction.observe(5, 0.5, 1.0);
        assert_eq!(exec.scheduler.correction.corrected_buckets(), 1);
        let mut new = theta();
        new.n_mb = 8;
        exec.apply_plan(&PlanSet::global(new));
        assert_eq!(
            exec.scheduler.correction.corrected_buckets(),
            0,
            "stale Eq-7 penalties survived the plan swap"
        );
        assert!(exec.scheduler.correction.is_active());
        assert_eq!(exec.plan().global, new);
        assert_eq!(exec.scheduler.theta, new);
    }

    #[test]
    fn single_exec_schedules_and_executes_a_batch() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth.clone());
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let cfg = RunConfig::new(1, 16, 1, 7);
        let mut exec =
            SingleReplicaExec::new(SystemKind::Megatron, &m, &truth, &est, theta(), &cfg);
        let mut tel = Telemetry::new(1);
        let draw = Draw::Single(Dataset::mixed(7).shaped_batch(&m, 16));
        let sched = exec.schedule(&draw, &mut tel);
        assert_eq!(sched.replicas.len(), 1);
        assert_eq!(sched.replicas[0].len(), theta().buckets());
        assert_eq!(
            sched.replicas[0].iter().map(Vec::len).sum::<usize>(),
            16,
            "random partitioner must place every item"
        );
        let stats = exec.execute(&sched, &mut tel);
        assert!(stats.iteration_time > 0.0);
        assert_eq!(tel.sched_elapsed.len(), 1);
        assert_eq!(tel.lpt_fallbacks, 0);
    }
}
