//! The unified per-run telemetry collector.
//!
//! Both pre-engine training loops carried the same ad-hoc bundle of
//! `Vec`s (iteration stats, scheduling wall-clocks, straggler gaps, the
//! Fig-4/Fig-14 sample pools) and assembled a [`RunResult`] from them with
//! duplicated mean arithmetic. [`Telemetry`] owns that state once: the
//! engine loop records into it and [`Telemetry::finish`] performs the one
//! canonical `RunResult` assembly. The arithmetic is a verbatim transplant
//! of the old loops' epilogue, so results are bit-identical
//! (`tests/engine_parity.rs`).

use crate::fault::{FaultDelta, FaultStats};
use crate::obs::Recorder;
use crate::optimizer::plan::Theta;
use crate::pipeline::build::IterationStats;
use crate::sim::trainer::{RunResult, SystemKind};
use crate::stream::replan::ReplanEvent;
use crate::util::stats::quantile;
use std::time::Duration;

/// Everything one run accumulates across iterations.
#[derive(Debug, Default)]
pub struct Telemetry {
    /// Full per-iteration stats for figure-specific postprocessing.
    pub iterations: Vec<IterationStats>,
    /// Scheduling wall-clock per iteration (real, Fig 16b).
    pub sched_elapsed: Vec<Duration>,
    /// ILP-deadline fallbacks (single-replica scheduled systems).
    pub lpt_fallbacks: usize,
    /// Per-iteration cross-shard straggler gap (sharded systems).
    pub straggler_gaps: Vec<f64>,
    /// Items migrated across shards over the run (sharded systems).
    pub migrations: usize,
    /// Per-stage throughput samples pooled over iterations (Fig 14).
    pub stage_throughput_samples: Vec<f64>,
    /// Per-bucket module times pooled over iterations (Fig 4).
    pub bucket_enc_times: Vec<f64>,
    pub bucket_llm_times: Vec<f64>,
    /// Injected-fault counters (fault-injected fleet runs; all zero
    /// otherwise).
    pub fault: FaultStats,
    /// The observability recorder (`crate::obs`). Defaults to
    /// [`Recorder::Off`] — a zero-cost no-op — and is switched on by the
    /// engine from `RunConfig::obs`. Execution models and policies reach
    /// it through the `&mut Telemetry` they already receive.
    pub rec: Recorder,
}

impl Telemetry {
    pub fn new(iters: usize) -> Telemetry {
        Telemetry {
            iterations: Vec::with_capacity(iters),
            sched_elapsed: Vec::with_capacity(iters),
            straggler_gaps: Vec::with_capacity(iters),
            ..Telemetry::default()
        }
    }

    /// Fold one iteration boundary's fault-layer activity into the run's
    /// counters — the single place injected-fault telemetry is recorded.
    pub fn record_fault(&mut self, d: &FaultDelta) {
        self.rec.fault(d);
        self.fault.failures += d.failures;
        self.fault.recoveries += d.recoveries;
        self.fault.reshard_events += usize::from(d.resharded);
        self.fault.degraded_iters += usize::from(d.degraded);
    }

    /// Fold one executed iteration into the pooled distributions and
    /// retain its full stats.
    pub fn record_iteration(&mut self, stats: IterationStats) {
        self.rec.end_iteration(&stats);
        self.stage_throughput_samples.extend(stats.stage_throughputs());
        for b in &stats.buckets {
            if b.enc_time > 0.0 {
                self.bucket_enc_times.push(b.enc_time);
            }
            if b.llm_time > 0.0 {
                self.bucket_llm_times.push(b.llm_time);
            }
        }
        self.iterations.push(stats);
    }

    /// Assemble the [`RunResult`] — the single copy of the mean arithmetic
    /// that used to live at the tail of both training loops.
    #[allow(clippy::too_many_arguments)] // the offline-phase scalars are a run's identity
    pub fn finish(
        mut self,
        system: SystemKind,
        theta: Theta,
        n_gpus: usize,
        profiling_seconds: f64,
        optimizer_elapsed: Duration,
        replan_events: Vec<ReplanEvent>,
        hetero_thetas: Vec<Theta>,
    ) -> RunResult {
        let n = self.iterations.len().max(1) as f64;
        let mean_iter = self.iterations.iter().map(|s| s.iteration_time).sum::<f64>() / n;
        let mean_idle = self.iterations.iter().map(|s| s.total_idle()).sum::<f64>() / n;
        let mean_thr = self
            .iterations
            .iter()
            .map(|s| s.cluster_throughput())
            .sum::<f64>()
            / n;
        let replans = replan_events.iter().filter(|e| e.swapped).count();
        let obs = self.rec.take_log(&replan_events);
        let straggler_gap_percentiles = if self.straggler_gaps.is_empty() {
            Vec::new()
        } else {
            [0.5, 0.9, 0.99]
                .iter()
                .map(|&q| (q, quantile(&self.straggler_gaps, q)))
                .collect()
        };
        RunResult {
            system,
            theta,
            n_gpus,
            per_gpu_throughput: mean_thr / n_gpus as f64,
            mean_iteration_time: mean_iter,
            mean_idle,
            stage_throughput_samples: self.stage_throughput_samples,
            bucket_enc_times: self.bucket_enc_times,
            bucket_llm_times: self.bucket_llm_times,
            sched_elapsed: self.sched_elapsed,
            lpt_fallbacks: self.lpt_fallbacks,
            profiling_seconds,
            optimizer_elapsed,
            replans,
            replan_events,
            straggler_gaps: self.straggler_gaps,
            straggler_gap_percentiles,
            migrations: self.migrations,
            fault: self.fault,
            hetero_thetas,
            iterations: self.iterations,
            obs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::plan::ModPar;
    use crate::pipeline::build::BucketExec;

    fn theta() -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 1, dp: 1 },
            n_mb: 1,
        }
    }

    fn stats(iteration_time: f64) -> IterationStats {
        IterationStats {
            iteration_time,
            pipeline_makespan: iteration_time,
            dp_sync_time: 0.0,
            stage_busy: vec![iteration_time / 2.0],
            stage_idle: vec![iteration_time / 2.0],
            stage_flop: vec![4.0e12],
            n_stages: 1,
            total_flop: 4.0e12,
            buckets: vec![BucketExec {
                enc_time: 0.0,
                llm_time: iteration_time,
                enc_flop: 0.0,
                llm_flop: 4.0e12,
                llm_shape_bucket: 0,
            }],
            timeline: Vec::new(),
            fills: Vec::new(),
        }
    }

    #[test]
    fn finish_reproduces_the_loop_epilogue_arithmetic() {
        let mut t = Telemetry::new(2);
        t.record_iteration(stats(2.0));
        t.record_iteration(stats(4.0));
        let r = t.finish(
            SystemKind::Megatron,
            theta(),
            8,
            10.0,
            Duration::ZERO,
            Vec::new(),
            Vec::new(),
        );
        assert_eq!(r.mean_iteration_time, 3.0);
        assert_eq!(r.mean_idle, 1.5);
        // Mean cluster throughput over iterations, divided by GPUs.
        let thr = (4.0e12 / 2.0 + 4.0e12 / 4.0) / 2.0 / 8.0;
        assert_eq!(r.per_gpu_throughput.to_bits(), thr.to_bits());
        assert_eq!(r.iterations.len(), 2);
        assert_eq!(r.replans, 0);
        // Zero-time encoder buckets are filtered, LLM buckets kept.
        assert!(r.bucket_enc_times.is_empty());
        assert_eq!(r.bucket_llm_times, vec![2.0, 4.0]);
    }

    #[test]
    fn fault_counters_and_gap_percentiles_flow_into_the_result() {
        let mut t = Telemetry::new(4);
        t.record_fault(&FaultDelta {
            failures: 1,
            recoveries: 0,
            resharded: true,
            degraded: true,
        });
        t.record_fault(&FaultDelta {
            failures: 0,
            recoveries: 1,
            resharded: true,
            degraded: false,
        });
        t.straggler_gaps = vec![1.0, 4.0, 2.0, 3.0];
        for _ in 0..4 {
            t.record_iteration(stats(2.0));
        }
        let r = t.finish(
            SystemKind::DflopSharded,
            theta(),
            8,
            1.0,
            Duration::ZERO,
            Vec::new(),
            Vec::new(),
        );
        assert_eq!(r.fault.failures, 1);
        assert_eq!(r.fault.recoveries, 1);
        assert_eq!(r.fault.reshard_events, 2);
        assert_eq!(r.fault.degraded_iters, 1);
        let qs: Vec<f64> = r.straggler_gap_percentiles.iter().map(|&(q, _)| q).collect();
        assert_eq!(qs, vec![0.5, 0.9, 0.99]);
        let vs: Vec<f64> = r.straggler_gap_percentiles.iter().map(|&(_, v)| v).collect();
        assert!(vs.windows(2).all(|w| w[0] <= w[1]), "percentiles are monotone: {vs:?}");
        assert_eq!(r.straggler_gap_percentiles[0].1, 2.5, "median of the four gaps");
    }

    #[test]
    fn empty_run_does_not_divide_by_zero() {
        let t = Telemetry::new(0);
        let r = t.finish(
            SystemKind::Pytorch,
            theta(),
            8,
            1.0,
            Duration::ZERO,
            Vec::new(),
            Vec::new(),
        );
        assert_eq!(r.mean_iteration_time, 0.0);
        assert_eq!(r.per_gpu_throughput, 0.0);
    }
}
