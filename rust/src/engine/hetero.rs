//! Heterogeneous per-replica plans: fit one θ_s per DP shard, then assign.
//!
//! The sharded trainer fits a single θ* to the **pooled** distribution,
//! which is exactly wrong when shards draw from genuinely different data
//! (the `skewed-shard` scenario's video-heavy rank runs an image-tuned
//! encoder/LLM split at every barrier). This module is the ROADMAP's
//! "heterogeneous per-replica θ" item:
//!
//! 1. **Fit** ([`fit_per_shard`]): for each shard, refit Eq 1's `D` from
//!    the shard's own recent shapes (`stream::replan::live_profile`) and
//!    re-run the optimizer **warm-started from the global θ***
//!    (`optimize_warm`) — the incumbent is seeded into the refinement
//!    top-K, so the per-shard verdict already compares θ_s against the
//!    global plan under the *shard's* distribution. A shard whose data
//!    matches the pool keeps the global plan.
//! 2. **Assign** ([`assign_plans`]): any fitted plan can serve any
//!    replica. Each shard keeps its own optimizer verdict as the
//!    incumbent and only adopts another shard's fitted plan when the
//!    Phase-2-style proxy score ([`plan_score`]) — the `shard::balance`
//!    bi-metric load model (`ItemCost` pricing, LPT bottleneck) times the
//!    1F1B pipeline occupancy `(m + p − 1)` — is strictly better; ties
//!    keep the shard's own plan. The candidate sweep runs through the
//!    batched [`plan_scores`], which shares one priced cost table per
//!    distinct `(tp, pp)` key and memoizes the LPT bottleneck per
//!    `(key, m)` while staying bit-identical to per-candidate
//!    [`plan_score`] calls. The whole step is a pure function of the
//!    reservoirs, so assignments are deterministic across thread counts.
//!
//! Memory feasibility of every fitted θ_s is enforced by the optimizer at
//! the per-replica batch size; adopting a neighbour's plan keeps that
//! envelope because shards of one scenario share the per-replica GBS.
//!
//! The policy seam (`engine::policy::PerShardPolicy`) gates all of this
//! behind the `shard::agg` skew statistic: statistically identical shards
//! never trigger a fit, keeping the homogeneous control bit-identical to
//! the single-global-θ path with zero extra replans.

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::plan::Theta;
use crate::optimizer::search::optimize_warm;
use crate::profiling::estimator::Estimator;
use crate::scheduler::lpt::{lpt, lpt_table_into, Assignment, CostTable, ItemCost};
use crate::stream::replan::{live_profile, ReplanContext};
use crate::stream::reservoir::ShapeReservoir;
use std::collections::BTreeMap;

/// The widest per-GPU gradient slice θ ships through the cross-shard
/// ring (`shard::sync::grad_slices`, the allreduce's own byte term). The
/// allreduce runs at the pace of the widest slice among the replicas, so
/// a fitted plan is only eligible when its slice is no wider than the
/// global plan's — otherwise a per-shard pipeline win could be paid back
/// with interest at the gradient barrier every replica shares.
pub fn grad_slice_bytes(m: &Mllm, theta: Theta) -> f64 {
    let (enc, llm) = crate::shard::sync::grad_slices(m, theta);
    enc.max(llm)
}

/// Fit one θ_s per shard from the shard's reservoir, warm-started from
/// `global`. Shards with an empty reservoir, where the optimizer finds
/// nothing feasible under the live distribution, or whose fitted plan
/// would widen the cross-shard gradient slice (see [`grad_slice_bytes`])
/// keep the global plan.
pub fn fit_per_shard(
    rctx: &ReplanContext,
    global: Theta,
    reservoirs: &[ShapeReservoir],
) -> Vec<Theta> {
    let slice_cap = grad_slice_bytes(rctx.m, global);
    reservoirs
        .iter()
        .map(|res| {
            if res.is_empty() {
                return global;
            }
            let live = live_profile(rctx.m, res.shapes());
            match optimize_warm(&rctx.inputs(&live), Some(global)) {
                Some(r) if grad_slice_bytes(rctx.m, r.theta) <= slice_cap => r.theta,
                _ => global,
            }
        })
        .collect()
}

/// Phase-2-style makespan proxy of running `shapes` under `theta`: the
/// bi-metric LPT bottleneck over θ's microbatch buckets (the same
/// `ItemCost` pricing `shard::balance` and `shard::sync` use) scaled by
/// the 1F1B pipeline occupancy `(m + p − 1)`. Only used to *rank* plans
/// over the same shapes — the absolute value is not a time estimate.
pub fn plan_score(est: &Estimator, theta: Theta, shapes: &[ItemShape]) -> f64 {
    if shapes.is_empty() {
        return 0.0;
    }
    let items: Vec<ItemCost> = shapes
        .iter()
        .map(|s| ItemCost {
            enc: est.enc_item_dur(s, theta.enc.tp) / theta.enc.pp as f64,
            llm: est.llm_item_dur(s, theta.llm.tp) / theta.llm.pp as f64,
        })
        .collect();
    let m = theta.buckets().min(items.len());
    let a = lpt(&items, m);
    (m + theta.pipeline_depth() - 1) as f64 * a.c_max()
}

/// Batched [`plan_score`]: one proxy score per candidate over the same
/// `shapes`, sharing the expensive pieces across candidates instead of
/// recomputing them per call. Two tiers of sharing:
///
/// 1. **Pricing**: item costs depend only on `(enc.tp, enc.pp, llm.tp,
///    llm.pp)`, so one structure-of-arrays [`CostTable`] is priced per
///    distinct key and shared by every candidate carrying it.
/// 2. **Partition**: the LPT bottleneck depends only on `(key, m)` —
///    candidates that differ merely in `dp`/`n_mb` combinations yielding
///    the same bucket count reuse one memoized `c_max`.
///
/// Scores are bit-identical to calling [`plan_score`] per candidate, in
/// candidate order (asserted by `batched_plan_scores_bitmatch_serial`) —
/// [`assign_plans`] leans on that to keep its tie-breaking semantics.
pub fn plan_scores(est: &Estimator<'_>, cands: &[Theta], shapes: &[ItemShape]) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    if shapes.is_empty() {
        return vec![0.0; cands.len()];
    }
    let key_of = |t: &Theta| (t.enc.tp, t.enc.pp, t.llm.tp, t.llm.pp);
    let mut keys: Vec<(usize, usize, usize, usize)> = cands.iter().map(key_of).collect();
    keys.sort_unstable();
    keys.dedup();
    let tables: Vec<CostTable> = keys
        .iter()
        .map(|&(e_tp, e_pp, l_tp, l_pp)| {
            let mut t = CostTable::new();
            for s in shapes {
                t.push(
                    est.enc_item_dur(s, e_tp) / e_pp as f64,
                    est.llm_item_dur(s, l_tp) / l_pp as f64,
                );
            }
            t
        })
        .collect();
    let mut cmax: BTreeMap<(usize, usize), f64> = BTreeMap::new();
    let mut scratch = Assignment::default();
    cands
        .iter()
        .map(|t| {
            let ki = keys.binary_search(&key_of(t)).expect("key was collected");
            let m = t.buckets().min(shapes.len());
            let c = *cmax.entry((ki, m)).or_insert_with(|| {
                lpt_table_into(&tables[ki], m, &mut scratch);
                scratch.c_max()
            });
            (m + t.pipeline_depth() - 1) as f64 * c
        })
        .collect()
}

/// The deterministic assignment step: shard r's candidate list is its own
/// fitted plan first, then every *distinct* other fitted plan in shard
/// order; the proxy score picks the winner and ties keep the earliest
/// candidate (i.e. the shard's own optimizer verdict).
pub fn assign_plans(
    est: &Estimator,
    fitted: &[Theta],
    reservoirs: &[ShapeReservoir],
) -> Vec<Theta> {
    assert_eq!(fitted.len(), reservoirs.len(), "one fitted plan per shard");
    (0..fitted.len())
        .map(|r| {
            let shapes = reservoirs[r].shapes();
            let mut cands: Vec<Theta> = vec![fitted[r]];
            for &t in fitted {
                if !cands.contains(&t) {
                    cands.push(t);
                }
            }
            let scores = plan_scores(est, &cands, shapes);
            let mut best = (scores[0], 0usize);
            for (ci, &s) in scores.iter().enumerate().skip(1) {
                if s < best.0 {
                    best = (s, ci);
                }
            }
            cands[best.1]
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfiler, ProfilerGrids};

    fn theta(l_pp: usize, n_mb: usize) -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: l_pp, dp: 1 },
            n_mb,
        }
    }

    fn fixture() -> (crate::model::catalog::Mllm, crate::profiling::engine::ModelProfile)
    {
        let m = llava_ov(llama3("8b"));
        let mut backend = SimBackend::new(Truth::smooth(ClusterSpec::hgx_a100(1)));
        let p = ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        (m, p)
    }

    #[test]
    fn grad_slice_guard_rejects_narrower_model_parallelism() {
        // A plan with less model parallelism ships wider gradient slices
        // through the cross-shard ring: the guard must read it as wider
        // than the global plan, never narrower.
        let m = llava_ov(llama3("8b"));
        let wide = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 2, pp: 3, dp: 1 },
            n_mb: 4,
        };
        let narrow = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 1, dp: 7 },
            n_mb: 4,
        };
        assert!(grad_slice_bytes(&m, narrow) > grad_slice_bytes(&m, wide));
        // Same model-parallel widths ⇒ identical slices, dp laid aside.
        let mut redp = wide;
        redp.llm.dp = 2;
        assert_eq!(
            grad_slice_bytes(&m, redp).to_bits(),
            grad_slice_bytes(&m, wide).to_bits()
        );
    }

    #[test]
    fn plan_score_is_deterministic_and_positive() {
        let (m, p) = fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(11).shaped_batch(&m, 24);
        let a = plan_score(&est, theta(3, 4), &shapes);
        let b = plan_score(&est, theta(3, 4), &shapes);
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), b.to_bits());
        assert_eq!(plan_score(&est, theta(3, 4), &[]), 0.0);
    }

    #[test]
    fn batched_plan_scores_bitmatch_serial() {
        // The batched evaluator must reproduce per-candidate plan_score
        // bit-for-bit, in candidate order, including duplicate candidates
        // and candidates sharing a pricing key but not a bucket count.
        let (m, p) = fixture();
        let est = Estimator::new(&m, &p.throughput);
        let mut ds = Dataset::mixed(21);
        crate::util::prop::forall("plan_scores = plan_score", 30, |g| {
            let shapes = ds.shaped_batch(&m, g.size(24));
            let n_c = g.size(8);
            let mut cands: Vec<Theta> = (0..n_c)
                .map(|_| Theta {
                    enc: ModPar { tp: 1 << g.rng.index(2), pp: g.size(2), dp: 1 },
                    llm: ModPar { tp: 1 << g.rng.index(2), pp: g.size(4), dp: 1 },
                    n_mb: g.size(12),
                })
                .collect();
            cands.push(cands[0]); // forced duplicate
            let batch = plan_scores(&est, &cands, &shapes);
            let ok = batch.len() == cands.len()
                && cands.iter().zip(&batch).all(|(&t, &s)| {
                    s.to_bits() == plan_score(&est, t, &shapes).to_bits()
                });
            (format!("shapes={} cands={}", shapes.len(), cands.len()), ok)
        });
    }

    #[test]
    fn plan_scores_degenerate_inputs() {
        let (m, p) = fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(11).shaped_batch(&m, 8);
        assert!(plan_scores(&est, &[], &shapes).is_empty());
        let cands = [theta(3, 4), theta(2, 8)];
        assert_eq!(plan_scores(&est, &cands, &[]), vec![0.0, 0.0]);
    }

    #[test]
    fn identical_fits_assign_identically() {
        let (m, p) = fixture();
        let est = Estimator::new(&m, &p.throughput);
        let mut res = Vec::new();
        let mut ds = Dataset::mixed(7);
        for _ in 0..3 {
            let mut r = ShapeReservoir::new(64);
            r.extend(&ds.shaped_batch(&m, 32));
            res.push(r);
        }
        let g = theta(3, 4);
        let assigned = assign_plans(&est, &[g, g, g], &res);
        assert_eq!(assigned, vec![g, g, g]);
    }

    #[test]
    fn assignment_keeps_own_fit_on_ties() {
        // Two shards with identical reservoirs but distinct fitted plans
        // whose proxy scores differ: both shards must converge on the
        // strictly-better plan, and exact ties keep the shard's own fit.
        let (m, p) = fixture();
        let est = Estimator::new(&m, &p.throughput);
        let mut ds = Dataset::mixed(9);
        let batch = ds.shaped_batch(&m, 48);
        let mut r0 = ShapeReservoir::new(64);
        r0.extend(&batch);
        let mut r1 = ShapeReservoir::new(64);
        r1.extend(&batch);
        let a = theta(3, 4);
        let b = theta(3, 12);
        let sa = plan_score(&est, a, r0.shapes());
        let sb = plan_score(&est, b, r0.shapes());
        assert_ne!(sa.to_bits(), sb.to_bits(), "degenerate fixture");
        let better = if sa < sb { a } else { b };
        let assigned = assign_plans(&est, &[a, b], &[r0, r1]);
        assert_eq!(assigned, vec![better, better]);
    }
}
