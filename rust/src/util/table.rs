//! Plain-text table rendering for the figure/benchmark harness.
//!
//! Every paper table/figure reproduction prints its rows through this
//! formatter so the output is uniform, aligned, and easy to diff against
//! EXPERIMENTS.md.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            // First column left-aligned (labels), the rest right-aligned
            // (numbers) by default.
            aligns: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            rows: Vec::new(),
        }
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        self.aligns[col] = a;
        self
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render to a string with unicode box rules.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        let fmt_row = |cells: &[String], widths: &[usize], aligns: &[Align]| {
            let mut line = String::from("|");
            for i in 0..ncols {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        line.push_str(&format!(" {}{} |", cells[i], " ".repeat(pad)))
                    }
                    Align::Right => {
                        line.push_str(&format!(" {}{} |", " ".repeat(pad), cells[i]))
                    }
                }
            }
            line
        };
        let rule: String = {
            let mut r = String::from("+");
            for w in &widths {
                r.push_str(&"-".repeat(w + 2));
                r.push('+');
            }
            r
        };
        out.push_str(&rule);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &widths, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths, &self.aligns));
            out.push('\n');
        }
        out.push_str(&rule);
        out.push('\n');
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format a float with `digits` decimal places.
pub fn f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

/// Format a speedup factor like `3.6x`.
pub fn speedup(x: f64) -> String {
    format!("{x:.2}x")
}

/// Format seconds human-readably (ns/µs/ms/s/h as appropriate).
pub fn secs(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.0}ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.1}µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.1}ms", t * 1e3)
    } else if t < 120.0 {
        format!("{t:.2}s")
    } else if t < 7200.0 {
        format!("{:.1}min", t / 60.0)
    } else {
        format!("{:.1}h", t / 3600.0)
    }
}

/// Format a byte count (GiB/MiB/...).
pub fn bytes(b: f64) -> String {
    const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
    const MIB: f64 = 1024.0 * 1024.0;
    if b >= GIB {
        format!("{:.2}GiB", b / GIB)
    } else if b >= MIB {
        format!("{:.1}MiB", b / MIB)
    } else {
        format!("{:.0}B", b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["alpha".into(), "1.0".into()]);
        t.row(vec!["b".into(), "123.45".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| alpha |"));
        // Right-aligned numbers share the right edge.
        let lines: Vec<&str> = s.lines().collect();
        let w = lines[1].len();
        assert!(lines.iter().skip(1).all(|l| l.len() == w), "{s}");
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn rejects_wrong_width() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }

    #[test]
    fn humanized_formats() {
        assert_eq!(secs(0.5e-7), "50ns");
        assert_eq!(secs(2.5e-4), "250.0µs");
        assert_eq!(secs(0.25), "250.0ms");
        assert_eq!(secs(2.0), "2.00s");
        assert_eq!(secs(180.0), "3.0min");
        assert_eq!(secs(7200.0), "2.0h");
        assert_eq!(speedup(3.6), "3.60x");
        assert_eq!(bytes(2.0 * 1024.0 * 1024.0 * 1024.0), "2.00GiB");
    }
}
