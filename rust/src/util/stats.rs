//! Descriptive statistics, histograms and boxplot summaries.
//!
//! The figure harness reproduces several distribution-shaped exhibits from
//! the paper (Fig 4 stage-duration histograms, Fig 11b input-shape
//! distributions, Fig 14 stage-throughput boxplots); this module provides the
//! shared summarization machinery.

/// Summary statistics of a sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    /// Compute all summary statistics of `xs`. Panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty slice");
        let mut sorted: Vec<f64> = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(1) as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p25: quantile_sorted(&sorted, 0.25),
            p50: quantile_sorted(&sorted, 0.50),
            p75: quantile_sorted(&sorted, 0.75),
            p95: quantile_sorted(&sorted, 0.95),
            p99: quantile_sorted(&sorted, 0.99),
        }
    }

    /// Coefficient of variation (std / mean); 0 for a zero-mean sample.
    pub fn cv(&self) -> f64 {
        if self.mean.abs() < f64::EPSILON {
            0.0
        } else {
            self.std / self.mean
        }
    }

    /// Interquartile range.
    pub fn iqr(&self) -> f64 {
        self.p75 - self.p25
    }
}

/// Linear-interpolated quantile of a pre-sorted sample, q in [0,1].
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Quantile of an unsorted sample.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    let mut sorted: Vec<f64> = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
    quantile_sorted(&sorted, q)
}

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Fixed-width histogram over `[lo, hi)` with `bins` buckets.
///
/// Out-of-range samples are clamped into the first/last bucket so the mass
/// always sums to the sample count (the figure harness relies on this).
#[derive(Clone, Debug)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub total: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Histogram {
        assert!(hi > lo && bins > 0);
        Histogram { lo, hi, counts: vec![0; bins], total: 0 }
    }

    /// Build a histogram spanning the data range of `xs`.
    pub fn of(xs: &[f64], bins: usize) -> Histogram {
        let (lo, hi) = (min(xs), max(xs));
        let hi = if hi > lo { hi } else { lo + 1.0 };
        let mut h = Histogram::new(lo, hi + f64::EPSILON, bins);
        for &x in xs {
            h.add(x);
        }
        h
    }

    pub fn add(&mut self, x: f64) {
        let bins = self.counts.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64) as i64;
        let idx = idx.clamp(0, bins as i64 - 1) as usize;
        self.counts[idx] += 1;
        self.total += 1;
    }

    /// Bucket center values.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }

    /// Normalized densities (fractions summing to 1).
    pub fn densities(&self) -> Vec<f64> {
        if self.total == 0 {
            return vec![0.0; self.counts.len()];
        }
        self.counts
            .iter()
            .map(|&c| c as f64 / self.total as f64)
            .collect()
    }

    /// Render as a unicode sparkline for terminal figure output.
    pub fn sparkline(&self) -> String {
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let peak = self.counts.iter().cloned().max().unwrap_or(0).max(1);
        self.counts
            .iter()
            .map(|&c| BARS[(c * 7 / peak) as usize])
            .collect()
    }
}

/// Five-number boxplot summary (used by the Fig 14 reproduction).
#[derive(Clone, Debug)]
pub struct BoxPlot {
    pub whisker_lo: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub whisker_hi: f64,
    pub outliers: Vec<f64>,
}

impl BoxPlot {
    /// Tukey boxplot: whiskers at the most extreme points within 1.5·IQR.
    pub fn of(xs: &[f64]) -> BoxPlot {
        let s = Summary::of(xs);
        let iqr = s.iqr();
        let lo_fence = s.p25 - 1.5 * iqr;
        let hi_fence = s.p75 + 1.5 * iqr;
        let mut whisker_lo = f64::INFINITY;
        let mut whisker_hi = f64::NEG_INFINITY;
        let mut outliers = Vec::new();
        for &x in xs {
            if x < lo_fence || x > hi_fence {
                outliers.push(x);
            } else {
                whisker_lo = whisker_lo.min(x);
                whisker_hi = whisker_hi.max(x);
            }
        }
        BoxPlot {
            whisker_lo,
            q1: s.p25,
            median: s.p50,
            q3: s.p75,
            whisker_hi,
            outliers,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.min, 2.0);
        assert_eq!(s.max, 2.0);
    }

    #[test]
    fn summary_of_range() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = Summary::of(&xs);
        assert!((s.mean - 50.0).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() < 1e-9);
        assert!((s.p25 - 25.0).abs() < 1e-9);
        assert!((s.p75 - 75.0).abs() < 1e-9);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = vec![0.0, 10.0];
        assert!((quantile(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 0.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_mass_conserved() {
        let xs: Vec<f64> = (0..1000).map(|i| (i % 37) as f64).collect();
        let h = Histogram::of(&xs, 8);
        assert_eq!(h.total, 1000);
        assert_eq!(h.counts.iter().sum::<u64>(), 1000);
    }

    #[test]
    fn histogram_clamps_out_of_range() {
        let mut h = Histogram::new(0.0, 10.0, 5);
        h.add(-100.0);
        h.add(100.0);
        assert_eq!(h.counts[0], 1);
        assert_eq!(h.counts[4], 1);
    }

    #[test]
    fn boxplot_flags_outliers() {
        let mut xs = vec![1.0; 50];
        xs.extend_from_slice(&[2.0; 50]);
        xs.push(100.0);
        let b = BoxPlot::of(&xs);
        assert_eq!(b.outliers, vec![100.0]);
        assert!(b.whisker_hi <= 2.0 + 1e-9);
    }

    #[test]
    fn sparkline_len_matches_bins() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let h = Histogram::of(&xs, 12);
        assert_eq!(h.sparkline().chars().count(), 12);
    }
}
