//! Minimal dynamic error type for fallible paths (artifact loading, CLI,
//! the PJRT runtime).
//!
//! `anyhow` is not in the offline vendor set, so the crate carries the
//! small subset it actually uses: a string-backed [`Error`], a [`Result`]
//! alias, a [`Context`] extension trait, and the [`err!`](crate::err) /
//! [`bail!`](crate::bail) macros. Like `anyhow::Error`, [`Error`] does
//! *not* implement `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` conversion (and therefore `?` on any
//! standard error) coherent.

use std::fmt;

/// A type-erased, message-carrying error.
pub struct Error(String);

impl Error {
    /// Construct from any displayable message.
    pub fn msg(m: impl Into<String>) -> Error {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

/// Crate-wide result alias (mirrors `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error as it propagates (`anyhow::Context` subset).
pub trait Context<T> {
    /// Prefix the error with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    /// Prefix the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", ctx())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, ctx: F) -> Result<T> {
        self.ok_or_else(|| Error(ctx().to_string()))
    }
}

/// Construct an [`Error`] from a format string (`anyhow::anyhow!` analogue).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] (`anyhow::bail!` analogue).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn question_mark_converts_standard_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("17").unwrap(), 17);
        assert!(parse("nope").is_err());
    }

    #[test]
    fn context_prefixes_messages() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "gone",
        ));
        let e = r.context("reading manifest").unwrap_err();
        let text = e.to_string();
        assert!(text.starts_with("reading manifest: "), "{text}");
        assert!(text.contains("gone"), "{text}");
    }

    #[test]
    fn option_context_and_macros() {
        fn pick(v: Option<u8>) -> Result<u8> {
            let x = v.context("missing value")?;
            if x > 10 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(pick(Some(3)).unwrap(), 3);
        assert_eq!(pick(None).unwrap_err().to_string(), "missing value");
        assert_eq!(pick(Some(11)).unwrap_err().to_string(), "too big: 11");
        assert_eq!(err!("x={}", 5).to_string(), "x=5");
    }
}
