//! Dependency-free scoped thread pool for the evaluation substrate.
//!
//! `rayon` is not in the offline vendor set, so this module carries the
//! minimal parallel-iteration primitives the hot paths need: [`par_map`] /
//! [`par_for_each`] over index ranges, executed by scoped worker threads
//! that self-schedule chunks from a shared atomic index queue (chunked
//! work stealing — an idle worker keeps claiming the next chunk until the
//! range is drained, so stragglers cannot leave cores idle).
//!
//! **Determinism contract.** Output order is by index, never by completion
//! order, and callers hand out independent per-task RNG streams (see
//! `util::rng::Rng::fork` and the per-cell seeding in `sim::trainer`), so
//! every result is bit-identical to the serial path regardless of thread
//! count. The determinism test suite (`tests/determinism.rs`) enforces
//! this for the optimizer, the simulator, and the ILP scheduler.
//!
//! **Nesting.** Worker threads mark themselves, and any `par_map` issued
//! from inside a worker runs serially in place: outer parallelism (e.g. a
//! figure's evaluation grid) claims the cores, inner parallelism (the
//! optimizer scan inside one cell) degrades to the serial path instead of
//! oversubscribing the machine.
//!
//! The pool size comes from, in order: [`set_max_threads`] (the `--threads`
//! CLI flag), the `DFLOP_THREADS` environment variable, and
//! `std::thread::available_parallelism()`.

use std::cell::Cell;
use std::panic::resume_unwind;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Configured pool width; 0 means "not yet resolved" (auto-detect).
static MAX_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on pool worker threads: nested calls run serially.
    static IN_POOL: Cell<bool> = const { Cell::new(false) };
}

fn detect_threads() -> usize {
    if let Ok(v) = std::env::var("DFLOP_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The number of worker threads parallel sections may use.
pub fn max_threads() -> usize {
    let n = MAX_THREADS.load(Ordering::Relaxed);
    if n != 0 {
        return n;
    }
    let detected = detect_threads();
    // First caller wins; later callers read a stable value.
    let _ = MAX_THREADS.compare_exchange(0, detected, Ordering::Relaxed, Ordering::Relaxed);
    MAX_THREADS.load(Ordering::Relaxed)
}

/// Set the pool width (the `--threads` flag). `0` resets to auto-detect.
pub fn set_max_threads(n: usize) {
    MAX_THREADS.store(n, Ordering::Relaxed);
}

/// Map `f` over `0..n` on the pool; results are returned in index order.
///
/// Falls back to a plain serial map when the pool is width 1, the range is
/// trivial, or the caller is itself a pool worker (nested section). A
/// panic in any task is propagated to the caller after all workers have
/// drained.
pub fn par_map<R, F>(n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let threads = max_threads().min(n);
    if threads <= 1 || IN_POOL.with(|c| c.get()) {
        return (0..n).map(f).collect();
    }
    // ~4 chunks per worker: coarse enough to amortize queue traffic, fine
    // enough that one slow chunk cannot serialize the tail.
    let chunk = n.div_ceil(threads * 4).max(1);
    let next = AtomicUsize::new(0);
    let next = &next;
    let f = &f;
    std::thread::scope(|s| {
        let workers: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    IN_POOL.with(|c| c.set(true));
                    let mut part: Vec<(usize, R)> = Vec::new();
                    loop {
                        let start = next.fetch_add(chunk, Ordering::Relaxed);
                        if start >= n {
                            break;
                        }
                        let end = (start + chunk).min(n);
                        for i in start..end {
                            part.push((i, f(i)));
                        }
                    }
                    part
                })
            })
            .collect();
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut panic = None;
        for w in workers {
            match w.join() {
                Ok(part) => {
                    for (i, v) in part {
                        slots[i] = Some(v);
                    }
                }
                Err(p) => panic = Some(p),
            }
        }
        if let Some(p) = panic {
            resume_unwind(p);
        }
        slots
            .into_iter()
            .map(|slot| slot.expect("every index produced exactly once"))
            .collect()
    })
}

/// Run `f` for every index in `0..n` on the pool (no results collected).
pub fn par_for_each<F>(n: usize, f: F)
where
    F: Fn(usize) + Sync,
{
    par_map(n, f);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn matches_serial_map() {
        let par = par_map(257, |i| i * i + 1);
        let ser: Vec<usize> = (0..257).map(|i| i * i + 1).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn empty_range_yields_empty_vec() {
        let out: Vec<u64> = par_map(0, |_| unreachable!("no tasks"));
        assert!(out.is_empty());
    }

    #[test]
    fn single_element_runs_inline() {
        assert_eq!(par_map(1, |i| i + 41), vec![41]);
    }

    #[test]
    fn propagates_worker_panics() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            par_map(64, |i| {
                if i == 23 {
                    panic!("task 23 exploded");
                }
                i
            })
        }));
        assert!(r.is_err(), "panic must cross the pool boundary");
    }

    #[test]
    fn nested_sections_run_serially_and_correctly() {
        let out = par_map(8, |i| par_map(8, |j| i * 8 + j).iter().sum::<usize>());
        let expect: Vec<usize> = (0..8).map(|i| (0..8).map(|j| i * 8 + j).sum()).collect();
        assert_eq!(out, expect);
    }

    #[test]
    fn for_each_visits_every_index_once() {
        let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
        par_for_each(100, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    // The thread-width-independence contract is deliberately NOT tested
    // here: flipping the process-global width would race against the
    // crate's other unit tests. The cross-width bitwise checks live in
    // tests/determinism.rs, which serializes every flip behind WIDTH_LOCK
    // in its own test binary.
}
