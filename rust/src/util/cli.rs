//! Minimal command-line argument parser.
//!
//! `clap` is not in the offline vendor set; the launcher only needs
//! subcommands plus `--flag value` / `--flag=value` / boolean switches, so we
//! implement exactly that. Unknown flags are an error (catches typos in
//! experiment scripts).

use std::collections::BTreeMap;

/// Parsed command line: a subcommand, `--key value` options, boolean
/// switches, and positional arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

/// Declares which flags a (sub)command accepts, so unknown flags fail fast.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    /// Flags that take a value, e.g. `--seed 42`.
    pub valued: Vec<&'static str>,
    /// Boolean switches, e.g. `--verbose`.
    pub boolean: Vec<&'static str>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Args {
    /// Parse raw arguments (without argv[0]) against a spec.
    ///
    /// The first non-flag token becomes the subcommand; later non-flag
    /// tokens are positional.
    pub fn parse<I, S>(raw: I, spec: &Spec) -> Result<Args, CliError>
    where
        I: IntoIterator<Item = S>,
        S: Into<String>,
    {
        let mut args = Args::default();
        let mut iter = raw.into_iter().map(Into::into).peekable();
        while let Some(tok) = iter.next() {
            if let Some(flag) = tok.strip_prefix("--") {
                // `--key=value` form.
                if let Some((key, value)) = flag.split_once('=') {
                    if !spec.valued.contains(&key) {
                        return Err(CliError(format!("unknown option --{key}")));
                    }
                    args.options.insert(key.to_string(), value.to_string());
                    continue;
                }
                if spec.boolean.contains(&flag) {
                    args.switches.push(flag.to_string());
                } else if spec.valued.contains(&flag) {
                    match iter.next() {
                        Some(v) => {
                            args.options.insert(flag.to_string(), v);
                        }
                        None => {
                            return Err(CliError(format!(
                                "option --{flag} requires a value"
                            )))
                        }
                    }
                } else {
                    return Err(CliError(format!("unknown option --{flag}")));
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option access with parse-error reporting.
    pub fn get_parsed<T: std::str::FromStr>(
        &self,
        key: &str,
    ) -> Result<Option<T>, CliError> {
        match self.get(key) {
            None => Ok(None),
            Some(raw) => raw.parse::<T>().map(Some).map_err(|_| {
                CliError(format!("option --{key}: cannot parse '{raw}'"))
            }),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        Ok(self.get_parsed::<usize>(key)?.unwrap_or(default))
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        Ok(self.get_parsed::<u64>(key)?.unwrap_or(default))
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        Ok(self.get_parsed::<f64>(key)?.unwrap_or(default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec {
            valued: vec!["seed", "fig", "nodes"],
            boolean: vec!["verbose", "json"],
        }
    }

    #[test]
    fn parses_subcommand_and_options() {
        let a = Args::parse(
            ["figures", "--fig", "7", "--seed=99", "--verbose"],
            &spec(),
        )
        .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("figures"));
        assert_eq!(a.get("fig"), Some("7"));
        assert_eq!(a.get("seed"), Some("99"));
        assert!(a.has("verbose"));
        assert!(!a.has("json"));
    }

    #[test]
    fn typed_access() {
        let a = Args::parse(["x", "--nodes", "8"], &spec()).unwrap();
        assert_eq!(a.get_usize("nodes", 1).unwrap(), 8);
        assert_eq!(a.get_usize("seed", 7).unwrap(), 7);
    }

    #[test]
    fn rejects_unknown_flag() {
        assert!(Args::parse(["x", "--bogus"], &spec()).is_err());
        assert!(Args::parse(["x", "--bogus=1"], &spec()).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(Args::parse(["x", "--seed"], &spec()).is_err());
    }

    #[test]
    fn rejects_bad_parse() {
        let a = Args::parse(["x", "--nodes", "eight"], &spec()).unwrap();
        assert!(a.get_usize("nodes", 1).is_err());
    }

    #[test]
    fn positional_after_subcommand() {
        let a = Args::parse(["run", "conf.toml", "more"], &spec()).unwrap();
        assert_eq!(a.positional, vec!["conf.toml", "more"]);
    }
}
