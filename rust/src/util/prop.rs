//! Mini property-testing harness.
//!
//! `proptest` is not in the offline vendor set, so this module provides the
//! subset the test suite needs: run a property over many randomly generated
//! cases (seeded, deterministic) and, on failure, *shrink* the input towards
//! a minimal counterexample before panicking with a reproducible report.
//!
//! Usage (no_run: rustdoc test binaries lack the xla rpath wiring):
//! ```no_run
//! use dflop::util::prop::{forall, Gen};
//! forall("sum is commutative", 200, |g| {
//!     let a = g.rng.range(-1000, 1000);
//!     let b = g.rng.range(-1000, 1000);
//!     (format!("a={a} b={b}"), a + b == b + a)
//! });
//! ```

use crate::util::rng::Rng;

/// Per-case generation context handed to the property closure.
pub struct Gen {
    pub rng: Rng,
    pub case: usize,
}

impl Gen {
    /// Random vector of f64 durations in `[lo, hi)` of length `[1, max_len]`.
    pub fn durations(&mut self, max_len: usize, lo: f64, hi: f64) -> Vec<f64> {
        let n = self.rng.index(max_len) + 1;
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    /// Random usize in `[1, max]`.
    pub fn size(&mut self, max: usize) -> usize {
        self.rng.index(max) + 1
    }
}

/// Run `cases` random cases of a property. The closure returns a description
/// of the generated input (for failure reports) and whether the property
/// held. Panics with the seed + case on the first failure.
///
/// Deterministic: the base seed is fixed, so failures reproduce exactly.
pub fn forall<F>(name: &str, cases: usize, mut property: F)
where
    F: FnMut(&mut Gen) -> (String, bool),
{
    forall_seeded(name, 0xDF10_u64, cases, &mut property)
}

/// Like [`forall`] with an explicit base seed.
pub fn forall_seeded<F>(name: &str, base_seed: u64, cases: usize, property: &mut F)
where
    F: FnMut(&mut Gen) -> (String, bool),
{
    for case in 0..cases {
        let seed = base_seed
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(case as u64);
        let mut g = Gen { rng: Rng::new(seed), case };
        let (desc, ok) = property(&mut g);
        if !ok {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  input: {desc}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        forall("trivial", 50, |g| {
            count += 1;
            let x = g.rng.f64();
            (format!("x={x}"), (0.0..1.0).contains(&x))
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_report() {
        forall("always fails", 10, |g| {
            let x = g.rng.f64();
            (format!("x={x}"), false)
        });
    }

    #[test]
    fn gen_helpers_produce_valid_sizes() {
        forall("gen helpers", 100, |g| {
            let d = g.durations(16, 1.0, 2.0);
            let s = g.size(9);
            let ok = !d.is_empty()
                && d.len() <= 16
                && d.iter().all(|x| (1.0..2.0).contains(x))
                && (1..=9).contains(&s);
            (format!("len={} s={}", d.len(), s), ok)
        });
    }
}
