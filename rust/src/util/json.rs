//! Minimal JSON parser and emitter.
//!
//! The build-time python layer (`python/compile/aot.py`) describes the AOT
//! artifacts it produced — shapes, parameter counts, HLO file names — in a
//! `artifacts/manifest.json`. `serde`/`serde_json` are not in the offline
//! vendor set, so we implement the small JSON subset the manifest needs:
//! objects, arrays, strings (with escapes), numbers, booleans, null.
//!
//! This is a strict recursive-descent parser over UTF-8 text; errors carry a
//! byte offset for debugging malformed manifests.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Object keys are ordered (BTreeMap) so emission is
/// deterministic, which keeps artifact manifests diff-stable.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access; returns None for non-objects / missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// `obj["a"]["b"][...]` convenience with a dotted path.
    pub fn path(&self, dotted: &str) -> Option<&Json> {
        let mut cur = self;
        for part in dotted.split('.') {
            cur = cur.get(part)?;
        }
        Some(cur)
    }

    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            entries
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err<T>(&self, msg: impl Into<String>) -> Result<T, JsonError> {
        Err(JsonError { offset: self.pos, message: msg.into() })
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            self.err(format!("expected '{}'", b as char))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            self.err(format!("expected '{word}'"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => self.err(format!("unexpected byte '{}'", c as char)),
            None => self.err("unexpected end of input"),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or '}'");
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return self.err("expected ',' or ']'");
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return self.err("unterminated string"),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs for completeness.
                        let ch = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return self.err("unpaired surrogate");
                            }
                            let lo = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        match ch {
                            Some(c) => out.push(c),
                            None => return self.err("invalid unicode escape"),
                        }
                    }
                    _ => return self.err("bad escape"),
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Re-decode multibyte UTF-8 in place.
                    let start = self.pos - 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        0xF0..=0xF7 => 4,
                        _ => return self.err("invalid utf-8"),
                    };
                    let end = start + width;
                    if end > self.bytes.len() {
                        return self.err("truncated utf-8");
                    }
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => {
                            out.push_str(s);
                            self.pos = end;
                        }
                        Err(_) => return self.err("invalid utf-8"),
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = match self.bump() {
                Some(b) => b,
                None => return self.err("truncated \\u escape"),
            };
            let d = match b {
                b'0'..=b'9' => (b - b'0') as u32,
                b'a'..=b'f' => (b - b'a' + 10) as u32,
                b'A'..=b'F' => (b - b'A' + 10) as u32,
                _ => return self.err("bad hex digit"),
            };
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| JsonError { offset: start, message: "bad number".into() })?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError { offset: start, message: "bad number".into() })
    }
}

/// Parse a JSON document. Trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return p.err("trailing garbage");
    }
    Ok(v)
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn emit_into(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(items) => {
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                emit_into(item, out);
            }
            out.push(']');
        }
        Json::Obj(map) => {
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                emit_into(val, out);
            }
            out.push('}');
        }
    }
}

/// Serialize a JSON value compactly (deterministic key order).
pub fn emit(v: &Json) -> String {
    let mut out = String::new();
    emit_into(v, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let doc = r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.path("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path("c").unwrap().as_str().unwrap(), "x\ny");
    }

    #[test]
    fn round_trips() {
        let doc = r#"{"shapes":[[2,128],[4,256]],"name":"train_step","ok":true,"loss":0.125}"#;
        let v = parse(doc).unwrap();
        let emitted = emit(&v);
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
    }

    #[test]
    fn utf8_passthrough() {
        let v = parse("\"héllo — 😀\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — 😀");
        assert_eq!(parse(&emit(&v)).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(emit(&Json::Num(7.0)), "7");
        assert_eq!(emit(&Json::Num(0.5)), "0.5");
    }

    #[test]
    fn dotted_path() {
        let v = parse(r#"{"a":{"b":{"c":9}}}"#).unwrap();
        assert_eq!(v.path("a.b.c").unwrap().as_i64().unwrap(), 9);
        assert!(v.path("a.x").is_none());
    }
}
