//! Deterministic pseudo-random number generation.
//!
//! The crate cannot depend on `rand` (offline vendor set only covers the
//! `xla` dependency closure), so we implement the small set of generators and
//! distributions the workload synthesizer and the property-test harness need:
//! a SplitMix64 seeder and an xoshiro256** core generator, plus uniform /
//! normal / log-normal / categorical sampling.
//!
//! Everything here is deterministic given a seed: every experiment, figure,
//! and property test in the repository is reproducible bit-for-bit.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main generator. Fast, high quality, tiny state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal variate from the Box–Muller transform.
    spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            spare_normal: None,
        }
    }

    /// Derive an independent child generator (for parallel / per-module
    /// streams) without correlating with the parent's future output.
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (unbiased via rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "range lo > hi");
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform usize in `[0, n)`.
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (caching the spare variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.spare_normal = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal parameterized by the mean/std of the *underlying* normal.
    /// Heavy-tailed: used for video frame counts and text lengths.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Sample an index from unnormalized non-negative weights. Indices
    /// with zero weight are never returned (scheduled mixtures rely on
    /// this to drop a source completely).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical: zero total weight");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        // Floating-point rounding can let x survive the subtraction loop;
        // fall back to the last *positively weighted* index so a
        // zero-weight tail entry can never be emitted.
        weights
            .iter()
            .rposition(|&w| w > 0.0)
            .expect("total > 0 implies a positive weight")
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5) as usize] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(9);
        let w = [1.0, 3.0];
        let mut ones = 0usize;
        for _ in 0..40_000 {
            if r.categorical(&w) == 1 {
                ones += 1;
            }
        }
        let frac = ones as f64 / 40_000.0;
        assert!((frac - 0.75).abs() < 0.02, "frac {frac}");
    }

    #[test]
    fn categorical_never_returns_zero_weight_entries() {
        // Zero-weight slots — including a zero tail, which the fallback
        // branch must skip — are never sampled.
        let mut r = Rng::new(31);
        let w = [2.0, 0.0, 1.0, 0.0];
        for _ in 0..20_000 {
            let i = r.categorical(&w);
            assert!(i == 0 || i == 2, "sampled zero-weight index {i}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(13);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_decorrelates() {
        let mut a = Rng::new(21);
        let mut c = a.fork();
        let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
        assert!(same < 2);
    }
}
