//! Shared substrates: deterministic RNG, statistics, JSON, CLI parsing,
//! property testing, and table rendering.
//!
//! These exist because the offline build environment vendors only the `xla`
//! crate's dependency closure — `rand`, `serde`, `clap`, `proptest`,
//! `criterion` are unavailable, so the library carries minimal from-scratch
//! equivalents (see DESIGN.md "Reproduction posture").

pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
