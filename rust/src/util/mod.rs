//! Shared substrates: deterministic RNG, statistics, JSON, CLI parsing,
//! property testing, table rendering, error handling, and the scoped
//! thread pool.
//!
//! These exist because the offline build environment vendors only the `xla`
//! crate's dependency closure — `rand`, `serde`, `clap`, `proptest`,
//! `criterion`, `anyhow`, `rayon` are unavailable, so the library carries
//! minimal from-scratch equivalents (see DESIGN.md "Reproduction posture").

pub mod cli;
pub mod error;
pub mod json;
pub mod parallel;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod table;
