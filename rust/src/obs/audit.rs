//! Predicted-vs-measured plan audit and counterfactual replan
//! attribution — the evidence layer that closes the paper's
//! profile → predict → schedule loop.
//!
//! Two questions, both answered post-run from recorded data only:
//!
//! 1. **How good were the predictions?** For every iteration whose
//!    realized global batch was recorded ([`ObsConfig::audit`]), the
//!    batch is re-priced under the plan that actually executed it
//!    using the same `profiling::estimator` packed-microbatch frame
//!    the optimizer scored candidates with ([`CfPricer`]). The
//!    residual against the simulator's measured step time — bucketed
//!    by modality mix and plan epoch — quantifies estimator error
//!    *plus* everything the comm-free evaluator frame deliberately
//!    ignores (pipeline hops, DP sync), which is exactly the gap a
//!    predictive scheduler rides on.
//! 2. **Did each replan pay off?** At every plan swap the *incumbent*
//!    θ is counterfactually re-priced over the realized batches the
//!    *new* plan executed, via PR-6 cost-only edits
//!    (`SimWorkspace::update_leg` + `delta_run` — no fresh
//!    simulation), so the swap gains a measured benefit next to the
//!    optimizer's predicted one
//!    (`ReplanEvent::expected_incumbent − expected_makespan`).
//!
//! **Bit-exactness contract.** The counterfactual pricer's delta
//! replay is bit-identical to a fresh full simulation of the same
//! plan over the same realized batches (property-tested): both paths
//! write the same leg costs through `optimizer::batch::write_slot_legs`
//! (the one leg-layout definition, shared with the batch evaluator)
//! and the event core's replay recomputes with the operand order of
//! the original run. Everything here runs after the simulation on the
//! engine-loop thread over sim-time data, so the audit inherits the
//! obs determinism contract: byte-identical at any `DFLOP_THREADS`.

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::obs::record::RunLog;
use crate::optimizer::batch::write_slot_legs;
use crate::optimizer::plan::Theta;
use crate::pipeline::build::IterationStats;
use crate::pipeline::sim::SimWorkspace;
use crate::profiling::engine::ThroughputModel;
use crate::profiling::estimator::Estimator;
use crate::stream::replan::ReplanEvent;
use crate::util::json::Json;

/// Iterations priced after a swap for its measured benefit (bounded so
/// one audit pass stays linear in run length even under replan storms).
pub const REPLAN_WINDOW: usize = 16;

/// One iteration's predicted-vs-measured record.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AuditRow {
    pub iteration: usize,
    /// Evaluator-frame price of the realized batch under the plan that
    /// executed it (comm-free pipeline makespan, per-stage overheads
    /// included — the quantity the optimizer compared candidates by).
    pub predicted: f64,
    /// The simulator's end-to-end step time (makespan + DP sync).
    pub measured: f64,
    /// `predicted − measured` (negative: the frame under-predicted,
    /// usually by the comm + sync it ignores).
    pub residual: f64,
    /// `residual / measured`.
    pub rel_err: f64,
    /// Encoder share of the iteration's FLOP — the modality-mix key.
    pub enc_flop_share: f64,
    /// Plan epoch: 0 under the offline θ*, +1 per adopted swap.
    pub plan_epoch: usize,
}

/// Measured (counterfactual) vs predicted benefit of one adopted swap.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplanAudit {
    /// First iteration the adopted plan executed.
    pub iteration: usize,
    /// Realized iterations priced under both plans (≤ [`REPLAN_WINDOW`],
    /// truncated at the next swap).
    pub window: usize,
    /// Mean evaluator-frame price of the *incumbent* θ over the window's
    /// realized batches (delta replay, no fresh simulation).
    pub incumbent_mean: f64,
    /// Same for the adopted θ.
    pub adopted_mean: f64,
    /// `incumbent_mean − adopted_mean`: positive means the swap paid
    /// off on the batches that actually arrived.
    pub measured_benefit: f64,
    /// `expected_incumbent − expected_makespan` from the replan event
    /// (both Eq-1 scores under the refitted distribution); NaN when the
    /// event predates incumbent re-scoring.
    pub predicted_benefit: f64,
}

/// Mean absolute relative error over one bucket of audit rows.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ErrBucket {
    /// Bucket key: modality-mix decile (`lo = d/10`) or plan epoch.
    pub key: usize,
    pub count: usize,
    pub mean_abs_rel_err: f64,
}

/// The full audit: per-iteration residuals, aggregates, and per-swap
/// counterfactual attribution. Stored on [`RunLog::audit`] and
/// serialized into the `--json` summary and `AUDIT_REPORT.json`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AuditReport {
    pub rows: Vec<AuditRow>,
    pub replans: Vec<ReplanAudit>,
    /// Mean `|rel_err|` over all rows.
    pub mean_abs_rel_err: f64,
    /// Mean residual in seconds (the frame's systematic bias).
    pub bias: f64,
    /// Rows bucketed by encoder-FLOP-share decile.
    pub by_mix: Vec<ErrBucket>,
    /// Rows bucketed by plan epoch.
    pub by_epoch: Vec<ErrBucket>,
}

/// The counterfactual pricer: prices realized batches under a fixed θ
/// in the batch evaluator's comm-free frame, reusing one standing route
/// set across calls — after the first batch every re-price is
/// `update_leg` edits + `delta_run` replay (cost-only, no topology
/// rebuild, no fresh simulation).
///
/// Items are dealt round-robin into the plan's `buckets()` microbatch
/// slots — the audit's fixed stand-in for the scheduler's LPT
/// assignment, deterministic and θ-independent so incumbent and adopted
/// plans price identical item groupings.
pub struct CfPricer<'a> {
    est: Estimator<'a>,
    theta: Theta,
    n_stages: usize,
    e_ovh: f64,
    l_ovh: f64,
    sim: SimWorkspace,
    seqs: Vec<f64>,
    /// Bucket count of the standing route set (0 = none built yet).
    built_buckets: usize,
}

impl<'a> CfPricer<'a> {
    pub fn new(m: &'a Mllm, thr: &'a ThroughputModel, theta: Theta) -> CfPricer<'a> {
        CfPricer {
            est: Estimator::new(m, thr),
            theta,
            n_stages: theta.enc.dp * theta.enc.pp + theta.llm.dp * theta.llm.pp,
            e_ovh: thr.enc_overhead(theta.enc.tp),
            l_ovh: thr.llm_overhead(theta.llm.tp),
            sim: SimWorkspace::new(),
            seqs: Vec::new(),
            built_buckets: 0,
        }
    }

    pub fn theta(&self) -> Theta {
        self.theta
    }

    /// Price one realized batch. First call (or a bucket-count change —
    /// impossible for same-θ fixed-GBS runs) builds the route set and
    /// runs tracked; every later call re-prices in place and replays.
    pub fn price(&mut self, batch: &[ItemShape]) -> f64 {
        let t = self.theta;
        let nb = t.buckets().min(batch.len().max(1));
        let rebuild = self.built_buckets != nb;
        if rebuild {
            self.sim.routes.clear();
        }
        for j in 0..nb {
            let mut units = 0.0f64;
            self.seqs.clear();
            for shape in batch.iter().skip(j).step_by(nb) {
                units += shape.units as f64;
                let seq = shape.llm_seq as f64;
                if seq > 0.0 {
                    self.seqs.push(seq);
                }
            }
            let e_t = self.est.enc_bucket_dur(units, t.enc.tp) / t.enc.pp as f64 + self.e_ovh;
            let l_t = self.est.llm_bucket_dur(&self.seqs, t.llm.tp) / t.llm.pp as f64 + self.l_ovh;
            write_slot_legs(
                &mut self.sim,
                j,
                t.enc.pp,
                t.llm.pp,
                t.enc.dp,
                t.llm.dp,
                e_t,
                l_t,
                rebuild,
            );
        }
        self.built_buckets = nb;
        if rebuild {
            self.sim.run_tracked(self.n_stages)
        } else {
            self.sim.delta_run(self.n_stages)
        }
    }

    /// The fresh-simulation oracle: identical pricing, but the route set
    /// is rebuilt and fully re-run — the reference [`CfPricer::price`]'s
    /// delta replay must (and does, property-tested) bit-match.
    pub fn price_fresh(&mut self, batch: &[ItemShape]) -> f64 {
        self.built_buckets = 0;
        self.price(batch)
    }
}

/// Encoder share of an iteration's FLOP, from its per-bucket execution
/// records (0 when no FLOP was recorded).
fn enc_flop_share(stats: &IterationStats) -> f64 {
    let (mut enc, mut total) = (0.0f64, 0.0f64);
    for b in &stats.buckets {
        enc += b.enc_flop;
        total += b.enc_flop + b.llm_flop;
    }
    if total > 0.0 {
        enc / total
    } else {
        0.0
    }
}

/// The plan that executed each iteration: the offline θ* plus every
/// *adopted* replan, as `(first_iteration, theta)` segments. Replan
/// events record the iteration whose batch confirmed the drift — the
/// swap applies to that same batch (it had not been scheduled yet).
fn plan_segments(initial: Theta, replans: &[ReplanEvent]) -> Vec<(usize, Theta)> {
    let mut segs = vec![(0usize, initial)];
    for e in replans.iter().filter(|e| e.swapped) {
        segs.push((e.iteration, e.new));
    }
    segs
}

fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

fn bucket_errs(rows: &[AuditRow], key: impl Fn(&AuditRow) -> usize) -> Vec<ErrBucket> {
    let mut acc: std::collections::BTreeMap<usize, (usize, f64)> = Default::default();
    for r in rows {
        let e = acc.entry(key(r)).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += r.rel_err.abs();
    }
    acc.into_iter()
        .map(|(key, (count, sum))| ErrBucket {
            key,
            count,
            mean_abs_rel_err: sum / count as f64,
        })
        .collect()
}

/// Run the full audit over a finished run's recorded batches and attach
/// it to the log ([`RunLog::audit`], plus registry rows when metrics
/// are on). `initial` is the offline θ*; `iterations`/`replans` are the
/// run's own outputs. No-op (empty report) when no batches were
/// recorded.
pub fn run_audit(
    log: &mut RunLog,
    initial: Theta,
    iterations: &[IterationStats],
    replans: &[ReplanEvent],
    m: &Mllm,
    thr: &ThroughputModel,
) {
    let segs = plan_segments(initial, replans);
    let n = iterations.len().min(log.iterations.len());

    // Per-iteration residuals: one pricer per plan epoch, so within an
    // epoch every price after the first is a delta replay.
    let mut rows: Vec<AuditRow> = Vec::new();
    for (epoch, &(seg_start, theta)) in segs.iter().enumerate() {
        let seg_end = segs.get(epoch + 1).map_or(n, |&(s, _)| s.min(n));
        let mut pricer = CfPricer::new(m, thr, theta);
        for i in seg_start.min(n)..seg_end {
            let batch = &log.iterations[i].batch;
            if batch.is_empty() {
                continue;
            }
            let predicted = pricer.price(batch);
            let measured = iterations[i].iteration_time;
            let residual = predicted - measured;
            rows.push(AuditRow {
                iteration: i,
                predicted,
                measured,
                residual,
                rel_err: if measured > 0.0 { residual / measured } else { 0.0 },
                enc_flop_share: enc_flop_share(&iterations[i]),
                plan_epoch: epoch,
            });
        }
    }

    // Counterfactual attribution: price incumbent and adopted θ over
    // the realized batches following each adopted swap.
    let mut replan_audits: Vec<ReplanAudit> = Vec::new();
    for e in replans.iter().filter(|e| e.swapped) {
        let start = e.iteration.min(n);
        let next_swap = replans
            .iter()
            .filter(|o| o.swapped && o.iteration > e.iteration)
            .map(|o| o.iteration)
            .next()
            .unwrap_or(n);
        let end = (start + REPLAN_WINDOW).min(next_swap).min(n);
        let mut old_p = CfPricer::new(m, thr, e.old);
        let mut new_p = CfPricer::new(m, thr, e.new);
        let (mut olds, mut news) = (Vec::new(), Vec::new());
        for i in start..end {
            let batch = &log.iterations[i].batch;
            if batch.is_empty() {
                continue;
            }
            olds.push(old_p.price(batch));
            news.push(new_p.price(batch));
        }
        if olds.is_empty() {
            continue;
        }
        let (incumbent_mean, adopted_mean) = (mean(&olds), mean(&news));
        replan_audits.push(ReplanAudit {
            iteration: e.iteration,
            window: olds.len(),
            incumbent_mean,
            adopted_mean,
            measured_benefit: incumbent_mean - adopted_mean,
            predicted_benefit: e.expected_incumbent - e.expected_makespan,
        });
    }

    let report = AuditReport {
        mean_abs_rel_err: mean(&rows.iter().map(|r| r.rel_err.abs()).collect::<Vec<_>>()),
        bias: mean(&rows.iter().map(|r| r.residual).collect::<Vec<_>>()),
        by_mix: bucket_errs(&rows, |r| {
            ((r.enc_flop_share * 10.0).floor() as usize).min(9)
        }),
        by_epoch: bucket_errs(&rows, |r| r.plan_epoch),
        rows,
        replans: replan_audits,
    };
    if let Some(reg) = log.metrics.as_mut() {
        for r in &report.rows {
            reg.observe("audit_abs_rel_err", r.rel_err.abs());
        }
        reg.counter_add("audit_rows", report.rows.len() as u64);
        reg.counter_add("audit_replans", report.replans.len() as u64);
        reg.gauge_set("audit_mean_abs_rel_err", report.mean_abs_rel_err);
        reg.gauge_set("audit_bias_s", report.bias);
        if !report.replans.is_empty() {
            reg.gauge_set(
                "audit_mean_measured_benefit_s",
                mean(&report.replans.iter().map(|r| r.measured_benefit).collect::<Vec<_>>()),
            );
        }
    }
    log.audit = Some(report);
}

/// The audit as JSON (embedded in the `--json` run summary and emitted
/// standalone by `examples/audit_report.rs`).
pub fn audit_json(a: &AuditReport) -> Json {
    let rows: Vec<Json> = a
        .rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("iteration", Json::Num(r.iteration as f64)),
                ("predicted_s", Json::Num(r.predicted)),
                ("measured_s", Json::Num(r.measured)),
                ("residual_s", Json::Num(r.residual)),
                ("rel_err", Json::Num(r.rel_err)),
                ("enc_flop_share", Json::Num(r.enc_flop_share)),
                ("plan_epoch", Json::Num(r.plan_epoch as f64)),
            ])
        })
        .collect();
    let replans: Vec<Json> = a
        .replans
        .iter()
        .map(|r| {
            let mut fields = vec![
                ("iteration", Json::Num(r.iteration as f64)),
                ("window", Json::Num(r.window as f64)),
                ("incumbent_mean_s", Json::Num(r.incumbent_mean)),
                ("adopted_mean_s", Json::Num(r.adopted_mean)),
                ("measured_benefit_s", Json::Num(r.measured_benefit)),
            ];
            // NaN (no incumbent re-score on the event) has no JSON form.
            if r.predicted_benefit.is_finite() {
                fields.push(("predicted_benefit_s", Json::Num(r.predicted_benefit)));
            }
            Json::obj(fields)
        })
        .collect();
    let buckets = |bs: &[ErrBucket]| {
        Json::Arr(
            bs.iter()
                .map(|b| {
                    Json::obj(vec![
                        ("key", Json::Num(b.key as f64)),
                        ("count", Json::Num(b.count as f64)),
                        ("mean_abs_rel_err", Json::Num(b.mean_abs_rel_err)),
                    ])
                })
                .collect(),
        )
    };
    Json::obj(vec![
        ("schema", Json::str("dflop-audit-v1")),
        ("mean_abs_rel_err", Json::Num(a.mean_abs_rel_err)),
        ("bias_s", Json::Num(a.bias)),
        ("rows", Json::Arr(rows)),
        ("replans", Json::Arr(replans)),
        ("by_mix_decile", buckets(&a.by_mix)),
        ("by_plan_epoch", buckets(&a.by_epoch)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfile, ModelProfiler, ProfilerGrids};
    use crate::util::prop::forall;

    fn fixture() -> (Mllm, ModelProfile) {
        let m = llava_ov(llama3("8b"));
        let cluster = ClusterSpec::hgx_a100(2);
        let mut backend = SimBackend::new(Truth::new(cluster));
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
        (m, profile)
    }

    fn random_theta(g: &mut crate::util::prop::Gen) -> Theta {
        let pick = |g: &mut crate::util::prop::Gen, xs: &[usize]| xs[g.rng.index(xs.len())];
        Theta {
            enc: ModPar {
                tp: pick(g, &[1, 2]),
                pp: pick(g, &[1, 2]),
                dp: pick(g, &[1, 2]),
            },
            llm: ModPar {
                tp: pick(g, &[1, 2, 4]),
                pp: pick(g, &[1, 2, 4]),
                dp: pick(g, &[1, 2]),
            },
            n_mb: pick(g, &[1, 2, 4]),
        }
    }

    #[test]
    fn delta_replay_pricing_bit_matches_fresh_simulation() {
        let (m, profile) = fixture();
        let mut ds = Dataset::mixed(0xA0D1);
        forall("cf delta pricing == fresh sim, bit for bit", 25, |g| {
            let theta = random_theta(g);
            let mut inc = CfPricer::new(&m, &profile.throughput, theta);
            let mut fresh = CfPricer::new(&m, &profile.throughput, theta);
            let gbs = 8 + 8 * g.size(6);
            for _ in 0..4 {
                let batch = ds.shaped_batch(&m, gbs);
                let a = inc.price(&batch);
                let b = fresh.price_fresh(&batch);
                if a.to_bits() != b.to_bits() {
                    return (format!("θ={theta} gbs={gbs}: {a} != {b}"), false);
                }
            }
            (format!("θ={theta} gbs={gbs}"), true)
        });
    }

    #[test]
    fn batch_length_change_rebuilds_and_still_matches() {
        let (m, profile) = fixture();
        let theta = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 2 },
            llm: ModPar { tp: 2, pp: 2, dp: 2 },
            n_mb: 4,
        };
        let mut ds = Dataset::mixed(7);
        let mut inc = CfPricer::new(&m, &profile.throughput, theta);
        let mut fresh = CfPricer::new(&m, &profile.throughput, theta);
        // buckets() = 8: a 4-item batch forces nb=4, then 32 restores 8.
        for gbs in [32usize, 4, 32, 32] {
            let batch = ds.shaped_batch(&m, gbs);
            assert_eq!(
                inc.price(&batch).to_bits(),
                fresh.price_fresh(&batch).to_bits(),
                "gbs={gbs}"
            );
        }
    }

    #[test]
    fn audit_rows_and_epochs_follow_the_swap() {
        use crate::pipeline::build::{iterate_ws, SystemPlan};
        use crate::stream::drift::DriftStat;
        let (m, profile) = fixture();
        let truth = Truth::new(ClusterSpec::hgx_a100(2));
        let theta0 = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 2 },
            llm: ModPar { tp: 1, pp: 2, dp: 2 },
            n_mb: 2,
        };
        let theta1 = Theta { n_mb: 4, ..theta0 };
        let mut ds = Dataset::mixed(0xBEEF);
        let mut log = RunLog::default();
        log.cfg.audit = true;
        let mut ws = SimWorkspace::new();
        let mut stats = Vec::new();
        for i in 0..6 {
            let batch = ds.shaped_batch(&m, 16);
            let theta = if i < 3 { theta0 } else { theta1 };
            let plan = SystemPlan { m: &m, truth: &truth, theta };
            let mut bks: Vec<Vec<ItemShape>> = vec![Vec::new(); theta.buckets()];
            for (k, s) in batch.iter().enumerate() {
                bks[k % bks.len()].push(*s);
            }
            let s = iterate_ws(&plan, &bks, &mut ws);
            let mut tr = crate::obs::record::IterationTrace::default();
            tr.batch = batch;
            log.iterations.push(tr);
            stats.push(s);
        }
        let replans = vec![ReplanEvent {
            iteration: 3,
            stat: DriftStat { quantile_dist: 0.0, units_dist: 0.0, mix_tv: 0.0 },
            old: theta0,
            new: theta1,
            swapped: true,
            expected_makespan: 1.0,
            expected_incumbent: 1.5,
            elapsed: std::time::Duration::ZERO,
        }];
        run_audit(&mut log, theta0, &stats, &replans, &m, &profile.throughput);
        let audit = log.audit.as_ref().expect("report attached");
        assert_eq!(audit.rows.len(), 6);
        assert!(audit.rows[..3].iter().all(|r| r.plan_epoch == 0));
        assert!(audit.rows[3..].iter().all(|r| r.plan_epoch == 1));
        assert!(audit.rows.iter().all(|r| {
            r.predicted > 0.0 && r.measured > 0.0 && r.rel_err.is_finite()
        }));
        assert_eq!(audit.replans.len(), 1);
        let ra = &audit.replans[0];
        assert_eq!(ra.iteration, 3);
        assert_eq!(ra.window, 3);
        assert!((ra.predicted_benefit - 0.5).abs() < 1e-12);
        assert!(ra.incumbent_mean > 0.0 && ra.adopted_mean > 0.0);
        // JSON serializes without panicking and carries the schema tag.
        let doc = audit_json(audit);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some("dflop-audit-v1"));
        assert_eq!(
            doc.get("rows").and_then(Json::as_arr).map(<[Json]>::len),
            Some(6)
        );
    }

    #[test]
    fn no_recorded_batches_yields_empty_report() {
        let (m, profile) = fixture();
        let theta = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 1, dp: 1 },
            n_mb: 1,
        };
        let mut log = RunLog::default();
        run_audit(&mut log, theta, &[], &[], &m, &profile.throughput);
        let audit = log.audit.as_ref().expect("report attached");
        assert!(audit.rows.is_empty() && audit.replans.is_empty());
        assert_eq!(audit.mean_abs_rel_err, 0.0);
    }
}
