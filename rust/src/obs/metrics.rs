//! A std-only counter/gauge/histogram registry with per-iteration
//! snapshots — the one place subsystems register run telemetry.
//!
//! The registry lives inside the recorder (`RunLog::metrics`), so it
//! inherits the observability determinism contract for free: it is
//! only ever touched from the single engine-loop thread at iteration
//! boundaries, keys are `BTreeMap`-ordered, and every recorded value is
//! a deterministic simulation output — the JSON dump is byte-identical
//! at any `DFLOP_THREADS`.
//!
//! Registering a new metric is one call at the recording site:
//! `reg.counter_add("my_counter", n)` / `reg.gauge_set("my_gauge", x)`
//! / `reg.observe("my_hist", x)` — names are created on first use and
//! appear in the dump (and in every subsequent snapshot for counters
//! and gauges) automatically.

use crate::util::json::{emit, Json};
use crate::util::stats::quantile;
use std::collections::BTreeMap;

/// Samples a histogram retains for quantile estimation. Below this the
/// reservoir holds every sample and quantiles are exact; above it a
/// seeded deterministic reservoir (Algorithm R) keeps a uniform sample
/// while count/mean/min/max stay exact — so long-horizon fault
/// scenarios observe O(1) memory per series instead of O(iterations).
pub const RESERVOIR_CAP: usize = 512;

/// A fixed-capacity histogram series: exact count/sum/min/max plus a
/// bounded sample set. The replacement stream is a xorshift64 seeded
/// from the metric name (FNV-1a), so retention is a pure function of
/// the name and the sample sequence — identical at any `DFLOP_THREADS`
/// and across runs, per the obs determinism contract.
#[derive(Clone, Debug, PartialEq)]
pub struct Reservoir {
    n: u64,
    sum: f64,
    min: f64,
    max: f64,
    xs: Vec<f64>,
    state: u64,
}

impl Reservoir {
    fn new(name: &str) -> Reservoir {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in name.bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        Reservoir {
            n: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
            xs: Vec::new(),
            state: h | 1, // xorshift64 must not start at 0
        }
    }

    fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x < self.min {
            self.min = x;
        }
        if x > self.max {
            self.max = x;
        }
        if self.xs.len() < RESERVOIR_CAP {
            self.xs.push(x);
        } else {
            // Algorithm R: keep the newcomer with probability cap/n, in
            // a uniformly random retained slot.
            self.state ^= self.state << 13;
            self.state ^= self.state >> 7;
            self.state ^= self.state << 17;
            let j = (self.state % self.n) as usize;
            if j < RESERVOIR_CAP {
                self.xs[j] = x;
            }
        }
    }

    /// Finite samples observed (exact, not capped).
    pub fn count(&self) -> u64 {
        self.n
    }

    /// The retained samples (every sample below [`RESERVOIR_CAP`]).
    pub fn samples(&self) -> &[f64] {
        &self.xs
    }
}

/// Counter/gauge state captured at the end of one iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub iteration: usize,
    /// Simulated seconds at the iteration's start.
    pub t: f64,
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
}

/// The metrics registry: monotonic counters, last-value gauges, and
/// bounded-memory histogram series (summarized on dump).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Reservoir>,
    snapshots: Vec<Snapshot>,
}

impl Registry {
    pub fn counter_add(&mut self, name: &'static str, n: u64) {
        *self.counters.entry(name).or_default() += n;
    }

    /// Set a gauge. Non-finite values are dropped: the JSON layer has
    /// no encoding for them, and a NaN gauge is always a bug upstream.
    pub fn gauge_set(&mut self, name: &'static str, value: f64) {
        if value.is_finite() {
            self.gauges.insert(name, value);
        }
    }

    /// Record one histogram sample (non-finite values register the
    /// series but are dropped from it).
    pub fn observe(&mut self, name: &'static str, value: f64) {
        let r = self.hists.entry(name).or_insert_with(|| Reservoir::new(name));
        if value.is_finite() {
            r.push(value);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// A histogram's retained samples (all of them below
    /// [`RESERVOIR_CAP`], a deterministic uniform subsample above).
    pub fn samples(&self, name: &str) -> &[f64] {
        self.hists.get(name).map_or(&[], Reservoir::samples)
    }

    /// A histogram's exact observation count (0 if never registered).
    pub fn hist_count(&self, name: &str) -> u64 {
        self.hists.get(name).map_or(0, Reservoir::count)
    }

    pub fn snapshots(&self) -> &[Snapshot] {
        &self.snapshots
    }

    /// Capture the current counter/gauge state as iteration `it`'s
    /// snapshot (`t` = simulated seconds at its start).
    pub fn snapshot(&mut self, it: usize, t: f64) {
        self.snapshots.push(Snapshot {
            iteration: it,
            t,
            counters: self.counters.clone(),
            gauges: self.gauges.clone(),
        });
    }

    /// The full registry as a JSON document: final counters/gauges,
    /// histogram summaries, and the per-iteration snapshot series.
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> =
            self.counters.iter().map(|(&k, &v)| (k, Json::Num(v as f64))).collect();
        let gauges: Vec<(&str, Json)> =
            self.gauges.iter().map(|(&k, &v)| (k, Json::Num(v))).collect();
        let hists: Vec<(&str, Json)> = self
            .hists
            .iter()
            .map(|(&k, r)| (k, hist_summary(r)))
            .collect();
        let snaps: Vec<Json> = self
            .snapshots
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("iteration", Json::Num(s.iteration as f64)),
                    ("t_s", Json::Num(s.t)),
                    (
                        "counters",
                        Json::obj(
                            s.counters
                                .iter()
                                .map(|(&k, &v)| (k, Json::Num(v as f64)))
                                .collect(),
                        ),
                    ),
                    (
                        "gauges",
                        Json::obj(
                            s.gauges.iter().map(|(&k, &v)| (k, Json::Num(v))).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("dflop-metrics-v1")),
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
            ("snapshots", Json::Arr(snaps)),
        ])
    }

    /// `to_json` rendered to a string (trailing newline included).
    pub fn dump(&self) -> String {
        emit(&self.to_json()) + "\n"
    }
}

/// Summarize one histogram series. Count/mean/min/max are exact over
/// every observed sample; quantiles are computed over the retained
/// reservoir (exact below [`RESERVOIR_CAP`]). `quantile` asserts on
/// empty input, so an empty series dumps as `{"count": 0}` only.
fn hist_summary(r: &Reservoir) -> Json {
    if r.n == 0 {
        return Json::obj(vec![("count", Json::Num(0.0))]);
    }
    Json::obj(vec![
        ("count", Json::Num(r.n as f64)),
        ("mean", Json::Num(r.sum / r.n as f64)),
        ("min", Json::Num(r.min)),
        ("max", Json::Num(r.max)),
        ("p50", Json::Num(quantile(&r.xs, 0.50))),
        ("p90", Json::Num(quantile(&r.xs, 0.90))),
        ("p99", Json::Num(quantile(&r.xs, 0.99))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn counters_gauges_and_snapshots_round_trip() {
        let mut reg = Registry::default();
        reg.counter_add("iterations", 1);
        reg.gauge_set("step_time_s", 0.5);
        reg.observe("step_time_s", 0.5);
        reg.snapshot(0, 0.0);
        reg.counter_add("iterations", 1);
        reg.gauge_set("step_time_s", 0.7);
        reg.observe("step_time_s", 0.7);
        reg.snapshot(1, 0.5);
        assert_eq!(reg.counter("iterations"), 2);
        assert_eq!(reg.gauge("step_time_s"), Some(0.7));
        assert_eq!(reg.snapshots()[0].counters["iterations"], 1);

        let doc = parse(&reg.dump()).expect("valid json");
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some("dflop-metrics-v1")
        );
        assert_eq!(doc.path("counters.iterations").and_then(Json::as_usize), Some(2));
        assert_eq!(
            doc.path("histograms.step_time_s.count").and_then(Json::as_usize),
            Some(2)
        );
        assert_eq!(
            doc.get("snapshots").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
    }

    #[test]
    fn reservoir_is_exact_below_capacity() {
        use crate::util::prop::forall;
        forall("below-cap reservoir keeps every sample in order", 30, |g| {
            let n = 1 + g.size(RESERVOIR_CAP - 1);
            let xs: Vec<f64> = (0..n).map(|_| g.rng.uniform(0.0, 10.0)).collect();
            let mut reg = Registry::default();
            for &x in &xs {
                reg.observe("h", x);
            }
            let exact = reg.samples("h") == xs.as_slice()
                && reg.hist_count("h") == n as u64;
            // Below capacity the dumped quantiles are over the full set.
            let doc = parse(&reg.dump()).expect("valid json");
            let p50 = doc.path("histograms.h.p50").and_then(Json::as_f64);
            let ok = exact && p50 == Some(quantile(&xs, 0.50));
            (format!("n={n}"), ok)
        });
    }

    #[test]
    fn reservoir_above_capacity_is_bounded_deterministic_and_a_subsample() {
        use crate::util::prop::forall;
        forall("above-cap reservoir: bounded, deterministic, subset", 10, |g| {
            let n = RESERVOIR_CAP + 1 + g.size(3 * RESERVOIR_CAP);
            let xs: Vec<f64> = (0..n).map(|i| i as f64 + g.rng.uniform(0.0, 0.5)).collect();
            let (mut a, mut b) = (Registry::default(), Registry::default());
            for &x in &xs {
                a.observe("h", x);
                b.observe("h", x);
            }
            let ok = a.samples("h") == b.samples("h")
                && a.samples("h").len() == RESERVOIR_CAP
                && a.hist_count("h") == n as u64
                && a.samples("h").iter().all(|x| xs.contains(x))
                && a.dump() == b.dump();
            (format!("n={n}"), ok)
        });
    }

    #[test]
    fn exact_aggregates_survive_capped_retention() {
        let mut reg = Registry::default();
        let n = 4 * RESERVOIR_CAP;
        for i in 0..n {
            reg.observe("h", i as f64);
        }
        let doc = parse(&reg.dump()).expect("valid json");
        assert_eq!(doc.path("histograms.h.count").and_then(Json::as_usize), Some(n));
        assert_eq!(doc.path("histograms.h.min").and_then(Json::as_f64), Some(0.0));
        assert_eq!(
            doc.path("histograms.h.max").and_then(Json::as_f64),
            Some((n - 1) as f64)
        );
        let sum: f64 = (0..n).map(|i| i as f64).sum();
        assert_eq!(
            doc.path("histograms.h.mean").and_then(Json::as_f64),
            Some(sum / n as f64)
        );
    }

    #[test]
    fn non_finite_values_are_dropped() {
        let mut reg = Registry::default();
        reg.gauge_set("g", f64::NAN);
        reg.observe("h", f64::INFINITY);
        assert_eq!(reg.gauge("g"), None);
        // The empty histogram summarizes as count 0 without panicking.
        let doc = parse(&reg.dump()).expect("valid json");
        assert_eq!(doc.path("histograms.h.count").and_then(Json::as_usize), Some(0));
    }
}
