//! A std-only counter/gauge/histogram registry with per-iteration
//! snapshots — the one place subsystems register run telemetry.
//!
//! The registry lives inside the recorder (`RunLog::metrics`), so it
//! inherits the observability determinism contract for free: it is
//! only ever touched from the single engine-loop thread at iteration
//! boundaries, keys are `BTreeMap`-ordered, and every recorded value is
//! a deterministic simulation output — the JSON dump is byte-identical
//! at any `DFLOP_THREADS`.
//!
//! Registering a new metric is one call at the recording site:
//! `reg.counter_add("my_counter", n)` / `reg.gauge_set("my_gauge", x)`
//! / `reg.observe("my_hist", x)` — names are created on first use and
//! appear in the dump (and in every subsequent snapshot for counters
//! and gauges) automatically.

use crate::util::json::{emit, Json};
use crate::util::stats::quantile;
use std::collections::BTreeMap;

/// Counter/gauge state captured at the end of one iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub iteration: usize,
    /// Simulated seconds at the iteration's start.
    pub t: f64,
    pub counters: BTreeMap<&'static str, u64>,
    pub gauges: BTreeMap<&'static str, f64>,
}

/// The metrics registry: monotonic counters, last-value gauges, and
/// raw-sample histograms (summarized on dump).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Registry {
    counters: BTreeMap<&'static str, u64>,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Vec<f64>>,
    snapshots: Vec<Snapshot>,
}

impl Registry {
    pub fn counter_add(&mut self, name: &'static str, n: u64) {
        *self.counters.entry(name).or_default() += n;
    }

    /// Set a gauge. Non-finite values are dropped: the JSON layer has
    /// no encoding for them, and a NaN gauge is always a bug upstream.
    pub fn gauge_set(&mut self, name: &'static str, value: f64) {
        if value.is_finite() {
            self.gauges.insert(name, value);
        }
    }

    /// Record one histogram sample (non-finite values register the
    /// series but are dropped from it).
    pub fn observe(&mut self, name: &'static str, value: f64) {
        let xs = self.hists.entry(name).or_default();
        if value.is_finite() {
            xs.push(value);
        }
    }

    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    pub fn samples(&self, name: &str) -> &[f64] {
        self.hists.get(name).map_or(&[], Vec::as_slice)
    }

    pub fn snapshots(&self) -> &[Snapshot] {
        &self.snapshots
    }

    /// Capture the current counter/gauge state as iteration `it`'s
    /// snapshot (`t` = simulated seconds at its start).
    pub fn snapshot(&mut self, it: usize, t: f64) {
        self.snapshots.push(Snapshot {
            iteration: it,
            t,
            counters: self.counters.clone(),
            gauges: self.gauges.clone(),
        });
    }

    /// The full registry as a JSON document: final counters/gauges,
    /// histogram summaries, and the per-iteration snapshot series.
    pub fn to_json(&self) -> Json {
        let counters: Vec<(&str, Json)> =
            self.counters.iter().map(|(&k, &v)| (k, Json::Num(v as f64))).collect();
        let gauges: Vec<(&str, Json)> =
            self.gauges.iter().map(|(&k, &v)| (k, Json::Num(v))).collect();
        let hists: Vec<(&str, Json)> = self
            .hists
            .iter()
            .map(|(&k, xs)| (k, hist_summary(xs)))
            .collect();
        let snaps: Vec<Json> = self
            .snapshots
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("iteration", Json::Num(s.iteration as f64)),
                    ("t_s", Json::Num(s.t)),
                    (
                        "counters",
                        Json::obj(
                            s.counters
                                .iter()
                                .map(|(&k, &v)| (k, Json::Num(v as f64)))
                                .collect(),
                        ),
                    ),
                    (
                        "gauges",
                        Json::obj(
                            s.gauges.iter().map(|(&k, &v)| (k, Json::Num(v))).collect(),
                        ),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![
            ("schema", Json::str("dflop-metrics-v1")),
            ("counters", Json::obj(counters)),
            ("gauges", Json::obj(gauges)),
            ("histograms", Json::obj(hists)),
            ("snapshots", Json::Arr(snaps)),
        ])
    }

    /// `to_json` rendered to a string (trailing newline included).
    pub fn dump(&self) -> String {
        emit(&self.to_json()) + "\n"
    }
}

/// Summarize one histogram's samples. `quantile` asserts on empty
/// input, so an empty series dumps as `{"count": 0}` only.
fn hist_summary(xs: &[f64]) -> Json {
    if xs.is_empty() {
        return Json::obj(vec![("count", Json::Num(0.0))]);
    }
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    Json::obj(vec![
        ("count", Json::Num(xs.len() as f64)),
        ("mean", Json::Num(mean)),
        ("min", Json::Num(xs.iter().cloned().fold(f64::INFINITY, f64::min))),
        ("max", Json::Num(xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max))),
        ("p50", Json::Num(quantile(xs, 0.50))),
        ("p90", Json::Num(quantile(xs, 0.90))),
        ("p99", Json::Num(quantile(xs, 0.99))),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn counters_gauges_and_snapshots_round_trip() {
        let mut reg = Registry::default();
        reg.counter_add("iterations", 1);
        reg.gauge_set("step_time_s", 0.5);
        reg.observe("step_time_s", 0.5);
        reg.snapshot(0, 0.0);
        reg.counter_add("iterations", 1);
        reg.gauge_set("step_time_s", 0.7);
        reg.observe("step_time_s", 0.7);
        reg.snapshot(1, 0.5);
        assert_eq!(reg.counter("iterations"), 2);
        assert_eq!(reg.gauge("step_time_s"), Some(0.7));
        assert_eq!(reg.snapshots()[0].counters["iterations"], 1);

        let doc = parse(&reg.dump()).expect("valid json");
        assert_eq!(
            doc.get("schema").and_then(Json::as_str),
            Some("dflop-metrics-v1")
        );
        assert_eq!(doc.path("counters.iterations").and_then(Json::as_usize), Some(2));
        assert_eq!(
            doc.path("histograms.step_time_s.count").and_then(Json::as_usize),
            Some(2)
        );
        assert_eq!(
            doc.get("snapshots").and_then(Json::as_arr).map(<[Json]>::len),
            Some(2)
        );
    }

    #[test]
    fn non_finite_values_are_dropped() {
        let mut reg = Registry::default();
        reg.gauge_set("g", f64::NAN);
        reg.observe("h", f64::INFINITY);
        assert_eq!(reg.gauge("g"), None);
        // The empty histogram summarizes as count 0 without panicking.
        let doc = parse(&reg.dump()).expect("valid json");
        assert_eq!(doc.path("histograms.h.count").and_then(Json::as_usize), Some(0));
    }
}
