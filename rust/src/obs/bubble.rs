//! Per-stage bubble (idle-gap) accounting over recorded op timelines.
//!
//! A *bubble* is a maximal interval inside `[0, makespan]` during which
//! a pipeline stage executes nothing. The extraction walks the recorded
//! `OpRecord` timeline — the `SimWorkspace` finish table flattened into
//! per-op start/finish pairs — and is purely derivational: `busy` is
//! copied bit-for-bit from the simulation's own `stage_busy`
//! accumulation and `idle` uses the exact expression `iterate_ws` uses
//! for `stage_idle` (`makespan - busy`), so the figures and traces
//! built on top can be cross-checked bit-exactly against `RunResult`.
//! Only the gap *intervals* are recomputed here (from the op
//! endpoints); their sum matches `idle` up to float associativity.

use crate::pipeline::build::IterationStats;
use crate::pipeline::sim::OpRecord;

/// One idle interval on one stage.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Gap {
    pub stage: usize,
    pub start: f64,
    pub end: f64,
}

impl Gap {
    pub fn len(&self) -> f64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// Per-stage busy/idle accounting plus the explicit gap intervals for
/// one iteration.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StageBubbles {
    pub makespan: f64,
    /// Per-stage busy seconds — copied from the simulation, bit-exact
    /// vs `IterationStats::stage_busy`.
    pub busy: Vec<f64>,
    /// Per-stage idle seconds — `makespan - busy[s]`, the same
    /// expression `iterate_ws` evaluates for `stage_idle`.
    pub idle: Vec<f64>,
    /// Idle intervals, sorted by stage then by time within a stage.
    pub gaps: Vec<Gap>,
}

impl StageBubbles {
    /// Idle area over total area: `Σ idle / (makespan · n_stages)`
    /// (0 when the iteration has no area).
    pub fn bubble_fraction(&self) -> f64 {
        let area = self.makespan * self.busy.len() as f64;
        if area > 0.0 {
            self.idle.iter().sum::<f64>() / area
        } else {
            0.0
        }
    }
}

/// Extract per-stage bubbles from a recorded op timeline.
///
/// `stage_busy` is the simulation's own per-stage busy accumulation
/// (copied, not recomputed). Stages execute their ops sequentially, so
/// each stage's subsequence of `timeline` is already time-ordered — a
/// gap opens wherever the next op starts after the previous finish, and
/// a tail gap runs to `makespan`. A stage with no ops is one whole-span
/// gap.
pub fn stage_bubbles(
    timeline: &[OpRecord],
    n_stages: usize,
    makespan: f64,
    stage_busy: &[f64],
) -> StageBubbles {
    let mut gaps = Vec::new();
    let mut cursor = vec![0.0_f64; n_stages];
    let mut seen = vec![false; n_stages];
    for op in timeline {
        let s = op.stage;
        if op.start > cursor[s] {
            gaps.push(Gap { stage: s, start: cursor[s], end: op.start });
        }
        cursor[s] = op.finish;
        seen[s] = true;
    }
    for (s, (&c, &saw)) in cursor.iter().zip(&seen).enumerate() {
        if !saw {
            if makespan > 0.0 {
                gaps.push(Gap { stage: s, start: 0.0, end: makespan });
            }
        } else if makespan > c {
            gaps.push(Gap { stage: s, start: c, end: makespan });
        }
    }
    // Stable by stage: within a stage the push order above is already
    // time order.
    gaps.sort_by_key(|g| g.stage);
    let busy: Vec<f64> = stage_busy.iter().take(n_stages).copied().collect();
    let idle: Vec<f64> = busy.iter().map(|&b| makespan - b).collect();
    StageBubbles { makespan, busy, idle, gaps }
}

/// The bubble fraction of one simulated iteration:
/// `total_idle / (makespan · n_stages)`, 0 when the area is 0.
pub fn iteration_bubble_fraction(stats: &IterationStats) -> f64 {
    let area = stats.pipeline_makespan * stats.n_stages as f64;
    if area > 0.0 {
        stats.total_idle() / area
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn op(stage: usize, start: f64, finish: f64) -> OpRecord {
        OpRecord { bucket: 0, stage, is_forward: true, start, finish }
    }

    #[test]
    fn gaps_cover_idle_time_and_tail() {
        // Stage 0: [0,1] [2,3]  → gap [1,2], tail [3,4].
        // Stage 1: [1,2]        → gap [0,1], tail [2,4].
        let tl =
            vec![op(0, 0.0, 1.0), op(1, 1.0, 2.0), op(0, 2.0, 3.0)];
        let b = stage_bubbles(&tl, 2, 4.0, &[2.0, 1.0]);
        assert_eq!(b.busy, vec![2.0, 1.0]);
        assert_eq!(b.idle, vec![2.0, 3.0]);
        assert_eq!(
            b.gaps,
            vec![
                Gap { stage: 0, start: 1.0, end: 2.0 },
                Gap { stage: 0, start: 3.0, end: 4.0 },
                Gap { stage: 1, start: 0.0, end: 1.0 },
                Gap { stage: 1, start: 2.0, end: 4.0 },
            ]
        );
        let per_stage_gap: Vec<f64> = (0..2)
            .map(|s| b.gaps.iter().filter(|g| g.stage == s).map(Gap::len).sum())
            .collect();
        assert_eq!(per_stage_gap, b.idle);
        assert!((b.bubble_fraction() - 5.0 / 8.0).abs() < 1e-12);
    }

    #[test]
    fn empty_stage_is_one_whole_span_gap() {
        let tl = vec![op(0, 0.0, 3.0)];
        let b = stage_bubbles(&tl, 2, 3.0, &[3.0, 0.0]);
        assert_eq!(b.gaps, vec![Gap { stage: 1, start: 0.0, end: 3.0 }]);
    }

    #[test]
    fn zero_makespan_yields_no_gaps() {
        let b = stage_bubbles(&[], 2, 0.0, &[0.0, 0.0]);
        assert!(b.gaps.is_empty());
        assert_eq!(b.bubble_fraction(), 0.0);
    }
}
