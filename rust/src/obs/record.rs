//! The recorder seam: structured, sim-time-stamped run events plus
//! opt-in per-op / per-replica timelines, captured at iteration
//! boundaries only.
//!
//! [`Recorder`] lives on `engine::telemetry::Telemetry`, so every
//! `PlanPolicy`/`ExecModel` hook reaches it through the `&mut Telemetry`
//! the engine already threads — no trait-signature changes. Two
//! guarantees back it:
//!
//! - **Zero-cost off.** [`Recorder::Off`] is a unit variant: every hook
//!   is an `#[inline]` early-return behind one branch, allocates
//!   nothing, and performs no arithmetic — so a recorder-off run is
//!   bit-identical to a build without the seam.
//! - **Bit-deterministic on.** Recording happens only on the single
//!   engine-loop thread, at iteration boundaries, with sharded replica
//!   results assembled in shard order — so the captured log (and every
//!   export derived from it) is byte-identical at any `DFLOP_THREADS`.
//!   The recorder copies values the simulation already produced; it
//!   never feeds anything back, so recorder-on results equal
//!   recorder-off results bit for bit.
//!
//! All timestamps are **simulated** seconds (the running sum of
//! iteration times). Wall-clock quantities (`sched_elapsed`,
//! `ReplanEvent::elapsed`) never enter the log — they would break the
//! byte-identity contract.

use crate::data::item::ItemShape;
use crate::engine::policy::PlanSet;
use crate::fault::FaultDelta;
use crate::obs::audit::AuditReport;
use crate::obs::bubble::iteration_bubble_fraction;
use crate::obs::metrics::Registry;
use crate::optimizer::plan::Theta;
use crate::pipeline::build::IterationStats;
use crate::pipeline::sim::OpRecord;
use crate::shard::sync::BarrierStats;
use crate::stream::replan::ReplanEvent;

/// What a run's recorder captures beyond the always-on event stream and
/// per-iteration boundary timings.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// Capture per-op timelines, replica-tagged on sharded systems
    /// (`--trace` needs these for op and bubble spans).
    pub timelines: bool,
    /// Maintain the `obs::metrics` registry with per-iteration
    /// snapshots (`--metrics`).
    pub metrics: bool,
    /// Record each iteration's realized global batch and run the
    /// post-run predicted-vs-measured audit (`obs::audit`, `--audit`).
    pub audit: bool,
}

/// One structured run event.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    /// Iteration the event landed on (events fire at boundaries).
    pub iteration: usize,
    /// Simulated seconds at the start of that iteration.
    pub t: f64,
    pub kind: EventKind,
}

/// What happened at an iteration boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum EventKind {
    /// Fleet membership changed (failures/recoveries/reshard).
    Fault { failures: usize, recoveries: usize, resharded: bool },
    /// The policy applied a plan at this boundary. `replicas` is the
    /// per-replica override count (0 = global plan only).
    PlanSwap { old: Theta, new: Theta, replicas: usize },
    /// The drift detector's phase changed: `drift-enter` (watch),
    /// `drift-confirm` (confirmed drift), `drift-exit` (back to stable).
    DriftPhase { phase: &'static str },
    /// Items migrated between shards by the rebalance walk.
    Migration { items: usize },
    /// The ILP scheduler hit its budget and fell back to LPT.
    LptFallback,
    /// A replan fit ran: `swapped` plans, or kept/failed (`refit-retry`
    /// when `expected_makespan` is `None` — the optimizer found no
    /// feasible plan).
    Replan { swapped: bool, score: f64, expected_makespan: Option<f64> },
}

/// One replica's recorded iteration execution (`ObsConfig::timelines`).
#[derive(Clone, Debug, PartialEq)]
pub struct ReplicaTrace {
    /// Shard slot (0 on single-replica systems).
    pub replica: usize,
    pub n_stages: usize,
    /// The replica's own pipeline makespan (post any straggler charge).
    pub makespan: f64,
    /// Per-stage busy seconds — the simulation's own accumulation.
    pub stage_busy: Vec<f64>,
    pub timeline: Vec<OpRecord>,
}

/// The step barrier's breakdown for one sharded iteration.
#[derive(Clone, Debug, PartialEq)]
pub struct BarrierTrace {
    /// Per-replica iteration time, shard order.
    pub per_replica: Vec<f64>,
    pub allreduce: f64,
    pub step_time: f64,
    pub straggler_gap: f64,
}

/// One iteration's boundary record (always captured when the recorder
/// is on; `replicas` only under [`ObsConfig::timelines`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IterationTrace {
    /// Simulated seconds at which the iteration started.
    pub t_start: f64,
    pub iteration_time: f64,
    pub pipeline_makespan: f64,
    pub dp_sync_time: f64,
    pub n_stages: usize,
    /// Per-replica op timelines, shard order (one entry, replica 0, on
    /// single-replica systems). Empty unless timelines were requested.
    pub replicas: Vec<ReplicaTrace>,
    /// Step-barrier breakdown (sharded systems only).
    pub barrier: Option<BarrierTrace>,
    /// The realized global batch this iteration executed (pooled, shard
    /// order on sharded systems). Empty unless [`ObsConfig::audit`].
    pub batch: Vec<ItemShape>,
}

impl IterationTrace {
    fn default_with(t_start: f64) -> IterationTrace {
        IterationTrace { t_start, ..IterationTrace::default() }
    }
}

/// Everything one run's recorder captured, in iteration order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunLog {
    pub cfg: ObsConfig,
    /// Simulated seconds at run end (sum of iteration times).
    pub sim_now: f64,
    pub iterations: Vec<IterationTrace>,
    /// Structured events sorted by iteration (stable within one).
    pub events: Vec<Event>,
    /// The metrics registry (`ObsConfig::metrics`).
    pub metrics: Option<Registry>,
    /// The post-run audit ([`ObsConfig::audit`]; attached by
    /// `obs::audit::run_audit` after the engine loop finishes).
    pub audit: Option<AuditReport>,
    /// Replica traces staged by the executor for the in-flight
    /// iteration, drained at the next `end_iteration`.
    pending_replicas: Vec<ReplicaTrace>,
    pending_barrier: Option<BarrierTrace>,
    /// The in-flight iteration's realized batch ([`ObsConfig::audit`]).
    pending_batch: Vec<ItemShape>,
    /// Last drift phase, so only transitions emit events.
    last_phase: Option<&'static str>,
}

impl RunLog {
    fn push_event(&mut self, kind: EventKind) {
        if let Some(reg) = self.metrics.as_mut() {
            match &kind {
                EventKind::Fault { failures, recoveries, resharded } => {
                    reg.counter_add("fault_failures", *failures as u64);
                    reg.counter_add("fault_recoveries", *recoveries as u64);
                    if *resharded {
                        reg.counter_add("fault_reshards", 1);
                    }
                }
                EventKind::PlanSwap { .. } => reg.counter_add("plan_swaps", 1),
                EventKind::DriftPhase { .. } => reg.counter_add("drift_transitions", 1),
                EventKind::Migration { items } => {
                    reg.counter_add("migrated_items", *items as u64)
                }
                EventKind::LptFallback => reg.counter_add("lpt_fallbacks", 1),
                EventKind::Replan { .. } => {}
            }
        }
        self.events.push(Event { iteration: self.iterations.len(), t: self.sim_now, kind });
    }

    fn end_iteration(&mut self, stats: &IterationStats) {
        let t_start = self.sim_now;
        let mut replicas = std::mem::take(&mut self.pending_replicas);
        // Single-replica systems never stage traces — lift replica 0
        // straight off the iteration's own recorded timeline.
        if self.cfg.timelines && replicas.is_empty() && !stats.timeline.is_empty() {
            replicas.push(ReplicaTrace {
                replica: 0,
                n_stages: stats.n_stages,
                makespan: stats.pipeline_makespan,
                stage_busy: stats.stage_busy.clone(),
                timeline: stats.timeline.clone(),
            });
        }
        let barrier = self.pending_barrier.take();
        if let Some(reg) = self.metrics.as_mut() {
            reg.counter_add("iterations", 1);
            reg.gauge_set("step_time_s", stats.iteration_time);
            reg.gauge_set("pipeline_makespan_s", stats.pipeline_makespan);
            reg.gauge_set("dp_sync_s", stats.dp_sync_time);
            let frac = iteration_bubble_fraction(stats);
            reg.gauge_set("bubble_fraction", frac);
            reg.observe("step_time_s", stats.iteration_time);
            reg.observe("bubble_fraction", frac);
            if let Some(b) = &barrier {
                reg.gauge_set("straggler_gap_s", b.straggler_gap);
                reg.observe("straggler_gap_s", b.straggler_gap);
            }
            reg.snapshot(self.iterations.len(), t_start);
        }
        self.iterations.push(IterationTrace {
            t_start,
            iteration_time: stats.iteration_time,
            pipeline_makespan: stats.pipeline_makespan,
            dp_sync_time: stats.dp_sync_time,
            n_stages: stats.n_stages,
            replicas,
            barrier,
            batch: std::mem::take(&mut self.pending_batch),
        });
        self.sim_now += stats.iteration_time;
    }
}

/// The recorder seam itself. `Off` is the default and the hot-path
/// contract: every hook below is an inlined single-branch early return,
/// with no allocation and no arithmetic.
#[derive(Clone, Debug, Default, PartialEq)]
pub enum Recorder {
    #[default]
    Off,
    On(Box<RunLog>),
}

impl Recorder {
    /// A recorder for `cfg` (`None` = off — the engine passes
    /// `RunConfig::obs` straight through).
    pub fn new(cfg: Option<&ObsConfig>) -> Recorder {
        match cfg {
            None => Recorder::Off,
            Some(c) => Recorder::On(Box::new(RunLog {
                cfg: *c,
                metrics: c.metrics.then(Registry::default),
                ..RunLog::default()
            })),
        }
    }

    #[inline]
    pub fn is_on(&self) -> bool {
        matches!(self, Recorder::On(_))
    }

    /// Whether per-op timelines should be captured this run.
    #[inline]
    pub fn wants_timelines(&self) -> bool {
        matches!(self, Recorder::On(log) if log.cfg.timelines)
    }

    /// Whether realized batches should be captured for the post-run
    /// audit.
    #[inline]
    pub fn wants_audit(&self) -> bool {
        matches!(self, Recorder::On(log) if log.cfg.audit)
    }

    /// Stage the in-flight iteration's realized global batch (pooled,
    /// shard order on sharded systems; the engine calls this right
    /// after drawing, before scheduling). No-op unless audit was
    /// requested.
    #[inline]
    pub fn audit_batch(&mut self, batch: &[ItemShape]) {
        if let Recorder::On(log) = self {
            if log.cfg.audit {
                log.pending_batch.clear();
                log.pending_batch.extend_from_slice(batch);
            }
        }
    }

    /// Fleet activity at this boundary (no event for a quiet delta;
    /// degraded iterations are counted in the metrics registry).
    #[inline]
    pub fn fault(&mut self, d: &FaultDelta) {
        if let Recorder::On(log) = self {
            if d.degraded {
                if let Some(reg) = log.metrics.as_mut() {
                    reg.counter_add("fault_degraded_iters", 1);
                }
            }
            if d.failures > 0 || d.recoveries > 0 || d.resharded {
                log.push_event(EventKind::Fault {
                    failures: d.failures,
                    recoveries: d.recoveries,
                    resharded: d.resharded,
                });
            }
        }
    }

    /// The policy handed the executor a new plan at this boundary.
    #[inline]
    pub fn plan_swap(&mut self, old: Theta, new: &PlanSet) {
        if let Recorder::On(log) = self {
            log.push_event(EventKind::PlanSwap {
                old,
                new: new.global,
                replicas: new.per_replica.as_ref().map_or(0, Vec::len),
            });
        }
    }

    /// The drift detector's current phase (`stable`/`watch`/`drift`;
    /// `None` for policies without a detector). Only transitions emit
    /// events; an initial `stable` is the baseline, not a transition.
    #[inline]
    pub fn drift_phase(&mut self, phase: Option<&'static str>) {
        if let Recorder::On(log) = self {
            let Some(p) = phase else { return };
            if log.last_phase == Some(p) || (log.last_phase.is_none() && p == "stable") {
                log.last_phase = Some(p);
                return;
            }
            log.last_phase = Some(p);
            let name = match p {
                "watch" => "drift-enter",
                "drift" => "drift-confirm",
                _ => "drift-exit",
            };
            log.push_event(EventKind::DriftPhase { phase: name });
        }
    }

    /// Items the rebalance walk migrated this boundary (0 = no event).
    #[inline]
    pub fn migrations(&mut self, items: usize) {
        if let Recorder::On(log) = self {
            if items > 0 {
                log.push_event(EventKind::Migration { items });
            }
        }
    }

    /// The ILP scheduler's budget expired; the LPT incumbent ran.
    #[inline]
    pub fn lpt_fallback(&mut self) {
        if let Recorder::On(log) = self {
            log.push_event(EventKind::LptFallback);
        }
    }

    /// Stage the per-replica execution of the in-flight sharded
    /// iteration, shard order (called by `ShardedExec` after the health
    /// charge, so traces match the barrier's stretched times). No-op
    /// unless timelines were requested.
    #[inline]
    pub fn replica_timelines(&mut self, per_replica: &[IterationStats]) {
        if let Recorder::On(log) = self {
            if !log.cfg.timelines {
                return;
            }
            log.pending_replicas = per_replica
                .iter()
                .enumerate()
                .map(|(r, s)| ReplicaTrace {
                    replica: r,
                    n_stages: s.n_stages,
                    makespan: s.pipeline_makespan,
                    stage_busy: s.stage_busy.clone(),
                    timeline: s.timeline.clone(),
                })
                .collect();
        }
    }

    /// Stage the in-flight sharded iteration's barrier breakdown.
    #[inline]
    pub fn barrier(&mut self, b: &BarrierStats) {
        if let Recorder::On(log) = self {
            log.pending_barrier = Some(BarrierTrace {
                per_replica: b.per_replica.clone(),
                allreduce: b.allreduce,
                step_time: b.step_time,
                straggler_gap: b.straggler_gap,
            });
        }
    }

    /// Close the in-flight iteration: drain staged traces, snapshot
    /// metrics, advance the simulated clock.
    #[inline]
    pub fn end_iteration(&mut self, stats: &IterationStats) {
        if let Recorder::On(log) = self {
            log.end_iteration(stats);
        }
    }

    /// Finish the run: fold the replanner's event log in (stamped with
    /// each event's iteration start time) and hand the log out. `self`
    /// reverts to `Off`.
    pub fn take_log(&mut self, replans: &[ReplanEvent]) -> Option<Box<RunLog>> {
        let Recorder::On(mut log) = std::mem::take(self) else {
            return None;
        };
        if let Some(reg) = log.metrics.as_mut() {
            let swapped = replans.iter().filter(|e| e.swapped).count() as u64;
            reg.counter_add("replans", swapped);
            reg.counter_add(
                "refit_retries",
                replans.iter().filter(|e| e.expected_makespan.is_nan()).count() as u64,
            );
        }
        for e in replans {
            let t = log
                .iterations
                .get(e.iteration)
                .map_or(log.sim_now, |it| it.t_start);
            log.events.push(Event {
                iteration: e.iteration,
                t,
                kind: EventKind::Replan {
                    swapped: e.swapped,
                    score: e.stat.score(),
                    expected_makespan: e
                        .expected_makespan
                        .is_finite()
                        .then_some(e.expected_makespan),
                },
            });
        }
        // Stable: within one iteration, live events keep their order and
        // replans land after them.
        log.events.sort_by_key(|e| e.iteration);
        Some(log)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::build::IterationStats;

    fn stats(t: f64) -> IterationStats {
        IterationStats {
            iteration_time: t,
            pipeline_makespan: t,
            dp_sync_time: 0.0,
            stage_busy: vec![t],
            stage_idle: vec![0.0],
            stage_flop: vec![1.0],
            n_stages: 1,
            total_flop: 1.0,
            buckets: Vec::new(),
            timeline: vec![OpRecord {
                bucket: 0,
                stage: 0,
                is_forward: true,
                start: 0.0,
                finish: t,
            }],
            fills: Vec::new(),
        }
    }

    #[test]
    fn off_recorder_is_inert_and_yields_no_log() {
        let mut rec = Recorder::new(None);
        assert!(!rec.is_on());
        rec.end_iteration(&stats(1.0));
        rec.migrations(5);
        rec.lpt_fallback();
        assert!(rec.take_log(&[]).is_none());
    }

    #[test]
    fn sim_clock_advances_and_events_stamp_iteration_starts() {
        let mut rec =
            Recorder::new(Some(&ObsConfig { timelines: true, metrics: false, audit: false }));
        rec.end_iteration(&stats(2.0));
        rec.migrations(3);
        rec.end_iteration(&stats(3.0));
        let log = rec.take_log(&[]).expect("on");
        assert_eq!(log.iterations.len(), 2);
        assert_eq!(log.iterations[0].t_start, 0.0);
        assert_eq!(log.iterations[1].t_start, 2.0);
        assert_eq!(log.sim_now, 5.0);
        // The migration fired between the boundaries: iteration 1, t=2.
        assert_eq!(log.events.len(), 1);
        assert_eq!(log.events[0].iteration, 1);
        assert_eq!(log.events[0].t, 2.0);
        // Timelines were requested: replica 0 lifted off the stats.
        assert_eq!(log.iterations[0].replicas.len(), 1);
        assert_eq!(log.iterations[0].replicas[0].replica, 0);
    }

    #[test]
    fn drift_phase_emits_transitions_only() {
        let mut rec =
            Recorder::new(Some(&ObsConfig { timelines: false, metrics: false, audit: false }));
        rec.drift_phase(None);
        rec.drift_phase(Some("stable"));
        rec.drift_phase(Some("stable"));
        rec.drift_phase(Some("watch"));
        rec.drift_phase(Some("drift"));
        rec.drift_phase(Some("stable"));
        let log = rec.take_log(&[]).expect("on");
        let phases: Vec<&str> = log
            .events
            .iter()
            .map(|e| match &e.kind {
                EventKind::DriftPhase { phase } => *phase,
                other => panic!("unexpected {other:?}"),
            })
            .collect();
        assert_eq!(phases, vec!["drift-enter", "drift-confirm", "drift-exit"]);
    }
}
