//! Chrome Trace Event Format export (loadable in Perfetto /
//! `chrome://tracing`).
//!
//! Lane layout: the synthetic *cluster* process (pid 1000) carries
//! iteration spans (tid 0), sync spans (tid 1: allreduce / straggler
//! gap / dp-sync), and all instant events; each replica is its own
//! process (pid = replica index) with one thread per pipeline stage
//! carrying op spans (`F<bucket>`/`B<bucket>`, cat `op`) and bubble
//! spans (cat `bubble`, from [`crate::obs::bubble::stage_bubbles`]).
//!
//! Timestamps are simulated seconds scaled to microseconds (the
//! format's unit); `dur` may be fractional, which the format allows.
//! Events are emitted only as `X` (complete), `i` (instant, global
//! scope) and `M` (metadata) phases, sorted by `ts` with a stable
//! `total_cmp` — the export is byte-deterministic because the `RunLog`
//! it renders is.

use crate::obs::bubble::stage_bubbles;
use crate::obs::record::{EventKind, RunLog};
use crate::util::json::{emit, parse, Json};

/// The cluster-wide synthetic process id (replica pids count from 0).
pub const CLUSTER_PID: usize = 1000;

const TID_ITER: usize = 0;
const TID_SYNC: usize = 1;

fn us(sim_seconds: f64) -> f64 {
    sim_seconds * 1e6
}

fn span(
    name: &str,
    cat: &str,
    pid: usize,
    tid: usize,
    ts_us: f64,
    dur_us: f64,
    args: Vec<(&str, Json)>,
) -> (f64, Json) {
    let mut fields = vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("X")),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(dur_us)),
    ];
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    (ts_us, Json::obj(fields))
}

fn meta_process(pid: usize, name: &str) -> (f64, Json) {
    (
        f64::NEG_INFINITY, // metadata sorts ahead of every timed event
        Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]),
    )
}

/// Render a recorded run as a Chrome Trace Event Format document
/// (trailing newline included).
pub fn trace_json(log: &RunLog) -> String {
    let mut evs: Vec<(f64, Json)> = Vec::new();
    evs.push(meta_process(CLUSTER_PID, "cluster"));
    let n_replicas =
        log.iterations.iter().map(|it| it.replicas.len()).max().unwrap_or(0);
    for r in 0..n_replicas {
        evs.push(meta_process(r, &format!("replica {r}")));
    }

    for (i, it) in log.iterations.iter().enumerate() {
        evs.push(span(
            &format!("iter {i}"),
            "iteration",
            CLUSTER_PID,
            TID_ITER,
            us(it.t_start),
            us(it.iteration_time),
            vec![
                ("makespan_s", Json::Num(it.pipeline_makespan)),
                ("dp_sync_s", Json::Num(it.dp_sync_time)),
            ],
        ));
        if let Some(b) = &it.barrier {
            if b.allreduce > 0.0 {
                evs.push(span(
                    "allreduce",
                    "sync",
                    CLUSTER_PID,
                    TID_SYNC,
                    us(it.t_start + (b.step_time - b.allreduce)),
                    us(b.allreduce),
                    Vec::new(),
                ));
            }
            if b.straggler_gap > 0.0 {
                let first_done =
                    b.per_replica.iter().cloned().fold(f64::INFINITY, f64::min);
                evs.push(span(
                    "straggler gap",
                    "sync",
                    CLUSTER_PID,
                    TID_SYNC,
                    us(it.t_start + first_done),
                    us(b.straggler_gap),
                    Vec::new(),
                ));
            }
        } else if it.dp_sync_time > 0.0 {
            evs.push(span(
                "dp sync",
                "sync",
                CLUSTER_PID,
                TID_SYNC,
                us(it.t_start + it.pipeline_makespan),
                us(it.dp_sync_time),
                Vec::new(),
            ));
        }
        for rep in &it.replicas {
            for op in &rep.timeline {
                let name = format!(
                    "{}{}",
                    if op.is_forward { "F" } else { "B" },
                    op.bucket
                );
                evs.push(span(
                    &name,
                    "op",
                    rep.replica,
                    op.stage,
                    us(it.t_start + op.start),
                    us(op.finish - op.start),
                    Vec::new(),
                ));
            }
            let bub = stage_bubbles(
                &rep.timeline,
                rep.n_stages,
                rep.makespan,
                &rep.stage_busy,
            );
            for g in bub.gaps.iter().filter(|g| !g.is_empty()) {
                evs.push(span(
                    "bubble",
                    "bubble",
                    rep.replica,
                    g.stage,
                    us(it.t_start + g.start),
                    us(g.len()),
                    Vec::new(),
                ));
            }
        }
    }

    for e in &log.events {
        let mut args = vec![("iteration", Json::Num(e.iteration as f64))];
        let name = match &e.kind {
            EventKind::Fault { failures, recoveries, resharded } => {
                args.push(("failures", Json::Num(*failures as f64)));
                args.push(("recoveries", Json::Num(*recoveries as f64)));
                args.push(("resharded", Json::Bool(*resharded)));
                "fault"
            }
            EventKind::PlanSwap { old, new, replicas } => {
                args.push(("old", Json::str(format!("{old}"))));
                args.push(("new", Json::str(format!("{new}"))));
                args.push(("per_replica", Json::Num(*replicas as f64)));
                "plan-swap"
            }
            EventKind::DriftPhase { phase } => *phase,
            EventKind::Migration { items } => {
                args.push(("items", Json::Num(*items as f64)));
                "migration"
            }
            EventKind::LptFallback => "lpt-fallback",
            EventKind::Replan { swapped, score, expected_makespan } => {
                args.push(("score", Json::Num(*score)));
                if let Some(m) = expected_makespan {
                    args.push(("expected_makespan_s", Json::Num(*m)));
                }
                if *swapped {
                    "replan"
                } else if expected_makespan.is_some() {
                    "replan-kept"
                } else {
                    "refit-retry"
                }
            }
        };
        let ts = us(e.t);
        evs.push((
            ts,
            Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str("event")),
                ("ph", Json::str("i")),
                ("s", Json::str("g")),
                ("pid", Json::Num(CLUSTER_PID as f64)),
                ("tid", Json::Num(TID_ITER as f64)),
                ("ts", Json::Num(ts)),
                ("args", Json::obj(args)),
            ]),
        ));
    }

    evs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let doc = Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(evs.into_iter().map(|(_, j)| j).collect())),
    ]);
    emit(&doc) + "\n"
}

/// Validate a trace document against the slice of the Chrome Trace
/// Event Format this exporter emits: valid JSON with a `traceEvents`
/// array; every event carries `name`/`ph`/`pid`/`tid`; timed phases
/// (`X`, `i`) carry finite `ts` in non-decreasing order; `X` carries a
/// finite non-negative `dur`; `i` carries a scope `s`; no other phases
/// appear (durations are exported as complete `X` spans, never `B`/`E`
/// pairs).
pub fn validate_trace(text: &str) -> Result<(), String> {
    let doc = parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut last_ts = f64::NEG_INFINITY;
    for (i, ev) in events.iter().enumerate() {
        if ev.as_obj().is_none() {
            return Err(format!("event {i}: not an object"));
        }
        for key in ["name", "ph"] {
            if ev.get(key).and_then(Json::as_str).is_none() {
                return Err(format!("event {i}: missing {key}"));
            }
        }
        for key in ["pid", "tid"] {
            if ev.get(key).and_then(Json::as_f64).is_none() {
                return Err(format!("event {i}: missing {key}"));
            }
        }
        let ph = ev.get("ph").and_then(Json::as_str).unwrap_or_default();
        if ph == "M" {
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        if !ts.is_finite() {
            return Err(format!("event {i}: non-finite ts"));
        }
        if ts < last_ts {
            return Err(format!("event {i}: ts out of order"));
        }
        last_ts = ts;
        match ph {
            "X" => {
                let dur = ev
                    .get("dur")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i}: X without dur"))?;
                if !dur.is_finite() || dur < 0.0 {
                    return Err(format!("event {i}: bad dur"));
                }
            }
            "i" => {
                if ev.get("s").and_then(Json::as_str).is_none() {
                    return Err(format!("event {i}: instant without scope"));
                }
            }
            other => return Err(format!("event {i}: unexpected phase '{other}'")),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::record::{ObsConfig, Recorder};
    use crate::pipeline::build::IterationStats;
    use crate::pipeline::sim::OpRecord;

    fn one_iteration_log() -> Box<RunLog> {
        let mut rec =
            Recorder::new(Some(&ObsConfig { timelines: true, metrics: false }));
        rec.migrations(2);
        rec.end_iteration(&IterationStats {
            iteration_time: 1.5,
            pipeline_makespan: 1.0,
            dp_sync_time: 0.5,
            stage_busy: vec![0.75],
            stage_idle: vec![0.25],
            stage_flop: vec![1.0],
            n_stages: 1,
            total_flop: 1.0,
            buckets: Vec::new(),
            timeline: vec![OpRecord {
                bucket: 0,
                stage: 0,
                is_forward: true,
                start: 0.25,
                finish: 1.0,
            }],
        });
        rec.take_log(&[]).expect("on")
    }

    #[test]
    fn export_validates_and_contains_expected_lanes() {
        let text = trace_json(&one_iteration_log());
        validate_trace(&text).expect("schema-valid");
        let doc = parse(&text).expect("json");
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(Json::as_str))
            .collect();
        assert!(names.contains(&"iter 0"));
        assert!(names.contains(&"F0"));
        assert!(names.contains(&"bubble"));
        assert!(names.contains(&"dp sync"));
        assert!(names.contains(&"migration"));
        assert!(names.contains(&"process_name"));
    }

    #[test]
    fn validator_rejects_unsorted_and_unknown_phases() {
        let bad = r#"{"traceEvents":[
            {"name":"a","ph":"X","pid":0,"tid":0,"ts":5,"dur":1},
            {"name":"b","ph":"X","pid":0,"tid":0,"ts":1,"dur":1}]}"#;
        assert!(validate_trace(bad).is_err());
        let bad_ph = r#"{"traceEvents":[
            {"name":"a","ph":"B","pid":0,"tid":0,"ts":1}]}"#;
        assert!(validate_trace(bad_ph).is_err());
        assert!(validate_trace("not json").is_err());
    }
}
