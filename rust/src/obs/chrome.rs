//! Chrome Trace Event Format export (loadable in Perfetto /
//! `chrome://tracing`).
//!
//! Lane layout: the synthetic *cluster* process (pid 1000) carries
//! iteration spans (tid 0), sync spans (tid 1: allreduce / straggler
//! gap / dp-sync), and all instant events; each replica is its own
//! process (pid = replica index) with one thread per pipeline stage
//! carrying op spans (`F<bucket>`/`B<bucket>`, cat `op`) and bubble
//! spans (cat `bubble`, from [`crate::obs::bubble::stage_bubbles`]).
//!
//! Timestamps are simulated seconds scaled to microseconds (the
//! format's unit); `dur` may be fractional, which the format allows.
//! Events are emitted as `X` (complete), `i` (instant, global scope),
//! `M` (metadata), `s`/`t`/`f` (flow: each confirmed drift is linked
//! through its replan verdict to the plan swap it produced, one flow id
//! per episode) and `C` (per-iteration predicted-vs-measured counter
//! rows when an `obs::audit` report is attached) phases, sorted by `ts`
//! with a stable `total_cmp` — the export is byte-deterministic because
//! the `RunLog` it renders is.

use crate::obs::bubble::stage_bubbles;
use crate::obs::record::{EventKind, RunLog};
use crate::util::json::{emit, parse, Json};

/// The cluster-wide synthetic process id (replica pids count from 0).
pub const CLUSTER_PID: usize = 1000;

const TID_ITER: usize = 0;
const TID_SYNC: usize = 1;

fn us(sim_seconds: f64) -> f64 {
    sim_seconds * 1e6
}

fn span(
    name: &str,
    cat: &str,
    pid: usize,
    tid: usize,
    ts_us: f64,
    dur_us: f64,
    args: Vec<(&str, Json)>,
) -> (f64, Json) {
    let mut fields = vec![
        ("name", Json::str(name)),
        ("cat", Json::str(cat)),
        ("ph", Json::str("X")),
        ("pid", Json::Num(pid as f64)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(ts_us)),
        ("dur", Json::Num(dur_us)),
    ];
    if !args.is_empty() {
        fields.push(("args", Json::obj(args)));
    }
    (ts_us, Json::obj(fields))
}

/// One flow-event phase (`s` start / `t` step / `f` end) of the
/// drift-confirm → replan-verdict → plan-swap chain `id`.
fn flow(ph: &str, id: usize, ts_us: f64) -> (f64, Json) {
    let mut fields = vec![
        ("name", Json::str("replan-flow")),
        ("cat", Json::str("flow")),
        ("ph", Json::str(ph)),
        ("id", Json::Num(id as f64)),
        ("pid", Json::Num(CLUSTER_PID as f64)),
        ("tid", Json::Num(TID_ITER as f64)),
        ("ts", Json::Num(ts_us)),
    ];
    if ph == "f" {
        // Bind the arrow head to the enclosing slice's end.
        fields.push(("bp", Json::str("e")));
    }
    (ts_us, Json::obj(fields))
}

fn meta_process(pid: usize, name: &str) -> (f64, Json) {
    (
        f64::NEG_INFINITY, // metadata sorts ahead of every timed event
        Json::obj(vec![
            ("name", Json::str("process_name")),
            ("ph", Json::str("M")),
            ("pid", Json::Num(pid as f64)),
            ("tid", Json::Num(0.0)),
            ("args", Json::obj(vec![("name", Json::str(name))])),
        ]),
    )
}

/// Render a recorded run as a Chrome Trace Event Format document
/// (trailing newline included).
pub fn trace_json(log: &RunLog) -> String {
    let mut evs: Vec<(f64, Json)> = Vec::new();
    evs.push(meta_process(CLUSTER_PID, "cluster"));
    let n_replicas =
        log.iterations.iter().map(|it| it.replicas.len()).max().unwrap_or(0);
    for r in 0..n_replicas {
        evs.push(meta_process(r, &format!("replica {r}")));
    }

    for (i, it) in log.iterations.iter().enumerate() {
        evs.push(span(
            &format!("iter {i}"),
            "iteration",
            CLUSTER_PID,
            TID_ITER,
            us(it.t_start),
            us(it.iteration_time),
            vec![
                ("makespan_s", Json::Num(it.pipeline_makespan)),
                ("dp_sync_s", Json::Num(it.dp_sync_time)),
            ],
        ));
        if let Some(b) = &it.barrier {
            if b.allreduce > 0.0 {
                evs.push(span(
                    "allreduce",
                    "sync",
                    CLUSTER_PID,
                    TID_SYNC,
                    us(it.t_start + (b.step_time - b.allreduce)),
                    us(b.allreduce),
                    Vec::new(),
                ));
            }
            if b.straggler_gap > 0.0 {
                let first_done =
                    b.per_replica.iter().cloned().fold(f64::INFINITY, f64::min);
                evs.push(span(
                    "straggler gap",
                    "sync",
                    CLUSTER_PID,
                    TID_SYNC,
                    us(it.t_start + first_done),
                    us(b.straggler_gap),
                    Vec::new(),
                ));
            }
        } else if it.dp_sync_time > 0.0 {
            evs.push(span(
                "dp sync",
                "sync",
                CLUSTER_PID,
                TID_SYNC,
                us(it.t_start + it.pipeline_makespan),
                us(it.dp_sync_time),
                Vec::new(),
            ));
        }
        for rep in &it.replicas {
            for op in &rep.timeline {
                let name = format!(
                    "{}{}",
                    if op.is_forward { "F" } else { "B" },
                    op.bucket
                );
                evs.push(span(
                    &name,
                    "op",
                    rep.replica,
                    op.stage,
                    us(it.t_start + op.start),
                    us(op.finish - op.start),
                    Vec::new(),
                ));
            }
            let bub = stage_bubbles(
                &rep.timeline,
                rep.n_stages,
                rep.makespan,
                &rep.stage_busy,
            );
            for g in bub.gaps.iter().filter(|g| !g.is_empty()) {
                evs.push(span(
                    "bubble",
                    "bubble",
                    rep.replica,
                    g.stage,
                    us(it.t_start + g.start),
                    us(g.len()),
                    Vec::new(),
                ));
            }
        }
    }

    for e in &log.events {
        let mut args = vec![("iteration", Json::Num(e.iteration as f64))];
        let name = match &e.kind {
            EventKind::Fault { failures, recoveries, resharded } => {
                args.push(("failures", Json::Num(*failures as f64)));
                args.push(("recoveries", Json::Num(*recoveries as f64)));
                args.push(("resharded", Json::Bool(*resharded)));
                "fault"
            }
            EventKind::PlanSwap { old, new, replicas } => {
                args.push(("old", Json::str(format!("{old}"))));
                args.push(("new", Json::str(format!("{new}"))));
                args.push(("per_replica", Json::Num(*replicas as f64)));
                "plan-swap"
            }
            EventKind::DriftPhase { phase } => *phase,
            EventKind::Migration { items } => {
                args.push(("items", Json::Num(*items as f64)));
                "migration"
            }
            EventKind::LptFallback => "lpt-fallback",
            EventKind::Replan { swapped, score, expected_makespan } => {
                args.push(("score", Json::Num(*score)));
                if let Some(m) = expected_makespan {
                    args.push(("expected_makespan_s", Json::Num(*m)));
                }
                if *swapped {
                    "replan"
                } else if expected_makespan.is_some() {
                    "replan-kept"
                } else {
                    "refit-retry"
                }
            }
        };
        let ts = us(e.t);
        evs.push((
            ts,
            Json::obj(vec![
                ("name", Json::str(name)),
                ("cat", Json::str("event")),
                ("ph", Json::str("i")),
                ("s", Json::str("g")),
                ("pid", Json::Num(CLUSTER_PID as f64)),
                ("tid", Json::Num(TID_ITER as f64)),
                ("ts", Json::Num(ts)),
                ("args", Json::obj(args)),
            ]),
        ));
    }

    // Flow chains: each confirmed drift opens an episode; the next
    // replan verdict and plan swap (in event order — within one
    // iteration live events precede the folded verdict) close it. An
    // episode missing both is dropped whole, so every emitted flow id
    // has its `s` paired with exactly one `f`.
    #[derive(Clone, Copy, Default)]
    struct Episode {
        confirm: Option<f64>,
        verdict: Option<f64>,
        swap: Option<f64>,
    }
    let mut episodes: Vec<Episode> = Vec::new();
    let mut open: Option<Episode> = None;
    for e in &log.events {
        match &e.kind {
            EventKind::DriftPhase { phase: "drift-confirm" } => {
                if let Some(ep) = open.take() {
                    episodes.push(ep);
                }
                open = Some(Episode { confirm: Some(us(e.t)), ..Episode::default() });
            }
            EventKind::Replan { .. } => {
                if let Some(ep) = open.as_mut() {
                    if ep.verdict.is_none() {
                        ep.verdict = Some(us(e.t));
                    }
                }
            }
            EventKind::PlanSwap { .. } => {
                if let Some(ep) = open.as_mut() {
                    if ep.swap.is_none() {
                        ep.swap = Some(us(e.t));
                    }
                }
            }
            _ => {}
        }
    }
    episodes.extend(open.take());
    let mut flow_id = 0usize;
    for ep in &episodes {
        let Some(start) = ep.confirm else { continue };
        let Some(end) = ep.swap.or(ep.verdict) else { continue };
        flow_id += 1;
        evs.push(flow("s", flow_id, start));
        if let (Some(v), Some(_)) = (ep.verdict, ep.swap) {
            evs.push(flow("t", flow_id, v));
        }
        evs.push(flow("f", flow_id, end));
    }

    // Audit counter rows: predicted vs measured step time per audited
    // iteration, rendered as a counter track.
    if let Some(audit) = &log.audit {
        for r in &audit.rows {
            let t = log.iterations.get(r.iteration).map_or(log.sim_now, |it| it.t_start);
            let ts = us(t);
            evs.push((
                ts,
                Json::obj(vec![
                    ("name", Json::str("plan-audit")),
                    ("cat", Json::str("audit")),
                    ("ph", Json::str("C")),
                    ("pid", Json::Num(CLUSTER_PID as f64)),
                    ("tid", Json::Num(TID_ITER as f64)),
                    ("ts", Json::Num(ts)),
                    (
                        "args",
                        Json::obj(vec![
                            ("predicted_s", Json::Num(r.predicted)),
                            ("measured_s", Json::Num(r.measured)),
                        ]),
                    ),
                ]),
            ));
        }
    }

    evs.sort_by(|a, b| a.0.total_cmp(&b.0));
    let doc = Json::obj(vec![
        ("displayTimeUnit", Json::str("ms")),
        ("traceEvents", Json::Arr(evs.into_iter().map(|(_, j)| j).collect())),
    ]);
    emit(&doc) + "\n"
}

/// Validate a trace document against the slice of the Chrome Trace
/// Event Format this exporter emits: valid JSON with a `traceEvents`
/// array; every event carries `name`/`ph`/`pid`/`tid`; timed phases
/// carry finite `ts` in non-decreasing order; `X` carries a finite
/// non-negative `dur`; `i` carries a scope `s`; `C` carries an `args`
/// object; flow phases (`s`/`t`/`f`) carry a numeric `id` and pair up —
/// per id exactly one `s` opens the chain, steps stay inside it, and
/// exactly one `f` closes it. No other phases appear (durations are
/// exported as complete `X` spans, never `B`/`E` pairs).
pub fn validate_trace(text: &str) -> Result<(), String> {
    let doc = parse(text).map_err(|e| format!("invalid JSON: {e}"))?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or("missing traceEvents array")?;
    let mut last_ts = f64::NEG_INFINITY;
    // Flow-chain state per id: 1 = open (`s` seen), 2 = closed (`f`).
    let mut flows: std::collections::BTreeMap<u64, u8> = Default::default();
    for (i, ev) in events.iter().enumerate() {
        if ev.as_obj().is_none() {
            return Err(format!("event {i}: not an object"));
        }
        for key in ["name", "ph"] {
            if ev.get(key).and_then(Json::as_str).is_none() {
                return Err(format!("event {i}: missing {key}"));
            }
        }
        for key in ["pid", "tid"] {
            if ev.get(key).and_then(Json::as_f64).is_none() {
                return Err(format!("event {i}: missing {key}"));
            }
        }
        let ph = ev.get("ph").and_then(Json::as_str).unwrap_or_default();
        if ph == "M" {
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        if !ts.is_finite() {
            return Err(format!("event {i}: non-finite ts"));
        }
        if ts < last_ts {
            return Err(format!("event {i}: ts out of order"));
        }
        last_ts = ts;
        match ph {
            "X" => {
                let dur = ev
                    .get("dur")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("event {i}: X without dur"))?;
                if !dur.is_finite() || dur < 0.0 {
                    return Err(format!("event {i}: bad dur"));
                }
            }
            "i" => {
                if ev.get("s").and_then(Json::as_str).is_none() {
                    return Err(format!("event {i}: instant without scope"));
                }
            }
            "C" => {
                if ev.get("args").and_then(Json::as_obj).is_none() {
                    return Err(format!("event {i}: counter without args"));
                }
            }
            "s" | "t" | "f" => {
                let id = ev
                    .get("id")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| format!("event {i}: flow without numeric id"))? as u64;
                let state = flows.entry(id).or_insert(0);
                match (ph, *state) {
                    ("s", 0) => *state = 1,
                    ("s", _) => return Err(format!("event {i}: duplicate flow start id {id}")),
                    ("t", 1) => {}
                    ("f", 1) => *state = 2,
                    (_, 0) => return Err(format!("event {i}: flow id {id} not opened")),
                    (_, _) => {
                        return Err(format!("event {i}: flow id {id} already closed"))
                    }
                }
            }
            other => return Err(format!("event {i}: unexpected phase '{other}'")),
        }
    }
    if let Some((id, _)) = flows.iter().find(|(_, &s)| s != 2) {
        return Err(format!("flow id {id}: started but never finished"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::record::{ObsConfig, Recorder};
    use crate::pipeline::build::IterationStats;
    use crate::pipeline::sim::OpRecord;

    fn stats_1op(t: f64) -> IterationStats {
        IterationStats {
            iteration_time: t * 1.5,
            pipeline_makespan: t,
            dp_sync_time: t * 0.5,
            stage_busy: vec![t * 0.75],
            stage_idle: vec![t * 0.25],
            stage_flop: vec![1.0],
            n_stages: 1,
            total_flop: 1.0,
            buckets: Vec::new(),
            timeline: vec![OpRecord {
                bucket: 0,
                stage: 0,
                is_forward: true,
                start: t * 0.25,
                finish: t,
            }],
            fills: Vec::new(),
        }
    }

    fn one_iteration_log() -> Box<RunLog> {
        let mut rec = Recorder::new(Some(&ObsConfig {
            timelines: true,
            metrics: false,
            audit: false,
        }));
        rec.migrations(2);
        rec.end_iteration(&stats_1op(1.0));
        rec.take_log(&[]).expect("on")
    }

    #[test]
    fn export_validates_and_contains_expected_lanes() {
        let text = trace_json(&one_iteration_log());
        validate_trace(&text).expect("schema-valid");
        let doc = parse(&text).expect("json");
        let events = doc.get("traceEvents").and_then(Json::as_arr).unwrap();
        let names: Vec<&str> = events
            .iter()
            .filter_map(|e| e.get("name").and_then(Json::as_str))
            .collect();
        assert!(names.contains(&"iter 0"));
        assert!(names.contains(&"F0"));
        assert!(names.contains(&"bubble"));
        assert!(names.contains(&"dp sync"));
        assert!(names.contains(&"migration"));
        assert!(names.contains(&"process_name"));
    }

    #[test]
    fn validator_rejects_unsorted_and_unknown_phases() {
        let bad = r#"{"traceEvents":[
            {"name":"a","ph":"X","pid":0,"tid":0,"ts":5,"dur":1},
            {"name":"b","ph":"X","pid":0,"tid":0,"ts":1,"dur":1}]}"#;
        assert!(validate_trace(bad).is_err());
        let bad_ph = r#"{"traceEvents":[
            {"name":"a","ph":"B","pid":0,"tid":0,"ts":1}]}"#;
        assert!(validate_trace(bad_ph).is_err());
        assert!(validate_trace("not json").is_err());
    }

    #[test]
    fn replan_chain_exports_paired_flow_events() {
        use crate::engine::policy::PlanSet;
        use crate::optimizer::plan::{ModPar, Theta};
        use crate::stream::drift::DriftStat;
        use crate::stream::replan::ReplanEvent;
        let theta = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 1, dp: 1 },
            n_mb: 1,
        };
        let mut rec = Recorder::new(Some(&ObsConfig {
            timelines: false,
            metrics: false,
            audit: false,
        }));
        rec.end_iteration(&stats_1op(1.0));
        rec.drift_phase(Some("watch"));
        rec.drift_phase(Some("drift"));
        rec.plan_swap(theta, &PlanSet { global: theta, per_replica: None });
        rec.end_iteration(&stats_1op(1.0));
        let log = rec.take_log(&[ReplanEvent {
            iteration: 1,
            stat: DriftStat { quantile_dist: 0.0, units_dist: 0.0, mix_tv: 0.0 },
            old: theta,
            new: theta,
            swapped: true,
            expected_makespan: 1.0,
            expected_incumbent: 1.2,
            elapsed: std::time::Duration::ZERO,
        }]);
        let text = trace_json(&log.expect("on"));
        validate_trace(&text).expect("flow ids pair up");
        let doc = parse(&text).expect("json");
        let phases: Vec<&str> = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .filter(|e| e.get("name").and_then(Json::as_str) == Some("replan-flow"))
            .filter_map(|e| e.get("ph").and_then(Json::as_str))
            .collect();
        assert_eq!(phases, vec!["s", "t", "f"]);
    }

    #[test]
    fn audit_report_exports_counter_rows() {
        use crate::obs::audit::{AuditReport, AuditRow};
        let mut log = one_iteration_log();
        log.audit = Some(AuditReport {
            rows: vec![AuditRow {
                iteration: 0,
                predicted: 1.4,
                measured: 1.5,
                residual: -0.1,
                rel_err: -0.1 / 1.5,
                enc_flop_share: 0.3,
                plan_epoch: 0,
            }],
            ..AuditReport::default()
        });
        let text = trace_json(&log);
        validate_trace(&text).expect("schema-valid with counters");
        let doc = parse(&text).expect("json");
        let counter = doc
            .get("traceEvents")
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .find(|e| e.get("ph").and_then(Json::as_str) == Some("C"))
            .expect("counter row present");
        assert_eq!(counter.get("name").and_then(Json::as_str), Some("plan-audit"));
        assert_eq!(
            counter.path("args.predicted_s").and_then(Json::as_f64),
            Some(1.4)
        );
    }

    #[test]
    fn validator_rejects_unpaired_or_reused_flow_ids() {
        let dangling = r#"{"traceEvents":[
            {"name":"x","cat":"flow","ph":"s","id":1,"pid":0,"tid":0,"ts":1}]}"#;
        assert!(validate_trace(dangling).is_err());
        let unopened = r#"{"traceEvents":[
            {"name":"x","cat":"flow","ph":"f","id":1,"pid":0,"tid":0,"ts":1}]}"#;
        assert!(validate_trace(unopened).is_err());
        let reused = r#"{"traceEvents":[
            {"name":"x","cat":"flow","ph":"s","id":1,"pid":0,"tid":0,"ts":1},
            {"name":"x","cat":"flow","ph":"f","id":1,"pid":0,"tid":0,"ts":2},
            {"name":"x","cat":"flow","ph":"s","id":1,"pid":0,"tid":0,"ts":3}]}"#;
        assert!(validate_trace(reused).is_err());
        let paired = r#"{"traceEvents":[
            {"name":"x","cat":"flow","ph":"s","id":1,"pid":0,"tid":0,"ts":1},
            {"name":"x","cat":"flow","ph":"t","id":1,"pid":0,"tid":0,"ts":2},
            {"name":"x","cat":"flow","ph":"f","id":1,"pid":0,"tid":0,"ts":3}]}"#;
        assert!(validate_trace(paired).is_ok());
    }
}
