//! Observability: deterministic run tracing, analysis, and telemetry
//! export.
//!
//! Recording layers on one seam:
//!
//! - [`record`] — the [`Recorder`] that `engine::run` threads through
//!   `Telemetry`: structured sim-time-stamped events (plan swaps, drift
//!   transitions, fault deltas, migrations, refit retries) plus opt-in
//!   per-op / per-replica timelines and realized batches, captured only
//!   at iteration boundaries on the engine-loop thread.
//! - [`chrome`] — Chrome Trace Event Format export
//!   (`dflop run ... --trace out.json`, loadable in Perfetto) plus a
//!   schema validator (spans, instants, replan flow chains, audit
//!   counter rows).
//! - [`metrics`] — the std-only counter/gauge/histogram [`Registry`]
//!   with per-iteration snapshots and bounded-memory histogram
//!   reservoirs (`--metrics out.json`) — the one place new subsystems
//!   register run telemetry.
//!
//! Analysis layers on the recorded log:
//!
//! - [`bubble`] — per-stage bubble-interval extraction and
//!   busy/idle/bubble-fraction accounting over recorded timelines
//!   (`--fig bubbles`).
//! - [`critical`] — critical-path extraction (span durations sum
//!   bit-exactly to the recorded makespan), per-op slack, and
//!   stage/modality blame (`--fig critpath`); together with
//!   [`bubble`]'s gap intervals this is the slot list ROADMAP item 1's
//!   bubble-exploiting execution model consumes.
//! - [`audit`] — predicted-vs-measured residuals per iteration and
//!   counterfactual replan attribution via delta replay
//!   (`dflop run --audit`, `--fig audit`).
//!
//! **Determinism contract.** The recorder only copies values the
//! simulation already produced, on one thread, at iteration
//! boundaries, assembled in shard order — so a recorded log and every
//! export derived from it are byte-identical at any `DFLOP_THREADS`,
//! and recorder-on simulation results are bit-identical to
//! recorder-off. Wall-clock quantities never enter the log or its
//! exports; [`run_result_json`] (the `--json` summary) is the one
//! place wall-clock overheads are reported, explicitly labelled.
//!
//! **Zero-overhead-off.** `Recorder::Off` is a unit variant; every
//! hook is an inlined early return with no allocation and no
//! arithmetic. `obs_bench` pins the guarantee with a paired
//! recorder-off vs recorder-on row checked by `dflop-bench-compare`.

pub mod audit;
pub mod bubble;
pub mod chrome;
pub mod critical;
pub mod metrics;
pub mod record;

pub use audit::AuditReport;
pub use metrics::Registry;
pub use record::{Event, EventKind, ObsConfig, Recorder, RunLog};

use crate::sim::trainer::RunResult;
use crate::util::json::{emit, Json};

fn theta_json(t: &crate::optimizer::plan::Theta) -> Json {
    let mp = |m: &crate::optimizer::plan::ModPar| {
        Json::obj(vec![
            ("tp", Json::Num(m.tp as f64)),
            ("pp", Json::Num(m.pp as f64)),
            ("dp", Json::Num(m.dp as f64)),
        ])
    };
    Json::obj(vec![
        ("label", Json::str(format!("{t}"))),
        ("enc", mp(&t.enc)),
        ("llm", mp(&t.llm)),
        ("n_mb", Json::Num(t.n_mb as f64)),
    ])
}

/// The full [`RunResult`] summary as machine-readable JSON
/// (`dflop run --json <path>`): simulated means and series, fault
/// counters, straggler percentiles, and replan events, plus the
/// wall-clock offline overheads under `wall_clock` (the only
/// non-deterministic fields — everything else is bit-deterministic).
pub fn run_result_json(r: &RunResult) -> String {
    let replans: Vec<Json> = r
        .replan_events
        .iter()
        .map(|e| {
            let mut fields = vec![
                ("iteration", Json::Num(e.iteration as f64)),
                ("swapped", Json::Bool(e.swapped)),
                ("score", Json::Num(e.stat.score())),
                ("old", Json::str(format!("{}", e.old))),
                ("new", Json::str(format!("{}", e.new))),
            ];
            // NaN marks a failed refit and has no JSON encoding.
            if e.expected_makespan.is_finite() {
                fields.push(("expected_makespan_s", Json::Num(e.expected_makespan)));
            }
            if e.expected_incumbent.is_finite() {
                fields.push(("expected_incumbent_s", Json::Num(e.expected_incumbent)));
            }
            fields.push(("elapsed_s", Json::Num(e.elapsed.as_secs_f64())));
            Json::obj(fields)
        })
        .collect();
    let gap_pcts: Vec<Json> = r
        .straggler_gap_percentiles
        .iter()
        .map(|&(q, g)| Json::obj(vec![("q", Json::Num(q)), ("gap_s", Json::Num(g))]))
        .collect();
    let step_series: Vec<Json> =
        r.iterations.iter().map(|s| Json::Num(s.iteration_time)).collect();
    let sched_total: f64 = r.sched_elapsed.iter().map(|d| d.as_secs_f64()).sum();
    let mut fields = vec![
        ("schema", Json::str("dflop-run-v1")),
        ("system", Json::str(r.system.label())),
        ("theta", theta_json(&r.theta)),
        ("n_gpus", Json::Num(r.n_gpus as f64)),
        ("per_gpu_throughput_flops", Json::Num(r.per_gpu_throughput)),
        ("mean_iteration_time_s", Json::Num(r.mean_iteration_time)),
        ("mean_idle_gpu_s", Json::Num(r.mean_idle)),
        ("iteration_time_s", Json::Arr(step_series)),
        ("lpt_fallbacks", Json::Num(r.lpt_fallbacks as f64)),
        ("replans", Json::Num(r.replans as f64)),
        ("replan_events", Json::Arr(replans)),
        (
            "straggler_gaps_s",
            Json::Arr(r.straggler_gaps.iter().map(|&g| Json::Num(g)).collect()),
        ),
        ("straggler_gap_percentiles", Json::Arr(gap_pcts)),
        ("migrations", Json::Num(r.migrations as f64)),
        (
            "fault",
            Json::obj(vec![
                ("failures", Json::Num(r.fault.failures as f64)),
                ("recoveries", Json::Num(r.fault.recoveries as f64)),
                ("reshard_events", Json::Num(r.fault.reshard_events as f64)),
                ("degraded_iters", Json::Num(r.fault.degraded_iters as f64)),
            ]),
        ),
        (
            "hetero_thetas",
            Json::Arr(r.hetero_thetas.iter().map(theta_json).collect()),
        ),
        (
            "wall_clock",
            Json::obj(vec![
                ("profiling_s", Json::Num(r.profiling_seconds)),
                ("optimizer_s", Json::Num(r.optimizer_elapsed.as_secs_f64())),
                ("sched_total_s", Json::Num(sched_total)),
            ]),
        ),
    ];
    // The predicted-vs-measured audit, when the run recorded one
    // (`--audit`): deterministic, so it rides in the main document.
    if let Some(a) = r.obs.as_deref().and_then(|log| log.audit.as_ref()) {
        fields.push(("audit", audit::audit_json(a)));
    }
    emit(&Json::obj(fields)) + "\n"
}
