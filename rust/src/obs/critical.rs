//! Critical-path and slack extraction over recorded per-op timelines.
//!
//! The 1F1B event core schedules every op with the recurrence
//! `start = stage_free[s].max(dep_finish + comm)` and `f64::max`
//! returns one of its arguments, so every recorded op start bit-equals
//! either its same-stage predecessor's finish (the stage was the
//! binding constraint) or its dependency's finish plus the hop cost
//! (the data edge bound it). Backtracking the binding constraint from
//! the op whose finish realises the makespan therefore yields a chain
//! of op spans and communication-wait spans that *tiles* `[0,
//! makespan]` with bit-contiguous endpoints: each span starts exactly
//! (same f64 bits) where the previous one ends. The span durations
//! telescope — their sum, evaluated in chain order, is exactly the
//! recorded makespan, which is the bit-exactness contract
//! [`CriticalPath::total`] returns and the property tests pin.
//!
//! On top of the chain, [`op_slack`] computes per-op slack (how far an
//! op's finish can slip without moving the makespan) by a backward pass
//! over the recorded timeline — a topological order for both edge
//! kinds, since an op is only executed (hence recorded) after its
//! dependency finished and after its same-stage predecessor ran. The
//! resulting slack/slot list is the machine-readable input a
//! bubble-filling `ExecModel` (ROADMAP open item 1) consumes together
//! with `obs::bubble`'s gap intervals: gaps say *where* idle time sits,
//! slack says *which ops can slide into it*.
//!
//! Everything here is derivational over sim-time data already recorded;
//! nothing feeds back into the simulation, so the determinism contract
//! (byte-identical at any `DFLOP_THREADS`) holds trivially.

use crate::pipeline::sim::OpRecord;
use crate::util::json::Json;

/// One element of the critical chain: an executed op span, or the
/// communication wait between a dependency's finish and the bound op's
/// start (`is_comm`). Spans tile `[0, makespan]` in chain order.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PathSpan {
    /// Executing stage (for comm spans: the *destination* stage that
    /// waited on the hop).
    pub stage: usize,
    pub bucket: usize,
    pub is_forward: bool,
    pub is_comm: bool,
    pub start: f64,
    pub end: f64,
    /// Index into the source timeline for op spans (`None` for comm).
    pub timeline_idx: Option<usize>,
}

impl PathSpan {
    pub fn len(&self) -> f64 {
        self.end - self.start
    }

    pub fn is_empty(&self) -> bool {
        self.end <= self.start
    }
}

/// The extracted critical path of one iteration's pipeline execution.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CriticalPath {
    /// The recorded makespan the chain terminates at (bit-exact).
    pub makespan: f64,
    /// Chain order (time order): `spans[0].start == 0.0`, each span's
    /// start bit-equals its predecessor's end, and the last span's end
    /// bit-equals `makespan`.
    pub spans: Vec<PathSpan>,
}

impl CriticalPath {
    /// The sum of the chain's span durations. The spans tile
    /// `[0, makespan]` with bit-contiguous endpoints (verified at
    /// extraction), so the durations telescope: evaluated in chain
    /// order the sum is `last.end − first.start`, exactly the recorded
    /// makespan bit for bit.
    pub fn total(&self) -> f64 {
        match (self.spans.first(), self.spans.last()) {
            (Some(a), Some(b)) => b.end - a.start,
            _ => 0.0,
        }
    }

    /// Seconds of the chain spent waiting on communication hops.
    pub fn comm_wait(&self) -> f64 {
        self.spans.iter().filter(|s| s.is_comm).map(PathSpan::len).sum()
    }

    /// Per-stage blame: seconds of chain op time executed on each
    /// stage (comm waits excluded — see [`CriticalPath::comm_wait`]).
    pub fn stage_blame(&self, n_stages: usize) -> Vec<f64> {
        let mut blame = vec![0.0f64; n_stages];
        for s in self.spans.iter().filter(|s| !s.is_comm) {
            if s.stage < n_stages {
                blame[s.stage] += s.len();
            }
        }
        blame
    }

    /// Modality blame `(encoder, llm, comm)`: chain seconds attributed
    /// to encoder stages (`stage < enc_stages`, the build layout puts
    /// all `E_dp · E_pp` encoder stages first), LLM stages, and
    /// communication waits.
    pub fn modality_blame(&self, enc_stages: usize) -> (f64, f64, f64) {
        let (mut enc, mut llm, mut comm) = (0.0f64, 0.0f64, 0.0f64);
        for s in &self.spans {
            if s.is_comm {
                comm += s.len();
            } else if s.stage < enc_stages {
                enc += s.len();
            } else {
                llm += s.len();
            }
        }
        (enc, llm, comm)
    }
}

/// One op's scheduling freedom in the recorded iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpSlack {
    pub bucket: usize,
    pub stage: usize,
    pub is_forward: bool,
    pub start: f64,
    pub finish: f64,
    /// How far the op's finish can slip without moving the makespan
    /// (0 exactly for ops on the extracted critical chain). Hop costs
    /// on non-binding edges are not recorded, so off-chain slack is an
    /// upper bound by at most one hop — see module docs.
    pub slack: f64,
    pub critical: bool,
}

/// Reconstructed identity of each timeline entry: `(bucket, position,
/// forward)`. The event core records ops in execution order and a
/// bucket's forward chain (then its backward chain) is dependency
/// ordered, so within one bucket forwards appear in position order
/// `0..depth` followed by backwards in order `depth−1..=0`.
struct OpIndex {
    /// Per timeline entry: position along its bucket's route.
    pos: Vec<usize>,
    /// Per bucket: route depth (leg count).
    depth: Vec<usize>,
    /// Flat `(bucket, pos, forward) → timeline index` lookup
    /// (`usize::MAX` = absent). Stride layout mirrors the sim core.
    lookup: Vec<usize>,
    stride: usize,
}

impl OpIndex {
    fn build(timeline: &[OpRecord]) -> Option<OpIndex> {
        let n_buckets = timeline.iter().map(|o| o.bucket + 1).max()?;
        let mut depth = vec![0usize; n_buckets];
        for op in timeline {
            if op.is_forward {
                depth[op.bucket] += 1;
            }
        }
        let stride = depth.iter().copied().max().unwrap_or(0).max(1);
        let mut pos = Vec::with_capacity(timeline.len());
        let mut lookup = vec![usize::MAX; n_buckets * stride * 2];
        let mut fwd_seen = vec![0usize; n_buckets];
        let mut bwd_seen = vec![0usize; n_buckets];
        for (i, op) in timeline.iter().enumerate() {
            let b = op.bucket;
            let p = if op.is_forward {
                let p = fwd_seen[b];
                fwd_seen[b] += 1;
                p
            } else {
                if bwd_seen[b] >= depth[b] {
                    return None; // more backwards than forwards
                }
                let p = depth[b] - 1 - bwd_seen[b];
                bwd_seen[b] += 1;
                p
            };
            pos.push(p);
            lookup[Self::key(b, p, op.is_forward, stride)] = i;
        }
        Some(OpIndex { pos, depth, lookup, stride })
    }

    fn key(bucket: usize, pos: usize, forward: bool, stride: usize) -> usize {
        (bucket * stride + pos) * 2 + usize::from(forward)
    }

    fn get(&self, bucket: usize, pos: usize, forward: bool) -> Option<usize> {
        let i = self.lookup[Self::key(bucket, pos, forward, self.stride)];
        (i != usize::MAX).then_some(i)
    }

    /// The timeline index of op `i`'s single data dependency (the sim
    /// core's `dep_of`, reconstructed): previous forward leg, own
    /// forward for the first backward, next backward otherwise.
    fn dep_of(&self, timeline: &[OpRecord], i: usize) -> Option<usize> {
        let op = &timeline[i];
        let p = self.pos[i];
        if op.is_forward {
            if p == 0 {
                None
            } else {
                self.get(op.bucket, p - 1, true)
            }
        } else if p + 1 == self.depth[op.bucket] {
            self.get(op.bucket, p, true)
        } else {
            self.get(op.bucket, p + 1, false)
        }
    }
}

/// Extract the critical path of one recorded iteration.
///
/// Returns `None` when the timeline is empty, no recorded finish
/// realises `makespan` bit-exactly, or the timeline is structurally
/// inconsistent (hand-built records) — engine-recorded timelines always
/// extract.
pub fn critical_path(
    timeline: &[OpRecord],
    n_stages: usize,
    makespan: f64,
) -> Option<CriticalPath> {
    if timeline.is_empty() || !(makespan > 0.0) {
        return None;
    }
    let index = OpIndex::build(timeline)?;
    // Same-stage predecessor per timeline entry, and each stage's last
    // op — the candidates realising the makespan (`stage_free[s]` is
    // the finish of the stage's last executed op).
    let mut prev_on_stage = vec![usize::MAX; timeline.len()];
    let mut stage_last = vec![usize::MAX; n_stages];
    for (i, op) in timeline.iter().enumerate() {
        if op.stage >= n_stages {
            return None;
        }
        prev_on_stage[i] = stage_last[op.stage];
        stage_last[op.stage] = i;
    }
    // Terminal: lowest stage whose last op's finish bit-equals the
    // makespan (deterministic tie-break; `f64::max` folding guarantees
    // at least one exists on engine timelines).
    let terminal = stage_last
        .iter()
        .copied()
        .filter(|&i| i != usize::MAX)
        .find(|&i| timeline[i].finish.to_bits() == makespan.to_bits())?;

    // Backtrack the binding constraint to time zero.
    let mut spans_rev: Vec<PathSpan> = Vec::new();
    let mut cur = terminal;
    loop {
        let op = &timeline[cur];
        spans_rev.push(PathSpan {
            stage: op.stage,
            bucket: op.bucket,
            is_forward: op.is_forward,
            is_comm: false,
            start: op.start,
            end: op.finish,
            timeline_idx: Some(cur),
        });
        if op.start == 0.0 {
            break;
        }
        let p = prev_on_stage[cur];
        if p != usize::MAX && timeline[p].finish.to_bits() == op.start.to_bits() {
            cur = p; // the stage was busy right up to our start
            continue;
        }
        // The data edge bound us: start == dep.finish + comm, so the
        // interval [dep.finish, start] is the hop wait.
        let d = index.dep_of(timeline, cur)?;
        let dep = &timeline[d];
        if !(dep.finish <= op.start) {
            return None; // inconsistent record
        }
        if dep.finish.to_bits() != op.start.to_bits() {
            spans_rev.push(PathSpan {
                stage: op.stage,
                bucket: op.bucket,
                is_forward: op.is_forward,
                is_comm: true,
                start: dep.finish,
                end: op.start,
                timeline_idx: None,
            });
        }
        cur = d;
    }
    spans_rev.reverse();
    let spans = spans_rev;
    // Verify the tiling the bit-exactness contract rests on.
    if spans.first().map_or(true, |s| s.start != 0.0) {
        return None;
    }
    for w in spans.windows(2) {
        if w[0].end.to_bits() != w[1].start.to_bits() {
            return None;
        }
    }
    if spans.last().map_or(true, |s| s.end.to_bits() != makespan.to_bits()) {
        return None;
    }
    Some(CriticalPath { makespan, spans })
}

/// Per-op slack over one recorded iteration, timeline order.
///
/// Backward pass over the timeline (a topological order for both the
/// data-dependency and same-stage edges): an op's latest finish is the
/// minimum over its successors of their latest start minus the edge's
/// hop wait, seeded at `makespan` for ops with no successor. Ops on the
/// extracted critical chain are forced to slack 0 exactly.
pub fn op_slack(timeline: &[OpRecord], n_stages: usize, makespan: f64) -> Vec<OpSlack> {
    let Some(index) = OpIndex::build(timeline) else {
        return Vec::new();
    };
    let n = timeline.len();
    // Successor edges, inverted from the dependency/stage predecessors.
    let mut next_on_stage = vec![usize::MAX; n];
    let mut stage_last = vec![usize::MAX; n_stages.max(1)];
    for (i, op) in timeline.iter().enumerate() {
        let s = op.stage.min(n_stages.max(1) - 1);
        if stage_last[s] != usize::MAX {
            next_on_stage[stage_last[s]] = i;
        }
        stage_last[s] = i;
    }
    let mut latest_finish = vec![makespan; n];
    for i in (0..n).rev() {
        // Data-dependent successor: the op whose dep is `i`.
        let op = &timeline[i];
        let p = index.pos[i];
        let dependent = if op.is_forward {
            if p + 1 < index.depth[op.bucket] {
                index.get(op.bucket, p + 1, true)
            } else {
                index.get(op.bucket, p, false)
            }
        } else if p > 0 {
            index.get(op.bucket, p - 1, false)
        } else {
            None
        };
        if let Some(v) = dependent {
            let dur = timeline[v].finish - timeline[v].start;
            // The hop cost is only observable when the edge bound the
            // successor; the recorded wait is the best available bound.
            let hop = (timeline[v].start - timeline[i].finish).max(0.0);
            let cand = latest_finish[v] - dur - hop;
            if cand < latest_finish[i] {
                latest_finish[i] = cand;
            }
        }
        if next_on_stage[i] != usize::MAX {
            let v = next_on_stage[i];
            let dur = timeline[v].finish - timeline[v].start;
            let cand = latest_finish[v] - dur;
            if cand < latest_finish[i] {
                latest_finish[i] = cand;
            }
        }
    }
    let mut critical = vec![false; n];
    if let Some(path) = critical_path(timeline, n_stages, makespan) {
        for s in path.spans.iter().filter_map(|s| s.timeline_idx) {
            critical[s] = true;
        }
    }
    timeline
        .iter()
        .enumerate()
        .map(|(i, op)| OpSlack {
            bucket: op.bucket,
            stage: op.stage,
            is_forward: op.is_forward,
            start: op.start,
            finish: op.finish,
            slack: if critical[i] { 0.0 } else { (latest_finish[i] - op.finish).max(0.0) },
            critical: critical[i],
        })
        .collect()
}

/// The machine-readable slack/slot list a bubble-filling scheduler
/// consumes (ROADMAP open item 1): every op with its placement, slack,
/// and critical flag, timeline order.
pub fn slack_json(slacks: &[OpSlack]) -> Json {
    Json::Arr(
        slacks
            .iter()
            .map(|s| {
                Json::obj(vec![
                    ("bucket", Json::Num(s.bucket as f64)),
                    ("stage", Json::Num(s.stage as f64)),
                    ("forward", Json::Bool(s.is_forward)),
                    ("start", Json::Num(s.start)),
                    ("finish", Json::Num(s.finish)),
                    ("slack", Json::Num(s.slack)),
                    ("critical", Json::Bool(s.critical)),
                ])
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pipeline::sim::SimWorkspace;
    use crate::util::prop::forall;

    /// Route a random (e_pp, l_pp, e_dp, l_dp, buckets) layout with
    /// random durations and comm hops through the event core, recording
    /// the timeline.
    fn random_run(
        g: &mut crate::util::prop::Gen,
        ws: &mut SimWorkspace,
    ) -> (usize, f64) {
        let e_pp = g.size(2);
        let l_pp = g.size(3);
        let e_dp = g.size(2);
        let l_dp = g.size(2);
        let buckets = g.size(8);
        let n_stages = e_dp * e_pp + l_dp * l_pp;
        ws.routes.clear();
        for j in 0..buckets {
            let e = j % e_dp;
            let gp = j % l_dp;
            for s in 0..e_pp {
                let t = g.rng.uniform(0.01, 1.0);
                let comm = if s == 0 { 0.0 } else { g.rng.uniform(0.0, 0.05) };
                ws.routes.push_leg(e * e_pp + s, t / 3.0, t * 2.0 / 3.0, comm);
            }
            for s in 0..l_pp {
                let t = g.rng.uniform(0.01, 1.0);
                let comm = g.rng.uniform(0.0, 0.05);
                ws.routes.push_leg(e_dp * e_pp + gp * l_pp + s, t / 3.0, t * 2.0 / 3.0, comm);
            }
            ws.routes.end_route();
        }
        let makespan = ws.run(n_stages, true);
        (n_stages, makespan)
    }

    #[test]
    fn chain_tiles_zero_to_makespan_bit_exactly() {
        let mut ws = SimWorkspace::new();
        forall("critical path sums bit-exact to makespan", 60, |g| {
            let (n_stages, makespan) = random_run(g, &mut ws);
            let tl = ws.timeline().to_vec();
            let Some(path) = critical_path(&tl, n_stages, makespan) else {
                return (format!("no path (n_stages={n_stages})"), false);
            };
            let tiled = path.spans.first().map_or(false, |s| s.start == 0.0)
                && path
                    .spans
                    .windows(2)
                    .all(|w| w[0].end.to_bits() == w[1].start.to_bits());
            let ok = tiled && path.total().to_bits() == makespan.to_bits();
            (
                format!("spans={} makespan={makespan}", path.spans.len()),
                ok,
            )
        });
    }

    #[test]
    fn slack_zero_on_chain_and_nonnegative_everywhere() {
        let mut ws = SimWorkspace::new();
        forall("slack: chain ops 0, all finite and nonnegative", 40, |g| {
            let (n_stages, makespan) = random_run(g, &mut ws);
            let tl = ws.timeline().to_vec();
            let slacks = op_slack(&tl, n_stages, makespan);
            let ok = slacks.len() == tl.len()
                && slacks.iter().all(|s| {
                    s.slack.is_finite()
                        && s.slack >= 0.0
                        && (!s.critical || s.slack == 0.0)
                })
                && slacks.iter().any(|s| s.critical);
            (format!("ops={}", slacks.len()), ok)
        });
    }

    #[test]
    fn blame_partitions_the_chain() {
        let mut ws = SimWorkspace::new();
        forall("stage+modality blame partition the chain total", 30, |g| {
            let (n_stages, makespan) = random_run(g, &mut ws);
            let tl = ws.timeline().to_vec();
            let Some(path) = critical_path(&tl, n_stages, makespan) else {
                return ("no path".to_string(), false);
            };
            let stage_sum: f64 = path.stage_blame(n_stages).iter().sum();
            let (enc, llm, comm) = path.modality_blame(1);
            let tol = 1e-9 * makespan.max(1.0);
            let ok = ((stage_sum + path.comm_wait()) - makespan).abs() < tol
                && ((enc + llm + comm) - makespan).abs() < tol;
            (format!("stage_sum={stage_sum} comm={comm}"), ok)
        });
    }

    #[test]
    fn empty_timeline_has_no_path() {
        assert!(critical_path(&[], 2, 1.0).is_none());
        assert!(op_slack(&[], 2, 1.0).is_empty());
    }

    #[test]
    fn single_op_chain_is_the_op() {
        let tl = vec![OpRecord {
            bucket: 0,
            stage: 0,
            is_forward: true,
            start: 0.0,
            finish: 2.5,
        }];
        let path = critical_path(&tl, 1, 2.5).expect("path");
        assert_eq!(path.spans.len(), 1);
        assert_eq!(path.total().to_bits(), 2.5f64.to_bits());
        let slacks = op_slack(&tl, 1, 2.5);
        assert!(slacks[0].critical && slacks[0].slack == 0.0);
    }

    #[test]
    fn slack_json_lists_every_op() {
        let tl = vec![
            OpRecord { bucket: 0, stage: 0, is_forward: true, start: 0.0, finish: 1.0 },
            OpRecord { bucket: 0, stage: 0, is_forward: false, start: 1.0, finish: 3.0 },
        ];
        let slacks = op_slack(&tl, 1, 3.0);
        let Json::Arr(rows) = slack_json(&slacks) else { panic!("array") };
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].get("stage").and_then(Json::as_usize), Some(0));
        assert_eq!(rows[1].get("forward"), Some(&Json::Bool(false)));
    }
}
