//! Rust-side generator for the synthetic multimodal captioning task.
//!
//! Mirrors `python/compile/task.py`: images are noise around a
//! deterministic per-key prototype (`sin(0.1 + 1.7k + 0.37j)`), token
//! sequences follow `t[j+1] = (t[j] + 1 + key) mod vocab`. The two
//! implementations share the *distribution* (formula + constants from the
//! manifest), not RNG state — the model cannot tell them apart.

use crate::runtime::artifacts::{Manifest, ModelInfo};
use crate::util::rng::Rng;

/// One packed training batch for a (n_img, seq) shape bucket.
#[derive(Clone, Debug)]
pub struct TrainBatch {
    pub n_img: usize,
    pub seq: usize,
    /// `(n_img, tokens_per_image, patch_dim)` row-major.
    pub patches: Vec<f32>,
    pub token_ids: Vec<i32>,
    pub segment_ids: Vec<i32>,
    pub img_index: Vec<i32>,
    /// Hidden keys (diagnostics).
    pub keys: Vec<u32>,
}

/// Deterministic prototype direction for a key.
pub fn prototype(key: u32, patch_dim: usize) -> Vec<f32> {
    (0..patch_dim)
        .map(|j| (0.1 + 1.7 * key as f64 + 0.37 * j as f64).sin() as f32)
        .collect()
}

/// Generate one packed batch (the bucket may be larger than the logical
/// content; the tail is padding with segment 0).
pub fn make_batch(
    rng: &mut Rng,
    model: &ModelInfo,
    n_keys: usize,
    noise: f64,
    n_img: usize,
    seq: usize,
) -> TrainBatch {
    let t = model.tokens_per_image;
    let p = model.patch_dim;
    let per = seq / n_img;
    let mut patches = vec![0.0f32; n_img * t * p];
    let mut token_ids = vec![0i32; seq];
    let mut segment_ids = vec![0i32; seq];
    let mut img_index = vec![n_img as i32; seq];
    let mut keys = Vec::with_capacity(n_img);
    let mut pos = 0usize;
    for i in 0..n_img {
        let base = if i + 1 < n_img { per } else { seq - pos };
        let trim = rng.index(per / 4 + 1);
        let length = base.saturating_sub(trim).max(8).min(seq - pos);
        let key = rng.below(n_keys as u64) as u32;
        keys.push(key);
        let proto = prototype(key, p);
        for tok in 0..t {
            for j in 0..p {
                patches[(i * t + tok) * p + j] =
                    proto[j] + (noise * rng.normal()) as f32;
            }
        }
        let mut cur = rng.below(model.vocab as u64) as i64;
        for s in 0..length {
            token_ids[pos + s] = cur as i32;
            segment_ids[pos + s] = (i + 1) as i32;
            img_index[pos + s] = i as i32;
            cur = (cur + 1 + key as i64) % model.vocab as i64;
        }
        pos += length;
    }
    TrainBatch { n_img, seq, patches, token_ids, segment_ids, img_index, keys }
}

/// Convenience: batch from the manifest for one of its buckets.
pub fn batch_for_bucket(rng: &mut Rng, m: &Manifest, n_img: usize, seq: usize) -> TrainBatch {
    make_batch(rng, &m.model, m.task.n_keys, m.task.noise, n_img, seq)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> ModelInfo {
        ModelInfo {
            vocab: 512,
            hidden: 256,
            heads: 4,
            enc_layers: 2,
            llm_layers: 4,
            mlp_ratio: 4,
            tokens_per_image: 16,
            patch_dim: 48,
            total_params: 0,
        }
    }

    #[test]
    fn batch_structure_valid() {
        let mut rng = Rng::new(1);
        let m = model();
        let b = make_batch(&mut rng, &m, 8, 0.5, 2, 256);
        assert_eq!(b.patches.len(), 2 * 16 * 48);
        assert_eq!(b.token_ids.len(), 256);
        // Token recurrence holds within segments.
        for i in 0..2i32 {
            let idxs: Vec<usize> = (0..256)
                .filter(|&s| b.segment_ids[s] == i + 1)
                .collect();
            assert!(idxs.len() >= 8);
            let key = b.keys[i as usize] as i64;
            for w in idxs.windows(2) {
                let (a, c) = (b.token_ids[w[0]] as i64, b.token_ids[w[1]] as i64);
                assert_eq!(c, (a + 1 + key).rem_euclid(512), "recurrence broken");
            }
            // img_index consistent.
            assert!(idxs.iter().all(|&s| b.img_index[s] == i));
        }
        // Padding tail points at the zero image row.
        for s in 0..256 {
            if b.segment_ids[s] == 0 {
                assert_eq!(b.img_index[s], 2);
                assert_eq!(b.token_ids[s], 0);
            }
        }
    }

    #[test]
    fn prototype_matches_python_formula() {
        let p = prototype(3, 4);
        for (j, &v) in p.iter().enumerate() {
            let expect = (0.1 + 1.7 * 3.0 + 0.37 * j as f64).sin() as f32;
            assert_eq!(v, expect);
        }
    }

    #[test]
    fn keys_span_range() {
        let mut rng = Rng::new(5);
        let m = model();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..50 {
            let b = make_batch(&mut rng, &m, 8, 0.5, 2, 256);
            seen.extend(b.keys.iter().copied());
        }
        assert!(seen.len() >= 6, "keys {seen:?}");
        assert!(seen.iter().all(|&k| k < 8));
    }
}
