//! Real-execution profiling backend: times the AOT forward-pass artifacts
//! on the PJRT CPU client, playing the Model Profiler's measurement role
//! against real execution instead of the analytic cluster model.

use crate::err;
use crate::runtime::artifacts::Manifest;
use crate::util::error::{Context, Result};
use crate::util::rng::Rng;
use std::time::Instant;

/// One measured grid point.
#[derive(Clone, Copy, Debug)]
pub struct MeasuredPoint {
    /// Grid coordinate: n_img for the encoder, seq for the LLM.
    pub coord: usize,
    /// Mean wall-clock seconds per execution.
    pub seconds: f64,
}

/// Measured throughput curves from real PJRT execution.
#[derive(Clone, Debug)]
pub struct RealProfile {
    pub encoder: Vec<MeasuredPoint>,
    pub llm: Vec<MeasuredPoint>,
}

fn compile(client: &xla::PjRtClient, path: &std::path::Path) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| err!("non-utf8 path"))?,
    )
    .with_context(|| format!("parsing {}", path.display()))?;
    Ok(client.compile(&xla::XlaComputation::from_proto(&proto))?)
}

/// Time each encoder/LLM forward artifact (`reps` measured runs after one
/// warm-up). This is the Profiling Engine's PJRT measurement backend: the
/// same grid-measure-fit flow as `SimBackend`, against real execution.
pub fn profile_real(manifest: &Manifest, reps: usize, seed: u64) -> Result<RealProfile> {
    let client = xla::PjRtClient::cpu()?;
    let mut rng = Rng::new(seed);
    let m = &manifest.model;
    let params = manifest.load_params()?;
    let mut param_lits = Vec::with_capacity(params.len());
    for (vals, spec) in params.iter().zip(&manifest.params) {
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        param_lits.push(xla::Literal::vec1(vals).reshape(&dims)?);
    }

    let mut encoder = Vec::new();
    for e in &manifest.encoder_fwd {
        let exe = compile(&client, &e.file)?;
        let n = e.coord;
        let patches: Vec<f32> = (0..n * m.tokens_per_image * m.patch_dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let patches_lit = xla::Literal::vec1(&patches).reshape(&[
            n as i64,
            m.tokens_per_image as i64,
            m.patch_dim as i64,
        ])?;
        let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
        args.push(&patches_lit);
        // Warm-up, then measure.
        exe.execute::<&xla::Literal>(&args)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            let r = exe.execute::<&xla::Literal>(&args)?;
            let _ = r[0][0].to_literal_sync()?;
        }
        encoder.push(MeasuredPoint { coord: n, seconds: t0.elapsed().as_secs_f64() / reps as f64 });
    }

    let mut llm = Vec::new();
    for e in &manifest.llm_fwd {
        let exe = compile(&client, &e.file)?;
        let s = e.coord;
        let token_ids: Vec<i32> =
            (0..s).map(|_| rng.below(m.vocab as u64) as i32).collect();
        let segment_ids: Vec<i32> = vec![1; s];
        let img_index: Vec<i32> = vec![0; s];
        let visual: Vec<f32> = (0..m.hidden).map(|_| rng.normal() as f32).collect();
        let tok = xla::Literal::vec1(&token_ids).reshape(&[s as i64])?;
        let seg = xla::Literal::vec1(&segment_ids).reshape(&[s as i64])?;
        let img = xla::Literal::vec1(&img_index).reshape(&[s as i64])?;
        let vis = xla::Literal::vec1(&visual).reshape(&[1, m.hidden as i64])?;
        let mut args: Vec<&xla::Literal> = param_lits.iter().collect();
        args.push(&tok);
        args.push(&seg);
        args.push(&img);
        args.push(&vis);
        exe.execute::<&xla::Literal>(&args)?;
        let t0 = Instant::now();
        for _ in 0..reps {
            let r = exe.execute::<&xla::Literal>(&args)?;
            let _ = r[0][0].to_literal_sync()?;
        }
        llm.push(MeasuredPoint { coord: s, seconds: t0.elapsed().as_secs_f64() / reps as f64 });
    }

    Ok(RealProfile { encoder, llm })
}
