//! PJRT training session: load AOT artifacts, hold parameters on the
//! runtime, execute train steps. Python never runs here — the HLO text
//! emitted once by `aot.py` is the entire contract.

use crate::bail;
use crate::err;
use crate::runtime::artifacts::Manifest;
use crate::runtime::taskgen::TrainBatch;
use crate::util::error::{Context, Result};
use std::path::Path;
use std::time::{Duration, Instant};

/// A compiled train-step executable for one shape bucket.
struct BucketExe {
    n_img: usize,
    seq: usize,
    exe: xla::PjRtLoadedExecutable,
}

/// A live training session: PJRT client + compiled buckets + parameters.
pub struct TrainSession {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    buckets: Vec<BucketExe>,
    /// Current parameters, spec order, as host literals.
    params: Vec<xla::Literal>,
    pub steps_taken: u64,
    /// Cumulative device execution time.
    pub exec_time: Duration,
}

fn f32_literal(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

fn i32_literal(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(data);
    Ok(lit.reshape(dims)?)
}

impl TrainSession {
    /// Load the manifest, compile every train-step bucket, initialize
    /// parameters from the blob.
    pub fn load(artifacts_dir: &Path) -> Result<TrainSession> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut buckets = Vec::new();
        for b in &manifest.train_steps {
            let proto = xla::HloModuleProto::from_text_file(
                b.file.to_str().ok_or_else(|| err!("non-utf8 path"))?,
            )
            .with_context(|| format!("parsing {}", b.file.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", b.file.display()))?;
            buckets.push(BucketExe { n_img: b.n_img, seq: b.seq, exe });
        }
        let raw = manifest.load_params()?;
        let mut params = Vec::with_capacity(raw.len());
        for (vals, spec) in raw.iter().zip(&manifest.params) {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            params.push(f32_literal(vals, &dims)?);
        }
        Ok(TrainSession {
            manifest,
            client,
            buckets,
            params,
            steps_taken: 0,
            exec_time: Duration::ZERO,
        })
    }

    /// Shape buckets available (n_img, seq).
    pub fn bucket_shapes(&self) -> Vec<(usize, usize)> {
        self.buckets.iter().map(|b| (b.n_img, b.seq)).collect()
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute one SGD step on the bucket exactly matching the batch shape.
    /// Returns the loss. Parameters advance in place.
    pub fn step(&mut self, batch: &TrainBatch, lr: f32) -> Result<f32> {
        let bucket = self
            .buckets
            .iter()
            .find(|b| b.n_img == batch.n_img && b.seq == batch.seq)
            .ok_or_else(|| {
                err!(
                    "no compiled bucket for (n_img={}, seq={}); have {:?}",
                    batch.n_img,
                    batch.seq,
                    self.bucket_shapes()
                )
            })?;
        let t = self.manifest.model.tokens_per_image as i64;
        let p = self.manifest.model.patch_dim as i64;
        let s = batch.seq as i64;

        let mut args: Vec<&xla::Literal> = self.params.iter().collect();
        let patches =
            f32_literal(&batch.patches, &[batch.n_img as i64, t, p])?;
        let token_ids = i32_literal(&batch.token_ids, &[s])?;
        let segment_ids = i32_literal(&batch.segment_ids, &[s])?;
        let img_index = i32_literal(&batch.img_index, &[s])?;
        let lr_lit = xla::Literal::scalar(lr);
        args.push(&patches);
        args.push(&token_ids);
        args.push(&segment_ids);
        args.push(&img_index);
        args.push(&lr_lit);

        let t0 = Instant::now();
        let result = bucket.exe.execute::<&xla::Literal>(&args)?;
        let out = result[0][0].to_literal_sync()?;
        self.exec_time += t0.elapsed();

        let mut parts = out.to_tuple()?;
        let n = self.params.len();
        if parts.len() != n + 1 {
            bail!("expected {} outputs, got {}", n + 1, parts.len());
        }
        let loss_lit = parts.pop().expect("loss output");
        let loss: f32 = loss_lit.get_first_element()?;
        self.params = parts;
        self.steps_taken += 1;
        Ok(loss)
    }

    /// Read back one parameter tensor (diagnostics / checkpoints).
    pub fn param(&self, name: &str) -> Result<Vec<f32>> {
        let idx = self
            .manifest
            .params
            .iter()
            .position(|p| p.name == name)
            .ok_or_else(|| err!("unknown param '{name}'"))?;
        Ok(self.params[idx].to_vec::<f32>()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::taskgen::batch_for_bucket;
    use crate::util::rng::Rng;
    use std::path::PathBuf;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn end_to_end_steps_reduce_loss() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut session = TrainSession::load(&dir).expect("session");
        let (n_img, seq) = session.bucket_shapes()[0];
        let mut rng = Rng::new(42);
        let manifest = session.manifest.clone();
        let mut losses = Vec::new();
        for _ in 0..30 {
            let batch = batch_for_bucket(&mut rng, &manifest, n_img, seq);
            let loss = session.step(&batch, 0.02).expect("step");
            assert!(loss.is_finite());
            losses.push(loss as f64);
        }
        let early: f64 = losses[..5].iter().sum::<f64>() / 5.0;
        let late: f64 = losses[losses.len() - 5..].iter().sum::<f64>() / 5.0;
        assert!(
            late < early - 0.3,
            "no learning through PJRT: {early:.3} -> {late:.3}"
        );
        assert_eq!(session.steps_taken, 30);
        assert!(session.exec_time > Duration::ZERO);
    }

    #[test]
    fn step_rejects_unknown_shape() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let mut session = TrainSession::load(&dir).expect("session");
        let manifest = session.manifest.clone();
        let mut rng = Rng::new(1);
        let mut batch = batch_for_bucket(&mut rng, &manifest, 1, 128);
        batch.seq = 96; // not a compiled bucket
        assert!(session.step(&batch, 0.01).is_err());
    }
}
