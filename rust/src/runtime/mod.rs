//! PJRT runtime: artifact loading, the training session, the synthetic
//! task generator, and the real-execution profiler.
//!
//! Adapted from /opt/xla-example/load_hlo: HLO *text* is the interchange
//! format (`HloModuleProto::from_text_file` → `XlaComputation` →
//! `PjRtClient::compile` → `execute`).
pub mod artifacts;
#[cfg(feature = "xla")]
pub mod profiler;
#[cfg(feature = "xla")]
pub mod session;
pub mod taskgen;

pub use artifacts::Manifest;
#[cfg(feature = "xla")]
pub use session::TrainSession;
pub use taskgen::{batch_for_bucket, make_batch, TrainBatch};
