//! Artifact manifest parsing and parameter-blob loading.
//!
//! `python/compile/aot.py` emits `artifacts/manifest.json` plus HLO-text
//! files and a concatenated f32 parameter blob; this module reads them into
//! typed structures the runtime consumes. The manifest is the only contract
//! between the python compile path and the rust request path.

use crate::bail;
use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::{parse, Json};
use std::path::{Path, PathBuf};

/// One parameter tensor in the blob.
#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub bytes: usize,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }
}

/// One compiled train-step shape bucket.
#[derive(Clone, Debug)]
pub struct BucketSpec {
    pub n_img: usize,
    pub seq: usize,
    pub file: PathBuf,
}

/// Profiling forward-pass artifacts.
#[derive(Clone, Debug)]
pub struct FwdSpec {
    /// Grid coordinate: number of images (encoder) or sequence (LLM).
    pub coord: usize,
    pub file: PathBuf,
}

/// Model hyperparameters recorded by the compile path.
#[derive(Clone, Copy, Debug)]
pub struct ModelInfo {
    pub vocab: usize,
    pub hidden: usize,
    pub heads: usize,
    pub enc_layers: usize,
    pub llm_layers: usize,
    pub mlp_ratio: usize,
    pub tokens_per_image: usize,
    pub patch_dim: usize,
    pub total_params: usize,
}

/// Synthetic-task constants shared with `python/compile/task.py`.
#[derive(Clone, Copy, Debug)]
pub struct TaskInfo {
    pub n_keys: usize,
    pub noise: f64,
}

/// The full parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: String,
    pub model: ModelInfo,
    pub task: TaskInfo,
    pub params: Vec<ParamSpec>,
    pub params_file: PathBuf,
    pub train_steps: Vec<BucketSpec>,
    pub encoder_fwd: Vec<FwdSpec>,
    pub llm_fwd: Vec<FwdSpec>,
}

fn usize_field(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| err!("manifest missing numeric field '{key}'"))
}

impl Manifest {
    /// Load and validate `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", dir.display()))?;
        let root = parse(&text).map_err(|e| err!("manifest: {e}"))?;

        let model_j = root.get("model").ok_or_else(|| err!("missing model"))?;
        let model = ModelInfo {
            vocab: usize_field(model_j, "vocab")?,
            hidden: usize_field(model_j, "hidden")?,
            heads: usize_field(model_j, "heads")?,
            enc_layers: usize_field(model_j, "enc_layers")?,
            llm_layers: usize_field(model_j, "llm_layers")?,
            mlp_ratio: usize_field(model_j, "mlp_ratio")?,
            tokens_per_image: usize_field(model_j, "tokens_per_image")?,
            patch_dim: usize_field(model_j, "patch_dim")?,
            total_params: usize_field(model_j, "total_params")?,
        };
        let task_j = root.get("task").ok_or_else(|| err!("missing task"))?;
        let task = TaskInfo {
            n_keys: usize_field(task_j, "n_keys")?,
            noise: task_j
                .get("noise")
                .and_then(Json::as_f64)
                .ok_or_else(|| err!("missing task.noise"))?,
        };

        let mut params = Vec::new();
        let mut expect_offset = 0usize;
        for p in root
            .get("params")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("missing params"))?
        {
            let spec = ParamSpec {
                name: p
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err!("param name"))?
                    .to_string(),
                shape: p
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| err!("param shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| err!("bad dim")))
                    .collect::<Result<_>>()?,
                offset: usize_field(p, "offset")?,
                bytes: usize_field(p, "bytes")?,
            };
            if spec.offset != expect_offset {
                bail!("param '{}' offset {} != expected {expect_offset}", spec.name, spec.offset);
            }
            if spec.bytes != 4 * spec.elements() {
                bail!("param '{}' byte/shape mismatch", spec.name);
            }
            expect_offset += spec.bytes;
            params.push(spec);
        }

        let buckets = root
            .get("train_steps")
            .and_then(Json::as_arr)
            .ok_or_else(|| err!("missing train_steps"))?
            .iter()
            .map(|b| {
                Ok(BucketSpec {
                    n_img: usize_field(b, "n_img")?,
                    seq: usize_field(b, "seq")?,
                    file: dir.join(
                        b.get("file")
                            .and_then(Json::as_str)
                            .ok_or_else(|| err!("bucket file"))?,
                    ),
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let fwd = |key: &str, coord_key: &str| -> Result<Vec<FwdSpec>> {
            root.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| err!("missing {key}"))?
                .iter()
                .map(|e| {
                    Ok(FwdSpec {
                        coord: usize_field(e, coord_key)?,
                        file: dir.join(
                            e.get("file")
                                .and_then(Json::as_str)
                                .ok_or_else(|| err!("{key} file"))?,
                        ),
                    })
                })
                .collect()
        };

        Ok(Manifest {
            dir: dir.to_path_buf(),
            config: root
                .get("config")
                .and_then(Json::as_str)
                .unwrap_or("unknown")
                .to_string(),
            model,
            task,
            params_file: dir.join(
                root.get("params_file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| err!("missing params_file"))?,
            ),
            params,
            train_steps: buckets,
            encoder_fwd: fwd("encoder_fwd", "n_img")?,
            llm_fwd: fwd("llm_fwd", "seq")?,
        })
    }

    /// Read the parameter blob into per-tensor f32 vectors (spec order).
    pub fn load_params(&self) -> Result<Vec<Vec<f32>>> {
        let blob = std::fs::read(&self.params_file)
            .with_context(|| format!("reading {}", self.params_file.display()))?;
        let expected: usize = self.params.iter().map(|p| p.bytes).sum();
        if blob.len() != expected {
            bail!("params blob {} bytes, manifest says {expected}", blob.len());
        }
        let mut out = Vec::with_capacity(self.params.len());
        for spec in &self.params {
            let raw = &blob[spec.offset..spec.offset + spec.bytes];
            let vals: Vec<f32> = raw
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            out.push(vals);
        }
        Ok(out)
    }

    /// Pick the smallest bucket that fits (n_img, seq); None if none fits.
    pub fn bucket_for(&self, n_img: usize, seq: usize) -> Option<&BucketSpec> {
        self.train_steps
            .iter()
            .filter(|b| b.n_img >= n_img && b.seq >= seq)
            .min_by_key(|b| (b.n_img, b.seq))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The checked-in artifacts dir (built by `make artifacts`); tests that
    /// need it are skipped gracefully when it has not been built yet.
    pub fn artifacts_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn manifest_round_trip() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).expect("manifest parses");
        assert!(!m.train_steps.is_empty());
        assert!(m.model.total_params > 1_000_000);
        let params = m.load_params().expect("params blob");
        assert_eq!(params.len(), m.params.len());
        let total: usize = params.iter().map(Vec::len).sum();
        assert_eq!(total, m.model.total_params);
        // Values finite and non-degenerate.
        assert!(params.iter().flatten().all(|x| x.is_finite()));
    }

    #[test]
    fn bucket_selection_prefers_smallest_fit() {
        let Some(dir) = artifacts_dir() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).expect("manifest");
        if m.train_steps.len() < 2 {
            return;
        }
        let smallest = m.train_steps.iter().map(|b| b.seq).min().unwrap();
        let b = m.bucket_for(1, smallest).expect("bucket");
        assert_eq!(b.seq, smallest);
        // Oversized request yields None.
        assert!(m.bucket_for(1, 1 << 20).is_none());
    }
}
