//! Baseline systems (§5.1): Megatron-LM-style and plain-PyTorch-style
//! homogeneous 3D parallelism.
//!
//! Both baselines share the structural constraints the paper attributes to
//! conventional frameworks:
//!
//! - **homogeneous parallelism**: one (TP, DP) pair for the whole model;
//!   the modality encoder occupies pipeline stage 0 and the LLM the
//!   remaining stages (Fig 1 "real case"), so the encoder stage gets exactly
//!   one pipeline stage's worth of GPUs regardless of its compute share;
//! - **data-agnostic tuning**: the configuration is selected against a
//!   single point estimate (the mean input shape), not the distribution;
//! - **random microbatching**: items are assigned to microbatches randomly
//!   (equal counts, uncontrolled loads).
//!
//! They differ in tuning quality and software overhead:
//!
//! - [`megatron_tune`] searches all homogeneous candidates and picks the
//!   best mean-shape makespan ("manually tuned following conventional best
//!   practices to achieve their best possible performance", §5.1) and runs
//!   at `software_factor = 1.0`;
//! - [`pytorch_tune`] follows the common hand-tuning recipe — smallest TP
//!   that fits memory, then pipeline depth by memory need, microbatch count
//!   maxed for bubble amortization — and carries a small constant kernel
//!   overhead (no custom fused kernels).

pub mod homogeneous;

pub use homogeneous::{megatron_tune, pytorch_tune, HomogeneousChoice, PYTORCH_SOFTWARE_FACTOR};
