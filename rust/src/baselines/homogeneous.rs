//! Homogeneous 3D-parallelism tuners for the baseline systems.

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::plan::{ModPar, Theta};
use crate::perfmodel::{ClusterSpec, Truth};

/// Software-stack overhead of the plain-PyTorch baseline relative to
/// Megatron-grade fused kernels (~6% — unfused LayerNorm/bias-add paths).
pub const PYTORCH_SOFTWARE_FACTOR: f64 = 1.06;

/// A tuned homogeneous configuration expressed in DFLOP's θ terms:
/// encoder on pipeline stage 0 (`enc.pp = 1`), LLM on the remaining
/// `pp − 1` stages, shared TP and DP.
#[derive(Clone, Copy, Debug)]
pub struct HomogeneousChoice {
    pub theta: Theta,
    /// Point-estimate iteration time used for tuning (diagnostics).
    pub est_makespan: f64,
}

/// Memory feasibility for a homogeneous candidate, using the ground-truth
/// closed forms (the baselines are assumed competently configured — they
/// do not OOM in the paper either).
fn fits_memory(
    m: &Mllm,
    cluster: &ClusterSpec,
    tp: usize,
    llm_pp: usize,
    mean_units_mb: f64,
    mean_seq_mb: f64,
    total_pp: usize,
) -> bool {
    let cap = cluster.gpu.mem_bytes;
    let e_layers = m.encoder.layers as f64;
    let l_layers = m.llm.layers as f64 / llm_pp as f64;
    let mem_e = m.encoder_model_state_bytes(e_layers, tp)
        + total_pp as f64 * m.encoder_act_bytes(e_layers, tp, mean_units_mb);
    let mem_l = m.llm_model_state_bytes(l_layers, tp)
        + llm_pp as f64 * m.llm_act_bytes(l_layers, tp, mean_seq_mb);
    mem_e <= cap && mem_l <= cap
}

/// Point-estimate (mean-shape) iteration time of a homogeneous candidate —
/// the data-agnostic tuning objective.
fn point_estimate(
    m: &Mllm,
    truth: &Truth,
    theta: Theta,
    mean_units: f64,
    mean_seq: f64,
    gbs: usize,
) -> f64 {
    let items_per_mb = gbs as f64 / (theta.n_mb as f64 * theta.llm.dp as f64);
    let e_t = truth.encoder_stage_time(
        m,
        mean_units * items_per_mb,
        m.encoder.layers as f64 / theta.enc.pp as f64,
        theta.enc.tp,
    );
    // Point estimate treats the microbatch as one packed mean-shape batch —
    // exactly the homogeneity assumption the paper criticizes.
    let seqs = vec![mean_seq; items_per_mb.round().max(1.0) as usize];
    let l_t = truth.llm_stage_time(
        m,
        &seqs,
        m.llm.layers as f64 / theta.llm.pp as f64,
        theta.llm.tp,
    );
    (theta.n_mb + theta.pipeline_depth() - 1) as f64 * e_t.max(l_t)
}

/// All homogeneous candidates for a cluster: `tp · pp · dp = N_gpus`,
/// `pp ≥ 2` (stage 0 hosts the encoder), `dp | GBS`.
fn homogeneous_candidates(
    cluster: &ClusterSpec,
    max_pp: usize,
    gbs: usize,
) -> Vec<(usize, usize, usize)> {
    let n = cluster.total_gpus();
    let mut out = Vec::new();
    let mut tp = 1;
    while tp <= cluster.gpus_per_node {
        if n % tp == 0 {
            let rest = n / tp;
            for pp in 2..=rest.min(max_pp) {
                if rest % pp == 0 {
                    let dp = rest / pp;
                    // dp | GBS, strictly: a non-dividing dp gives fractional
                    // items per microbatch, which no homogeneous runtime
                    // accepts. (An earlier `|| dp <= gbs` escape made this
                    // constraint vacuous.)
                    if gbs % dp == 0 {
                        out.push((tp, pp, dp));
                    }
                }
            }
        }
        tp *= 2;
    }
    out
}

fn choice_from(
    m: &Mllm,
    truth: &Truth,
    tp: usize,
    pp: usize,
    dp: usize,
    n_mb: usize,
    mean_units: f64,
    mean_seq: f64,
    gbs: usize,
) -> HomogeneousChoice {
    let theta = Theta {
        enc: ModPar { tp, pp: 1, dp },
        llm: ModPar { tp, pp: pp - 1, dp },
        n_mb,
    };
    let est = point_estimate(m, truth, theta, mean_units, mean_seq, gbs);
    HomogeneousChoice { theta, est_makespan: est }
}

/// Megatron-LM-style tuning: exhaustively score homogeneous candidates on
/// the mean shape and pick the best; microbatch count maximized (one item
/// per microbatch where memory allows) for minimal theoretical bubble
/// fraction — the conventional best practice the paper contrasts with
/// DFLOP's deliberately smaller `N_mb` (§5.3.5).
pub fn megatron_tune(
    m: &Mllm,
    truth: &Truth,
    gbs: usize,
    mean_units: f64,
    mean_seq: f64,
) -> Option<HomogeneousChoice> {
    let cluster = &truth.cluster;
    let mut best: Option<HomogeneousChoice> = None;
    for (tp, pp, dp) in homogeneous_candidates(cluster, m.llm.layers + 1, gbs) {
        // Max microbatches given per-DP-group item budget.
        let max_mb = (gbs / dp).max(1);
        for n_mb in [max_mb, max_mb.div_ceil(2), max_mb.div_ceil(4)] {
            let items_mb = gbs as f64 / (n_mb as f64 * dp as f64);
            if !fits_memory(
                m,
                cluster,
                tp,
                pp - 1,
                mean_units * items_mb,
                mean_seq * items_mb,
                pp,
            ) {
                continue;
            }
            let c = choice_from(m, truth, tp, pp, dp, n_mb, mean_units, mean_seq, gbs);
            if best
                .as_ref()
                .map(|b| c.est_makespan < b.est_makespan)
                .unwrap_or(true)
            {
                best = Some(c);
            }
        }
    }
    best
}

/// Plain-PyTorch-style tuning: the common hand recipe — smallest TP that
/// fits, smallest workable PP, the rest DP; microbatches maximized.
pub fn pytorch_tune(
    m: &Mllm,
    truth: &Truth,
    gbs: usize,
    mean_units: f64,
    mean_seq: f64,
) -> Option<HomogeneousChoice> {
    let cluster = &truth.cluster;
    let mut cands = homogeneous_candidates(cluster, m.llm.layers + 1, gbs);
    // Hand-tuning order: prefer small tp, then small pp (maximize dp).
    cands.sort_by_key(|&(tp, pp, _)| (tp, pp));
    for (tp, pp, dp) in cands {
        let n_mb = (gbs / dp).max(1);
        let items_mb = gbs as f64 / (n_mb as f64 * dp as f64);
        if fits_memory(
            m,
            cluster,
            tp,
            pp - 1,
            mean_units * items_mb,
            mean_seq * items_mb,
            pp,
        ) {
            return Some(choice_from(
                m, truth, tp, pp, dp, n_mb, mean_units, mean_seq, gbs,
            ));
        }
    }
    None
}

/// Random microbatch partition used by both baselines: equal *counts* per
/// bucket, composition uncontrolled (§3.4).
pub fn random_buckets(
    shapes: &[ItemShape],
    n_buckets: usize,
    rng: &mut crate::util::rng::Rng,
) -> Vec<Vec<ItemShape>> {
    let mut order: Vec<usize> = (0..shapes.len()).collect();
    rng.shuffle(&mut order);
    let mut out: Vec<Vec<ItemShape>> = vec![Vec::new(); n_buckets];
    for (pos, &i) in order.iter().enumerate() {
        out[pos % n_buckets].push(shapes[i]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3, qwen25};

    #[test]
    fn megatron_finds_feasible_homogeneous_config() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::new(ClusterSpec::hgx_a100(4));
        let c = megatron_tune(&m, &truth, 128, 15.0, 3000.0).expect("config");
        // Homogeneity invariants.
        assert_eq!(c.theta.enc.tp, c.theta.llm.tp);
        assert_eq!(c.theta.enc.dp, c.theta.llm.dp);
        assert_eq!(c.theta.enc.pp, 1);
        assert_eq!(c.theta.gpus(), 32);
    }

    #[test]
    fn pytorch_prefers_small_tp() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::new(ClusterSpec::hgx_a100(4));
        let c = pytorch_tune(&m, &truth, 128, 15.0, 3000.0).expect("config");
        // 8B fits at tp=1 with modest pp.
        assert_eq!(c.theta.llm.tp, 1, "{:?}", c.theta);
    }

    #[test]
    fn big_model_forces_model_parallel_baseline() {
        let m = llava_ov(qwen25("72b"));
        let truth = Truth::new(ClusterSpec::hgx_a100(8));
        let c = megatron_tune(&m, &truth, 256, 15.0, 3000.0).expect("config");
        let slice = c.theta.llm.tp * (c.theta.llm.pp + 1);
        assert!(slice >= 16, "72B needs a large model-parallel slice: {:?}", c.theta);
    }

    #[test]
    fn candidates_require_dp_to_divide_gbs() {
        // Regression: `gbs % dp == 0 || dp <= gbs` admitted every dp ≤ gbs,
        // i.e. candidates with fractional items per microbatch. One
        // 8-GPU node, gbs = 30: dp ∈ {1, 2} only.
        let cluster = ClusterSpec::hgx_a100(1);
        let cands = homogeneous_candidates(&cluster, 8, 30);
        assert!(!cands.is_empty());
        for &(tp, pp, dp) in &cands {
            assert_eq!(30 % dp, 0, "dp={dp} does not divide gbs (tp={tp}, pp={pp})");
        }
        // The old escape admitted (tp=1, pp=2, dp=4): 30/4 items per group.
        assert!(cands.iter().all(|&(_, _, dp)| dp != 4));
        // Divisible batch sizes keep their full candidate set.
        assert!(homogeneous_candidates(&cluster, 8, 32)
            .iter()
            .any(|&(_, _, dp)| dp == 4));
    }

    #[test]
    fn random_buckets_partition_with_even_counts() {
        let shapes: Vec<ItemShape> = (0..37)
            .map(|i| ItemShape { units: i as u32 % 5, llm_seq: 100 + i as u32, source: 0 })
            .collect();
        let mut rng = crate::util::rng::Rng::new(3);
        let buckets = random_buckets(&shapes, 8, &mut rng);
        let total: usize = buckets.iter().map(Vec::len).sum();
        assert_eq!(total, 37);
        let max = buckets.iter().map(Vec::len).max().unwrap();
        let min = buckets.iter().map(Vec::len).min().unwrap();
        assert!(max - min <= 1, "counts must be even: {max} vs {min}");
    }
}
