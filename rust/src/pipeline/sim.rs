//! Generic dependency-driven 1F1B pipeline execution engine.
//!
//! The engine simulates a 1F1B schedule over an arbitrary set of physical
//! stages and per-bucket routes with *variable* forward/backward durations —
//! the setting of Fig 1's "real case". Unlike the closed-form makespan
//! formula (which assumes uniform microbatches), execution times here flow
//! from data dependencies:
//!
//! - `F(k, r)` starts after `F(k, r−1)` finishes plus the communication hop;
//! - `B(k, r)` starts after `B(k, r+1)` (or `F(k, last)` for the last
//!   stage) plus the hop;
//! - each physical stage executes its ops in the static 1F1B order
//!   (warm-up forwards, then alternating backward/forward, then drain),
//!   and is busy with at most one op at a time.
//!
//! The engine reports per-stage busy/idle time (Fig 13), the full op
//! timeline (Fig 1), and the iteration makespan.

/// One bucket's path through the pipeline.
#[derive(Clone, Debug)]
pub struct Route {
    /// Physical stage ids, in traversal order.
    pub stages: Vec<usize>,
    /// Forward duration at each route position.
    pub fwd: Vec<f64>,
    /// Backward duration at each route position.
    pub bwd: Vec<f64>,
    /// Communication time for the hop *into* route position r (index 0 is
    /// unused / 0.0; index r is the transfer from stage r−1 to r).
    pub comm: Vec<f64>,
}

impl Route {
    pub fn depth(&self) -> usize {
        self.stages.len()
    }
}

/// A simulated operation for timeline rendering.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpRecord {
    pub bucket: usize,
    pub stage: usize,
    pub is_forward: bool,
    pub start: f64,
    pub finish: f64,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Time at which every backward has drained.
    pub makespan: f64,
    /// Per physical stage: time spent executing ops.
    pub stage_busy: Vec<f64>,
    /// Per physical stage: `makespan − busy` (bubbles + warm-up/drain).
    pub stage_idle: Vec<f64>,
    pub timeline: Vec<OpRecord>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct OpId {
    bucket: usize,
    /// Position along the bucket's route.
    pos: usize,
    forward: bool,
}

/// Simulate the 1F1B execution of `routes` over `n_stages` physical stages.
///
/// Buckets routed through the same stage are ordered by bucket index
/// (their arrival order from the scheduler). Panics if the op order
/// deadlocks — which would indicate an invalid route set, e.g. two buckets
/// traversing shared stages in opposite orders.
pub fn simulate(n_stages: usize, routes: &[Route]) -> PipelineResult {
    // ---- build the static per-stage op order (1F1B) ----
    // For each stage, gather the buckets that traverse it (with their route
    // position), sorted by bucket index.
    let mut stage_buckets: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_stages];
    for (b, r) in routes.iter().enumerate() {
        for (pos, &s) in r.stages.iter().enumerate() {
            assert!(s < n_stages, "route references unknown stage {s}");
            stage_buckets[s].push((b, pos));
        }
    }
    let max_depth = routes.iter().map(Route::depth).max().unwrap_or(0);

    // Fan-out per stage: when a stage feeds several distinct downstream
    // stages (e.g. one encoder DP group serving multiple LLM pipelines),
    // its warm-up must cover each of them — count distinct successors.
    let mut successors: Vec<std::collections::HashSet<usize>> =
        vec![std::collections::HashSet::new(); n_stages];
    for r in routes {
        for w in r.stages.windows(2) {
            successors[w[0]].insert(w[1]);
        }
    }

    // 1F1B op order per stage: warm-up = stage depth × fan-out forwards,
    // then alternate B/F, then drain backwards.
    let mut stage_order: Vec<Vec<OpId>> = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        let buckets = &stage_buckets[s];
        let mut order = Vec::with_capacity(buckets.len() * 2);
        if buckets.is_empty() {
            stage_order.push(order);
            continue;
        }
        // The stage's pipeline depth (distance from the end) governs how
        // many in-flight forwards 1F1B allows it; fan-out multiplies it.
        let depth_here = buckets
            .iter()
            .map(|&(b, pos)| routes[b].depth() - pos)
            .max()
            .expect("non-empty");
        let n = buckets.len();
        let fan_out = successors[s].len().max(1);
        let warmup = (depth_here * fan_out).min(n);
        for &(b, pos) in buckets.iter().take(warmup) {
            order.push(OpId { bucket: b, pos, forward: true });
        }
        for k in 0..n - warmup {
            let (bb, bp) = buckets[k];
            order.push(OpId { bucket: bb, pos: bp, forward: false });
            let (fb, fp) = buckets[k + warmup];
            order.push(OpId { bucket: fb, pos: fp, forward: true });
        }
        for &(b, pos) in buckets.iter().skip(n - warmup) {
            order.push(OpId { bucket: b, pos, forward: false });
        }
        stage_order.push(order);
    }

    // ---- worklist execution ----
    // finish[op] once computed; flat-indexed by (bucket, pos, dir) with a
    // NaN sentinel (a HashMap here dominated the optimizer's refinement
    // loop — see EXPERIMENTS.md §Perf).
    let stride = max_depth.max(1);
    let idx_of = |op: &OpId| (op.bucket * stride + op.pos) * 2 + op.forward as usize;
    let mut finish_v = vec![f64::NAN; routes.len() * stride * 2];
    struct Finish<'a> {
        v: &'a mut Vec<f64>,
    }
    let mut finish = Finish { v: &mut finish_v };
    impl<'a> Finish<'a> {
        #[inline]
        fn get_at(&self, i: usize) -> Option<f64> {
            let x = self.v[i];
            if x.is_nan() {
                None
            } else {
                Some(x)
            }
        }
        #[inline]
        fn set_at(&mut self, i: usize, t: f64) {
            self.v[i] = t;
        }
    }
    let mut stage_ptr = vec![0usize; n_stages];
    let mut stage_free = vec![0.0f64; n_stages];
    let mut stage_busy = vec![0.0f64; n_stages];
    let mut timeline = Vec::new();
    let total_ops: usize = stage_order.iter().map(Vec::len).sum();
    let mut done = 0usize;

    while done < total_ops {
        let mut progressed = false;
        for s in 0..n_stages {
            // Execute as many consecutive ready ops as possible per sweep.
            while stage_ptr[s] < stage_order[s].len() {
                let op = stage_order[s][stage_ptr[s]];
                let route = &routes[op.bucket];
                // Dependency finish time (None → not ready yet).
                let dep: Option<f64> = if op.forward {
                    if op.pos == 0 {
                        Some(0.0)
                    } else {
                        finish
                            .get_at(idx_of(&OpId {
                                bucket: op.bucket,
                                pos: op.pos - 1,
                                forward: true,
                            }))
                            .map(|f| f + route.comm[op.pos])
                    }
                } else if op.pos + 1 == route.depth() {
                    // Last stage: backward follows own forward directly.
                    finish.get_at(idx_of(&OpId {
                        bucket: op.bucket,
                        pos: op.pos,
                        forward: true,
                    }))
                } else {
                    finish
                        .get_at(idx_of(&OpId {
                            bucket: op.bucket,
                            pos: op.pos + 1,
                            forward: false,
                        }))
                        .map(|f| f + route.comm[op.pos + 1])
                };
                let Some(dep_t) = dep else { break };
                let dur = if op.forward { route.fwd[op.pos] } else { route.bwd[op.pos] };
                let start = stage_free[s].max(dep_t);
                let end = start + dur;
                stage_free[s] = end;
                stage_busy[s] += dur;
                finish.set_at(idx_of(&op), end);
                timeline.push(OpRecord {
                    bucket: op.bucket,
                    stage: s,
                    is_forward: op.forward,
                    start,
                    finish: end,
                });
                stage_ptr[s] += 1;
                done += 1;
                progressed = true;
            }
        }
        if !progressed && done < total_ops {
            // Work-conserving fallback: the static 1F1B order stalled
            // (possible under exotic DP-group topologies where the
            // warm-up heuristic under-provisions). Pull the earliest
            // *ready* op forward in some stage's order — dependencies are
            // still honored, only the local 1F1B ordering is relaxed.
            let mut recovered = false;
            'outer: for s in 0..n_stages {
                for idx in stage_ptr[s] + 1..stage_order[s].len() {
                    let op = stage_order[s][idx];
                    let route = &routes[op.bucket];
                    let ready = if op.forward {
                        op.pos == 0
                            || finish
                                .get_at(idx_of(&OpId {
                                    bucket: op.bucket,
                                    pos: op.pos - 1,
                                    forward: true,
                                }))
                                .is_some()
                    } else if op.pos + 1 == route.depth() {
                        finish
                            .get_at(idx_of(&OpId {
                                bucket: op.bucket,
                                pos: op.pos,
                                forward: true,
                            }))
                            .is_some()
                    } else {
                        finish
                            .get_at(idx_of(&OpId {
                                bucket: op.bucket,
                                pos: op.pos + 1,
                                forward: false,
                            }))
                            .is_some()
                    };
                    if ready {
                        // Hoist the ready op to the current position.
                        let op = stage_order[s].remove(idx);
                        stage_order[s].insert(stage_ptr[s], op);
                        recovered = true;
                        break 'outer;
                    }
                }
            }
            assert!(
                recovered,
                "1F1B schedule deadlocked with no ready op at {done}/{total_ops} \
                 (max_depth {max_depth}, {} routes) — dependency cycle in routes",
                routes.len()
            );
        }
    }

    let makespan = stage_free.iter().cloned().fold(0.0, f64::max);
    let stage_idle = stage_busy.iter().map(|&b| makespan - b).collect();
    PipelineResult { makespan, stage_busy, stage_idle, timeline }
}

/// The theoretical minimum bubble *fraction* of a uniform 1F1B pipeline:
/// `(p − 1) / (m + p − 1)` (§5.3.5, [44]).
pub fn ideal_bubble_fraction(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 + p as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Uniform linear pipeline helper: `m` buckets through `p` stages.
    fn uniform(p: usize, m: usize, fwd: f64, bwd: f64) -> Vec<Route> {
        (0..m)
            .map(|_| Route {
                stages: (0..p).collect(),
                fwd: vec![fwd; p],
                bwd: vec![bwd; p],
                comm: vec![0.0; p],
            })
            .collect()
    }

    #[test]
    fn single_stage_single_bucket() {
        let r = simulate(1, &uniform(1, 1, 1.0, 2.0));
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert_eq!(r.timeline.len(), 2);
        assert!((r.stage_busy[0] - 3.0).abs() < 1e-12);
        assert!(r.stage_idle[0].abs() < 1e-12);
    }

    #[test]
    fn uniform_pipeline_matches_1f1b_closed_form() {
        // With fwd = f, bwd = 2f, p stages, m ≥ p buckets, the 1F1B
        // makespan is (p−1)·f (warmup) + m·(f+2f) (steady state on stage
        // 0) + (p−1)·2f (drain) = (p−1)·3f + 3mf.
        for (p, m) in [(2usize, 4usize), (4, 6), (4, 4), (3, 8)] {
            let f = 1.0;
            let r = simulate(p, &uniform(p, m, f, 2.0 * f));
            let expect = (p as f64 - 1.0) * 3.0 * f + 3.0 * m as f64 * f;
            assert!(
                (r.makespan - expect).abs() < 1e-9,
                "p={p} m={m}: got {} expect {expect}",
                r.makespan
            );
        }
    }

    #[test]
    fn bubble_fraction_tracks_ideal_for_uniform_input() {
        // Idle on the *last* stage of a uniform 1F1B pipeline equals the
        // classic (p−1)/(m+p−1) fraction of the makespan (fwd+bwd = 3f
        // per bucket, warm-up+drain bubbles of 3f per missing slot).
        let (p, m) = (4usize, 12usize);
        let r = simulate(p, &uniform(p, m, 1.0, 2.0));
        let last = p - 1;
        let measured = r.stage_idle[last] / r.makespan;
        let ideal = ideal_bubble_fraction(p, m);
        assert!(
            (measured - ideal).abs() < 0.02,
            "measured {measured} ideal {ideal}"
        );
    }

    #[test]
    fn ops_never_overlap_on_a_stage() {
        let mut routes = uniform(3, 5, 1.0, 2.0);
        // Perturb durations to exercise the variable-duration path.
        for (i, r) in routes.iter_mut().enumerate() {
            for s in 0..3 {
                r.fwd[s] = 1.0 + 0.3 * ((i + s) % 3) as f64;
                r.bwd[s] = 2.0 + 0.5 * ((i * s) % 2) as f64;
            }
        }
        let res = simulate(3, &routes);
        for s in 0..3 {
            let mut ops: Vec<&OpRecord> =
                res.timeline.iter().filter(|o| o.stage == s).collect();
            ops.sort_by(|a, b| a.start.partial_cmp(&b.start).expect("NaN"));
            for w in ops.windows(2) {
                assert!(
                    w[1].start >= w[0].finish - 1e-9,
                    "overlap on stage {s}: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    #[test]
    fn dependencies_respected() {
        let routes = uniform(4, 6, 1.0, 2.0);
        let res = simulate(4, &routes);
        let get = |bucket: usize, stage: usize, fw: bool| {
            res.timeline
                .iter()
                .find(|o| o.bucket == bucket && o.stage == stage && o.is_forward == fw)
                .expect("op present")
        };
        for b in 0..6 {
            for s in 1..4 {
                assert!(get(b, s, true).start >= get(b, s - 1, true).finish - 1e-9);
            }
            for s in 0..3 {
                assert!(get(b, s, false).start >= get(b, s + 1, false).finish - 1e-9);
            }
            assert!(get(b, 3, false).start >= get(b, 3, true).finish - 1e-9);
        }
    }

    #[test]
    fn comm_hops_delay_downstream_stages() {
        let mut with_comm = uniform(2, 2, 1.0, 2.0);
        for r in &mut with_comm {
            r.comm[1] = 5.0;
        }
        let base = simulate(2, &uniform(2, 2, 1.0, 2.0));
        let delayed = simulate(2, &with_comm);
        assert!(delayed.makespan > base.makespan + 5.0 - 1e-9);
    }

    #[test]
    fn heterogeneous_durations_create_extra_bubbles() {
        // One slow bucket inflates idle time versus uniform (Fig 1 bottom).
        let uniform_res = simulate(4, &uniform(4, 8, 1.0, 2.0));
        let mut skew = uniform(4, 8, 1.0, 2.0);
        for s in 0..4 {
            skew[3].fwd[s] = 4.0;
            skew[3].bwd[s] = 8.0;
        }
        let skew_res = simulate(4, &skew);
        let idle_u: f64 = uniform_res.stage_idle.iter().sum();
        let idle_s: f64 = skew_res.stage_idle.iter().sum();
        assert!(idle_s > idle_u * 1.5, "uniform {idle_u} skewed {idle_s}");
    }

    #[test]
    fn disjoint_pipelines_run_concurrently() {
        // Two independent 1-stage pipelines: makespan is the max, not sum.
        let routes = vec![
            Route { stages: vec![0], fwd: vec![1.0], bwd: vec![2.0], comm: vec![0.0] },
            Route { stages: vec![1], fwd: vec![1.0], bwd: vec![2.0], comm: vec![0.0] },
        ];
        let r = simulate(2, &routes);
        assert!((r.makespan - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ideal_bubble_formula() {
        assert!((ideal_bubble_fraction(4, 12) - 3.0 / 15.0).abs() < 1e-12);
        assert_eq!(ideal_bubble_fraction(1, 8), 0.0);
    }
}
