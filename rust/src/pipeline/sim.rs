//! Generic dependency-driven 1F1B pipeline execution engine.
//!
//! The engine simulates a 1F1B schedule over an arbitrary set of physical
//! stages and per-bucket routes with *variable* forward/backward durations —
//! the setting of Fig 1's "real case". Unlike the closed-form makespan
//! formula (which assumes uniform microbatches), execution times here flow
//! from data dependencies:
//!
//! - `F(k, r)` starts after `F(k, r−1)` finishes plus the communication hop;
//! - `B(k, r)` starts after `B(k, r+1)` (or `F(k, last)` for the last
//!   stage) plus the hop;
//! - each physical stage executes its ops in the static 1F1B order
//!   (warm-up forwards, then alternating backward/forward, then drain),
//!   and is busy with at most one op at a time.
//!
//! The engine reports per-stage busy/idle time (Fig 13), the full op
//! timeline (Fig 1), and the iteration makespan.
//!
//! Two implementations share that contract:
//!
//! - the **event-driven core** ([`SimWorkspace::run`]): ready-queue
//!   execution over the precomputed dependency structure, all state in a
//!   reusable arena — zero heap allocation in steady state, `O(total ops)`
//!   work. Every hot path (optimizer Eq-1 refinement, trainer iterations,
//!   the evaluation grid) goes through this core.
//! - the **polling oracle** ([`simulate_reference`]): the original
//!   worklist engine, retained as the bit-exactness baseline. The oracle
//!   property test asserts the two produce identical `makespan` /
//!   `stage_busy` bits on randomized heterogeneous route sets.
//!
//! Both engines compute the same per-op arithmetic in the same per-stage
//! order, so the results agree bit-for-bit (the op *timeline* may be
//! emitted in a different global interleaving — per-op records are
//! identical, execution order across stages is not observable).

/// One bucket's path through the pipeline.
#[derive(Clone, Debug)]
pub struct Route {
    /// Physical stage ids, in traversal order.
    pub stages: Vec<usize>,
    /// Forward duration at each route position.
    pub fwd: Vec<f64>,
    /// Backward duration at each route position.
    pub bwd: Vec<f64>,
    /// Communication time for the hop *into* route position r (index 0 is
    /// unused / 0.0; index r is the transfer from stage r−1 to r).
    pub comm: Vec<f64>,
}

impl Route {
    pub fn depth(&self) -> usize {
        self.stages.len()
    }
}

/// A simulated operation for timeline rendering.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct OpRecord {
    pub bucket: usize,
    pub stage: usize,
    pub is_forward: bool,
    pub start: f64,
    pub finish: f64,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct PipelineResult {
    /// Time at which every backward has drained.
    pub makespan: f64,
    /// Per physical stage: time spent executing ops.
    pub stage_busy: Vec<f64>,
    /// Per physical stage: `makespan − busy` (bubbles + warm-up/drain).
    pub stage_idle: Vec<f64>,
    pub timeline: Vec<OpRecord>,
}

/// One encoder sub-op placed into another stage's idle gap by the
/// bubble-filling interleaved executor
/// (`crate::pipeline::build::iterate_interleaved`). Fill ops are kept
/// *out* of the op [`SimWorkspace::timeline`] on purpose: the chain
/// timeline must stay one-record-per-(bucket, stage, direction) so the
/// critical-path extractor's op index (`crate::obs::critical`) remains
/// collision-free. Their work is charged into the host stage's busy
/// accounting via [`SimWorkspace::record_fill`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FillOp {
    /// Bucket whose encoder leg was decomposed.
    pub bucket: usize,
    /// Stage whose bubble hosts the sub-op.
    pub stage: usize,
    pub start: f64,
    pub finish: f64,
}

impl FillOp {
    /// Placed duration (`finish − start`).
    pub fn dur(&self) -> f64 {
        self.finish - self.start
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
struct OpId {
    bucket: usize,
    /// Position along the bucket's route.
    pos: usize,
    forward: bool,
}

// ------------------------------------------------------------------
// Route arena
// ------------------------------------------------------------------

/// Flat, arena-style route storage: the workspace equivalent of
/// `&[Route]`. Legs live in four parallel vectors; `ends[r]` is the
/// exclusive end of route `r`'s leg range. Building into a cleared
/// `RouteSet` allocates nothing once the buffers have grown to the
/// workload's steady-state size.
#[derive(Clone, Debug, Default)]
pub struct RouteSet {
    stages: Vec<usize>,
    fwd: Vec<f64>,
    bwd: Vec<f64>,
    comm: Vec<f64>,
    ends: Vec<usize>,
    /// Structure generation: bumped by every mutation that can change the
    /// route *topology* (stages, leg counts, hop costs). The delta-replay
    /// path ([`SimWorkspace::delta_run`]) compares this against the
    /// generation it recorded its execution order under and falls back to
    /// a full run on mismatch. Cost-only edits via
    /// [`SimWorkspace::update_leg`] deliberately do not bump it.
    version: u64,
}

impl RouteSet {
    pub fn new() -> RouteSet {
        RouteSet::default()
    }

    /// Drop all routes, keeping buffer capacity.
    pub fn clear(&mut self) {
        self.stages.clear();
        self.fwd.clear();
        self.bwd.clear();
        self.comm.clear();
        self.ends.clear();
        self.version += 1;
    }

    /// Number of sealed routes.
    pub fn len(&self) -> usize {
        self.ends.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ends.is_empty()
    }

    /// Append one leg to the route under construction; seal it with
    /// [`RouteSet::end_route`]. `comm` is the hop *into* this leg (0.0 for
    /// a route's first leg, matching [`Route::comm`]).
    #[inline]
    pub fn push_leg(&mut self, stage: usize, fwd: f64, bwd: f64, comm: f64) {
        self.stages.push(stage);
        self.fwd.push(fwd);
        self.bwd.push(bwd);
        self.comm.push(comm);
        self.version += 1;
    }

    /// Seal the route under construction (possibly empty).
    #[inline]
    pub fn end_route(&mut self) {
        self.ends.push(self.stages.len());
        self.version += 1;
    }

    /// Append a materialized [`Route`].
    pub fn push_route(&mut self, r: &Route) {
        for pos in 0..r.stages.len() {
            self.push_leg(r.stages[pos], r.fwd[pos], r.bwd[pos], r.comm[pos]);
        }
        self.end_route();
    }

    /// Leg range `[lo, hi)` of route `r`.
    #[inline]
    fn bounds(&self, r: usize) -> (usize, usize) {
        (if r == 0 { 0 } else { self.ends[r - 1] }, self.ends[r])
    }

    #[inline]
    fn depth(&self, r: usize) -> usize {
        let (lo, hi) = self.bounds(r);
        hi - lo
    }

    fn max_depth(&self) -> usize {
        (0..self.len()).map(|r| self.depth(r)).max().unwrap_or(0)
    }
}

// ------------------------------------------------------------------
// Event-driven core
// ------------------------------------------------------------------

/// The flat finish-table index of an op.
#[inline]
fn idx_of(op: OpId, stride: usize) -> usize {
    (op.bucket * stride + op.pos) * 2 + op.forward as usize
}

/// The single dependency of `op`: `None` for a first-stage forward (ready
/// at t = 0), otherwise the dep op's finish index plus the communication
/// charged on the hop. Every op has at most one dependency, which is what
/// makes event propagation O(1) per completed op.
#[inline]
fn dep_of(op: OpId, routes: &RouteSet, stride: usize) -> Option<(usize, f64)> {
    let (lo, _) = routes.bounds(op.bucket);
    if op.forward {
        if op.pos == 0 {
            None
        } else {
            Some((
                idx_of(OpId { bucket: op.bucket, pos: op.pos - 1, forward: true }, stride),
                routes.comm[lo + op.pos],
            ))
        }
    } else if op.pos + 1 == routes.depth(op.bucket) {
        // Last stage: backward follows own forward directly.
        Some((idx_of(OpId { bucket: op.bucket, pos: op.pos, forward: true }, stride), 0.0))
    } else {
        Some((
            idx_of(OpId { bucket: op.bucket, pos: op.pos + 1, forward: false }, stride),
            routes.comm[lo + op.pos + 1],
        ))
    }
}

/// Reusable arena for the event-driven simulation core.
///
/// Ownership rule: **one workspace per worker** — allocate once per thread
/// of execution (a pool worker, a trainer loop, a bench harness) and pass
/// by `&mut`. A workspace is plain mutable state; sharing one across
/// concurrent tasks is a data race the borrow checker will reject anyway.
/// After warm-up, a `run` call performs no heap allocation: buffers are
/// cleared and refilled, never shrunk.
///
/// Call cycle: `ws.routes.clear()` → build legs (`push_leg`/`end_route` or
/// `push_route`) → `ws.run(n_stages, record_timeline)` → read
/// [`SimWorkspace::makespan`], [`SimWorkspace::stage_busy`],
/// [`SimWorkspace::timeline`] (or clone out via [`SimWorkspace::to_result`]).
#[derive(Clone, Debug, Default)]
pub struct SimWorkspace {
    /// Route arena consumed by the next [`SimWorkspace::run`] call.
    pub routes: RouteSet,
    /// Caller scratch for packed-bucket pricing inputs (e.g.
    /// `Estimator::llm_bucket_dur`); nothing in the core reads it.
    pub seqs: Vec<f64>,
    /// Bubble-slot ledger: encoder sub-ops the bubble-filling pass placed
    /// into other stages' idle gaps after the last run (see
    /// [`SimWorkspace::record_fill`]). Cleared by every run; plain 1F1B
    /// execution leaves it empty.
    pub fills: Vec<FillOp>,

    // ---- static 1F1B order (rebuilt per run) ----
    /// (bucket, pos) legs grouped by stage, bucket-major within a stage.
    legs: Vec<(usize, usize)>,
    legs_off: Vec<usize>,
    cursor: Vec<usize>,
    /// Sorted, deduped (stage, successor-stage) pairs: fan-out counting
    /// without a per-stage `HashSet`.
    succ_pairs: Vec<(usize, usize)>,
    /// Per-stage 1F1B op order, flat; `order_off` delimits stages.
    order: Vec<OpId>,
    order_off: Vec<usize>,

    // ---- execution state ----
    /// Finish time per (bucket, pos, dir) flat index; NaN = not executed.
    finish: Vec<f64>,
    stage_ptr: Vec<usize>,
    stage_free: Vec<f64>,
    stage_busy: Vec<f64>,
    /// Stages whose head op is known ready (LIFO; order is irrelevant to
    /// the computed times — see the module docs).
    ready: Vec<usize>,
    in_ready: Vec<bool>,
    timeline: Vec<OpRecord>,
    makespan: f64,

    // ---- delta-replay record (valid only while `tracked`) ----
    /// Global execution order of the last tracked run: a topological order
    /// of the dependency DAG (dep edges + same-stage predecessor edges).
    /// The engine's control flow is duration-independent — every branch it
    /// takes tests *structure* (`finish[i].is_nan()`, queue membership),
    /// never a time value — so this order stays valid under arbitrary
    /// cost-only edits and can be replayed instead of re-scheduled.
    exec: Vec<OpId>,
    /// Buckets edited since the last (delta or full) run.
    dirty_bucket: Vec<bool>,
    dirty_list: Vec<usize>,
    /// Per finish-table index: did the last delta walk change this op's
    /// finish bits? Written before any dependent reads it (topological
    /// walk), so it never needs pre-clearing.
    changed: Vec<bool>,
    /// Stages hosting at least one dirty-bucket leg (busy re-sum set).
    dirty_stage: Vec<bool>,
    /// Walk state: finish of the stage's latest replayed op, and whether
    /// that finish changed bits.
    delta_prev: Vec<f64>,
    delta_prev_changed: Vec<bool>,
    /// A delta-replayable record exists (set by [`SimWorkspace::run_tracked`],
    /// cleared by plain [`SimWorkspace::run`]).
    tracked: bool,
    tracked_version: u64,
    tracked_stages: usize,
    /// The tracked run exercised the work-conserving hoist. The recorded
    /// order is still a valid topological order, but replay keeps this as
    /// a conservative full-rerun trigger for the one code path whose
    /// order mutation is hardest to audit.
    hoisted: bool,
    /// Set by [`SimWorkspace::mark_duration_dependent`]: the current leg
    /// costs were *derived from a previous run's measured durations*
    /// (bubble-filling), so the cost-edits-are-exogenous assumption the
    /// delta record relies on no longer holds. Cleared by every full run.
    duration_dependent: bool,
}

impl SimWorkspace {
    pub fn new() -> SimWorkspace {
        SimWorkspace::default()
    }

    /// Makespan of the last [`SimWorkspace::run`].
    pub fn makespan(&self) -> f64 {
        self.makespan
    }

    /// Per-stage busy time of the last run.
    pub fn stage_busy(&self) -> &[f64] {
        &self.stage_busy
    }

    /// Op timeline of the last run (empty unless it was recorded).
    pub fn timeline(&self) -> &[OpRecord] {
        &self.timeline
    }

    /// Copy the last run's outputs into an owned [`PipelineResult`].
    pub fn to_result(&self) -> PipelineResult {
        let makespan = self.makespan;
        PipelineResult {
            makespan,
            stage_busy: self.stage_busy.clone(),
            stage_idle: self.stage_busy.iter().map(|&b| makespan - b).collect(),
            timeline: self.timeline.clone(),
        }
    }

    /// Simulate the 1F1B execution of `self.routes` over `n_stages`
    /// physical stages and return the makespan.
    ///
    /// Buckets routed through the same stage are ordered by bucket index
    /// (their arrival order from the scheduler). Panics if the op order
    /// deadlocks — which would indicate an invalid route set, e.g. two
    /// buckets traversing shared stages in opposite orders.
    ///
    /// `record_timeline = false` skips [`OpRecord`] accumulation — the
    /// optimizer's refinement loop only needs the makespan, and the
    /// timeline is the one per-op cost that cannot be amortized.
    pub fn run(&mut self, n_stages: usize, record_timeline: bool) -> f64 {
        self.run_impl(n_stages, record_timeline, false)
    }

    /// [`SimWorkspace::run`] (timeline off) that additionally records the
    /// global execution order, arming [`SimWorkspace::update_leg`] +
    /// [`SimWorkspace::delta_run`] for cheap cost-only re-evaluation.
    pub fn run_tracked(&mut self, n_stages: usize) -> f64 {
        self.run_impl(n_stages, false, true)
    }

    fn run_impl(&mut self, n_stages: usize, record_timeline: bool, track: bool) -> f64 {
        let routes = &self.routes;
        let n_routes = routes.len();

        // ---- per-stage legs via counting sort (bucket-major, matching
        // the oracle's `stage_buckets` construction order) ----
        self.legs_off.clear();
        self.legs_off.resize(n_stages + 1, 0);
        for &s in &routes.stages {
            assert!(s < n_stages, "route references unknown stage {s}");
            self.legs_off[s + 1] += 1;
        }
        for s in 0..n_stages {
            self.legs_off[s + 1] += self.legs_off[s];
        }
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.legs_off[..n_stages]);
        self.legs.clear();
        self.legs.resize(routes.stages.len(), (0, 0));
        for b in 0..n_routes {
            let (lo, hi) = routes.bounds(b);
            for (pos, leg) in (lo..hi).enumerate() {
                let s = routes.stages[leg];
                self.legs[self.cursor[s]] = (b, pos);
                self.cursor[s] += 1;
            }
        }

        // Fan-out per stage: when a stage feeds several distinct
        // downstream stages (e.g. one encoder DP group serving multiple
        // LLM pipelines), its warm-up must cover each of them — count
        // distinct successors via sort + dedup on a reused pair buffer.
        self.succ_pairs.clear();
        for b in 0..n_routes {
            let (lo, hi) = routes.bounds(b);
            for leg in lo..hi.saturating_sub(1) {
                self.succ_pairs.push((routes.stages[leg], routes.stages[leg + 1]));
            }
        }
        self.succ_pairs.sort_unstable();
        self.succ_pairs.dedup();

        // ---- 1F1B op order per stage: warm-up = stage depth × fan-out
        // forwards, then alternate B/F, then drain backwards ----
        self.order.clear();
        self.order_off.clear();
        self.order_off.push(0);
        let mut succ_at = 0usize;
        for s in 0..n_stages {
            // Consume this stage's run of the sorted successor pairs.
            let mut fan_out = 0usize;
            while succ_at < self.succ_pairs.len() && self.succ_pairs[succ_at].0 == s {
                fan_out += 1;
                succ_at += 1;
            }
            let legs = &self.legs[self.legs_off[s]..self.legs_off[s + 1]];
            let n = legs.len();
            if n == 0 {
                self.order_off.push(self.order.len());
                continue;
            }
            // The stage's pipeline depth (distance from the end) governs
            // how many in-flight forwards 1F1B allows it; fan-out
            // multiplies it.
            let depth_here = legs
                .iter()
                .map(|&(b, pos)| routes.depth(b) - pos)
                .max()
                .expect("non-empty");
            let warmup = (depth_here * fan_out.max(1)).min(n);
            for &(b, pos) in legs.iter().take(warmup) {
                self.order.push(OpId { bucket: b, pos, forward: true });
            }
            for k in 0..n - warmup {
                let (bb, bp) = legs[k];
                self.order.push(OpId { bucket: bb, pos: bp, forward: false });
                let (fb, fp) = legs[k + warmup];
                self.order.push(OpId { bucket: fb, pos: fp, forward: true });
            }
            for &(b, pos) in legs.iter().skip(n - warmup) {
                self.order.push(OpId { bucket: b, pos, forward: false });
            }
            self.order_off.push(self.order.len());
        }

        // ---- execution state ----
        let stride = routes.max_depth().max(1);
        self.finish.clear();
        self.finish.resize(n_routes * stride * 2, f64::NAN);
        self.stage_ptr.clear();
        self.stage_ptr.resize(n_stages, 0);
        self.stage_free.clear();
        self.stage_free.resize(n_stages, 0.0);
        self.stage_busy.clear();
        self.stage_busy.resize(n_stages, 0.0);
        self.in_ready.clear();
        self.in_ready.resize(n_stages, false);
        self.ready.clear();
        self.timeline.clear();
        self.fills.clear();
        self.exec.clear();
        let mut hoisted = false;

        let exec = &mut self.exec;
        let order = &mut self.order;
        let order_off = &self.order_off;
        let finish = &mut self.finish;
        let stage_ptr = &mut self.stage_ptr;
        let stage_free = &mut self.stage_free;
        let stage_busy = &mut self.stage_busy;
        let ready = &mut self.ready;
        let in_ready = &mut self.in_ready;
        let timeline = &mut self.timeline;

        let total_ops = order.len();
        let mut done = 0usize;

        // Seed: stages whose head op has no unmet dependency (at t = 0
        // that is first-position forwards; the general check costs the
        // same and tolerates pre-finished state).
        for s in 0..n_stages {
            let head = order_off[s];
            if head < order_off[s + 1] {
                let ok = match dep_of(order[head], routes, stride) {
                    None => true,
                    Some((i, _)) => !finish[i].is_nan(),
                };
                if ok {
                    ready.push(s);
                    in_ready[s] = true;
                }
            }
        }

        // ---- event-driven execution ----
        // Pop a ready stage, run its head ops while their single
        // dependency is met, and propagate each completion to the one op
        // it unblocks. Every op is examined O(1) times; no polling sweeps.
        while done < total_ops {
            let Some(s) = ready.pop() else {
                // Work-conserving fallback, identical to the oracle's
                // stall recovery: the static 1F1B order stalled (possible
                // under exotic DP-group topologies where the warm-up
                // heuristic under-provisions). Hoist the earliest *ready*
                // op (stage order, then position) to its stage's current
                // position — dependencies are still honored, only the
                // local 1F1B ordering is relaxed.
                let mut recovered = false;
                'outer: for s in 0..n_stages {
                    let cur = order_off[s] + stage_ptr[s];
                    for abs in cur + 1..order_off[s + 1] {
                        let ok = match dep_of(order[abs], routes, stride) {
                            None => true,
                            Some((i, _)) => !finish[i].is_nan(),
                        };
                        if ok {
                            order[cur..=abs].rotate_right(1);
                            ready.push(s);
                            in_ready[s] = true;
                            recovered = true;
                            hoisted = true;
                            break 'outer;
                        }
                    }
                }
                assert!(
                    recovered,
                    "1F1B schedule deadlocked with no ready op at {done}/{total_ops} \
                     ({n_routes} routes) — dependency cycle in routes"
                );
                continue;
            };
            in_ready[s] = false;
            let seg_hi = order_off[s + 1];
            loop {
                let cur = order_off[s] + stage_ptr[s];
                if cur >= seg_hi {
                    break;
                }
                let op = order[cur];
                let dep_t = match dep_of(op, routes, stride) {
                    None => 0.0,
                    Some((i, c)) => {
                        let fin = finish[i];
                        if fin.is_nan() {
                            break; // head not ready; a completion re-queues us
                        }
                        fin + c
                    }
                };
                let (lo, _) = routes.bounds(op.bucket);
                let dur =
                    if op.forward { routes.fwd[lo + op.pos] } else { routes.bwd[lo + op.pos] };
                let start = stage_free[s].max(dep_t);
                let end = start + dur;
                stage_free[s] = end;
                stage_busy[s] += dur;
                finish[idx_of(op, stride)] = end;
                if record_timeline {
                    timeline.push(OpRecord {
                        bucket: op.bucket,
                        stage: s,
                        is_forward: op.forward,
                        start,
                        finish: end,
                    });
                }
                stage_ptr[s] += 1;
                done += 1;
                if track {
                    exec.push(op);
                }
                // This completion readies exactly one dependent op; if it
                // now heads a *different* stage, queue that stage (this
                // stage's own head is re-checked by the loop).
                let dependent = if op.forward {
                    if op.pos + 1 < routes.depth(op.bucket) {
                        Some(OpId { bucket: op.bucket, pos: op.pos + 1, forward: true })
                    } else {
                        Some(OpId { bucket: op.bucket, pos: op.pos, forward: false })
                    }
                } else if op.pos > 0 {
                    Some(OpId { bucket: op.bucket, pos: op.pos - 1, forward: false })
                } else {
                    None
                };
                if let Some(dep_op) = dependent {
                    let ds = routes.stages[lo + dep_op.pos];
                    if ds != s && !in_ready[ds] {
                        let head = order_off[ds] + stage_ptr[ds];
                        if head < order_off[ds + 1] && order[head] == dep_op {
                            ready.push(ds);
                            in_ready[ds] = true;
                        }
                    }
                }
            }
        }

        self.makespan = stage_free.iter().cloned().fold(0.0, f64::max);
        self.tracked = track;
        // A full run re-derives every finish time from the routes as they
        // stand, so any prior duration-derived edits are now baked in.
        self.duration_dependent = false;
        if track {
            self.tracked_version = self.routes.version;
            self.tracked_stages = n_stages;
            self.hoisted = hoisted;
            let n_routes = self.routes.len();
            self.dirty_bucket.clear();
            self.dirty_bucket.resize(n_routes, false);
            self.dirty_list.clear();
            // `changed` carries no information across walks — sized here,
            // written before read inside every delta walk.
            self.changed.clear();
            self.changed.resize(self.finish.len(), false);
        }
        self.makespan
    }

    /// Overwrite one leg's forward/backward cost in place and mark its
    /// bucket dirty for the next [`SimWorkspace::delta_run`].
    ///
    /// This is a *cost-only* edit: the stage id and hop cost are fixed (a
    /// comm change alters `dep_of` arithmetic mid-route and therefore
    /// requires a route rebuild, which bumps the structure generation and
    /// forces the full path anyway).
    #[inline]
    pub fn update_leg(&mut self, bucket: usize, pos: usize, fwd: f64, bwd: f64) {
        let (lo, hi) = self.routes.bounds(bucket);
        assert!(pos < hi - lo, "leg {pos} out of range for bucket {bucket}");
        self.routes.fwd[lo + pos] = fwd;
        self.routes.bwd[lo + pos] = bwd;
        self.mark_bucket_dirty(bucket);
    }

    /// Flag a bucket whose costs were edited (idempotent). Callers that
    /// write `routes` costs directly must call this per touched bucket or
    /// the next [`SimWorkspace::delta_run`] will skip their ops.
    #[inline]
    pub fn mark_bucket_dirty(&mut self, bucket: usize) {
        if self.tracked && !self.dirty_bucket[bucket] {
            self.dirty_bucket[bucket] = true;
            self.dirty_list.push(bucket);
        }
    }

    /// Declare the pending cost edits *duration-derived*: they were
    /// computed from a previous run's measured schedule (the bubble-filling
    /// pass shrinks encoder legs by exactly the work it re-placed into
    /// observed gaps). The delta record assumes edits are exogenous, so a
    /// duration-driven editor must call this after its `update_leg` batch —
    /// it bumps the route structure generation *and* pins a conservative
    /// flag, forcing the next [`SimWorkspace::delta_run`] onto the full
    /// tracked path instead of replaying a stale order.
    pub fn mark_duration_dependent(&mut self) {
        self.duration_dependent = true;
        self.routes.version += 1;
    }

    /// Register one bubble-fill sub-op: append it to the
    /// [`SimWorkspace::fills`] ledger and charge its duration into the host
    /// stage's busy time (so `makespan − busy` keeps reporting true idle).
    /// The caller guarantees `[start, start + dur)` lies inside an idle gap
    /// of `stage` in the last run's schedule.
    pub fn record_fill(&mut self, bucket: usize, stage: usize, start: f64, dur: f64) {
        self.stage_busy[stage] += dur;
        self.fills.push(FillOp { bucket, stage, start, finish: start + dur });
    }

    /// Re-evaluate the makespan after cost-only edits by replaying the
    /// recorded execution order, recomputing only ops that can have moved:
    /// ops of dirty buckets, ops whose single dependency changed bits, and
    /// ops whose same-stage predecessor changed bits. Everything upstream
    /// of the dirty frontier is skipped; results are bit-identical to a
    /// full [`SimWorkspace::run`] over the edited routes.
    ///
    /// Falls back to a full tracked run when no replayable record exists:
    /// never tracked, the route structure changed (generation mismatch),
    /// the stage count changed, the tracked run hoisted, or the pending
    /// edits are duration-derived
    /// ([`SimWorkspace::mark_duration_dependent`]). The op timeline is not
    /// maintained on this path.
    pub fn delta_run(&mut self, n_stages: usize) -> f64 {
        if !self.tracked
            || self.hoisted
            || self.duration_dependent
            || n_stages != self.tracked_stages
            || self.routes.version != self.tracked_version
        {
            return self.run_tracked(n_stages);
        }
        if self.dirty_list.is_empty() {
            return self.makespan;
        }
        let routes = &self.routes;
        let stride = routes.max_depth().max(1);
        self.delta_prev.clear();
        self.delta_prev.resize(n_stages, 0.0);
        self.delta_prev_changed.clear();
        self.delta_prev_changed.resize(n_stages, false);
        self.dirty_stage.clear();
        self.dirty_stage.resize(n_stages, false);

        let finish = &mut self.finish;
        let changed = &mut self.changed;
        let delta_prev = &mut self.delta_prev;
        let prev_changed = &mut self.delta_prev_changed;
        let dirty_stage = &mut self.dirty_stage;
        let dirty_bucket = &self.dirty_bucket;

        // The recorded order is a topological order of both edge kinds, so
        // a single forward walk sees every op's dependency and same-stage
        // predecessor already settled.
        for &op in &self.exec {
            let (lo, _) = routes.bounds(op.bucket);
            let s = routes.stages[lo + op.pos];
            let fin = idx_of(op, stride);
            let bucket_dirty = dirty_bucket[op.bucket];
            if bucket_dirty {
                dirty_stage[s] = true;
            }
            let dep = dep_of(op, routes, stride);
            let dep_changed = match dep {
                None => false,
                Some((i, _)) => changed[i],
            };
            // Skip requires the predecessor unchanged too; the skip path
            // therefore never needs to update `prev_changed[s]` (it is
            // false and stays false).
            if !bucket_dirty && !dep_changed && !prev_changed[s] {
                changed[fin] = false;
                delta_prev[s] = finish[fin];
                continue;
            }
            let dep_t = match dep {
                None => 0.0,
                Some((i, c)) => finish[i] + c,
            };
            let dur =
                if op.forward { routes.fwd[lo + op.pos] } else { routes.bwd[lo + op.pos] };
            // Same max() argument order as the full engine's
            // `stage_free[s].max(dep_t)` — bit-exactness depends on it.
            let start = delta_prev[s].max(dep_t);
            let end = start + dur;
            let ch = end.to_bits() != finish[fin].to_bits();
            finish[fin] = end;
            changed[fin] = ch;
            delta_prev[s] = end;
            prev_changed[s] = ch;
        }

        // Busy time only moves on stages hosting dirty legs; re-SUM in the
        // stage's executed segment order (the full engine's addition
        // order) — an incremental subtract/add would reassociate floats.
        let order = &self.order;
        let order_off = &self.order_off;
        for s in 0..n_stages {
            if dirty_stage[s] {
                let mut busy = 0.0;
                for &op in &order[order_off[s]..order_off[s + 1]] {
                    let (lo, _) = routes.bounds(op.bucket);
                    busy +=
                        if op.forward { routes.fwd[lo + op.pos] } else { routes.bwd[lo + op.pos] };
                }
                self.stage_busy[s] = busy;
            }
            // stage_free[s] is the finish of the stage's last executed op.
            self.stage_free[s] = match order[order_off[s]..order_off[s + 1]].last() {
                None => 0.0,
                Some(&op) => finish[idx_of(op, stride)],
            };
        }
        self.makespan = self.stage_free.iter().cloned().fold(0.0, f64::max);

        for &b in &self.dirty_list {
            self.dirty_bucket[b] = false;
        }
        self.dirty_list.clear();
        self.makespan
    }
}

/// Simulate the 1F1B execution of `routes` over `n_stages` physical
/// stages.
///
/// One-shot convenience wrapper over the event-driven core: allocates a
/// fresh [`SimWorkspace`] per call. Hot loops should hold a workspace and
/// call [`SimWorkspace::run`] instead.
pub fn simulate(n_stages: usize, routes: &[Route]) -> PipelineResult {
    let mut ws = SimWorkspace::new();
    for r in routes {
        ws.routes.push_route(r);
    }
    ws.run(n_stages, true);
    ws.to_result()
}

// ------------------------------------------------------------------
// Polling oracle
// ------------------------------------------------------------------

/// The original polling-worklist engine, retained as the bit-exactness
/// oracle for the event-driven core (and as the before/after baseline in
/// `pipeline_bench`). Repeatedly sweeps all stages executing every ready
/// head op until no progress is made, then hoists a ready op forward
/// (work-conserving fallback). Semantics are identical to
/// [`SimWorkspace::run`]; cost is O(n_stages) per sweep plus per-call
/// allocation of every intermediate structure.
pub fn simulate_reference(n_stages: usize, routes: &[Route]) -> PipelineResult {
    // ---- build the static per-stage op order (1F1B) ----
    let mut stage_buckets: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n_stages];
    for (b, r) in routes.iter().enumerate() {
        for (pos, &s) in r.stages.iter().enumerate() {
            assert!(s < n_stages, "route references unknown stage {s}");
            stage_buckets[s].push((b, pos));
        }
    }
    let max_depth = routes.iter().map(Route::depth).max().unwrap_or(0);

    let mut successors: Vec<std::collections::HashSet<usize>> =
        vec![std::collections::HashSet::new(); n_stages];
    for r in routes {
        for w in r.stages.windows(2) {
            successors[w[0]].insert(w[1]);
        }
    }

    let mut stage_order: Vec<Vec<OpId>> = Vec::with_capacity(n_stages);
    for s in 0..n_stages {
        let buckets = &stage_buckets[s];
        let mut order = Vec::with_capacity(buckets.len() * 2);
        if buckets.is_empty() {
            stage_order.push(order);
            continue;
        }
        let depth_here = buckets
            .iter()
            .map(|&(b, pos)| routes[b].depth() - pos)
            .max()
            .expect("non-empty");
        let n = buckets.len();
        let fan_out = successors[s].len().max(1);
        let warmup = (depth_here * fan_out).min(n);
        for &(b, pos) in buckets.iter().take(warmup) {
            order.push(OpId { bucket: b, pos, forward: true });
        }
        for k in 0..n - warmup {
            let (bb, bp) = buckets[k];
            order.push(OpId { bucket: bb, pos: bp, forward: false });
            let (fb, fp) = buckets[k + warmup];
            order.push(OpId { bucket: fb, pos: fp, forward: true });
        }
        for &(b, pos) in buckets.iter().skip(n - warmup) {
            order.push(OpId { bucket: b, pos, forward: false });
        }
        stage_order.push(order);
    }

    // ---- worklist execution ----
    // finish[op] once computed; flat-indexed by (bucket, pos, dir) with a
    // NaN sentinel (a HashMap here dominated the optimizer's refinement
    // loop — see EXPERIMENTS.md §Perf).
    let stride = max_depth.max(1);
    let idx = |op: &OpId| (op.bucket * stride + op.pos) * 2 + op.forward as usize;
    let mut finish = vec![f64::NAN; routes.len() * stride * 2];
    let mut stage_ptr = vec![0usize; n_stages];
    let mut stage_free = vec![0.0f64; n_stages];
    let mut stage_busy = vec![0.0f64; n_stages];
    let mut timeline = Vec::new();
    let total_ops: usize = stage_order.iter().map(Vec::len).sum();
    let mut done = 0usize;

    while done < total_ops {
        let mut progressed = false;
        for s in 0..n_stages {
            // Execute as many consecutive ready ops as possible per sweep.
            while stage_ptr[s] < stage_order[s].len() {
                let op = stage_order[s][stage_ptr[s]];
                let route = &routes[op.bucket];
                // Dependency finish time (None → not ready yet).
                let dep: Option<f64> = if op.forward {
                    if op.pos == 0 {
                        Some(0.0)
                    } else {
                        let f = finish
                            [idx(&OpId { bucket: op.bucket, pos: op.pos - 1, forward: true })];
                        (!f.is_nan()).then(|| f + route.comm[op.pos])
                    }
                } else if op.pos + 1 == route.depth() {
                    // Last stage: backward follows own forward directly.
                    let f =
                        finish[idx(&OpId { bucket: op.bucket, pos: op.pos, forward: true })];
                    (!f.is_nan()).then_some(f)
                } else {
                    let f = finish
                        [idx(&OpId { bucket: op.bucket, pos: op.pos + 1, forward: false })];
                    (!f.is_nan()).then(|| f + route.comm[op.pos + 1])
                };
                let Some(dep_t) = dep else { break };
                let dur = if op.forward { route.fwd[op.pos] } else { route.bwd[op.pos] };
                let start = stage_free[s].max(dep_t);
                let end = start + dur;
                stage_free[s] = end;
                stage_busy[s] += dur;
                finish[idx(&op)] = end;
                timeline.push(OpRecord {
                    bucket: op.bucket,
                    stage: s,
                    is_forward: op.forward,
                    start,
                    finish: end,
                });
                stage_ptr[s] += 1;
                done += 1;
                progressed = true;
            }
        }
        if !progressed && done < total_ops {
            // Work-conserving fallback (see SimWorkspace::run).
            let mut recovered = false;
            'outer: for s in 0..n_stages {
                for i in stage_ptr[s] + 1..stage_order[s].len() {
                    let op = stage_order[s][i];
                    let route = &routes[op.bucket];
                    let ready = if op.forward {
                        op.pos == 0
                            || !finish[idx(&OpId {
                                bucket: op.bucket,
                                pos: op.pos - 1,
                                forward: true,
                            })]
                            .is_nan()
                    } else if op.pos + 1 == route.depth() {
                        !finish[idx(&OpId { bucket: op.bucket, pos: op.pos, forward: true })]
                            .is_nan()
                    } else {
                        !finish[idx(&OpId {
                            bucket: op.bucket,
                            pos: op.pos + 1,
                            forward: false,
                        })]
                        .is_nan()
                    };
                    if ready {
                        // Hoist the ready op to the current position.
                        let op = stage_order[s].remove(i);
                        stage_order[s].insert(stage_ptr[s], op);
                        recovered = true;
                        break 'outer;
                    }
                }
            }
            assert!(
                recovered,
                "1F1B schedule deadlocked with no ready op at {done}/{total_ops} \
                 (max_depth {max_depth}, {} routes) — dependency cycle in routes",
                routes.len()
            );
        }
    }

    let makespan = stage_free.iter().cloned().fold(0.0, f64::max);
    let stage_idle = stage_busy.iter().map(|&b| makespan - b).collect();
    PipelineResult { makespan, stage_busy, stage_idle, timeline }
}

/// The theoretical minimum bubble *fraction* of a uniform 1F1B pipeline:
/// `(p − 1) / (m + p − 1)` (§5.3.5, [44]).
pub fn ideal_bubble_fraction(p: usize, m: usize) -> f64 {
    (p as f64 - 1.0) / (m as f64 + p as f64 - 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    /// Uniform linear pipeline helper: `m` buckets through `p` stages.
    fn uniform(p: usize, m: usize, fwd: f64, bwd: f64) -> Vec<Route> {
        (0..m)
            .map(|_| Route {
                stages: (0..p).collect(),
                fwd: vec![fwd; p],
                bwd: vec![bwd; p],
                comm: vec![0.0; p],
            })
            .collect()
    }

    #[test]
    fn single_stage_single_bucket() {
        let r = simulate(1, &uniform(1, 1, 1.0, 2.0));
        assert!((r.makespan - 3.0).abs() < 1e-12);
        assert_eq!(r.timeline.len(), 2);
        assert!((r.stage_busy[0] - 3.0).abs() < 1e-12);
        assert!(r.stage_idle[0].abs() < 1e-12);
    }

    #[test]
    fn uniform_pipeline_matches_1f1b_closed_form() {
        // With fwd = f, bwd = 2f, p stages, m ≥ p buckets, the 1F1B
        // makespan is (p−1)·f (warmup) + m·(f+2f) (steady state on stage
        // 0) + (p−1)·2f (drain) = (p−1)·3f + 3mf.
        for (p, m) in [(2usize, 4usize), (4, 6), (4, 4), (3, 8)] {
            let f = 1.0;
            let r = simulate(p, &uniform(p, m, f, 2.0 * f));
            let expect = (p as f64 - 1.0) * 3.0 * f + 3.0 * m as f64 * f;
            assert!(
                (r.makespan - expect).abs() < 1e-9,
                "p={p} m={m}: got {} expect {expect}",
                r.makespan
            );
        }
    }

    #[test]
    fn bubble_fraction_tracks_ideal_for_uniform_input() {
        // Idle on the *last* stage of a uniform 1F1B pipeline equals the
        // classic (p−1)/(m+p−1) fraction of the makespan (fwd+bwd = 3f
        // per bucket, warm-up+drain bubbles of 3f per missing slot).
        let (p, m) = (4usize, 12usize);
        let r = simulate(p, &uniform(p, m, 1.0, 2.0));
        let last = p - 1;
        let measured = r.stage_idle[last] / r.makespan;
        let ideal = ideal_bubble_fraction(p, m);
        assert!(
            (measured - ideal).abs() < 0.02,
            "measured {measured} ideal {ideal}"
        );
    }

    #[test]
    fn ops_never_overlap_on_a_stage() {
        let mut routes = uniform(3, 5, 1.0, 2.0);
        // Perturb durations to exercise the variable-duration path.
        for (i, r) in routes.iter_mut().enumerate() {
            for s in 0..3 {
                r.fwd[s] = 1.0 + 0.3 * ((i + s) % 3) as f64;
                r.bwd[s] = 2.0 + 0.5 * ((i * s) % 2) as f64;
            }
        }
        let res = simulate(3, &routes);
        for s in 0..3 {
            let mut ops: Vec<&OpRecord> =
                res.timeline.iter().filter(|o| o.stage == s).collect();
            ops.sort_by(|a, b| a.start.partial_cmp(&b.start).expect("NaN"));
            for w in ops.windows(2) {
                assert!(
                    w[1].start >= w[0].finish - 1e-9,
                    "overlap on stage {s}: {:?} then {:?}",
                    w[0],
                    w[1]
                );
            }
        }
    }

    #[test]
    fn dependencies_respected() {
        let routes = uniform(4, 6, 1.0, 2.0);
        let res = simulate(4, &routes);
        let get = |bucket: usize, stage: usize, fw: bool| {
            res.timeline
                .iter()
                .find(|o| o.bucket == bucket && o.stage == stage && o.is_forward == fw)
                .expect("op present")
        };
        for b in 0..6 {
            for s in 1..4 {
                assert!(get(b, s, true).start >= get(b, s - 1, true).finish - 1e-9);
            }
            for s in 0..3 {
                assert!(get(b, s, false).start >= get(b, s + 1, false).finish - 1e-9);
            }
            assert!(get(b, 3, false).start >= get(b, 3, true).finish - 1e-9);
        }
    }

    #[test]
    fn comm_hops_delay_downstream_stages() {
        let mut with_comm = uniform(2, 2, 1.0, 2.0);
        for r in &mut with_comm {
            r.comm[1] = 5.0;
        }
        let base = simulate(2, &uniform(2, 2, 1.0, 2.0));
        let delayed = simulate(2, &with_comm);
        assert!(delayed.makespan > base.makespan + 5.0 - 1e-9);
    }

    #[test]
    fn heterogeneous_durations_create_extra_bubbles() {
        // One slow bucket inflates idle time versus uniform (Fig 1 bottom).
        let uniform_res = simulate(4, &uniform(4, 8, 1.0, 2.0));
        let mut skew = uniform(4, 8, 1.0, 2.0);
        for s in 0..4 {
            skew[3].fwd[s] = 4.0;
            skew[3].bwd[s] = 8.0;
        }
        let skew_res = simulate(4, &skew);
        let idle_u: f64 = uniform_res.stage_idle.iter().sum();
        let idle_s: f64 = skew_res.stage_idle.iter().sum();
        assert!(idle_s > idle_u * 1.5, "uniform {idle_u} skewed {idle_s}");
    }

    #[test]
    fn disjoint_pipelines_run_concurrently() {
        // Two independent 1-stage pipelines: makespan is the max, not sum.
        let routes = vec![
            Route { stages: vec![0], fwd: vec![1.0], bwd: vec![2.0], comm: vec![0.0] },
            Route { stages: vec![1], fwd: vec![1.0], bwd: vec![2.0], comm: vec![0.0] },
        ];
        let r = simulate(2, &routes);
        assert!((r.makespan - 3.0).abs() < 1e-12);
    }

    #[test]
    fn ideal_bubble_formula() {
        assert!((ideal_bubble_fraction(4, 12) - 3.0 / 15.0).abs() < 1e-12);
        assert_eq!(ideal_bubble_fraction(1, 8), 0.0);
    }

    #[test]
    fn empty_route_set_yields_zero_makespan() {
        let r = simulate(3, &[]);
        assert_eq!(r.makespan, 0.0);
        assert!(r.timeline.is_empty());
        assert_eq!(r.stage_busy, vec![0.0; 3]);
    }

    /// Random heterogeneous route set: every route visits a strictly
    /// ascending subset of stages (shared-order traversal, so the set is
    /// always schedulable), with randomized durations and hops.
    fn random_routes(g: &mut crate::util::prop::Gen, n_stages: usize) -> Vec<Route> {
        let n_routes = g.size(16);
        (0..n_routes)
            .map(|_| {
                let depth = g.size(n_stages);
                let mut pool: Vec<usize> = (0..n_stages).collect();
                g.rng.shuffle(&mut pool);
                let mut stages: Vec<usize> = pool.into_iter().take(depth).collect();
                stages.sort_unstable();
                let fwd = (0..depth).map(|_| g.rng.uniform(0.1, 3.0)).collect();
                let bwd = (0..depth).map(|_| g.rng.uniform(0.1, 5.0)).collect();
                let comm: Vec<f64> = (0..depth)
                    .map(|p| if p == 0 { 0.0 } else { g.rng.uniform(0.0, 0.5) })
                    .collect();
                Route { stages, fwd, bwd, comm }
            })
            .collect()
    }

    /// Sort key that fully discriminates a timeline's records (each
    /// (bucket, stage, dir) triple occurs at most once per run here).
    fn timeline_key(o: &OpRecord) -> (usize, usize, bool) {
        (o.bucket, o.stage, o.is_forward)
    }

    #[test]
    fn event_core_matches_polling_oracle_bitwise() {
        // The tentpole contract: on randomized heterogeneous route sets
        // the event-driven core reproduces the retained polling engine
        // bit-for-bit — makespan, per-stage busy, and the (order-
        // insensitive) set of op records. One workspace is reused across
        // every case, so stale-state bugs fail the same property.
        let mut ws = SimWorkspace::new();
        forall("event core = polling oracle", 150, |g| {
            let n_stages = g.size(8);
            let routes = random_routes(g, n_stages);
            let oracle = simulate_reference(n_stages, &routes);

            ws.routes.clear();
            for r in &routes {
                ws.routes.push_route(r);
            }
            let makespan = ws.run(n_stages, true);

            let mut ok = makespan.to_bits() == oracle.makespan.to_bits()
                && ws.stage_busy().len() == oracle.stage_busy.len()
                && ws
                    .stage_busy()
                    .iter()
                    .zip(&oracle.stage_busy)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
                && ws.timeline().len() == oracle.timeline.len();
            if ok {
                let mut a: Vec<OpRecord> = ws.timeline().to_vec();
                let mut b = oracle.timeline.clone();
                a.sort_by_key(timeline_key);
                b.sort_by_key(timeline_key);
                ok = a
                    .iter()
                    .zip(&b)
                    .all(|(x, y)| {
                        timeline_key(x) == timeline_key(y)
                            && x.start.to_bits() == y.start.to_bits()
                            && x.finish.to_bits() == y.finish.to_bits()
                    });
            }
            (
                format!(
                    "n_stages={n_stages} n_routes={} makespan={makespan} oracle={}",
                    routes.len(),
                    oracle.makespan
                ),
                ok,
            )
        });
    }

    #[test]
    fn workspace_reuse_matches_fresh_workspace() {
        // Stale-state guard: interleave differently-sized workloads
        // through one workspace and check each against a fresh one.
        let workloads: Vec<(usize, Vec<Route>)> = vec![
            (16, uniform(16, 24, 1.0, 2.0)),
            (2, uniform(2, 3, 0.5, 1.5)),
            (16, uniform(16, 24, 1.0, 2.0)),
            (4, {
                let mut r = uniform(4, 8, 1.0, 2.0);
                r[5].fwd[2] = 9.0;
                r
            }),
            (3, vec![]),
            (16, uniform(16, 24, 1.0, 2.0)),
        ];
        let mut reused = SimWorkspace::new();
        for (n_stages, routes) in &workloads {
            reused.routes.clear();
            for r in routes {
                reused.routes.push_route(r);
            }
            let makespan = reused.run(*n_stages, true);
            let fresh = simulate(*n_stages, routes);
            assert_eq!(makespan.to_bits(), fresh.makespan.to_bits());
            assert_eq!(reused.stage_busy().len(), fresh.stage_busy.len());
            for (a, b) in reused.stage_busy().iter().zip(&fresh.stage_busy) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(reused.timeline(), &fresh.timeline[..]);
        }
    }

    #[test]
    fn skipping_timeline_changes_nothing_else() {
        let routes = uniform(4, 8, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &routes {
            ws.routes.push_route(r);
        }
        let with = ws.run(4, true);
        let n_records = ws.timeline().len();
        let busy: Vec<u64> = ws.stage_busy().iter().map(|b| b.to_bits()).collect();
        ws.routes.clear();
        for r in &routes {
            ws.routes.push_route(r);
        }
        let without = ws.run(4, false);
        assert_eq!(with.to_bits(), without.to_bits());
        assert!(n_records > 0);
        assert!(ws.timeline().is_empty());
        let busy2: Vec<u64> = ws.stage_busy().iter().map(|b| b.to_bits()).collect();
        assert_eq!(busy, busy2);
    }

    /// Assert the workspace's last run bit-matches a fresh full simulation
    /// of `routes` (makespan + per-stage busy).
    fn assert_matches_fresh(ws: &SimWorkspace, n_stages: usize, routes: &[Route]) -> bool {
        let fresh = simulate(n_stages, routes);
        ws.makespan().to_bits() == fresh.makespan.to_bits()
            && ws.stage_busy().len() == fresh.stage_busy.len()
            && ws
                .stage_busy()
                .iter()
                .zip(&fresh.stage_busy)
                .all(|(a, b)| a.to_bits() == b.to_bits())
    }

    #[test]
    fn delta_run_matches_full_run_bitwise() {
        // The delta contract: after any sequence of single- and
        // multi-bucket cost edits, delta_run reproduces a from-scratch
        // full simulation of the edited routes bit-for-bit — makespan and
        // per-stage busy. One workspace is reused across cases, and each
        // case chains several edit rounds so a stale dirty flag or finish
        // entry from round k poisons round k+1.
        let mut ws = SimWorkspace::new();
        forall("delta re-sim = full re-sim", 120, |g| {
            let n_stages = g.size(8);
            let mut routes = random_routes(g, n_stages);
            ws.routes.clear();
            for r in &routes {
                ws.routes.push_route(r);
            }
            ws.run_tracked(n_stages);
            let mut ok = assert_matches_fresh(&ws, n_stages, &routes);
            let mut edits = 0usize;
            for _round in 0..4 {
                if routes.is_empty() || !ok {
                    break;
                }
                // 1..=3 random bucket edits per round (possibly the same
                // bucket twice — the dirty set must be idempotent).
                let n_edits = g.size(3);
                for _ in 0..n_edits {
                    let b = g.rng.below(routes.len() as u64) as usize;
                    if routes[b].depth() == 0 {
                        continue;
                    }
                    let pos = g.rng.below(routes[b].depth() as u64) as usize;
                    let fwd = g.rng.uniform(0.1, 3.0);
                    let bwd = g.rng.uniform(0.1, 5.0);
                    routes[b].fwd[pos] = fwd;
                    routes[b].bwd[pos] = bwd;
                    ws.update_leg(b, pos, fwd, bwd);
                    edits += 1;
                }
                ws.delta_run(n_stages);
                ok = assert_matches_fresh(&ws, n_stages, &routes);
            }
            (
                format!(
                    "n_stages={n_stages} n_routes={} edits={edits} makespan={}",
                    routes.len(),
                    ws.makespan()
                ),
                ok,
            )
        });
    }

    #[test]
    fn delta_frontier_reaches_stage_zero() {
        // Edit bucket 0's first leg: the dirty frontier starts at stage 0
        // and every downstream op must replay correctly.
        let mut routes = uniform(6, 10, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &routes {
            ws.routes.push_route(r);
        }
        ws.run_tracked(6);
        routes[0].fwd[0] = 7.5;
        routes[0].bwd[0] = 0.25;
        ws.update_leg(0, 0, 7.5, 0.25);
        ws.delta_run(6);
        assert!(assert_matches_fresh(&ws, 6, &routes));
    }

    #[test]
    fn delta_run_without_edits_is_a_no_op() {
        let routes = uniform(4, 8, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &routes {
            ws.routes.push_route(r);
        }
        let full = ws.run_tracked(4);
        let again = ws.delta_run(4);
        assert_eq!(full.to_bits(), again.to_bits());
        assert!(assert_matches_fresh(&ws, 4, &routes));
    }

    #[test]
    fn delta_run_falls_back_on_structure_or_stage_change() {
        // Route rebuild bumps the structure generation → full path.
        let first = uniform(4, 6, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &first {
            ws.routes.push_route(r);
        }
        ws.run_tracked(4);
        let second = uniform(5, 9, 0.7, 1.9);
        ws.routes.clear();
        for r in &second {
            ws.routes.push_route(r);
        }
        ws.delta_run(5);
        assert!(assert_matches_fresh(&ws, 5, &second));
        // Same routes, different stage count (extra idle stage) → full
        // path via the tracked_stages mismatch.
        ws.delta_run(7);
        assert!(assert_matches_fresh(&ws, 7, &second));
        // An untracked run() disarms replay; delta_run self-heals.
        ws.run(7, false);
        ws.delta_run(7);
        assert!(assert_matches_fresh(&ws, 7, &second));
    }

    #[test]
    fn repeated_deltas_keep_the_record_valid() {
        // Many successive single-bucket edits over one tracked record —
        // the replay must stay exact without re-tracking in between.
        let mut routes = uniform(8, 16, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &routes {
            ws.routes.push_route(r);
        }
        ws.run_tracked(8);
        for k in 0..32 {
            let b = (k * 7) % routes.len();
            let pos = (k * 3) % routes[b].depth();
            let fwd = 0.5 + 0.13 * k as f64;
            let bwd = 1.5 + 0.07 * k as f64;
            routes[b].fwd[pos] = fwd;
            routes[b].bwd[pos] = bwd;
            ws.update_leg(b, pos, fwd, bwd);
            ws.delta_run(8);
            assert!(assert_matches_fresh(&ws, 8, &routes), "edit {k}");
        }
    }

    #[test]
    fn duration_dependent_edits_force_bit_exact_full_replay() {
        // Satellite: the bubble-fill hardening contract. A bubble-filling
        // pass rewrites leg costs *derived from the previous run's measured
        // schedule* and declares it via mark_duration_dependent(); after
        // that, delta_run must reproduce a from-scratch simulation of the
        // edited routes bit-for-bit by conservatively abandoning the stale
        // record. Randomized edit streams mimic the pass: forward legs
        // shrink by a duration-derived fraction, several buckets per round,
        // interleaved with ordinary exogenous edits so the record's
        // re-arming after each fallback is exercised too.
        let mut ws = SimWorkspace::new();
        forall("duration-dependent delta = fresh full sim", 100, |g| {
            let n_stages = g.size(8);
            let mut routes = random_routes(g, n_stages);
            ws.routes.clear();
            for r in &routes {
                ws.routes.push_route(r);
            }
            ws.run_tracked(n_stages);
            let mut ok = assert_matches_fresh(&ws, n_stages, &routes);
            let mut edits = 0usize;
            for round in 0..4 {
                if routes.is_empty() || !ok {
                    break;
                }
                let n_edits = g.size(3);
                for _ in 0..n_edits {
                    let b = g.rng.below(routes.len() as u64) as usize;
                    if routes[b].depth() == 0 {
                        continue;
                    }
                    let pos = g.rng.below(routes[b].depth() as u64) as usize;
                    // Bubble-fill shape: shrink the forward leg by a
                    // fraction of its *current* (measured) duration.
                    let fwd = routes[b].fwd[pos] * (1.0 - g.rng.uniform(0.1, 0.9));
                    let bwd = routes[b].bwd[pos];
                    routes[b].fwd[pos] = fwd;
                    ws.update_leg(b, pos, fwd, bwd);
                    edits += 1;
                }
                if round % 2 == 0 {
                    ws.mark_duration_dependent();
                }
                ws.delta_run(n_stages);
                ok = assert_matches_fresh(&ws, n_stages, &routes);
            }
            (
                format!(
                    "n_stages={n_stages} n_routes={} edits={edits} makespan={}",
                    routes.len(),
                    ws.makespan()
                ),
                ok,
            )
        });
    }

    #[test]
    fn record_fill_charges_busy_and_keeps_the_ledger() {
        let routes = uniform(3, 4, 1.0, 2.0);
        let mut ws = SimWorkspace::new();
        for r in &routes {
            ws.routes.push_route(r);
        }
        ws.run(3, true);
        let busy0 = ws.stage_busy()[2];
        ws.record_fill(1, 2, 0.0, 0.5);
        assert_eq!(ws.fills, vec![FillOp { bucket: 1, stage: 2, start: 0.0, finish: 0.5 }]);
        assert_eq!(ws.stage_busy()[2].to_bits(), (busy0 + 0.5).to_bits());
        assert_eq!(ws.fills[0].dur(), 0.5);
        // Any run clears the ledger.
        ws.routes.clear();
        for r in &routes {
            ws.routes.push_route(r);
        }
        ws.run(3, false);
        assert!(ws.fills.is_empty());
    }
}
