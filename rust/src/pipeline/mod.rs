//! 1F1B pipeline execution simulation: the generic engine, the
//! cluster-level builder (heterogeneous encoder/LLM pipelines with the
//! Inter-model Communicator), and iteration statistics.
pub mod build;
pub mod sim;

pub use build::{iterate, IterationStats, SystemPlan};
pub use sim::{ideal_bubble_fraction, simulate, OpRecord, PipelineResult, Route};
