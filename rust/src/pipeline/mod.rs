//! 1F1B pipeline execution simulation: the generic engine, the
//! cluster-level builder (heterogeneous encoder/LLM pipelines with the
//! Inter-model Communicator), and iteration statistics.
pub mod build;
pub mod sim;

pub use build::{iterate, iterate_ws, IterationStats, SystemPlan};
pub use sim::{
    ideal_bubble_fraction, simulate, simulate_reference, OpRecord, PipelineResult, Route,
    RouteSet, SimWorkspace,
};
