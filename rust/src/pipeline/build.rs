//! Cluster-level iteration assembly: turns a parallel plan θ plus a
//! scheduled bucket partition into physical pipeline routes, runs the 1F1B
//! engine, and accounts for the Inter-model Communicator and data-parallel
//! gradient synchronization.
//!
//! Physical stage layout (ids into the 1F1B engine):
//!
//! ```text
//! enc pipeline e ∈ [0, E_dp):  stages e·E_pp … e·E_pp + E_pp − 1
//! llm pipeline g ∈ [0, L_dp):  stages E_dp·E_pp + g·L_pp … + L_pp − 1
//! ```
//!
//! Bucket `j` is served by encoder pipeline `j mod E_dp` and LLM pipeline
//! `j mod L_dp` — when `E_dp ≠ L_dp` the hop between them crosses
//! data-parallel groups and is charged the Inter-model Communicator's
//! gather+scatter cost (Fig 6); when the groups match it is a plain
//! pipeline-parallel point-to-point send.

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::obs::bubble::{stage_bubbles, Gap};
use crate::obs::critical::{critical_path, op_slack};
use crate::optimizer::plan::Theta;
use crate::perfmodel::Truth;
use crate::pipeline::sim::{FillOp, OpRecord, SimWorkspace};
use crate::stream::window::ShapeStats;

/// A system's execution plan for one iteration: the strategy plus the
/// scheduled bucket contents.
#[derive(Clone, Debug)]
pub struct SystemPlan<'a> {
    pub m: &'a Mllm,
    pub truth: &'a Truth,
    pub theta: Theta,
}

/// Per-bucket measured execution (for Adaptive Correction feedback and the
/// Fig 4 / Fig 14 distributions).
#[derive(Clone, Copy, Debug)]
pub struct BucketExec {
    /// Total encoder-module time (all E_pp stages).
    pub enc_time: f64,
    /// Total LLM-module time (all L_pp stages).
    pub llm_time: f64,
    pub enc_flop: f64,
    pub llm_flop: f64,
    /// Shape bucket of the packed LLM total (Adaptive Correction key).
    pub llm_shape_bucket: u64,
}

/// Everything one simulated training iteration produces.
#[derive(Clone, Debug)]
pub struct IterationStats {
    /// End-to-end iteration time: pipeline makespan + DP gradient sync.
    pub iteration_time: f64,
    pub pipeline_makespan: f64,
    pub dp_sync_time: f64,
    /// Per physical stage.
    pub stage_busy: Vec<f64>,
    pub stage_idle: Vec<f64>,
    pub stage_flop: Vec<f64>,
    pub n_stages: usize,
    pub total_flop: f64,
    pub buckets: Vec<BucketExec>,
    pub timeline: Vec<OpRecord>,
    /// Encoder sub-ops the bubble-filling pass placed into other stages'
    /// idle gaps ([`iterate_interleaved`]; empty on every other execution
    /// path). The placed work is charged into the host stage's
    /// `stage_busy` (so `stage_idle` reports true idle), but deliberately
    /// kept out of `timeline` — the chain timeline stays
    /// one-record-per-(bucket, stage, direction) for the critical-path op
    /// index — and `stage_flop` is *not* re-attributed (total FLOP is
    /// conserved; per-stage FLOP keeps the plan's static layout).
    pub fills: Vec<FillOp>,
}

impl IterationStats {
    /// Aggregate GPU-seconds of idle time attributable to pipeline bubbles
    /// (Fig 13's metric), summed over stages.
    pub fn total_idle(&self) -> f64 {
        self.stage_idle.iter().sum()
    }

    /// Total encoder work re-placed into bubbles by the bubble-filling
    /// pass (0.0 on non-interleaved paths).
    pub fn filled_time(&self) -> f64 {
        self.fills.iter().map(FillOp::dur).sum()
    }

    /// Achieved cluster throughput in FLOP/s for this iteration.
    pub fn cluster_throughput(&self) -> f64 {
        self.total_flop / self.iteration_time
    }

    /// Per-stage achieved throughput (stage FLOP over busy time) — the
    /// Fig 14 distribution. Stages with no work are skipped.
    pub fn stage_throughputs(&self) -> Vec<f64> {
        self.stage_flop
            .iter()
            .zip(&self.stage_busy)
            .filter(|(f, b)| **f > 0.0 && **b > 0.0)
            .map(|(f, b)| f / b)
            .collect()
    }
}

/// The Inter-model Communicator's transfer time for one bucket's encoder
/// activations (Fig 6). Matching DP groups reduce to a pipeline P2P send;
/// mismatched groups pay gather + scatter through the designated
/// communicator rank.
fn communicator_time(plan: &SystemPlan, act_bytes: f64) -> f64 {
    let c = &plan.truth.cluster;
    // Cross-module hops leave the TP island: inter-node unless the whole
    // deployment fits one node.
    let cross_node = plan.theta.enc.gpus() + plan.theta.llm.gpus() > c.gpus_per_node;
    if plan.theta.enc.dp == plan.theta.llm.dp {
        c.p2p_time(act_bytes, !cross_node)
    } else {
        // Gather onto the communicator rank, scatter to the target group.
        2.0 * c.p2p_time(act_bytes, !cross_node) + c.nvlink_latency
    }
}

/// Simulate one training iteration of `plan` over the scheduled buckets.
///
/// `buckets[j]` holds the item shapes assigned to bucket j by the
/// scheduler (DFLOP) or the random partitioner (baselines).
///
/// One-shot convenience over [`iterate_ws`]: allocates a fresh
/// [`SimWorkspace`] per call. Per-iteration loops (the trainer, sweeps)
/// should hold a workspace and call [`iterate_ws`] instead.
pub fn iterate(plan: &SystemPlan, buckets: &[Vec<ItemShape>]) -> IterationStats {
    iterate_ws(plan, buckets, &mut SimWorkspace::new())
}

/// [`iterate`] against a caller-owned simulation workspace: routes build
/// into the workspace's arena and the 1F1B engine runs allocation-free in
/// steady state (one workspace per worker — see [`SimWorkspace`]).
pub fn iterate_ws(
    plan: &SystemPlan,
    buckets: &[Vec<ItemShape>],
    ws: &mut SimWorkspace,
) -> IterationStats {
    let built = build_routes(plan, buckets, ws);
    let pipeline_makespan = ws.run(built.n_stages, true);
    assemble(built, ws, pipeline_makespan)
}

/// The first encoder leg of one bucket's route: the decomposition source
/// the bubble-filling pass offloads from. Its forward op has no
/// dependency (inputs are host-resident at t = 0), so sub-ops split from
/// it are placeable into any bubble that closes before the consumer —
/// the op at route position 1 — starts.
#[derive(Clone, Copy, Debug)]
struct EncHead {
    /// Stage hosting the leg (`enc_stage(e, 0)`).
    stage: usize,
    /// Stage of the route's position-1 op (every route has depth ≥ 2:
    /// `e_pp` encoder legs followed by `l_pp` LLM legs).
    consumer_stage: usize,
    /// Forward / backward cost of the leg as built.
    fwd: f64,
    bwd: f64,
}

/// Everything [`build_routes`] produces besides the routes themselves
/// (which live in the workspace arena).
struct BuiltRoutes {
    n_stages: usize,
    /// Stages `[0, enc_stages)` host encoder pipeline legs; the rest are
    /// LLM stages (the module-docs layout).
    enc_stages: usize,
    stage_flop: Vec<f64>,
    total_flop: f64,
    bucket_exec: Vec<BucketExec>,
    dp_sync: f64,
    /// Per bucket, aligned with `buckets`.
    enc_head: Vec<EncHead>,
}

/// Translate θ plus scheduled buckets into routes in the workspace arena
/// (shared by the plain and bubble-filling execution paths).
fn build_routes(
    plan: &SystemPlan,
    buckets: &[Vec<ItemShape>],
    ws: &mut SimWorkspace,
) -> BuiltRoutes {
    let th = plan.theta;
    let (e_pp, e_dp) = (th.enc.pp, th.enc.dp);
    let (l_pp, l_dp) = (th.llm.pp, th.llm.dp);
    let n_stages = e_dp * e_pp + l_dp * l_pp;
    let enc_stage = |e: usize, s: usize| e * e_pp + s;
    let llm_stage = |g: usize, s: usize| e_dp * e_pp + g * l_pp + s;

    let e_layers = plan.m.encoder.layers as f64 / e_pp as f64;
    let l_layers = plan.m.llm.layers as f64 / l_pp as f64;

    ws.routes.clear();
    let mut bucket_exec = Vec::with_capacity(buckets.len());
    let mut enc_head = Vec::with_capacity(buckets.len());
    let mut stage_flop = vec![0.0f64; n_stages];
    let mut total_flop = 0.0f64;

    for (j, items) in buckets.iter().enumerate() {
        let e = j % e_dp;
        let g = j % l_dp;
        let units: f64 = items.iter().map(|i| i.units as f64).sum();
        ws.seqs.clear();
        ws.seqs
            .extend(items.iter().filter(|i| i.llm_seq > 0).map(|i| i.llm_seq as f64));
        let total_seq: f64 = ws.seqs.iter().sum();

        // Per-stage ground-truth durations (fwd = 1/3, bwd = 2/3 of total).
        let enc_t = plan.truth.encoder_stage_time(plan.m, units, e_layers, th.enc.tp);
        let llm_t = plan.truth.llm_stage_time(plan.m, &ws.seqs, l_layers, th.llm.tp);

        // FLOP accounting for throughput/idle reporting.
        let enc_flop: f64 = items.iter().map(|i| i.encoder_flop(plan.m)).sum();
        let llm_flop: f64 = items.iter().map(|i| i.llm_flop(plan.m)).sum();
        total_flop += enc_flop + llm_flop;

        // Communication hops.
        let c = &plan.truth.cluster;
        let enc_act_bytes =
            units * plan.m.tokens_per_unit as f64 * plan.m.encoder.hidden as f64 * 2.0
                / th.enc.tp as f64;
        let llm_act_bytes =
            total_seq * plan.m.llm.hidden as f64 * 2.0 / th.llm.tp as f64;
        let pp_hop_enc = c.p2p_time(enc_act_bytes, true);
        let pp_hop_llm = c.p2p_time(llm_act_bytes, true);
        let comm_hop = communicator_time(plan, enc_act_bytes);

        for s in 0..e_pp {
            ws.routes.push_leg(
                enc_stage(e, s),
                enc_t / 3.0,
                enc_t * 2.0 / 3.0,
                if s == 0 { 0.0 } else { pp_hop_enc },
            );
            stage_flop[enc_stage(e, s)] += enc_flop / e_pp as f64;
        }
        for s in 0..l_pp {
            ws.routes.push_leg(
                llm_stage(g, s),
                llm_t / 3.0,
                llm_t * 2.0 / 3.0,
                if s == 0 { comm_hop } else { pp_hop_llm },
            );
            stage_flop[llm_stage(g, s)] += llm_flop / l_pp as f64;
        }
        ws.routes.end_route();
        bucket_exec.push(BucketExec {
            enc_time: enc_t * e_pp as f64,
            llm_time: llm_t * l_pp as f64,
            enc_flop,
            llm_flop,
            llm_shape_bucket: Truth::llm_bucket(total_seq),
        });
        enc_head.push(EncHead {
            stage: enc_stage(e, 0),
            consumer_stage: if e_pp > 1 { enc_stage(e, 1) } else { llm_stage(g, 0) },
            fwd: enc_t / 3.0,
            bwd: enc_t * 2.0 / 3.0,
        });
    }

    // ---- data-parallel gradient synchronization (straggler-inclusive:
    // the all-reduce starts only after the slowest pipeline drains, which
    // is exactly the simulated makespan) ----
    let enc_grad_bytes = plan.m.encoder.total_params(plan.m.enc_mlp_matrices) * 2.0
        / (th.enc.tp * th.enc.pp) as f64;
    let llm_grad_bytes = plan.m.llm.total_params(plan.m.llm_mlp_matrices) * 2.0
        / (th.llm.tp * th.llm.pp) as f64;
    let dp_sync = plan
        .truth
        .dp_allreduce_time(enc_grad_bytes, e_dp)
        .max(plan.truth.dp_allreduce_time(llm_grad_bytes, l_dp));

    BuiltRoutes {
        n_stages,
        enc_stages: e_dp * e_pp,
        stage_flop,
        total_flop,
        bucket_exec,
        dp_sync,
        enc_head,
    }
}

/// Package the workspace's last run into [`IterationStats`].
fn assemble(built: BuiltRoutes, ws: &SimWorkspace, pipeline_makespan: f64) -> IterationStats {
    IterationStats {
        iteration_time: pipeline_makespan + built.dp_sync,
        pipeline_makespan,
        dp_sync_time: built.dp_sync,
        stage_busy: ws.stage_busy().to_vec(),
        stage_idle: ws.stage_busy().iter().map(|&b| pipeline_makespan - b).collect(),
        stage_flop: built.stage_flop,
        n_stages: built.n_stages,
        total_flop: built.total_flop,
        buckets: built.bucket_exec,
        timeline: ws.timeline().to_vec(),
        fills: ws.fills.clone(),
    }
}

/// Unit-granularity cap: one bucket's first encoder leg splits into at
/// most this many equal sub-ops (chunk count = encoder units, capped).
const MAX_SUBOPS: usize = 64;
/// Fraction of the leg that may be offloaded into bubbles; the residual
/// models the dispatch/launch work that cannot leave the home stage.
const MAX_OFFLOAD_FRAC: f64 = 0.9;
/// Safety cap on the place-or-drop refinement loop. Each failed round
/// strictly shrinks the offload set, so termination never relies on it.
const MAX_FILL_ROUNDS: usize = 8;

/// One bucket's offload decision: `take` equal chunks of `chunk` seconds
/// leave the first encoder leg (total `delta`).
#[derive(Clone, Copy, Debug)]
struct Offload {
    bucket: usize,
    take: usize,
    chunk: f64,
    delta: f64,
}

/// Bubble-filling interleaved execution of one iteration
/// (`SystemKind::DflopInterleaved`): run the plain 1F1B schedule, then
/// decompose each microbatch's first encoder leg into unit-granularity
/// sub-ops — driven by the same per-microbatch [`ShapeStats`] the stream
/// subsystem tracks — and pack them into the LLM stages' idle gaps
/// (warm-up, steady-state, and drain bubbles alike).
///
/// Mechanics, two passes over the event core:
///
/// 1. **Measure.** Run the plain schedule; `obs::critical::op_slack`
///    gates which encoder head legs are worth offloading (slack ≥ own
///    duration ⇒ off-critical, skipped) and `critical_path`'s modality
///    blame gates the pass as a whole (no encoder seconds on the chain ⇒
///    nothing to win).
/// 2. **Shrink & place.** Shrink the chosen legs by their offloaded
///    share (`update_leg` + [`SimWorkspace::mark_duration_dependent`] —
///    the edits are duration-derived, so delta replays must not trust
///    the old record), re-run, and place each bucket's sub-ops
///    earliest-deadline-first into the *new* schedule's idle gaps
///    (`obs::bubble::stage_bubbles` on LLM stages), deadline = the
///    bucket's route-position-1 op start (sub-op results must be
///    gathered before the consumer starts; the sub-op duration includes
///    its return transfer). Buckets whose sub-ops do not all fit are
///    dropped from the offload set and the pass repeats; if no
///    improving, fully-placed set remains, the iteration falls back to
///    the plain schedule bit-for-bit.
///
/// Placed sub-ops are charged into the host stage's busy time and
/// reported in [`IterationStats::fills`]; total work is conserved, the
/// makespan strictly drops whenever fills are reported.
pub fn iterate_interleaved(
    plan: &SystemPlan,
    buckets: &[Vec<ItemShape>],
    ws: &mut SimWorkspace,
) -> IterationStats {
    let built = build_routes(plan, buckets, ws);
    let n_stages = built.n_stages;
    let baseline = ws.run(n_stages, true);
    if baseline <= 0.0 {
        return assemble(built, ws, baseline);
    }

    // ---- pass 1: measure — is encoder work on the critical chain, and
    // which head legs are tight enough that shrinking them can move it?
    let enc_blame = match critical_path(ws.timeline(), n_stages, baseline) {
        Some(cp) => cp.modality_blame(built.enc_stages).0,
        None => 0.0,
    };
    if enc_blame <= 0.0 {
        return assemble(built, ws, baseline);
    }
    let mut head_slack = vec![f64::INFINITY; buckets.len()];
    for o in op_slack(ws.timeline(), n_stages, baseline) {
        if o.is_forward
            && o.bucket < built.enc_head.len()
            && o.stage == built.enc_head[o.bucket].stage
        {
            head_slack[o.bucket] = o.slack;
        }
    }

    let mut active: Vec<Offload> = Vec::new();
    for (j, items) in buckets.iter().enumerate() {
        let head = built.enc_head[j];
        if head.fwd <= 0.0 || head_slack[j] >= head.fwd {
            continue;
        }
        // Decomposition granularity from the microbatch's shape stats:
        // one sub-op per encoder unit (tile / frame / audio-second),
        // capped — the per-unit share of the leg is the schedulable
        // quantum.
        let st = ShapeStats::of_batch(items);
        let n_chunks = (st.units_sum as usize).clamp(1, MAX_SUBOPS);
        let take = (n_chunks as f64 * MAX_OFFLOAD_FRAC) as usize;
        if take == 0 {
            continue;
        }
        let chunk = head.fwd / n_chunks as f64;
        active.push(Offload { bucket: j, take, chunk, delta: chunk * take as f64 });
    }

    // ---- pass 2 (iterated): shrink, re-run, place or drop ----
    for _round in 0..MAX_FILL_ROUNDS {
        if active.is_empty() {
            break;
        }
        for o in &active {
            let h = built.enc_head[o.bucket];
            ws.update_leg(o.bucket, 0, h.fwd - o.delta, h.bwd);
        }
        ws.mark_duration_dependent();
        let makespan = ws.run(n_stages, true);
        let placed = if makespan < baseline {
            place_fills(ws.timeline(), n_stages, makespan, ws.stage_busy(), &built, &active)
        } else {
            // Shrinking did not move the makespan — the bubbles were not
            // binding after all; give the whole offload back.
            Err(active.iter().map(|o| o.bucket).collect())
        };
        match placed {
            Ok(fills) => {
                for &(bucket, stage, start, dur) in &fills {
                    ws.record_fill(bucket, stage, start, dur);
                }
                return assemble(built, ws, makespan);
            }
            Err(failed) => {
                for o in &active {
                    let h = built.enc_head[o.bucket];
                    ws.update_leg(o.bucket, 0, h.fwd, h.bwd);
                }
                active.retain(|o| !failed.contains(&o.bucket));
            }
        }
    }

    // Nothing could be placed: plain schedule, bit-for-bit.
    let makespan = ws.run(n_stages, true);
    assemble(built, ws, makespan)
}

/// Earliest-deadline-first packing of the active offloads' sub-ops into
/// the schedule's LLM-stage idle gaps. Pure: validates against the given
/// timeline only. `Ok` carries every placement as
/// `(bucket, host stage, start, duration)`; `Err` carries the buckets
/// whose sub-ops did not all fit (their placements are rolled back, so a
/// failed bucket consumes no gap capacity).
fn place_fills(
    timeline: &[OpRecord],
    n_stages: usize,
    makespan: f64,
    stage_busy: &[f64],
    built: &BuiltRoutes,
    active: &[Offload],
) -> Result<Vec<(usize, usize, f64, f64)>, Vec<usize>> {
    // Deadline per bucket: its consumer op's start in *this* schedule.
    let mut deadline = vec![f64::INFINITY; built.enc_head.len()];
    for op in timeline {
        if op.is_forward
            && op.bucket < built.enc_head.len()
            && op.stage == built.enc_head[op.bucket].consumer_stage
        {
            deadline[op.bucket] = op.start;
        }
    }

    // Slot list: idle gaps on LLM stages, earliest-opening first.
    let sb = stage_bubbles(timeline, n_stages, makespan, stage_busy);
    let mut slots: Vec<Gap> = sb
        .gaps
        .into_iter()
        .filter(|g| g.stage >= built.enc_stages && !g.is_empty())
        .collect();
    slots.sort_by(|a, b| {
        a.start.partial_cmp(&b.start).expect("finite gap times").then(a.stage.cmp(&b.stage))
    });
    let mut cursor: Vec<f64> = slots.iter().map(|g| g.start).collect();

    let mut order: Vec<usize> = (0..active.len()).collect();
    order.sort_by(|&a, &b| {
        deadline[active[a].bucket]
            .partial_cmp(&deadline[active[b].bucket])
            .expect("finite deadlines")
            .then(active[a].bucket.cmp(&active[b].bucket))
    });

    let mut placed = Vec::new();
    let mut failed = Vec::new();
    for &oi in &order {
        let o = &active[oi];
        let dl = deadline[o.bucket];
        let mark = placed.len();
        let snapshot = cursor.clone();
        let mut ok = true;
        'chunks: for _ in 0..o.take {
            for (k, g) in slots.iter().enumerate() {
                let end = cursor[k] + o.chunk;
                if end <= g.end && end <= dl {
                    placed.push((o.bucket, g.stage, cursor[k], o.chunk));
                    cursor[k] = end;
                    continue 'chunks;
                }
            }
            ok = false;
            break;
        }
        if !ok {
            placed.truncate(mark);
            cursor = snapshot;
            failed.push(o.bucket);
        }
    }
    if failed.is_empty() {
        Ok(placed)
    } else {
        Err(failed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{internvl_25, llava_ov, llama3, qwen25};
    use crate::obs::bubble::iteration_bubble_fraction;
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::ClusterSpec;

    fn fixture() -> (Mllm, Truth) {
        (llava_ov(llama3("8b")), Truth::smooth(ClusterSpec::hgx_a100(1)))
    }

    fn theta(e_dp: usize, l_dp: usize, l_pp: usize, n_mb: usize) -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: e_dp },
            llm: ModPar { tp: 1, pp: l_pp, dp: l_dp },
            n_mb,
        }
    }

    fn make_buckets(m: &Mllm, n_buckets: usize, per_bucket: usize) -> Vec<Vec<ItemShape>> {
        let mut ds = Dataset::mixed(99);
        (0..n_buckets)
            .map(|_| ds.shaped_batch(m, per_bucket))
            .collect()
    }

    #[test]
    fn iteration_produces_consistent_accounting() {
        let (m, truth) = fixture();
        let th = theta(2, 2, 3, 4);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let buckets = make_buckets(&m, th.buckets(), 4);
        let stats = iterate(&plan, &buckets);
        assert!(stats.iteration_time > 0.0);
        assert!(stats.pipeline_makespan <= stats.iteration_time);
        assert_eq!(stats.n_stages, 2 * 1 + 2 * 3);
        assert_eq!(stats.stage_busy.len(), stats.n_stages);
        // FLOP conservation: stage FLOP sums to total FLOP.
        let sum: f64 = stats.stage_flop.iter().sum();
        assert!((sum / stats.total_flop - 1.0).abs() < 1e-9);
        // Idle = makespan − busy per stage.
        for s in 0..stats.n_stages {
            assert!(
                (stats.stage_idle[s] - (stats.pipeline_makespan - stats.stage_busy[s]))
                    .abs()
                    < 1e-9
            );
        }
    }

    #[test]
    fn balanced_buckets_idle_less_than_skewed() {
        let (m, truth) = fixture();
        let th = theta(1, 1, 3, 8);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        // Build one balanced and one skewed partition of the same items.
        let mut ds = Dataset::mixed(7);
        let items = ds.shaped_batch(&m, 32);
        let balanced: Vec<Vec<ItemShape>> = {
            // Greedy by LLM seq (a decent proxy for balance).
            let mut order: Vec<&ItemShape> = items.iter().collect();
            order.sort_by_key(|i| std::cmp::Reverse(i.llm_seq));
            let mut bks: Vec<Vec<ItemShape>> = vec![Vec::new(); 8];
            let mut loads = vec![0u64; 8];
            for it in order {
                let j = (0..8).min_by_key(|&j| loads[j]).expect("nonempty");
                loads[j] += it.llm_seq as u64;
                bks[j].push(*it);
            }
            bks
        };
        let skewed: Vec<Vec<ItemShape>> =
            items.chunks(4).map(|c| c.to_vec()).collect();
        let b = iterate(&plan, &balanced);
        let s = iterate(&plan, &skewed);
        assert!(
            b.total_idle() < s.total_idle(),
            "balanced idle {} skewed idle {}",
            b.total_idle(),
            s.total_idle()
        );
        assert!(b.iteration_time <= s.iteration_time + 1e-9);
    }

    #[test]
    fn dp_mismatch_charges_communicator() {
        let (m, truth) = fixture();
        // Same bucket contents; matched vs mismatched DP groups.
        let buckets = make_buckets(&m, 4, 2);
        let matched = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 2, 2) };
        let mismatched = SystemPlan { m: &m, truth: &truth, theta: theta(4, 2, 2, 2) };
        let t_match = iterate(&matched, &buckets);
        let t_mis = iterate(&mismatched, &buckets);
        assert!(t_match.iteration_time > 0.0);
        assert!(t_mis.iteration_time > 0.0);
        assert_eq!(t_mis.n_stages, 4 + 4);
    }

    #[test]
    fn empty_buckets_are_tolerated() {
        let (m, truth) = fixture();
        let th = theta(1, 1, 2, 4);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let mut buckets = make_buckets(&m, 3, 2);
        buckets.push(Vec::new());
        let stats = iterate(&plan, &buckets);
        assert!(stats.iteration_time.is_finite());
        assert_eq!(stats.buckets.len(), 4);
        assert_eq!(stats.buckets[3].enc_flop, 0.0);
    }

    #[test]
    fn iterate_ws_reuse_is_stateless() {
        // Interleaving differently-shaped iterations through one workspace
        // must reproduce the fresh-workspace results bit-for-bit.
        let (m, truth) = fixture();
        let big_plan = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 3, 4) };
        let small_plan = SystemPlan { m: &m, truth: &truth, theta: theta(1, 1, 2, 2) };
        let big = make_buckets(&m, big_plan.theta.buckets(), 4);
        let small = make_buckets(&m, small_plan.theta.buckets(), 2);
        let mut ws = SimWorkspace::new();
        let first = iterate_ws(&big_plan, &big, &mut ws);
        let _ = iterate_ws(&small_plan, &small, &mut ws);
        let again = iterate_ws(&big_plan, &big, &mut ws);
        let fresh = iterate(&big_plan, &big);
        for r in [&again, &fresh] {
            assert_eq!(
                first.iteration_time.to_bits(),
                r.iteration_time.to_bits()
            );
            assert_eq!(first.stage_busy.len(), r.stage_busy.len());
            for (a, b) in first.stage_busy.iter().zip(&r.stage_busy) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(first.timeline, r.timeline);
        }
    }

    #[test]
    fn interleaved_fills_bubbles_and_cuts_the_makespan() {
        // Encoder-dominant fixture: internvl's 6B encoder on one stage
        // against a 3-stage LLM pipeline, pure-video items. The fill pass
        // must place sub-ops, strictly cut the makespan and the bubble
        // fraction, conserve total busy work, and keep every fill inside
        // a legal slot (LLM stage, no overlap with the stage's ops or
        // other fills, done before the bucket's consumer starts).
        let m = internvl_25(qwen25("7b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let th = theta(1, 1, 3, 6);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let mut ds = Dataset::by_key("video", 11).expect("video dataset");
        let buckets: Vec<Vec<ItemShape>> =
            (0..th.buckets()).map(|_| ds.shaped_batch(&m, 4)).collect();

        let mut ws = SimWorkspace::new();
        let plain = iterate_ws(&plan, &buckets, &mut ws);
        let inter = iterate_interleaved(&plan, &buckets, &mut ws);

        assert!(!inter.fills.is_empty(), "no sub-ops placed");
        assert!(
            inter.pipeline_makespan < plain.pipeline_makespan,
            "interleaved {} !< plain {}",
            inter.pipeline_makespan,
            plain.pipeline_makespan
        );
        assert!(inter.iteration_time < plain.iteration_time);
        assert!(iteration_bubble_fraction(&inter) < iteration_bubble_fraction(&plain));

        // Work conservation: offloaded chunks are charged back into the
        // host stages' busy time.
        let pb: f64 = plain.stage_busy.iter().sum();
        let ib: f64 = inter.stage_busy.iter().sum();
        assert!((pb - ib).abs() <= 1e-9 * pb, "busy drifted: plain {pb} inter {ib}");
        assert!(inter.filled_time() > 0.0);

        // Fill legality against the interleaved schedule.
        let enc_stages = 1; // e_dp · e_pp
        let consumer_start = |j: usize| {
            inter
                .timeline
                .iter()
                .find(|o| o.bucket == j && o.stage == enc_stages && o.is_forward)
                .expect("consumer op")
                .start
        };
        for f in &inter.fills {
            assert!(f.stage >= enc_stages, "fill on encoder stage: {f:?}");
            assert!(f.start >= 0.0 && f.finish <= inter.pipeline_makespan + 1e-12);
            assert!(f.finish <= consumer_start(f.bucket) + 1e-12, "late fill {f:?}");
            for o in inter.timeline.iter().filter(|o| o.stage == f.stage) {
                assert!(
                    f.finish <= o.start + 1e-12 || o.finish <= f.start + 1e-12,
                    "fill {f:?} overlaps op {o:?}"
                );
            }
        }
        for s in enc_stages..inter.n_stages {
            let mut on_stage: Vec<_> =
                inter.fills.iter().filter(|f| f.stage == s).collect();
            on_stage.sort_by(|a, b| a.start.partial_cmp(&b.start).expect("finite"));
            for w in on_stage.windows(2) {
                assert!(w[1].start >= w[0].finish - 1e-12, "fills overlap on stage {s}");
            }
        }
    }

    #[test]
    fn interleaved_without_placeable_work_is_bit_identical_to_plain() {
        // Empty bucket set: the pass gates out immediately and the result
        // must be the plain path bit-for-bit, with an empty fill ledger.
        let (m, truth) = fixture();
        let th = theta(1, 1, 2, 4);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let empty: Vec<Vec<ItemShape>> = vec![Vec::new(); 4];
        let mut ws = SimWorkspace::new();
        let plain = iterate_ws(&plan, &empty, &mut ws);
        let inter = iterate_interleaved(&plan, &empty, &mut ws);
        assert!(inter.fills.is_empty());
        assert_eq!(plain.iteration_time.to_bits(), inter.iteration_time.to_bits());
        assert_eq!(plain.timeline, inter.timeline);
    }

    #[test]
    fn interleaved_reuse_is_stateless() {
        // A plain iteration after an interleaved one must be bit-identical
        // to a fresh-workspace plain iteration: the fill pass leaves no
        // residue (edited legs are rebuilt, the ledger is cleared).
        let m = internvl_25(qwen25("7b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let th = theta(1, 1, 3, 6);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let mut ds = Dataset::by_key("video", 23).expect("video dataset");
        let buckets: Vec<Vec<ItemShape>> =
            (0..th.buckets()).map(|_| ds.shaped_batch(&m, 4)).collect();
        let mut ws = SimWorkspace::new();
        let inter = iterate_interleaved(&plan, &buckets, &mut ws);
        assert!(!inter.fills.is_empty());
        let after = iterate_ws(&plan, &buckets, &mut ws);
        let fresh = iterate(&plan, &buckets);
        assert!(after.fills.is_empty());
        assert_eq!(after.iteration_time.to_bits(), fresh.iteration_time.to_bits());
        for (a, b) in after.stage_busy.iter().zip(&fresh.stage_busy) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(after.timeline, fresh.timeline);
    }

    #[test]
    fn dp_sync_positive_only_with_dp() {
        let (m, truth) = fixture();
        let single = SystemPlan { m: &m, truth: &truth, theta: theta(1, 1, 2, 2) };
        let multi = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 2, 2) };
        let buckets = make_buckets(&m, 2, 2);
        assert_eq!(iterate(&single, &buckets).dp_sync_time, 0.0);
        assert!(iterate(&multi, &buckets).dp_sync_time > 0.0);
    }
}
