//! Cluster-level iteration assembly: turns a parallel plan θ plus a
//! scheduled bucket partition into physical pipeline routes, runs the 1F1B
//! engine, and accounts for the Inter-model Communicator and data-parallel
//! gradient synchronization.
//!
//! Physical stage layout (ids into the 1F1B engine):
//!
//! ```text
//! enc pipeline e ∈ [0, E_dp):  stages e·E_pp … e·E_pp + E_pp − 1
//! llm pipeline g ∈ [0, L_dp):  stages E_dp·E_pp + g·L_pp … + L_pp − 1
//! ```
//!
//! Bucket `j` is served by encoder pipeline `j mod E_dp` and LLM pipeline
//! `j mod L_dp` — when `E_dp ≠ L_dp` the hop between them crosses
//! data-parallel groups and is charged the Inter-model Communicator's
//! gather+scatter cost (Fig 6); when the groups match it is a plain
//! pipeline-parallel point-to-point send.

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::plan::Theta;
use crate::perfmodel::Truth;
use crate::pipeline::sim::{OpRecord, SimWorkspace};

/// A system's execution plan for one iteration: the strategy plus the
/// scheduled bucket contents.
#[derive(Clone, Debug)]
pub struct SystemPlan<'a> {
    pub m: &'a Mllm,
    pub truth: &'a Truth,
    pub theta: Theta,
}

/// Per-bucket measured execution (for Adaptive Correction feedback and the
/// Fig 4 / Fig 14 distributions).
#[derive(Clone, Copy, Debug)]
pub struct BucketExec {
    /// Total encoder-module time (all E_pp stages).
    pub enc_time: f64,
    /// Total LLM-module time (all L_pp stages).
    pub llm_time: f64,
    pub enc_flop: f64,
    pub llm_flop: f64,
    /// Shape bucket of the packed LLM total (Adaptive Correction key).
    pub llm_shape_bucket: u64,
}

/// Everything one simulated training iteration produces.
#[derive(Clone, Debug)]
pub struct IterationStats {
    /// End-to-end iteration time: pipeline makespan + DP gradient sync.
    pub iteration_time: f64,
    pub pipeline_makespan: f64,
    pub dp_sync_time: f64,
    /// Per physical stage.
    pub stage_busy: Vec<f64>,
    pub stage_idle: Vec<f64>,
    pub stage_flop: Vec<f64>,
    pub n_stages: usize,
    pub total_flop: f64,
    pub buckets: Vec<BucketExec>,
    pub timeline: Vec<OpRecord>,
}

impl IterationStats {
    /// Aggregate GPU-seconds of idle time attributable to pipeline bubbles
    /// (Fig 13's metric), summed over stages.
    pub fn total_idle(&self) -> f64 {
        self.stage_idle.iter().sum()
    }

    /// Achieved cluster throughput in FLOP/s for this iteration.
    pub fn cluster_throughput(&self) -> f64 {
        self.total_flop / self.iteration_time
    }

    /// Per-stage achieved throughput (stage FLOP over busy time) — the
    /// Fig 14 distribution. Stages with no work are skipped.
    pub fn stage_throughputs(&self) -> Vec<f64> {
        self.stage_flop
            .iter()
            .zip(&self.stage_busy)
            .filter(|(f, b)| **f > 0.0 && **b > 0.0)
            .map(|(f, b)| f / b)
            .collect()
    }
}

/// The Inter-model Communicator's transfer time for one bucket's encoder
/// activations (Fig 6). Matching DP groups reduce to a pipeline P2P send;
/// mismatched groups pay gather + scatter through the designated
/// communicator rank.
fn communicator_time(plan: &SystemPlan, act_bytes: f64) -> f64 {
    let c = &plan.truth.cluster;
    // Cross-module hops leave the TP island: inter-node unless the whole
    // deployment fits one node.
    let cross_node = plan.theta.enc.gpus() + plan.theta.llm.gpus() > c.gpus_per_node;
    if plan.theta.enc.dp == plan.theta.llm.dp {
        c.p2p_time(act_bytes, !cross_node)
    } else {
        // Gather onto the communicator rank, scatter to the target group.
        2.0 * c.p2p_time(act_bytes, !cross_node) + c.nvlink_latency
    }
}

/// Simulate one training iteration of `plan` over the scheduled buckets.
///
/// `buckets[j]` holds the item shapes assigned to bucket j by the
/// scheduler (DFLOP) or the random partitioner (baselines).
///
/// One-shot convenience over [`iterate_ws`]: allocates a fresh
/// [`SimWorkspace`] per call. Per-iteration loops (the trainer, sweeps)
/// should hold a workspace and call [`iterate_ws`] instead.
pub fn iterate(plan: &SystemPlan, buckets: &[Vec<ItemShape>]) -> IterationStats {
    iterate_ws(plan, buckets, &mut SimWorkspace::new())
}

/// [`iterate`] against a caller-owned simulation workspace: routes build
/// into the workspace's arena and the 1F1B engine runs allocation-free in
/// steady state (one workspace per worker — see [`SimWorkspace`]).
pub fn iterate_ws(
    plan: &SystemPlan,
    buckets: &[Vec<ItemShape>],
    ws: &mut SimWorkspace,
) -> IterationStats {
    let th = plan.theta;
    let (e_pp, e_dp) = (th.enc.pp, th.enc.dp);
    let (l_pp, l_dp) = (th.llm.pp, th.llm.dp);
    let n_stages = e_dp * e_pp + l_dp * l_pp;
    let enc_stage = |e: usize, s: usize| e * e_pp + s;
    let llm_stage = |g: usize, s: usize| e_dp * e_pp + g * l_pp + s;

    let e_layers = plan.m.encoder.layers as f64 / e_pp as f64;
    let l_layers = plan.m.llm.layers as f64 / l_pp as f64;

    ws.routes.clear();
    let mut bucket_exec = Vec::with_capacity(buckets.len());
    let mut stage_flop = vec![0.0f64; n_stages];
    let mut total_flop = 0.0f64;

    for (j, items) in buckets.iter().enumerate() {
        let e = j % e_dp;
        let g = j % l_dp;
        let units: f64 = items.iter().map(|i| i.units as f64).sum();
        ws.seqs.clear();
        ws.seqs
            .extend(items.iter().filter(|i| i.llm_seq > 0).map(|i| i.llm_seq as f64));
        let total_seq: f64 = ws.seqs.iter().sum();

        // Per-stage ground-truth durations (fwd = 1/3, bwd = 2/3 of total).
        let enc_t = plan.truth.encoder_stage_time(plan.m, units, e_layers, th.enc.tp);
        let llm_t = plan.truth.llm_stage_time(plan.m, &ws.seqs, l_layers, th.llm.tp);

        // FLOP accounting for throughput/idle reporting.
        let enc_flop: f64 = items.iter().map(|i| i.encoder_flop(plan.m)).sum();
        let llm_flop: f64 = items.iter().map(|i| i.llm_flop(plan.m)).sum();
        total_flop += enc_flop + llm_flop;

        // Communication hops.
        let c = &plan.truth.cluster;
        let enc_act_bytes =
            units * plan.m.tokens_per_unit as f64 * plan.m.encoder.hidden as f64 * 2.0
                / th.enc.tp as f64;
        let llm_act_bytes =
            total_seq * plan.m.llm.hidden as f64 * 2.0 / th.llm.tp as f64;
        let pp_hop_enc = c.p2p_time(enc_act_bytes, true);
        let pp_hop_llm = c.p2p_time(llm_act_bytes, true);
        let comm_hop = communicator_time(plan, enc_act_bytes);

        for s in 0..e_pp {
            ws.routes.push_leg(
                enc_stage(e, s),
                enc_t / 3.0,
                enc_t * 2.0 / 3.0,
                if s == 0 { 0.0 } else { pp_hop_enc },
            );
            stage_flop[enc_stage(e, s)] += enc_flop / e_pp as f64;
        }
        for s in 0..l_pp {
            ws.routes.push_leg(
                llm_stage(g, s),
                llm_t / 3.0,
                llm_t * 2.0 / 3.0,
                if s == 0 { comm_hop } else { pp_hop_llm },
            );
            stage_flop[llm_stage(g, s)] += llm_flop / l_pp as f64;
        }
        ws.routes.end_route();
        bucket_exec.push(BucketExec {
            enc_time: enc_t * e_pp as f64,
            llm_time: llm_t * l_pp as f64,
            enc_flop,
            llm_flop,
            llm_shape_bucket: Truth::llm_bucket(total_seq),
        });
    }

    let pipeline_makespan = ws.run(n_stages, true);

    // ---- data-parallel gradient synchronization (straggler-inclusive:
    // the all-reduce starts only after the slowest pipeline drains, which
    // is exactly the simulated makespan) ----
    let enc_grad_bytes = plan.m.encoder.total_params(plan.m.enc_mlp_matrices) * 2.0
        / (th.enc.tp * th.enc.pp) as f64;
    let llm_grad_bytes = plan.m.llm.total_params(plan.m.llm_mlp_matrices) * 2.0
        / (th.llm.tp * th.llm.pp) as f64;
    let dp_sync = plan
        .truth
        .dp_allreduce_time(enc_grad_bytes, e_dp)
        .max(plan.truth.dp_allreduce_time(llm_grad_bytes, l_dp));

    IterationStats {
        iteration_time: pipeline_makespan + dp_sync,
        pipeline_makespan,
        dp_sync_time: dp_sync,
        stage_busy: ws.stage_busy().to_vec(),
        stage_idle: ws.stage_busy().iter().map(|&b| pipeline_makespan - b).collect(),
        stage_flop,
        n_stages,
        total_flop,
        buckets: bucket_exec,
        timeline: ws.timeline().to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::ClusterSpec;

    fn fixture() -> (Mllm, Truth) {
        (llava_ov(llama3("8b")), Truth::smooth(ClusterSpec::hgx_a100(1)))
    }

    fn theta(e_dp: usize, l_dp: usize, l_pp: usize, n_mb: usize) -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: e_dp },
            llm: ModPar { tp: 1, pp: l_pp, dp: l_dp },
            n_mb,
        }
    }

    fn make_buckets(m: &Mllm, n_buckets: usize, per_bucket: usize) -> Vec<Vec<ItemShape>> {
        let mut ds = Dataset::mixed(99);
        (0..n_buckets)
            .map(|_| ds.shaped_batch(m, per_bucket))
            .collect()
    }

    #[test]
    fn iteration_produces_consistent_accounting() {
        let (m, truth) = fixture();
        let th = theta(2, 2, 3, 4);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let buckets = make_buckets(&m, th.buckets(), 4);
        let stats = iterate(&plan, &buckets);
        assert!(stats.iteration_time > 0.0);
        assert!(stats.pipeline_makespan <= stats.iteration_time);
        assert_eq!(stats.n_stages, 2 * 1 + 2 * 3);
        assert_eq!(stats.stage_busy.len(), stats.n_stages);
        // FLOP conservation: stage FLOP sums to total FLOP.
        let sum: f64 = stats.stage_flop.iter().sum();
        assert!((sum / stats.total_flop - 1.0).abs() < 1e-9);
        // Idle = makespan − busy per stage.
        for s in 0..stats.n_stages {
            assert!(
                (stats.stage_idle[s] - (stats.pipeline_makespan - stats.stage_busy[s]))
                    .abs()
                    < 1e-9
            );
        }
    }

    #[test]
    fn balanced_buckets_idle_less_than_skewed() {
        let (m, truth) = fixture();
        let th = theta(1, 1, 3, 8);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        // Build one balanced and one skewed partition of the same items.
        let mut ds = Dataset::mixed(7);
        let items = ds.shaped_batch(&m, 32);
        let balanced: Vec<Vec<ItemShape>> = {
            // Greedy by LLM seq (a decent proxy for balance).
            let mut order: Vec<&ItemShape> = items.iter().collect();
            order.sort_by_key(|i| std::cmp::Reverse(i.llm_seq));
            let mut bks: Vec<Vec<ItemShape>> = vec![Vec::new(); 8];
            let mut loads = vec![0u64; 8];
            for it in order {
                let j = (0..8).min_by_key(|&j| loads[j]).expect("nonempty");
                loads[j] += it.llm_seq as u64;
                bks[j].push(*it);
            }
            bks
        };
        let skewed: Vec<Vec<ItemShape>> =
            items.chunks(4).map(|c| c.to_vec()).collect();
        let b = iterate(&plan, &balanced);
        let s = iterate(&plan, &skewed);
        assert!(
            b.total_idle() < s.total_idle(),
            "balanced idle {} skewed idle {}",
            b.total_idle(),
            s.total_idle()
        );
        assert!(b.iteration_time <= s.iteration_time + 1e-9);
    }

    #[test]
    fn dp_mismatch_charges_communicator() {
        let (m, truth) = fixture();
        // Same bucket contents; matched vs mismatched DP groups.
        let buckets = make_buckets(&m, 4, 2);
        let matched = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 2, 2) };
        let mismatched = SystemPlan { m: &m, truth: &truth, theta: theta(4, 2, 2, 2) };
        let t_match = iterate(&matched, &buckets);
        let t_mis = iterate(&mismatched, &buckets);
        assert!(t_match.iteration_time > 0.0);
        assert!(t_mis.iteration_time > 0.0);
        assert_eq!(t_mis.n_stages, 4 + 4);
    }

    #[test]
    fn empty_buckets_are_tolerated() {
        let (m, truth) = fixture();
        let th = theta(1, 1, 2, 4);
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let mut buckets = make_buckets(&m, 3, 2);
        buckets.push(Vec::new());
        let stats = iterate(&plan, &buckets);
        assert!(stats.iteration_time.is_finite());
        assert_eq!(stats.buckets.len(), 4);
        assert_eq!(stats.buckets[3].enc_flop, 0.0);
    }

    #[test]
    fn iterate_ws_reuse_is_stateless() {
        // Interleaving differently-shaped iterations through one workspace
        // must reproduce the fresh-workspace results bit-for-bit.
        let (m, truth) = fixture();
        let big_plan = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 3, 4) };
        let small_plan = SystemPlan { m: &m, truth: &truth, theta: theta(1, 1, 2, 2) };
        let big = make_buckets(&m, big_plan.theta.buckets(), 4);
        let small = make_buckets(&m, small_plan.theta.buckets(), 2);
        let mut ws = SimWorkspace::new();
        let first = iterate_ws(&big_plan, &big, &mut ws);
        let _ = iterate_ws(&small_plan, &small, &mut ws);
        let again = iterate_ws(&big_plan, &big, &mut ws);
        let fresh = iterate(&big_plan, &big);
        for r in [&again, &fresh] {
            assert_eq!(
                first.iteration_time.to_bits(),
                r.iteration_time.to_bits()
            );
            assert_eq!(first.stage_busy.len(), r.stage_busy.len());
            for (a, b) in first.stage_busy.iter().zip(&r.stage_busy) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
            assert_eq!(first.timeline, r.timeline);
        }
    }

    #[test]
    fn dp_sync_positive_only_with_dp() {
        let (m, truth) = fixture();
        let single = SystemPlan { m: &m, truth: &truth, theta: theta(1, 1, 2, 2) };
        let multi = SystemPlan { m: &m, truth: &truth, theta: theta(2, 2, 2, 2) };
        let buckets = make_buckets(&m, 2, 2);
        assert_eq!(iterate(&single, &buckets).dp_sync_time, 0.0);
        assert!(iterate(&multi, &buckets).dp_sync_time > 0.0);
    }
}
