//! Adaptive Correction (§3.4.3).
//!
//! Interpolation-based duration prediction is accurate for most shapes but
//! consistently wrong for shapes that fall into specialized kernel regimes.
//! This mechanism tracks, per shape bucket, the deviation between observed
//! and predicted throughput (Eq 7: `B = Th_actual − Th_pred`), feeds a
//! multiplicative penalty back into the scheduler's duration estimates, and
//! runs a cost-benefit loop: if the average benefit over a window of
//! iterations fails to exceed the recurring monitoring cost, tracking is
//! deactivated (§5.3.7).

use std::collections::HashMap;

/// Exponential moving average of the actual/predicted throughput ratio.
#[derive(Clone, Copy, Debug)]
struct Ema {
    value: f64,
    n: u32,
}

impl Ema {
    const ALPHA: f64 = 0.3;

    fn new(x: f64) -> Ema {
        Ema { value: x, n: 1 }
    }

    fn update(&mut self, x: f64) {
        self.value = (1.0 - Self::ALPHA) * self.value + Self::ALPHA * x;
        self.n += 1;
    }
}

/// Configuration of the correction loop.
#[derive(Clone, Copy, Debug)]
pub struct CorrectionConfig {
    /// Recurring monitoring cost as a fraction of iteration time. The
    /// paper measures ≈4% by toggling the tracker during warm-up (§3.4.3);
    /// we take it as a config input measured the same way by the caller.
    pub cost_fraction: f64,
    /// Iterations per cost-benefit evaluation window (the paper's `I`).
    pub window: usize,
    /// Minimum observations before a bucket's penalty is trusted.
    pub min_observations: u32,
}

impl Default for CorrectionConfig {
    fn default() -> Self {
        CorrectionConfig { cost_fraction: 0.04, window: 20, min_observations: 2 }
    }
}

/// The Adaptive Correction state.
#[derive(Clone, Debug)]
pub struct Correction {
    pub cfg: CorrectionConfig,
    active: bool,
    /// Per shape-bucket ratio `Th_actual / Th_pred`.
    penalties: HashMap<u64, Ema>,
    /// Realized benefit (fraction of iteration time) per iteration in the
    /// current window.
    window_benefits: Vec<f64>,
    /// Total iterations observed (diagnostics).
    pub iterations: u64,
}

impl Correction {
    pub fn new(cfg: CorrectionConfig) -> Correction {
        Correction {
            cfg,
            active: true,
            penalties: HashMap::new(),
            window_benefits: Vec::new(),
            iterations: 0,
        }
    }

    pub fn is_active(&self) -> bool {
        self.active
    }

    /// Record an observation for a shape bucket: measured vs predicted
    /// throughput (any consistent unit). No-op when deactivated.
    pub fn observe(&mut self, bucket: u64, th_actual: f64, th_pred: f64) {
        if !self.active || th_pred <= 0.0 || th_actual <= 0.0 {
            return;
        }
        let ratio = th_actual / th_pred;
        self.penalties
            .entry(bucket)
            .and_modify(|e| e.update(ratio))
            .or_insert_with(|| Ema::new(ratio));
    }

    /// Adjust a predicted duration for a shape bucket: a bucket observed to
    /// run at ratio r of predicted throughput takes 1/r times as long.
    pub fn adjust(&self, bucket: u64, predicted_dur: f64) -> f64 {
        if !self.active {
            return predicted_dur;
        }
        match self.penalties.get(&bucket) {
            Some(e) if e.n >= self.cfg.min_observations => predicted_dur / e.value,
            _ => predicted_dur,
        }
    }

    /// Close one iteration with the realized benefit (fraction of iteration
    /// time the corrections saved — e.g. reduction in bubble time vs the
    /// uncorrected plan). Runs the cost-benefit toggle at window edges.
    pub fn end_iteration(&mut self, benefit_fraction: f64) {
        self.iterations += 1;
        if !self.active {
            return;
        }
        self.window_benefits.push(benefit_fraction.max(0.0));
        if self.window_benefits.len() >= self.cfg.window {
            let avg: f64 = self.window_benefits.iter().sum::<f64>()
                / self.window_benefits.len() as f64;
            if avg < self.cfg.cost_fraction {
                // Benefit does not cover the monitoring cost: deactivate
                // (the paper keeps it off thereafter to avoid thrash).
                self.active = false;
            }
            self.window_benefits.clear();
        }
    }

    /// Drop every per-bucket penalty. Called on a plan swap
    /// (`engine::exec`): the Eq-7 ratios were measured against the *old*
    /// θ's predictions (its TP/PP shape the estimator priced), so carrying
    /// them across a replan would bias the first post-swap schedules. The
    /// cost-benefit state (activation, iteration count, the current
    /// benefit window) survives — deactivation reflects the monitoring
    /// cost, which a plan swap does not change.
    pub fn reset_penalties(&mut self) {
        self.penalties.clear();
    }

    /// Number of shape buckets with a trusted penalty (diagnostics).
    pub fn corrected_buckets(&self) -> usize {
        self.penalties
            .values()
            .filter(|e| e.n >= self.cfg.min_observations)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn penalty_lengthens_slow_bucket_durations() {
        let mut c = Correction::new(CorrectionConfig::default());
        // Bucket 7 consistently runs at 50% of predicted throughput.
        c.observe(7, 0.5, 1.0);
        c.observe(7, 0.5, 1.0);
        c.observe(7, 0.5, 1.0);
        let adj = c.adjust(7, 10.0);
        assert!(adj > 15.0, "adjusted {adj}");
        // Unobserved buckets are untouched.
        assert_eq!(c.adjust(8, 10.0), 10.0);
    }

    #[test]
    fn single_observation_not_trusted() {
        let mut c = Correction::new(CorrectionConfig::default());
        c.observe(3, 0.5, 1.0);
        assert_eq!(c.adjust(3, 10.0), 10.0);
        c.observe(3, 0.5, 1.0);
        assert!(c.adjust(3, 10.0) > 10.0);
    }

    #[test]
    fn deactivates_when_benefit_below_cost() {
        let cfg = CorrectionConfig { cost_fraction: 0.04, window: 5, min_observations: 2 };
        let mut c = Correction::new(cfg);
        for _ in 0..5 {
            c.end_iteration(0.01); // 1% benefit < 4% cost
        }
        assert!(!c.is_active());
        // Once off, penalties stop applying.
        c.observe(1, 0.5, 1.0);
        c.observe(1, 0.5, 1.0);
        assert_eq!(c.adjust(1, 10.0), 10.0);
    }

    #[test]
    fn stays_active_when_benefit_exceeds_cost() {
        let cfg = CorrectionConfig { cost_fraction: 0.04, window: 5, min_observations: 2 };
        let mut c = Correction::new(cfg);
        for _ in 0..25 {
            c.end_iteration(0.10);
        }
        assert!(c.is_active());
        assert_eq!(c.iterations, 25);
    }

    #[test]
    fn ema_converges_to_sustained_ratio() {
        let mut c = Correction::new(CorrectionConfig::default());
        for _ in 0..50 {
            c.observe(9, 0.7, 1.0);
        }
        let adj = c.adjust(9, 7.0);
        assert!((adj - 10.0).abs() < 0.1, "adjusted {adj}");
        assert_eq!(c.corrected_buckets(), 1);
    }

    #[test]
    fn reset_penalties_clears_ratios_but_keeps_cost_benefit_state() {
        let cfg = CorrectionConfig { cost_fraction: 0.04, window: 5, min_observations: 2 };
        let mut c = Correction::new(cfg);
        c.observe(7, 0.5, 1.0);
        c.observe(7, 0.5, 1.0);
        assert!(c.adjust(7, 10.0) > 10.0);
        for _ in 0..3 {
            c.end_iteration(0.10);
        }
        c.reset_penalties();
        // Penalties are gone…
        assert_eq!(c.corrected_buckets(), 0);
        assert_eq!(c.adjust(7, 10.0), 10.0);
        // …but the cost-benefit loop is untouched: still active, same
        // iteration count, and the partially-filled benefit window keeps
        // accumulating (two more rich iterations close the window of 5
        // without deactivating).
        assert!(c.is_active());
        assert_eq!(c.iterations, 3);
        c.end_iteration(0.10);
        c.end_iteration(0.10);
        assert!(c.is_active());
        // New observations after the reset are trusted again.
        c.observe(7, 0.5, 1.0);
        c.observe(7, 0.5, 1.0);
        assert!(c.adjust(7, 10.0) > 10.0);
    }

    #[test]
    fn ignores_degenerate_observations() {
        let mut c = Correction::new(CorrectionConfig::default());
        c.observe(1, 0.0, 1.0);
        c.observe(1, 1.0, 0.0);
        assert_eq!(c.corrected_buckets(), 0);
    }
}
