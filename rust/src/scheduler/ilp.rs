//! Branch-and-bound solver for the microbatch-partitioning ILP (§3.4.1).
//!
//! The paper formulates the per-iteration load-balancing problem (Eq 6) as
//! an ILP and solves it with a commercial solver under a strict time limit,
//! falling back to LPT on timeout. No solver is available offline, so this
//! module implements the exact formulation as a depth-first branch-and-bound
//! over item→bucket assignments:
//!
//! - items are branched in descending weight order (most constrained first);
//! - the incumbent starts at the LPT solution, so the solver can only
//!   improve on the fallback;
//! - pruning bound: placing item k cannot beat
//!   `max(current C_max, remaining-work/m lower bound, largest single item)`;
//! - symmetry breaking: an item may open at most one new (empty) bucket —
//!   empty buckets are interchangeable;
//! - wall-clock budget checked every `CHECK_EVERY` nodes; on expiry the
//!   incumbent (≥ LPT quality) is returned with `optimal = false`.
//!
//! The search tree is split at the root: the first few levels of
//! item→bucket placements are enumerated into a **fixed** set of disjoint
//! prefixes (fixed = independent of thread count), ordered by their entry
//! bound (most promising first), and each prefix's subtree is searched on
//! the `util::parallel` pool under the one shared deadline. Subtrees
//! deliberately do *not* share an incumbent — each warm-starts from the
//! same LPT solution — so a subtree explores exactly the same nodes
//! wherever and whenever it runs, and the deterministic merge
//! (strictly-better C_max, earliest in bound order wins ties) makes the
//! returned assignment independent of thread count. The price is some
//! redundant exploration versus a shared bound — later subtrees re-derive
//! improvements the first ones already found, which matters most when
//! this runs nested-serial inside a simulation cell — the bound ordering
//! is what keeps an expiring budget spent where the old best-first
//! descent would have gone first. `--threads 1` and `--threads N` agree
//! bit-for-bit whenever the budget suffices; on expiry the incumbent is
//! timing-dependent, exactly as the serial search already was.

use crate::scheduler::lpt::{lower_bound, lpt, Assignment, ItemCost};
use crate::util::parallel::par_map;
use std::time::{Duration, Instant};

/// Solver outcome.
#[derive(Clone, Debug)]
pub struct IlpResult {
    pub assignment: Assignment,
    /// True if the search space was exhausted (solution is optimal).
    pub optimal: bool,
    /// Nodes expanded (diagnostics / Fig 16b).
    pub nodes: u64,
    pub elapsed: Duration,
}

struct Search<'a> {
    items: &'a [ItemCost],
    order: &'a [usize],
    m: usize,
    deadline: Instant,
    // incumbent
    best_cmax: f64,
    best_assign: Vec<usize>, // item -> bucket (in `order` space)
    // current partial state
    cur_assign: Vec<usize>,
    enc_loads: Vec<f64>,
    llm_loads: Vec<f64>,
    // suffix sums of remaining work (by position in `order`)
    suffix_enc: &'a [f64],
    suffix_llm: &'a [f64],
    nodes: u64,
    timed_out: bool,
    global_lb: f64,
}

const CHECK_EVERY: u64 = 4096;

/// Root-split width: prefixes are expanded breadth-first until at least
/// this many subtrees exist (or the tree is exhausted). A constant — never
/// derived from the pool width — so the subtree decomposition, and with it
/// the merged result, is identical at every thread count.
const ROOT_SPLIT_TARGET: usize = 64;

/// One partial assignment of the first `assign.len()` items (in branch
/// order), with its running loads — the root of an independent subtree.
#[derive(Clone)]
struct Prefix {
    assign: Vec<usize>,
    enc_loads: Vec<f64>,
    llm_loads: Vec<f64>,
    used: usize,
    cmax: f64,
}

/// Enumerate the symmetric search tree's first levels into disjoint
/// subtree roots (no pruning here — subtrees prune themselves).
fn root_prefixes(items: &[ItemCost], order: &[usize], m: usize) -> Vec<Prefix> {
    let mut level = vec![Prefix {
        assign: Vec::new(),
        enc_loads: vec![0.0; m],
        llm_loads: vec![0.0; m],
        used: 0,
        cmax: 0.0,
    }];
    while level.len() < ROOT_SPLIT_TARGET && level[0].assign.len() < order.len() {
        let pos = level[0].assign.len();
        let item = items[order[pos]];
        let mut next = Vec::with_capacity(level.len() * 2);
        for p in &level {
            // Same child set as the serial branch step: existing buckets
            // plus at most one fresh bucket (symmetry breaking).
            let limit = (p.used + 1).min(m);
            for j in 0..limit {
                let mut q = p.clone();
                q.assign.push(j);
                q.enc_loads[j] += item.enc;
                q.llm_loads[j] += item.llm;
                q.used = p.used.max(j + 1);
                q.cmax = p.cmax.max(q.enc_loads[j].max(q.llm_loads[j]));
                next.push(q);
            }
        }
        level = next;
    }
    level
}

impl<'a> Search<'a> {
    fn dfs(&mut self, pos: usize, used_buckets: usize, cur_cmax: f64) {
        self.nodes += 1;
        if self.nodes % CHECK_EVERY == 0 && Instant::now() >= self.deadline {
            self.timed_out = true;
        }
        if self.timed_out {
            return;
        }
        if pos == self.order.len() {
            if cur_cmax < self.best_cmax {
                self.best_cmax = cur_cmax;
                self.best_assign = self.cur_assign.clone();
            }
            return;
        }
        // Prune: even perfectly spreading the remaining work cannot beat
        // the incumbent.
        let rem_bound = (self.suffix_enc[pos] / self.m as f64)
            .max(self.suffix_llm[pos] / self.m as f64);
        if cur_cmax.max(rem_bound) >= self.best_cmax - 1e-12 {
            return;
        }
        let item = self.items[self.order[pos]];
        // Try existing buckets plus at most one fresh bucket (symmetry).
        let limit = (used_buckets + 1).min(self.m);
        // Branch order: buckets by ascending resulting bottleneck, so the
        // most promising child is explored first (better incumbents early
        // → more pruning).
        let mut children: Vec<(f64, usize)> = (0..limit)
            .map(|j| {
                let e = self.enc_loads[j] + item.enc;
                let l = self.llm_loads[j] + item.llm;
                (e.max(l), j)
            })
            .collect();
        children.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN"));
        for (bottleneck, j) in children {
            let new_cmax = cur_cmax.max(bottleneck);
            if new_cmax >= self.best_cmax - 1e-12 {
                continue;
            }
            self.enc_loads[j] += item.enc;
            self.llm_loads[j] += item.llm;
            self.cur_assign[pos] = j;
            let new_used = used_buckets.max(j + 1);
            self.dfs(pos + 1, new_used, new_cmax);
            self.enc_loads[j] -= item.enc;
            self.llm_loads[j] -= item.llm;
            if self.timed_out {
                return;
            }
            // Optimality shortcut: incumbent hit the global lower bound.
            if self.best_cmax <= self.global_lb + 1e-12 {
                return;
            }
        }
    }
}

/// Solve Eq 6 by branch-and-bound within `budget`. Always returns an
/// assignment at least as good as LPT.
pub fn solve(items: &[ItemCost], m: usize, budget: Duration) -> IlpResult {
    let start = Instant::now();
    assert!(m > 0);
    let warm = lpt(items, m);
    if items.is_empty() || m == 1 {
        return IlpResult {
            assignment: warm,
            optimal: true,
            nodes: 0,
            elapsed: start.elapsed(),
        };
    }

    // Branch in descending combined-weight order.
    let mut order: Vec<usize> = (0..items.len()).collect();
    order.sort_by(|&a, &b| {
        let wa = items[a].enc + items[a].llm;
        let wb = items[b].enc + items[b].llm;
        wb.partial_cmp(&wa).expect("NaN").then(a.cmp(&b))
    });
    let n = order.len();
    let mut suffix_enc = vec![0.0; n + 1];
    let mut suffix_llm = vec![0.0; n + 1];
    for pos in (0..n).rev() {
        suffix_enc[pos] = suffix_enc[pos + 1] + items[order[pos]].enc;
        suffix_llm[pos] = suffix_llm[pos + 1] + items[order[pos]].llm;
    }

    // Seed incumbent with LPT: map its buckets into `order` positions.
    let mut lpt_assign = vec![0usize; n];
    {
        let mut item_to_bucket = vec![0usize; items.len()];
        for (j, b) in warm.buckets.iter().enumerate() {
            for &i in b {
                item_to_bucket[i] = j;
            }
        }
        for (pos, &i) in order.iter().enumerate() {
            lpt_assign[pos] = item_to_bucket[i];
        }
    }

    let global_lb = lower_bound(items, m);
    let deadline = start + budget;
    // The warm start's objective is read per prefix below; c_max() is an
    // O(m) fold, so compute it once.
    let warm_cmax = warm.c_max();
    let mut best_cmax = warm_cmax;
    let mut best_assign = lpt_assign.clone();
    let mut nodes = 0u64;
    let mut timed_out = false;
    // LPT may already be optimal.
    if warm_cmax > global_lb + 1e-12 {
        // Deadline-shared parallel root split: search each fixed prefix's
        // subtree independently (own incumbent, common LPT warm start),
        // then merge in a fixed order.
        let mut prefixes = root_prefixes(items, &order, m);
        // Most-promising-first: order subtrees by their entry bound (the
        // same bound dfs prunes with), drop the ones the warm start
        // already beats. Both steps depend only on fixed inputs, so the
        // schedule — and the merge order — is thread-count independent,
        // while an expiring budget gets spent where the old best-first
        // descent would have gone first.
        let entry_bound = |p: &Prefix| -> f64 {
            let d = p.assign.len();
            p.cmax.max((suffix_enc[d] / m as f64).max(suffix_llm[d] / m as f64))
        };
        prefixes.sort_by(|a, b| {
            entry_bound(a).partial_cmp(&entry_bound(b)).expect("NaN bound")
        });
        prefixes.retain(|p| entry_bound(p) < warm_cmax - 1e-12);
        let subtree = |pi: usize| -> (f64, Vec<usize>, u64, bool) {
            let p = &prefixes[pi];
            // Budget already spent: report the warm start without paying
            // for a CHECK_EVERY granule of doomed exploration.
            if Instant::now() >= deadline {
                return (warm_cmax, lpt_assign.clone(), 0, true);
            }
            let depth = p.assign.len();
            let mut cur_assign = vec![0usize; n];
            cur_assign[..depth].copy_from_slice(&p.assign);
            // No cross-subtree lb-hit shortcut on purpose: stopping
            // siblings once one subtree reaches `global_lb` would make
            // *which* lb-achieving assignment wins depend on timing
            // (exact-lb ties are common when the largest item is the
            // binding bound), breaking the thread-count determinism
            // contract. Each subtree still stops itself on lb-hit, and
            // the deadline caps the residual exploration.
            let mut search = Search {
                items,
                order: &order,
                m,
                deadline,
                best_cmax: warm_cmax,
                best_assign: lpt_assign.clone(),
                cur_assign,
                enc_loads: p.enc_loads.clone(),
                llm_loads: p.llm_loads.clone(),
                suffix_enc: &suffix_enc,
                suffix_llm: &suffix_llm,
                nodes: 0,
                timed_out: false,
                global_lb,
            };
            search.dfs(depth, p.used, p.cmax);
            (search.best_cmax, search.best_assign, search.nodes, search.timed_out)
        };
        for (cmax, assign, sub_nodes, sub_timed_out) in par_map(prefixes.len(), subtree) {
            nodes += sub_nodes;
            timed_out |= sub_timed_out;
            if cmax < best_cmax {
                best_cmax = cmax;
                best_assign = assign;
            }
        }
    }

    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (pos, &j) in best_assign.iter().enumerate() {
        buckets[j].push(order[pos]);
    }
    for b in &mut buckets {
        b.sort_unstable(); // deterministic output
    }
    let assignment = Assignment::from_buckets(buckets, items);
    IlpResult {
        // Exhausted the space, or proved the bound — either way optimal.
        optimal: !timed_out || best_cmax <= global_lb + 1e-12,
        nodes,
        elapsed: start.elapsed(),
        assignment,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn items_from(pairs: &[(f64, f64)]) -> Vec<ItemCost> {
        pairs.iter().map(|&(e, l)| ItemCost { enc: e, llm: l }).collect()
    }

    #[test]
    fn finds_optimum_where_lpt_fails() {
        // Classic LPT counterexample (single metric): {3,3,2,2,2} into 2
        // buckets. LPT gives 7, optimal is 6.
        let items = items_from(&[(3.0, 0.0), (3.0, 0.0), (2.0, 0.0), (2.0, 0.0), (2.0, 0.0)]);
        let warm = lpt(&items, 2);
        assert!((warm.c_max() - 7.0).abs() < 1e-12, "lpt {}", warm.c_max());
        let r = solve(&items, 2, Duration::from_secs(5));
        assert!(r.optimal);
        assert!((r.assignment.c_max() - 6.0).abs() < 1e-12, "{}", r.assignment.c_max());
    }

    #[test]
    fn never_worse_than_lpt() {
        forall("ilp ≥ lpt", 150, |g| {
            let n = g.size(14);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.1, 4.0),
                    llm: g.rng.uniform(0.1, 4.0),
                })
                .collect();
            let m = g.size(4);
            let warm = lpt(&items, m).c_max();
            let r = solve(&items, m, Duration::from_millis(200));
            (
                format!("n={n} m={m} lpt={warm} ilp={}", r.assignment.c_max()),
                r.assignment.c_max() <= warm + 1e-9
                    && r.assignment.is_partition(n),
            )
        });
    }

    #[test]
    fn matches_exhaustive_optimum_on_small_instances() {
        // Brute-force all m^n assignments and compare.
        fn brute(items: &[ItemCost], m: usize) -> f64 {
            let n = items.len();
            let mut best = f64::INFINITY;
            let total = (m as u64).pow(n as u32);
            for code in 0..total {
                let mut enc = vec![0.0; m];
                let mut llm = vec![0.0; m];
                let mut c = code;
                for item in items {
                    let j = (c % m as u64) as usize;
                    c /= m as u64;
                    enc[j] += item.enc;
                    llm[j] += item.llm;
                }
                let cmax = enc
                    .iter()
                    .chain(llm.iter())
                    .cloned()
                    .fold(0.0, f64::max);
                best = best.min(cmax);
            }
            best
        }
        forall("ilp = brute force", 40, |g| {
            let n = g.size(7);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.0, 3.0),
                    llm: g.rng.uniform(0.0, 3.0),
                })
                .collect();
            let m = g.size(3);
            let opt = brute(&items, m);
            let r = solve(&items, m, Duration::from_secs(10));
            (
                format!("n={n} m={m} opt={opt} got={}", r.assignment.c_max()),
                r.optimal && (r.assignment.c_max() - opt).abs() < 1e-9,
            )
        });
    }

    #[test]
    fn respects_time_limit() {
        // A large adversarial instance cannot be solved to optimality in
        // 5 ms; the solver must return promptly with the incumbent.
        let mut g = crate::util::rng::Rng::new(77);
        let items: Vec<ItemCost> = (0..200)
            .map(|_| ItemCost {
                enc: g.uniform(0.1, 1.0),
                llm: g.uniform(0.1, 1.0),
            })
            .collect();
        let t0 = Instant::now();
        let r = solve(&items, 7, Duration::from_millis(5));
        let took = t0.elapsed();
        assert!(took < Duration::from_millis(500), "took {took:?}");
        assert!(r.assignment.is_partition(200));
        assert!(r.assignment.c_max() <= lpt(&items, 7).c_max() + 1e-9);
    }

    #[test]
    fn single_bucket_trivial() {
        let items = items_from(&[(1.0, 2.0), (3.0, 4.0)]);
        let r = solve(&items, 1, Duration::from_secs(1));
        assert!(r.optimal);
        assert!((r.assignment.c_max() - 6.0).abs() < 1e-12);
    }

    #[test]
    fn bimetric_conflict_resolved() {
        // Two items heavy on encoder, two heavy on LLM: optimum pairs one
        // of each per bucket (C_max = 11), not same-type (C_max = 20).
        let items = items_from(&[(10.0, 1.0), (10.0, 1.0), (1.0, 10.0), (1.0, 10.0)]);
        let r = solve(&items, 2, Duration::from_secs(1));
        assert!((r.assignment.c_max() - 11.0).abs() < 1e-9, "{}", r.assignment.c_max());
    }
}
