//! Longest-Processing-Time fallback heuristic (§3.4.2).
//!
//! Bi-stage variant of Graham's LPT: items carry an (encoder, LLM) duration
//! pair; the greedy sorts by descending combined weight and places each item
//! in the bucket that minimizes the resulting bottleneck
//! `max(max_j E_j, max_j L_j)` (Eq 6's objective). A binary heap keyed on
//! bucket load gives the paper's `O(GBS · log m)` bound for the classic
//! single-metric case; for the bi-metric objective we scan buckets but keep
//! the same interface.

/// One item's per-stage durations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ItemCost {
    pub enc: f64,
    pub llm: f64,
}

/// Structure-of-arrays [`ItemCost`] table: the batched candidate
/// evaluator's layout (`optimizer::batch`). Per-candidate-key cost columns
/// live contiguously, so the LPT's hot placement scan streams one metric
/// at a time instead of striding over interleaved pairs, and a table can
/// be built once and shared by every candidate with the same `(tp, pp)`
/// key. [`lpt_table_into`] over a table is bit-identical to [`lpt_into`]
/// over the equivalent `&[ItemCost]` slice — both run the same generic
/// core.
#[derive(Clone, Debug, Default)]
pub struct CostTable {
    pub enc: Vec<f64>,
    pub llm: Vec<f64>,
}

impl CostTable {
    pub fn new() -> CostTable {
        CostTable::default()
    }

    pub fn len(&self) -> usize {
        self.enc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.enc.is_empty()
    }

    /// Drop all rows, keeping capacity.
    pub fn clear(&mut self) {
        self.enc.clear();
        self.llm.clear();
    }

    #[inline]
    pub fn push(&mut self, enc: f64, llm: f64) {
        self.enc.push(enc);
        self.llm.push(llm);
    }

    pub fn from_items(items: &[ItemCost]) -> CostTable {
        CostTable {
            enc: items.iter().map(|i| i.enc).collect(),
            llm: items.iter().map(|i| i.llm).collect(),
        }
    }

    #[inline]
    pub fn get(&self, i: usize) -> ItemCost {
        ItemCost { enc: self.enc[i], llm: self.llm[i] }
    }
}

/// Result of a partitioning pass.
#[derive(Clone, Debug, Default)]
pub struct Assignment {
    /// `buckets[j]` = indices of the items placed in bucket j.
    pub buckets: Vec<Vec<usize>>,
    /// Total encoder / LLM duration per bucket.
    pub enc_loads: Vec<f64>,
    pub llm_loads: Vec<f64>,
}

impl Assignment {
    /// The Eq-6 objective: `C_max = max(max_j E_j, max_j L_j)`.
    pub fn c_max(&self) -> f64 {
        let e = self.enc_loads.iter().cloned().fold(0.0, f64::max);
        let l = self.llm_loads.iter().cloned().fold(0.0, f64::max);
        e.max(l)
    }

    /// Build loads from a bucket assignment.
    pub fn from_buckets(buckets: Vec<Vec<usize>>, items: &[ItemCost]) -> Assignment {
        let enc_loads = buckets
            .iter()
            .map(|b| b.iter().map(|&i| items[i].enc).sum())
            .collect();
        let llm_loads = buckets
            .iter()
            .map(|b| b.iter().map(|&i| items[i].llm).sum())
            .collect();
        Assignment { buckets, enc_loads, llm_loads }
    }

    /// Emission permutation: bucket indices ordered heaviest bottleneck
    /// first (ties by index), written into `out` (cleared first). This is
    /// the Online Scheduler's launch order — long microbatches early
    /// shrink 1F1B drain bubbles — computed without cloning the
    /// assignment; pair with [`Assignment::apply_order`] or feed the
    /// permutation straight to a route builder.
    pub fn heavy_order(&self, out: &mut Vec<usize>) {
        out.clear();
        out.extend(0..self.buckets.len());
        out.sort_by(|&x, &y| {
            let kx = self.enc_loads[x].max(self.llm_loads[x]);
            let ky = self.enc_loads[y].max(self.llm_loads[y]);
            ky.partial_cmp(&kx).expect("NaN load").then(x.cmp(&y))
        });
    }

    /// Reorder buckets and loads by `order` (a permutation of
    /// `0..buckets.len()`). Buckets are *moved*, not cloned.
    pub fn apply_order(&mut self, order: &[usize]) {
        debug_assert_eq!(order.len(), self.buckets.len());
        let mut old: Vec<Option<Vec<usize>>> =
            std::mem::take(&mut self.buckets).into_iter().map(Some).collect();
        self.buckets = order
            .iter()
            .map(|&j| old[j].take().expect("order is a permutation"))
            .collect();
        let enc = order.iter().map(|&j| self.enc_loads[j]).collect();
        let llm = order.iter().map(|&j| self.llm_loads[j]).collect();
        self.enc_loads = enc;
        self.llm_loads = llm;
    }

    /// Check the partition property: every item in exactly one bucket.
    pub fn is_partition(&self, n_items: usize) -> bool {
        let mut seen = vec![false; n_items];
        for b in &self.buckets {
            for &i in b {
                if i >= n_items || seen[i] {
                    return false;
                }
                seen[i] = true;
            }
        }
        seen.into_iter().all(|s| s)
    }
}

/// Perfectly-balanced lower bound for the Eq-6 objective: each metric's
/// total divided by the bucket count, and no bucket can beat the largest
/// single item.
pub fn lower_bound(items: &[ItemCost], m: usize) -> f64 {
    let te: f64 = items.iter().map(|i| i.enc).sum();
    let tl: f64 = items.iter().map(|i| i.llm).sum();
    let max_item = items
        .iter()
        .map(|i| i.enc.max(i.llm))
        .fold(0.0, f64::max);
    (te / m as f64).max(tl / m as f64).max(max_item)
}

/// Greedy LPT partition of `items` into `m` buckets.
pub fn lpt(items: &[ItemCost], m: usize) -> Assignment {
    let mut out = Assignment::default();
    lpt_into(items, m, &mut out);
    out
}

/// [`lpt`] into a reusable `out`: bucket and load buffers are cleared and
/// refilled, keeping their capacity — the optimizer's Eq-1 refinement
/// calls this once per candidate and must not churn the allocator.
pub fn lpt_into(items: &[ItemCost], m: usize, out: &mut Assignment) {
    lpt_core(items.len(), |i| items[i].enc, |i| items[i].llm, m, out);
}

/// [`lpt_into`] over a structure-of-arrays [`CostTable`]. Shares
/// [`lpt_core`] with the slice path, so the two are bit-identical on
/// equivalent inputs (asserted by `lpt_table_matches_slice_bitwise`).
pub fn lpt_table_into(table: &CostTable, m: usize, out: &mut Assignment) {
    lpt_core(table.len(), |i| table.enc[i], |i| table.llm[i], m, out);
}

/// The single greedy implementation behind both item layouts: costs are
/// reached only through the accessor closures, so any layout that returns
/// the same bits produces the same partition.
fn lpt_core<E, L>(n: usize, enc: E, llm: L, m: usize, out: &mut Assignment)
where
    E: Fn(usize) -> f64,
    L: Fn(usize) -> f64,
{
    assert!(m > 0, "lpt with zero buckets");
    let mut order: Vec<usize> = (0..n).collect();
    // Descending by combined weight (ties broken by index for determinism).
    order.sort_by(|&a, &b| {
        let wa = enc(a) + llm(a);
        let wb = enc(b) + llm(b);
        wb.partial_cmp(&wa).expect("NaN duration").then(a.cmp(&b))
    });

    for b in out.buckets.iter_mut() {
        b.clear();
    }
    out.buckets.resize_with(m, Vec::new);
    out.enc_loads.clear();
    out.enc_loads.resize(m, 0.0);
    out.llm_loads.clear();
    out.llm_loads.resize(m, 0.0);
    let (buckets, enc_loads, llm_loads) =
        (&mut out.buckets, &mut out.enc_loads, &mut out.llm_loads);
    for &i in &order {
        // Place where the resulting bottleneck grows least.
        let (ei, li) = (enc(i), llm(i));
        let mut best_j = 0usize;
        let mut best_key = f64::INFINITY;
        for j in 0..m {
            let e = enc_loads[j] + ei;
            let l = llm_loads[j] + li;
            // Primary: bucket bottleneck; secondary: combined load for
            // tie-breaking (keeps buckets even when one metric is zero).
            let key = e.max(l) + 1e-9 * (e + l);
            if key < best_key {
                best_key = key;
                best_j = j;
            }
        }
        buckets[best_j].push(i);
        enc_loads[best_j] += ei;
        llm_loads[best_j] += li;
    }
}

/// Random assignment — what the data-agnostic baselines do (§3.4: "existing
/// scheduling strategies assign data items to these buckets in a random
/// manner"). Round-robin over a shuffled order, so bucket *counts* stay
/// even but *loads* do not.
pub fn random_assign(
    items: &[ItemCost],
    m: usize,
    rng: &mut crate::util::rng::Rng,
) -> Assignment {
    let mut order: Vec<usize> = (0..items.len()).collect();
    rng.shuffle(&mut order);
    let mut buckets: Vec<Vec<usize>> = vec![Vec::new(); m];
    for (pos, &i) in order.iter().enumerate() {
        buckets[pos % m].push(i);
    }
    Assignment::from_buckets(buckets, items)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn items_from(pairs: &[(f64, f64)]) -> Vec<ItemCost> {
        pairs.iter().map(|&(e, l)| ItemCost { enc: e, llm: l }).collect()
    }

    #[test]
    fn lpt_is_a_partition() {
        let items = items_from(&[(3.0, 1.0), (2.0, 2.0), (1.0, 3.0), (4.0, 4.0)]);
        let a = lpt(&items, 2);
        assert!(a.is_partition(4));
    }

    #[test]
    fn lpt_balances_simple_case() {
        // 4 equal items into 2 buckets: perfect split.
        let items = items_from(&[(1.0, 1.0); 4]);
        let a = lpt(&items, 2);
        assert!((a.c_max() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn lpt_beats_random_on_heterogeneous_load() {
        let mut rng = Rng::new(3);
        let items: Vec<ItemCost> = (0..64)
            .map(|_| ItemCost {
                enc: rng.lognormal(0.0, 1.0),
                llm: rng.lognormal(0.5, 1.0),
            })
            .collect();
        let a_lpt = lpt(&items, 8);
        let a_rand = random_assign(&items, 8, &mut rng);
        assert!(
            a_lpt.c_max() < a_rand.c_max(),
            "lpt {} rand {}",
            a_lpt.c_max(),
            a_rand.c_max()
        );
    }

    #[test]
    fn lpt_within_4_3_of_optimum_single_metric() {
        // Graham's bound: LPT ≤ (4/3 − 1/(3m))·OPT for one metric. Zero
        // LLM costs reduce the bi-metric greedy to classic LPT; the exact
        // optimum comes from the branch-and-bound solver on small
        // instances.
        use crate::scheduler::ilp::solve;
        use std::time::Duration;
        forall("lpt 4/3 bound", 60, |g| {
            let durs = g.durations(11, 0.1, 10.0);
            let items: Vec<ItemCost> =
                durs.iter().map(|&d| ItemCost { enc: d, llm: 0.0 }).collect();
            let m = g.size(4);
            let a = lpt(&items, m);
            let exact = solve(&items, m, Duration::from_secs(10));
            let opt = exact.assignment.c_max();
            let bound = (4.0 / 3.0 - 1.0 / (3.0 * m as f64)) * opt + 1e-9;
            let ok = exact.optimal && a.c_max() <= bound;
            (
                format!("n={} m={} lpt={} opt={opt}", items.len(), m, a.c_max()),
                ok,
            )
        });
    }

    #[test]
    fn lpt_partition_property_random() {
        forall("lpt partition", 200, |g| {
            let n = g.size(50);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.0, 5.0),
                    llm: g.rng.uniform(0.0, 5.0),
                })
                .collect();
            let m = g.size(10);
            let a = lpt(&items, m);
            (format!("n={n} m={m}"), a.is_partition(n))
        });
    }

    #[test]
    fn lower_bound_never_exceeds_any_assignment() {
        forall("lb sound", 200, |g| {
            let n = g.size(30);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.1, 3.0),
                    llm: g.rng.uniform(0.1, 3.0),
                })
                .collect();
            let m = g.size(6);
            let lb = lower_bound(&items, m);
            let a = lpt(&items, m);
            let r = random_assign(&items, m, &mut g.rng);
            (
                format!("lb={lb} lpt={} rand={}", a.c_max(), r.c_max()),
                lb <= a.c_max() + 1e-9 && lb <= r.c_max() + 1e-9,
            )
        });
    }

    #[test]
    fn lpt_into_reuse_matches_fresh() {
        // A reused Assignment (including one left over from a *larger*
        // instance) must reproduce the fresh result exactly.
        let big = items_from(&[(3.0, 1.0), (2.0, 2.0), (1.0, 3.0), (4.0, 4.0), (0.5, 0.5)]);
        let small = items_from(&[(1.0, 2.0), (2.0, 1.0)]);
        let mut reused = Assignment::default();
        lpt_into(&big, 4, &mut reused);
        lpt_into(&small, 2, &mut reused);
        let fresh = lpt(&small, 2);
        assert_eq!(reused.buckets, fresh.buckets);
        assert_eq!(reused.enc_loads, fresh.enc_loads);
        assert_eq!(reused.llm_loads, fresh.llm_loads);
    }

    #[test]
    fn heavy_order_then_apply_sorts_by_bottleneck() {
        let items = items_from(&[(5.0, 0.0), (1.0, 1.0), (0.0, 3.0), (2.0, 2.0)]);
        let mut a = Assignment::from_buckets(
            vec![vec![1], vec![0], vec![2], vec![3]],
            &items,
        );
        let mut order = Vec::new();
        a.heavy_order(&mut order);
        assert_eq!(order, vec![1, 2, 3, 0]);
        a.apply_order(&order);
        assert_eq!(a.buckets, vec![vec![0], vec![2], vec![3], vec![1]]);
        assert!(a.is_partition(4));
        for w in 0..3 {
            let k0 = a.enc_loads[w].max(a.llm_loads[w]);
            let k1 = a.enc_loads[w + 1].max(a.llm_loads[w + 1]);
            assert!(k0 >= k1, "not heaviest-first at {w}: {k0} < {k1}");
        }
    }

    #[test]
    fn lpt_table_matches_slice_bitwise() {
        // The SoA table path must reproduce the slice path exactly:
        // identical buckets and bit-identical loads.
        forall("lpt table = lpt slice", 150, |g| {
            let n = g.size(60);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.0, 5.0),
                    llm: g.rng.uniform(0.0, 5.0),
                })
                .collect();
            let m = g.size(10);
            let a = lpt(&items, m);
            let table = CostTable::from_items(&items);
            let mut b = Assignment::default();
            lpt_table_into(&table, m, &mut b);
            let ok = a.buckets == b.buckets
                && a.enc_loads.iter().zip(&b.enc_loads).all(|(x, y)| x.to_bits() == y.to_bits())
                && a.llm_loads.iter().zip(&b.llm_loads).all(|(x, y)| x.to_bits() == y.to_bits());
            (format!("n={n} m={m} c_max={}", a.c_max()), ok)
        });
    }

    #[test]
    fn cost_table_round_trips_items() {
        let items = items_from(&[(3.0, 1.0), (2.0, 2.0), (0.5, 4.0)]);
        let mut t = CostTable::from_items(&items);
        assert_eq!(t.len(), 3);
        assert!(!t.is_empty());
        for (i, &it) in items.iter().enumerate() {
            assert_eq!(t.get(i), it);
        }
        t.clear();
        assert!(t.is_empty());
        t.push(1.0, 2.0);
        assert_eq!(t.get(0), ItemCost { enc: 1.0, llm: 2.0 });
    }

    #[test]
    fn empty_items_yield_empty_buckets() {
        let a = lpt(&[], 4);
        assert_eq!(a.buckets.len(), 4);
        assert_eq!(a.c_max(), 0.0);
        assert!(a.is_partition(0));
    }
}
