//! The Online Microbatch Scheduler (§3.4): hybrid ILP/LPT partitioning with
//! Adaptive Correction.
pub mod correction;
pub mod ilp;
pub mod lpt;
pub mod online;

pub use correction::{Correction, CorrectionConfig};
pub use lpt::{lower_bound, lpt, Assignment, ItemCost};
pub use online::{OnlineScheduler, Schedule, SchedulerConfig, Solver};
