//! The Online Microbatch Scheduler (§3.4): per-item duration calculation,
//! the hybrid ILP/LPT solving mechanism, and Adaptive Correction feedback.
//!
//! Each iteration receives a global batch of `N` item shapes, computes the
//! per-item stage durations under the active plan θ*, partitions the items
//! into `m = N_mb · L_dp` buckets by the hybrid mechanism (ILP with a time
//! limit, LPT fallback), and returns index groups (Fig 5).

use crate::data::item::ItemShape;
use crate::optimizer::plan::Theta;
use crate::perfmodel::Truth;
use crate::profiling::estimator::Estimator;
use crate::scheduler::correction::Correction;
use crate::scheduler::ilp;
use crate::scheduler::lpt::{lower_bound, lpt, random_assign, Assignment, ItemCost};
use std::time::Duration;

/// Which mechanism produced the final partition.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Solver {
    /// Branch-and-bound completed within its budget (proved optimal).
    Ilp,
    /// Budget expired; the returned partition is the best incumbent, which
    /// is at least as good as LPT (§3.4.2's fallback).
    LptFallback,
    /// Random assignment (baseline systems only).
    Random,
}

/// One iteration's scheduling decision.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub assignment: Assignment,
    pub items: Vec<ItemCost>,
    pub solver: Solver,
    /// Scheduling wall-clock (Fig 16b).
    pub elapsed: Duration,
    /// Load-imbalance vs the perfect-balance lower bound:
    /// `c_max / lower_bound − 1` (the paper reports <1% after fallback).
    pub imbalance: f64,
}

/// Scheduler configuration.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// ILP time limit per iteration (strict — §3.4.2).
    pub ilp_budget: Duration,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { ilp_budget: Duration::from_millis(50) }
    }
}

/// The Online Microbatch Scheduler.
pub struct OnlineScheduler {
    pub theta: Theta,
    pub cfg: SchedulerConfig,
    pub correction: Correction,
}

impl OnlineScheduler {
    pub fn new(theta: Theta, cfg: SchedulerConfig, correction: Correction) -> Self {
        OnlineScheduler { theta, cfg, correction }
    }

    /// Per-item *stage* durations under θ (full-module duration spread over
    /// the module's PP degree), with Adaptive Correction penalties applied
    /// to the LLM path (the regime-sensitive one).
    pub fn item_costs(&self, est: &Estimator, shapes: &[ItemShape]) -> Vec<ItemCost> {
        shapes
            .iter()
            .map(|s| {
                let enc = est.enc_item_dur(s, self.theta.enc.tp) / self.theta.enc.pp as f64;
                let raw_llm =
                    est.llm_item_dur(s, self.theta.llm.tp) / self.theta.llm.pp as f64;
                let bucket = Truth::llm_bucket(s.llm_seq as f64);
                let llm = self.correction.adjust(bucket, raw_llm);
                ItemCost { enc, llm }
            })
            .collect()
    }

    /// Partition a global batch into `m = N_mb · L_dp` scheduled
    /// microbatch buckets (Fig 5).
    pub fn schedule(&self, est: &Estimator, shapes: &[ItemShape]) -> Schedule {
        let t0 = std::time::Instant::now();
        let items = self.item_costs(est, shapes);
        let m = self.theta.buckets().min(items.len().max(1));
        let mut r = ilp::solve(&items, m, self.cfg.ilp_budget);
        // Emit buckets heaviest-first: launching long microbatches early
        // shrinks 1F1B drain bubbles under heterogeneous durations.
        {
            let a = &mut r.assignment;
            let mut order = Vec::with_capacity(a.buckets.len());
            a.heavy_order(&mut order);
            a.apply_order(&order);
        }
        let solver = if r.optimal { Solver::Ilp } else { Solver::LptFallback };
        let lb = lower_bound(&items, m);
        let imbalance = if lb > 0.0 {
            (r.assignment.c_max() / lb - 1.0).max(0.0)
        } else {
            0.0
        };
        Schedule {
            assignment: r.assignment,
            items,
            solver,
            elapsed: t0.elapsed(),
            imbalance,
        }
    }

    /// The data-agnostic strategy used by the baselines: random assignment
    /// into equally-*sized* buckets.
    pub fn schedule_random(
        &self,
        est: &Estimator,
        shapes: &[ItemShape],
        rng: &mut crate::util::rng::Rng,
    ) -> Schedule {
        let t0 = std::time::Instant::now();
        let items = self.item_costs(est, shapes);
        let m = self.theta.buckets().min(items.len().max(1));
        let assignment = random_assign(&items, m, rng);
        let lb = lower_bound(&items, m);
        let imbalance = if lb > 0.0 {
            (assignment.c_max() / lb - 1.0).max(0.0)
        } else {
            0.0
        };
        Schedule {
            assignment,
            items,
            solver: Solver::Random,
            elapsed: t0.elapsed(),
            imbalance,
        }
    }

    /// Feed execution feedback into Adaptive Correction: observed per-bucket
    /// LLM throughput vs the estimator's prediction (Eq 7), plus the
    /// realized benefit fraction for the cost-benefit toggle.
    pub fn feedback(
        &mut self,
        observations: &[(u64, f64, f64)],
        benefit_fraction: f64,
    ) {
        for &(bucket, actual, pred) in observations {
            self.correction.observe(bucket, actual, pred);
        }
        self.correction.end_iteration(benefit_fraction);
    }
}

/// Pure-LPT scheduling (for ablations / Fig 16b comparison).
pub fn schedule_lpt_only(items: &[ItemCost], m: usize) -> Schedule {
    let t0 = std::time::Instant::now();
    let assignment = lpt(items, m);
    let lb = lower_bound(items, m);
    let imbalance = if lb > 0.0 {
        (assignment.c_max() / lb - 1.0).max(0.0)
    } else {
        0.0
    };
    Schedule {
        assignment,
        items: items.to_vec(),
        solver: Solver::LptFallback,
        elapsed: t0.elapsed(),
        imbalance,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llava_ov, llama3};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfiler, ProfilerGrids};
    use crate::scheduler::correction::{Correction, CorrectionConfig};

    fn theta() -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 2 },
            llm: ModPar { tp: 2, pp: 3, dp: 1 },
            n_mb: 4,
        }
    }

    fn scheduler() -> OnlineScheduler {
        OnlineScheduler::new(
            theta(),
            SchedulerConfig::default(),
            Correction::new(CorrectionConfig::default()),
        )
    }

    fn est_fixture() -> (crate::model::catalog::Mllm, crate::profiling::engine::ModelProfile)
    {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth);
        let p = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
        (m, p)
    }

    #[test]
    fn scheduled_partition_beats_random() {
        let (m, p) = est_fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(42).shaped_batch(&m, 32);
        let s = scheduler();
        let sched = s.schedule(&est, &shapes);
        let mut rng = crate::util::rng::Rng::new(5);
        let rand = s.schedule_random(&est, &shapes, &mut rng);
        assert!(sched.assignment.is_partition(32));
        assert!(
            sched.assignment.c_max() < rand.assignment.c_max(),
            "sched {} rand {}",
            sched.assignment.c_max(),
            rand.assignment.c_max()
        );
    }

    #[test]
    fn imbalance_near_zero_for_scheduled() {
        let (m, p) = est_fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(43).shaped_batch(&m, 64);
        let sched = scheduler().schedule(&est, &shapes);
        // Paper: <1% from the lower bound even after fallback; allow 10%
        // for tiny instances.
        assert!(sched.imbalance < 0.10, "imbalance {}", sched.imbalance);
    }

    #[test]
    fn bucket_count_is_theta_m() {
        let (m, p) = est_fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(44).shaped_batch(&m, 40);
        let sched = scheduler().schedule(&est, &shapes);
        assert_eq!(sched.assignment.buckets.len(), theta().buckets());
    }

    #[test]
    fn correction_shifts_item_costs() {
        let (m, p) = est_fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(45).shaped_batch(&m, 8);
        let mut s = scheduler();
        let before = s.item_costs(&est, &shapes);
        // Report that every LLM bucket runs at half the predicted speed.
        let obs: Vec<(u64, f64, f64)> = shapes
            .iter()
            .map(|sh| (Truth::llm_bucket(sh.llm_seq as f64), 0.5, 1.0))
            .collect();
        s.feedback(&obs, 0.5);
        s.feedback(&obs, 0.5);
        let after = s.item_costs(&est, &shapes);
        for (b, a) in before.iter().zip(&after) {
            assert!(a.llm > 1.5 * b.llm, "correction not applied: {} -> {}", b.llm, a.llm);
            assert_eq!(a.enc, b.enc);
        }
    }

    #[test]
    fn tiny_batches_clamp_bucket_count() {
        let (m, p) = est_fixture();
        let est = Estimator::new(&m, &p.throughput);
        let shapes = Dataset::mixed(46).shaped_batch(&m, 2);
        let sched = scheduler().schedule(&est, &shapes);
        assert!(sched.assignment.is_partition(2));
        assert_eq!(sched.assignment.buckets.len(), 2);
    }
}
