//! Synthetic equivalents of the paper's Table 2 data sources.
//!
//! The real corpora (LLaVA-Wild, AI2D, InfographicVQA, M4-Instruct,
//! LLaVA-Video) are not available offline; DFLOP only consumes their *input
//! shape distributions*, so each source is modeled as a parametric sampler
//! whose qualitative shape matches the paper's Fig 11b characterization:
//! single-image sources are narrow, multi-image sources are moderate, video
//! is broad/heavy-tailed, and the mixed dataset is the weighted union.

use crate::data::item::{Payload, RawItem};
use crate::util::rng::Rng;

/// A parametric source of raw items.
#[derive(Clone, Debug)]
pub struct Source {
    pub name: &'static str,
    /// Table-2 sample count (used as the mixture weight).
    pub samples: u64,
    pub kind: SourceKind,
}

#[derive(Clone, Debug)]
pub enum SourceKind {
    /// Single image with anyres tiling: `1 + grid` tiles, grid uniform in
    /// `[min_grid, max_grid]`; text tokens lognormal.
    SingleImage {
        min_grid: u32,
        max_grid: u32,
        text_mu: f64,
        text_sigma: f64,
    },
    /// Multi-image instance: image count uniform in `[min, max]`.
    MultiImage {
        min_images: u32,
        max_images: u32,
        text_mu: f64,
        text_sigma: f64,
    },
    /// Video with frame count lognormal, clamped to `[min, max]`.
    Video {
        frame_mu: f64,
        frame_sigma: f64,
        min_frames: u32,
        max_frames: u32,
        text_mu: f64,
        text_sigma: f64,
    },
    /// Audio clip with duration lognormal, clamped to `[min, max]` seconds.
    Audio {
        sec_mu: f64,
        sec_sigma: f64,
        min_sec: u32,
        max_sec: u32,
        text_mu: f64,
        text_sigma: f64,
    },
}

fn text_tokens(rng: &mut Rng, mu: f64, sigma: f64) -> u32 {
    rng.lognormal(mu, sigma).round().clamp(8.0, 8192.0) as u32
}

impl Source {
    /// Sample one raw item from this source.
    pub fn sample(&self, rng: &mut Rng, source_idx: u8) -> RawItem {
        match &self.kind {
            SourceKind::SingleImage { min_grid, max_grid, text_mu, text_sigma } => {
                let grid = rng.range(*min_grid as i64, *max_grid as i64) as u32;
                RawItem {
                    payload: Payload::SingleImage { tiles: 1 + grid },
                    text_tokens: text_tokens(rng, *text_mu, *text_sigma),
                    source: source_idx,
                }
            }
            SourceKind::MultiImage { min_images, max_images, text_mu, text_sigma } => {
                let images =
                    rng.range(*min_images as i64, *max_images as i64) as u32;
                RawItem {
                    payload: Payload::MultiImage { images },
                    text_tokens: text_tokens(rng, *text_mu, *text_sigma),
                    source: source_idx,
                }
            }
            SourceKind::Video {
                frame_mu,
                frame_sigma,
                min_frames,
                max_frames,
                text_mu,
                text_sigma,
            } => {
                let frames = rng
                    .lognormal(*frame_mu, *frame_sigma)
                    .round()
                    .clamp(*min_frames as f64, *max_frames as f64)
                    as u32;
                RawItem {
                    payload: Payload::Video { frames },
                    text_tokens: text_tokens(rng, *text_mu, *text_sigma),
                    source: source_idx,
                }
            }
            SourceKind::Audio { sec_mu, sec_sigma, min_sec, max_sec, text_mu, text_sigma } => {
                let seconds = rng
                    .lognormal(*sec_mu, *sec_sigma)
                    .round()
                    .clamp(*min_sec as f64, *max_sec as f64)
                    as u32;
                RawItem {
                    payload: Payload::Audio { seconds },
                    text_tokens: text_tokens(rng, *text_mu, *text_sigma),
                    source: source_idx,
                }
            }
        }
    }
}

/// Table 2's five sources with shape parameters chosen to mirror the paper's
/// qualitative distributions (Fig 11b).
pub fn table2_sources() -> Vec<Source> {
    vec![
        // LLaVA-Wild: in-the-wild photos, moderate anyres tiling, chatty
        // responses.
        Source {
            name: "LLaVA-Wild",
            samples: 28_000,
            kind: SourceKind::SingleImage {
                min_grid: 1,
                max_grid: 6,
                text_mu: 5.3, // median ≈ 200 tokens
                text_sigma: 0.5,
            },
        },
        // AI2D: diagrams, mostly low-resolution → few tiles, short QA text.
        Source {
            name: "AI2D",
            samples: 18_000,
            kind: SourceKind::SingleImage {
                min_grid: 0,
                max_grid: 3,
                text_mu: 4.4, // median ≈ 80 tokens
                text_sigma: 0.4,
            },
        },
        // InfographicVQA: tall high-resolution infographics → many tiles.
        Source {
            name: "Infographic VQA",
            samples: 19_000,
            kind: SourceKind::SingleImage {
                min_grid: 4,
                max_grid: 11,
                text_mu: 4.6,
                text_sigma: 0.4,
            },
        },
        // M4-Instruct: interleaved multi-image, 2–8 images.
        Source {
            name: "M4-Instruct",
            samples: 60_000,
            kind: SourceKind::MultiImage {
                min_images: 2,
                max_images: 8,
                text_mu: 5.0,
                text_sigma: 0.5,
            },
        },
        // LLaVA-Video: 8–64 sampled frames, heavy-tailed.
        Source {
            name: "LLaVA-Video",
            samples: 60_000,
            kind: SourceKind::Video {
                frame_mu: 3.3, // median ≈ 27 frames
                frame_sigma: 0.55,
                min_frames: 8,
                max_frames: 64,
                text_mu: 5.2,
                text_sigma: 0.5,
            },
        },
    ]
}

/// Piecewise-constant schedule of per-source weight multipliers over
/// training iterations — the non-stationary scenarios the `stream`
/// subsystem reacts to. Real multimodal curricula are non-stationary
/// (phase-scheduled mixtures, bursty web scrapes, sources exhausting
/// early); a schedule models that by scaling each source's Table-2
/// mixture weight as a function of the global-batch index.
#[derive(Clone, Debug)]
pub struct MixSchedule {
    /// `(start_iteration, per-source weight multipliers)`, sorted by
    /// strictly increasing start. The first segment also covers any
    /// iterations before its own start.
    pub segments: Vec<(usize, Vec<f64>)>,
}

impl MixSchedule {
    pub fn new(segments: Vec<(usize, Vec<f64>)>) -> MixSchedule {
        assert!(!segments.is_empty(), "empty schedule");
        assert!(
            segments.windows(2).all(|w| w[0].0 < w[1].0),
            "schedule segments must have strictly increasing starts"
        );
        assert!(
            segments
                .iter()
                .all(|(_, m)| m.iter().all(|&x| x >= 0.0) && m.iter().sum::<f64>() > 0.0),
            "multipliers must be non-negative with positive total"
        );
        MixSchedule { segments }
    }

    /// Multipliers in effect at `iteration` (the last segment at or
    /// before it).
    pub fn multipliers(&self, iteration: usize) -> &[f64] {
        let mut cur = &self.segments[0].1;
        for (start, m) in &self.segments {
            if *start <= iteration {
                cur = m;
            } else {
                break;
            }
        }
        cur
    }
}

/// Curriculum text→video ramp over the five Table-2 sources
/// `[Wild, AI2D, Info, M4, Video]`: an image-heavy warm-up phase, a short
/// ramp, then a video-dominated steady state — the canonical
/// phase-scheduled curriculum that silently invalidates a frozen θ*.
pub fn curriculum_schedule() -> MixSchedule {
    MixSchedule::new(vec![
        (0, vec![1.5, 2.0, 1.5, 1.0, 0.05]),
        (7, vec![1.0, 1.0, 1.0, 1.0, 0.6]),
        (9, vec![0.5, 0.4, 0.5, 0.8, 2.0]),
        (11, vec![0.25, 0.2, 0.25, 0.5, 4.0]),
    ])
}

/// Recurring video bursts over a mixed baseline (a web-scrape pipeline
/// delivering video dumps in batches).
pub fn bursty_video_schedule() -> MixSchedule {
    let base = vec![1.0, 1.0, 1.0, 1.0, 1.0];
    let burst = vec![0.15, 0.15, 0.15, 0.3, 6.0];
    MixSchedule::new(vec![
        (0, base.clone()),
        (8, burst.clone()),
        (12, base.clone()),
        (20, burst),
        (24, base),
    ])
}

/// Modality dropout: the video source exhausts mid-run and its weight
/// collapses to zero, leaving an image-only remainder.
pub fn modality_dropout_schedule() -> MixSchedule {
    MixSchedule::new(vec![
        (0, vec![1.0, 1.0, 1.0, 1.0, 1.0]),
        (10, vec![1.5, 1.5, 1.5, 1.5, 0.0]),
    ])
}

/// Per-shard mixture description for the sharded data-parallel layer
/// (`shard::partition`): every DP rank draws from its own reweighted
/// Table-2 mixture, optionally with its own [`MixSchedule`]. This is the
/// *cross-replica* analogue of the per-batch heterogeneity above — when
/// shards differ, the allreduce barrier runs at the pace of the slowest
/// replica, which is the skew `shard::balance` exists to remove.
#[derive(Clone, Debug)]
pub struct ShardScenario {
    pub name: &'static str,
    /// `mults[r]` = shard r's per-source weight multipliers over the
    /// Table-2 base weights (all rows have Table-2 arity).
    pub mults: Vec<Vec<f64>>,
    /// Optional per-shard schedule on top of the static multipliers
    /// (the hot-shard burst).
    pub schedules: Vec<Option<MixSchedule>>,
}

/// Graded skew: shard 0 is video-dominated (expensive long-sequence
/// items), the last shard is short-image-dominated, with a linear tilt in
/// between — a stationary heterogeneity that makes static sharding pay a
/// persistent straggler gap every step.
pub fn skewed_shard_scenario(shards: usize) -> ShardScenario {
    assert!(shards >= 1, "scenario needs at least one shard");
    let mults = (0..shards)
        .map(|r| {
            // t = 0 at the video-heavy end, 1 at the image-heavy end.
            let t = if shards > 1 { r as f64 / (shards - 1) as f64 } else { 0.5 };
            vec![
                0.3 + 1.7 * t, // LLaVA-Wild
                0.3 + 1.7 * t, // AI2D
                0.3 + 1.2 * t, // Infographic VQA
                0.5 + 0.5 * t, // M4-Instruct
                4.0 - 3.95 * t, // LLaVA-Video
            ]
        })
        .collect();
    ShardScenario {
        name: "skewed-shard",
        mults,
        schedules: vec![None; shards],
    }
}

/// One persistent laggard: shard 0 draws almost exclusively video while
/// every other shard sees a slightly video-light mixture — the single
/// slow replica that gates the whole step under static sharding.
pub fn laggard_shard_scenario(shards: usize) -> ShardScenario {
    assert!(shards >= 1, "scenario needs at least one shard");
    let mut mults = vec![vec![1.2, 1.2, 1.2, 1.2, 0.3]; shards];
    mults[0] = vec![0.1, 0.1, 0.1, 0.2, 6.0];
    ShardScenario {
        name: "laggard-shard",
        mults,
        schedules: vec![None; shards],
    }
}

/// One shard turns hot mid-run: all shards start on the plain Table-2
/// mixture, then shard 0's web-scrape pipeline hands it a persistent
/// video dump from batch 8 on. The pooled distribution barely moves (the
/// shift is diluted by 1/shards), so the *global* drift aggregation stays
/// quiet while the skew gate + rebalancer absorb the hot shard.
pub fn hot_shard_scenario(shards: usize) -> ShardScenario {
    assert!(shards >= 1, "scenario needs at least one shard");
    let base = vec![1.0; 5];
    let mut schedules: Vec<Option<MixSchedule>> = vec![None; shards];
    schedules[0] = Some(MixSchedule::new(vec![
        (0, base.clone()),
        (8, vec![0.15, 0.15, 0.15, 0.3, 6.0]),
    ]));
    ShardScenario {
        name: "hot-shard",
        mults: vec![base; shards],
        schedules,
    }
}

/// The control: statistically identical shards (independent streams of
/// the same Table-2 mixture). The sharded system must stay completely
/// quiet here — zero migrations, zero replans.
pub fn homogeneous_shard_scenario(shards: usize) -> ShardScenario {
    assert!(shards >= 1, "scenario needs at least one shard");
    ShardScenario {
        name: "homogeneous-shard",
        mults: vec![vec![1.0; 5]; shards],
        schedules: vec![None; shards],
    }
}

/// Fig 9's audio workload (Qwen2-Audio): speech clips.
pub fn audio_sources() -> Vec<Source> {
    vec![Source {
        name: "Audio-Mix",
        samples: 100_000,
        kind: SourceKind::Audio {
            sec_mu: 2.5, // median ≈ 12 s
            sec_sigma: 0.6,
            min_sec: 2,
            max_sec: 30,
            text_mu: 4.8,
            text_sigma: 0.5,
        },
    }]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_selects_segment_by_iteration() {
        let s = MixSchedule::new(vec![
            (0, vec![1.0, 1.0]),
            (5, vec![2.0, 0.5]),
            (9, vec![0.0, 4.0]),
        ]);
        assert_eq!(s.multipliers(0), &[1.0, 1.0]);
        assert_eq!(s.multipliers(4), &[1.0, 1.0]);
        assert_eq!(s.multipliers(5), &[2.0, 0.5]);
        assert_eq!(s.multipliers(8), &[2.0, 0.5]);
        assert_eq!(s.multipliers(9), &[0.0, 4.0]);
        assert_eq!(s.multipliers(1000), &[0.0, 4.0]);
    }

    #[test]
    fn scenario_schedules_match_table2_arity() {
        let n = table2_sources().len();
        for sched in [
            curriculum_schedule(),
            bursty_video_schedule(),
            modality_dropout_schedule(),
        ] {
            for (_, m) in &sched.segments {
                assert_eq!(m.len(), n);
            }
        }
        // The curriculum really ramps: video multiplier grows
        // monotonically across segments while image ones shrink.
        let c = curriculum_schedule();
        let video: Vec<f64> = c.segments.iter().map(|(_, m)| m[4]).collect();
        assert!(video.windows(2).all(|w| w[0] < w[1]), "{video:?}");
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn schedule_rejects_unsorted_segments() {
        MixSchedule::new(vec![(3, vec![1.0]), (3, vec![1.0])]);
    }

    #[test]
    fn shard_scenarios_have_table2_arity_and_expected_shape() {
        let n = table2_sources().len();
        for shards in [1usize, 2, 4, 8] {
            for sc in [
                skewed_shard_scenario(shards),
                laggard_shard_scenario(shards),
                hot_shard_scenario(shards),
                homogeneous_shard_scenario(shards),
            ] {
                assert_eq!(sc.mults.len(), shards, "{}", sc.name);
                assert_eq!(sc.schedules.len(), shards, "{}", sc.name);
                for m in &sc.mults {
                    assert_eq!(m.len(), n, "{}", sc.name);
                    assert!(m.iter().all(|&x| x >= 0.0) && m.iter().sum::<f64>() > 0.0);
                }
            }
        }
        // The graded tilt really tilts: video weight strictly decreases
        // across shards while the image weights grow.
        let sc = skewed_shard_scenario(4);
        let video: Vec<f64> = sc.mults.iter().map(|m| m[4]).collect();
        assert!(video.windows(2).all(|w| w[0] > w[1]), "{video:?}");
        let wild: Vec<f64> = sc.mults.iter().map(|m| m[0]).collect();
        assert!(wild.windows(2).all(|w| w[0] < w[1]), "{wild:?}");
        // Laggard: exactly one heavy shard.
        let sc = laggard_shard_scenario(4);
        assert!(sc.mults[0][4] > 4.0);
        assert!(sc.mults[1..].iter().all(|m| m[4] < 1.0));
        // Hot shard: only shard 0 is scheduled, and its burst raises the
        // video multiplier.
        let sc = hot_shard_scenario(4);
        assert!(sc.schedules[0].is_some());
        assert!(sc.schedules[1..].iter().all(Option::is_none));
        let sched = sc.schedules[0].as_ref().expect("hot schedule");
        assert!(sched.multipliers(100)[4] > sched.multipliers(0)[4]);
    }

    #[test]
    fn table2_composition_matches_paper() {
        let srcs = table2_sources();
        assert_eq!(srcs.len(), 5);
        let total: u64 = srcs.iter().map(|s| s.samples).sum();
        assert_eq!(total, 185_000);
        assert_eq!(srcs[3].name, "M4-Instruct");
        assert_eq!(srcs[3].samples, 60_000);
    }

    #[test]
    fn samples_respect_bounds() {
        let srcs = table2_sources();
        let mut rng = Rng::new(42);
        for (i, s) in srcs.iter().enumerate() {
            for _ in 0..500 {
                let item = s.sample(&mut rng, i as u8);
                assert!(item.text_tokens >= 8);
                match item.payload {
                    Payload::SingleImage { tiles } => {
                        assert!((1..=12).contains(&tiles), "{}: {tiles}", s.name)
                    }
                    Payload::MultiImage { images } => {
                        assert!((2..=8).contains(&images))
                    }
                    Payload::Video { frames } => {
                        assert!((8..=64).contains(&frames))
                    }
                    other => panic!("unexpected payload {other:?}"),
                }
            }
        }
    }

    #[test]
    fn video_is_heavier_tailed_than_single_image() {
        let srcs = table2_sources();
        let spread = |s: &Source| {
            let mut rng = Rng::new(7);
            let units: Vec<f64> = (0..2000)
                .map(|i| match s.sample(&mut rng, i as u8).payload {
                    Payload::SingleImage { tiles } => tiles as f64,
                    Payload::MultiImage { images } => images as f64,
                    Payload::Video { frames } => frames as f64,
                    _ => 0.0,
                })
                .collect();
            crate::util::stats::Summary::of(&units)
        };
        let wild = spread(&srcs[0]);
        let video = spread(&srcs[4]);
        assert!(video.std > 2.0 * wild.std, "video std {} wild std {}", video.std, wild.std);
    }
}
