//! Data items and their model-specific input shapes.
//!
//! DFLOP never looks at pixels or waveforms — only at *input shapes*
//! (§3.2.2: the Data Profiler computes "the precise input shapes for each
//! sampled item within the target architecture"). A raw [`RawItem`] carries
//! modality-level counts (tiles, images, frames, audio seconds, text
//! tokens); [`shape_for`] applies an architecture's preprocessing to produce
//! the [`ItemShape`] the rest of the system reasons about.

use crate::model::catalog::{Mllm, Modality};

/// The visual/audio payload of a training instance, before preprocessing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Payload {
    /// One image, already expressed as the number of anyres tiles the
    /// architecture's dynamic-resolution pipeline produces (base + grid).
    SingleImage { tiles: u32 },
    /// Interleaved multi-image instance: `images` images, each one tile.
    MultiImage { images: u32 },
    /// A video: `frames` sampled frames.
    Video { frames: u32 },
    /// An audio clip of `seconds` seconds.
    Audio { seconds: u32 },
    /// Pure text (no encoder work).
    TextOnly,
}

/// A raw training instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RawItem {
    pub payload: Payload,
    /// Text tokens (prompt + answer).
    pub text_tokens: u32,
    /// Which Table-2 source the item was drawn from (index into the
    /// mixture; used for per-source statistics).
    pub source: u8,
}

/// Architecture-specific input shape of one item — the unit of work the
/// Profiling Engine, optimizer and scheduler all operate on.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ItemShape {
    /// Encoder effective batch contribution: number of vision/audio units
    /// (tiles, frames, audio-seconds) this item puts through the encoder.
    pub units: u32,
    /// LLM packed sequence length: connector outputs + text tokens.
    pub llm_seq: u32,
    /// Source index carried through for diagnostics.
    pub source: u8,
}

/// Apply an architecture's preprocessing to a raw item (§3.2.2: "the
/// varying input dimensions ... are strictly governed by the MLLM's
/// architecture and its data processing pipeline").
///
/// - LLaVA-OV: image tiles keep all 729 tokens (MLP connector); video
///   frames are additionally pooled ~4× (bilinear) before the LLM.
/// - InternVL-2.5: every tile is pixel-unshuffled 4× by the connector
///   (handled by `Connector::Pool` inside the model).
/// - Qwen2-Audio: 8× average-pool at the end of the encoder.
pub fn shape_for(m: &Mllm, item: &RawItem) -> ItemShape {
    let (units, visual_tokens): (u32, u32) = match (m.modality, item.payload) {
        (Modality::Vision, Payload::SingleImage { tiles }) => {
            (tiles, m.llm_visual_tokens(tiles as usize) as u32)
        }
        (Modality::Vision, Payload::MultiImage { images }) => {
            (images, m.llm_visual_tokens(images as usize) as u32)
        }
        (Modality::Vision, Payload::Video { frames }) => {
            // Video frames get an extra 4× token pool before the LLM
            // (LLaVA-OV's frame pooling; InternVL samples fewer tokens per
            // frame to the same effect).
            let per_frame = m.connector.llm_tokens(m.tokens_per_unit).div_ceil(4);
            (frames, frames * per_frame as u32)
        }
        (Modality::Audio, Payload::Audio { seconds }) => {
            (seconds, m.llm_visual_tokens(seconds as usize) as u32)
        }
        // Cross-modality payloads contribute no encoder work.
        (_, Payload::TextOnly) | (Modality::Audio, _) | (Modality::Vision, Payload::Audio { .. }) => {
            (0, 0)
        }
    };
    ItemShape {
        units,
        llm_seq: visual_tokens + item.text_tokens,
        source: item.source,
    }
}

impl ItemShape {
    /// Encoder fwd+bwd FLOP of this item under architecture `m`.
    pub fn encoder_flop(&self, m: &Mllm) -> f64 {
        if self.units == 0 {
            0.0
        } else {
            m.encoder_flop_total(self.units as usize)
        }
    }

    /// LLM fwd+bwd FLOP of this item under architecture `m`.
    pub fn llm_flop(&self, m: &Mllm) -> f64 {
        m.llm_flop_total(self.llm_seq as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{internvl_25, llava_ov, llama3, qwen25, qwen2_audio};

    #[test]
    fn llava_single_image_keeps_all_tokens() {
        let m = llava_ov(llama3("8b"));
        let item = RawItem {
            payload: Payload::SingleImage { tiles: 5 },
            text_tokens: 100,
            source: 0,
        };
        let s = shape_for(&m, &item);
        assert_eq!(s.units, 5);
        assert_eq!(s.llm_seq, 5 * 729 + 100);
    }

    #[test]
    fn internvl_tiles_are_pooled_4x() {
        let m = internvl_25(qwen25("72b"));
        let item = RawItem {
            payload: Payload::SingleImage { tiles: 4 },
            text_tokens: 0,
            source: 0,
        };
        let s = shape_for(&m, &item);
        assert_eq!(s.llm_seq, 4 * 256);
    }

    #[test]
    fn video_frames_pooled_extra_4x() {
        let m = llava_ov(llama3("8b"));
        let item = RawItem {
            payload: Payload::Video { frames: 32 },
            text_tokens: 50,
            source: 4,
        };
        let s = shape_for(&m, &item);
        assert_eq!(s.units, 32);
        assert_eq!(s.llm_seq, 32 * 183 + 50); // ceil(729/4) = 183
    }

    #[test]
    fn audio_model_ignores_vision_payload() {
        let m = qwen2_audio();
        let item = RawItem {
            payload: Payload::Video { frames: 8 },
            text_tokens: 77,
            source: 0,
        };
        let s = shape_for(&m, &item);
        assert_eq!(s.units, 0);
        assert_eq!(s.llm_seq, 77);
    }

    #[test]
    fn audio_payload_pools_8x() {
        let m = qwen2_audio();
        let item = RawItem {
            payload: Payload::Audio { seconds: 16 },
            text_tokens: 0,
            source: 0,
        };
        let s = shape_for(&m, &item);
        assert_eq!(s.units, 16);
        assert_eq!(s.llm_seq, 16 * 7); // ceil(50/8) = 7
    }

    #[test]
    fn flop_accessors_are_positive_and_monotone() {
        let m = llava_ov(llama3("8b"));
        let small = ItemShape { units: 1, llm_seq: 500, source: 0 };
        let big = ItemShape { units: 8, llm_seq: 4000, source: 0 };
        assert!(small.encoder_flop(&m) > 0.0);
        assert!(big.encoder_flop(&m) > small.encoder_flop(&m));
        assert!(big.llm_flop(&m) > small.llm_flop(&m));
    }
}
