//! Workload substrate: synthetic equivalents of the paper's datasets
//! (Table 2), per-architecture preprocessing into input shapes, and
//! deterministic batch streams.
pub mod dataset;
pub mod item;
pub mod sources;
