//! Dataset mixtures and batch streams.
//!
//! A [`Dataset`] is a weighted mixture of [`Source`]s (Table 2); it yields
//! deterministic global batches of raw items. The three Fig 11 workload
//! scenarios (multiple-image, video, mixed) are alternative mixtures over
//! the same sources.

use crate::data::item::{shape_for, ItemShape, RawItem};
use crate::data::sources::{
    audio_sources, bursty_video_schedule, curriculum_schedule, modality_dropout_schedule,
    table2_sources, MixSchedule, Source,
};
use crate::model::catalog::Mllm;
use crate::util::rng::Rng;

/// A weighted mixture of sources with a deterministic sampling stream.
///
/// With a [`MixSchedule`] attached the mixture is *non-stationary*: the
/// effective weights are the Table-2 base weights scaled by the
/// schedule's multipliers for the current global-batch index, refreshed
/// after every [`Dataset::batch`] / [`Dataset::shaped_batch`] call.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    pub sources: Vec<Source>,
    weights: Vec<f64>,
    base_weights: Vec<f64>,
    schedule: Option<MixSchedule>,
    /// Global-batch index the current weights correspond to.
    iteration: usize,
    rng: Rng,
}

impl Dataset {
    pub fn new(name: &str, sources: Vec<Source>, seed: u64) -> Dataset {
        let weights: Vec<f64> = sources.iter().map(|s| s.samples as f64).collect();
        Dataset {
            name: name.to_string(),
            sources,
            base_weights: weights.clone(),
            weights,
            schedule: None,
            iteration: 0,
            rng: Rng::new(seed),
        }
    }

    /// A mixture whose weights follow `schedule` over batch indices.
    pub fn scheduled(
        name: &str,
        sources: Vec<Source>,
        seed: u64,
        schedule: MixSchedule,
    ) -> Dataset {
        for (_, m) in &schedule.segments {
            assert_eq!(
                m.len(),
                sources.len(),
                "schedule arity must match source count"
            );
        }
        let mut d = Dataset::new(name, sources, seed);
        d.schedule = Some(schedule);
        d.refresh_weights();
        d
    }

    /// The paper's mixed dataset (Table 2: all five sources).
    pub fn mixed(seed: u64) -> Dataset {
        Dataset::new("mixed", table2_sources(), seed)
    }

    /// Fig 11's multiple-image scenario (M4-Instruct only).
    pub fn multi_image(seed: u64) -> Dataset {
        let m4 = table2_sources().into_iter().nth(3).expect("m4 source");
        Dataset::new("multiple-image", vec![m4], seed)
    }

    /// Fig 11's video scenario (LLaVA-Video only).
    pub fn video(seed: u64) -> Dataset {
        let v = table2_sources().into_iter().nth(4).expect("video source");
        Dataset::new("video", vec![v], seed)
    }

    /// Fig 9's audio workload.
    pub fn audio(seed: u64) -> Dataset {
        Dataset::new("audio", audio_sources(), seed)
    }

    /// Non-stationary scenario: curriculum text→video ramp.
    pub fn curriculum(seed: u64) -> Dataset {
        Dataset::scheduled("curriculum", table2_sources(), seed, curriculum_schedule())
    }

    /// Non-stationary scenario: recurring video bursts.
    pub fn bursty_video(seed: u64) -> Dataset {
        Dataset::scheduled(
            "bursty-video",
            table2_sources(),
            seed,
            bursty_video_schedule(),
        )
    }

    /// Non-stationary scenario: the video source exhausts mid-run.
    pub fn modality_dropout(seed: u64) -> Dataset {
        Dataset::scheduled(
            "modality-dropout",
            table2_sources(),
            seed,
            modality_dropout_schedule(),
        )
    }

    /// Look up a scenario by CLI key.
    pub fn by_key(key: &str, seed: u64) -> Option<Dataset> {
        match key {
            "mixed" => Some(Dataset::mixed(seed)),
            "multi-image" | "multiple-image" => Some(Dataset::multi_image(seed)),
            "video" => Some(Dataset::video(seed)),
            "audio" => Some(Dataset::audio(seed)),
            "curriculum" => Some(Dataset::curriculum(seed)),
            "bursty-video" => Some(Dataset::bursty_video(seed)),
            "modality-dropout" => Some(Dataset::modality_dropout(seed)),
            _ => None,
        }
    }

    /// Scale the mixture's per-source *base* weights by `mults` — the
    /// per-shard reweighting `shard::partition` applies. Composes with an
    /// attached [`MixSchedule`], whose multipliers keep applying on top of
    /// the scaled base.
    pub fn reweight(&mut self, mults: &[f64]) {
        assert_eq!(
            mults.len(),
            self.base_weights.len(),
            "reweight arity must match source count"
        );
        assert!(
            mults.iter().all(|&x| x >= 0.0),
            "weight multipliers must be non-negative"
        );
        for (w, m) in self.base_weights.iter_mut().zip(mults) {
            *w *= m;
        }
        assert!(
            self.base_weights.iter().sum::<f64>() > 0.0,
            "reweight zeroed the whole mixture"
        );
        self.weights.copy_from_slice(&self.base_weights);
        self.refresh_weights();
    }

    /// Total corpus size implied by the mixture (Table 2's sample counts).
    pub fn corpus_size(&self) -> u64 {
        self.sources.iter().map(|s| s.samples).sum()
    }

    /// Sample one raw item.
    pub fn sample(&mut self) -> RawItem {
        let idx = self.rng.categorical(&self.weights);
        self.sources[idx].sample(&mut self.rng, idx as u8)
    }

    /// Sample a global batch of `n` raw items (advances the schedule to
    /// the next batch index afterwards).
    pub fn batch(&mut self, n: usize) -> Vec<RawItem> {
        let out = (0..n).map(|_| self.sample()).collect();
        self.end_batch();
        out
    }

    /// Sample a global batch already preprocessed into shapes for `m`.
    pub fn shaped_batch(&mut self, m: &Mllm, n: usize) -> Vec<ItemShape> {
        let out = (0..n).map(|_| shape_for(m, &self.sample())).collect();
        self.end_batch();
        out
    }

    /// The global-batch index the *next* batch will be drawn at.
    pub fn iteration(&self) -> usize {
        self.iteration
    }

    fn end_batch(&mut self) {
        self.iteration += 1;
        self.refresh_weights();
    }

    fn refresh_weights(&mut self) {
        if let Some(sched) = &self.schedule {
            let mult = sched.multipliers(self.iteration);
            for (i, w) in self.weights.iter_mut().enumerate() {
                *w = self.base_weights[i] * mult[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3};

    #[test]
    fn mixture_proportions_track_table2() {
        let mut d = Dataset::mixed(123);
        let n = 50_000;
        let mut counts = [0u64; 5];
        for _ in 0..n {
            counts[d.sample().source as usize] += 1;
        }
        // 60k/185k ≈ 32.4% for M4 and Video, 28k/185k ≈ 15.1% for Wild.
        let frac = |i: usize| counts[i] as f64 / n as f64;
        assert!((frac(3) - 60.0 / 185.0).abs() < 0.01, "m4 {}", frac(3));
        assert!((frac(4) - 60.0 / 185.0).abs() < 0.01, "video {}", frac(4));
        assert!((frac(0) - 28.0 / 185.0).abs() < 0.01, "wild {}", frac(0));
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Dataset::mixed(9).batch(64);
        let b = Dataset::mixed(9).batch(64);
        assert_eq!(a.len(), b.len());
        a.iter().zip(&b).for_each(|(x, y)| assert_eq!(x, y));
    }

    #[test]
    fn scenarios_have_expected_heterogeneity_order() {
        // Fig 11b: multiple-image is narrow, video broad, mixed broadest
        // relative to its mean (bimodal). Compare LLM seq-len CV.
        let m = llava_ov(llama3("8b"));
        let cv = |mut d: Dataset| {
            let shapes = d.shaped_batch(&m, 4000);
            let seqs: Vec<f64> = shapes.iter().map(|s| s.llm_seq as f64).collect();
            crate::util::stats::Summary::of(&seqs).cv()
        };
        let multi = cv(Dataset::multi_image(5));
        let video = cv(Dataset::video(5));
        let mixed = cv(Dataset::mixed(5));
        assert!(video > multi, "video {video} multi {multi}");
        assert!(mixed > multi, "mixed {mixed} multi {multi}");
    }

    #[test]
    fn by_key_covers_scenarios() {
        for key in [
            "mixed",
            "multi-image",
            "video",
            "audio",
            "curriculum",
            "bursty-video",
            "modality-dropout",
        ] {
            assert!(Dataset::by_key(key, 1).is_some(), "{key}");
        }
        assert!(Dataset::by_key("bogus", 1).is_none());
    }

    #[test]
    fn scheduled_mixture_shifts_over_iterations() {
        // The curriculum ramp: video share grows from a few percent to a
        // clear majority as batches advance through the schedule.
        let mut d = Dataset::curriculum(3);
        let video_share = |batch: &[RawItem]| {
            batch.iter().filter(|i| i.source == 4).count() as f64 / batch.len() as f64
        };
        let early = video_share(&d.batch(2000));
        assert_eq!(d.iteration(), 1);
        for _ in 1..12 {
            d.batch(64);
        }
        let late = video_share(&d.batch(2000)); // iteration 12, final phase
        assert!(early < 0.08, "early video share {early}");
        assert!(late > 0.5, "late video share {late}");

        // Dropout: the video source disappears entirely after its cut.
        let mut d = Dataset::modality_dropout(3);
        for _ in 0..11 {
            d.batch(16);
        }
        assert_eq!(video_share(&d.batch(2000)), 0.0);
    }

    #[test]
    fn unscheduled_mixture_is_stationary() {
        // Batch-boundary advancement must not change a plain mixture's
        // stream: two datasets drawing the same total in different batch
        // splits see identical items.
        let mut a = Dataset::mixed(17);
        let mut b = Dataset::mixed(17);
        let one: Vec<RawItem> = a.batch(64);
        let mut two = b.batch(32);
        two.extend(b.batch(32));
        assert_eq!(one, two);
    }

    #[test]
    fn corpus_size_matches_paper_total() {
        assert_eq!(Dataset::mixed(1).corpus_size(), 185_000);
    }

    #[test]
    fn reweight_shifts_mixture_and_composes_with_schedule() {
        // Zeroing everything but the video source leaves a video-only
        // stream.
        let mut d = Dataset::mixed(5);
        d.reweight(&[0.0, 0.0, 0.0, 0.0, 1.0]);
        assert!(d.batch(500).iter().all(|i| i.source == 4));

        // A reweighted *scheduled* mixture still follows its schedule: the
        // modality-dropout cut at batch 10 kills video even after a
        // video-boosting reweight.
        let mut d = Dataset::modality_dropout(5);
        d.reweight(&[1.0, 1.0, 1.0, 1.0, 3.0]);
        let early = d.batch(500);
        assert!(early.iter().filter(|i| i.source == 4).count() > 200);
        for _ in 1..10 {
            d.batch(16);
        }
        assert!(d.batch(500).iter().all(|i| i.source != 4));
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn reweight_rejects_wrong_arity() {
        Dataset::mixed(1).reweight(&[1.0, 1.0]);
    }
}
