//! CI bench-regression gate: check a `dflop-bench-v1` JSON document
//! against the named in-binary speedup claims.
//!
//! Every expectation is a (numerator row, denominator row, max ratio)
//! triple over `mean_s` of two benches from the *same* run — paired rows
//! measured in one process on one machine, so the ratio cancels the
//! host's absolute speed and stays meaningful even in quick mode. The
//! current claims:
//!
//! - delta re-sim ≤ ⅓ of full re-sim on a single-bucket edit stream
//!   (`pipeline_bench`, the PR-6 tentpole's ≥3× target),
//! - batched θ-candidate evaluation ≤ serial evaluation
//!   (`optimizer_bench`),
//! - warm replan from the incumbent ≤ cold optimize (`stream_bench`),
//! - under the skewed-churn `FaultTrace` the fault-aware fleet sustains
//!   a strictly faster mean step AND a strictly smaller worst straggler
//!   gap than the static-θ* arm (`fault_bench`, the PR-7 acceptance —
//!   these rows are *simulated* seconds from paired runs replaying the
//!   identical trace, so the ratio is exactly reproducible),
//! - switching the observability recorder fully on leaves the simulated
//!   mean step within 1.02× of the recorder-off run (`obs_bench`, the
//!   PR-8 zero-overhead seam — the paired rows are simulated seconds and
//!   bit-identical by contract, so any ratio above 1.0 means the
//!   recorder fed a value back into the simulation),
//! - the audit's counterfactual pricer re-prices realized batches via
//!   delta replay at ≤ ½ the cost of a fresh tracked re-simulation per
//!   batch (`audit_bench`, the PR-9 claim that post-run replan
//!   attribution needs no new simulations — the bench itself asserts
//!   the two paths agree to the bit before timing them),
//! - bubble-filling interleaved execution strictly beats plain DFLOP on
//!   the video-heavy mixture: mean step ≤ 0.999× AND mean iteration
//!   bubble fraction strictly lower (`interleave_bench`, the PR-10
//!   acceptance — simulated seconds from paired runs sharing the seed
//!   and a provably-optimal ILP regime, so the ratios are exactly
//!   reproducible).
//!
//! A missing row is a hard error, not a skip: renaming a bench silently
//! would otherwise disarm the gate. Exit code 1 on any violation, 2 on
//! usage/parse errors; `rust/scripts/bench_gate.sh` regenerates the
//! document and runs this binary, and CI fails the workflow on its exit
//! status.

use dflop::util::json::{parse, Json};
use std::process::ExitCode;

struct Expect {
    target: &'static str,
    numerator: &'static str,
    denominator: &'static str,
    max_ratio: f64,
    claim: &'static str,
}

const EXPECTATIONS: &[Expect] = &[
    Expect {
        target: "pipeline_bench",
        numerator: "delta re-sim x64 single-bucket edits (256x16)",
        denominator: "full re-sim x64 single-bucket edits (256x16)",
        max_ratio: 1.0 / 3.0,
        claim: "delta re-sim >= 3x faster than full re-sim per edit",
    },
    Expect {
        target: "optimizer_bench",
        numerator: "refine 48 candidates, batched (gbs 512)",
        denominator: "refine 48 candidates, serial (gbs 512)",
        max_ratio: 1.0,
        claim: "batched candidate evaluation no slower than serial",
    },
    Expect {
        target: "stream_bench",
        numerator: "warm replan from incumbent theta*",
        denominator: "cold optimize (8 GPUs, gbs 64)",
        max_ratio: 1.0,
        claim: "warm replan no slower than a cold optimize",
    },
    Expect {
        target: "fault_bench",
        numerator: "fleet mean step, fault-aware (skewed-churn, 4 shards)",
        denominator: "fleet mean step, static theta (skewed-churn, 4 shards)",
        max_ratio: 0.999,
        claim: "fault-aware replanning sustains higher throughput under churn",
    },
    Expect {
        target: "fault_bench",
        numerator: "fleet worst straggler gap, fault-aware (skewed-churn, 4 shards)",
        denominator: "fleet worst straggler gap, static theta (skewed-churn, 4 shards)",
        max_ratio: 0.999,
        claim: "fault-aware replanning shrinks the worst straggler gap under churn",
    },
    Expect {
        target: "obs_bench",
        numerator: "fleet mean step, recorder on (skewed-churn, 4 shards)",
        denominator: "fleet mean step, recorder off (skewed-churn, 4 shards)",
        max_ratio: 1.02,
        claim: "switching the recorder on leaves the simulated step unchanged",
    },
    Expect {
        target: "audit_bench",
        numerator: "cf pricing x64 batches, delta replay (gbs 64)",
        denominator: "cf pricing x64 batches, fresh re-sim (gbs 64)",
        max_ratio: 0.5,
        claim: "counterfactual pricing via delta replay >= 2x faster than fresh re-sim",
    },
    Expect {
        target: "interleave_bench",
        numerator: "mean step, interleaved (video, InternVL 6B enc)",
        denominator: "mean step, plain dflop (video, InternVL 6B enc)",
        max_ratio: 0.999,
        claim: "bubble-filling interleaved execution beats plain DFLOP on video",
    },
    Expect {
        target: "interleave_bench",
        numerator: "bubble fraction, interleaved (video, InternVL 6B enc)",
        denominator: "bubble fraction, plain dflop (video, InternVL 6B enc)",
        max_ratio: 0.999,
        claim: "bubble-filling strictly shrinks the iteration bubble fraction",
    },
];

fn mean_of(rows: &[Json], target: &str, bench: &str) -> Result<f64, String> {
    for row in rows {
        let t = row.get("target").and_then(Json::as_str);
        let b = row.get("bench").and_then(Json::as_str);
        if t == Some(target) && b == Some(bench) {
            return row
                .get("mean_s")
                .and_then(Json::as_f64)
                .filter(|m| m.is_finite() && *m > 0.0)
                .ok_or_else(|| {
                    format!("row {target} / {bench:?} has no positive finite mean_s")
                });
        }
    }
    Err(format!("missing row: target={target} bench={bench:?}"))
}

fn run() -> Result<bool, String> {
    let path = std::env::args()
        .nth(1)
        .ok_or_else(|| "usage: dflop-bench-compare <bench.json>".to_string())?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| format!("cannot read {path}: {e}"))?;
    let doc = parse(&text).map_err(|e| format!("cannot parse {path}: {e:?}"))?;
    if doc.get("schema").and_then(Json::as_str) != Some("dflop-bench-v1") {
        return Err(format!("{path}: not a dflop-bench-v1 document"));
    }
    let rows = doc
        .get("results")
        .and_then(Json::as_arr)
        .ok_or_else(|| format!("{path}: no results array"))?;

    println!("bench-regression gate over {path}:");
    let mut ok = true;
    for e in EXPECTATIONS {
        let num = mean_of(rows, e.target, e.numerator)?;
        let den = mean_of(rows, e.target, e.denominator)?;
        let ratio = num / den;
        let pass = ratio <= e.max_ratio;
        ok &= pass;
        println!(
            "  [{}] {:14} {:<52} ratio {:.3} (max {:.3})  # {}",
            if pass { "PASS" } else { "FAIL" },
            e.target,
            e.numerator,
            ratio,
            e.max_ratio,
            e.claim,
        );
    }
    Ok(ok)
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => {
            println!("all bench expectations hold");
            ExitCode::SUCCESS
        }
        Ok(false) => {
            eprintln!("bench regression detected (see FAIL rows above)");
            ExitCode::from(1)
        }
        Err(msg) => {
            eprintln!("error: {msg}");
            ExitCode::from(2)
        }
    }
}
