//! MLLM architecture catalog and closed-form compute/memory accounting.
pub mod arch;
pub mod catalog;
