//! Catalog of the MLLM configurations evaluated in the paper (Table 3 plus
//! the Fig 9 audio model), with per-item FLOP / memory closed forms.
//!
//! Each `Mllm` couples a modality-encoder tower, a connector, and an LLM
//! tower, and knows how the architecture's preprocessing maps a raw data
//! item (images / video frames / audio seconds / text tokens) to the two
//! shapes DFLOP reasons about: the encoder *effective batch size* (number of
//! vision units) and the LLM *packed sequence length* (§3.2.2).

use super::arch::{Connector, Tower, MODEL_STATE_BYTES_PER_PARAM};

/// Modality of the non-text tower.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Modality {
    Vision,
    Audio,
}

/// A full multimodal model: encoder → connector → LLM.
#[derive(Clone, Debug)]
pub struct Mllm {
    pub name: &'static str,
    pub modality: Modality,
    pub encoder: Tower,
    pub connector: Connector,
    pub llm: Tower,
    /// Tokens the encoder produces per vision unit (image tile / video
    /// frame / 30 ms audio hop group) — fixed per architecture (§3.2.1:
    /// "E_seq_len remains fixed for the modality encoder").
    pub tokens_per_unit: usize,
    /// MLP matrices per layer in each tower (2 = classic, 3 = gated).
    pub enc_mlp_matrices: usize,
    pub llm_mlp_matrices: usize,
}

impl Mllm {
    // ---------------- FLOP accounting (per data item) ----------------

    /// Forward FLOP of the encoder for an item with `units` vision units.
    /// Each unit is an independent sequence of `tokens_per_unit` tokens, so
    /// attention is quadratic per unit, linear in the number of units.
    pub fn encoder_flop_fwd(&self, units: usize) -> f64 {
        let s = self.tokens_per_unit as f64;
        let tokens = units as f64 * s;
        self.encoder
            .linear_flop_fwd(tokens, self.encoder.layers as f64, self.enc_mlp_matrices)
            + units as f64
                * self.encoder.attn_flop_fwd(s, self.encoder.layers as f64)
    }

    /// Forward FLOP of the LLM for an item whose packed sequence length is
    /// `seq` (visual tokens after the connector + text tokens). Sequence
    /// packing keeps batch = 1; attention remains per-item quadratic.
    pub fn llm_flop_fwd(&self, seq: usize) -> f64 {
        let s = seq as f64;
        self.llm
            .linear_flop_fwd(s, self.llm.layers as f64, self.llm_mlp_matrices)
            + self.llm.attn_flop_fwd(s, self.llm.layers as f64)
    }

    /// fwd+bwd multiplier: backward is ~2× forward (paper Fig 1).
    pub const BWD_FACTOR: f64 = 2.0;

    /// Total (fwd+bwd) encoder FLOP for an item.
    pub fn encoder_flop_total(&self, units: usize) -> f64 {
        self.encoder_flop_fwd(units) * (1.0 + Self::BWD_FACTOR)
    }

    /// Encoder FLOP is exactly linear in the unit count, so the fractional
    /// form is exact (used for packed-bucket estimates).
    pub fn encoder_flop_total_f64(&self, units: f64) -> f64 {
        self.encoder_flop_total(1) * units
    }

    /// Total (fwd+bwd) LLM FLOP for an item.
    pub fn llm_flop_total(&self, seq: usize) -> f64 {
        self.llm_flop_fwd(seq) * (1.0 + Self::BWD_FACTOR)
    }

    /// LLM tokens contributed by `units` vision units after the connector.
    pub fn llm_visual_tokens(&self, units: usize) -> usize {
        units * self.connector.llm_tokens(self.tokens_per_unit)
    }

    // ---------------- Memory accounting ----------------

    /// Model-state bytes per GPU for `layers` encoder layers at TP `tp`.
    pub fn encoder_model_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        layers * self.encoder.params_per_layer(self.enc_mlp_matrices)
            * MODEL_STATE_BYTES_PER_PARAM
            / tp as f64
    }

    /// Model-state bytes per GPU for `layers` LLM layers at TP `tp`
    /// (embedding + head included, divided across PP stages upstream).
    pub fn llm_model_state_bytes(&self, layers: f64, tp: usize) -> f64 {
        let layer_part = layers * self.llm.params_per_layer(self.llm_mlp_matrices);
        let emb_part = 2.0 * self.llm.vocab as f64 * self.llm.hidden as f64
            * layers
            / self.llm.layers as f64;
        (layer_part + emb_part) * MODEL_STATE_BYTES_PER_PARAM / tp as f64
    }

    /// Activation bytes per GPU for the encoder processing `units` vision
    /// units through `layers` layers at TP `tp` (one microbatch).
    pub fn encoder_act_bytes(&self, layers: f64, tp: usize, units: f64) -> f64 {
        let tokens = units * self.tokens_per_unit as f64;
        tokens * layers * self.encoder.act_bytes_per_token_layer() / tp as f64
    }

    /// Activation bytes per GPU for the LLM processing a packed sequence of
    /// `seq` tokens through `layers` layers at TP `tp` (one microbatch).
    pub fn llm_act_bytes(&self, layers: f64, tp: usize, seq: f64) -> f64 {
        seq * layers * self.llm.act_bytes_per_token_layer() / tp as f64
    }

    /// Ratio of encoder to LLM compute for a "mean" item — the x-axis of
    /// Fig 8. `mean_units`/`mean_seq` come from the Data Profiler.
    pub fn compute_ratio(&self, mean_units: f64, mean_seq: f64) -> f64 {
        let e = self.encoder_flop_total(mean_units.round() as usize);
        let l = self.llm_flop_total(mean_seq.round() as usize);
        e / l
    }
}

// ---------------- Towers used in the paper ----------------

/// SigLIP-SO400M @ 384px, patch 14 → 27×27 = 729 tokens per image tile.
pub fn siglip_so400m() -> Tower {
    Tower {
        name: "siglip-so400m",
        layers: 27,
        hidden: 1152,
        heads: 16,
        kv_heads: 16,
        intermediate: 4304,
        vocab: 0,
    }
}

/// InternViT-6B (InternVL-2.5's large vision tower), 448px tiles → 1025
/// tokens pre-shuffle, 256 after pixel unshuffle (factor 4).
pub fn internvit_6b() -> Tower {
    Tower {
        name: "internvit-6b",
        layers: 45,
        hidden: 3200,
        heads: 25,
        kv_heads: 25,
        intermediate: 12800,
        vocab: 0,
    }
}

/// Whisper-large-v3 style audio encoder used by Qwen2-Audio.
pub fn whisper_large() -> Tower {
    Tower {
        name: "whisper-large-audio",
        layers: 32,
        hidden: 1280,
        heads: 20,
        kv_heads: 20,
        intermediate: 5120,
        vocab: 0,
    }
}

pub fn qwen25(size: &str) -> Tower {
    match size {
        "7b" => Tower {
            name: "qwen-2.5-7b",
            layers: 28,
            hidden: 3584,
            heads: 28,
            kv_heads: 4,
            intermediate: 18944,
            vocab: 152_064,
        },
        "32b" => Tower {
            name: "qwen-2.5-32b",
            layers: 64,
            hidden: 5120,
            heads: 40,
            kv_heads: 8,
            intermediate: 27648,
            vocab: 152_064,
        },
        "72b" => Tower {
            name: "qwen-2.5-72b",
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 8,
            intermediate: 29568,
            vocab: 152_064,
        },
        other => panic!("unknown qwen-2.5 size '{other}'"),
    }
}

pub fn llama3(size: &str) -> Tower {
    match size {
        "8b" => Tower {
            name: "llama-3-8b",
            layers: 32,
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            intermediate: 14336,
            vocab: 128_256,
        },
        "70b" => Tower {
            name: "llama-3-70b",
            layers: 80,
            hidden: 8192,
            heads: 64,
            kv_heads: 8,
            intermediate: 28672,
            vocab: 128_256,
        },
        other => panic!("unknown llama-3 size '{other}'"),
    }
}

/// Qwen2-Audio's 7B LLM backbone.
pub fn qwen2_7b_audio_llm() -> Tower {
    Tower {
        name: "qwen2-7b",
        layers: 28,
        hidden: 3584,
        heads: 28,
        kv_heads: 4,
        intermediate: 18944,
        vocab: 152_064,
    }
}

// ---------------- MLLM catalog (Table 3 + Fig 9) ----------------

/// LLaVA-OneVision: SigLIP encoder, MLP connector (identity token count for
/// images; video frames are pooled ~4× via bilinear interpolation).
pub fn llava_ov(llm: Tower) -> Mllm {
    Mllm {
        name: "llava-ov",
        modality: Modality::Vision,
        encoder: siglip_so400m(),
        connector: Connector::Mlp,
        llm,
        tokens_per_unit: 729,
        enc_mlp_matrices: 2,
        llm_mlp_matrices: 3,
    }
}

/// InternVL-2.5: InternViT-6B encoder, pixel-unshuffle connector (4×
/// token reduction: 1024 → 256 tokens per 448px tile).
pub fn internvl_25(llm: Tower) -> Mllm {
    Mllm {
        name: "internvl-2.5",
        modality: Modality::Vision,
        encoder: internvit_6b(),
        connector: Connector::Pool { factor: 4 },
        llm,
        tokens_per_unit: 1024,
        enc_mlp_matrices: 2,
        llm_mlp_matrices: 3,
    }
}

/// Qwen2-Audio: Whisper-style encoder with a final average pool that cuts
/// the token count ~8× before the LLM (§5.3.1: the pooling balances the
/// compute distribution between encoder and LLM).
pub fn qwen2_audio() -> Mllm {
    Mllm {
        name: "qwen2-audio",
        modality: Modality::Audio,
        encoder: whisper_large(),
        connector: Connector::Pool { factor: 8 },
        llm: qwen2_7b_audio_llm(),
        // One unit = 1 s of audio ≈ 50 post-conv frames.
        tokens_per_unit: 50,
        enc_mlp_matrices: 2,
        llm_mlp_matrices: 3,
    }
}

/// A named evaluation configuration (one bar group in Fig 7).
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub label: &'static str,
    pub mllm: Mllm,
}

/// The six Fig 7 / Table 4 configurations, in paper order.
pub fn paper_configs() -> Vec<EvalConfig> {
    vec![
        EvalConfig { label: "LLaVA-OV (Qwen-2.5 7B)", mllm: llava_ov(qwen25("7b")) },
        EvalConfig { label: "LLaVA-OV (Llama-3 8B)", mllm: llava_ov(llama3("8b")) },
        EvalConfig { label: "LLaVA-OV (Qwen-2.5 32B)", mllm: llava_ov(qwen25("32b")) },
        EvalConfig { label: "LLaVA-OV (Llama-3 70B)", mllm: llava_ov(llama3("70b")) },
        EvalConfig { label: "LLaVA-OV (Qwen-2.5 72B)", mllm: llava_ov(qwen25("72b")) },
        EvalConfig { label: "InternVL (Qwen-2.5 72B)", mllm: internvl_25(qwen25("72b")) },
    ]
}

/// Look up a catalog model by a CLI-friendly key.
pub fn by_key(key: &str) -> Option<Mllm> {
    match key {
        "llava-ov-qwen25-7b" => Some(llava_ov(qwen25("7b"))),
        "llava-ov-llama3-8b" => Some(llava_ov(llama3("8b"))),
        "llava-ov-qwen25-32b" => Some(llava_ov(qwen25("32b"))),
        "llava-ov-llama3-70b" => Some(llava_ov(llama3("70b"))),
        "llava-ov-qwen25-72b" => Some(llava_ov(qwen25("72b"))),
        "internvl-qwen25-72b" => Some(internvl_25(qwen25("72b"))),
        "qwen2-audio" => Some(qwen2_audio()),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_param_counts_in_band() {
        let p7 = qwen25("7b").total_params(3);
        let p72 = qwen25("72b").total_params(3);
        assert!((6.0e9..9.0e9).contains(&p7), "{p7:.3e}");
        assert!((65.0e9..80.0e9).contains(&p72), "{p72:.3e}");
    }

    #[test]
    fn siglip_params_in_band() {
        // SO400M ≈ 0.4B.
        let p = siglip_so400m().total_params(2);
        assert!((0.25e9..0.6e9).contains(&p), "{p:.3e}");
    }

    #[test]
    fn internvit_params_in_band() {
        let p = internvit_6b().total_params(2);
        assert!((4.5e9..7.5e9).contains(&p), "{p:.3e}");
    }

    #[test]
    fn encoder_flop_scales_linearly_in_units() {
        let m = llava_ov(llama3("8b"));
        let f1 = m.encoder_flop_fwd(1);
        let f8 = m.encoder_flop_fwd(8);
        assert!((f8 / f1 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn internvl_compute_ratio_higher_than_llava_7b() {
        // InternViT-6B vs SigLIP-0.4B against the same 72B LLM: InternVL's
        // encoder/LLM ratio must be much larger (drives Fig 8).
        let a = internvl_25(qwen25("72b")).compute_ratio(8.0, 3000.0);
        let b = llava_ov(qwen25("72b")).compute_ratio(8.0, 3000.0);
        assert!(a > 5.0 * b, "internvl {a} vs llava {b}");
    }

    #[test]
    fn audio_pooling_reduces_llm_tokens() {
        let m = qwen2_audio();
        // 30 s of audio = 30 units = 1500 encoder tokens → ~188 LLM tokens.
        let t = m.llm_visual_tokens(30);
        assert!(t < 30 * 50 / 7, "{t}");
    }

    #[test]
    fn catalog_lookup_round_trip() {
        for cfg in paper_configs() {
            // Every paper config is reachable via some CLI key.
            let key = match cfg.label {
                "LLaVA-OV (Qwen-2.5 7B)" => "llava-ov-qwen25-7b",
                "LLaVA-OV (Llama-3 8B)" => "llava-ov-llama3-8b",
                "LLaVA-OV (Qwen-2.5 32B)" => "llava-ov-qwen25-32b",
                "LLaVA-OV (Llama-3 70B)" => "llava-ov-llama3-70b",
                "LLaVA-OV (Qwen-2.5 72B)" => "llava-ov-qwen25-72b",
                "InternVL (Qwen-2.5 72B)" => "internvl-qwen25-72b",
                other => panic!("unmapped config {other}"),
            };
            let m = by_key(key).expect(key);
            assert_eq!(m.llm.name, cfg.mllm.llm.name);
        }
        assert!(by_key("nope").is_none());
    }

    #[test]
    fn memory_accounting_divides_by_tp() {
        let m = llava_ov(llama3("8b"));
        let full = m.llm_model_state_bytes(32.0, 1);
        let tp8 = m.llm_model_state_bytes(32.0, 8);
        assert!((full / tp8 - 8.0).abs() < 1e-9);
        let act1 = m.llm_act_bytes(32.0, 1, 4096.0);
        let act4 = m.llm_act_bytes(32.0, 4, 4096.0);
        assert!((act1 / act4 - 4.0).abs() < 1e-9);
    }
}
