//! Transformer architecture descriptions and closed-form FLOP / parameter /
//! activation accounting.
//!
//! The reproduction never instantiates the paper's 7B–72B models; instead it
//! carries their architectural hyperparameters and uses standard closed forms
//! (Megatron-style accounting) for per-item FLOP, parameter bytes, and
//! activation bytes. These feed the ground-truth cluster model
//! (`perfmodel`), the Profiling Engine's memory model (§3.2), and the
//! optimizer's feasibility checks (Eq 4–5).

/// Hyperparameters of one transformer tower (encoder or LLM).
#[derive(Clone, Debug, PartialEq)]
pub struct Tower {
    pub name: &'static str,
    pub layers: usize,
    pub hidden: usize,
    pub heads: usize,
    /// Key/value heads (GQA); equals `heads` for MHA towers.
    pub kv_heads: usize,
    /// MLP intermediate width.
    pub intermediate: usize,
    /// Vocabulary size (0 for vision/audio towers without an LM head).
    pub vocab: usize,
}

impl Tower {
    /// Parameters of one transformer layer.
    ///
    /// Attention: Q (h·h), KV (2·h·h_kv), O (h·h); MLP: gate/up/down.
    /// We include the gated-MLP factor for LLM towers (3 matrices) and the
    /// classic 2-matrix MLP for encoder towers; both are captured by
    /// `mlp_matrices`.
    pub fn params_per_layer(&self, mlp_matrices: usize) -> f64 {
        let h = self.hidden as f64;
        let h_kv = h * self.kv_heads as f64 / self.heads as f64;
        let attn = h * h * 2.0 + h * h_kv * 2.0; // Q,O + K,V
        let mlp = mlp_matrices as f64 * h * self.intermediate as f64;
        let norms = 2.0 * h;
        attn + mlp + norms
    }

    /// Total parameters (embeddings + layers + head).
    pub fn total_params(&self, mlp_matrices: usize) -> f64 {
        let h = self.hidden as f64;
        let emb = self.vocab as f64 * h; // 0 for towers without vocab
        emb * 2.0 + self.layers as f64 * self.params_per_layer(mlp_matrices)
    }

    /// Forward FLOP of the *linear* (GEMM) portion for `tokens` tokens across
    /// `layers` layers: 2·params_matmul FLOP per token per matrix element.
    pub fn linear_flop_fwd(&self, tokens: f64, layers: f64, mlp_matrices: usize) -> f64 {
        let h = self.hidden as f64;
        let h_kv = h * self.kv_heads as f64 / self.heads as f64;
        let attn_proj = 2.0 * tokens * (h * h * 2.0 + h * h_kv * 2.0);
        let mlp = 2.0 * tokens * (mlp_matrices as f64 * h * self.intermediate as f64);
        layers * (attn_proj + mlp)
    }

    /// Forward FLOP of the attention score/context GEMMs for a *single*
    /// sequence of length `seq` across `layers` layers. Quadratic in `seq` —
    /// this is why packed-batch attention cost depends on individual
    /// sequence lengths (paper §3.2) while linear cost depends on the total.
    pub fn attn_flop_fwd(&self, seq: f64, layers: f64) -> f64 {
        let h = self.hidden as f64;
        // QK^T and PV: 2 GEMMs of 2·s²·h each.
        layers * 4.0 * seq * seq * h
    }

    /// Activation bytes per token per layer under mixed precision with
    /// flash-style attention (no S×S score materialization). The classic
    /// Megatron estimate is ≈34·h bytes/token/layer (bf16 residual stream,
    /// QKV, MLP intermediates); TP divides the per-GPU share.
    /// Decomposed as 18·h (residual stream, QKV, attention out, norms)
    /// plus 4·intermediate (MLP up/act checkpoints); for the classic 4·h
    /// MLP this recovers the familiar ≈34·h constant.
    pub fn act_bytes_per_token_layer(&self) -> f64 {
        18.0 * self.hidden as f64 + 4.0 * self.intermediate as f64
    }
}

/// Bytes of model state per parameter under mixed-precision Adam:
/// bf16 weights (2) + bf16 grads (2) + fp32 master weights (4) +
/// fp32 Adam m/v (8) = 16.
pub const MODEL_STATE_BYTES_PER_PARAM: f64 = 16.0;

/// How a connector maps encoder output tokens to LLM input tokens.
#[derive(Clone, Debug, PartialEq)]
pub enum Connector {
    /// MLP projector, token count preserved (LLaVA-OV images).
    Mlp,
    /// Spatial pixel-shuffle / pooling reducing tokens by `1/factor`
    /// (InternVL-2.5: 4; LLaVA-OV video frames: ~4 via bilinear pooling;
    /// Qwen2-Audio: ~8 via the final average-pool).
    Pool { factor: usize },
}

impl Connector {
    /// LLM-side tokens produced from `encoder_tokens` encoder outputs.
    pub fn llm_tokens(&self, encoder_tokens: usize) -> usize {
        match self {
            Connector::Mlp => encoder_tokens,
            Connector::Pool { factor } => encoder_tokens.div_ceil(*factor),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn llama8b() -> Tower {
        Tower {
            name: "llama-3-8b",
            layers: 32,
            hidden: 4096,
            heads: 32,
            kv_heads: 8,
            intermediate: 14336,
            vocab: 128_256,
        }
    }

    #[test]
    fn llama8b_param_count_close() {
        // Llama-3 8B has ≈8.0B parameters.
        let p = llama8b().total_params(3);
        assert!(
            (7.0e9..9.0e9).contains(&p),
            "llama-3-8b params {p:.3e} out of expected band"
        );
    }

    #[test]
    fn linear_flop_matches_2pt_rule() {
        // Linear FLOP per token ≈ 2 · (matmul params per layer) · layers.
        let t = llama8b();
        let per_token = t.linear_flop_fwd(1.0, t.layers as f64, 3);
        let h = t.hidden as f64;
        let h_kv = h * t.kv_heads as f64 / t.heads as f64;
        let matmul_params =
            t.layers as f64 * (2.0 * h * h + 2.0 * h * h_kv + 3.0 * h * t.intermediate as f64);
        assert!((per_token / (2.0 * matmul_params) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn attn_flop_quadratic() {
        let t = llama8b();
        let f1 = t.attn_flop_fwd(1024.0, 1.0);
        let f2 = t.attn_flop_fwd(2048.0, 1.0);
        assert!((f2 / f1 - 4.0).abs() < 1e-9);
    }

    #[test]
    fn pool_connector_reduces_tokens() {
        assert_eq!(Connector::Pool { factor: 4 }.llm_tokens(729), 183);
        assert_eq!(Connector::Mlp.llm_tokens(729), 729);
    }
}
