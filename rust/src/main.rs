//! DFLOP launcher: figure/table regeneration, simulated system runs,
//! optimizer/scheduler inspection, and real-artifact profiling.
//!
//! ```text
//! dflop figures --fig <1|2|4|7|8|9|10|11|12|13|14|15|16|17|drift|18|shard|19|hetero|20|fleet|bubbles|critpath|audit|all> [--nodes N] [--gbs N] [--iters N] [--seed S] [--threads N]
//! dflop table   --n <2|4>
//! dflop run     --system <dflop|interleaved|adaptive|sharded|megatron|pytorch|opt-only|sched-only> --model <key> --dataset <key>
//!               [--no-bubble-fill]                                                                   # --system interleaved
//!               [--dp-shards N] [--shard-skew <skewed|hot|laggard|homogeneous>] [--static-sharding] [--hetero-plans]   # --system sharded
//!               [--faults <none|churn|straggler|degraded-link|skewed-churn|long-horizon>] [--static-faults]            # fault-injected fleet
//!               [--trace out.json] [--metrics out.json] [--audit] [--json out.json]   # obs: trace / metrics / audit / summary
//! dflop optimize --model <key> --nodes N --gbs N
//! dflop profile-real [--artifacts DIR]      # PJRT timing (needs `xla` feature)
//! dflop models                              # list catalog keys
//! ```
//!
//! Every subcommand accepts `--threads N` to cap the evaluation thread
//! pool (default: all available cores). Results do not depend on the
//! thread count, with one caveat: scheduling calls whose ILP budget
//! expires return a wall-clock-dependent incumbent (see `scheduler::ilp`),
//! so DFLOP-system runs can drift between invocations — serial ones too.

use dflop::bail;
use dflop::err;
use dflop::figures::{by_id, table2, table4, FigOpts};
use dflop::model::catalog;
use dflop::sim::{FaultConfig, RunConfig, SystemKind};
use dflop::util::cli::{Args, Spec};
use dflop::util::error::Result;
use std::process::ExitCode;

fn opts_from(args: &Args) -> Result<FigOpts> {
    let d = FigOpts::default();
    Ok(FigOpts {
        nodes: args.get_usize("nodes", d.nodes)?,
        gbs: args.get_usize("gbs", d.gbs)?,
        iters: args.get_usize("iters", d.iters)?,
        seed: args.get_u64("seed", d.seed)?,
    })
}

fn main() -> ExitCode {
    match real_main() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn real_main() -> Result<()> {
    let spec = Spec {
        valued: vec![
            "fig", "n", "nodes", "gbs", "iters", "seed", "system", "model", "dataset",
            "artifacts", "threads", "dp-shards", "shard-skew", "faults", "trace",
            "metrics", "json",
        ],
        boolean: vec![
            "help", "static-sharding", "hetero-plans", "static-faults", "audit",
            "no-bubble-fill",
        ],
    };
    let args = Args::parse(std::env::args().skip(1), &spec)?;
    // Pool width for every parallel section below (0 = auto-detect).
    dflop::util::parallel::set_max_threads(args.get_usize("threads", 0)?);
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    match sub.as_str() {
        "figures" => {
            let o = opts_from(&args)?;
            let id = args.get_or("fig", "all");
            match by_id(&id, &o) {
                Some(text) => print!("{text}"),
                None => bail!("unknown figure id '{id}'"),
            }
        }
        "table" => {
            let o = opts_from(&args)?;
            match args.get_or("n", "2").as_str() {
                "2" => print!("{}", table2(&o)),
                "4" => print!("{}", table4(&o)),
                other => bail!("unknown table '{other}'"),
            }
        }
        "run" => {
            let o = opts_from(&args)?;
            let kind = match args.get_or("system", "dflop").as_str() {
                "dflop" => SystemKind::Dflop,
                "interleaved" => SystemKind::DflopInterleaved,
                "adaptive" => SystemKind::DflopAdaptive,
                "sharded" => SystemKind::DflopSharded,
                "megatron" => SystemKind::Megatron,
                "pytorch" => SystemKind::Pytorch,
                "opt-only" => SystemKind::DflopOptimizerOnly,
                "sched-only" => SystemKind::DflopSchedulerOnly,
                other => bail!("unknown system '{other}'"),
            };
            let model_key = args.get_or("model", "llava-ov-llama3-8b");
            let m = catalog::by_key(&model_key)
                .ok_or_else(|| err!("unknown model '{model_key}' (try `dflop models`)"))?;
            let mut dataset = args.get_or("dataset", "mixed");
            let mut cfg = RunConfig::new(o.nodes, o.gbs, o.iters, o.seed);
            // --no-bubble-fill pins the interleaved system to the plain
            // DFLOP execution path (the bit-parity anchor).
            cfg.bubble_fill = !args.has("no-bubble-fill");
            if kind == SystemKind::DflopSharded {
                // --dp-shards N replicas of the --nodes cluster; --shard-skew
                // picks a `data::sources` shard scenario (homogeneous keeps
                // --dataset, giving identically-distributed shards of it).
                let d = dflop::shard::ShardConfig::default();
                cfg.shard = Some(dflop::shard::ShardConfig {
                    dp_shards: args.get_usize("dp-shards", d.dp_shards)?,
                    // --static-sharding runs the baseline every shard
                    // comparison is against (rebalancing off).
                    rebalance: !args.has("static-sharding"),
                    // --hetero-plans fits one θ per shard behind the skew
                    // gate (engine::hetero).
                    hetero: args.has("hetero-plans"),
                    ..d
                });
                match args.get_or("shard-skew", "homogeneous").as_str() {
                    "homogeneous" | "none" => {}
                    "skewed" => dataset = "skewed-shard".into(),
                    "hot" => dataset = "hot-shard".into(),
                    "laggard" => dataset = "laggard-shard".into(),
                    other => bail!(
                        "unknown --shard-skew '{other}' (skewed|hot|laggard|homogeneous)"
                    ),
                }
                // --faults <trace> injects a deterministic fault scenario;
                // --static-faults keeps the static-θ* arm that absorbs the
                // same physics without responding.
                if let Some(trace) = args.get("faults") {
                    cfg.faults = Some(FaultConfig {
                        trace: trace.to_string(),
                        respond: !args.has("static-faults"),
                    });
                }
            }
            // --trace / --metrics / --audit switch the recorder on;
            // --json only reads the summary struct, so it needs no
            // recorder (but picks up the audit section when --audit ran).
            let trace_path = args.get("trace").map(String::from);
            let metrics_path = args.get("metrics").map(String::from);
            let json_path = args.get("json").map(String::from);
            let audit = args.has("audit");
            if trace_path.is_some() || metrics_path.is_some() || audit {
                cfg.obs = Some(dflop::obs::ObsConfig {
                    timelines: trace_path.is_some(),
                    metrics: metrics_path.is_some(),
                    audit,
                });
            }
            // The engine entry returns a Result, so a bad key is a clean
            // CLI error instead of a panic inside a worker thread.
            let r = dflop::engine::run(kind, &m, &dataset, &cfg)?;
            println!("system        : {}", kind.label());
            println!("model         : {model_key}");
            println!("dataset       : {dataset}");
            println!("theta         : {}", r.theta);
            println!("per-GPU thr   : {:.1} TFLOP/s", r.per_gpu_throughput / 1e12);
            println!("iteration time: {:.3} s", r.mean_iteration_time);
            println!("idle GPU·s    : {:.2}", r.mean_idle);
            println!("profiling     : {:.1} min", r.profiling_seconds / 60.0);
            println!("optimizer     : {:?}", r.optimizer_elapsed);
            println!("LPT fallbacks : {}/{}", r.lpt_fallbacks, r.sched_elapsed.len());
            if kind == SystemKind::DflopInterleaved {
                let filled: f64 = r.iterations.iter().map(|s| s.filled_time()).sum();
                let subops: usize = r.iterations.iter().map(|s| s.fills.len()).sum();
                println!(
                    "bubble fill   : {} sub-ops, {:.3} GPU·s packed into bubbles{}",
                    subops,
                    filled,
                    if cfg.bubble_fill { "" } else { " (fill disabled)" }
                );
            }
            if kind == SystemKind::DflopSharded {
                let sc = cfg.shard.as_ref().expect("shard config set above");
                println!("dp shards     : {}", sc.dp_shards);
                println!(
                    "rebalancing   : {}",
                    if sc.rebalance { "on" } else { "off (static baseline)" }
                );
                println!("total GPUs    : {}", r.n_gpus);
                println!("migrations    : {}", r.migrations);
                println!("straggler gap : {:.3} s (mean over iterations)", r.mean_straggler_gap());
                if let Some(fc) = &cfg.faults {
                    println!("fault trace   : {} ({})", fc.trace,
                        if fc.respond { "degradation-aware" } else { "static θ* arm" });
                    println!(
                        "fault events  : {} failures, {} recoveries, {} reshards, {} degraded iters",
                        r.fault.failures, r.fault.recoveries,
                        r.fault.reshard_events, r.fault.degraded_iters
                    );
                    for (q, v) in &r.straggler_gap_percentiles {
                        println!("  gap p{:<4} : {v:.3} s", q * 100.0);
                    }
                }
                if !r.hetero_thetas.is_empty() {
                    println!("per-replica θ :");
                    for (i, t) in r.hetero_thetas.iter().enumerate() {
                        println!("  shard {i}: {t}");
                    }
                }
            }
            if matches!(kind, SystemKind::DflopAdaptive | SystemKind::DflopSharded) {
                println!("replans       : {}", r.replans);
                for e in &r.replan_events {
                    println!(
                        "  iter {:>3}: score {:.3} {} {} -> {}",
                        e.iteration,
                        e.stat.score(),
                        if e.swapped { "swap" } else { "keep" },
                        e.old,
                        e.new
                    );
                }
            }
            if audit {
                let a = r
                    .obs
                    .as_deref()
                    .and_then(|log| log.audit.as_ref())
                    .ok_or_else(|| {
                        err!("--audit requested but the run recorded no audit report")
                    })?;
                println!(
                    "audit         : {} iters, mean |rel err| {:.2}%, bias {:+.4} s",
                    a.rows.len(),
                    a.mean_abs_rel_err * 100.0,
                    a.bias
                );
                for ra in &a.replans {
                    println!(
                        "  swap @ iter {:>3}: incumbent {:.3} s vs adopted {:.3} s over {} iters \
                         -> measured {:+.3} s{}",
                        ra.iteration,
                        ra.incumbent_mean,
                        ra.adopted_mean,
                        ra.window,
                        ra.measured_benefit,
                        if ra.predicted_benefit.is_finite() {
                            format!(", predicted {:+.3} s", ra.predicted_benefit)
                        } else {
                            String::new()
                        }
                    );
                }
            }
            if let Some(path) = &trace_path {
                let log = r.obs.as_ref().ok_or_else(|| {
                    err!("--trace requested but the run returned no observation log")
                })?;
                std::fs::write(path, dflop::obs::chrome::trace_json(log))?;
                println!("trace         : wrote Chrome trace to {path}");
            }
            if let Some(path) = &metrics_path {
                let reg = r
                    .obs
                    .as_ref()
                    .and_then(|log| log.metrics.as_ref())
                    .ok_or_else(|| {
                        err!("--metrics requested but the run returned no metrics registry")
                    })?;
                std::fs::write(path, reg.dump())?;
                println!("metrics       : wrote metrics dump to {path}");
            }
            if let Some(path) = &json_path {
                std::fs::write(path, dflop::obs::run_result_json(&r))?;
                println!("summary       : wrote run summary to {path}");
            }
        }
        "optimize" => {
            use dflop::data::dataset::Dataset;
            use dflop::optimizer::search::{optimize, OptimizerInputs};
            use dflop::perfmodel::{ClusterSpec, Truth};
            use dflop::profiling::backend::SimBackend;
            use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
            let o = opts_from(&args)?;
            let model_key = args.get_or("model", "llava-ov-llama3-8b");
            let m = catalog::by_key(&model_key)
                .ok_or_else(|| err!("unknown model '{model_key}'"))?;
            let cluster = ClusterSpec::hgx_a100(o.nodes);
            let mut backend = SimBackend::new(Truth::new(cluster));
            let profile =
                ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
            let dataset = args.get_or("dataset", "mixed");
            let mut ds = Dataset::by_key(&dataset, o.seed)
                .ok_or_else(|| err!("unknown dataset '{dataset}'"))?;
            let data = profile_data(&m, &mut ds, 512);
            let inp = OptimizerInputs {
                m: &m,
                profile: &profile,
                data: &data,
                n_gpus: cluster.total_gpus(),
                gpus_per_node: cluster.gpus_per_node,
                mem_capacity: cluster.gpu.mem_bytes,
                gbs: o.gbs,
                assume_balanced: true,
            };
            match optimize(&inp) {
                Some(r) => {
                    println!("theta*            : {}", r.theta);
                    println!("expected makespan : {:.3} s", r.expected_makespan);
                    println!("candidates scanned: {}", r.candidates_scanned);
                    println!("memory-rejected   : {}", r.memory_rejected);
                    println!("elapsed           : {:?}", r.elapsed);
                }
                None => bail!("no feasible configuration"),
            }
        }
        #[cfg(feature = "xla")]
        "profile-real" => {
            use dflop::runtime::artifacts::Manifest;
            use dflop::runtime::profiler::profile_real;
            let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
            let manifest = Manifest::load(&dir)?;
            println!(
                "profiling real AOT artifacts ({} config, {} params)…",
                manifest.config, manifest.model.total_params
            );
            let p = profile_real(&manifest, 3, args.get_u64("seed", 42)?)?;
            println!("encoder forward (PJRT CPU):");
            for pt in &p.encoder {
                println!("  n_img {:>3}: {:>10.3} ms", pt.coord, pt.seconds * 1e3);
            }
            println!("llm forward (PJRT CPU):");
            for pt in &p.llm {
                println!("  seq {:>5}: {:>10.3} ms", pt.coord, pt.seconds * 1e3);
            }
        }
        #[cfg(not(feature = "xla"))]
        "profile-real" => {
            bail!(
                "this binary was built without PJRT support: add the vendored `xla` \
                 crate as a path dependency in rust/Cargo.toml, then rebuild with \
                 --features xla (see rust/DESIGN.md)"
            );
        }
        "models" => {
            for key in [
                "llava-ov-qwen25-7b",
                "llava-ov-llama3-8b",
                "llava-ov-qwen25-32b",
                "llava-ov-llama3-70b",
                "llava-ov-qwen25-72b",
                "internvl-qwen25-72b",
                "qwen2-audio",
            ] {
                let m = catalog::by_key(key).expect("catalog key");
                println!("{key:24} encoder={} llm={}", m.encoder.name, m.llm.name);
            }
        }
        _ => {
            println!("usage: dflop <figures|table|run|optimize|profile-real|models> [options]");
            println!("common options: --threads N (evaluation thread pool; default all cores)");
            println!(
                "run --system interleaved: bubble-filling DFLOP (encoder sub-ops \
                 packed into 1F1B bubbles); --no-bubble-fill pins it to the plain \
                 DFLOP execution path (bit-parity anchor)"
            );
            println!(
                "run --system sharded: --dp-shards N (DP replicas, default 4), \
                 --shard-skew <skewed|hot|laggard|homogeneous> (per-shard data skew \
                 scenario; homogeneous keeps --dataset), --static-sharding \
                 (disable cross-shard rebalancing: the baseline), --hetero-plans \
                 (fit per-replica plans behind the skew gate), --faults <key> \
                 (inject a deterministic fault trace: none|churn|straggler|\
                 degraded-link|skewed-churn|long-horizon), --static-faults \
                 (absorb the faults without responding: the comparison arm)"
            );
            println!(
                "run observability: --trace out.json (Chrome trace, load in \
                 Perfetto/chrome://tracing), --metrics out.json (counter/gauge/\
                 histogram dump), --audit (predicted-vs-measured step-time \
                 residuals + counterfactual replan attribution), --json out.json \
                 (machine-readable run summary; includes the audit when --audit ran)"
            );
            println!("see rust/src/main.rs header or DESIGN.md for details");
        }
    }
    Ok(())
}
