//! Hardware specifications of the simulated testbed.
//!
//! Mirrors the paper's cluster: HGX A100 8-GPU nodes (NVLink intra-node)
//! connected by 800 Gbps InfiniBand (§5.1). All quantities are SI: FLOP/s,
//! bytes, bytes/s, seconds.

/// One GPU's capabilities.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Peak dense bf16 FLOP/s (A100: 312 TFLOPS).
    pub peak_flops: f64,
    /// HBM capacity in bytes (A100 80GB).
    pub mem_bytes: f64,
    /// HBM bandwidth (A100: ~2.0 TB/s).
    pub hbm_bw: f64,
    /// Per-kernel launch/dispatch overhead in seconds.
    pub kernel_overhead: f64,
}

impl GpuSpec {
    pub fn a100_80g() -> GpuSpec {
        GpuSpec {
            name: "A100-80G",
            peak_flops: 312e12,
            mem_bytes: 80.0 * 1024.0 * 1024.0 * 1024.0,
            hbm_bw: 2.0e12,
            kernel_overhead: 6e-6,
        }
    }
}

/// Cluster topology.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ClusterSpec {
    pub nodes: usize,
    pub gpus_per_node: usize,
    pub gpu: GpuSpec,
    /// Per-GPU NVLink bandwidth within a node (A100 HGX: 600 GB/s).
    pub nvlink_bw: f64,
    /// Per-node InfiniBand bandwidth (800 Gbps = 100 GB/s).
    pub ib_bw: f64,
    /// One-way collective latency within a node / across nodes.
    pub nvlink_latency: f64,
    pub ib_latency: f64,
}

impl ClusterSpec {
    /// The paper's node type: HGX A100 8×80G + 800 Gbps IB.
    pub fn hgx_a100(nodes: usize) -> ClusterSpec {
        ClusterSpec {
            nodes,
            gpus_per_node: 8,
            gpu: GpuSpec::a100_80g(),
            nvlink_bw: 600e9,
            ib_bw: 100e9,
            nvlink_latency: 8e-6,
            ib_latency: 25e-6,
        }
    }

    pub fn total_gpus(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Ring all-reduce time for `bytes` over `n` ranks.
    ///
    /// Classic cost model: 2·(n−1)/n · bytes / bw, plus per-step latency.
    /// `intra_node` selects NVLink vs IB bandwidth.
    pub fn allreduce_time(&self, bytes: f64, n: usize, intra_node: bool) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let (bw, lat) = if intra_node {
            (self.nvlink_bw, self.nvlink_latency)
        } else {
            (self.ib_bw, self.ib_latency)
        };
        let steps = 2 * (n - 1);
        2.0 * (n as f64 - 1.0) / n as f64 * bytes / bw + steps as f64 * lat
    }

    /// Point-to-point transfer time for `bytes` (pipeline stage hand-off /
    /// inter-model communicator hop).
    pub fn p2p_time(&self, bytes: f64, intra_node: bool) -> f64 {
        let (bw, lat) = if intra_node {
            (self.nvlink_bw, self.nvlink_latency)
        } else {
            (self.ib_bw, self.ib_latency)
        };
        bytes / bw + lat
    }

    /// Whether a TP group of the given degree fits inside one node
    /// (the paper's Eq 2 restricts TP to intra-node GPUs).
    pub fn tp_fits_in_node(&self, tp: usize) -> bool {
        tp <= self.gpus_per_node
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hgx_topology() {
        let c = ClusterSpec::hgx_a100(4);
        assert_eq!(c.total_gpus(), 32);
        assert!(c.tp_fits_in_node(8));
        assert!(!c.tp_fits_in_node(16));
    }

    #[test]
    fn allreduce_scales_with_bytes_and_ranks() {
        let c = ClusterSpec::hgx_a100(1);
        let t1 = c.allreduce_time(1e9, 2, true);
        let t2 = c.allreduce_time(2e9, 2, true);
        assert!(t2 > t1);
        // n=1 is free.
        assert_eq!(c.allreduce_time(1e9, 1, true), 0.0);
        // Inter-node is slower than intra-node for the same payload.
        assert!(c.allreduce_time(1e9, 4, false) > c.allreduce_time(1e9, 4, true));
    }

    #[test]
    fn allreduce_bandwidth_term_converges() {
        // As n grows the bandwidth term approaches 2·bytes/bw.
        let c = ClusterSpec::hgx_a100(8);
        let t = c.allreduce_time(10e9, 64, false);
        let asymptote = 2.0 * 10e9 / c.ib_bw;
        assert!(t > asymptote && t < asymptote * 1.2, "{t} vs {asymptote}");
    }

    #[test]
    fn p2p_time_includes_latency() {
        let c = ClusterSpec::hgx_a100(1);
        assert!(c.p2p_time(0.0, true) > 0.0);
        assert!(c.p2p_time(1e9, false) > c.p2p_time(1e9, true));
    }
}
