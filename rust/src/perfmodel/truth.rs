//! Ground-truth execution-time model of the simulated A100 cluster.
//!
//! This plays the role of the physical testbed: every "measurement" in the
//! reproduction — the Profiling Engine's grid runs, the pipeline executor's
//! stage durations, the baselines' tuning runs — bottoms out here.
//!
//! The model captures the three behaviours the paper's motivation (§2.3,
//! Fig 2) rests on:
//!
//! 1. **Shape-dependent efficiency**: achieved FLOP/s saturates with the
//!    per-GPU workload fragment; small fragments underutilize the GPU.
//! 2. **TP overhead**: tensor parallelism splits each GEMM `tp` ways (making
//!    fragments smaller) *and* adds two all-reduces per layer per pass, so
//!    TP degradation is worst for small inputs — exactly Fig 2's shape.
//! 3. **Kernel-regime cliffs**: for a sparse set of shape buckets the
//!    runtime picks a slower specialized kernel (§3.4.3: "non-smooth and
//!    regime-dependent performance"). Deterministic, rare, and invisible to
//!    coarse-grid linear interpolation — the raison d'être of Adaptive
//!    Correction.
//!
//! Attention and linear (GEMM) work are modeled separately (the paper
//! profiles `L_attn_thr` and `L_lin_thr` independently, §3.2.1): linear work
//! is compute-bound with high peak MFU; attention is bandwidth-limited with
//! a lower effective roofline.

use crate::model::catalog::Mllm;
use crate::perfmodel::gpu::ClusterSpec;

/// Peak model FLOP utilization for large GEMM-dominated work.
const MFU_LINEAR: f64 = 0.62;
/// Effective utilization ceiling for attention (flash-style, BW-limited).
const MFU_ATTN: f64 = 0.35;
/// Tokens-per-GPU at which GEMM efficiency reaches half of peak.
const HALF_SAT_TOKENS: f64 = 640.0;
/// Sequence length at which attention efficiency reaches half of peak.
const HALF_SAT_ATTN_SEQ: f64 = 512.0;
/// Fixed per-(microbatch × stage) execution overhead: kernel-launch
/// batching, pipeline runtime bookkeeping, stream sync. This is what makes
/// extreme pipeline depths and microbatch counts unprofitable in practice.
const MB_STAGE_OVERHEAD: f64 = 140e-6;

/// Ground-truth time model. `cliffs` enables the kernel-regime
/// perturbations (on for all experiments; off in a couple of unit tests
/// that check smooth-model invariants).
#[derive(Clone, Debug)]
pub struct Truth {
    pub cluster: ClusterSpec,
    pub cliffs: bool,
    /// Multiplicative software-stack inefficiency (1.0 = Megatron-grade
    /// kernels; >1.0 models a less-optimized framework, e.g. the paper's
    /// plain-PyTorch baseline without fused kernels).
    pub software_factor: f64,
    /// Extra multiplicative slowdown injected for anomaly experiments
    /// (Fig 15): `(bucket, factor)` pairs applied to LLM shapes.
    pub injected: Vec<(u64, f64)>,
}

impl Truth {
    pub fn new(cluster: ClusterSpec) -> Truth {
        Truth { cluster, cliffs: true, software_factor: 1.0, injected: Vec::new() }
    }

    pub fn smooth(cluster: ClusterSpec) -> Truth {
        Truth { cluster, cliffs: false, software_factor: 1.0, injected: Vec::new() }
    }

    // ---------------- efficiency primitives ----------------

    /// Saturating utilization curve: `x / (x + half)`.
    fn sat(x: f64, half: f64) -> f64 {
        x / (x + half)
    }

    /// Kernel-regime multiplier for a shape bucket. Deterministic hash:
    /// ~6% of buckets fall into a slow regime (0.55–0.85×).
    pub fn regime_factor(&self, bucket: u64) -> f64 {
        if !self.cliffs {
            return 1.0;
        }
        // SplitMix-style scramble for bucket decorrelation.
        let mut z = bucket.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        let h = z ^ (z >> 31);
        if h % 100 < 6 {
            // Slow regime severity also deterministic per bucket.
            0.55 + 0.30 * ((h / 100) % 100) as f64 / 100.0
        } else {
            1.0
        }
    }

    /// Injected anomaly multiplier (Fig 15 experiments) for an LLM bucket.
    fn injected_factor(&self, bucket: u64) -> f64 {
        self.injected
            .iter()
            .find(|(b, _)| *b == bucket)
            .map(|(_, f)| *f)
            .unwrap_or(1.0)
    }

    /// Shape bucket for LLM sequences: 64-token granularity, mirroring
    /// dispatch boundaries of tile-quantized kernels.
    pub fn llm_bucket(seq: f64) -> u64 {
        (seq / 64.0) as u64
    }

    /// Shape bucket for encoder effective batch sizes.
    pub fn enc_bucket(units: f64) -> u64 {
        units as u64
    }

    /// Achieved per-GPU FLOP/s for linear (GEMM) work given the per-GPU
    /// token fragment.
    fn linear_flops(&self, tokens_per_gpu: f64, regime: f64) -> f64 {
        self.cluster.gpu.peak_flops
            * MFU_LINEAR
            * Self::sat(tokens_per_gpu, HALF_SAT_TOKENS)
            * regime
    }

    /// Achieved per-GPU FLOP/s for attention work at a given sequence
    /// length (per instance within the pack).
    fn attn_flops(&self, seq: f64, regime: f64) -> f64 {
        self.cluster.gpu.peak_flops
            * MFU_ATTN
            * Self::sat(seq, HALF_SAT_ATTN_SEQ)
            * regime
    }

    /// TP all-reduce time for one microbatch across `layers` layers:
    /// 2 all-reduces per layer forward + 2 backward, each over the
    /// activation tensor (`tokens · hidden · 2` bytes).
    fn tp_comm_time(&self, tokens: f64, hidden: f64, layers: f64, tp: usize) -> f64 {
        if tp <= 1 {
            return 0.0;
        }
        let bytes = tokens * hidden * 2.0;
        4.0 * layers * self.cluster.allreduce_time(bytes, tp, true)
    }

    // ---------------- module-level stage times ----------------

    /// Ground-truth fwd+bwd time for the *encoder share of one pipeline
    /// stage* (`layers` of the encoder) processing `units` vision units at
    /// tensor parallelism `tp`.
    pub fn encoder_stage_time(&self, m: &Mllm, units: f64, layers: f64, tp: usize) -> f64 {
        if units <= 0.0 {
            return 0.0;
        }
        let s = m.tokens_per_unit as f64;
        let tokens = units * s;
        let regime = self.regime_factor(0x5EED_0000 ^ Self::enc_bucket(units));
        // fwd+bwd linear FLOP for this slice of layers.
        let lin = m
            .encoder
            .linear_flop_fwd(tokens, layers, m.enc_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        let attn = units
            * m.encoder.attn_flop_fwd(s, layers)
            * (1.0 + Mllm::BWD_FACTOR);
        let t_lin = lin / tp as f64 / self.linear_flops(tokens / tp as f64, regime);
        let t_attn = attn / tp as f64 / self.attn_flops(s, regime);
        let t_comm = 3.0 * self.tp_comm_time(tokens, m.encoder.hidden as f64, layers, tp);
        let overhead =
            layers * 8.0 * self.cluster.gpu.kernel_overhead + MB_STAGE_OVERHEAD;
        (t_lin + t_attn + t_comm + overhead) * self.software_factor
    }

    /// Ground-truth fwd+bwd time of the *linear* (GEMM) portion of `layers`
    /// LLM layers over a packed total of `total` tokens at TP `tp` —
    /// depends only on the packed total (§3.2.1). Includes the TP
    /// all-reduces and kernel overheads, which ride on the linear path.
    pub fn llm_linear_time(&self, m: &Mllm, total: f64, layers: f64, tp: usize) -> f64 {
        if total <= 0.0 {
            return 0.0;
        }
        let bucket = Self::llm_bucket(total);
        let regime = self.regime_factor(0x11AA_0000 ^ bucket) * self.injected_factor(bucket);
        let lin = m
            .llm
            .linear_flop_fwd(total, layers, m.llm_mlp_matrices)
            * (1.0 + Mllm::BWD_FACTOR);
        let t_lin = lin / tp as f64 / self.linear_flops(total / tp as f64, regime);
        let t_comm = 3.0 * self.tp_comm_time(total, m.llm.hidden as f64, layers, tp);
        let overhead = layers * 8.0 * self.cluster.gpu.kernel_overhead + MB_STAGE_OVERHEAD;
        (t_lin + t_comm + overhead) * self.software_factor
    }

    /// Ground-truth fwd+bwd time of the *attention* portion of `layers` LLM
    /// layers for a single instance of sequence length `seq` at TP `tp` —
    /// quadratic per instance, independent of the rest of the pack.
    pub fn llm_attn_time(&self, m: &Mllm, seq: f64, layers: f64, tp: usize) -> f64 {
        if seq <= 0.0 {
            return 0.0;
        }
        let bucket = Self::llm_bucket(seq);
        let regime = self.regime_factor(0x22BB_0000 ^ bucket) * self.injected_factor(bucket);
        let attn = m.llm.attn_flop_fwd(seq, layers) * (1.0 + Mllm::BWD_FACTOR);
        attn / tp as f64 / self.attn_flops(seq, regime) * self.software_factor
    }

    /// Ground-truth fwd+bwd time for the *LLM share of one pipeline stage*
    /// (`layers` LLM layers) over a packed microbatch whose constituent
    /// sequence lengths are `seqs`, at tensor parallelism `tp`.
    ///
    /// Linear work depends only on the packed total; attention work is
    /// per-instance quadratic (§3.2.1).
    pub fn llm_stage_time(&self, m: &Mllm, seqs: &[f64], layers: f64, tp: usize) -> f64 {
        let total: f64 = seqs.iter().sum();
        if total <= 0.0 {
            return 0.0;
        }
        let t_lin = self.llm_linear_time(m, total, layers, tp);
        let t_attn: f64 = seqs
            .iter()
            .map(|&s| self.llm_attn_time(m, s, layers, tp))
            .sum();
        t_lin + t_attn
    }

    // ---------------- reported throughputs (Fig 2 axes) ----------------

    /// Per-GPU achieved FLOP/s of the full encoder for an effective batch
    /// of `units` at TP `tp` — the quantity Fig 2a plots and `E_thr`
    /// interpolates (§3.3.1).
    pub fn encoder_throughput(&self, m: &Mllm, units: f64, tp: usize) -> f64 {
        let layers = m.encoder.layers as f64;
        let t = self.encoder_stage_time(m, units, layers, tp);
        let flop = m.encoder_flop_total(units.max(1.0) as usize);
        flop / t / tp as f64
    }

    /// Per-GPU achieved FLOP/s of the full LLM for a packed sequence of
    /// length `seq` at TP `tp` — Fig 2b / `L_thr`.
    pub fn llm_throughput(&self, m: &Mllm, seq: f64, tp: usize) -> f64 {
        let layers = m.llm.layers as f64;
        let t = self.llm_stage_time(m, &[seq], layers, tp);
        let flop = m.llm_flop_total(seq as usize);
        flop / t / tp as f64
    }

    /// DP gradient all-reduce time for one module slice: `param_bytes` of
    /// bf16 gradients across `dp` ranks (inter-node when dp groups span
    /// nodes, which we assume at dp > 1 for conservative costing).
    pub fn dp_allreduce_time(&self, param_bytes: f64, dp: usize) -> f64 {
        // Gradients are reduced in bf16: half of model-state bytes is a
        // gross overestimate, so scale to 2/16 of state bytes upstream.
        self.cluster.allreduce_time(param_bytes, dp, false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llava_ov, llama3, qwen25};

    fn truth() -> Truth {
        Truth::smooth(ClusterSpec::hgx_a100(1))
    }

    #[test]
    fn encoder_time_monotone_in_units() {
        let t = truth();
        let m = llava_ov(llama3("8b"));
        let mut prev = 0.0;
        for units in [1.0, 2.0, 4.0, 8.0, 16.0, 32.0] {
            let dt = t.encoder_stage_time(&m, units, 27.0, 1);
            assert!(dt > prev, "units {units}: {dt} <= {prev}");
            prev = dt;
        }
    }

    #[test]
    fn llm_time_superlinear_in_seq() {
        // Attention quadratic ⇒ time(2s) > 2·time(s) for long sequences.
        let t = truth();
        let m = llava_ov(qwen25("7b"));
        let t1 = t.llm_stage_time(&m, &[8192.0], 28.0, 1);
        let t2 = t.llm_stage_time(&m, &[16384.0], 28.0, 1);
        assert!(t2 > 2.0 * t1, "t2 {t2} vs 2*t1 {}", 2.0 * t1);
    }

    #[test]
    fn packing_attention_depends_on_instance_lengths() {
        // Same packed total, different composition: one long sequence costs
        // more attention time than many short ones (paper §3.2.1).
        let t = truth();
        let m = llava_ov(qwen25("7b"));
        let one_long = t.llm_stage_time(&m, &[8192.0], 28.0, 1);
        let many_short = t.llm_stage_time(&m, &[1024.0; 8], 28.0, 1);
        assert!(one_long > many_short, "{one_long} vs {many_short}");
    }

    #[test]
    fn tp_degradation_worse_for_small_inputs() {
        // Fig 2's core observation: thr(tp=8)/thr(tp=1) is much lower for
        // small shapes than for large ones.
        let t = truth();
        let m = llava_ov(llama3("8b"));
        let deg_small = t.encoder_throughput(&m, 1.0, 8) / t.encoder_throughput(&m, 1.0, 1);
        let deg_large = t.encoder_throughput(&m, 64.0, 8) / t.encoder_throughput(&m, 64.0, 1);
        assert!(deg_small < deg_large, "small {deg_small} large {deg_large}");
        assert!(deg_small < 0.75, "small-input TP degradation too mild: {deg_small}");
    }

    #[test]
    fn llm_throughput_rises_with_seq_len() {
        let t = truth();
        let m = llava_ov(qwen25("7b"));
        let lo = t.llm_throughput(&m, 256.0, 1);
        let hi = t.llm_throughput(&m, 4096.0, 1);
        assert!(hi > lo, "lo {lo} hi {hi}");
        // And stays below the linear-roofline.
        assert!(hi < t.cluster.gpu.peak_flops * MFU_LINEAR);
    }

    #[test]
    fn cliffs_are_rare_and_deterministic() {
        let t = Truth::new(ClusterSpec::hgx_a100(1));
        let mut slow = 0usize;
        for b in 0..2000u64 {
            let f = t.regime_factor(b);
            assert_eq!(f, t.regime_factor(b), "determinism");
            if f < 1.0 {
                slow += 1;
                assert!((0.55..0.86).contains(&f));
            }
        }
        let frac = slow as f64 / 2000.0;
        assert!((0.03..0.10).contains(&frac), "cliff fraction {frac}");
    }

    #[test]
    fn injected_anomalies_apply() {
        let mut t = Truth::smooth(ClusterSpec::hgx_a100(1));
        let m = llava_ov(llama3("8b"));
        let base = t.llm_stage_time(&m, &[4096.0], 32.0, 1);
        let bucket = Truth::llm_bucket(4096.0);
        t.injected.push((bucket, 0.5)); // half throughput = double time
        let slowed = t.llm_stage_time(&m, &[4096.0], 32.0, 1);
        assert!(slowed > 1.5 * base, "base {base} slowed {slowed}");
    }

    #[test]
    fn zero_work_is_free() {
        let t = truth();
        let m = llava_ov(llama3("8b"));
        assert_eq!(t.encoder_stage_time(&m, 0.0, 27.0, 1), 0.0);
        assert_eq!(t.llm_stage_time(&m, &[], 32.0, 1), 0.0);
    }
}
