//! Ground-truth cluster performance model (the simulated A100 testbed).
//!
//! See DESIGN.md "Reproduction posture": the paper's physical cluster is
//! replaced by an analytic model that reproduces the shape-dependent
//! efficiency, TP-degradation, and kernel-regime behaviours DFLOP's design
//! responds to.
pub mod gpu;
pub mod truth;

pub use gpu::{ClusterSpec, GpuSpec};
pub use truth::Truth;
