//! Batched Eq-1 candidate evaluation over a shared simulation arena.
//!
//! The optimizer's refinement pass (and the heterogeneous per-shard fit
//! that reuses it) scores dozens of θ candidates against the sampled
//! distribution. Scoring one candidate costs an LPT partition plus a full
//! 1F1B simulation — but candidates overlap heavily:
//!
//! - candidates sharing `(E_tp, E_pp, L_tp, L_pp)` price items
//!   identically, so they share one structure-of-arrays [`CostTable`]
//!   (built once by [`candidate_tables`]);
//! - candidates additionally sharing the bucket count `m` share the whole
//!   LPT partition, emission order, and per-bucket stage prices;
//! - candidates sharing `(E_pp, L_pp, E_dp, L_dp, m)` — the *structure
//!   signature* — build byte-identical route topologies, differing only
//!   in leg durations. The batch evaluator sorts candidates by signature
//!   and, inside a signature group, re-prices the standing route set via
//!   [`SimWorkspace::update_leg`] + [`SimWorkspace::delta_run`] instead of
//!   rebuilding it: the counting sort, successor dedup, and 1F1B order
//!   construction run once per signature instead of once per candidate;
//! - candidates identical under both keys (same signature *and* same
//!   pricing key — they differ only in an `N_mb` that collapses to the
//!   same `m`) share a single simulation outright.
//!
//! [`eval_candidates`] exploits all four tiers and returns scores in
//! candidate order, bit-identical to the serial one-candidate-at-a-time
//! path ([`eval_candidates_serial`]) at any thread count — signature
//! groups fan out over the `util::parallel` pool, but every score is a
//! pure function of its candidate. The parity is enforced by a property
//! test here and exercised at `--threads {1,8}` by the CI matrix.

use crate::optimizer::plan::Theta;
use crate::optimizer::search::OptimizerInputs;
use crate::pipeline::sim::SimWorkspace;
use crate::profiling::estimator::Estimator;
use crate::scheduler::lpt::{lpt_table_into, Assignment, CostTable};
use crate::util::parallel::par_map;
use std::cell::RefCell;

/// A candidate's pricing key: `(E_tp, E_pp, L_tp, L_pp)` — the fields an
/// item's per-stage cost depends on.
pub type PriceKey = (usize, usize, usize, usize);

/// The pricing key of a candidate θ.
pub fn price_key(t: &Theta) -> PriceKey {
    (t.enc.tp, t.enc.pp, t.llm.tp, t.llm.pp)
}

/// Per-thread Eq-1 evaluation arena: the LPT output, emission order,
/// ablation scratch, and the 1F1B simulation workspace. Workspaces obey
/// the one-per-worker rule ([`SimWorkspace`]) by construction — each pool
/// worker (and the serial path) owns its thread-local instance and reuses
/// it across every candidate it scores.
#[derive(Default)]
pub(crate) struct EvalWorkspace {
    pub(crate) sim: SimWorkspace,
    pub(crate) assign: Assignment,
    pub(crate) order: Vec<usize>,
    pub(crate) shuffled: Vec<usize>,
    pub(crate) buckets: Vec<Vec<usize>>,
}

thread_local! {
    pub(crate) static EVAL_WS: RefCell<EvalWorkspace> = RefCell::new(EvalWorkspace::default());
}

/// The evaluation's bucket count: the candidate's `m = N_mb · L_dp`
/// compressed by the proportional-subsample scale (`gbs / eval_n` items
/// per pseudo-sample) and clamped to the evaluation batch. One definition
/// shared by the serial scorer and the batch grouper — the signature
/// grouping is only sound while both compute the same `m`.
fn bucket_count(gbs: usize, eval_n: usize, n_mb: usize, l_dp: usize) -> usize {
    let scale = (gbs as f64 / eval_n as f64).round().max(1.0) as usize;
    ((n_mb * l_dp).div_ceil(scale)).min(eval_n).max(1)
}

/// Write emission slot `j`'s legs into `sim` under the evaluator's
/// comm-free route frame: the encoder pipeline `j mod e_dp` then the LLM
/// pipeline `j mod l_dp`, fwd = t/3 and bwd = 2t/3 per leg, zero hop
/// cost. With `push` the route is appended to the workspace's route set
/// (structural build — ends the route); otherwise the standing route
/// `j`'s legs are re-priced in place via [`SimWorkspace::update_leg`]
/// for a subsequent [`SimWorkspace::delta_run`].
///
/// This is the one leg-layout definition shared by the batch evaluator
/// and `obs::audit`'s counterfactual pricer, so both frames are
/// bit-identical by construction.
#[allow(clippy::too_many_arguments)]
pub fn write_slot_legs(
    sim: &mut SimWorkspace,
    j: usize,
    e_pp: usize,
    l_pp: usize,
    e_dp: usize,
    l_dp: usize,
    e_t: f64,
    l_t: f64,
    push: bool,
) {
    if push {
        let e = j % e_dp;
        let g = j % l_dp;
        for sidx in 0..e_pp {
            sim.routes.push_leg(e * e_pp + sidx, e_t / 3.0, e_t * 2.0 / 3.0, 0.0);
        }
        for sidx in 0..l_pp {
            sim.routes.push_leg(
                e_dp * e_pp + g * l_pp + sidx,
                l_t / 3.0,
                l_t * 2.0 / 3.0,
                0.0,
            );
        }
        sim.routes.end_route();
    } else {
        for sidx in 0..e_pp {
            sim.update_leg(j, sidx, e_t / 3.0, e_t * 2.0 / 3.0);
        }
        for sidx in 0..l_pp {
            sim.update_leg(j, e_pp + sidx, l_t / 3.0, l_t * 2.0 / 3.0);
        }
    }
}

/// Eq 1: expected makespan over the sampled dataset D for one candidate.
///
/// Where Algorithm 1's inner loop scores with the mean shape, the
/// refinement evaluates the candidate against the *distribution*: the
/// sampled items are partitioned into the candidate's `m = N_mb · L_dp`
/// buckets with the same balancing the Online Scheduler will apply (LPT),
/// and the makespan is assembled from the resulting per-bucket stage
/// durations by running the 1F1B engine — steady-state plus warm-up/drain
/// bubbles, heterogeneity stalls, and encoder/LLM pipeline coupling that
/// closed forms miss. This is what lets DFLOP trade theoretical bubble
/// fraction for schedulable bucket sizes (§5.3.5).
///
/// `table` is the memoized per-item stage-cost column for this
/// candidate's pricing key (see [`candidate_tables`]): entry `i` prices
/// sample `i mod |D|` of one pseudo global batch. All mutable state lives
/// in `ws`; in steady state the call allocates nothing.
pub(crate) fn expected_makespan(
    inp: &OptimizerInputs,
    table: &CostTable,
    enc: crate::optimizer::plan::ModPar,
    llm: crate::optimizer::plan::ModPar,
    n_mb: usize,
    ws: &mut EvalWorkspace,
) -> f64 {
    let est = Estimator::new(inp.m, &inp.profile.throughput);
    let samples = &inp.data.samples;
    let n = samples.len();
    let eval_n = table.len();
    let m = bucket_count(inp.gbs, eval_n, n_mb, llm.dp);

    // Score a partition by *running the 1F1B engine* over the estimated
    // per-bucket stage durations. `order[j]` names the bucket launched at
    // position j; routes build into the workspace arena and the engine
    // skips timeline recording (only the makespan is needed).
    let e_ovh = inp.profile.throughput.enc_overhead(enc.tp);
    let l_ovh = inp.profile.throughput.llm_overhead(llm.tp);
    let n_stages = enc.dp * enc.pp + llm.dp * llm.pp;
    let score = |sim: &mut SimWorkspace, buckets: &[Vec<usize>], order: &[usize]| -> f64 {
        sim.routes.clear();
        for (j, &bj) in order.iter().enumerate() {
            // Packed pricing of this bucket's contents.
            let mut units = 0.0f64;
            sim.seqs.clear();
            for &i in &buckets[bj] {
                let shape = &samples[i % n];
                units += shape.units as f64;
                let seq = shape.llm_seq as f64;
                if seq > 0.0 {
                    sim.seqs.push(seq);
                }
            }
            let e_t = est.enc_bucket_dur(units, enc.tp) / enc.pp as f64 + e_ovh;
            let l_t = est.llm_bucket_dur(&sim.seqs, llm.tp) / llm.pp as f64 + l_ovh;
            write_slot_legs(sim, j, enc.pp, llm.pp, enc.dp, llm.dp, e_t, l_t, true);
        }
        sim.run(n_stages, false)
    };

    if inp.assume_balanced {
        lpt_table_into(table, m, &mut ws.assign);
        // Heaviest-bucket-first emission (mirrors the Online Scheduler's
        // launch order) — as a visit permutation, no clone/reorder.
        ws.assign.heavy_order(&mut ws.order);
        score(&mut ws.sim, &ws.assign.buckets, &ws.order)
    } else {
        // Optimizer-only ablation: the runtime partitions randomly, so
        // evaluate the expected makespan over seeded random partitions
        // (matching `baselines::random_buckets`' semantics). The shuffle
        // and bucket scratch live in the workspace — they used to be
        // reallocated every rep of every candidate.
        let mut rng = crate::util::rng::Rng::new(0xAB1A);
        let reps = 2;
        let mut acc = 0.0;
        // Identity emission order: the random partitioner shuffles bucket
        // contents, not their launch order.
        ws.order.clear();
        ws.order.extend(0..m);
        ws.buckets.resize_with(m, Vec::new);
        for _ in 0..reps {
            ws.shuffled.clear();
            ws.shuffled.extend(0..eval_n);
            rng.shuffle(&mut ws.shuffled);
            for b in ws.buckets.iter_mut() {
                b.clear();
            }
            for (pos, &i) in ws.shuffled.iter().enumerate() {
                ws.buckets[pos % m].push(i);
            }
            acc += score(&mut ws.sim, &ws.buckets, &ws.order);
        }
        acc / reps as f64
    }
}

/// Build the memoized per-pricing-key cost tables for a candidate set.
///
/// Refinement partitions one pseudo global batch of item costs whose
/// entries depend only on the candidate's pricing key — and many
/// candidates share that key, differing only in `N_mb` — so each distinct
/// key's table is built once. Per-item durations are precomputed per TP
/// degree first, then divided by each key's PP.
///
/// Evaluation batch cap: beyond 512 items the score is computed on a
/// proportional subsample (bucket sizes — gbs/m items each — are
/// preserved, so granularity effects survive the scaling). Keeps the
/// refinement inside Fig 16a's budget at GBS 2048.
///
/// Returns the sorted, deduplicated keys and their tables in key order;
/// look a candidate up with `keys.binary_search(&price_key(t))`.
pub fn candidate_tables(
    inp: &OptimizerInputs,
    cands: &[Theta],
) -> (Vec<PriceKey>, Vec<CostTable>) {
    let est = Estimator::new(inp.m, &inp.profile.throughput);
    let mut tps: Vec<usize> = cands.iter().flat_map(|t| [t.enc.tp, t.llm.tp]).collect();
    tps.sort_unstable();
    tps.dedup();
    let mut enc_durs: Vec<(usize, Vec<f64>)> = Vec::new();
    let mut llm_durs: Vec<(usize, Vec<f64>)> = Vec::new();
    for &tp in &tps {
        enc_durs.push((
            tp,
            inp.data.samples.iter().map(|s| est.enc_item_dur(s, tp)).collect(),
        ));
        llm_durs.push((
            tp,
            inp.data.samples.iter().map(|s| est.llm_item_dur(s, tp)).collect(),
        ));
    }
    fn durs_for(v: &[(usize, Vec<f64>)], tp: usize) -> &[f64] {
        &v.iter().find(|(t, _)| *t == tp).expect("precomputed tp").1
    }

    let eval_n = inp.gbs.min(512);
    let n_samples = inp.data.samples.len();
    let mut keys: Vec<PriceKey> = cands.iter().map(price_key).collect();
    keys.sort_unstable();
    keys.dedup();
    let tables: Vec<CostTable> = keys
        .iter()
        .map(|&(e_tp, e_pp, l_tp, l_pp)| {
            let e = durs_for(&enc_durs, e_tp);
            let l = durs_for(&llm_durs, l_tp);
            let mut t = CostTable::new();
            for i in 0..eval_n {
                t.push(e[i % n_samples] / e_pp as f64, l[i % n_samples] / l_pp as f64);
            }
            t
        })
        .collect();
    (keys, tables)
}

/// The route-topology fields of a candidate: two candidates with equal
/// signatures build byte-identical route sets (stage ids, leg counts,
/// zero hops), differing only in durations.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
struct Sig {
    e_pp: usize,
    l_pp: usize,
    e_dp: usize,
    l_dp: usize,
    m: usize,
}

/// Score one pricing key under a fixed structure signature. `reuse` means
/// the workspace's standing route set was built by a previous call with
/// the same signature: legs are re-priced in place ([`SimWorkspace::update_leg`])
/// and the recorded execution order replayed ([`SimWorkspace::delta_run`])
/// instead of rebuilding the topology and the 1F1B static order.
#[allow(clippy::too_many_arguments)]
fn eval_keyed(
    inp: &OptimizerInputs,
    est: &Estimator<'_>,
    table: &CostTable,
    key: PriceKey,
    sig: Sig,
    n_stages: usize,
    reuse: bool,
    ws: &mut EvalWorkspace,
) -> f64 {
    let (e_tp, e_pp, l_tp, l_pp) = key;
    let samples = &inp.data.samples;
    let n = samples.len();
    let e_ovh = inp.profile.throughput.enc_overhead(e_tp);
    let l_ovh = inp.profile.throughput.llm_overhead(l_tp);
    lpt_table_into(table, sig.m, &mut ws.assign);
    ws.assign.heavy_order(&mut ws.order);
    if !reuse {
        ws.sim.routes.clear();
    }
    for (j, &bj) in ws.order.iter().enumerate() {
        let mut units = 0.0f64;
        ws.sim.seqs.clear();
        for &i in &ws.assign.buckets[bj] {
            let shape = &samples[i % n];
            units += shape.units as f64;
            let seq = shape.llm_seq as f64;
            if seq > 0.0 {
                ws.sim.seqs.push(seq);
            }
        }
        let e_t = est.enc_bucket_dur(units, e_tp) / e_pp as f64 + e_ovh;
        let l_t = est.llm_bucket_dur(&ws.sim.seqs, l_tp) / l_pp as f64 + l_ovh;
        write_slot_legs(&mut ws.sim, j, e_pp, l_pp, sig.e_dp, sig.l_dp, e_t, l_t, !reuse);
    }
    if reuse {
        ws.sim.delta_run(n_stages)
    } else {
        ws.sim.run_tracked(n_stages)
    }
}

/// Score every candidate, batched: scores return in candidate order and
/// bit-match [`eval_candidates_serial`] (and therefore the pre-batching
/// per-candidate path) at any thread count.
///
/// `keys`/`tables` come from [`candidate_tables`] over a superset of
/// `cands`. The random-partition ablation (`assume_balanced = false`)
/// keeps the per-candidate path — its shuffle stream is per-candidate
/// state with nothing to share.
pub fn eval_candidates(
    inp: &OptimizerInputs,
    keys: &[PriceKey],
    tables: &[CostTable],
    cands: &[Theta],
) -> Vec<f64> {
    if cands.is_empty() {
        return Vec::new();
    }
    if !inp.assume_balanced {
        return eval_candidates_serial(inp, keys, tables, cands);
    }
    let eval_n = tables.first().map(CostTable::len).unwrap_or(0);
    // Tag each candidate with (signature, pricing-key index) and sort:
    // equal signatures become contiguous runs, equal (sig, key) pairs
    // collapse to one simulation.
    let mut tagged: Vec<(Sig, usize, usize)> = cands
        .iter()
        .enumerate()
        .map(|(k, t)| {
            let ti = keys.binary_search(&price_key(t)).expect("memoized key");
            let sig = Sig {
                e_pp: t.enc.pp,
                l_pp: t.llm.pp,
                e_dp: t.enc.dp,
                l_dp: t.llm.dp,
                m: bucket_count(inp.gbs, eval_n, t.n_mb, t.llm.dp),
            };
            (sig, ti, k)
        })
        .collect();
    tagged.sort_unstable();
    let mut groups: Vec<(usize, usize)> = Vec::new();
    let mut lo = 0usize;
    for hi in 1..=tagged.len() {
        if hi == tagged.len() || tagged[hi].0 != tagged[lo].0 {
            groups.push((lo, hi));
            lo = hi;
        }
    }

    let est = Estimator::new(inp.m, &inp.profile.throughput);
    let est = &est;
    let tagged = &tagged;
    let parts: Vec<Vec<(usize, f64)>> = par_map(groups.len(), |gi| {
        let (lo, hi) = groups[gi];
        let sig = tagged[lo].0;
        let n_stages = sig.e_dp * sig.e_pp + sig.l_dp * sig.l_pp;
        EVAL_WS.with(|cell| {
            let ws = &mut *cell.borrow_mut();
            let mut out = Vec::with_capacity(hi - lo);
            let mut last_ti = usize::MAX;
            let mut last_score = 0.0f64;
            let mut have_routes = false;
            for &(_, ti, k) in &tagged[lo..hi] {
                if ti != last_ti {
                    last_score = eval_keyed(
                        inp, est, &tables[ti], keys[ti], sig, n_stages, have_routes, ws,
                    );
                    have_routes = true;
                    last_ti = ti;
                }
                out.push((k, last_score));
            }
            out
        })
    });
    let mut scores = vec![0.0f64; cands.len()];
    for part in parts {
        for (k, s) in part {
            scores[k] = s;
        }
    }
    scores
}

/// The serial reference: one [`expected_makespan`] call per candidate in
/// order, no cross-candidate sharing. Retained as the batched path's
/// bit-exactness oracle (property-tested below) and as the before/after
/// baseline in `optimizer_bench`.
pub fn eval_candidates_serial(
    inp: &OptimizerInputs,
    keys: &[PriceKey],
    tables: &[CostTable],
    cands: &[Theta],
) -> Vec<f64> {
    par_map(cands.len(), |k| {
        let t = &cands[k];
        let ti = keys.binary_search(&price_key(t)).expect("memoized key");
        EVAL_WS.with(|ws| {
            expected_makespan(inp, &tables[ti], t.enc, t.llm, t.n_mb, &mut ws.borrow_mut())
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov, Mllm};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{profile_data, DataProfile, ModelProfile, ModelProfiler, ProfilerGrids};
    use crate::util::prop::forall;

    fn fixture() -> (Mllm, ModelProfile, DataProfile, ClusterSpec) {
        let m = llava_ov(llama3("8b"));
        let cluster = ClusterSpec::hgx_a100(2);
        let mut backend = SimBackend::new(Truth::new(cluster));
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let mut ds = Dataset::mixed(77);
        let data = profile_data(&m, &mut ds, 128);
        (m, profile, data, cluster)
    }

    fn inputs<'a>(
        m: &'a Mllm,
        profile: &'a ModelProfile,
        data: &'a DataProfile,
        cluster: &ClusterSpec,
        gbs: usize,
        balanced: bool,
    ) -> OptimizerInputs<'a> {
        OptimizerInputs {
            m,
            profile,
            data,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs,
            assume_balanced: balanced,
        }
    }

    /// A random plausible θ (feasibility is irrelevant to the evaluator).
    fn random_theta(g: &mut crate::util::prop::Gen) -> Theta {
        Theta {
            enc: ModPar { tp: 1 << g.rng.index(2), pp: g.size(2), dp: g.size(2) },
            llm: ModPar { tp: 1 << g.rng.index(3), pp: g.size(4), dp: g.size(2) },
            n_mb: g.size(24),
        }
    }

    #[test]
    fn batched_scores_bitmatch_serial_in_candidate_order() {
        // The tentpole contract for the evaluator: batching (shared
        // tables, shared partitions, delta-replayed route re-pricing,
        // collapsed duplicates) must not move a single bit relative to
        // scoring each candidate alone.
        let (m, profile, data, cluster) = fixture();
        let inp = inputs(&m, &profile, &data, &cluster, 96, true);
        forall("batched eval = serial eval", 25, |g| {
            let n_cands = g.size(24);
            let cands: Vec<Theta> = (0..n_cands).map(|_| random_theta(g)).collect();
            let (keys, tables) = candidate_tables(&inp, &cands);
            let batched = eval_candidates(&inp, &keys, &tables, &cands);
            let serial = eval_candidates_serial(&inp, &keys, &tables, &cands);
            let ok = batched.len() == serial.len()
                && batched
                    .iter()
                    .zip(&serial)
                    .all(|(a, b)| a.to_bits() == b.to_bits());
            (format!("n_cands={n_cands} keys={}", keys.len()), ok)
        });
    }

    #[test]
    fn unbalanced_path_matches_serial_too() {
        let (m, profile, data, cluster) = fixture();
        let inp = inputs(&m, &profile, &data, &cluster, 64, false);
        forall("unbalanced batched = serial", 8, |g| {
            let cands: Vec<Theta> = (0..g.size(8)).map(|_| random_theta(g)).collect();
            let (keys, tables) = candidate_tables(&inp, &cands);
            let batched = eval_candidates(&inp, &keys, &tables, &cands);
            let serial = eval_candidates_serial(&inp, &keys, &tables, &cands);
            let ok = batched
                .iter()
                .zip(&serial)
                .all(|(a, b)| a.to_bits() == b.to_bits());
            (format!("n_cands={}", cands.len()), ok)
        });
    }

    #[test]
    fn duplicate_candidates_share_one_score() {
        let (m, profile, data, cluster) = fixture();
        let inp = inputs(&m, &profile, &data, &cluster, 48, true);
        let t = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 3, dp: 1 },
            n_mb: 6,
        };
        let cands = vec![t, t, t];
        let (keys, tables) = candidate_tables(&inp, &cands);
        let scores = eval_candidates(&inp, &keys, &tables, &cands);
        assert_eq!(scores.len(), 3);
        assert!(scores[0] > 0.0);
        assert_eq!(scores[0].to_bits(), scores[1].to_bits());
        assert_eq!(scores[1].to_bits(), scores[2].to_bits());
    }

    #[test]
    fn empty_candidate_set_yields_empty_scores() {
        let (m, profile, data, cluster) = fixture();
        let inp = inputs(&m, &profile, &data, &cluster, 48, true);
        let (keys, tables) = candidate_tables(&inp, &[]);
        assert!(keys.is_empty() && tables.is_empty());
        assert!(eval_candidates(&inp, &keys, &tables, &[]).is_empty());
    }
}
