//! Algorithm 1: the Data-aware 3D Parallelism Optimizer (§3.3).
//!
//! Phase 1 enumerates every GPU split between encoder and LLM and every
//! (TP, PP, DP) factorization of each side (`find_combs`). Phase 2 sweeps
//! the microbatch count, rejects memory-infeasible candidates via the
//! profiled memory model (Eq 4–5), scores the survivors with the profiled
//! throughput model, and returns θ*.
//!
//! Scoring follows the paper in two tiers:
//! - the **mean approximation** of Algorithm 1 (lines 14–27): stage
//!   durations from the dataset's mean shapes — O(1) per candidate, used to
//!   scan the full space;
//! - the **expected makespan** of Eq 1: the top `REFINE_K` candidates are
//!   re-scored as `1/|D| · Σ_d T(d;θ)` over the Data Profiler's samples,
//!   which is what the objective actually asks for. The refinement is
//!   delegated to the batched candidate evaluator (`optimizer::batch`):
//!   per-item durations are memoized per (TP, PP) key into
//!   structure-of-arrays cost tables, candidates sharing a route-topology
//!   signature re-price one standing simulation arena via the delta-replay
//!   engine, and duplicates collapse to a single simulation — bit-identical
//!   to scoring every candidate alone.
//!
//! Both tiers run on the `util::parallel` pool: each split's (pair × N_mb)
//! scan is scored across workers and merged in candidate order, and the
//! refinement's signature groups (the dominant cost) run one per worker.
//! Merging preserves the serial insertion order, so θ* is bit-identical to
//! the single-threaded search at any `--threads` value.

use crate::model::catalog::Mllm;
use crate::optimizer::batch::{candidate_tables, eval_candidates};
use crate::optimizer::plan::{find_combs, ModPar, Theta};
use crate::profiling::engine::{DataProfile, ModelProfile};
use crate::profiling::estimator::Estimator;
use crate::util::parallel::par_map;

/// Inputs fixed for one optimization run.
pub struct OptimizerInputs<'a> {
    pub m: &'a Mllm,
    pub profile: &'a ModelProfile,
    pub data: &'a DataProfile,
    pub n_gpus: usize,
    pub gpus_per_node: usize,
    /// Per-GPU memory capacity in bytes (M_gpu).
    pub mem_capacity: f64,
    /// Global batch size (items per iteration across the cluster).
    pub gbs: usize,
    /// Whether the runtime will balance bucket loads (DFLOP's Online
    /// Scheduler). When false — e.g. the optimizer-only ablation that runs
    /// with random microbatching — the expected-makespan refinement models
    /// arbitrary (round-robin) bucket composition instead of LPT balance.
    pub assume_balanced: bool,
}

/// The selected strategy with diagnostics.
#[derive(Clone, Debug)]
pub struct OptimizerResult {
    pub theta: Theta,
    /// Expected makespan (seconds per iteration) under Eq 1.
    pub expected_makespan: f64,
    /// Search-space statistics.
    pub candidates_scanned: usize,
    pub memory_rejected: usize,
    /// Wall-clock of the optimization itself (Fig 16a / Table 4).
    pub elapsed: std::time::Duration,
}

/// How many mean-scored candidates get the full Eq-1 refinement pass.
const REFINE_K: usize = 64;

/// Stage durations for a candidate under the mean-shape approximation
/// (Algorithm 1 lines 18–26).
fn mean_stage_durations(
    inp: &OptimizerInputs,
    est: &Estimator,
    enc: ModPar,
    llm: ModPar,
    n_mb: usize,
) -> (f64, f64) {
    let gbs = inp.gbs as f64;
    // Mean per-item durations at each module's TP degree; a microbatch
    // carries GBS/(i·dp) items, the module spreads it over pp stages.
    let mean_units = inp.data.mean_units();
    let mean_seq = inp.data.mean_seq();
    let items_e = gbs / (n_mb as f64 * enc.dp as f64);
    let items_l = gbs / (n_mb as f64 * llm.dp as f64);
    let thr = &inp.profile.throughput;
    // Packed-bucket pricing without per-call allocation: linear work runs
    // at the packed total's throughput; attention per instance.
    let e_dur = est.enc_bucket_dur(mean_units * items_e, enc.tp) / enc.pp as f64
        + thr.enc_overhead(enc.tp);
    let l_dur = est.llm_bucket_dur_uniform(mean_seq, items_l, llm.tp) / llm.pp as f64
        + thr.llm_overhead(llm.tp);
    (e_dur, l_dur)
}

/// 1F1B makespan formula (§3.3.1):
/// `T = (N_mb + E_pp + L_pp − 1) · max(E_dur, L_dur)`.
fn makespan(n_mb: usize, enc_pp: usize, llm_pp: usize, e_dur: f64, l_dur: f64) -> f64 {
    (n_mb + enc_pp + llm_pp - 1) as f64 * e_dur.max(l_dur)
}

/// Memory feasibility (Eq 4–5). The encoder's activations are retained for
/// the whole pipeline depth (`E_pp + L_pp` in-flight microbatches); the LLM
/// holds up to `L_pp` in-flight microbatches under 1F1B.
fn memory_feasible(
    inp: &OptimizerInputs,
    enc: ModPar,
    llm: ModPar,
    mb_units: f64,
    mb_seq: f64,
) -> bool {
    let e_layers = inp.m.encoder.layers as f64 / enc.pp as f64;
    let l_layers = inp.m.llm.layers as f64 / llm.pp as f64;
    let mem = &inp.profile.memory;
    let mem_e = mem.e_state_bytes(e_layers, enc.tp)
        + (enc.pp + llm.pp) as f64 * mem.e_act_bytes(e_layers, enc.tp, mb_units);
    let mem_l = mem.l_state_bytes(l_layers, llm.tp)
        + llm.pp as f64 * mem.l_act_bytes(l_layers, llm.tp, mb_seq);
    mem_e <= inp.mem_capacity && mem_l <= inp.mem_capacity
}

/// Run Algorithm 1 and return θ*.
pub fn optimize(inp: &OptimizerInputs) -> Option<OptimizerResult> {
    optimize_warm(inp, None)
}

/// Algorithm 1 **warm-started from an incumbent θ***.
///
/// The `stream::replan` controller re-optimizes against a refitted live
/// distribution while training runs; a cold search would rescan the whole
/// strategy space every time. The incumbent (when still GPU- and
/// memory-feasible under the live mean shapes) is re-scored with the live
/// distribution and becomes (1) the first entry of the refinement top-K —
/// so the swap decision always compares the candidate plans against the
/// current one under the *same* data — and (2) a pruning bound with a
/// slack margin: GPU splits whose lower bound cannot come within
/// `WARM_SLACK` of the incumbent's mean score are dropped before the
/// top-K fills, typically collapsing the scan to the incumbent's
/// neighbourhood while keeping plausible Eq-1 winners (mean score is
/// only the refinement's filter) in play. Cold calls (`incumbent = None`)
/// follow the exact original scan. Either way the result is
/// deterministic and thread-count-independent (`tests/determinism.rs`).
pub fn optimize_warm(
    inp: &OptimizerInputs,
    incumbent: Option<Theta>,
) -> Option<OptimizerResult> {
    let start = std::time::Instant::now();
    let est = Estimator::new(inp.m, &inp.profile.throughput);

    // ---- Phase 1: enumerate the candidate space, split-bound-first ----
    // Lower bound per GPU split: even perfect parallelization cannot beat
    // each module's total work divided over its GPUs at peak (tp = 1,
    // fully-packed) efficiency. Splits are processed in ascending-bound
    // order and the scan stops once the bound cannot enter the top-K —
    // this is what keeps Fig 16a in the sub-second range at 1024 GPUs.
    let max_e_pp = inp.m.encoder.layers;
    let max_l_pp = inp.m.llm.layers;
    let gbs_f = inp.gbs as f64;
    let w_e = est.enc_bucket_dur(inp.data.mean_units() * gbs_f, 1);
    let w_l = est.llm_bucket_dur_uniform(inp.data.mean_seq(), gbs_f, 1);
    let mut splits: Vec<(f64, usize)> = (1..inp.n_gpus)
        .map(|e_gpus| {
            let l_gpus = inp.n_gpus - e_gpus;
            let lb = (w_e / e_gpus as f64).max(w_l / l_gpus as f64);
            (lb, e_gpus)
        })
        .collect();
    splits.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN bound"));

    // ---- Phase 2: sweep N_mb, check memory, score by mean makespan ----
    let mean_units = inp.data.mean_units();
    let mean_seq = inp.data.mean_seq();
    let mut scanned = 0usize;
    let mut mem_rejected = 0usize;
    // Keep the best-REFINE_K candidates by mean score.
    let mut top: Vec<(f64, Theta)> = Vec::new();
    // Geometric microbatch-count grid: T(i) = (i+p−1)·max(E(i), L(i)) is
    // smooth in i, so scoring ~1.3×-spaced counts (plus the endpoints)
    // loses nothing the top-K refinement can't recover, and keeps the scan
    // within the paper's Fig 16a budget at GBS 2048.
    let n_mb_grid = |n_max: usize| -> Vec<usize> {
        let mut v = Vec::new();
        let mut i = 1usize;
        while i <= n_max {
            v.push(i);
            i = (i as f64 * 1.3).ceil() as usize;
        }
        if *v.last().unwrap_or(&0) != n_max {
            v.push(n_max);
        }
        v
    };
    // Serial-order top-K insertion (shared by the serial and merged paths).
    let push_top = |top: &mut Vec<(f64, Theta)>, t: f64, theta: Theta| {
        if top.len() < REFINE_K {
            top.push((t, theta));
            top.sort_by(|a, b| a.0.partial_cmp(&b.0).expect("NaN score"));
        } else if t < top.last().expect("non-empty top").0 {
            top.pop();
            let pos = top
                .binary_search_by(|probe| probe.0.partial_cmp(&t).expect("NaN"))
                .unwrap_or_else(|p| p);
            top.insert(pos, (t, theta));
        }
    };
    // Warm start: seed the top-K with the incumbent re-scored under the
    // live mean shapes. Its mean score also prunes splits before the
    // top-K fills — with a slack margin, because the scan's mean score is
    // only a *filter* for the Eq-1 refinement: a split whose lower bound
    // is modestly above the incumbent's mean score can still hold the
    // Eq-1 winner (the two metrics disagree exactly when the distribution
    // is skewed, i.e. post-drift), so only splits that cannot come within
    // WARM_SLACK of the incumbent are dropped.
    const WARM_SLACK: f64 = 1.5;
    let mut warm_bound = f64::INFINITY;
    let mut warm_seed: Option<Theta> = None;
    if let Some(t) = incumbent {
        if t.gpus() == inp.n_gpus && t.n_mb >= 1 {
            let mb_units = mean_units * inp.gbs as f64 / (t.n_mb as f64 * t.enc.dp as f64);
            let mb_seq = mean_seq * inp.gbs as f64 / (t.n_mb as f64 * t.llm.dp as f64);
            if memory_feasible(inp, t.enc, t.llm, mb_units, mb_seq) {
                let (e_dur, l_dur) = mean_stage_durations(inp, &est, t.enc, t.llm, t.n_mb);
                let score = makespan(t.n_mb, t.enc.pp, t.llm.pp, e_dur, l_dur);
                warm_bound = score * WARM_SLACK;
                warm_seed = Some(t);
                push_top(&mut top, score, t);
            }
        }
    }
    for &(split_lb, e_gpus) in &splits {
        // Prune whole splits once the bound cannot enter a full top-K —
        // or, warm-started, cannot come within the slack margin of the
        // incumbent's mean score.
        let prune_at = if top.len() == REFINE_K {
            top.last().expect("top full").0
        } else {
            warm_bound
        };
        if split_lb >= prune_at {
            break;
        }
        let l_gpus = inp.n_gpus - e_gpus;
        let e_combs = find_combs(e_gpus, inp.gpus_per_node, max_e_pp);
        let l_combs = find_combs(l_gpus, inp.gpus_per_node, max_l_pp);
        let mut pairs: Vec<(ModPar, ModPar)> = Vec::new();
        for &e in &e_combs {
            for &l in &l_combs {
                // DP-group compatibility: the Inter-model Communicator
                // gathers/scatters cleanly when one DP degree divides the
                // other (Fig 6's 4→2 example); coprime group counts create
                // head-of-line blocking between pipelines.
                if e.dp % l.dp != 0 && l.dp % e.dp != 0 {
                    continue;
                }
                pairs.push((e, l));
            }
        }
        // Score one pair's whole N_mb sweep: (scanned, rejected, candidates
        // in sweep order). Candidates merge below in (pair, n_mb) order —
        // exactly the serial insertion sequence — so the resulting top-K is
        // independent of how the pairs were distributed over workers.
        let score_pair = |pi: usize| -> (usize, usize, Vec<(f64, Theta)>) {
            let (enc, llm) = pairs[pi];
            let n_max = (inp.gbs / llm.dp).max(1);
            let mut found = Vec::new();
            let mut pair_scanned = 0usize;
            let mut pair_rejected = 0usize;
            for n_mb in n_mb_grid(n_max) {
                pair_scanned += 1;
                // Mean shape per microbatch (Algorithm 1 lines 18–19).
                let mb_units = mean_units * inp.gbs as f64 / (n_mb as f64 * enc.dp as f64);
                let mb_seq = mean_seq * inp.gbs as f64 / (n_mb as f64 * llm.dp as f64);
                if !memory_feasible(inp, enc, llm, mb_units, mb_seq) {
                    pair_rejected += 1;
                    continue;
                }
                let (e_dur, l_dur) = mean_stage_durations(inp, &est, enc, llm, n_mb);
                let t = makespan(n_mb, enc.pp, llm.pp, e_dur, l_dur);
                found.push((t, Theta { enc, llm, n_mb }));
            }
            (pair_scanned, pair_rejected, found)
        };
        // Below ~16 pairs the sweep is cheaper than spawning workers.
        let scored: Vec<(usize, usize, Vec<(f64, Theta)>)> = if pairs.len() >= 16 {
            par_map(pairs.len(), score_pair)
        } else {
            (0..pairs.len()).map(score_pair).collect()
        };
        for (pair_scanned, pair_rejected, found) in scored {
            scanned += pair_scanned;
            mem_rejected += pair_rejected;
            for (t, theta) in found {
                // The scan re-encounters the warm-seeded incumbent at its
                // own (pair, n_mb) grid point; skip the twin so it cannot
                // waste one of the REFINE_K Eq-1 slots.
                if warm_seed == Some(theta) {
                    continue;
                }
                push_top(&mut top, t, theta);
            }
        }
    }

    if top.is_empty() {
        return None;
    }

    // ---- Refinement: Eq-1 expected makespan over the sampled D ----
    // Eq-1 scoring dominates the optimizer's wall-clock: hand the top-K
    // to the batched evaluator, which memoizes one SoA cost table per
    // (TP, PP) pricing key, shares LPT partitions and delta-replays route
    // re-pricing inside each structure-signature group, and fans the
    // groups out over the pool. Scores come back in rank order,
    // bit-identical to scoring each candidate alone; the strict `<` below
    // keeps the earliest-ranked of tied scores, matching the serial
    // scan's winner.
    let thetas: Vec<Theta> = top.iter().map(|&(_, t)| t).collect();
    let (keys, tables) = candidate_tables(inp, &thetas);
    let scores = eval_candidates(inp, &keys, &tables, &thetas);
    let mut best: Option<(f64, Theta)> = None;
    for (score, (_, theta)) in scores.iter().zip(&top) {
        if best.map(|(b, _)| *score < b).unwrap_or(true) {
            best = Some((*score, *theta));
        }
    }

    let (expected, theta) = best.expect("top was non-empty");
    Some(OptimizerResult {
        theta,
        expected_makespan: expected,
        candidates_scanned: scanned,
        memory_rejected: mem_rejected,
        elapsed: start.elapsed(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{internvl_25, llava_ov, llama3, qwen25};
    use crate::perfmodel::{ClusterSpec, Truth};
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};

    fn setup(
        m: &Mllm,
        nodes: usize,
        gbs: usize,
    ) -> (ModelProfile, DataProfile, ClusterSpec) {
        let cluster = ClusterSpec::hgx_a100(nodes);
        let truth = Truth::new(cluster);
        let mut backend = SimBackend::new(truth);
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(m);
        let mut ds = Dataset::mixed(1234);
        let data = profile_data(m, &mut ds, 512);
        let _ = gbs;
        (profile, data, cluster)
    }

    fn run(m: &Mllm, nodes: usize, gbs: usize) -> OptimizerResult {
        let (profile, data, cluster) = setup(m, nodes, gbs);
        let inp = OptimizerInputs {
            m,
            profile: &profile,
            data: &data,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs,
            assume_balanced: true,
        };
        optimize(&inp).expect("feasible config must exist")
    }

    #[test]
    fn returns_valid_theta_respecting_gpu_budget() {
        let m = llava_ov(llama3("8b"));
        let r = run(&m, 1, 64);
        assert_eq!(r.theta.gpus(), 8, "Eq 3 violated: {}", r.theta);
        assert!(r.theta.n_mb >= 1);
        assert!(r.expected_makespan > 0.0);
        assert!(r.candidates_scanned > 0);
    }

    #[test]
    fn small_encoder_gets_minority_of_gpus() {
        // SigLIP-0.4B vs Llama-3-8B: the encoder share must be small.
        let m = llava_ov(llama3("8b"));
        let r = run(&m, 4, 128);
        assert!(
            r.theta.enc.gpus() < r.theta.llm.gpus(),
            "encoder got {} of {} GPUs",
            r.theta.enc.gpus(),
            32
        );
    }

    #[test]
    fn big_encoder_gets_bigger_share() {
        // InternViT-6B shifts GPUs toward the encoder relative to SigLIP.
        let small = run(&llava_ov(qwen25("72b")), 4, 128);
        let big = run(&internvl_25(qwen25("72b")), 4, 128);
        assert!(
            big.theta.enc.gpus() > small.theta.enc.gpus(),
            "internvl enc {} vs llava enc {}",
            big.theta.enc.gpus(),
            small.theta.enc.gpus()
        );
    }

    #[test]
    fn memory_pressure_rejects_candidates() {
        let m = llava_ov(qwen25("72b"));
        let r = run(&m, 4, 128);
        assert!(r.memory_rejected > 0, "72B on 32 GPUs must hit memory limits");
    }

    #[test]
    fn big_model_forces_model_parallelism() {
        // 72B at 16 B/param model state cannot fit a single A100-80G:
        // tp·pp of the chosen LLM strategy must exceed ~16.
        let m = llava_ov(qwen25("72b"));
        let r = run(&m, 4, 128);
        let slice = r.theta.llm.tp * r.theta.llm.pp;
        assert!(slice >= 16, "llm slice {} too small for 72B", slice);
    }

    #[test]
    fn infeasible_when_memory_impossible() {
        let m = llava_ov(qwen25("72b"));
        let (profile, data, cluster) = setup(&m, 1, 32);
        let inp = OptimizerInputs {
            m: &m,
            profile: &profile,
            data: &data,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            // 1 GiB GPUs: nothing fits.
            mem_capacity: 1024.0 * 1024.0 * 1024.0,
            gbs: 32,
            assume_balanced: true,
        };
        assert!(optimize(&inp).is_none());
    }

    #[test]
    fn warm_start_never_worse_and_scans_no_more() {
        let m = llava_ov(llama3("8b"));
        let (profile, data, cluster) = setup(&m, 2, 64);
        let inp = OptimizerInputs {
            m: &m,
            profile: &profile,
            data: &data,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs: 64,
            assume_balanced: true,
        };
        let cold = optimize(&inp).expect("feasible");
        let warm = optimize_warm(&inp, Some(cold.theta)).expect("feasible");
        // The incumbent is in the warm top-K, so the winner's Eq-1 score
        // can only match or beat it.
        assert!(
            warm.expected_makespan <= cold.expected_makespan * (1.0 + 1e-12),
            "warm {} worse than cold {}",
            warm.expected_makespan,
            cold.expected_makespan
        );
        // Warm pruning can only shrink the scan (+1 for the seed itself).
        assert!(
            warm.candidates_scanned <= cold.candidates_scanned + 1,
            "warm scanned {} vs cold {}",
            warm.candidates_scanned,
            cold.candidates_scanned
        );
    }

    #[test]
    fn warm_start_ignores_mismatched_incumbent() {
        // An incumbent sized for a different cluster cannot seed the
        // search: the warm call must reproduce the cold result exactly.
        let m = llava_ov(llama3("8b"));
        let (profile, data, cluster) = setup(&m, 1, 32);
        let inp = OptimizerInputs {
            m: &m,
            profile: &profile,
            data: &data,
            n_gpus: cluster.total_gpus(),
            gpus_per_node: cluster.gpus_per_node,
            mem_capacity: cluster.gpu.mem_bytes,
            gbs: 32,
            assume_balanced: true,
        };
        let bogus = Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 1, dp: 1 },
            n_mb: 1,
        };
        let cold = optimize(&inp).expect("feasible");
        let warm = optimize_warm(&inp, Some(bogus)).expect("feasible");
        assert_eq!(cold.theta, warm.theta);
        assert_eq!(
            cold.expected_makespan.to_bits(),
            warm.expected_makespan.to_bits()
        );
        assert_eq!(cold.candidates_scanned, warm.candidates_scanned);
    }

    #[test]
    fn optimizer_is_fast_at_paper_scale() {
        // Fig 16a: < 200 ms at 1024 GPUs. Check a smaller scale here
        // (the bench harness covers 1024).
        let m = llava_ov(llama3("8b"));
        let r = run(&m, 8, 512);
        assert!(
            r.elapsed.as_millis() < 2_000,
            "optimizer took {:?}",
            r.elapsed
        );
    }
}
