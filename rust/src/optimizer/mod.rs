//! The Data-aware 3D Parallelism Optimizer (§3.3, Algorithm 1).
pub mod batch;
pub mod plan;
pub mod search;

pub use plan::{find_combs, ModPar, Theta};
pub use search::{optimize, OptimizerInputs, OptimizerResult};
