//! Parallelism strategy types: the parameter vector θ of §3.3.1.

use std::fmt;

/// 3D parallelism degrees for one module; `tp · pp · dp` GPUs total.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ModPar {
    pub tp: usize,
    pub pp: usize,
    pub dp: usize,
}

impl ModPar {
    pub fn gpus(&self) -> usize {
        self.tp * self.pp * self.dp
    }
}

impl fmt::Display for ModPar {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "(tp={}, pp={}, dp={})", self.tp, self.pp, self.dp)
    }
}

/// The complete strategy θ = (E_tp, E_pp, E_dp, L_tp, L_pp, L_dp, N_mb).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Theta {
    pub enc: ModPar,
    pub llm: ModPar,
    /// Microbatches per pipeline (per LLM data-parallel group).
    pub n_mb: usize,
}

impl Theta {
    /// Total pipeline depth `E_pp + L_pp`.
    pub fn pipeline_depth(&self) -> usize {
        self.enc.pp + self.llm.pp
    }

    /// GPU accounting constraint (Eq 3).
    pub fn gpus(&self) -> usize {
        self.enc.gpus() + self.llm.gpus()
    }

    /// Buckets per iteration `m = N_mb · L_dp` (§3.4).
    pub fn buckets(&self) -> usize {
        self.n_mb * self.llm.dp
    }
}

impl fmt::Display for Theta {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "enc{} llm{} n_mb={}",
            self.enc, self.llm, self.n_mb
        )
    }
}

/// Enumerate all (tp, pp, dp) factorizations of `gpus` with
/// `tp ∈ {1, 2, 4, …, gpus_per_node}` (Eq 2: TP stays intra-node),
/// `pp ≤ max_pp` (cannot exceed layer count), and `dp ≥ 1`
/// — Algorithm 1's `FindCombs`.
pub fn find_combs(gpus: usize, gpus_per_node: usize, max_pp: usize) -> Vec<ModPar> {
    let mut out = Vec::new();
    let mut tp = 1;
    while tp <= gpus_per_node {
        if gpus % tp == 0 {
            let rest = gpus / tp;
            for pp in 1..=rest.min(max_pp) {
                if rest % pp == 0 {
                    out.push(ModPar { tp, pp, dp: rest / pp });
                }
            }
        }
        tp *= 2;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn find_combs_products_are_exact() {
        for gpus in [1usize, 4, 8, 24, 32] {
            for c in find_combs(gpus, 8, 64) {
                assert_eq!(c.gpus(), gpus, "{c}");
                assert!(c.tp.is_power_of_two());
                assert!(c.tp <= 8);
            }
        }
    }

    #[test]
    fn find_combs_respects_max_pp() {
        let combs = find_combs(32, 8, 2);
        assert!(combs.iter().all(|c| c.pp <= 2));
        // (tp=1, pp=1, dp=32) must be present.
        assert!(combs.contains(&ModPar { tp: 1, pp: 1, dp: 32 }));
    }

    #[test]
    fn find_combs_count_example() {
        // gpus=8, node=8, max_pp=8: tp ∈ {1,2,4,8}; for each tp the
        // divisors of 8/tp define pp. 4+3+2+1 = 10 strategies.
        assert_eq!(find_combs(8, 8, 8).len(), 10);
    }

    #[test]
    fn theta_accounting() {
        let t = Theta {
            enc: ModPar { tp: 2, pp: 1, dp: 4 },
            llm: ModPar { tp: 4, pp: 3, dp: 2 },
            n_mb: 6,
        };
        assert_eq!(t.gpus(), 8 + 24);
        assert_eq!(t.pipeline_depth(), 4);
        assert_eq!(t.buckets(), 12);
    }
}
