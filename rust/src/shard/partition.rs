//! Deterministic per-shard dataset synthesis.
//!
//! A [`ShardedDataset`] gives every DP rank its own [`Dataset`]: an
//! independently-seeded stream of the rank's own reweighted Table-2
//! mixture, optionally with its own `MixSchedule` (the shard scenarios in
//! `data::sources`). Shard streams are fully independent — each shard owns
//! its RNG, seeded as a pure function of `(base seed, shard index)` — so
//! batches are reproducible regardless of the order shards are drawn or
//! simulated in.

use crate::data::dataset::Dataset;
use crate::data::item::ItemShape;
use crate::data::sources::{
    homogeneous_shard_scenario, hot_shard_scenario, laggard_shard_scenario,
    skewed_shard_scenario, table2_sources, ShardScenario,
};
use crate::model::catalog::Mllm;
use crate::profiling::engine::DataProfile;

/// Per-shard stream seed: decorrelate the shards without losing
/// reproducibility (same mixing constant as `util::rng`'s splitmix).
fn shard_seed(seed: u64, shard: usize) -> u64 {
    seed ^ (shard as u64 + 1).wrapping_mul(0x9E3779B97F4A7C15)
}

/// One dataset per DP rank.
#[derive(Clone, Debug)]
pub struct ShardedDataset {
    pub scenario: String,
    pub shards: Vec<Dataset>,
}

impl ShardedDataset {
    /// Materialize a scenario into per-shard datasets.
    pub fn from_scenario(sc: &ShardScenario, seed: u64) -> ShardedDataset {
        let shards = sc
            .mults
            .iter()
            .zip(&sc.schedules)
            .enumerate()
            .map(|(r, (mults, schedule))| {
                let name = format!("{}#{r}", sc.name);
                let s = shard_seed(seed, r);
                let mut d = match schedule {
                    Some(sched) => {
                        Dataset::scheduled(&name, table2_sources(), s, sched.clone())
                    }
                    None => Dataset::new(&name, table2_sources(), s),
                };
                d.reweight(mults);
                d
            })
            .collect();
        ShardedDataset { scenario: sc.name.to_string(), shards }
    }

    /// Look up a shard scenario by CLI key. The dedicated scenarios come
    /// from `data::sources`; any plain dataset key falls back to
    /// homogeneous shards of that dataset (independent streams, identical
    /// distribution) — the no-skew control.
    pub fn by_key(key: &str, shards: usize, seed: u64) -> Option<ShardedDataset> {
        let sc = match key {
            "skewed-shard" => Some(skewed_shard_scenario(shards)),
            "laggard-shard" => Some(laggard_shard_scenario(shards)),
            "hot-shard" => Some(hot_shard_scenario(shards)),
            "homogeneous-shard" => Some(homogeneous_shard_scenario(shards)),
            _ => None,
        };
        if let Some(sc) = sc {
            return Some(ShardedDataset::from_scenario(&sc, seed));
        }
        // Fallback: homogeneous shards of a plain dataset key.
        let per_shard: Option<Vec<Dataset>> = (0..shards)
            .map(|r| Dataset::by_key(key, shard_seed(seed, r)))
            .collect();
        per_shard.map(|shards| ShardedDataset { scenario: key.to_string(), shards })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Split a global batch as evenly as possible over `shards` ranks
    /// (the first `gbs mod shards` ranks take one extra item).
    pub fn split_counts(gbs: usize, shards: usize) -> Vec<usize> {
        assert!(shards >= 1, "split over zero shards");
        let base = gbs / shards;
        let rem = gbs % shards;
        (0..shards).map(|r| base + usize::from(r < rem)).collect()
    }

    /// Split a global batch over weighted slots (largest-remainder
    /// apportionment, ties to the lower index). A slot's share is
    /// proportional to its weight; every slot keeps at least one item
    /// whenever `gbs` covers it, so every active replica keeps drawing
    /// from its stream. Equal weights delegate to
    /// [`ShardedDataset::split_counts`] so the healthy path stays
    /// bit-identical to the even split.
    pub fn weighted_counts(gbs: usize, weights: &[f64]) -> Vec<usize> {
        assert!(!weights.is_empty(), "split over zero shards");
        assert!(
            weights.iter().all(|w| w.is_finite() && *w > 0.0),
            "weights must be positive and finite: {weights:?}"
        );
        let n = weights.len();
        if weights.iter().all(|w| *w == weights[0]) {
            return Self::split_counts(gbs, n);
        }
        let floor_each = usize::from(gbs >= n);
        let spare = gbs - floor_each * n;
        let total: f64 = weights.iter().sum();
        let quota: Vec<f64> = weights.iter().map(|w| spare as f64 * w / total).collect();
        let mut counts: Vec<usize> = quota.iter().map(|q| q.floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut by_frac: Vec<usize> = (0..n).collect();
        by_frac.sort_by(|&a, &b| {
            let (fa, fb) = (quota[a] - quota[a].floor(), quota[b] - quota[b].floor());
            fb.partial_cmp(&fa).expect("finite fractions").then(a.cmp(&b))
        });
        for &slot in by_frac.iter().take(spare - assigned) {
            counts[slot] += 1;
        }
        for c in &mut counts {
            *c += floor_each;
        }
        counts
    }

    /// Draw one global batch: `counts[r]` shaped items from shard r's own
    /// stream, in shard order.
    pub fn shard_batches(&mut self, m: &Mllm, counts: &[usize]) -> Vec<Vec<ItemShape>> {
        assert_eq!(counts.len(), self.shards.len(), "one count per shard");
        let members: Vec<usize> = (0..self.shards.len()).collect();
        self.shard_batches_members(m, &members, counts)
    }

    /// Draw one global batch over an elastic membership: `counts[i]`
    /// shaped items from shard `members[i]`'s own stream, in member
    /// order. Inactive shards are skipped entirely — their streams do
    /// not advance while they are out of the group — so the draw is a
    /// pure function of each member's own stream position, regardless of
    /// who else is in the group.
    pub fn shard_batches_members(
        &mut self,
        m: &Mllm,
        members: &[usize],
        counts: &[usize],
    ) -> Vec<Vec<ItemShape>> {
        assert_eq!(counts.len(), members.len(), "one count per active member");
        members
            .iter()
            .zip(counts)
            .map(|(&r, &n)| self.shards[r].shaped_batch(m, n))
            .collect()
    }

    /// The Data Profiler over a sharded corpus: sample every shard
    /// proportionally (split as [`ShardedDataset::split_counts`]), pool
    /// the shapes in shard order, and charge the same simulated per-item
    /// preprocessing cost as `profiling::engine::profile_data` — θ* for a
    /// sharded run is fitted to the *pooled* distribution, which is what
    /// the rebalancer steers every replica towards.
    pub fn profile_pooled(&mut self, m: &Mllm, n_samples: usize) -> DataProfile {
        let t0 = std::time::Instant::now();
        let counts = Self::split_counts(n_samples, self.n_shards());
        let mut pooled = Vec::with_capacity(n_samples);
        for batch in self.shard_batches(m, &counts) {
            pooled.extend(batch);
        }
        let simulated = n_samples as f64 * 0.018;
        let name = self.scenario.clone();
        DataProfile::from_samples(
            &name,
            m,
            pooled,
            simulated + t0.elapsed().as_secs_f64(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::catalog::{llama3, llava_ov};

    #[test]
    fn split_counts_partition_the_batch() {
        assert_eq!(ShardedDataset::split_counts(64, 4), vec![16, 16, 16, 16]);
        assert_eq!(ShardedDataset::split_counts(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(ShardedDataset::split_counts(3, 4), vec![1, 1, 1, 0]);
        for (gbs, s) in [(64, 4), (10, 4), (7, 3), (1, 1)] {
            assert_eq!(
                ShardedDataset::split_counts(gbs, s).iter().sum::<usize>(),
                gbs
            );
        }
    }

    #[test]
    fn weighted_counts_apportion_by_weight() {
        // Equal weights are bit-identical to the even split.
        assert_eq!(
            ShardedDataset::weighted_counts(10, &[1.0; 4]),
            ShardedDataset::split_counts(10, 4)
        );
        // A 2x-slower slot (half weight) draws roughly half the work,
        // and the split still partitions the batch exactly.
        let counts = ShardedDataset::weighted_counts(48, &[1.0, 0.5, 1.0, 1.0]);
        assert_eq!(counts.iter().sum::<usize>(), 48);
        assert!(counts[1] < counts[0], "{counts:?}");
        assert!(counts[1] >= 48 / 4 / 2, "{counts:?}");
        // Every slot keeps at least one item when the batch covers it.
        let tiny = ShardedDataset::weighted_counts(4, &[10.0, 0.1, 0.1, 0.1]);
        assert_eq!(tiny.iter().sum::<usize>(), 4);
        assert!(tiny.iter().all(|&c| c >= 1), "{tiny:?}");
        // Deterministic: same inputs, same split.
        assert_eq!(
            ShardedDataset::weighted_counts(31, &[1.0, 0.7, 0.4]),
            ShardedDataset::weighted_counts(31, &[1.0, 0.7, 0.4])
        );
    }

    #[test]
    fn member_draws_skip_inactive_shards_and_preserve_streams() {
        let m = llava_ov(llama3("8b"));
        let counts = ShardedDataset::split_counts(48, 4);
        let mut full = ShardedDataset::by_key("skewed-shard", 4, 9).expect("scenario");
        let mut elastic = ShardedDataset::by_key("skewed-shard", 4, 9).expect("scenario");
        // Full membership is bit-identical to the plain draw.
        let all: Vec<usize> = (0..4).collect();
        assert_eq!(
            full.shard_batches(&m, &counts),
            elastic.shard_batches_members(&m, &all, &counts)
        );
        // Skipping shard 3 for a draw leaves its stream untouched: each
        // shard's next batch depends only on its own stream position,
        // not on who else was in the group.
        let full_next = full.shard_batches(&m, &counts);
        let partial = elastic.shard_batches_members(&m, &[0, 1, 2], &counts[..3]);
        assert_eq!(partial[..], full_next[..3], "survivors draw as if nothing changed");
        let rejoined = elastic.shard_batches_members(&m, &[3], &counts[3..]);
        assert_eq!(
            rejoined[0], full_next[3],
            "the skipped shard resumes exactly where it left off"
        );
    }

    #[test]
    fn by_key_covers_scenarios_and_plain_datasets() {
        for key in [
            "skewed-shard",
            "laggard-shard",
            "hot-shard",
            "homogeneous-shard",
            "mixed",
            "curriculum",
        ] {
            let sd = ShardedDataset::by_key(key, 4, 1).unwrap_or_else(|| panic!("{key}"));
            assert_eq!(sd.n_shards(), 4);
        }
        assert!(ShardedDataset::by_key("bogus", 4, 1).is_none());
    }

    #[test]
    fn shard_streams_are_deterministic_and_decorrelated() {
        let m = llava_ov(llama3("8b"));
        let counts = ShardedDataset::split_counts(64, 4);
        let mut a = ShardedDataset::by_key("skewed-shard", 4, 9).expect("scenario");
        let mut b = ShardedDataset::by_key("skewed-shard", 4, 9).expect("scenario");
        let ba = a.shard_batches(&m, &counts);
        let bb = b.shard_batches(&m, &counts);
        assert_eq!(ba, bb, "same seed must reproduce the same shard batches");
        // Homogeneous shards draw from the same distribution but distinct
        // streams: identical per-shard seeds would make the replicas'
        // batches (and therefore their loads) identical, hiding all
        // sampling noise.
        let mut h = ShardedDataset::by_key("mixed", 2, 9).expect("fallback");
        let hb = h.shard_batches(&m, &[32, 32]);
        assert_ne!(hb[0], hb[1]);
    }

    #[test]
    fn skewed_scenario_shards_really_differ() {
        let m = llava_ov(llama3("8b"));
        let mut sd = ShardedDataset::by_key("skewed-shard", 4, 7).expect("scenario");
        let batches = sd.shard_batches(&m, &[400, 400, 400, 400]);
        let video_share = |b: &[ItemShape]| {
            b.iter().filter(|s| s.source == 4).count() as f64 / b.len() as f64
        };
        assert!(video_share(&batches[0]) > 0.6, "{}", video_share(&batches[0]));
        assert!(video_share(&batches[3]) < 0.05, "{}", video_share(&batches[3]));
        // The heavy shard's mean LLM sequence dwarfs the light shard's.
        let mean_seq = |b: &[ItemShape]| {
            b.iter().map(|s| s.llm_seq as f64).sum::<f64>() / b.len() as f64
        };
        assert!(
            mean_seq(&batches[0]) > 1.3 * mean_seq(&batches[3]),
            "video-heavy {} vs image-heavy {}",
            mean_seq(&batches[0]),
            mean_seq(&batches[3])
        );
    }

    #[test]
    fn pooled_profile_summarizes_all_shards() {
        let m = llava_ov(llama3("8b"));
        let mut sd = ShardedDataset::by_key("laggard-shard", 4, 3).expect("scenario");
        let p = sd.profile_pooled(&m, 200);
        assert_eq!(p.samples.len(), 200);
        assert_eq!(p.dataset_name, "laggard-shard");
        assert!(p.profiling_seconds >= 200.0 * 0.018);
        // The pool contains both the laggard's video and the others' mix.
        assert!(p.samples.iter().any(|s| s.source == 4));
        assert!(p.samples.iter().any(|s| s.source != 4));
    }
}
