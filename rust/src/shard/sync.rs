//! The cross-shard step barrier.
//!
//! Each DP shard is one full pipeline replica of the active θ. A training
//! step executes every replica's 1F1B iteration independently, then
//! synchronizes gradients across replicas — so the *step* time is the
//! slowest replica's iteration time plus the cross-shard allreduce, and
//! the max−min spread of replica times is the straggler gap the
//! rebalancer exists to shrink.
//!
//! Replica simulations are independent, so they fan out over the
//! `util::parallel` pool with results assembled in shard order
//! (`sim::run_cells`-style): the output is bit-identical to a serial loop
//! at any `--threads` setting. Each pool worker reuses its own
//! thread-local [`SimWorkspace`] (the one-arena-per-worker rule).

use crate::data::item::ItemShape;
use crate::model::catalog::Mllm;
use crate::optimizer::plan::Theta;
use crate::perfmodel::Truth;
use crate::pipeline::build::{iterate_ws, IterationStats, SystemPlan};
use crate::pipeline::sim::SimWorkspace;
use crate::profiling::estimator::Estimator;
use crate::scheduler::lpt::{lpt, ItemCost};
use crate::util::parallel::par_map;
use std::cell::RefCell;

/// One step's barrier accounting.
#[derive(Clone, Debug)]
pub struct BarrierStats {
    /// Per-replica iteration time (pipeline makespan + the replica's own
    /// intra-replica DP sync), in shard order.
    pub per_replica: Vec<f64>,
    /// Cross-shard gradient allreduce cost.
    pub allreduce: f64,
    /// The step: `max(per_replica) + allreduce`.
    pub step_time: f64,
    /// `max(per_replica) − min(per_replica)` — idle time the fastest
    /// replica burns waiting at the barrier.
    pub straggler_gap: f64,
}

/// Assemble the barrier from per-replica iteration times.
pub fn step_barrier(per_replica: Vec<f64>, allreduce: f64) -> BarrierStats {
    assert!(!per_replica.is_empty(), "barrier over zero replicas");
    let max = per_replica.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let min = per_replica.iter().cloned().fold(f64::INFINITY, f64::min);
    BarrierStats {
        step_time: max + allreduce,
        straggler_gap: max - min,
        per_replica,
        allreduce,
    }
}

/// Charge a persistent-straggler slowdown into one replica's iteration:
/// every time term (makespan, busy/idle, bucket execution, intra-replica
/// sync, the per-op `timeline` endpoints) stretches by `factor`, while
/// FLOP counts stay untouched — the replica does the same work on slower
/// hardware. The cross-shard merge still drops the timeline, but the
/// observability recorder captures it replica-tagged first, so its
/// endpoints must stay consistent with the stretched makespan. Charging
/// happens *before* the step barrier, so the factor flows into the step
/// time and the straggler gap exactly like organic data skew does.
pub fn charge_straggler(stats: &mut IterationStats, factor: f64) {
    assert!(factor >= 1.0, "slowdown factors are multipliers >= 1");
    stats.iteration_time *= factor;
    stats.pipeline_makespan *= factor;
    stats.dp_sync_time *= factor;
    for t in &mut stats.stage_busy {
        *t *= factor;
    }
    for t in &mut stats.stage_idle {
        *t *= factor;
    }
    for b in &mut stats.buckets {
        b.enc_time *= factor;
        b.llm_time *= factor;
    }
    for op in &mut stats.timeline {
        op.start *= factor;
        op.finish *= factor;
    }
}

/// A degraded cross-shard link stretches the second-level allreduce by
/// `link_factor` (≥ 1; 1.0 is a no-op, bit for bit).
pub fn degraded_allreduce(allreduce: f64, link_factor: f64) -> f64 {
    assert!(link_factor >= 1.0, "link factors are multipliers >= 1");
    allreduce * link_factor
}

/// Per-GPU gradient slice each module ships through the cross-shard ring
/// under θ: `(encoder bytes, llm bytes)`. The single source of the byte
/// term shared by [`cross_shard_allreduce`] and the hetero plan guard
/// (`engine::hetero::grad_slice_bytes`) — the guard is only sound while
/// it prices exactly what the allreduce charges.
pub fn grad_slices(m: &Mllm, theta: Theta) -> (f64, f64) {
    let enc = m.encoder.total_params(m.enc_mlp_matrices) * 2.0
        / (theta.enc.tp * theta.enc.pp) as f64;
    let llm = m.llm.total_params(m.llm_mlp_matrices) * 2.0
        / (theta.llm.tp * theta.llm.pp) as f64;
    (enc, llm)
}

/// Cross-shard gradient allreduce time under the two-level DP model: the
/// intra-replica reduction (θ's own `dp` groups) is already charged inside
/// the replica's iteration (`pipeline::build`); the second level reduces
/// the same per-GPU gradient slices across the `shards` replica groups.
/// Replicas span nodes by construction, so the inter-node ring applies.
pub fn cross_shard_allreduce(m: &Mllm, truth: &Truth, theta: Theta, shards: usize) -> f64 {
    if shards <= 1 {
        return 0.0;
    }
    let (enc_grad, llm_grad) = grad_slices(m, theta);
    truth
        .dp_allreduce_time(enc_grad, shards)
        .max(truth.dp_allreduce_time(llm_grad, shards))
}

/// Partition one replica's items into its `m = N_mb · L_dp` microbatch
/// buckets with the bi-metric LPT, heaviest bucket launched first —
/// the Online Scheduler's emission order, without the ILP pass. The
/// sharded path is deliberately budget-free: a deadline ILP returns
/// wall-clock-dependent incumbents, and the sharded telemetry (straggler
/// gaps, migrations) promises bit-identical results across `--threads`
/// settings (`tests/determinism.rs`).
pub fn lpt_shard_buckets(
    est: &Estimator,
    theta: Theta,
    shapes: &[ItemShape],
) -> Vec<Vec<ItemShape>> {
    let items: Vec<ItemCost> = shapes
        .iter()
        .map(|s| ItemCost {
            enc: est.enc_item_dur(s, theta.enc.tp) / theta.enc.pp as f64,
            llm: est.llm_item_dur(s, theta.llm.tp) / theta.llm.pp as f64,
        })
        .collect();
    let m = theta.buckets().min(shapes.len().max(1));
    let mut a = lpt(&items, m);
    let mut order = Vec::new();
    a.heavy_order(&mut order);
    a.apply_order(&order);
    a.buckets
        .iter()
        .map(|b| b.iter().map(|&i| shapes[i]).collect())
        .collect()
}

thread_local! {
    /// One simulation arena per pool worker for the replica fan-out.
    static SHARD_WS: RefCell<SimWorkspace> = RefCell::new(SimWorkspace::new());
}

/// Simulate every replica's iteration (`shard_buckets[r]` = shard r's
/// scheduled buckets) on the worker pool; results come back in shard
/// order, bit-identical to a serial loop.
pub fn simulate_shards(
    m: &Mllm,
    truth: &Truth,
    theta: Theta,
    shard_buckets: &[Vec<Vec<ItemShape>>],
) -> Vec<IterationStats> {
    par_map(shard_buckets.len(), |r| {
        SHARD_WS.with(|ws| {
            let plan = SystemPlan { m, truth, theta };
            iterate_ws(&plan, &shard_buckets[r], &mut ws.borrow_mut())
        })
    })
}

/// [`simulate_shards`] with one plan per replica — the heterogeneous
/// per-replica-θ path (`engine::hetero`): `thetas[r]` drives shard r's
/// pipeline. With every entry equal this computes exactly what
/// [`simulate_shards`] computes, bit for bit.
pub fn simulate_shards_hetero(
    m: &Mllm,
    truth: &Truth,
    thetas: &[Theta],
    shard_buckets: &[Vec<Vec<ItemShape>>],
) -> Vec<IterationStats> {
    assert_eq!(thetas.len(), shard_buckets.len(), "one plan per replica");
    par_map(shard_buckets.len(), |r| {
        SHARD_WS.with(|ws| {
            let plan = SystemPlan { m, truth, theta: thetas[r] };
            iterate_ws(&plan, &shard_buckets[r], &mut ws.borrow_mut())
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::Dataset;
    use crate::model::catalog::{llama3, llava_ov};
    use crate::optimizer::plan::ModPar;
    use crate::perfmodel::ClusterSpec;
    use crate::profiling::backend::SimBackend;
    use crate::profiling::engine::{ModelProfiler, ProfilerGrids};

    fn theta() -> Theta {
        Theta {
            enc: ModPar { tp: 1, pp: 1, dp: 1 },
            llm: ModPar { tp: 1, pp: 3, dp: 1 },
            n_mb: 4,
        }
    }

    #[test]
    fn barrier_is_max_plus_allreduce() {
        let b = step_barrier(vec![2.0, 5.0, 3.0], 0.25);
        assert_eq!(b.step_time, 5.25);
        assert_eq!(b.straggler_gap, 3.0);
        assert_eq!(b.per_replica.len(), 3);
        let single = step_barrier(vec![4.0], 0.0);
        assert_eq!(single.step_time, 4.0);
        assert_eq!(single.straggler_gap, 0.0);
    }

    #[test]
    fn straggler_charge_scales_time_terms_but_not_flops() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let th = theta();
        let mut ds = Dataset::mixed(13);
        let buckets = {
            let mut backend = SimBackend::new(truth.clone());
            let profile =
                ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
            let est = Estimator::new(&m, &profile.throughput);
            lpt_shard_buckets(&est, th, &ds.shaped_batch(&m, 12))
        };
        let plan = SystemPlan { m: &m, truth: &truth, theta: th };
        let mut ws = SimWorkspace::new();
        let healthy = iterate_ws(&plan, &buckets, &mut ws);
        let mut charged = healthy.clone();
        charge_straggler(&mut charged, 1.5);
        assert_eq!(charged.iteration_time, healthy.iteration_time * 1.5);
        assert_eq!(charged.pipeline_makespan, healthy.pipeline_makespan * 1.5);
        assert_eq!(charged.total_flop.to_bits(), healthy.total_flop.to_bits());
        for (c, h) in charged.buckets.iter().zip(&healthy.buckets) {
            assert_eq!(c.enc_time, h.enc_time * 1.5);
            assert_eq!(c.llm_time, h.llm_time * 1.5);
            assert_eq!(c.enc_flop.to_bits(), h.enc_flop.to_bits());
        }
        // The recorded timeline stretches with the makespan it sits in.
        assert!(!charged.timeline.is_empty());
        for (c, h) in charged.timeline.iter().zip(&healthy.timeline) {
            assert_eq!(c.start, h.start * 1.5);
            assert_eq!(c.finish, h.finish * 1.5);
        }
        // The charged replica raises the barrier like an organic laggard.
        let b = step_barrier(vec![healthy.iteration_time, charged.iteration_time], 0.0);
        assert!(b.straggler_gap > 0.0);
        assert_eq!(b.step_time, charged.iteration_time);
    }

    #[test]
    fn degraded_link_stretches_the_allreduce() {
        assert_eq!(degraded_allreduce(0.25, 2.0), 0.5);
        assert_eq!(degraded_allreduce(0.25, 1.0).to_bits(), 0.25_f64.to_bits());
    }

    #[test]
    fn cross_shard_allreduce_grows_with_shards_and_vanishes_alone() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        assert_eq!(cross_shard_allreduce(&m, &truth, theta(), 1), 0.0);
        let t2 = cross_shard_allreduce(&m, &truth, theta(), 2);
        let t8 = cross_shard_allreduce(&m, &truth, theta(), 8);
        assert!(t2 > 0.0);
        assert!(t8 > t2, "ring cost must grow with participants");
    }

    #[test]
    fn shard_fanout_matches_serial_loop_bitwise() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth.clone());
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let th = theta();
        let mut ds = Dataset::mixed(21);
        let shard_buckets: Vec<Vec<Vec<ItemShape>>> = (0..4)
            .map(|_| {
                let shapes = ds.shaped_batch(&m, 12);
                lpt_shard_buckets(&est, th, &shapes)
            })
            .collect();
        let fanned = simulate_shards(&m, &truth, th, &shard_buckets);
        let mut ws = SimWorkspace::new();
        for (r, stats) in fanned.iter().enumerate() {
            let plan = SystemPlan { m: &m, truth: &truth, theta: th };
            let serial = iterate_ws(&plan, &shard_buckets[r], &mut ws);
            assert_eq!(
                stats.iteration_time.to_bits(),
                serial.iteration_time.to_bits(),
                "replica {r}"
            );
            assert_eq!(stats.total_flop.to_bits(), serial.total_flop.to_bits());
        }
    }

    #[test]
    fn hetero_fanout_with_equal_plans_matches_homogeneous() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth.clone());
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let th = theta();
        let mut ds = Dataset::mixed(5);
        let shard_buckets: Vec<Vec<Vec<ItemShape>>> = (0..3)
            .map(|_| lpt_shard_buckets(&est, th, &ds.shaped_batch(&m, 10)))
            .collect();
        let homo = simulate_shards(&m, &truth, th, &shard_buckets);
        let het = simulate_shards_hetero(&m, &truth, &[th; 3], &shard_buckets);
        for (a, b) in homo.iter().zip(&het) {
            assert_eq!(a.iteration_time.to_bits(), b.iteration_time.to_bits());
            assert_eq!(a.n_stages, b.n_stages);
        }
        // A genuinely different plan changes the replica's stage layout.
        let mut deep = th;
        deep.llm.pp = 7;
        let mixed = simulate_shards_hetero(&m, &truth, &[th, deep, th], &shard_buckets);
        assert_eq!(mixed[0].n_stages, homo[0].n_stages);
        assert_eq!(mixed[1].n_stages, 1 + 7, "per-replica θ must drive the layout");
    }

    #[test]
    fn lpt_shard_buckets_partition_and_balance() {
        let m = llava_ov(llama3("8b"));
        let truth = Truth::smooth(ClusterSpec::hgx_a100(1));
        let mut backend = SimBackend::new(truth);
        let profile =
            ModelProfiler::new(&mut backend, ProfilerGrids::coarse(8)).profile(&m);
        let est = Estimator::new(&m, &profile.throughput);
        let th = theta();
        let shapes = Dataset::mixed(33).shaped_batch(&m, 17);
        let buckets = lpt_shard_buckets(&est, th, &shapes);
        assert_eq!(buckets.len(), th.buckets());
        assert_eq!(buckets.iter().map(Vec::len).sum::<usize>(), 17);
        // Tiny replica batches clamp the bucket count.
        let two = lpt_shard_buckets(&est, th, &shapes[..2]);
        assert_eq!(two.len(), 2);
        // Empty replica (everything migrated away) stays simulable.
        let empty = lpt_shard_buckets(&est, th, &[]);
        assert_eq!(empty.len(), 1);
        assert!(empty[0].is_empty());
    }
}
