//! Cross-shard rebalancing of the global batch.
//!
//! The Online Scheduler's Eq-6 objective lifted one level: shards play the
//! role of buckets, each carrying a bi-metric `(encoder, LLM)` load, and
//! the step bottleneck is `max_r max(E_r, L_r)` — the replica the
//! allreduce barrier waits for. Unlike the per-iteration bucket problem,
//! items here have *homes* (the shard whose data loader drew them) and a
//! migration is a real cost (the item's tensors cross replicas), so the
//! solver is not a fresh partition but a **bounded-migration walk** from
//! the static home assignment: repeatedly take the bottleneck shard and
//! move the single item that lowers the global objective most, until the
//! objective is within `min_gain` of the LPT lower bound, no single move
//! improves, or the migration budget is spent. Every choice is
//! deterministically tie-broken (donor/receiver by lowest shard index,
//! items by heaviest-then-lowest-index), so rebalance decisions are
//! bit-identical across thread counts and shard evaluation orders.
//!
//! No ILP deadline in this layer, deliberately: the sharded path promises
//! bit-identical telemetry across `--threads` settings
//! (`tests/determinism.rs`), and a budget-expiring branch-and-bound
//! returns a wall-clock-dependent incumbent. The greedy reuses the same
//! `ItemCost` pricing and `lower_bound` machinery as `scheduler::lpt`; the
//! branch-and-bound (`scheduler::ilp`) serves as the optimality oracle in
//! this module's tests instead.

use crate::scheduler::lpt::{lower_bound, ItemCost};

/// Balancer tuning.
#[derive(Clone, Copy, Debug)]
pub struct BalanceConfig {
    /// Largest fraction of the global batch allowed to migrate per step
    /// (migrations move activations between replicas — bounded, not free).
    pub migration_budget: f64,
    /// Stop once the bottleneck is within this relative margin of the
    /// perfect-balance lower bound — chasing the last percent buys
    /// nothing the pipeline sim can resolve.
    pub min_gain: f64,
}

impl Default for BalanceConfig {
    fn default() -> Self {
        BalanceConfig { migration_budget: 0.25, min_gain: 0.02 }
    }
}

/// One step's rebalancing decision.
#[derive(Clone, Debug)]
pub struct Rebalance {
    /// `shard_of[i]` = shard item i executes on (== `home[i]` when it did
    /// not migrate).
    pub shard_of: Vec<usize>,
    /// Items moved off their home shard.
    pub migrations: usize,
    /// Predicted step bottleneck before / after migration.
    pub bottleneck_before: f64,
    pub bottleneck_after: f64,
}

impl Rebalance {
    /// Per-shard item-index groups (ascending global index — the
    /// deterministic order the per-shard schedulers consume).
    pub fn groups(&self, shards: usize) -> Vec<Vec<usize>> {
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for (i, &r) in self.shard_of.iter().enumerate() {
            out[r].push(i);
        }
        out
    }
}

/// Rebalance `items` (priced per item by the Estimator at the active θ)
/// across `shards` replicas, starting from `home` (the shard that drew
/// each item).
pub fn rebalance(
    items: &[ItemCost],
    home: &[usize],
    shards: usize,
    cfg: &BalanceConfig,
) -> Rebalance {
    assert_eq!(items.len(), home.len(), "one home per item");
    assert!(shards >= 1, "at least one shard");
    let n = items.len();
    let mut shard_of = home.to_vec();
    let mut enc = vec![0.0f64; shards];
    let mut llm = vec![0.0f64; shards];
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); shards];
    for (i, &r) in home.iter().enumerate() {
        assert!(r < shards, "home {r} out of range");
        enc[r] += items[i].enc;
        llm[r] += items[i].llm;
        members[r].push(i);
    }
    // Candidate order is (heaviest item, lowest global index) — a *total*
    // order, so each shard's sorted member list is unique and can be kept
    // sorted incrementally across the whole walk: one binary-search remove
    // plus one binary-search insert per accepted migration, instead of a
    // clone + O(k log k) re-sort of the donor on every step. The iteration
    // order any step observes is bit-identical to the re-sorted clone, so
    // every decision (and hence the final assignment) is unchanged; the
    // pre-refactor implementation survives as the oracle in
    // `tests::incremental_walk_matches_resort_reference`.
    let cmp = |a: usize, b: usize| {
        let wa = items[a].enc + items[a].llm;
        let wb = items[b].enc + items[b].llm;
        wb.partial_cmp(&wa).expect("NaN cost").then(a.cmp(&b))
    };
    for list in members.iter_mut() {
        list.sort_by(|&a, &b| cmp(a, b));
    }
    let bneck = |enc: &[f64], llm: &[f64], r: usize| enc[r].max(llm[r]);
    let objective = |enc: &[f64], llm: &[f64]| {
        (0..shards).map(|r| bneck(enc, llm, r)).fold(0.0, f64::max)
    };

    let before = objective(&enc, &llm);
    let lb = lower_bound(items, shards);
    let target = lb * (1.0 + cfg.min_gain);
    let budget = ((cfg.migration_budget * n as f64).floor() as usize).min(n);
    let mut cur = before;
    let mut migrations = 0usize;

    while migrations < budget && cur > target {
        // Donor: the bottleneck shard (ties → lowest index).
        let mut d = 0usize;
        for r in 1..shards {
            if bneck(&enc, &llm, r) > bneck(&enc, &llm, d) {
                d = r;
            }
        }
        // Bottlenecks of everyone else, as top-2 (value, shard), so each
        // candidate pair evaluates in O(1).
        let (mut top1, mut top1_r, mut top2) = (f64::NEG_INFINITY, usize::MAX, f64::NEG_INFINITY);
        for r in 0..shards {
            if r == d {
                continue;
            }
            let b = bneck(&enc, &llm, r);
            if b > top1 {
                top2 = top1;
                top1 = b;
                top1_r = r;
            } else if b > top2 {
                top2 = b;
            }
        }
        // Best single move (item, receiver): smallest resulting
        // (objective, donor/receiver pair max) — the secondary key breaks
        // bottleneck *ties*: when several shards sit at the max, a move
        // that drops the donor strictly below it cannot lower the max yet,
        // but it shrinks the set of bottleneck shards, so accepting it
        // (see below) keeps the walk moving instead of stalling at the
        // first tie. Remaining ties keep the first candidate in (heaviest
        // item, lowest item index, lowest receiver index) order — exactly
        // the order `members[d]` is maintained in.
        let mut best: Option<(f64, f64, usize, usize)> = None;
        for &i in &members[d] {
            for r in 0..shards {
                if r == d {
                    continue;
                }
                let new_d = (enc[d] - items[i].enc).max(llm[d] - items[i].llm);
                let new_r = (enc[r] + items[i].enc).max(llm[r] + items[i].llm);
                let pair_max = new_d.max(new_r);
                let others = if r == top1_r { top2 } else { top1 };
                let new_obj = pair_max.max(others.max(0.0));
                let improves = match best {
                    None => true,
                    Some((bo, bp, _, _)) => {
                        new_obj < bo || (new_obj == bo && pair_max < bp)
                    }
                };
                if improves {
                    best = Some((new_obj, pair_max, i, r));
                }
            }
        }
        // Accept a strict objective improvement, or a tie-escape: the
        // donor and receiver both end strictly below the current
        // bottleneck while nobody else rose — the bottleneck set loses a
        // member, so the (max, #shards-at-max) potential still strictly
        // decreases and the walk terminates.
        let accepted = match best {
            Some((new_obj, pair_max, i, r))
                if new_obj < cur * (1.0 - 1e-12)
                    || (new_obj <= cur && pair_max < cur * (1.0 - 1e-12)) =>
            {
                enc[d] -= items[i].enc;
                llm[d] -= items[i].llm;
                enc[r] += items[i].enc;
                llm[r] += items[i].llm;
                let pos = members[d]
                    .binary_search_by(|&x| cmp(x, i))
                    .expect("chosen item is a donor member");
                members[d].remove(pos);
                let ins = match members[r].binary_search_by(|&x| cmp(x, i)) {
                    Ok(p) | Err(p) => p,
                };
                members[r].insert(ins, i);
                shard_of[i] = r;
                migrations += 1;
                cur = new_obj;
                true
            }
            // Local optimum: no single move helps.
            _ => false,
        };
        if !accepted {
            break;
        }
    }

    Rebalance {
        shard_of,
        migrations,
        bottleneck_before: before,
        bottleneck_after: cur,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn homes(n: usize, shards: usize) -> Vec<usize> {
        (0..n).map(|i| i * shards / n.max(1)).collect()
    }

    /// The pre-refactor walk, verbatim: clones and re-sorts the donor's
    /// member list on every step. Kept as the oracle for the
    /// incrementally-sorted production walk — the two must agree bit-wise.
    fn rebalance_reference(
        items: &[ItemCost],
        home: &[usize],
        shards: usize,
        cfg: &BalanceConfig,
    ) -> Rebalance {
        let n = items.len();
        let mut shard_of = home.to_vec();
        let mut enc = vec![0.0f64; shards];
        let mut llm = vec![0.0f64; shards];
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); shards];
        for (i, &r) in home.iter().enumerate() {
            enc[r] += items[i].enc;
            llm[r] += items[i].llm;
            members[r].push(i);
        }
        let bneck = |enc: &[f64], llm: &[f64], r: usize| enc[r].max(llm[r]);
        let objective = |enc: &[f64], llm: &[f64]| {
            (0..shards).map(|r| bneck(enc, llm, r)).fold(0.0, f64::max)
        };
        let before = objective(&enc, &llm);
        let lb = lower_bound(items, shards);
        let target = lb * (1.0 + cfg.min_gain);
        let budget = ((cfg.migration_budget * n as f64).floor() as usize).min(n);
        let mut cur = before;
        let mut migrations = 0usize;
        while migrations < budget && cur > target {
            let mut d = 0usize;
            for r in 1..shards {
                if bneck(&enc, &llm, r) > bneck(&enc, &llm, d) {
                    d = r;
                }
            }
            let (mut top1, mut top1_r, mut top2) =
                (f64::NEG_INFINITY, usize::MAX, f64::NEG_INFINITY);
            for r in 0..shards {
                if r == d {
                    continue;
                }
                let b = bneck(&enc, &llm, r);
                if b > top1 {
                    top2 = top1;
                    top1 = b;
                    top1_r = r;
                } else if b > top2 {
                    top2 = b;
                }
            }
            let mut order: Vec<usize> = members[d].clone();
            order.sort_by(|&a, &b| {
                let wa = items[a].enc + items[a].llm;
                let wb = items[b].enc + items[b].llm;
                wb.partial_cmp(&wa).expect("NaN cost").then(a.cmp(&b))
            });
            let mut best: Option<(f64, f64, usize, usize)> = None;
            for &i in &order {
                for r in 0..shards {
                    if r == d {
                        continue;
                    }
                    let new_d = (enc[d] - items[i].enc).max(llm[d] - items[i].llm);
                    let new_r = (enc[r] + items[i].enc).max(llm[r] + items[i].llm);
                    let pair_max = new_d.max(new_r);
                    let others = if r == top1_r { top2 } else { top1 };
                    let new_obj = pair_max.max(others.max(0.0));
                    let improves = match best {
                        None => true,
                        Some((bo, bp, _, _)) => {
                            new_obj < bo || (new_obj == bo && pair_max < bp)
                        }
                    };
                    if improves {
                        best = Some((new_obj, pair_max, i, r));
                    }
                }
            }
            let accepted = match best {
                Some((new_obj, pair_max, i, r))
                    if new_obj < cur * (1.0 - 1e-12)
                        || (new_obj <= cur && pair_max < cur * (1.0 - 1e-12)) =>
                {
                    enc[d] -= items[i].enc;
                    llm[d] -= items[i].llm;
                    enc[r] += items[i].enc;
                    llm[r] += items[i].llm;
                    members[d].retain(|&j| j != i);
                    members[r].push(i);
                    shard_of[i] = r;
                    migrations += 1;
                    cur = new_obj;
                    true
                }
                _ => false,
            };
            if !accepted {
                break;
            }
        }
        Rebalance {
            shard_of,
            migrations,
            bottleneck_before: before,
            bottleneck_after: cur,
        }
    }

    #[test]
    fn incremental_walk_matches_resort_reference() {
        forall("incremental vs re-sort walk", 150, |g| {
            let n = g.size(80);
            let shards = g.size(6);
            let dup = g.rng.below(2) == 0; // force weight ties sometimes
            let items: Vec<ItemCost> = (0..n)
                .map(|i| {
                    if dup && i % 3 == 0 {
                        ItemCost { enc: 0.5, llm: 2.0 }
                    } else {
                        ItemCost {
                            enc: g.rng.uniform(0.0, 2.0),
                            llm: g.rng.uniform(0.0, 5.0),
                        }
                    }
                })
                .collect();
            let home: Vec<usize> = (0..n).map(|_| g.rng.index(shards)).collect();
            let cfg = BalanceConfig {
                migration_budget: g.rng.uniform(0.05, 1.0),
                min_gain: g.rng.uniform(0.0, 0.05),
            };
            let a = rebalance(&items, &home, shards, &cfg);
            let b = rebalance_reference(&items, &home, shards, &cfg);
            let ok = a.shard_of == b.shard_of
                && a.migrations == b.migrations
                && a.bottleneck_before.to_bits() == b.bottleneck_before.to_bits()
                && a.bottleneck_after.to_bits() == b.bottleneck_after.to_bits();
            (format!("n={n} shards={shards} dup={dup} moved={}", a.migrations), ok)
        });
    }

    #[test]
    fn rebalance_preserves_the_partition() {
        forall("rebalance partition", 120, |g| {
            let n = g.size(60);
            let shards = g.size(6);
            let items: Vec<ItemCost> = (0..n)
                .map(|_| ItemCost {
                    enc: g.rng.uniform(0.0, 2.0),
                    llm: g.rng.uniform(0.0, 5.0),
                })
                .collect();
            let home: Vec<usize> = (0..n).map(|_| g.rng.index(shards)).collect();
            let r = rebalance(&items, &home, shards, &BalanceConfig::default());
            let groups = r.groups(shards);
            let total: usize = groups.iter().map(Vec::len).sum();
            let budget = (0.25 * n as f64).floor() as usize;
            let moved = r
                .shard_of
                .iter()
                .zip(&home)
                .filter(|(a, b)| a != b)
                .count();
            let ok = total == n
                && r.shard_of.iter().all(|&s| s < shards)
                && moved == r.migrations
                && r.migrations <= budget
                && r.bottleneck_after <= r.bottleneck_before + 1e-12;
            (format!("n={n} shards={shards} moved={moved}"), ok)
        });
    }

    #[test]
    fn skewed_homes_get_balanced_near_the_lower_bound() {
        // All the heavy items start on shard 0 — the laggard case. The
        // walk must land within a few percent of the perfect-balance
        // bound given a free budget.
        let mut items: Vec<ItemCost> = (0..16)
            .map(|i| ItemCost { enc: 0.1, llm: 4.0 + (i as f64) * 0.01 })
            .collect();
        items.extend((0..48).map(|i| ItemCost { enc: 0.1, llm: 0.5 + (i as f64) * 0.001 }));
        let home: Vec<usize> = (0..16).map(|_| 0).chain((0..48).map(|i| 1 + i % 3)).collect();
        let cfg = BalanceConfig { migration_budget: 1.0, min_gain: 0.02 };
        let r = rebalance(&items, &home, 4, &cfg);
        let lb = lower_bound(&items, 4);
        assert!(r.migrations > 0);
        assert!(
            r.bottleneck_after <= lb * 1.10,
            "after {} vs lb {lb}",
            r.bottleneck_after
        );
        assert!(r.bottleneck_after < 0.5 * r.bottleneck_before);
    }

    #[test]
    fn budget_bounds_migrations() {
        let items: Vec<ItemCost> =
            (0..40).map(|_| ItemCost { enc: 0.0, llm: 1.0 }).collect();
        let home = vec![0usize; 40]; // everything on one shard
        let cfg = BalanceConfig { migration_budget: 0.1, min_gain: 0.0 };
        let r = rebalance(&items, &home, 4, &cfg);
        assert_eq!(r.migrations, 4, "floor(0.1 · 40)");
        // And with a free budget the same instance balances fully.
        let free = BalanceConfig { migration_budget: 1.0, min_gain: 0.0 };
        let r = rebalance(&items, &home, 4, &free);
        assert!((r.bottleneck_after - 10.0).abs() < 1e-9);
    }

    #[test]
    fn balanced_homes_need_no_migration() {
        // Already within min_gain of the bound: not a single move.
        let items: Vec<ItemCost> =
            (0..32).map(|_| ItemCost { enc: 1.0, llm: 1.0 }).collect();
        let home: Vec<usize> = (0..32).map(|i| i % 4).collect();
        let r = rebalance(&items, &home, 4, &BalanceConfig::default());
        assert_eq!(r.migrations, 0);
        assert_eq!(r.shard_of, home);
        assert_eq!(r.bottleneck_before, r.bottleneck_after);
    }

    #[test]
    fn greedy_matches_ilp_oracle_on_a_small_instance() {
        // The branch-and-bound from the per-iteration scheduler is the
        // optimality oracle here: a small laggard instance where the
        // bounded walk should reach the ILP's bottleneck exactly (it only
        // needs to peel the two heavy items off shard 0).
        use crate::scheduler::ilp;
        use std::time::Duration;
        let items: Vec<ItemCost> = vec![
            ItemCost { enc: 0.2, llm: 3.0 },
            ItemCost { enc: 0.2, llm: 3.0 },
            ItemCost { enc: 0.2, llm: 3.0 },
            ItemCost { enc: 0.2, llm: 1.0 },
            ItemCost { enc: 0.2, llm: 1.0 },
            ItemCost { enc: 0.2, llm: 1.0 },
        ];
        let home = vec![0, 0, 0, 1, 2, 2];
        let cfg = BalanceConfig { migration_budget: 1.0, min_gain: 0.0 };
        let r = rebalance(&items, &home, 3, &cfg);
        let exact = ilp::solve(&items, 3, Duration::from_secs(10));
        assert!(exact.optimal, "oracle must finish");
        assert!(
            (r.bottleneck_after - exact.assignment.c_max()).abs() < 1e-9,
            "greedy {} vs ILP {}",
            r.bottleneck_after,
            exact.assignment.c_max()
        );
    }

    #[test]
    fn rebalance_is_a_pure_function() {
        // Same items, same homes → identical decision; this is the
        // shard-evaluation-order invariance at the unit level (the caller
        // always presents items in pooled shard order).
        let mut g = crate::util::rng::Rng::new(12);
        let items: Vec<ItemCost> = (0..64)
            .map(|_| ItemCost { enc: g.uniform(0.0, 1.0), llm: g.uniform(0.0, 4.0) })
            .collect();
        let home = homes(64, 4);
        let a = rebalance(&items, &home, 4, &BalanceConfig::default());
        let b = rebalance(&items, &home, 4, &BalanceConfig::default());
        assert_eq!(a.shard_of, b.shard_of);
        assert_eq!(a.migrations, b.migrations);
        assert_eq!(a.bottleneck_after.to_bits(), b.bottleneck_after.to_bits());
    }
}
