//! Sharded data-parallel execution: per-shard data heterogeneity,
//! cross-shard rebalancing, and global drift aggregation.
//!
//! DFLOP's scheduler balances microbatches *within* one pipeline replica,
//! but the paper's computation-skew problem recurs across the
//! data-parallel dimension: when DP shards draw from heterogeneous data
//! distributions (graded source skew, one persistent laggard, a shard
//! turning hot mid-run), the gradient allreduce barrier runs at the pace
//! of the slowest replica. This subsystem closes that gap:
//!
//! - [`partition`] — deterministic per-shard dataset synthesis: every DP
//!   rank owns its own reweighted Table-2 mixture (optionally with its
//!   own `MixSchedule`), built from the shard scenarios in
//!   `data::sources`.
//! - [`sync`] — the step barrier model: each replica's iteration time
//!   comes from its own 1F1B pipeline sim (fanned over the
//!   `util::parallel` pool, results in shard order), the step time is the
//!   max over replicas plus the cross-shard allreduce from `perfmodel`,
//!   and the max−min straggler gap is reported per iteration.
//! - [`balance`] — cross-shard rebalancing: the Eq-6 bi-metric bottleneck
//!   objective lifted one level (shards are the buckets), walked from the
//!   static home assignment by a bounded-migration greedy with
//!   deterministic tie-breaks, gated by a distributional skew statistic
//!   so statistically identical shards see zero migrations.
//! - [`agg`] — per-shard `ShapeStats` merged into one global window,
//!   bit-identical to a pooled recompute (all-integer merge), so
//!   `stream::drift`/`stream::replan` fire one *global* replan instead of
//!   per-shard thrash.
//!
//! `sim::trainer` wires this together as `SystemKind::DflopSharded`
//! (`dflop run --system sharded`); the whole path is budget-free (per-shard
//! LPT, no ILP deadline), so every reported statistic is bit-identical
//! across `--threads` settings and shard evaluation orders
//! (`tests/determinism.rs`).

pub mod agg;
pub mod balance;
pub mod partition;
pub mod sync;

pub use agg::{merge_shard_stats, ShardWindows};
pub use balance::{rebalance, BalanceConfig, Rebalance};
pub use partition::ShardedDataset;
pub use sync::{
    cross_shard_allreduce, lpt_shard_buckets, simulate_shards, simulate_shards_hetero,
    step_barrier, BarrierStats,
};

/// Configuration of a sharded run (carried on `sim::RunConfig`).
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Data-parallel shard (replica) count.
    pub dp_shards: usize,
    /// Cross-shard rebalancing on (the DFLOP sharded system) or off (the
    /// static-sharding baseline every comparison is against).
    pub rebalance: bool,
    /// Migration budget + stop threshold of the balancer.
    pub balance: BalanceConfig,
    /// Per-shard gate window width in global batches (the skew gate only
    /// evaluates once every shard's window is full).
    pub window_batches: usize,
    /// Skew score (max per-shard drift statistic vs the pooled window) at
    /// or above which rebalancing activates. Sized like
    /// `stream::drift`'s thresholds: statistically identical shards score
    /// well below it, the `data::sources` shard scenarios well above.
    pub skew_enter: f64,
    /// Heterogeneous per-replica plans (`engine::hetero`): once the skew
    /// gate confirms the shards genuinely differ, fit one θ_s per shard
    /// from its own recent shapes (global replan controller retained) and
    /// assign each replica the best-scoring fitted plan. Off by default;
    /// on homogeneous shards the gate never opens, so enabling this is
    /// bit-identical to the single global θ. Plans are fitted to the
    /// *drawn* (home) distributions; composed with `rebalance`, the
    /// migration walk moves at most `balance.migration_budget` of the
    /// batch, so the home mix still dominates what each replica executes
    /// — the controlled plan comparisons (tests, `--fig hetero`, the
    /// `hetero_plan` example) pin `rebalance: false`.
    pub hetero: bool,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            dp_shards: 4,
            rebalance: true,
            balance: BalanceConfig::default(),
            window_batches: 6,
            skew_enter: 0.35,
            hetero: false,
        }
    }
}
