//! Sharded data-parallel execution: per-shard data heterogeneity,
//! cross-shard rebalancing, and global drift aggregation.
//!
//! DFLOP's scheduler balances microbatches *within* one pipeline replica,
//! but the paper's computation-skew problem recurs across the
//! data-parallel dimension: when DP shards draw from heterogeneous data
//! distributions (graded source skew, one persistent laggard, a shard
//! turning hot mid-run), the gradient allreduce barrier runs at the pace
//! of the slowest replica. This subsystem closes that gap:
//!
//! - [`partition`] — deterministic per-shard dataset synthesis: every DP
//!   rank owns its own reweighted Table-2 mixture (optionally with its
//!   own `MixSchedule`), built from the shard scenarios in
//!   `data::sources`.
//! - [`sync`] — the step barrier model: each replica's iteration time
//!   comes from its own 1F1B pipeline sim (fanned over the
//!   `util::parallel` pool, results in shard order), the step time is the
//!   max over replicas plus the cross-shard allreduce from `perfmodel`,
//!   and the max−min straggler gap is reported per iteration.
//! - [`balance`] — cross-shard rebalancing: the Eq-6 bi-metric bottleneck
//!   objective lifted one level (shards are the buckets), walked from the
//!   static home assignment by a bounded-migration greedy with
//!   deterministic tie-breaks, gated by a distributional skew statistic
//!   so statistically identical shards see zero migrations.
//! - [`agg`] — per-shard `ShapeStats` merged into one global window,
//!   bit-identical to a pooled recompute (all-integer merge), so
//!   `stream::drift`/`stream::replan` fire one *global* replan instead of
//!   per-shard thrash.
//!
//! `sim::trainer` wires this together as `SystemKind::DflopSharded`
//! (`dflop run --system sharded`); the whole path is budget-free (per-shard
//! LPT, no ILP deadline), so every reported statistic is bit-identical
//! across `--threads` settings and shard evaluation orders
//! (`tests/determinism.rs`).

pub mod agg;
pub mod balance;
pub mod partition;
pub mod sync;

pub use agg::{merge_shard_stats, ShardWindows};
pub use balance::{rebalance, BalanceConfig, Rebalance};
pub use partition::ShardedDataset;
pub use sync::{
    cross_shard_allreduce, lpt_shard_buckets, simulate_shards, step_barrier, BarrierStats,
};

/// Configuration of a sharded run (carried on `sim::RunConfig`).
#[derive(Clone, Debug)]
pub struct ShardConfig {
    /// Data-parallel shard (replica) count.
    pub dp_shards: usize,
    /// Cross-shard rebalancing on (the DFLOP sharded system) or off (the
    /// static-sharding baseline every comparison is against).
    pub rebalance: bool,
    /// Migration budget + stop threshold of the balancer.
    pub balance: BalanceConfig,
    /// Per-shard gate window width in global batches (the skew gate only
    /// evaluates once every shard's window is full).
    pub window_batches: usize,
    /// Skew score (max per-shard drift statistic vs the pooled window) at
    /// or above which rebalancing activates. Sized like
    /// `stream::drift`'s thresholds: statistically identical shards score
    /// well below it, the `data::sources` shard scenarios well above.
    pub skew_enter: f64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            dp_shards: 4,
            rebalance: true,
            balance: BalanceConfig::default(),
            window_batches: 6,
            skew_enter: 0.35,
        }
    }
}
