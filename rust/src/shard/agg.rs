//! Cross-shard aggregation of shape statistics.
//!
//! Every shard summarizes its per-iteration batch into an exact integer
//! [`ShapeStats`]; merging those summaries is plain `u64` addition, so the
//! global aggregate is **bit-identical to a pooled recompute** over the
//! concatenated shapes — in any merge order, on any thread count. That
//! invariant (property-tested below) is what lets the sharded trainer run
//! *one* global drift detector over the merged window instead of one per
//! shard: `stream::replan` sees exactly the statistics it would have seen
//! on the pooled stream, so a distribution shift fires exactly one global
//! replan rather than S replica-local ones.
//!
//! The same per-shard summaries feed the rebalancer's *skew gate*: each
//! shard's window aggregate is scored against the pooled window with the
//! drift statistic (`stream::drift::stat_between`). Statistically
//! identical shards score near zero — so the homogeneous control performs
//! zero migrations — while the `data::sources` shard scenarios score far
//! above the gate.

use crate::stream::drift::{stat_between, DriftStat};
use crate::stream::window::{ShapeStats, ShapeWindow};

/// Merge per-shard batch summaries into the global batch summary. Exact:
/// all fields are integers, so the result equals
/// `ShapeStats::of_batch(pooled shapes)` bit for bit, independent of
/// shard order.
pub fn merge_shard_stats(stats: &[ShapeStats]) -> ShapeStats {
    let mut out = ShapeStats::default();
    for s in stats {
        out.merge(s);
    }
    out
}

/// Per-shard sliding windows plus the pooled view — the state behind the
/// rebalancer's skew gate.
#[derive(Clone, Debug)]
pub struct ShardWindows {
    windows: Vec<ShapeWindow>,
}

impl ShardWindows {
    pub fn new(shards: usize, capacity: usize) -> ShardWindows {
        assert!(shards >= 1, "at least one shard");
        ShardWindows {
            windows: (0..shards).map(|_| ShapeWindow::new(capacity)).collect(),
        }
    }

    /// Push one iteration's per-shard batch summaries (`per_shard[r]` is
    /// shard r's batch).
    pub fn push(&mut self, per_shard: Vec<ShapeStats>) {
        assert_eq!(per_shard.len(), self.windows.len(), "one summary per shard");
        for (w, s) in self.windows.iter_mut().zip(per_shard) {
            w.push_stats(s);
        }
    }

    /// True once every shard's window is full (the gate only evaluates
    /// then — early, short windows would make the skew score pure noise).
    pub fn is_full(&self) -> bool {
        self.windows.iter().all(ShapeWindow::is_full)
    }

    pub fn n_shards(&self) -> usize {
        self.windows.len()
    }

    /// Shard r's window aggregate.
    pub fn shard(&self, r: usize) -> &ShapeStats {
        self.windows[r].stats()
    }

    /// The pooled window aggregate (merge of the per-shard aggregates —
    /// bit-identical to a window over the concatenated batches).
    pub fn merged(&self) -> ShapeStats {
        let mut out = ShapeStats::default();
        for w in &self.windows {
            out.merge(w.stats());
        }
        out
    }

    /// The skew gate: the worst per-shard drift statistic against the
    /// pooled window, with its shard index (ties keep the lowest index).
    /// `None` until every window is full.
    pub fn max_skew(&self) -> Option<(usize, DriftStat)> {
        if !self.is_full() {
            return None;
        }
        let pooled = self.merged();
        let mut best: Option<(usize, DriftStat)> = None;
        for (r, w) in self.windows.iter().enumerate() {
            let stat = stat_between(&pooled, w.stats());
            let better = match &best {
                None => true,
                Some((_, b)) => stat.score() > b.score(),
            };
            if better {
                best = Some((r, stat));
            }
        }
        best
    }

    /// True when the worst shard's skew score reaches `enter`.
    pub fn skewed(&self, enter: f64) -> bool {
        self.max_skew().is_some_and(|(_, stat)| stat.score() >= enter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::item::ItemShape;
    use crate::util::prop::forall;

    fn item(g: &mut crate::util::prop::Gen) -> ItemShape {
        ItemShape {
            units: g.rng.below(65) as u32,
            llm_seq: 1 + g.rng.below(40_000) as u32,
            source: g.rng.below(6) as u8,
        }
    }

    #[test]
    fn merge_is_commutative_and_associative() {
        // The algebraic half of the shard::agg invariant: ⊕ is a
        // commutative monoid on ShapeStats (u64 addition field-wise), so
        // any merge tree over per-shard summaries yields the same bits.
        forall("ShapeStats merge comm/assoc", 100, |g| {
            let batch = |g: &mut crate::util::prop::Gen| {
                let n = g.size(40);
                let shapes: Vec<ItemShape> = (0..n).map(|_| item(g)).collect();
                ShapeStats::of_batch(&shapes)
            };
            let (a, b, c) = (batch(g), batch(g), batch(g));
            let mut ab = a.clone();
            ab.merge(&b);
            let mut ba = b.clone();
            ba.merge(&a);
            let comm = ab == ba;
            let mut ab_c = ab.clone();
            ab_c.merge(&c);
            let mut bc = b.clone();
            bc.merge(&c);
            let mut a_bc = a.clone();
            a_bc.merge(&bc);
            let assoc = ab_c == a_bc;
            // Identity: merging the default leaves the aggregate alone.
            let mut a_id = a.clone();
            a_id.merge(&ShapeStats::default());
            (format!("items {}/{}/{}", a.items, b.items, c.items), comm && assoc && a_id == a)
        });
    }

    #[test]
    fn k_shard_windows_bit_match_pooled_recompute() {
        // The invariant the sharded trainer relies on: merging K per-shard
        // windows equals (field for field) a from-scratch summarization of
        // the pooled retained shapes — after arbitrary push/evict
        // sequences, and regardless of the order the shard aggregates are
        // merged in.
        forall("K-shard merge == pooled recompute", 60, |g| {
            let shards = g.size(6);
            let cap = g.size(5);
            let mut sw = ShardWindows::new(shards, cap);
            // Retained raw shapes per shard, mirroring the windows.
            let mut kept: Vec<std::collections::VecDeque<Vec<ItemShape>>> =
                vec![std::collections::VecDeque::new(); shards];
            let iters = g.size(9);
            for _ in 0..iters {
                let mut per_shard = Vec::with_capacity(shards);
                for k in kept.iter_mut() {
                    let n = g.size(24);
                    let batch: Vec<ItemShape> = (0..n).map(|_| item(g)).collect();
                    per_shard.push(ShapeStats::of_batch(&batch));
                    k.push_back(batch);
                    if k.len() > cap {
                        k.pop_front();
                    }
                }
                sw.push(per_shard);
            }
            let mut pooled = ShapeStats::default();
            for k in &kept {
                for batch in k {
                    for s in batch {
                        pooled.add_item(s);
                    }
                }
            }
            let forward = sw.merged();
            // Reverse-order merge of the same aggregates.
            let mut reverse = ShapeStats::default();
            for r in (0..shards).rev() {
                reverse.merge(sw.shard(r));
            }
            let ok = forward == pooled && reverse == pooled;
            (format!("shards={shards} cap={cap} iters={iters}"), ok)
        });
    }

    #[test]
    fn skew_gate_separates_homogeneous_from_skewed() {
        use crate::model::catalog::{llama3, llava_ov};
        use crate::shard::partition::ShardedDataset;
        let m = llava_ov(llama3("8b"));
        let run = |key: &str| -> f64 {
            let mut sd = ShardedDataset::by_key(key, 4, 11).expect("scenario");
            let mut sw = ShardWindows::new(4, 6);
            let counts = ShardedDataset::split_counts(64, 4);
            let mut worst: f64 = 0.0;
            for _ in 0..10 {
                let batches = sd.shard_batches(&m, &counts);
                sw.push(batches.iter().map(|b| ShapeStats::of_batch(b)).collect());
                if let Some((_, stat)) = sw.max_skew() {
                    worst = worst.max(stat.score());
                }
            }
            worst
        };
        // The gate's separation property at the default threshold
        // (`ShardConfig::default().skew_enter` = 0.35): sampling noise
        // between statistically identical shards stays below it, the
        // graded scenario tilt lands far above it.
        let homog = run("mixed");
        let skew = run("skewed-shard");
        assert!(homog < 0.35, "homogeneous shards read as skewed: {homog}");
        assert!(skew >= 0.35, "skewed shards read as homogeneous: {skew}");
    }

    #[test]
    fn max_skew_waits_for_full_windows() {
        let mut sw = ShardWindows::new(2, 3);
        let shapes = vec![ItemShape { units: 2, llm_seq: 500, source: 0 }; 8];
        for _ in 0..2 {
            sw.push(vec![ShapeStats::of_batch(&shapes); 2]);
            assert!(sw.max_skew().is_none());
            assert!(!sw.skewed(0.0));
        }
        sw.push(vec![ShapeStats::of_batch(&shapes); 2]);
        let (r, stat) = sw.max_skew().expect("full");
        assert_eq!(r, 0, "tie must keep the lowest shard index");
        assert_eq!(stat.score(), 0.0);
    }
}
