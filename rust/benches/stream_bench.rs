//! Bench: the stream subsystem's steady-state and replan costs.
//!
//! The window ingest + drift statistic run on *every* training iteration,
//! so they must be negligible next to a scheduling call (µs, not ms). The
//! replan rows compare a cold `optimize` against the warm-started
//! `optimize_warm` the controller actually issues — the warm start's
//! incumbent-bound pruning is the reason a mid-run replan is affordable.
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llama3, llava_ov};
use dflop::optimizer::search::{optimize, optimize_warm, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};
use dflop::stream::drift::{DriftConfig, DriftDetector};
use dflop::stream::replan::live_profile;
use dflop::stream::window::ShapeWindow;

fn main() {
    println!("== stream_bench ==");
    let mut results = Vec::new();
    let m = llava_ov(llama3("8b"));

    // Steady-state path: ingest + drift statistic per iteration.
    let batch = Dataset::mixed(1).shaped_batch(&m, 512);
    let ingests = if common::quick() { 16 } else { 128 };
    let mut w = ShapeWindow::new(8);
    results.push(bench(
        &format!("window ingest {ingests} x 512-item batches"),
        10,
        || {
            for _ in 0..ingests {
                w.push(&batch);
            }
            std::hint::black_box(w.stats().items);
        },
    ));
    let det = DriftDetector::from_shapes(DriftConfig::default(), &batch);
    results.push(bench("drift statistic (sketch deciles + mix TV)", 10, || {
        std::hint::black_box(det.statistic(w.stats()).score());
    }));

    // Replan path: live-profile refit, then cold vs warm optimizer runs.
    let cluster = ClusterSpec::hgx_a100(1);
    let mut backend = SimBackend::new(Truth::new(cluster));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let data = profile_data(&m, &mut Dataset::mixed(7), 256);
    let inp = OptimizerInputs {
        m: &m,
        profile: &profile,
        data: &data,
        n_gpus: cluster.total_gpus(),
        gpus_per_node: cluster.gpus_per_node,
        mem_capacity: cluster.gpu.mem_bytes,
        gbs: 64,
        assume_balanced: true,
    };
    let star = optimize(&inp).expect("feasible").theta;
    results.push(bench("live-profile refit (384 shapes)", 10, || {
        let shapes = &batch[..384];
        std::hint::black_box(live_profile(&m, shapes).mean_seq());
    }));
    results.push(bench("cold optimize (8 GPUs, gbs 64)", 5, || {
        std::hint::black_box(optimize(&inp).expect("feasible").theta);
    }));
    results.push(bench("warm replan from incumbent theta*", 5, || {
        std::hint::black_box(optimize_warm(&inp, Some(star)).expect("feasible").theta);
    }));

    common::emit_json("stream_bench", &results);
}
