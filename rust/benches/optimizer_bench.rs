//! Bench: Data-aware 3D Parallelism Optimizer (paper Fig 16a).
//!
//! Target: < 200 ms at 1024 GPUs / GBS 2048 (the paper's "negligible even
//! for large clusters" claim).
mod common;
use common::bench;
use dflop::data::dataset::Dataset;
use dflop::model::catalog::{llava_ov, llama3};
use dflop::optimizer::search::{optimize, OptimizerInputs};
use dflop::perfmodel::{ClusterSpec, Truth};
use dflop::profiling::backend::SimBackend;
use dflop::profiling::engine::{profile_data, ModelProfiler, ProfilerGrids};

fn main() {
    let m = llava_ov(llama3("8b"));
    let mut backend = SimBackend::new(Truth::new(ClusterSpec::hgx_a100(1)));
    let profile = ModelProfiler::new(&mut backend, ProfilerGrids::standard(8)).profile(&m);
    let mut ds = Dataset::mixed(42);
    let data = profile_data(&m, &mut ds, 256);
    println!("== optimizer_bench (Fig 16a) ==");
    let mut results = Vec::new();
    for &(gpus, gbs) in &[(64usize, 512usize), (256, 1024), (1024, 2048)] {
        let inp = OptimizerInputs {
            m: &m,
            profile: &profile,
            data: &data,
            n_gpus: gpus,
            gpus_per_node: 8,
            mem_capacity: ClusterSpec::hgx_a100(1).gpu.mem_bytes,
            gbs,
            assume_balanced: true,
        };
        results.push(bench(&format!("optimize gpus={gpus} gbs={gbs}"), 3, || {
            let r = optimize(&inp).expect("feasible");
            std::hint::black_box(r.theta);
        }));
    }
    common::emit_json("optimizer_bench", &results);
}
